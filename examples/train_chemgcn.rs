//! End-to-end validation driver (DESIGN.md §6, "E2E validation"):
//! train the ChemGCN on the synthetic Tox21-like dataset with the
//! *batched* dispatch mode, log the loss curve, evaluate on a held-out
//! k-fold split, and save the trained parameters for the serving
//! example.
//!
//!     make artifacts && cargo run --release --example train_chemgcn -- \
//!         --samples 1000 --epochs 10 --lr 0.02
//!     # no artifacts? train on the host batched-SpMM engine instead:
//!     cargo run --release --example train_chemgcn -- --backend host --quick
//!
//! All layers compose here: synthetic molecules (S3) -> padded batches
//! (S1) -> either PJRT executions of the AOT'd train-step artifact
//! whose HLO embeds the L2 model and the L1 Pallas batched-SpMM
//! kernels (fwd AND bwd), or the host engine's fwd (`gcn::reference`)
//! + bwd (`gcn::backward`, DESIGN.md §8) -> rust training loop (S6).
//! The loss curve is recorded in EXPERIMENTS.md.

use std::path::Path;

use bspmm::coordinator::server::save_params_blob;
use bspmm::coordinator::trainer::{TrainMode, Trainer};
use bspmm::graph::dataset::{Dataset, DatasetKind};
use bspmm::util::cli::{parse_or_exit, Cli};
use bspmm::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let cli = Cli::new("train_chemgcn", "train ChemGCN on synthetic Tox21-like data")
        .opt("model", "tox21", "model: tox21 | reaction100")
        .opt("samples", "1000", "dataset size")
        .opt("epochs", "10", "training epochs")
        .opt("lr", "0.02", "SGD learning rate")
        .opt("seed", "42", "dataset seed")
        .opt("fold", "0", "k-fold test fold (k=5, paper §V-B)")
        .opt("mode", "batched", "dispatch mode: batched | nonbatched")
        .opt("backend", "pjrt", "execution backend: pjrt | host")
        .opt("threads", "0", "host-engine threads (0 = one per core)")
        .opt("out", "target/trained_params.bin", "trained parameter blob")
        .flag("quick", "tiny run (200 samples, 3 epochs)");
    let args = parse_or_exit(&cli);
    let quick = args.flag("quick");
    let n = if quick { 200 } else { args.usize("samples") };
    let epochs = if quick { 3 } else { args.usize("epochs") };
    let lr = args.f64("lr") as f32;
    let mode = match args.str("mode") {
        "batched" => TrainMode::Batched,
        "nonbatched" => TrainMode::NonBatched,
        other => anyhow::bail!("unknown mode {other}"),
    };

    let kind = match args.str("model") {
        "tox21" => DatasetKind::Tox21,
        "reaction100" => DatasetKind::Reaction100,
        other => anyhow::bail!("unknown model {other}"),
    };
    let mut tr = match args.str("backend") {
        "pjrt" => Trainer::new(Path::new("artifacts"), kind.model_name())?,
        "host" => Trainer::new_host(kind.model_name(), args.usize("threads"))?,
        other => anyhow::bail!("unknown backend {other} (use pjrt | host)"),
    };
    println!(
        "model {}: {} params, {} conv layers ({:?}), train batch {}",
        tr.cfg.name,
        tr.cfg.n_params,
        tr.cfg.hidden.len(),
        tr.cfg.hidden,
        tr.cfg.train_batch
    );

    let data = Dataset::generate(kind, n, args.u64("seed"));
    let (mut train_idx, test_idx) = data.kfold(5, args.usize("fold"));
    println!(
        "dataset: {} samples ({} train / {} test, fold {}/5)",
        n,
        train_idx.len(),
        test_idx.len(),
        args.usize("fold")
    );

    let (loss0, acc0) = tr.evaluate(&data, &test_idx)?;
    println!("before training: held-out loss {loss0:.4}, accuracy {acc0:.3}");

    let mut rng = Rng::new(1);
    let t0 = std::time::Instant::now();
    let mut curve = Vec::new();
    for epoch in 0..epochs {
        rng.shuffle(&mut train_idx);
        let stats = tr.train_epoch(mode, &data, &train_idx, lr, epoch)?;
        curve.push(stats.mean_loss);
        println!(
            "epoch {:>3}: loss {:.4}  ({:.2}s, {} dispatches)",
            epoch, stats.mean_loss, stats.secs, stats.dispatches
        );
    }
    let train_secs = t0.elapsed().as_secs_f64();

    let (loss1, acc1) = tr.evaluate(&data, &test_idx)?;
    println!(
        "after {epochs} epochs ({train_secs:.1}s, mode {:?}): held-out loss {loss1:.4} \
         (was {loss0:.4}), accuracy {acc1:.3} (was {acc0:.3})",
        mode
    );
    anyhow::ensure!(
        curve.last().unwrap() < curve.first().unwrap(),
        "training loss did not decrease: {curve:?}"
    );

    let out = Path::new(args.str("out"));
    if let Some(parent) = out.parent() {
        std::fs::create_dir_all(parent)?;
    }
    save_params_blob(&tr.params, out)?;
    println!("trained params -> {}", out.display());
    println!(
        "loss curve: {}",
        curve
            .iter()
            .map(|l| format!("{l:.4}"))
            .collect::<Vec<_>>()
            .join(" ")
    );
    Ok(())
}
