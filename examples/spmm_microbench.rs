//! SpMM micro-benchmark at a single user-chosen point, engine-first:
//! the batched-SpMM engine series (ST / CSR / ELL / dense-GEMM, plus
//! the cost-model-selected `auto` backend, DESIGN.md §11) in four
//! executor configurations — scalar serial baseline (the
//! pre-vectorization inner loops, DESIGN.md §10), vectorized serial
//! fallback, static-parallel (the legacy contiguous sample split) and
//! the work-stealing worker pool (DESIGN.md §9) — plus a host-engine
//! `train_step` line (full fwd + engine-dispatch backward + SGD,
//! DESIGN.md §8), a cold-plan vs cached-plan train-step line (the
//! plan/execute split, DESIGN.md §11) and, when the AOT artifacts
//! exist, the five measured + simulated §V-A series. The per-backend
//! summary lines report the scalar → vectorized kernel speedup, the
//! serial → parallel speedup, the auto-vs-best-fixed-backend ratio and
//! the plan-reuse speedup.
//!
//!     cargo run --release --example spmm_microbench -- --sweep fig8b --nb 64
//!     cargo run --release --example spmm_microbench -- --threads 4
//!     cargo run --release --example spmm_microbench -- --backend auto
//!     cargo run --release --example spmm_microbench -- --precision int8
//!     cargo run --release --example spmm_microbench -- --plan both
//!     cargo run --release --example spmm_microbench -- --plan aot
//!     cargo run --release --example spmm_microbench -- --json
//!     cargo run --release --example spmm_microbench -- --sweep large --json
//!     cargo run --release --example spmm_microbench -- --serve
//!
//! `--serve` runs the serving bench instead (DESIGN.md §14): offered
//! load × batch-close policy (fixed-size vs size-or-age) on the
//! host-engine server under a deterministic open-loop Poisson trace,
//! recording throughput-vs-latency curves (p50/p99/p99.9, shed counts,
//! occupancy) into `BENCH_serving.json` at the repo root.
//!
//! `--sweep large` runs the large-graph tier instead (DESIGN.md §12):
//! power-law graphs at 10^4/10^5/10^6 nodes (CI scale under
//! `BENCH_QUICK=1`), batch-of-one CSR dispatches comparing the
//! cache-tiled vs untiled kernels under static vs work-stealing
//! scheduling; with `--json` the series merge into `BENCH_engine.json`.
//!
//! `--precision` adds the quantized ELL inference series
//! (DESIGN.md §16): the adjacency dispatch from f32 vs bf16 vs int8
//! value storage, reporting bytes moved per dispatch alongside GFLOPS
//! and a speedup-vs-f32 summary line per quantized precision; the
//! figure merges into `BENCH_engine.json` under `--json`.
//!
//! `--plan aot` exercises the AOT plan-artifact round trip
//! (DESIGN.md §13): a producer trainer dumps its compiled plans, a
//! fresh trainer warm-starts from them, and the line reports the
//! cold-vs-warm first-step times plus the cold-start contract —
//! `plans_built=0` and bit-identical training.
//!
//! `--json` additionally runs the mixed-batch sweep (fig10, first n_B
//! point — the load-imbalance case stealing exists for) and writes the
//! whole scalar / serial / static / work-stealing comparison — auto
//! backend, train_step, cold-vs-cached plan_reuse and aot_warmstart
//! lines included — to `BENCH_engine.json` at the repository root so
//! the perf trajectory is machine-recorded across PRs.
//!
//! No artifacts are required for the engine, train_step or plan series:
//! sweep geometry falls back to the built-in copy of the aot.py table.

use std::path::Path;

use bspmm::bench::figures::{
    auto_choices, auto_vs_fixed_summary, engine_speedup_summary, precision_speedup_summary,
    run_aot_warmstart_bench, run_engine_bench_backends, run_large_graph_bench,
    run_mixed_serving_bench, run_plan_bench, run_precision_bench, run_serving_bench,
    run_train_step_bench, FigureRunner, ENGINE_SERIES,
};
use bspmm::bench::report::save_json_in;
use bspmm::bench::BenchOpts;
use bspmm::runtime::artifact::SweepSpec;
use bspmm::runtime::Runtime;
use bspmm::sparse::engine::{Backend, Executor};
use bspmm::util::cli::{parse_or_exit, Cli};
use bspmm::util::json::{arr, num, obj, parse, s, Json};

fn main() -> anyhow::Result<()> {
    let cli = Cli::new("spmm_microbench", "one-point SpMM comparison")
        .opt(
            "sweep",
            "fig8b",
            "sweep key: fig8a|fig8b|fig9a..fig9f|fig10, or 'large' for the \
             power-law large-graph node-count sweep (tiled vs untiled CSR)",
        )
        .opt("nb", "64", "dense input width n_B (must exist in the sweep)")
        .opt("threads", "0", "parallel executor threads (0 = one per core)")
        .opt("backend", "all", "engine series: all|st|csr|ell|gemm|auto")
        .opt(
            "precision",
            "all",
            "quantized ELL inference series (DESIGN.md §16): all|f32|bf16|int8. \
             f32 skips the precision figure (the plain engine series already \
             covers f32); bf16/int8 run that precision against the f32 \
             baseline; all runs both. Each precision reports GFLOPS and \
             bytes moved per dispatch, plus a speedup-vs-f32 summary line",
        )
        .opt(
            "plan",
            "cached",
            "train-step plan regime: cached|cold|both|aot. cached (default) skips the \
             plan_reuse line unless --json; cold and both are synonyms that run the \
             cold-vs-cached comparison (the speedup line needs both regimes); aot \
             round-trips compiled plans through AOT artifacts and warm-starts a \
             fresh trainer from them (DESIGN.md §13)",
        )
        .opt("train_model", "tox21", "model for the train_step line")
        .opt("train_batch", "50", "train_step minibatch size (0 = skip)")
        .flag(
            "json",
            "also run the fig10 mixed sweep and write BENCH_engine.json at the repo root",
        )
        .flag(
            "serve",
            "run the serving bench instead: offered load x batch policy on the \
             host-engine server, writing BENCH_serving.json at the repo root",
        );
    let args = parse_or_exit(&cli);

    // The serving bench (DESIGN.md §14) drives a live host-engine
    // server under open-loop load — a different harness from the
    // kernel sweeps, so it short-circuits like `--sweep large`. It
    // always writes its own JSON record (BENCH_serving.json), merge
    // semantics unneeded: the file has a single producer.
    if args.flag("serve") {
        let bench = run_serving_bench(args.str("train_model"), args.usize("threads"))?;
        print!("{}", bench.render());
        // The mixed-model sweep (DESIGN.md §15): two registered models
        // round-robined at one server with a mid-trace parameter hot
        // swap, merged into the same record under the "mixed" key.
        let mixed = run_mixed_serving_bench(args.usize("threads"))?;
        print!("{}", mixed.render());
        let mut record = bench.to_json();
        if let Json::Obj(m) = &mut record {
            m.insert("mixed".into(), mixed.to_json());
        }
        let root = Path::new(env!("CARGO_MANIFEST_DIR"))
            .parent()
            .unwrap_or_else(|| Path::new("."));
        let path = save_json_in(root, "BENCH_serving", &record)?;
        println!("wrote {}\n", path.display());
        return Ok(());
    }

    let rt = match Runtime::new_default() {
        Ok(rt) => Some(rt),
        Err(e) => {
            println!("note: PJRT runtime unavailable — engine series only ({e:#})\n");
            None
        }
    };
    let key = args.str("sweep");

    // The large-graph tier sweep (DESIGN.md §12) is a node-count sweep
    // over generated power-law graphs, not a manifest SweepSpec — so
    // handle it before the key resolution below (which would bail on
    // the unknown key). `BENCH_QUICK=1` shrinks the node counts to CI
    // scale; `--json` merges the figure into the repo-root
    // `BENCH_engine.json` record instead of clobbering it.
    if key == "large" {
        let nodes: Vec<usize> = if std::env::var("BENCH_QUICK").is_ok() {
            vec![5_000, 20_000]
        } else {
            vec![10_000, 100_000, 1_000_000]
        };
        let opts = BenchOpts::from_env();
        let fig = run_large_graph_bench(&nodes, 4, args.usize("nb"), args.usize("threads"), &opts)?;
        println!("{}", fig.render());
        if args.flag("json") {
            let root = Path::new(env!("CARGO_MANIFEST_DIR"))
                .parent()
                .unwrap_or_else(|| Path::new("."));
            let mut record = std::fs::read_to_string(root.join("BENCH_engine.json"))
                .ok()
                .and_then(|t| parse(&t).ok())
                .unwrap_or_else(|| obj(vec![("key", s("BENCH_engine"))]));
            if let Json::Obj(m) = &mut record {
                m.insert("large_graph".into(), fig.to_json());
            }
            let path = save_json_in(root, "BENCH_engine", &record)?;
            println!("wrote {}\n", path.display());
        }
        return Ok(());
    }

    let mut sw = match &rt {
        Some(rt) => rt.manifest.sweep(key)?,
        None => SweepSpec::builtin(key)?,
    };
    let nb = args.usize("nb");
    anyhow::ensure!(
        sw.nbs.contains(&nb),
        "n_B {nb} not in sweep {} (available: {:?})",
        sw.key,
        sw.nbs
    );
    sw.nbs = vec![nb];

    // Engine series: one dispatch per whole batch, scalar baseline vs
    // vectorized serial vs static parallel vs work-stealing pool, for
    // the requested backend list (auto = cost-model selection).
    let backends: Vec<Backend> = match args.str("backend") {
        "all" => ENGINE_SERIES.to_vec(),
        one => vec![Backend::parse(one)?],
    };
    let opts = BenchOpts::from_env();
    let threads = args.usize("threads");
    let engine = run_engine_bench_backends(&sw, threads, &opts, &backends)?;
    println!("{}", engine.render());
    print!("{}", engine_speedup_summary(&engine));
    if backends.contains(&Backend::Auto) {
        print!("{}", auto_vs_fixed_summary(&engine));
        for (nb, chosen) in auto_choices(&sw)? {
            println!("  auto choice at n_B={nb}: {chosen}");
        }
    }
    println!();
    let mut figures = vec![engine];

    // Quantized inference precision series (DESIGN.md §16): the ELL
    // adjacency dispatch from f32 vs bf16 vs int8 value storage —
    // GFLOPS next to bytes moved per dispatch, with speedup-vs-f32
    // summary lines, merged into the same JSON record.
    let precision = args.str("precision");
    anyhow::ensure!(
        matches!(precision, "all" | "f32" | "bf16" | "int8"),
        "--precision must be all|f32|bf16|int8, got '{precision}'"
    );
    if precision != "f32" {
        let mut pfig = run_precision_bench(&sw, threads, &opts)?;
        if precision != "all" {
            // Keep the f32 baseline pair (the speedup denominator)
            // plus the requested precision's pair.
            pfig.series.retain(|ser| {
                ser.name.contains("[f32]") || ser.name.contains(&format!("[{precision}]"))
            });
        }
        println!("{}", pfig.render());
        print!("{}", precision_speedup_summary(&pfig));
        println!();
        figures.push(pfig);
    }

    // The mixed-batch sweep (Fig. 10 geometry): the skewed case the
    // work-stealing decomposition exists for. Only run for the JSON
    // record — it is the expensive point.
    if args.flag("json") && sw.key != "fig10" {
        let mut mixed = match &rt {
            Some(rt) => rt.manifest.sweep("fig10")?,
            None => SweepSpec::builtin("fig10")?,
        };
        mixed.nbs.truncate(1);
        let mixed_fig = run_engine_bench_backends(&mixed, threads, &opts, &backends)?;
        println!("{}", mixed_fig.render());
        print!("{}", engine_speedup_summary(&mixed_fig));
        if backends.contains(&Backend::Auto) {
            print!("{}", auto_vs_fixed_summary(&mixed_fig));
        }
        println!();
        figures.push(mixed_fig);
    }

    // Training-side counterpart: one host train_step (fwd + backward +
    // SGD, every matmul an engine dispatch) per iteration, serial vs
    // one persistent pool — plus the cold-vs-cached plan comparison
    // when requested (the plan/execute split, DESIGN.md §11).
    let tb = args.usize("train_batch");
    let mut train = None;
    let mut plan_bench = None;
    let mut aot_bench = None;
    if tb > 0 {
        let t = run_train_step_bench(args.str("train_model"), tb, threads, &opts)?;
        print!("{}", t.render());
        train = Some(t);
        let plan_mode = args.str("plan");
        anyhow::ensure!(
            matches!(plan_mode, "cached" | "cold" | "both" | "aot"),
            "--plan must be cached|cold|both|aot, got '{plan_mode}'"
        );
        if matches!(plan_mode, "cold" | "both") || args.flag("json") {
            let p = run_plan_bench(args.str("train_model"), tb, threads, &opts)?;
            print!("{}", p.render());
            plan_bench = Some(p);
        }
        // The AOT round trip: dump compiled plans as artifacts, boot a
        // fresh trainer from them, assert plans_built == 0 with
        // bit-identical training (the §13 cold-start contract).
        if plan_mode == "aot" || args.flag("json") {
            let a = run_aot_warmstart_bench(args.str("train_model"), tb, threads, &opts)?;
            print!("{}", a.render());
            anyhow::ensure!(
                a.plans_built == 0 && a.bit_identical,
                "AOT warm-start contract violated: plans_built={}, bit_identical={}",
                a.plans_built,
                a.bit_identical
            );
            aot_bench = Some(a);
        }
        println!();
    }

    if args.flag("json") {
        // Record the resolved worker count (not the raw CLI value,
        // where 0 means auto) so records from different machines stay
        // comparable.
        let mut fields = vec![
            ("key", s("BENCH_engine")),
            ("threads", num(Executor::resolve_threads(threads) as f64)),
            (
                "figures",
                arr(figures.iter().map(|f| f.to_json()).collect()),
            ),
        ];
        if let Some(t) = &train {
            fields.push(("train_step", t.to_json()));
        }
        if let Some(p) = &plan_bench {
            fields.push(("plan_reuse", p.to_json()));
        }
        if let Some(a) = &aot_bench {
            fields.push(("aot_warmstart", a.to_json()));
        }
        // CARGO_MANIFEST_DIR is rust/, so the repo root is its parent —
        // stable regardless of the invoking working directory.
        let root = Path::new(env!("CARGO_MANIFEST_DIR"))
            .parent()
            .unwrap_or_else(|| Path::new("."));
        let path = save_json_in(root, "BENCH_engine", &obj(fields))?;
        println!("wrote {}\n", path.display());
    }

    if let Some(rt) = &rt {
        let runner = FigureRunner::new(rt);
        let measured = runner.run_measured(&sw)?;
        println!("{}", measured.render());
        let sim = runner.run_simulated(&sw)?;
        println!("{}", sim.render());
    }
    Ok(())
}
