//! SpMM micro-benchmark at a single user-chosen point: all five §V-A
//! approaches, measured (CPU-PJRT) and simulated (P100 cost model).
//!
//!     cargo run --release --example spmm_microbench -- --sweep fig8a --nb 64

use bspmm::bench::figures::FigureRunner;
use bspmm::runtime::Runtime;
use bspmm::util::cli::{parse_or_exit, Cli};

fn main() -> anyhow::Result<()> {
    let cli = Cli::new("spmm_microbench", "one-point SpMM comparison")
        .opt("sweep", "fig8a", "sweep key: fig8a|fig8b|fig9a..fig9f|fig10")
        .opt("nb", "64", "dense input width n_B (must exist in the sweep)");
    let args = parse_or_exit(&cli);

    let rt = Runtime::new_default()?;
    let mut sw = rt.manifest.sweep(args.str("sweep"))?;
    let nb = args.usize("nb");
    anyhow::ensure!(
        sw.nbs.contains(&nb),
        "n_B {nb} not in sweep {} (available: {:?})",
        sw.key,
        sw.nbs
    );
    sw.nbs = vec![nb];

    let runner = FigureRunner::new(&rt);
    let measured = runner.run_measured(&sw)?;
    println!("{}", measured.render());
    let sim = runner.run_simulated(&sw)?;
    println!("{}", sim.render());
    Ok(())
}
