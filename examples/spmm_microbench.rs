//! SpMM micro-benchmark at a single user-chosen point, engine-first:
//! the four batched-SpMM engine backends (ST / CSR / ELL / dense-GEMM),
//! serial fallback vs the sample-parallel executor, and a host-engine
//! `train_step` line (full fwd + engine-dispatch backward + SGD,
//! DESIGN.md §8) — plus, when the AOT artifacts exist, the five
//! measured + simulated §V-A series.
//!
//!     cargo run --release --example spmm_microbench -- --sweep fig8b --nb 64
//!     cargo run --release --example spmm_microbench -- --threads 4
//!
//! No artifacts are required for the engine or train_step series: sweep
//! geometry falls back to the built-in copy of the aot.py table.

use bspmm::bench::figures::{
    engine_speedup_summary, run_engine_bench, run_train_step_bench, FigureRunner,
};
use bspmm::bench::BenchOpts;
use bspmm::runtime::artifact::SweepSpec;
use bspmm::runtime::Runtime;
use bspmm::util::cli::{parse_or_exit, Cli};

fn main() -> anyhow::Result<()> {
    let cli = Cli::new("spmm_microbench", "one-point SpMM comparison")
        .opt("sweep", "fig8b", "sweep key: fig8a|fig8b|fig9a..fig9f|fig10")
        .opt("nb", "64", "dense input width n_B (must exist in the sweep)")
        .opt("threads", "0", "parallel executor threads (0 = one per core)")
        .opt("train_model", "tox21", "model for the train_step line")
        .opt("train_batch", "50", "train_step minibatch size (0 = skip)");
    let args = parse_or_exit(&cli);

    let rt = match Runtime::new_default() {
        Ok(rt) => Some(rt),
        Err(e) => {
            println!("note: PJRT runtime unavailable — engine series only ({e:#})\n");
            None
        }
    };
    let key = args.str("sweep");
    let mut sw = match &rt {
        Some(rt) => rt.manifest.sweep(key)?,
        None => SweepSpec::builtin(key)?,
    };
    let nb = args.usize("nb");
    anyhow::ensure!(
        sw.nbs.contains(&nb),
        "n_B {nb} not in sweep {} (available: {:?})",
        sw.key,
        sw.nbs
    );
    sw.nbs = vec![nb];

    // Engine backends: one dispatch per whole batch, serial vs parallel.
    let opts = BenchOpts::from_env();
    let engine = run_engine_bench(&sw, args.usize("threads"), &opts)?;
    println!("{}", engine.render());
    print!("{}", engine_speedup_summary(&engine));
    println!();

    // Training-side counterpart: one host train_step (fwd + backward +
    // SGD, every matmul an engine dispatch), serial vs parallel.
    let tb = args.usize("train_batch");
    if tb > 0 {
        print!(
            "{}",
            run_train_step_bench(args.str("train_model"), tb, args.usize("threads"), &opts)?
        );
        println!();
    }

    if let Some(rt) = &rt {
        let runner = FigureRunner::new(rt);
        let measured = runner.run_measured(&sw)?;
        println!("{}", measured.render());
        let sim = runner.run_simulated(&sw)?;
        println!("{}", sim.render());
    }
    Ok(())
}
