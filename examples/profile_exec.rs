//! Dev profiling aid: phase breakdown of one heavy batched execute.
use bspmm::bench::workload::SpmmWorkload;
use bspmm::runtime::artifact::SweepSpec;
use bspmm::runtime::Runtime;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::new_default()?;
    let sw = SweepSpec { key: "p".into(), dim: 50, z: 2, batch: 100, nbs: vec![512], mixed: false };
    let w = SpmmWorkload::build(&sw, 512)?;
    let exe = rt.executable("spmm_st_d50_z2_n512_b100")?;
    let inputs = w.st_batched_inputs();
    exe.execute(&inputs)?; // warmup
    let t0 = Instant::now();
    let lits: Vec<xla::Literal> = inputs.iter().map(|t| t.to_literal().unwrap()).collect();
    println!("literal creation: {:?}", t0.elapsed());
    drop(lits);
    let t0 = Instant::now();
    let out = exe.execute(&inputs)?;
    println!("full execute: {:?}, out len {}", t0.elapsed(), out[0].len());
    // gemm comparison
    let gexe = rt.executable("gemm_d50_n512_b100")?;
    let ginputs = w.gemm_inputs();
    gexe.execute(&ginputs)?;
    let t0 = Instant::now();
    gexe.execute(&ginputs)?;
    println!("gemm execute: {:?}", t0.elapsed());
    Ok(())
}
