//! Quickstart: load the AOT artifacts, run one Batched SpMM and one
//! ChemGCN forward pass, and cross-check both against the pure-rust
//! oracles.
//!
//!     make artifacts && cargo run --release --example quickstart

use std::path::Path;

use bspmm::gcn::params::ParamSet;
use bspmm::gcn::reference;
use bspmm::graph::dataset::{Dataset, DatasetKind};
use bspmm::runtime::{Runtime, Tensor};
use bspmm::sparse::batch::{random_dense_batch, PaddedStBatch};
use bspmm::sparse::ops;
use bspmm::sparse::random::{random_batch, RandomSpec};
use bspmm::sparse::Dense;
use bspmm::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::new(Path::new("artifacts"))?;
    println!(
        "runtime up: platform={}, {} artifacts in manifest",
        rt.client.platform_name(),
        rt.manifest.artifacts.len()
    );

    // ---- 1. Batched SpMM on a random batch (the paper's §V-A setup) ----
    let sw = rt.manifest.sweep("fig8a")?;
    let nb = 64;
    let mut rng = Rng::new(7);
    let mats = random_batch(&mut rng, &RandomSpec::new(sw.dim, sw.z), sw.batch);
    let st = PaddedStBatch::pack(&mats, sw.dim, sw.nnz_cap())?;
    let dense = random_dense_batch(&mut rng, sw.batch, sw.dim, nb);
    let out = rt.run(
        &sw.st_batched(nb),
        &[
            Tensor::i32(&[sw.batch, sw.nnz_cap(), 2], st.ids.clone()),
            Tensor::f32(&[sw.batch, sw.nnz_cap()], st.vals.clone()),
            Tensor::f32(&[sw.batch, sw.dim, nb], dense.clone()),
        ],
    )?;
    let got = out[0].as_f32()?;
    // Cross-check matrix 0 against the CPU oracle.
    let expect = ops::spmm_st(
        &mats[0].to_sparse_tensor(),
        &Dense {
            rows: sw.dim,
            cols: nb,
            data: dense[..sw.dim * nb].to_vec(),
        },
    );
    let max_diff = got[..sw.dim * nb]
        .iter()
        .zip(&expect.data)
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max)
        ;
    println!(
        "batched SpMM over {} matrices: OK (max |diff| vs oracle = {max_diff:.2e})",
        sw.batch
    );

    // ---- 2. ChemGCN forward over a synthetic Tox21-like batch ----------
    let cfg = rt.manifest.model("tox21")?.clone();
    let ps = ParamSet::load_init(&cfg, &rt.manifest.dir)?;
    let data = Dataset::generate(DatasetKind::Tox21, cfg.train_batch, 1);
    let idx: Vec<usize> = (0..cfg.train_batch).collect();
    let mb = data.pack_batch(&idx, cfg.max_nodes, cfg.ell_width)?;
    let mut inputs: Vec<Tensor> = cfg
        .params
        .iter()
        .zip(ps.views(&cfg))
        .map(|(p, v)| Tensor::f32(&p.shape, v.to_vec()))
        .collect();
    inputs.push(Tensor::i32(
        &[mb.batch, mb.channels, mb.max_nodes, mb.ell_width],
        mb.ell_cols.clone(),
    ));
    inputs.push(Tensor::f32(
        &[mb.batch, mb.channels, mb.max_nodes, mb.ell_width],
        mb.ell_vals.clone(),
    ));
    inputs.push(Tensor::f32(&[mb.batch, mb.max_nodes, mb.feat_dim], mb.x.clone()));
    inputs.push(Tensor::f32(&[mb.batch, mb.max_nodes], mb.mask.clone()));
    let out = rt.run(&cfg.artifact_fwd_train, &inputs)?;
    let logits = out[0].as_f32()?;
    let oracle = reference::forward(&cfg, &ps, &mb)?;
    let max_diff = logits
        .iter()
        .zip(&oracle)
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max);
    let loss = reference::loss(&cfg, logits, &mb.labels, mb.batch);
    println!(
        "ChemGCN forward over {} molecules: loss = {loss:.4} \
         (max |diff| vs rust oracle = {max_diff:.2e})",
        mb.batch
    );
    println!("quickstart OK");
    Ok(())
}
