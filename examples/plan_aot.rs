//! AOT step-plan artifact round trip (DESIGN.md §13): compile a
//! model's forward + train plans, dump them as versioned,
//! content-hashed `*.plan.json` artifacts, warm-start a fresh trainer
//! from them, and prove the cold-start contract — the warm trainer
//! compiles zero plans (`plans_built == 0`) and trains bit-identically
//! to a cold boot.
//!
//!     cargo run --release --example plan_aot
//!     cargo run --release --example plan_aot -- --dir artifacts/plans
//!     cargo run --release --example plan_aot -- --model tox21 --batch 50 --steps 5
//!
//! Without `--dir` the artifacts go to a process-scoped temp directory
//! that is removed on success; with `--dir` they are written there and
//! kept, ready for a server boot with
//! `BSPMM_PLAN_ARTIFACTS=<dir>` (the Trainer/HostDispatcher
//! constructors warm-start from that env var).

use std::path::PathBuf;

use bspmm::coordinator::trainer::Trainer;
use bspmm::graph::dataset::{Dataset, DatasetKind};
use bspmm::util::cli::{parse_or_exit, Cli};

fn main() -> anyhow::Result<()> {
    let cli = Cli::new(
        "plan_aot",
        "AOT step-plan artifact dump/load round trip (DESIGN.md §13)",
    )
    .opt(
        "dir",
        "",
        "artifact directory; written there and kept when given, else a \
         temp directory removed on success",
    )
    .opt("model", "tox21", "synthetic model config: tox21|reaction100")
    .opt("batch", "4", "minibatch size (any geometry works)")
    .opt("threads", "1", "executor threads (0 = one per core)")
    .opt("steps", "3", "parity train steps run on each side")
    .flag("keep", "keep a temp artifact directory instead of removing it");
    let args = parse_or_exit(&cli);
    let model = args.str("model");
    let batch = args.usize("batch");
    let steps = args.usize("steps").max(1);
    let threads = args.usize("threads");
    let (dir, ephemeral): (PathBuf, bool) = match args.str("dir") {
        "" => (
            std::env::temp_dir().join(format!("bspmm_plan_aot_{}", std::process::id())),
            true,
        ),
        d => (PathBuf::from(d), false),
    };
    let kind = match model {
        "tox21" => DatasetKind::Tox21,
        "reaction100" => DatasetKind::Reaction100,
        other => anyhow::bail!("no dataset for model '{other}'"),
    };
    let data = Dataset::generate(kind, batch, 77);
    let idx: Vec<usize> = (0..batch).collect();
    let lr = 1e-3f32;

    // Dump side: compile this geometry's forward and train plans, then
    // export every cached plan as an artifact.
    let mut producer = Trainer::new_host(model, threads)?;
    let mb = data.pack_batch(&idx, producer.cfg.max_nodes, producer.cfg.ell_width)?;
    producer.forward(&mb)?;
    producer.step_batched(&mb, lr)?;
    let n = producer.export_plans(&dir)?;
    println!("dumped {n} plan artifact(s) to {}", dir.display());

    // Load side: a fresh trainer warm-starts from the artifacts ...
    let mut warm = Trainer::new_host(model, threads)?;
    let report = warm.warm_start_plans(&dir)?;
    println!("{}", report.summary());
    // Duplicates count as warmed: with `BSPMM_PLAN_ARTIFACTS` pointing
    // at `dir`, the constructor already loaded these artifacts and the
    // explicit pass sees them as cache hits.
    anyhow::ensure!(
        report.loaded + report.skipped_duplicate >= 1,
        "warm start found no usable artifacts"
    );

    // ... and must match a cold boot bit-for-bit while compiling
    // nothing: same seed parameters, same minibatch, so the loss
    // stream, parameters, and logits are all exactly comparable.
    let mut cold = Trainer::new_host(model, threads)?;
    for step in 0..steps {
        let a = cold.step_batched(&mb, lr)?;
        let b = warm.step_batched(&mb, lr)?;
        anyhow::ensure!(
            a.to_bits() == b.to_bits(),
            "step {step}: cold loss {a} != warm loss {b}"
        );
    }
    anyhow::ensure!(
        cold.params.data == warm.params.data,
        "parameters diverged between cold and warm training"
    );
    let cf = cold.forward(&mb)?;
    let wf = warm.forward(&mb)?;
    anyhow::ensure!(cf == wf, "forward logits diverged");
    let ws = warm.plan_stats();
    anyhow::ensure!(
        ws.plans_built == 0,
        "warm trainer compiled {} plan(s) — the artifacts did not cover its geometries",
        ws.plans_built
    );
    println!(
        "round trip OK: {} warmed plan(s), plans_built=0, {} replays, bit-identical \
         across {steps} train steps + forward",
        ws.plans_warmed, ws.replays
    );
    if ephemeral && !args.flag("keep") {
        let _ = std::fs::remove_dir_all(&dir);
    }
    Ok(())
}
