//! Serving driver: load a (trained) ChemGCN and serve molecule
//! classification requests through the dynamic-batching coordinator,
//! comparing batched vs per-sample dispatch — the paper's Table III
//! scenario as a live system.
//!
//!     cargo run --release --example train_chemgcn   # optional: params
//!     cargo run --release --example serve_molecules -- --requests 600
//!
//! Reports throughput, latency percentiles, and batch occupancy for
//! both modes.

use std::path::PathBuf;
use std::time::Duration;

use bspmm::coordinator::server::{DispatchMode, ServeBackend, Server, ServerConfig};
use bspmm::coordinator::CloseRule;
use bspmm::graph::dataset::{Dataset, DatasetKind};
use bspmm::util::cli::{parse_or_exit, Cli};

fn run_mode(
    mode: DispatchMode,
    max_batch: usize,
    wait_ms: u64,
    data: &Dataset,
    params: Option<PathBuf>,
) -> anyhow::Result<()> {
    let label = match mode {
        DispatchMode::Batched => format!("batched(cap {max_batch}, wait {wait_ms}ms)"),
        DispatchMode::PerSample => "per-sample".to_string(),
    };
    let srv = Server::start(ServerConfig {
        artifacts_dir: PathBuf::from("artifacts"),
        model: "tox21".into(),
        mode,
        backend: ServeBackend::Pjrt,
        max_batch,
        max_wait: Duration::from_millis(wait_ms),
        close: CloseRule::SizeOrAge,
        queue_bound: 0,
        deadline: None,
        params_path: params,
        registry: None,
        plans_dir: None,
    })?;
    // Warmup (compile + first dispatch) outside the measurement.
    srv.submit(data.samples[0].mol.clone())
        .recv_timeout(Duration::from_secs(300))
        .map_err(|_| anyhow::anyhow!("warmup timeout"))?;

    let t0 = std::time::Instant::now();
    let rxs: Vec<_> = data
        .samples
        .iter()
        .map(|s| srv.submit(s.mol.clone()))
        .collect();
    let mut positive = 0usize;
    for rx in rxs {
        let resp = rx
            .recv_timeout(Duration::from_secs(600))
            .map_err(|_| anyhow::anyhow!("response timeout"))?;
        positive += resp.logits.iter().filter(|&&l| l > 0.0).count();
    }
    let secs = t0.elapsed().as_secs_f64();
    let m = srv.shutdown()?;
    println!(
        "{label:>32}: {:>7.1} req/s | latency mean {:>7.2}ms p95 {:>7.2}ms | \
         {} batches, occupancy {:.0}% | {} positive task-flags",
        m.requests as f64 / secs,
        m.mean_latency_us / 1e3,
        m.p95_latency_us as f64 / 1e3,
        m.batches,
        m.mean_occupancy * 100.0,
        positive,
    );
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let cli = Cli::new("serve_molecules", "batched vs per-sample molecule serving")
        .opt("requests", "600", "number of requests")
        .opt("batch", "200", "batched-mode capacity (paper: 200)")
        .opt("wait-ms", "5", "batcher deadline")
        .opt("params", "", "trained parameter blob (empty = init params)")
        .flag("quick", "smaller run");
    let args = parse_or_exit(&cli);
    let n = if args.flag("quick") { 150 } else { args.usize("requests") };
    let params = match args.str("params") {
        "" => None,
        p => Some(PathBuf::from(p)),
    };

    let data = Dataset::generate(DatasetKind::Tox21, n, 0xD06);
    println!("serving {n} synthetic molecules through ChemGCN (tox21)\n");
    run_mode(
        DispatchMode::Batched,
        args.usize("batch"),
        args.u64("wait-ms"),
        &data,
        params.clone(),
    )?;
    run_mode(DispatchMode::PerSample, 1, 0, &data, params)?;
    println!("\n(batched row should dominate throughput — the Table III effect)");
    Ok(())
}
