//! Host-engine forward dispatch: the in-process CPU twin of the PJRT
//! artifact dispatch paths, built on the batched-SpMM engine.
//!
//! The server and trainer choose between two execution backends; both
//! realize the same batched/per-sample contrast the paper measures:
//!
//! * **PJRT** — artifact executes on the device runtime (requires
//!   `make artifacts`);
//! * **Host engine** — `gcn::reference::forward_with` on a
//!   [`sparse::engine::Executor`](crate::sparse::engine::Executor), so
//!   every multiplication routes through the [`BatchedSpmm`]
//!   trait — no artifacts needed, and the executor's thread count is
//!   the speedup knob.
//!
//! [`BatchedSpmm`]: crate::sparse::engine::BatchedSpmm

use crate::coordinator::server::DispatchMode;
use crate::gcn::config::ModelConfig;
use crate::gcn::params::ParamSet;
use crate::gcn::reference;
use crate::graph::dataset::ModelBatch;
use crate::sparse::engine::Executor;

/// In-process model execution over the batched-SpMM engine.
pub struct HostDispatcher {
    pub cfg: ModelConfig,
    pub params: ParamSet,
    exec: Executor,
    /// Forward dispatches issued (1 per batch in Batched mode, 1 per
    /// sample in PerSample mode) — the same signal the PJRT paths count.
    pub dispatches: u64,
}

impl HostDispatcher {
    /// `threads = 0` means one thread per core.
    pub fn new(cfg: ModelConfig, params: ParamSet, threads: usize) -> HostDispatcher {
        HostDispatcher {
            cfg,
            params,
            exec: Executor::auto(threads),
            dispatches: 0,
        }
    }

    /// Manifest-free construction from the named synthetic model config.
    pub fn synthetic(model: &str, threads: usize, seed: u64) -> anyhow::Result<HostDispatcher> {
        let cfg = ModelConfig::synthetic(model)?;
        let params = ParamSet::random_init(&cfg, seed);
        Ok(HostDispatcher::new(cfg, params, threads))
    }

    pub fn executor(&self) -> &Executor {
        &self.exec
    }

    /// Forward a packed batch: one engine-batched dispatch, or one
    /// batch-1 dispatch per sample (the non-batched baseline).
    pub fn forward(&mut self, mode: DispatchMode, mb: &ModelBatch) -> anyhow::Result<Vec<f32>> {
        match mode {
            DispatchMode::Batched => {
                self.dispatches += 1;
                reference::forward_with(&self.cfg, &self.params, mb, &self.exec)
            }
            DispatchMode::PerSample => {
                let n = self.cfg.n_out;
                let mut logits = vec![0f32; mb.batch * n];
                for bi in 0..mb.batch {
                    let one = mb.single(bi);
                    let l = reference::forward_with(&self.cfg, &self.params, &one, &self.exec)?;
                    self.dispatches += 1;
                    logits[bi * n..(bi + 1) * n].copy_from_slice(&l);
                }
                Ok(logits)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::dataset::{Dataset, DatasetKind};

    #[test]
    fn batched_and_per_sample_agree() {
        let mut hd = HostDispatcher::synthetic("tox21", 1, 3).unwrap();
        let d = Dataset::generate(DatasetKind::Tox21, 6, 8);
        let idx: Vec<usize> = (0..6).collect();
        let mb = d
            .pack_batch(&idx, hd.cfg.max_nodes, hd.cfg.ell_width)
            .unwrap();
        let batched = hd.forward(DispatchMode::Batched, &mb).unwrap();
        let single = hd.forward(DispatchMode::PerSample, &mb).unwrap();
        assert_eq!(batched.len(), 6 * 12);
        for (i, (a, b)) in batched.iter().zip(&single).enumerate() {
            assert!(
                (a - b).abs() <= 1e-5 + 1e-5 * b.abs(),
                "logit {i}: batched {a} vs per-sample {b}"
            );
        }
        // 1 batched dispatch + 6 per-sample dispatches.
        assert_eq!(hd.dispatches, 7);
    }

    #[test]
    fn thread_count_does_not_change_logits() {
        let d = Dataset::generate(DatasetKind::Tox21, 5, 8);
        let idx: Vec<usize> = (0..5).collect();
        let mut serial = HostDispatcher::synthetic("tox21", 1, 3).unwrap();
        let mut parallel = HostDispatcher::synthetic("tox21", 8, 3).unwrap();
        let mb = d
            .pack_batch(&idx, serial.cfg.max_nodes, serial.cfg.ell_width)
            .unwrap();
        let a = serial.forward(DispatchMode::Batched, &mb).unwrap();
        let b = parallel.forward(DispatchMode::Batched, &mb).unwrap();
        assert_eq!(a, b);
    }
}
