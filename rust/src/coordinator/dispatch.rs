//! Host-engine forward dispatch: the in-process CPU twin of the PJRT
//! artifact dispatch paths, built on the batched-SpMM engine.
//!
//! The server and trainer choose between two execution backends; both
//! realize the same batched/per-sample contrast the paper measures:
//!
//! * **PJRT** — artifact executes on the device runtime (requires
//!   `make artifacts`);
//! * **Host engine** — `gcn::reference::forward_with_readout` on a
//!   [`sparse::engine::Executor`](crate::sparse::engine::Executor), so
//!   every multiplication routes through the [`BatchedSpmm`]
//!   trait — no artifacts needed, and the executor's thread count is
//!   the speedup knob.
//!
//! The dispatcher caches the tiled readout weight `w_rep`
//! ([`crate::gcn::reference::build_w_rep`]) — a pure function of
//! `readout.w`, ~10 MB per forward on reaction100 if rebuilt each call.
//! Replace parameters through [`HostDispatcher::set_params`] (or call
//! [`HostDispatcher::invalidate_cache`] after mutating
//! [`HostDispatcher::params`] directly) so the cache never goes stale.
//!
//! Forwards run plan/execute split (DESIGN.md §11): the dispatcher
//! keeps one compiled [`StepPlan`](crate::sparse::engine::StepPlan) +
//! [`Workspace`](crate::sparse::engine::Workspace) per batch geometry
//! in a [`PlanCache`] — built on the first batch of that shape,
//! replayed for every batch after it with zero intermediate
//! allocations. Geometry changes (batch size, node bucket) compile a
//! new entry; parameter updates keep every plan (only `w_rep` is
//! parameter-derived). [`HostDispatcher::plan_stats`] exposes the
//! accounting.
//!
//! [`BatchedSpmm`]: crate::sparse::engine::BatchedSpmm

use std::sync::Arc;

use crate::coordinator::registry::ModelRegistry;
use crate::coordinator::server::DispatchMode;
use crate::coordinator::trainer::Precision;
use crate::gcn::config::ModelConfig;
use crate::gcn::params::ParamSet;
use crate::gcn::reference;
use crate::graph::dataset::ModelBatch;
use crate::runtime::plan_artifact::{self, WarmStartReport};
use crate::sparse::engine::{
    AutoThresholds, Backend, Executor, GeometryKey, PlanCache, PlanStats, RhsKind,
    TenantPlanCaches,
};

/// In-process model execution over the batched-SpMM engine.
pub struct HostDispatcher {
    pub cfg: ModelConfig,
    /// Mutate only via [`HostDispatcher::set_params`], or follow direct
    /// edits with [`HostDispatcher::invalidate_cache`].
    pub params: ParamSet,
    /// One executor — and with it one persistent
    /// [`WorkerPool`](crate::sparse::engine::WorkerPool) — for the
    /// dispatcher's whole lifetime: every forward it serves runs on the
    /// same parked workers, with zero thread spawns after construction
    /// (DESIGN.md §9).
    exec: Executor,
    /// Cached tiled readout weight; lazily rebuilt after invalidation.
    w_rep: Option<Vec<f32>>,
    /// One compiled (plan, workspace) per batch geometry (DESIGN.md
    /// §11). Never invalidated by parameter updates.
    plans: PlanCache,
    /// Auto-backend decision thresholds baked into new plans.
    thresholds: AutoThresholds,
    /// Forward dispatches issued (1 per batch in Batched mode, 1 per
    /// sample in PerSample mode) — the same signal the PJRT paths count.
    pub dispatches: u64,
}

impl HostDispatcher {
    /// `threads = 0` means one thread per core.
    ///
    /// When `$BSPMM_PLAN_ARTIFACTS` is set the plan cache warm-starts
    /// from that directory (best-effort — this constructor is
    /// infallible, so a bad artifact directory loads nothing and every
    /// geometry compiles at runtime; use
    /// [`HostDispatcher::warm_start_plans`] when you want the report).
    pub fn new(cfg: ModelConfig, params: ParamSet, threads: usize) -> HostDispatcher {
        let thresholds = AutoThresholds::from_env();
        let mut plans = PlanCache::new();
        let _ = plan_artifact::warm_start_from_env(&mut plans, &thresholds);
        HostDispatcher {
            cfg,
            params,
            exec: Executor::auto(threads),
            w_rep: None,
            plans,
            thresholds,
            dispatches: 0,
        }
    }

    /// Warm-start the plan cache from `dir`'s `*.plan.json` artifacts
    /// (DESIGN.md §13). Threshold-mismatched or invalid artifacts are
    /// skipped — those geometries fall back to runtime compilation.
    pub fn warm_start_plans(&mut self, dir: &std::path::Path) -> anyhow::Result<WarmStartReport> {
        plan_artifact::warm_start(&mut self.plans, dir, &self.thresholds)
    }

    /// Dump every cached plan to `dir` as AOT artifacts (the producer
    /// side of [`HostDispatcher::warm_start_plans`]); returns how many
    /// were written.
    pub fn export_plans(&self, dir: &std::path::Path) -> anyhow::Result<usize> {
        let mut n = 0;
        for plan in self.plans.plans() {
            plan_artifact::save(plan, &self.thresholds, dir)?;
            n += 1;
        }
        Ok(n)
    }

    /// Manifest-free construction from the named synthetic model config.
    pub fn synthetic(model: &str, threads: usize, seed: u64) -> anyhow::Result<HostDispatcher> {
        let cfg = ModelConfig::synthetic(model)?;
        let params = ParamSet::random_init(&cfg, seed);
        Ok(HostDispatcher::new(cfg, params, threads))
    }

    /// The dispatcher's long-lived executor (a handle on its one
    /// worker pool).
    pub fn executor(&self) -> &Executor {
        &self.exec
    }

    /// Replace the parameter set (e.g. after training elsewhere) and
    /// drop parameter-derived caches.
    pub fn set_params(&mut self, params: ParamSet) {
        self.params = params;
        self.w_rep = None;
    }

    /// Drop parameter-derived caches after a direct `params` mutation.
    /// Plans are geometry-derived and survive.
    pub fn invalidate_cache(&mut self) {
        self.w_rep = None;
    }

    /// Plan/arena accounting across every geometry this dispatcher has
    /// served (DESIGN.md §11).
    pub fn plan_stats(&self) -> PlanStats {
        self.plans.stats()
    }

    /// Forward a packed batch: one engine-batched dispatch, or one
    /// batch-1 dispatch per sample (the non-batched baseline). Both
    /// reuse the cached readout tiling, and both replay a cached step
    /// plan — the per-sample mode shares one batch-1 plan + workspace
    /// across all its samples.
    pub fn forward(&mut self, mode: DispatchMode, mb: &ModelBatch) -> anyhow::Result<Vec<f32>> {
        if self.w_rep.is_none() {
            self.w_rep = Some(reference::build_w_rep(&self.cfg, &self.params)?);
        }
        let w_rep = self.w_rep.as_deref().unwrap();
        let cfg = &self.cfg;
        let th = self.thresholds;
        match mode {
            DispatchMode::Batched => {
                self.dispatches += 1;
                let key = reference::forward_plan_key(cfg, mb);
                let (plan, ws) = self
                    .plans
                    .entry_with(key, || reference::plan_forward(cfg, mb, &th))?;
                reference::forward_planned(cfg, &self.params, mb, &self.exec, w_rep, plan, ws)
            }
            DispatchMode::PerSample => {
                let n = cfg.n_out;
                let mut logits = vec![0f32; mb.batch * n];
                let mut dispatched = 0u64;
                for bi in 0..mb.batch {
                    let one = mb.single(bi);
                    let key = reference::forward_plan_key(cfg, &one);
                    let (plan, ws) = self
                        .plans
                        .entry_with(key, || reference::plan_forward(cfg, &one, &th))?;
                    let l = reference::forward_planned(
                        cfg,
                        &self.params,
                        &one,
                        &self.exec,
                        w_rep,
                        plan,
                        ws,
                    )?;
                    dispatched += 1;
                    logits[bi * n..(bi + 1) * n].copy_from_slice(&l);
                }
                self.dispatches += dispatched;
                Ok(logits)
            }
        }
    }
}

/// Multi-model host dispatch (DESIGN.md §15): the registry-backed twin
/// of [`HostDispatcher`]. One executor (one worker pool) serves every
/// registered model; parameters come from the
/// [`ModelRegistry`] — each forward clones the model's current
/// `Arc<ParamVersion>` **once** and runs the whole batch on it, so a
/// concurrent [`swap_params`](ModelRegistry::swap_params) can never mix
/// versions within a batch. Compiled plans live in per-tenant caches
/// ([`TenantPlanCaches`]) under the global arena budget; the
/// version-bound readout tile `w_rep` is the only parameter-derived
/// cache and is refreshed whenever the served version changes.
pub struct MultiDispatcher {
    registry: Arc<ModelRegistry>,
    exec: Executor,
    thresholds: AutoThresholds,
    plans: TenantPlanCaches,
    /// Per-model cached readout tile, stamped with the parameter
    /// version it was built from.
    w_rep: Vec<(String, u64, Vec<f32>)>,
    /// Forward dispatches issued, all models combined.
    pub dispatches: u64,
}

impl MultiDispatcher {
    /// `threads = 0` means one thread per core. The plan budget comes
    /// from `$BSPMM_PLAN_BUDGET_BYTES`
    /// ([`TenantPlanCaches::from_env`]).
    pub fn new(registry: Arc<ModelRegistry>, threads: usize) -> MultiDispatcher {
        MultiDispatcher {
            registry,
            exec: Executor::auto(threads),
            thresholds: AutoThresholds::from_env(),
            plans: TenantPlanCaches::from_env(),
            w_rep: Vec::new(),
            dispatches: 0,
        }
    }

    pub fn registry(&self) -> &Arc<ModelRegistry> {
        &self.registry
    }

    /// Warm-start every registered model's tenant cache from its
    /// per-model subdirectory `root/<model>/` (missing subdirectories
    /// are skipped — those models compile at runtime). Returns one
    /// report per model that had a directory.
    pub fn warm_start_plans(
        &mut self,
        root: &std::path::Path,
    ) -> anyhow::Result<Vec<(String, WarmStartReport)>> {
        let models: Vec<String> = self.registry.models().iter().map(|m| m.to_string()).collect();
        let th = self.thresholds;
        let mut reports = Vec::new();
        for model in models {
            let dir = root.join(&model);
            if !dir.is_dir() {
                continue;
            }
            let report = plan_artifact::warm_start(self.plans.tenant_cache_mut(&model), &dir, &th)?;
            reports.push((model, report));
        }
        Ok(reports)
    }

    /// Legacy single-model env warm start: with exactly one registered
    /// model, load `$BSPMM_PLAN_ARTIFACTS` (flat layout, no per-model
    /// subdirectory) into its tenant cache, so a registry-of-one server
    /// keeps the PR 7 boot behavior. No-op (`None`) with several models
    /// — those use [`MultiDispatcher::warm_start_plans`]'s per-model
    /// layout.
    pub fn warm_start_single_from_env(&mut self) -> anyhow::Result<Option<WarmStartReport>> {
        let models = self.registry.models();
        if models.len() != 1 {
            return Ok(None);
        }
        let model = models[0].to_string();
        let th = self.thresholds;
        plan_artifact::warm_start_from_env(self.plans.tenant_cache_mut(&model), &th)
    }

    /// Dump every tenant's cached plans into per-model subdirectories
    /// `root/<model>/` (the producer side of
    /// [`MultiDispatcher::warm_start_plans`]); returns how many
    /// artifacts were written.
    pub fn export_plans(&mut self, root: &std::path::Path) -> anyhow::Result<usize> {
        let models: Vec<String> = self.plans.tenants().map(|t| t.to_string()).collect();
        let th = self.thresholds;
        let mut n = 0;
        for model in models {
            let dir = root.join(&model);
            let cache = self.plans.tenant_cache_mut(&model);
            for plan in cache.plans() {
                plan_artifact::save(plan, &th, &dir)?;
                n += 1;
            }
        }
        Ok(n)
    }

    /// Aggregate plan/arena accounting across every tenant.
    pub fn plan_stats(&self) -> PlanStats {
        self.plans.stats()
    }

    /// Per-model plan/arena accounting (budget tests and the `--models`
    /// serve report read this).
    pub fn per_tenant_stats(&self) -> Vec<(String, PlanStats)> {
        self.plans.per_tenant_stats()
    }

    pub fn plan_budget(&self) -> u64 {
        self.plans.budget()
    }

    pub fn total_arena_bytes(&self) -> u64 {
        self.plans.total_arena_bytes()
    }

    /// Forward a packed batch for `model` on its current parameter
    /// version; returns the logits and the version they were computed
    /// under. The version is pinned for the whole batch (one `Arc`
    /// clone up front) — the linearization half of the hot-swap
    /// contract.
    pub fn forward(
        &mut self,
        model: &str,
        mode: DispatchMode,
        mb: &ModelBatch,
    ) -> anyhow::Result<(Vec<f32>, u64)> {
        let cur = self.registry.current(model)?;
        let cfg = self.registry.cfg(model)?;
        let th = self.thresholds;
        // Refresh the readout tile iff the served version moved.
        let pos = self.w_rep.iter().position(|(m, _, _)| m == model);
        if pos.map_or(true, |i| self.w_rep[i].1 != cur.version) {
            let tile = reference::build_w_rep(cfg, &cur.params)?;
            match pos {
                Some(i) => self.w_rep[i] = (model.to_string(), cur.version, tile),
                None => self.w_rep.push((model.to_string(), cur.version, tile)),
            }
        }
        let w_rep: &[f32] = {
            let i = self.w_rep.iter().position(|(m, _, _)| m == model).unwrap();
            &self.w_rep[i].2
        };
        let logits = match mode {
            DispatchMode::Batched => {
                self.dispatches += 1;
                let key = reference::forward_plan_key(cfg, mb);
                Self::revalidate_auto(&mut self.plans, model, cfg, mb, &th, &key)?;
                let (plan, ws) = self
                    .plans
                    .entry_with(model, key, || reference::plan_forward(cfg, mb, &th))?;
                reference::forward_planned(cfg, &cur.params, mb, &self.exec, w_rep, plan, ws)?
            }
            DispatchMode::PerSample => {
                let n = cfg.n_out;
                let mut logits = vec![0f32; mb.batch * n];
                let mut dispatched = 0u64;
                for bi in 0..mb.batch {
                    let one = mb.single(bi);
                    let key = reference::forward_plan_key(cfg, &one);
                    let (plan, ws) = self
                        .plans
                        .entry_with(model, key, || reference::plan_forward(cfg, &one, &th))?;
                    let l = reference::forward_planned(
                        cfg,
                        &cur.params,
                        &one,
                        &self.exec,
                        w_rep,
                        plan,
                        ws,
                    )?;
                    dispatched += 1;
                    logits[bi * n..(bi + 1) * n].copy_from_slice(&l);
                }
                self.dispatches += dispatched;
                logits
            }
        };
        Ok((logits, cur.version))
    }

    /// Per-batch `Backend::Auto` re-resolution (DESIGN.md §16). A
    /// cached plan froze one backend per adjacency dispatch from the
    /// *first* batch of its geometry, but batches of identical shape
    /// can carry very different per-channel densities. Before replay,
    /// re-run the O(channels) cost model on *this* batch's
    /// [`DispatchProfile`](crate::sparse::engine::DispatchProfile) and
    /// drop the cached plan when any frozen choice disagrees — the
    /// `entry_with` that follows recompiles it for the observed
    /// profile. With ELL the only packed adjacency candidate today the
    /// re-resolution always agrees (plans are never dropped); the hook
    /// becomes load-bearing the moment a second packing joins the
    /// candidate set.
    fn revalidate_auto(
        plans: &mut TenantPlanCaches,
        model: &str,
        cfg: &ModelConfig,
        mb: &ModelBatch,
        th: &AutoThresholds,
        key: &GeometryKey,
    ) -> anyhow::Result<()> {
        let mut want: Vec<Backend> = Vec::with_capacity(cfg.channels);
        for ch in 0..cfg.channels {
            want.push(reference::adjacency_backend(mb, ch, th)?);
        }
        // Adjacency dispatches are exactly the per-sample-RHS ones, in
        // (layer, channel) order — compare each against this batch's
        // resolution for its channel.
        plans.tenant_cache_mut(model).retain_key(key, |plan| {
            plan.dispatches
                .iter()
                .filter(|d| d.rhs == RhsKind::PerSample)
                .zip((0..cfg.hidden.len()).flat_map(|_| want.iter()))
                .all(|(d, w)| d.backend == *w)
        });
        Ok(())
    }

    /// [`MultiDispatcher::forward`] at an explicit inference precision
    /// (DESIGN.md §16). [`Precision::F32`] is the plain forward.
    /// `Bf16`/`Int8` serve on bf16-rounded parameters
    /// ([`ParamSet::round_to_bf16`]), quantize this batch's adjacency
    /// planes at pack time
    /// ([`reference::quantize_batch`]), and replay a plan cached under
    /// the dtype-tagged geometry key — compiled plans carry their
    /// precision, so an f32 plan can never serve a quantized request
    /// (nor the reverse).
    pub fn forward_precision(
        &mut self,
        model: &str,
        mode: DispatchMode,
        mb: &ModelBatch,
        precision: Precision,
    ) -> anyhow::Result<(Vec<f32>, u64)> {
        if precision == Precision::F32 {
            return self.forward(model, mode, mb);
        }
        let cur = self.registry.current(model)?;
        let cfg = self.registry.cfg(model)?;
        let th = self.thresholds;
        // The weight-storage half of the precision mode: serve on
        // bf16-rounded parameters and a matching readout tile. Built
        // per call rather than threaded through the version-stamped
        // f32 `w_rep` cache — quantized serving is inference-only and
        // the rounding is two passes over the parameter vector.
        let ps16 = cur.params.round_to_bf16();
        let w_rep = reference::build_w_rep(cfg, &ps16)?;
        let logits = match mode {
            DispatchMode::Batched => {
                self.dispatches += 1;
                let quant = reference::quantize_batch(mb, precision)?;
                let key = reference::forward_plan_key_dtype(cfg, mb, precision);
                let (plan, ws) = self.plans.entry_with(model, key, || {
                    reference::plan_forward_dtype(cfg, mb, &th, precision)
                })?;
                reference::forward_planned_quant(
                    cfg, &ps16, mb, &quant, &self.exec, &w_rep, plan, ws,
                )?
            }
            DispatchMode::PerSample => {
                let n = cfg.n_out;
                let mut logits = vec![0f32; mb.batch * n];
                let mut dispatched = 0u64;
                for bi in 0..mb.batch {
                    let one = mb.single(bi);
                    let quant = reference::quantize_batch(&one, precision)?;
                    let key = reference::forward_plan_key_dtype(cfg, &one, precision);
                    let (plan, ws) = self.plans.entry_with(model, key, || {
                        reference::plan_forward_dtype(cfg, &one, &th, precision)
                    })?;
                    let l = reference::forward_planned_quant(
                        cfg, &ps16, &one, &quant, &self.exec, &w_rep, plan, ws,
                    )?;
                    dispatched += 1;
                    logits[bi * n..(bi + 1) * n].copy_from_slice(&l);
                }
                self.dispatches += dispatched;
                logits
            }
        };
        Ok((logits, cur.version))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::dataset::{Dataset, DatasetKind};

    #[test]
    fn batched_and_per_sample_agree() {
        let mut hd = HostDispatcher::synthetic("tox21", 1, 3).unwrap();
        let d = Dataset::generate(DatasetKind::Tox21, 6, 8);
        let idx: Vec<usize> = (0..6).collect();
        let mb = d
            .pack_batch(&idx, hd.cfg.max_nodes, hd.cfg.ell_width)
            .unwrap();
        let batched = hd.forward(DispatchMode::Batched, &mb).unwrap();
        let single = hd.forward(DispatchMode::PerSample, &mb).unwrap();
        assert_eq!(batched.len(), 6 * 12);
        for (i, (a, b)) in batched.iter().zip(&single).enumerate() {
            assert!(
                (a - b).abs() <= 1e-5 + 1e-5 * b.abs(),
                "logit {i}: batched {a} vs per-sample {b}"
            );
        }
        // 1 batched dispatch + 6 per-sample dispatches.
        assert_eq!(hd.dispatches, 7);
    }

    #[test]
    fn thread_count_does_not_change_logits() {
        let d = Dataset::generate(DatasetKind::Tox21, 5, 8);
        let idx: Vec<usize> = (0..5).collect();
        let mut serial = HostDispatcher::synthetic("tox21", 1, 3).unwrap();
        let mut parallel = HostDispatcher::synthetic("tox21", 8, 3).unwrap();
        let mb = d
            .pack_batch(&idx, serial.cfg.max_nodes, serial.cfg.ell_width)
            .unwrap();
        let a = serial.forward(DispatchMode::Batched, &mb).unwrap();
        let b = parallel.forward(DispatchMode::Batched, &mb).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn per_sample_mode_shares_one_batch1_plan() {
        let mut hd = HostDispatcher::synthetic("tox21", 1, 3).unwrap();
        let d = Dataset::generate(DatasetKind::Tox21, 6, 8);
        let idx: Vec<usize> = (0..6).collect();
        let mb = d
            .pack_batch(&idx, hd.cfg.max_nodes, hd.cfg.ell_width)
            .unwrap();
        hd.forward(DispatchMode::PerSample, &mb).unwrap();
        let s = hd.plan_stats();
        // 6 samples, one compiled batch-1 plan, 5 replays.
        assert_eq!(s.plans_built, 1);
        assert_eq!(s.replays, 5);
        assert!(s.zero_fills_elided > 0);
        // The batched geometry is a second plan; repeating it replays.
        hd.forward(DispatchMode::Batched, &mb).unwrap();
        hd.forward(DispatchMode::Batched, &mb).unwrap();
        let s = hd.plan_stats();
        assert_eq!(s.plans_built, 2);
        assert_eq!(s.replays, 6);
        // Parameter updates keep every plan.
        let fresh = ParamSet::random_init(&hd.cfg, 5);
        hd.set_params(fresh);
        hd.forward(DispatchMode::Batched, &mb).unwrap();
        assert_eq!(hd.plan_stats().plans_built, 2);
    }

    #[test]
    fn multi_dispatcher_matches_host_dispatcher_per_model() {
        let mut reg = ModelRegistry::new();
        reg.register_synthetic("tox21", 3).unwrap();
        reg.register_synthetic("reaction100", 3).unwrap();
        let reg = Arc::new(reg);
        let mut md = MultiDispatcher::new(Arc::clone(&reg), 1);
        for model in ["tox21", "reaction100"] {
            let mut hd = HostDispatcher::synthetic(model, 1, 3).unwrap();
            let kind = if model == "tox21" {
                DatasetKind::Tox21
            } else {
                DatasetKind::Reaction100
            };
            let d = Dataset::generate(kind, 4, 8);
            let mb = d
                .pack_batch(&[0, 1, 2, 3], hd.cfg.max_nodes, hd.cfg.ell_width)
                .unwrap();
            let want = hd.forward(DispatchMode::Batched, &mb).unwrap();
            let (got, version) = md.forward(model, DispatchMode::Batched, &mb).unwrap();
            assert_eq!(got, want, "{model}: multi != single-model dispatch");
            assert_eq!(version, 1);
        }
        assert_eq!(md.dispatches, 2);
        // One plan per model geometry, in separate tenant caches.
        let per = md.per_tenant_stats();
        assert_eq!(per.len(), 2);
        for (model, s) in &per {
            assert_eq!(s.plans_built, 1, "{model}");
        }
        assert!(md.total_arena_bytes() <= md.plan_budget());
        // Unknown model errors instead of serving garbage.
        let d = Dataset::generate(DatasetKind::Tox21, 1, 8);
        let mb = d.pack_batch(&[0], 50, 12).unwrap();
        assert!(md.forward("nope", DispatchMode::Batched, &mb).is_err());
    }

    #[test]
    fn hot_swap_takes_effect_without_touching_plans() {
        let mut reg = ModelRegistry::new();
        reg.register_synthetic("tox21", 3).unwrap();
        let reg = Arc::new(reg);
        let mut md = MultiDispatcher::new(Arc::clone(&reg), 1);
        let d = Dataset::generate(DatasetKind::Tox21, 2, 8);
        let cfg = reg.cfg("tox21").unwrap().clone();
        let mb = d.pack_batch(&[0, 1], cfg.max_nodes, cfg.ell_width).unwrap();
        let (before, v1) = md.forward("tox21", DispatchMode::Batched, &mb).unwrap();
        assert_eq!(v1, 1);
        let fresh = ParamSet::random_init(&cfg, 99);
        let v2 = reg.swap_params("tox21", fresh.clone()).unwrap();
        let (after, served) = md.forward("tox21", DispatchMode::Batched, &mb).unwrap();
        assert_eq!(served, v2);
        assert_ne!(before, after, "swap did not take effect");
        // Same logits as a single-model dispatcher on the new params
        // (w_rep cache refreshed, plans untouched).
        let mut direct = HostDispatcher::new(cfg, fresh, 1);
        let want = direct.forward(DispatchMode::Batched, &mb).unwrap();
        assert_eq!(after, want);
        let s = md.plan_stats();
        assert_eq!(s.plans_built, 1, "hot swap must not invalidate plans");
        assert_eq!(s.replays, 1);
    }

    #[test]
    fn set_params_invalidates_readout_cache() {
        let mut hd = HostDispatcher::synthetic("tox21", 1, 3).unwrap();
        let d = Dataset::generate(DatasetKind::Tox21, 2, 8);
        let mb = d
            .pack_batch(&[0, 1], hd.cfg.max_nodes, hd.cfg.ell_width)
            .unwrap();
        let before = hd.forward(DispatchMode::Batched, &mb).unwrap();
        // New params must actually take effect (stale w_rep would keep
        // the old readout weights alive).
        let fresh = ParamSet::random_init(&hd.cfg, 99);
        hd.set_params(fresh.clone());
        let after = hd.forward(DispatchMode::Batched, &mb).unwrap();
        assert_ne!(before, after);
        // And match a dispatcher built directly on the new params.
        let mut direct = HostDispatcher::new(hd.cfg.clone(), fresh, 1);
        let want = direct.forward(DispatchMode::Batched, &mb).unwrap();
        assert_eq!(after, want);
    }

    #[test]
    fn forward_precision_serves_quantized_plans_per_dtype() {
        let mut reg = ModelRegistry::new();
        reg.register_synthetic("tox21", 3).unwrap();
        let reg = Arc::new(reg);
        let mut md = MultiDispatcher::new(Arc::clone(&reg), 1);
        let cfg = reg.cfg("tox21").unwrap().clone();
        let d = Dataset::generate(DatasetKind::Tox21, 4, 8);
        let mb = d
            .pack_batch(&[0, 1, 2, 3], cfg.max_nodes, cfg.ell_width)
            .unwrap();

        // F32 delegates to the plain forward.
        let (f32_logits, _) = md
            .forward_precision("tox21", DispatchMode::Batched, &mb, Precision::F32)
            .unwrap();
        let (plain, _) = md.forward("tox21", DispatchMode::Batched, &mb).unwrap();
        assert_eq!(f32_logits, plain);

        for (precision, tol) in [(Precision::Bf16, 0.05f32), (Precision::Int8, 0.3f32)] {
            let (q, version) = md
                .forward_precision("tox21", DispatchMode::Batched, &mb, precision)
                .unwrap();
            assert_eq!(version, 1);
            // Bit-identical to the unplanned quantized reference (the
            // engine's dispatches are bit-stable across thread counts
            // and plan replay).
            let want = reference::forward_quantized(
                &cfg,
                &reg.current("tox21").unwrap().params,
                &mb,
                &Executor::serial(),
                precision,
            )
            .unwrap();
            assert_eq!(q, want, "{precision}: planned != reference quantized");
            // And close to f32 within the dtype's error budget.
            for (i, (a, b)) in q.iter().zip(&f32_logits).enumerate() {
                assert!(
                    (a - b).abs() <= tol + tol * b.abs(),
                    "{precision} logit {i}: {a} vs f32 {b}"
                );
            }
            // Per-sample mode agrees with batched (quantization is
            // per-plane, so slicing the batch cannot move the scales).
            let (qs, _) = md
                .forward_precision("tox21", DispatchMode::PerSample, &mb, precision)
                .unwrap();
            for (i, (a, b)) in qs.iter().zip(&q).enumerate() {
                assert!(
                    (a - b).abs() <= 1e-4 + 1e-4 * b.abs(),
                    "{precision} per-sample logit {i}: {a} vs batched {b}"
                );
            }
        }
        // Every (precision, geometry) pair is its own cached plan: f32
        // B=4, bf16 B=4, int8 B=4, bf16 B=1, int8 B=1.
        assert_eq!(md.plan_stats().plans_built, 5);
    }

    #[test]
    fn per_batch_auto_revalidation() {
        let mut reg = ModelRegistry::new();
        reg.register_synthetic("tox21", 3).unwrap();
        let reg = Arc::new(reg);
        let mut md = MultiDispatcher::new(Arc::clone(&reg), 1);
        let cfg = reg.cfg("tox21").unwrap().clone();
        let d = Dataset::generate(DatasetKind::Tox21, 8, 8);
        // Two batches of identical geometry but different graphs: the
        // cost model re-runs on the second batch's profile, agrees
        // (ELL is the only packed candidate), and the cached plan is
        // replayed instead of recompiled.
        let a = d
            .pack_batch(&[0, 1, 2, 3], cfg.max_nodes, cfg.ell_width)
            .unwrap();
        let b = d
            .pack_batch(&[4, 5, 6, 7], cfg.max_nodes, cfg.ell_width)
            .unwrap();
        md.forward("tox21", DispatchMode::Batched, &a).unwrap();
        md.forward("tox21", DispatchMode::Batched, &b).unwrap();
        let s = md.plan_stats();
        assert_eq!((s.plans_built, s.replays), (1, 1));

        // A cached plan whose frozen adjacency backends disagree with
        // the observed batch is dropped and recompiled: plant one with
        // every adjacency dispatch flipped to GEMM under a fresh
        // geometry (B=5), then forward that geometry.
        let c = d
            .pack_batch(&[0, 1, 2, 3, 4], cfg.max_nodes, cfg.ell_width)
            .unwrap();
        let mut stale = reference::plan_forward(&cfg, &c, &md.thresholds).unwrap();
        for disp in &mut stale.dispatches {
            if disp.rhs == RhsKind::PerSample {
                disp.backend = Backend::Gemm;
            }
        }
        assert!(md.plans.tenant_cache_mut("tox21").insert_warm(stale));
        let (got, _) = md.forward("tox21", DispatchMode::Batched, &c).unwrap();
        let s = md.plan_stats();
        assert_eq!(
            s.plans_built, 2,
            "disagreeing plan must be dropped and recompiled"
        );
        // The recompiled plan serves the same logits as a fresh
        // single-model dispatcher.
        let mut hd = HostDispatcher::new(cfg, reg.current("tox21").unwrap().params.clone(), 1);
        let want = hd.forward(DispatchMode::Batched, &c).unwrap();
        assert_eq!(got, want);
    }
}
