//! Host-engine forward dispatch: the in-process CPU twin of the PJRT
//! artifact dispatch paths, built on the batched-SpMM engine.
//!
//! The server and trainer choose between two execution backends; both
//! realize the same batched/per-sample contrast the paper measures:
//!
//! * **PJRT** — artifact executes on the device runtime (requires
//!   `make artifacts`);
//! * **Host engine** — `gcn::reference::forward_with_readout` on a
//!   [`sparse::engine::Executor`](crate::sparse::engine::Executor), so
//!   every multiplication routes through the [`BatchedSpmm`]
//!   trait — no artifacts needed, and the executor's thread count is
//!   the speedup knob.
//!
//! The dispatcher caches the tiled readout weight `w_rep`
//! ([`crate::gcn::reference::build_w_rep`]) — a pure function of
//! `readout.w`, ~10 MB per forward on reaction100 if rebuilt each call.
//! Replace parameters through [`HostDispatcher::set_params`] (or call
//! [`HostDispatcher::invalidate_cache`] after mutating
//! [`HostDispatcher::params`] directly) so the cache never goes stale.
//!
//! Forwards run plan/execute split (DESIGN.md §11): the dispatcher
//! keeps one compiled [`StepPlan`](crate::sparse::engine::StepPlan) +
//! [`Workspace`](crate::sparse::engine::Workspace) per batch geometry
//! in a [`PlanCache`] — built on the first batch of that shape,
//! replayed for every batch after it with zero intermediate
//! allocations. Geometry changes (batch size, node bucket) compile a
//! new entry; parameter updates keep every plan (only `w_rep` is
//! parameter-derived). [`HostDispatcher::plan_stats`] exposes the
//! accounting.
//!
//! [`BatchedSpmm`]: crate::sparse::engine::BatchedSpmm

use crate::coordinator::server::DispatchMode;
use crate::gcn::config::ModelConfig;
use crate::gcn::params::ParamSet;
use crate::gcn::reference;
use crate::graph::dataset::ModelBatch;
use crate::runtime::plan_artifact::{self, WarmStartReport};
use crate::sparse::engine::{AutoThresholds, Executor, PlanCache, PlanStats};

/// In-process model execution over the batched-SpMM engine.
pub struct HostDispatcher {
    pub cfg: ModelConfig,
    /// Mutate only via [`HostDispatcher::set_params`], or follow direct
    /// edits with [`HostDispatcher::invalidate_cache`].
    pub params: ParamSet,
    /// One executor — and with it one persistent
    /// [`WorkerPool`](crate::sparse::engine::WorkerPool) — for the
    /// dispatcher's whole lifetime: every forward it serves runs on the
    /// same parked workers, with zero thread spawns after construction
    /// (DESIGN.md §9).
    exec: Executor,
    /// Cached tiled readout weight; lazily rebuilt after invalidation.
    w_rep: Option<Vec<f32>>,
    /// One compiled (plan, workspace) per batch geometry (DESIGN.md
    /// §11). Never invalidated by parameter updates.
    plans: PlanCache,
    /// Auto-backend decision thresholds baked into new plans.
    thresholds: AutoThresholds,
    /// Forward dispatches issued (1 per batch in Batched mode, 1 per
    /// sample in PerSample mode) — the same signal the PJRT paths count.
    pub dispatches: u64,
}

impl HostDispatcher {
    /// `threads = 0` means one thread per core.
    ///
    /// When `$BSPMM_PLAN_ARTIFACTS` is set the plan cache warm-starts
    /// from that directory (best-effort — this constructor is
    /// infallible, so a bad artifact directory loads nothing and every
    /// geometry compiles at runtime; use
    /// [`HostDispatcher::warm_start_plans`] when you want the report).
    pub fn new(cfg: ModelConfig, params: ParamSet, threads: usize) -> HostDispatcher {
        let thresholds = AutoThresholds::from_env();
        let mut plans = PlanCache::new();
        let _ = plan_artifact::warm_start_from_env(&mut plans, &thresholds);
        HostDispatcher {
            cfg,
            params,
            exec: Executor::auto(threads),
            w_rep: None,
            plans,
            thresholds,
            dispatches: 0,
        }
    }

    /// Warm-start the plan cache from `dir`'s `*.plan.json` artifacts
    /// (DESIGN.md §13). Threshold-mismatched or invalid artifacts are
    /// skipped — those geometries fall back to runtime compilation.
    pub fn warm_start_plans(&mut self, dir: &std::path::Path) -> anyhow::Result<WarmStartReport> {
        plan_artifact::warm_start(&mut self.plans, dir, &self.thresholds)
    }

    /// Dump every cached plan to `dir` as AOT artifacts (the producer
    /// side of [`HostDispatcher::warm_start_plans`]); returns how many
    /// were written.
    pub fn export_plans(&self, dir: &std::path::Path) -> anyhow::Result<usize> {
        let mut n = 0;
        for plan in self.plans.plans() {
            plan_artifact::save(plan, &self.thresholds, dir)?;
            n += 1;
        }
        Ok(n)
    }

    /// Manifest-free construction from the named synthetic model config.
    pub fn synthetic(model: &str, threads: usize, seed: u64) -> anyhow::Result<HostDispatcher> {
        let cfg = ModelConfig::synthetic(model)?;
        let params = ParamSet::random_init(&cfg, seed);
        Ok(HostDispatcher::new(cfg, params, threads))
    }

    /// The dispatcher's long-lived executor (a handle on its one
    /// worker pool).
    pub fn executor(&self) -> &Executor {
        &self.exec
    }

    /// Replace the parameter set (e.g. after training elsewhere) and
    /// drop parameter-derived caches.
    pub fn set_params(&mut self, params: ParamSet) {
        self.params = params;
        self.w_rep = None;
    }

    /// Drop parameter-derived caches after a direct `params` mutation.
    /// Plans are geometry-derived and survive.
    pub fn invalidate_cache(&mut self) {
        self.w_rep = None;
    }

    /// Plan/arena accounting across every geometry this dispatcher has
    /// served (DESIGN.md §11).
    pub fn plan_stats(&self) -> PlanStats {
        self.plans.stats()
    }

    /// Forward a packed batch: one engine-batched dispatch, or one
    /// batch-1 dispatch per sample (the non-batched baseline). Both
    /// reuse the cached readout tiling, and both replay a cached step
    /// plan — the per-sample mode shares one batch-1 plan + workspace
    /// across all its samples.
    pub fn forward(&mut self, mode: DispatchMode, mb: &ModelBatch) -> anyhow::Result<Vec<f32>> {
        if self.w_rep.is_none() {
            self.w_rep = Some(reference::build_w_rep(&self.cfg, &self.params)?);
        }
        let w_rep = self.w_rep.as_deref().unwrap();
        let cfg = &self.cfg;
        let th = self.thresholds;
        match mode {
            DispatchMode::Batched => {
                self.dispatches += 1;
                let key = reference::forward_plan_key(cfg, mb);
                let (plan, ws) = self
                    .plans
                    .entry_with(key, || reference::plan_forward(cfg, mb, &th))?;
                reference::forward_planned(cfg, &self.params, mb, &self.exec, w_rep, plan, ws)
            }
            DispatchMode::PerSample => {
                let n = cfg.n_out;
                let mut logits = vec![0f32; mb.batch * n];
                let mut dispatched = 0u64;
                for bi in 0..mb.batch {
                    let one = mb.single(bi);
                    let key = reference::forward_plan_key(cfg, &one);
                    let (plan, ws) = self
                        .plans
                        .entry_with(key, || reference::plan_forward(cfg, &one, &th))?;
                    let l = reference::forward_planned(
                        cfg,
                        &self.params,
                        &one,
                        &self.exec,
                        w_rep,
                        plan,
                        ws,
                    )?;
                    dispatched += 1;
                    logits[bi * n..(bi + 1) * n].copy_from_slice(&l);
                }
                self.dispatches += dispatched;
                Ok(logits)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::dataset::{Dataset, DatasetKind};

    #[test]
    fn batched_and_per_sample_agree() {
        let mut hd = HostDispatcher::synthetic("tox21", 1, 3).unwrap();
        let d = Dataset::generate(DatasetKind::Tox21, 6, 8);
        let idx: Vec<usize> = (0..6).collect();
        let mb = d
            .pack_batch(&idx, hd.cfg.max_nodes, hd.cfg.ell_width)
            .unwrap();
        let batched = hd.forward(DispatchMode::Batched, &mb).unwrap();
        let single = hd.forward(DispatchMode::PerSample, &mb).unwrap();
        assert_eq!(batched.len(), 6 * 12);
        for (i, (a, b)) in batched.iter().zip(&single).enumerate() {
            assert!(
                (a - b).abs() <= 1e-5 + 1e-5 * b.abs(),
                "logit {i}: batched {a} vs per-sample {b}"
            );
        }
        // 1 batched dispatch + 6 per-sample dispatches.
        assert_eq!(hd.dispatches, 7);
    }

    #[test]
    fn thread_count_does_not_change_logits() {
        let d = Dataset::generate(DatasetKind::Tox21, 5, 8);
        let idx: Vec<usize> = (0..5).collect();
        let mut serial = HostDispatcher::synthetic("tox21", 1, 3).unwrap();
        let mut parallel = HostDispatcher::synthetic("tox21", 8, 3).unwrap();
        let mb = d
            .pack_batch(&idx, serial.cfg.max_nodes, serial.cfg.ell_width)
            .unwrap();
        let a = serial.forward(DispatchMode::Batched, &mb).unwrap();
        let b = parallel.forward(DispatchMode::Batched, &mb).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn per_sample_mode_shares_one_batch1_plan() {
        let mut hd = HostDispatcher::synthetic("tox21", 1, 3).unwrap();
        let d = Dataset::generate(DatasetKind::Tox21, 6, 8);
        let idx: Vec<usize> = (0..6).collect();
        let mb = d
            .pack_batch(&idx, hd.cfg.max_nodes, hd.cfg.ell_width)
            .unwrap();
        hd.forward(DispatchMode::PerSample, &mb).unwrap();
        let s = hd.plan_stats();
        // 6 samples, one compiled batch-1 plan, 5 replays.
        assert_eq!(s.plans_built, 1);
        assert_eq!(s.replays, 5);
        assert!(s.zero_fills_elided > 0);
        // The batched geometry is a second plan; repeating it replays.
        hd.forward(DispatchMode::Batched, &mb).unwrap();
        hd.forward(DispatchMode::Batched, &mb).unwrap();
        let s = hd.plan_stats();
        assert_eq!(s.plans_built, 2);
        assert_eq!(s.replays, 6);
        // Parameter updates keep every plan.
        let fresh = ParamSet::random_init(&hd.cfg, 5);
        hd.set_params(fresh);
        hd.forward(DispatchMode::Batched, &mb).unwrap();
        assert_eq!(hd.plan_stats().plans_built, 2);
    }

    #[test]
    fn set_params_invalidates_readout_cache() {
        let mut hd = HostDispatcher::synthetic("tox21", 1, 3).unwrap();
        let d = Dataset::generate(DatasetKind::Tox21, 2, 8);
        let mb = d
            .pack_batch(&[0, 1], hd.cfg.max_nodes, hd.cfg.ell_width)
            .unwrap();
        let before = hd.forward(DispatchMode::Batched, &mb).unwrap();
        // New params must actually take effect (stale w_rep would keep
        // the old readout weights alive).
        let fresh = ParamSet::random_init(&hd.cfg, 99);
        hd.set_params(fresh.clone());
        let after = hd.forward(DispatchMode::Batched, &mb).unwrap();
        assert_ne!(before, after);
        // And match a dispatcher built directly on the new params.
        let mut direct = HostDispatcher::new(hd.cfg.clone(), fresh, 1);
        let want = direct.forward(DispatchMode::Batched, &mb).unwrap();
        assert_eq!(after, want);
    }
}
