//! The serving runtime: a dedicated device thread that owns the
//! execution backend, assembles dynamic batches, and dispatches
//! inference.
//!
//! Two dispatch modes realize the paper's comparison at system level:
//! * [`DispatchMode::Batched`] — requests ride a padded batch through
//!   one batched execute: one dispatch per *batch* (Fig. 7).
//! * [`DispatchMode::PerSample`] — each request is its own dispatch
//!   (Fig. 6 / TF-session style).
//!
//! Orthogonally, [`ServeBackend`] selects *where* the batch executes:
//! * [`ServeBackend::Pjrt`] — the AOT artifacts on the PJRT runtime
//!   (requires `make artifacts`);
//! * [`ServeBackend::HostEngine`] — the in-process batched-SpMM engine
//!   (`sparse::engine`), needing no artifacts; its executor thread
//!   count is the CPU speedup knob. Forwards replay compiled step
//!   plans from the dispatcher's per-geometry cache (DESIGN.md §11);
//!   the cache's accounting is surfaced in
//!   [`MetricsSnapshot::plans_built`] / `plans_warmed` /
//!   `plan_replays`. With `$BSPMM_PLAN_ARTIFACTS` set, the dispatcher
//!   warm-starts its plan cache from AOT artifacts at boot
//!   (DESIGN.md §13) and steady-state serving reports
//!   `plans_built == 0`.
//!
//! The device thread structure (everything backend-facing on one
//! thread, clients talking over channels) is forced by the `xla`
//! crate's `Rc`-based client, and is also how real GPU serving stacks
//! arrange their dispatch thread.
//!
//! Under load the server defends itself twice (DESIGN.md §14): a
//! bounded admission queue ([`ServerConfig::queue_bound`]) refuses
//! submits once the admitted-but-unanswered depth hits the bound, and
//! a per-request deadline ([`ServerConfig::deadline`]) sheds requests
//! that are already stale when their batch is assembled. Both paths
//! answer the client immediately with a shed response — a refused
//! request never touches the engine. Batch close policy is
//! [`CloseRule`]: size-or-age (adaptive, the default) vs fixed-size
//! (the throughput-first baseline the serving bench contrasts).
//!
//! Multi-model serving (DESIGN.md §15): with
//! [`ServerConfig::registry`] set, one host-engine device thread
//! serves every registered model. Requests are addressed per model
//! ([`Server::submit_to`]), batches assemble per model in a
//! [`KeyedBatchAssembler`] (never mixing models), and each batch runs
//! on the parameter version current when it was dispatched — pinned
//! for the whole batch, so a concurrent
//! [`swap_params`](crate::coordinator::ModelRegistry::swap_params)
//! flips versions only between batches. Responses carry the model,
//! the served parameter version, and a device batch sequence number so
//! the hot-swap test can verify no batch mixed versions. Without a
//! registry the server builds a registry-of-one from
//! [`ServerConfig::model`] (same deterministic init as before), so the
//! single-model path is the multi-model path with one tenant.

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::coordinator::batcher::{age_from_env, BatchPolicy, CloseRule, KeyedBatchAssembler};
use crate::coordinator::dispatch::MultiDispatcher;
use crate::coordinator::metrics::{Metrics, MetricsSnapshot};
use crate::coordinator::registry::ModelRegistry;
use crate::coordinator::request::{InferRequest, InferResponse};
use crate::coordinator::trainer::{batch_tensors, param_tensors};
use crate::gcn::config::ModelConfig;
use crate::gcn::params::ParamSet;
use crate::graph::dataset::pack_molecules;
use crate::graph::molecule::Molecule;
use crate::runtime::{Runtime, Tensor};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DispatchMode {
    /// One device dispatch per assembled batch (padded to capacity).
    Batched,
    /// One device dispatch per request (the non-batched baseline).
    PerSample,
}

/// Which execution backend the device thread drives.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ServeBackend {
    /// AOT artifacts on the PJRT runtime.
    Pjrt,
    /// In-process batched-SpMM engine; `threads = 0` means one per
    /// core. The device thread's [`MultiDispatcher`] constructs one
    /// persistent worker pool at startup and serves every registered
    /// model on it — no per-dispatch thread spawning (DESIGN.md §9).
    HostEngine { threads: usize },
}

#[derive(Clone, Debug)]
pub struct ServerConfig {
    pub artifacts_dir: PathBuf,
    pub model: String,
    pub mode: DispatchMode,
    pub backend: ServeBackend,
    /// Batch capacity. For the PJRT backend it must be one of the
    /// model's AOT'd fwd batch sizes (infer_batch / train_batch / 1);
    /// the host engine accepts any capacity >= 1. Forced to 1 in
    /// PerSample mode.
    pub max_batch: usize,
    /// Age cap for [`CloseRule::SizeOrAge`]: a non-empty batch closes
    /// once its oldest request has waited this long. Overridable at
    /// startup via `BSPMM_BATCH_AGE_US` (integer microseconds).
    /// Ignored under [`CloseRule::FixedSize`].
    pub max_wait: Duration,
    /// Which triggers may close a batch (size-or-age is the default
    /// adaptive policy; fixed-size is the throughput-first baseline).
    pub close: CloseRule,
    /// Bounded admission queue: maximum requests admitted but not yet
    /// answered. A submit beyond the bound is refused immediately with
    /// a shed response (backpressure at the front door). `0` =
    /// unbounded (the depth high-water mark is still tracked).
    pub queue_bound: usize,
    /// Per-request deadline: a request older than this when its batch
    /// is assembled is shed instead of executed (it would miss its SLO
    /// anyway — spending device time on it only delays the rest).
    /// `None` = never deadline-shed.
    pub deadline: Option<Duration>,
    /// Optional trained parameter blob (defaults to the init params on
    /// PJRT, to a deterministic random init on the host engine).
    /// Ignored when [`ServerConfig::registry`] is set — registered
    /// models bring their own parameters.
    pub params_path: Option<PathBuf>,
    /// Multi-model serving (host engine only): the model registry this
    /// server drives. `None` builds a registry-of-one from
    /// [`ServerConfig::model`] on the host engine (the single-model
    /// path unchanged); on PJRT a registry is rejected at startup.
    pub registry: Option<Arc<ModelRegistry>>,
    /// Plan-artifact root with per-model subdirectories
    /// (`<dir>/<model>/*.plan.json`) to warm-start every registered
    /// model's tenant plan cache from at boot (DESIGN.md §13/§15).
    /// `None` falls back to the legacy `$BSPMM_PLAN_ARTIFACTS` flat
    /// layout when exactly one model is registered.
    pub plans_dir: Option<PathBuf>,
}

enum Msg {
    Infer(InferRequest),
    Shutdown,
}

/// Handle owned by clients; the device thread runs until `shutdown`.
pub struct Server {
    tx: mpsc::Sender<Msg>,
    handle: Option<JoinHandle<anyhow::Result<()>>>,
    metrics: Arc<Metrics>,
    next_id: AtomicU64,
    /// Admitted-but-unanswered requests, shared with the device thread
    /// (incremented at admission, decremented at reply or shed).
    depth: Arc<AtomicUsize>,
    queue_bound: usize,
    /// The registry this server serves from (registry-of-one when the
    /// config had none). `None` only on the PJRT backend.
    registry: Option<Arc<ModelRegistry>>,
    /// Model [`Server::submit`] addresses.
    default_model: String,
}

impl Server {
    pub fn start(mut cfg: ServerConfig) -> anyhow::Result<Server> {
        // Resolve the registry up front so admission can validate model
        // names and so startup errors (unknown model, registry on PJRT)
        // surface synchronously.
        let registry: Option<Arc<ModelRegistry>> = match (&cfg.registry, cfg.backend) {
            (Some(_), ServeBackend::Pjrt) => {
                anyhow::bail!("a model registry requires the host-engine backend")
            }
            (Some(r), _) => {
                anyhow::ensure!(
                    r.contains(&cfg.model),
                    "default model '{}' is not in the registry (has: {:?})",
                    cfg.model,
                    r.models()
                );
                Some(Arc::clone(r))
            }
            (None, ServeBackend::HostEngine { .. }) => {
                // Registry-of-one: same model resolution + deterministic
                // init as the pre-registry host path.
                let model = ModelConfig::synthetic(&cfg.model)?;
                let params = match &cfg.params_path {
                    Some(p) => load_params_blob(&model, p)?,
                    None => ParamSet::random_init(&model, 0x5EED),
                };
                let mut reg = ModelRegistry::new();
                reg.register(model, params)?;
                Some(Arc::new(reg))
            }
            (None, ServeBackend::Pjrt) => None,
        };
        cfg.registry = registry.clone();
        let default_model = cfg.model.clone();
        let (tx, rx) = mpsc::channel::<Msg>();
        let metrics = Arc::new(Metrics::new());
        let m2 = metrics.clone();
        let depth = Arc::new(AtomicUsize::new(0));
        let d2 = depth.clone();
        let queue_bound = cfg.queue_bound;
        // Startup errors (bad artifacts dir, unknown model) must surface
        // to the caller, so the device thread reports readiness first.
        let (ready_tx, ready_rx) = mpsc::channel::<anyhow::Result<()>>();
        let handle = std::thread::Builder::new()
            .name("device".into())
            .spawn(move || device_thread(cfg, rx, m2, d2, ready_tx))?;
        ready_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("device thread died during startup"))??;
        Ok(Server {
            tx,
            handle: Some(handle),
            metrics,
            next_id: AtomicU64::new(0),
            depth,
            queue_bound,
            registry,
            default_model,
        })
    }

    /// The registry this server serves from (`None` on PJRT).
    pub fn registry(&self) -> Option<&Arc<ModelRegistry>> {
        self.registry.as_ref()
    }

    /// Submit one molecule to the server's default model; returns the
    /// channel the response arrives on. With a nonzero `queue_bound`, a
    /// submit that would push the admitted-but-unanswered depth past
    /// the bound is refused right here: a shed [`InferResponse`]
    /// arrives on the channel immediately and the request never reaches
    /// the device thread.
    pub fn submit(&self, mol: Molecule) -> mpsc::Receiver<InferResponse> {
        let model = self.default_model.clone();
        self.submit_to(&model, mol)
    }

    /// Submit one molecule to a specific registered model. A model
    /// unknown to the registry (or any non-default model on the PJRT
    /// backend) is refused immediately with a shed response.
    pub fn submit_to(&self, model: &str, mol: Molecule) -> mpsc::Receiver<InferResponse> {
        let (reply, rx) = mpsc::channel();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let known = match &self.registry {
            Some(r) => r.contains(model),
            None => model == self.default_model,
        };
        if !known {
            self.metrics.record_shed_for(model);
            let _ = reply.send(InferResponse::shed(id, model, 0));
            return rx;
        }
        // Reserve a queue slot first, then check the bound on the value
        // we displaced: concurrent submitters each see a distinct prior
        // depth, so the bound is never exceeded even under races.
        let prev = self.depth.fetch_add(1, Ordering::AcqRel);
        if self.queue_bound > 0 && prev >= self.queue_bound {
            self.depth.fetch_sub(1, Ordering::AcqRel);
            self.metrics.record_shed_for(model);
            let _ = reply.send(InferResponse::shed(id, model, 0));
            return rx;
        }
        self.metrics.record_queue_depth(prev + 1);
        let req = InferRequest {
            id,
            model: model.to_string(),
            mol,
            submitted: Instant::now(),
            reply,
        };
        // A send failure means the device thread is gone; the caller
        // notices via the closed response channel.
        if self.tx.send(Msg::Infer(req)).is_err() {
            self.depth.fetch_sub(1, Ordering::AcqRel);
        }
        rx
    }

    /// Current admitted-but-unanswered depth (racy by nature; exact at
    /// quiescence).
    pub fn queue_depth(&self) -> usize {
        self.depth.load(Ordering::Acquire)
    }

    pub fn metrics(&self) -> MetricsSnapshot {
        self.metrics.snapshot()
    }

    /// Drain + stop the device thread, returning final metrics.
    pub fn shutdown(mut self) -> anyhow::Result<MetricsSnapshot> {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.handle.take() {
            h.join().map_err(|_| anyhow::anyhow!("device thread panicked"))??;
        }
        Ok(self.metrics.snapshot())
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// The execution backend state the device thread owns.
enum Engine {
    Pjrt {
        rt: Runtime,
        model: ModelConfig,
        ptensors: Vec<Tensor>,
        artifact: String,
    },
    /// Registry-backed multi-model host dispatch (a registry-of-one for
    /// single-model configs).
    Host(MultiDispatcher),
}

fn device_thread(
    cfg: ServerConfig,
    rx: mpsc::Receiver<Msg>,
    metrics: Arc<Metrics>,
    depth: Arc<AtomicUsize>,
    ready: mpsc::Sender<anyhow::Result<()>>,
) -> anyhow::Result<()> {
    // ---- startup: backend + params + capacity selection ----------------
    let init = (|| -> anyhow::Result<(Engine, usize)> {
        let capacity = match cfg.mode {
            DispatchMode::PerSample => 1,
            DispatchMode::Batched => cfg.max_batch,
        };
        anyhow::ensure!(capacity >= 1, "batch capacity must be >= 1");
        match cfg.backend {
            ServeBackend::Pjrt => {
                let rt = Runtime::new(&cfg.artifacts_dir)?;
                let model = rt.manifest.model(&cfg.model)?.clone();
                let params = match &cfg.params_path {
                    Some(p) => load_params_blob(&model, p)?,
                    None => ParamSet::load_init(&model, &rt.manifest.dir)?,
                };
                let artifact = if capacity == model.infer_batch {
                    model.artifact_fwd_infer.clone()
                } else if capacity == model.train_batch {
                    model.artifact_fwd_train.clone()
                } else if capacity == 1 {
                    model.artifact_fwd_sample.clone()
                } else {
                    anyhow::bail!(
                        "no fwd artifact for batch {capacity} (model has {}, {}, 1)",
                        model.infer_batch,
                        model.train_batch
                    )
                };
                // Pre-compile so steady-state latencies exclude compilation.
                rt.executable(&artifact)?;
                let ptensors = param_tensors(&model, &params);
                Ok((
                    Engine::Pjrt {
                        rt,
                        model,
                        ptensors,
                        artifact,
                    },
                    capacity,
                ))
            }
            ServeBackend::HostEngine { threads } => {
                let registry = cfg
                    .registry
                    .clone()
                    .ok_or_else(|| anyhow::anyhow!("host engine started without a registry"))?;
                let mut md = MultiDispatcher::new(registry, threads);
                // Warm-start every tenant's plan cache: per-model
                // subdirectories when a plans dir is configured, the
                // legacy flat env layout for a registry-of-one.
                match &cfg.plans_dir {
                    Some(dir) => {
                        md.warm_start_plans(dir)?;
                    }
                    None => {
                        let _ = md.warm_start_single_from_env();
                    }
                }
                Ok((Engine::Host(md), capacity))
            }
        }
    })();
    let (mut engine, capacity) = match init {
        Ok(v) => {
            let _ = ready.send(Ok(()));
            v
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return Ok(());
        }
    };
    let policy = match cfg.close {
        // The age cap is env-calibratable: BSPMM_BATCH_AGE_US overrides
        // the configured max_wait at startup (DESIGN.md §14).
        CloseRule::SizeOrAge => BatchPolicy::new(capacity, age_from_env(cfg.max_wait)),
        CloseRule::FixedSize => BatchPolicy::fixed_size(capacity),
    };
    // One assembly lane per model (DESIGN.md §15): a batch never mixes
    // models, so each device dispatch replays one model's compiled plan.
    let mut assembler: KeyedBatchAssembler<InferRequest> = KeyedBatchAssembler::new(policy);
    // Device batch sequence: responses sharing a batch_seq rode one
    // engine dispatch (and therefore one parameter version).
    let mut batch_seq: u64 = 0;
    metrics.mark_start();

    // ---- serve loop ------------------------------------------------------
    let mut running = true;
    while running {
        let timeout = assembler
            .time_to_deadline(Instant::now())
            .unwrap_or(Duration::from_millis(100));
        match rx.recv_timeout(timeout) {
            Ok(Msg::Infer(req)) => {
                let lane = req.model.clone();
                assembler.push(&lane, req, Instant::now());
            }
            Ok(Msg::Shutdown) | Err(mpsc::RecvTimeoutError::Disconnected) => {
                running = false;
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {}
        }
        while let Some((model, batch)) = assembler.poll(Instant::now()) {
            serve_batch(
                &mut engine,
                &cfg,
                capacity,
                &model,
                batch,
                &metrics,
                &depth,
                &mut batch_seq,
            )?;
        }
        if !running {
            // Shutdown drain: flush every lane's partial batch.
            for (model, batch) in assembler.drain_all() {
                serve_batch(
                    &mut engine,
                    &cfg,
                    capacity,
                    &model,
                    batch,
                    &metrics,
                    &depth,
                    &mut batch_seq,
                )?;
            }
        }
    }
    metrics.mark_finish();
    Ok(())
}

/// Deadline-shed, chunk to capacity, and dispatch one assembled batch
/// for one model.
#[allow(clippy::too_many_arguments)]
fn serve_batch(
    engine: &mut Engine,
    cfg: &ServerConfig,
    capacity: usize,
    model: &str,
    mut batch: Vec<InferRequest>,
    metrics: &Arc<Metrics>,
    depth: &Arc<AtomicUsize>,
    batch_seq: &mut u64,
) -> anyhow::Result<()> {
    // Deadline shedding happens here, at assembly — once a request has
    // waited past its deadline it would miss its SLO anyway, and
    // executing it only delays the requests behind it. Shed requests
    // are answered (shed=true, no logits) but never reach the engine.
    // The shutdown drain sheds too: a stale request does not get
    // fresher by the server stopping.
    if let Some(deadline) = cfg.deadline {
        let now = Instant::now();
        batch.retain(|req| {
            let waited = now.saturating_duration_since(req.submitted);
            if waited <= deadline {
                return true;
            }
            metrics.record_shed_for(&req.model);
            depth.fetch_sub(1, Ordering::AcqRel);
            let _ = req.reply.send(InferResponse::shed(
                req.id,
                &req.model,
                waited.as_micros() as u64,
            ));
            false
        });
    }
    // PerSample capacity is 1, so each "batch" is one request.
    for chunk in batch.chunks(capacity) {
        *batch_seq += 1;
        serve_chunk(
            engine, cfg.mode, capacity, model, chunk, metrics, depth, *batch_seq,
        )?;
    }
    Ok(())
}

#[allow(clippy::too_many_arguments)]
fn serve_chunk(
    engine: &mut Engine,
    mode: DispatchMode,
    capacity: usize,
    model_name: &str,
    chunk: &[InferRequest],
    metrics: &Arc<Metrics>,
    depth: &Arc<AtomicUsize>,
    batch_seq: u64,
) -> anyhow::Result<()> {
    let mols: Vec<&Molecule> = chunk.iter().map(|r| &r.mol).collect();
    let (n_out, logits, version, device_us) = match engine {
        Engine::Pjrt {
            rt,
            model,
            ptensors,
            artifact,
        } => {
            let mb =
                pack_molecules(&mols, capacity, model.max_nodes, model.ell_width, model.n_out)?;
            let mut inputs = ptensors.to_vec();
            inputs.extend(batch_tensors(&mb, false));
            let t0 = Instant::now();
            let out = rt.run(artifact, &inputs)?;
            let device_us = t0.elapsed().as_micros() as u64;
            // The PJRT path has no registry versioning: version 0.
            (model.n_out, out[0].as_f32()?.to_vec(), 0u64, device_us)
        }
        Engine::Host(md) => {
            let mcfg = md.registry().cfg(model_name)?.clone();
            let mb = pack_molecules(&mols, capacity, mcfg.max_nodes, mcfg.ell_width, mcfg.n_out)?;
            let t0 = Instant::now();
            // One registry read pins the parameter version for the
            // whole chunk (MultiDispatcher::forward) — a concurrent
            // swap lands between chunks, never inside one.
            let (logits, version) = md.forward(model_name, mode, &mb)?;
            let device_us = t0.elapsed().as_micros() as u64;
            // Surface the dispatcher's plan-cache accounting: a steady
            // stream of same-capacity batches shows plans_built frozen
            // and plan_replays tracking the batch count (DESIGN.md §11);
            // after an AOT warm start (DESIGN.md §13) plans_built stays
            // 0 outright and plans_warmed names the boot's artifacts.
            let ps = md.plan_stats();
            metrics.record_plans(ps.plans_built, ps.plans_warmed, ps.replays);
            metrics.record_swaps(md.registry().total_swaps());
            (mcfg.n_out, logits, version, device_us)
        }
    };
    metrics.record_batch_for(model_name, chunk.len(), capacity, device_us);
    let done = Instant::now();
    for (bi, req) in chunk.iter().enumerate() {
        let latency_us = done.duration_since(req.submitted).as_micros() as u64;
        let queue_us = latency_us.saturating_sub(device_us);
        metrics.record_request_for(&req.model, latency_us, queue_us);
        depth.fetch_sub(1, Ordering::AcqRel);
        let _ = req.reply.send(InferResponse {
            id: req.id,
            model: req.model.clone(),
            version,
            batch_seq,
            logits: logits[bi * n_out..(bi + 1) * n_out].to_vec(),
            latency_us,
            batch_size: chunk.len(),
            shed: false,
        });
    }
    Ok(())
}

/// Load a raw little-endian f32 parameter blob (same format as the AOT
/// init file; `examples/train_chemgcn.rs` writes one after training).
pub fn load_params_blob(
    cfg: &crate::gcn::config::ModelConfig,
    path: &std::path::Path,
) -> anyhow::Result<ParamSet> {
    let bytes = std::fs::read(path)?;
    anyhow::ensure!(
        bytes.len() == cfg.n_params * 4,
        "params blob {} has {} bytes, expected {}",
        path.display(),
        bytes.len(),
        cfg.n_params * 4
    );
    Ok(ParamSet {
        data: bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect(),
    })
}

/// Save parameters in the same blob format.
pub fn save_params_blob(ps: &ParamSet, path: &std::path::Path) -> anyhow::Result<()> {
    let bytes: Vec<u8> = ps.data.iter().flat_map(|v| v.to_le_bytes()).collect();
    std::fs::write(path, bytes)?;
    Ok(())
}
