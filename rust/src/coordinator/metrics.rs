//! Serving/training metrics: latency histograms, throughput, batch
//! occupancy, and per-op dispatch accounting (the data behind our
//! Table III/IV reproductions).

use std::sync::Mutex;
use std::time::Instant;

use crate::util::stats::LatencyHistogram;

#[derive(Debug, Default)]
struct Inner {
    latency: LatencyHistogram,
    queue_wait: LatencyHistogram,
    requests: u64,
    batches: u64,
    batch_slots: u64,
    batch_capacity: u64,
    device_busy_us: u64,
    /// Requests refused without execution (admission bounce or
    /// deadline drop).
    shed: u64,
    /// Highest admitted-but-unanswered depth ever observed.
    queue_depth_hwm: u64,
    /// `batch_size_counts[s]` = number of emitted batches of exactly
    /// `s` requests (index 0 unused; grown on demand).
    batch_size_counts: Vec<u64>,
    /// Latest plan-cache accounting from the host-engine backend
    /// (DESIGN.md §11/§13): compiled step plans, plans warm-started
    /// from AOT artifacts, and cached replays. Zero on the PJRT
    /// backend.
    plans_built: u64,
    plans_warmed: u64,
    plan_replays: u64,
    /// Registry-wide parameter hot swaps (gauge: newest registry count
    /// wins, like the plan counters). Zero on single-model servers that
    /// never swap.
    param_swaps: u64,
    /// Per-model breakdown (DESIGN.md §15), keyed by registered model
    /// name in first-seen order. Aggregate counters above always
    /// include these; single-model servers see one entry.
    per_model: Vec<(String, ModelInner)>,
    started: Option<Instant>,
    finished: Option<Instant>,
}

impl Inner {
    fn model_mut(&mut self, model: &str) -> &mut ModelInner {
        if let Some(pos) = self.per_model.iter().position(|(m, _)| m == model) {
            return &mut self.per_model[pos].1;
        }
        self.per_model
            .push((model.to_string(), ModelInner::default()));
        &mut self.per_model.last_mut().unwrap().1
    }
}

/// Per-model slice of the serving counters.
#[derive(Debug, Default)]
struct ModelInner {
    latency: LatencyHistogram,
    requests: u64,
    shed: u64,
    batches: u64,
    batch_slots: u64,
    batch_capacity: u64,
}

/// Thread-safe metrics sink shared between client and server threads.
#[derive(Debug, Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

/// A point-in-time snapshot for reporting.
#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub batches: u64,
    pub mean_latency_us: f64,
    /// SLO quantiles from the power-of-two latency histogram
    /// (conservative bucket upper bounds, `LatencyHistogram`
    /// semantics).
    pub p50_latency_us: u64,
    pub p95_latency_us: u64,
    pub p99_latency_us: u64,
    pub p999_latency_us: u64,
    pub max_latency_us: u64,
    pub mean_queue_wait_us: f64,
    /// Requests shed (admission bounce or deadline drop) — these never
    /// executed and are not in `requests` or the latency histogram.
    pub shed: u64,
    /// High-water mark of admitted-but-unanswered requests. With a
    /// bounded admission queue this never exceeds the bound.
    pub queue_depth_hwm: u64,
    /// Per-batch-size occupancy: `(size, batches_of_that_size)` pairs,
    /// ascending by size, zero-count sizes omitted.
    pub batch_size_counts: Vec<(usize, u64)>,
    pub mean_batch_size: f64,
    pub mean_occupancy: f64,
    pub device_busy_us: u64,
    /// Step plans compiled by the host-engine backend (0 on PJRT).
    /// A server warm-started from AOT artifacts (DESIGN.md §13) serves
    /// steady state with this at 0.
    pub plans_built: u64,
    /// Plans installed from AOT artifacts at boot (0 on PJRT and on
    /// cold boots).
    pub plans_warmed: u64,
    /// Forwards served by replaying a cached plan (0 on PJRT).
    pub plan_replays: u64,
    /// Registry-wide parameter hot swaps completed
    /// (`ModelRegistry::total_swaps` at snapshot time).
    pub param_swaps: u64,
    /// Per-model latency/shed/occupancy breakdown, in first-served
    /// order. Empty until a model-tagged record lands.
    pub per_model: Vec<ModelMetricsSnapshot>,
    pub wall_secs: f64,
    pub throughput_rps: f64,
}

/// One model's slice of a [`MetricsSnapshot`].
#[derive(Clone, Debug)]
pub struct ModelMetricsSnapshot {
    pub model: String,
    pub requests: u64,
    pub shed: u64,
    pub batches: u64,
    pub mean_latency_us: f64,
    pub p50_latency_us: u64,
    pub p99_latency_us: u64,
    /// Mean filled-slot fraction of this model's device batches.
    pub mean_occupancy: f64,
}

impl MetricsSnapshot {
    /// The per-model slice for `model`, if any requests or sheds were
    /// recorded against it.
    pub fn model(&self, model: &str) -> Option<&ModelMetricsSnapshot> {
        self.per_model.iter().find(|m| m.model == model)
    }
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn mark_start(&self) {
        let mut g = self.inner.lock().unwrap();
        let now = Instant::now();
        g.started.get_or_insert(now);
        g.finished = None;
    }

    pub fn mark_finish(&self) {
        self.inner.lock().unwrap().finished = Some(Instant::now());
    }

    pub fn record_request(&self, latency_us: u64, queue_wait_us: u64) {
        let mut g = self.inner.lock().unwrap();
        g.latency.record_us(latency_us);
        g.queue_wait.record_us(queue_wait_us);
        g.requests += 1;
    }

    /// [`Metrics::record_request`] plus the per-model breakdown.
    pub fn record_request_for(&self, model: &str, latency_us: u64, queue_wait_us: u64) {
        let mut g = self.inner.lock().unwrap();
        g.latency.record_us(latency_us);
        g.queue_wait.record_us(queue_wait_us);
        g.requests += 1;
        let m = g.model_mut(model);
        m.latency.record_us(latency_us);
        m.requests += 1;
    }

    pub fn record_batch(&self, size: usize, capacity: usize, device_us: u64) {
        let mut g = self.inner.lock().unwrap();
        g.batches += 1;
        g.batch_slots += size as u64;
        g.batch_capacity += capacity as u64;
        g.device_busy_us += device_us;
        if g.batch_size_counts.len() <= size {
            g.batch_size_counts.resize(size + 1, 0);
        }
        g.batch_size_counts[size] += 1;
    }

    /// [`Metrics::record_batch`] plus the per-model breakdown.
    pub fn record_batch_for(&self, model: &str, size: usize, capacity: usize, device_us: u64) {
        let mut g = self.inner.lock().unwrap();
        g.batches += 1;
        g.batch_slots += size as u64;
        g.batch_capacity += capacity as u64;
        g.device_busy_us += device_us;
        if g.batch_size_counts.len() <= size {
            g.batch_size_counts.resize(size + 1, 0);
        }
        g.batch_size_counts[size] += 1;
        let m = g.model_mut(model);
        m.batches += 1;
        m.batch_slots += size as u64;
        m.batch_capacity += capacity as u64;
    }

    /// One request refused without execution.
    pub fn record_shed(&self) {
        self.inner.lock().unwrap().shed += 1;
    }

    /// [`Metrics::record_shed`] plus the per-model breakdown.
    pub fn record_shed_for(&self, model: &str) {
        let mut g = self.inner.lock().unwrap();
        g.shed += 1;
        g.model_mut(model).shed += 1;
    }

    /// Store the registry-wide hot-swap count (cumulative on the
    /// registry side, so the newest snapshot wins).
    pub fn record_swaps(&self, param_swaps: u64) {
        self.inner.lock().unwrap().param_swaps = param_swaps;
    }

    /// Observe the current admitted-but-unanswered depth; keeps the
    /// high-water mark.
    pub fn record_queue_depth(&self, depth: usize) {
        let mut g = self.inner.lock().unwrap();
        g.queue_depth_hwm = g.queue_depth_hwm.max(depth as u64);
    }

    /// Store the latest plan-cache counters (cumulative on the source
    /// side, so the newest snapshot wins).
    pub fn record_plans(&self, plans_built: u64, plans_warmed: u64, plan_replays: u64) {
        let mut g = self.inner.lock().unwrap();
        g.plans_built = plans_built;
        g.plans_warmed = plans_warmed;
        g.plan_replays = plan_replays;
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let g = self.inner.lock().unwrap();
        let wall = match (g.started, g.finished) {
            (Some(s), Some(f)) => f.duration_since(s).as_secs_f64(),
            (Some(s), None) => s.elapsed().as_secs_f64(),
            _ => 0.0,
        };
        MetricsSnapshot {
            requests: g.requests,
            batches: g.batches,
            mean_latency_us: g.latency.mean_us(),
            p50_latency_us: g.latency.quantile_us(0.50),
            p95_latency_us: g.latency.quantile_us(0.95),
            p99_latency_us: g.latency.quantile_us(0.99),
            p999_latency_us: g.latency.quantile_us(0.999),
            max_latency_us: g.latency.max_us(),
            mean_queue_wait_us: g.queue_wait.mean_us(),
            shed: g.shed,
            queue_depth_hwm: g.queue_depth_hwm,
            batch_size_counts: g
                .batch_size_counts
                .iter()
                .enumerate()
                .filter(|(_, &c)| c > 0)
                .map(|(s, &c)| (s, c))
                .collect(),
            mean_batch_size: if g.batches == 0 {
                0.0
            } else {
                g.batch_slots as f64 / g.batches as f64
            },
            mean_occupancy: if g.batch_capacity == 0 {
                0.0
            } else {
                g.batch_slots as f64 / g.batch_capacity as f64
            },
            device_busy_us: g.device_busy_us,
            plans_built: g.plans_built,
            plans_warmed: g.plans_warmed,
            plan_replays: g.plan_replays,
            param_swaps: g.param_swaps,
            per_model: g
                .per_model
                .iter()
                .map(|(name, m)| ModelMetricsSnapshot {
                    model: name.clone(),
                    requests: m.requests,
                    shed: m.shed,
                    batches: m.batches,
                    mean_latency_us: m.latency.mean_us(),
                    p50_latency_us: m.latency.quantile_us(0.50),
                    p99_latency_us: m.latency.quantile_us(0.99),
                    mean_occupancy: if m.batch_capacity == 0 {
                        0.0
                    } else {
                        m.batch_slots as f64 / m.batch_capacity as f64
                    },
                })
                .collect(),
            wall_secs: wall,
            throughput_rps: if wall > 0.0 {
                g.requests as f64 / wall
            } else {
                0.0
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_snapshots() {
        let m = Metrics::new();
        m.mark_start();
        m.record_request(1000, 200);
        m.record_request(3000, 600);
        m.record_batch(2, 4, 1500);
        m.record_plans(1, 2, 7);
        m.mark_finish();
        let s = m.snapshot();
        assert_eq!(s.requests, 2);
        assert_eq!(s.batches, 1);
        assert_eq!((s.plans_built, s.plans_warmed, s.plan_replays), (1, 2, 7));
        assert!((s.mean_latency_us - 2000.0).abs() < 1.0);
        assert!((s.mean_batch_size - 2.0).abs() < 1e-12);
        assert!((s.mean_occupancy - 0.5).abs() < 1e-12);
        assert!(s.throughput_rps > 0.0);
        assert_eq!(s.device_busy_us, 1500);
        assert_eq!(s.batch_size_counts, vec![(2, 1)]);
        // Quantiles are conservative bucket upper bounds and monotone.
        assert!(s.p50_latency_us >= 1000 && s.p50_latency_us <= s.p99_latency_us);
        assert!(s.p99_latency_us <= s.p999_latency_us);
        assert!(s.p999_latency_us >= 3000);
    }

    #[test]
    fn shed_and_depth_accounting() {
        let m = Metrics::new();
        m.record_queue_depth(3);
        m.record_queue_depth(9);
        m.record_queue_depth(2);
        m.record_shed();
        m.record_shed();
        m.record_batch(4, 4, 10);
        m.record_batch(4, 4, 10);
        m.record_batch(1, 4, 10);
        let s = m.snapshot();
        assert_eq!(s.shed, 2);
        assert_eq!(s.queue_depth_hwm, 9);
        assert_eq!(s.batch_size_counts, vec![(1, 1), (4, 2)]);
        // Shed requests never enter the request count or histogram.
        assert_eq!(s.requests, 0);
    }

    #[test]
    fn empty_snapshot_is_zeroed() {
        let s = Metrics::new().snapshot();
        assert_eq!(s.requests, 0);
        assert_eq!(s.mean_batch_size, 0.0);
        assert_eq!(s.throughput_rps, 0.0);
        assert_eq!(s.param_swaps, 0);
        assert!(s.per_model.is_empty());
    }

    #[test]
    fn per_model_breakdown_splits_the_aggregate() {
        let m = Metrics::new();
        m.record_request_for("tox21", 1000, 100);
        m.record_request_for("tox21", 3000, 100);
        m.record_request_for("reaction100", 9000, 100);
        m.record_batch_for("tox21", 2, 4, 50);
        m.record_batch_for("reaction100", 1, 4, 50);
        m.record_shed_for("reaction100");
        m.record_swaps(3);
        let s = m.snapshot();
        // Aggregates include every model.
        assert_eq!(s.requests, 3);
        assert_eq!(s.batches, 2);
        assert_eq!(s.shed, 1);
        assert_eq!(s.param_swaps, 3);
        assert_eq!(s.per_model.len(), 2);
        let tox = s.model("tox21").unwrap();
        assert_eq!((tox.requests, tox.shed, tox.batches), (2, 0, 1));
        assert!((tox.mean_latency_us - 2000.0).abs() < 1.0);
        assert!((tox.mean_occupancy - 0.5).abs() < 1e-12);
        let rxn = s.model("reaction100").unwrap();
        assert_eq!((rxn.requests, rxn.shed, rxn.batches), (1, 1, 1));
        assert!(rxn.p99_latency_us >= 9000);
        assert!((rxn.mean_occupancy - 0.25).abs() < 1e-12);
        assert!(s.model("nope").is_none());
    }
}
