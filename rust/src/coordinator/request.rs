//! Inference request/response types.

use std::sync::mpsc;
use std::time::Instant;

use crate::graph::molecule::Molecule;

/// A unique, monotonically-assigned request id.
pub type RequestId = u64;

/// One inference request: a molecule to classify, addressed to one
/// registered model (batches form per model — DESIGN.md §15).
#[derive(Debug)]
pub struct InferRequest {
    pub id: RequestId,
    /// Registered model this request is addressed to
    /// ([`Server::submit`](super::Server::submit) fills in the server's
    /// default model; [`Server::submit_to`](super::Server::submit_to)
    /// targets any registry entry).
    pub model: String,
    pub mol: Molecule,
    pub submitted: Instant,
    /// Where the server sends the answer.
    pub reply: mpsc::Sender<InferResponse>,
}

/// The server's answer.
#[derive(Clone, Debug)]
pub struct InferResponse {
    pub id: RequestId,
    /// The model that served (or shed) the request.
    pub model: String,
    /// Parameter version the logits were computed under
    /// (`ModelRegistry` version numbering, 1-based). `0` when shed or
    /// when the backend has no registry versioning (PJRT device path).
    /// The hot-swap test replays this exact version to prove no batch
    /// mixed versions.
    pub version: u64,
    /// Sequence number of the device batch this request rode in
    /// (1-based per server; `0` when shed). Requests sharing a
    /// `batch_seq` were computed in one engine dispatch — and therefore
    /// must share a `version`.
    pub batch_seq: u64,
    /// Model logits for this molecule. Empty when `shed`.
    pub logits: Vec<f32>,
    /// End-to-end latency (enqueue -> response ready). For shed
    /// requests: time from submit to the shed decision.
    pub latency_us: u64,
    /// Size of the device batch this request rode in (1 in non-batched
    /// mode, 0 when `shed`) — the occupancy signal for the Table III
    /// analysis.
    pub batch_size: usize,
    /// True when the server refused the request instead of executing it
    /// — either bounced at admission (queue at `queue_bound`) or
    /// dropped at batch assembly (older than `deadline`). Shed requests
    /// never reach the engine; `logits` is empty.
    pub shed: bool,
}

impl InferResponse {
    /// A load-shedding refusal: no logits, never executed.
    pub fn shed(id: RequestId, model: &str, latency_us: u64) -> Self {
        Self {
            id,
            model: model.to_string(),
            version: 0,
            batch_seq: 0,
            logits: Vec::new(),
            latency_us,
            batch_size: 0,
            shed: true,
        }
    }
}
