//! Inference request/response types.

use std::sync::mpsc;
use std::time::Instant;

use crate::graph::molecule::Molecule;

/// A unique, monotonically-assigned request id.
pub type RequestId = u64;

/// One inference request: a molecule to classify.
#[derive(Debug)]
pub struct InferRequest {
    pub id: RequestId,
    pub mol: Molecule,
    pub submitted: Instant,
    /// Where the server sends the answer.
    pub reply: mpsc::Sender<InferResponse>,
}

/// The server's answer.
#[derive(Clone, Debug)]
pub struct InferResponse {
    pub id: RequestId,
    /// Model logits for this molecule.
    pub logits: Vec<f32>,
    /// End-to-end latency (enqueue -> response ready).
    pub latency_us: u64,
    /// Size of the device batch this request rode in (1 in non-batched
    /// mode) — the occupancy signal for the Table III analysis.
    pub batch_size: usize,
}
