//! Inference request/response types.

use std::sync::mpsc;
use std::time::Instant;

use crate::graph::molecule::Molecule;

/// A unique, monotonically-assigned request id.
pub type RequestId = u64;

/// One inference request: a molecule to classify.
#[derive(Debug)]
pub struct InferRequest {
    pub id: RequestId,
    pub mol: Molecule,
    pub submitted: Instant,
    /// Where the server sends the answer.
    pub reply: mpsc::Sender<InferResponse>,
}

/// The server's answer.
#[derive(Clone, Debug)]
pub struct InferResponse {
    pub id: RequestId,
    /// Model logits for this molecule. Empty when `shed`.
    pub logits: Vec<f32>,
    /// End-to-end latency (enqueue -> response ready). For shed
    /// requests: time from submit to the shed decision.
    pub latency_us: u64,
    /// Size of the device batch this request rode in (1 in non-batched
    /// mode, 0 when `shed`) — the occupancy signal for the Table III
    /// analysis.
    pub batch_size: usize,
    /// True when the server refused the request instead of executing it
    /// — either bounced at admission (queue at `queue_bound`) or
    /// dropped at batch assembly (older than `deadline`). Shed requests
    /// never reach the engine; `logits` is empty.
    pub shed: bool,
}

impl InferResponse {
    /// A load-shedding refusal: no logits, never executed.
    pub fn shed(id: RequestId, latency_us: u64) -> Self {
        Self {
            id,
            logits: Vec::new(),
            latency_us,
            batch_size: 0,
            shed: true,
        }
    }
}
