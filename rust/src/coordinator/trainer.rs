//! Training loop in both dispatch modes (the Table II experiment).
//!
//! * **Batched** (Fig. 7): one `train_step` execute per minibatch — the
//!   whole fwd+bwd+SGD is a single device dispatch.
//! * **NonBatched** (Fig. 6): one `grad_sample` execute per *sample*
//!   (B dispatches), gradients accumulated host-side, then one
//!   `apply_sgd` execute. Identical mathematics (the model is exactly
//!   per-sample decomposable — see python/compile/model.py), so the
//!   timing comparison isolates dispatch overhead + device occupancy,
//!   which is precisely the paper's claim.
//!
//! Forward/evaluation additionally run on the host batched-SpMM engine
//! ([`Trainer::new_host`]): same `BatchedSpmm`-routed math, no
//! artifacts. Training steps need the AOT gradient artifacts and stay
//! PJRT-only.

use std::path::Path;

use crate::gcn::config::ModelConfig;
use crate::gcn::params::ParamSet;
use crate::gcn::reference;
use crate::graph::dataset::{Dataset, ModelBatch};
use crate::runtime::{Runtime, Tensor};
use crate::sparse::engine::Executor;
use crate::sparse::ops::axpy;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TrainMode {
    Batched,
    NonBatched,
}

/// Build the artifact input tensors for one packed batch.
pub fn batch_tensors(mb: &ModelBatch, with_labels: bool) -> Vec<Tensor> {
    let mut v = vec![
        Tensor::i32(
            &[mb.batch, mb.channels, mb.max_nodes, mb.ell_width],
            mb.ell_cols.clone(),
        ),
        Tensor::f32(
            &[mb.batch, mb.channels, mb.max_nodes, mb.ell_width],
            mb.ell_vals.clone(),
        ),
        Tensor::f32(&[mb.batch, mb.max_nodes, mb.feat_dim], mb.x.clone()),
        Tensor::f32(&[mb.batch, mb.max_nodes], mb.mask.clone()),
    ];
    if with_labels {
        v.push(Tensor::f32(&[mb.batch, mb.n_out], mb.labels.clone()));
    }
    v
}

/// Parameter tensors in artifact order.
pub fn param_tensors(cfg: &ModelConfig, ps: &ParamSet) -> Vec<Tensor> {
    cfg.params
        .iter()
        .zip(ps.views(cfg))
        .map(|(p, view)| Tensor::f32(&p.shape, view.to_vec()))
        .collect()
}

/// Epoch-level training statistics.
#[derive(Clone, Debug)]
pub struct EpochStats {
    pub epoch: usize,
    pub mean_loss: f64,
    pub secs: f64,
    pub dispatches: u64,
}

pub struct Trainer {
    /// PJRT runtime; `None` on the host-engine backend.
    pub rt: Option<Runtime>,
    /// Host engine executor; `None` on the PJRT backend.
    host_exec: Option<Executor>,
    pub cfg: ModelConfig,
    pub params: ParamSet,
    /// Device dispatch counter (executes issued) — the Fig. 11 signal.
    pub dispatches: u64,
}

impl Trainer {
    pub fn new(artifacts_dir: &Path, model: &str) -> anyhow::Result<Trainer> {
        let rt = Runtime::new(artifacts_dir)?;
        let cfg = rt.manifest.model(model)?.clone();
        let params = ParamSet::load_init(&cfg, &rt.manifest.dir)?;
        Ok(Trainer {
            rt: Some(rt),
            host_exec: None,
            cfg,
            params,
            dispatches: 0,
        })
    }

    /// Host-engine trainer (no artifacts): forward/evaluate route
    /// through the batched-SpMM engine; training steps, which need the
    /// AOT gradient artifacts, return an error. `threads = 0` means one
    /// thread per core.
    pub fn new_host(model: &str, threads: usize) -> anyhow::Result<Trainer> {
        let cfg = ModelConfig::synthetic(model)?;
        let params = ParamSet::random_init(&cfg, 0x5EED);
        Ok(Trainer {
            rt: None,
            host_exec: Some(Executor::auto(threads)),
            cfg,
            params,
            dispatches: 0,
        })
    }

    fn pjrt(&self) -> anyhow::Result<&Runtime> {
        self.rt.as_ref().ok_or_else(|| {
            anyhow::anyhow!(
                "training requires the PJRT artifacts; the host-engine backend is \
                 forward/evaluate-only"
            )
        })
    }

    /// One batched train step; returns the minibatch loss.
    pub fn step_batched(&mut self, mb: &ModelBatch, lr: f32) -> anyhow::Result<f32> {
        anyhow::ensure!(mb.batch == self.cfg.train_batch, "batch size mismatch");
        let mut inputs = param_tensors(&self.cfg, &self.params);
        inputs.extend(batch_tensors(mb, true));
        inputs.push(Tensor::scalar_f32(lr));
        let out = self.pjrt()?.run(&self.cfg.artifact_train_step, &inputs)?;
        self.dispatches += 1;
        anyhow::ensure!(out.len() == self.cfg.params.len() + 1, "bad output arity");
        for (p, t) in self.cfg.params.iter().zip(&out) {
            self.params.data[p.offset..p.offset + p.size]
                .copy_from_slice(t.as_f32()?);
        }
        Ok(out.last().unwrap().as_f32()?[0])
    }

    /// One non-batched train step: B grad dispatches + host-side
    /// accumulation + one apply_sgd dispatch.
    pub fn step_nonbatched(&mut self, mb: &ModelBatch, lr: f32) -> anyhow::Result<f32> {
        let b = mb.batch;
        let mut grad_sum = vec![0f32; self.cfg.n_params];
        let mut loss_sum = 0f64;
        let exe = self.pjrt()?.executable(&self.cfg.artifact_grad_sample)?;
        for bi in 0..b {
            let one = mb.single(bi);
            let mut inputs = param_tensors(&self.cfg, &self.params);
            inputs.extend(batch_tensors(&one, true));
            let out = exe.execute(&inputs)?;
            self.dispatches += 1;
            for (p, t) in self.cfg.params.iter().zip(&out) {
                axpy(1.0, t.as_f32()?, &mut grad_sum[p.offset..p.offset + p.size]);
            }
            loss_sum += out.last().unwrap().as_f32()?[0] as f64;
        }
        // params <- params - (lr / B) * grad_sum, on device.
        let mut inputs = param_tensors(&self.cfg, &self.params);
        for p in &self.cfg.params {
            inputs.push(Tensor::f32(
                &p.shape,
                grad_sum[p.offset..p.offset + p.size].to_vec(),
            ));
        }
        inputs.push(Tensor::scalar_f32(lr / b as f32));
        let out = self.pjrt()?.run(&self.cfg.artifact_apply_sgd, &inputs)?;
        self.dispatches += 1;
        for (p, t) in self.cfg.params.iter().zip(&out) {
            self.params.data[p.offset..p.offset + p.size]
                .copy_from_slice(t.as_f32()?);
        }
        Ok((loss_sum / b as f64) as f32)
    }

    /// Train over `idx` (shuffled by the caller) for one epoch;
    /// incomplete trailing minibatches are dropped (paper-style).
    pub fn train_epoch(
        &mut self,
        mode: TrainMode,
        data: &Dataset,
        idx: &[usize],
        lr: f32,
        epoch: usize,
    ) -> anyhow::Result<EpochStats> {
        let b = self.cfg.train_batch;
        let d0 = self.dispatches;
        let t0 = std::time::Instant::now();
        let mut losses = Vec::new();
        for chunk in idx.chunks_exact(b) {
            let mb = data.pack_batch(chunk, self.cfg.max_nodes, self.cfg.ell_width)?;
            let loss = match mode {
                TrainMode::Batched => self.step_batched(&mb, lr)?,
                TrainMode::NonBatched => self.step_nonbatched(&mb, lr)?,
            };
            losses.push(loss as f64);
        }
        anyhow::ensure!(!losses.is_empty(), "epoch with no full minibatch");
        Ok(EpochStats {
            epoch,
            mean_loss: losses.iter().sum::<f64>() / losses.len() as f64,
            secs: t0.elapsed().as_secs_f64(),
            dispatches: self.dispatches - d0,
        })
    }

    /// Forward a packed batch: one engine dispatch on the host backend,
    /// or the matching fwd artifact on PJRT.
    pub fn forward(&mut self, mb: &ModelBatch) -> anyhow::Result<Vec<f32>> {
        if let Some(exec) = self.host_exec {
            self.dispatches += 1;
            return reference::forward_with(&self.cfg, &self.params, mb, &exec);
        }
        let name = if mb.batch == self.cfg.infer_batch {
            &self.cfg.artifact_fwd_infer
        } else if mb.batch == self.cfg.train_batch {
            &self.cfg.artifact_fwd_train
        } else if mb.batch == 1 {
            &self.cfg.artifact_fwd_sample
        } else {
            anyhow::bail!("no fwd artifact for batch {}", mb.batch)
        };
        let mut inputs = param_tensors(&self.cfg, &self.params);
        inputs.extend(batch_tensors(mb, false));
        let out = self.pjrt()?.run(name, &inputs)?;
        self.dispatches += 1;
        Ok(out[0].as_f32()?.to_vec())
    }

    /// Loss + accuracy over `idx`: full train-batch-sized fwd dispatches
    /// plus per-sample dispatches for the remainder (sample-weighted).
    pub fn evaluate(&mut self, data: &Dataset, idx: &[usize]) -> anyhow::Result<(f64, f64)> {
        anyhow::ensure!(!idx.is_empty(), "evaluate on empty index set");
        let b = self.cfg.train_batch;
        let mut loss_sum = 0f64;
        let mut acc_sum = 0f64;
        let mut n = 0usize;
        for chunk in idx.chunks(b) {
            let mb = data.pack_batch(chunk, self.cfg.max_nodes, self.cfg.ell_width)?;
            if chunk.len() == b {
                let logits = self.forward(&mb)?;
                loss_sum +=
                    reference::loss(&self.cfg, &logits, &mb.labels, b) as f64 * b as f64;
                acc_sum += reference::accuracy(&self.cfg, &logits, &mb.labels, b) * b as f64;
            } else {
                for bi in 0..chunk.len() {
                    let one = mb.single(bi);
                    let logits = self.forward(&one)?;
                    loss_sum += reference::loss(&self.cfg, &logits, &one.labels, 1) as f64;
                    acc_sum += reference::accuracy(&self.cfg, &logits, &one.labels, 1);
                }
            }
            n += chunk.len();
        }
        Ok((loss_sum / n as f64, acc_sum / n as f64))
    }
}
