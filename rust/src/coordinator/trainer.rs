//! Training loop in both dispatch modes (the Table II experiment), on
//! either execution backend.
//!
//! * **Batched** (Fig. 7): one `train_step` execute per minibatch — the
//!   whole fwd+bwd+SGD is a single device dispatch.
//! * **NonBatched** (Fig. 6): one `grad_sample` execute per *sample*
//!   (B dispatches), gradients accumulated host-side, then one
//!   `apply_sgd` execute. Identical mathematics (the model is exactly
//!   per-sample decomposable — see python/compile/model.py), so the
//!   timing comparison isolates dispatch overhead + device occupancy,
//!   which is precisely the paper's claim.
//!
//! Both modes also run end-to-end on the host batched-SpMM engine
//! ([`Trainer::new_host`], no artifacts needed): forward/evaluate via
//! `gcn::reference`, training via `gcn::backward` — every gradient
//! matmul an engine dispatch (DESIGN.md §8) — plus an in-process SGD
//! apply. The trainer owns **one** executor (and with it one persistent
//! [`WorkerPool`](crate::sparse::engine::WorkerPool)) for its whole
//! lifetime: all 39 engine dispatches of a tox21 train step — and every
//! step after it — run on the same parked workers, with zero thread
//! spawns after construction (DESIGN.md §9; pinned by
//! `tests/host_serving.rs`). The host paths cache the tiled readout
//! weight `w_rep` (a pure function of `readout.w`, ~10 MB rebuilt per
//! forward otherwise) and invalidate it on every parameter update.
//!
//! The host paths also run the plan/execute split (DESIGN.md §11): one
//! compiled [`StepPlan`](crate::sparse::engine::StepPlan) +
//! [`Workspace`](crate::sparse::engine::Workspace) per (geometry,
//! mode), built on the first step of that shape and replayed after —
//! steady-state train steps rebuild no plan and allocate no
//! intermediate (pinned by `tests/host_serving.rs` via
//! [`Trainer::plan_stats`]). Geometry changes compile a new entry;
//! parameter updates keep every plan (only `w_rep` is
//! parameter-derived).

use std::path::Path;

use crate::gcn::backward;
use crate::gcn::config::ModelConfig;
use crate::gcn::params::ParamSet;
use crate::gcn::reference;
use crate::graph::dataset::{Dataset, ModelBatch};
use crate::runtime::plan_artifact::{self, WarmStartReport};
use crate::runtime::{Runtime, Tensor};
use crate::sparse::engine::{AutoThresholds, Executor, PlanCache, PlanStats};
use crate::sparse::ops::axpy;

/// Inference-only precision selector for the reduced-precision serving
/// path (DESIGN.md §16) — the serving-facing name of the engine's
/// [`DType`](crate::sparse::engine::DType). `F32` is the training
/// precision; `Bf16`/`Int8` quantize the adjacency at pack time and
/// round the weights through bf16, trading a bounded accuracy delta
/// (pinned by AUC tests here) for smaller dispatch traffic.
pub use crate::sparse::engine::DType as Precision;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TrainMode {
    Batched,
    NonBatched,
}

/// Build the artifact input tensors for one packed batch.
pub fn batch_tensors(mb: &ModelBatch, with_labels: bool) -> Vec<Tensor> {
    let mut v = vec![
        Tensor::i32(
            &[mb.batch, mb.channels, mb.max_nodes, mb.ell_width],
            mb.ell_cols.clone(),
        ),
        Tensor::f32(
            &[mb.batch, mb.channels, mb.max_nodes, mb.ell_width],
            mb.ell_vals.clone(),
        ),
        Tensor::f32(&[mb.batch, mb.max_nodes, mb.feat_dim], mb.x.clone()),
        Tensor::f32(&[mb.batch, mb.max_nodes], mb.mask.clone()),
    ];
    if with_labels {
        v.push(Tensor::f32(&[mb.batch, mb.n_out], mb.labels.clone()));
    }
    v
}

/// Parameter tensors in artifact order.
pub fn param_tensors(cfg: &ModelConfig, ps: &ParamSet) -> Vec<Tensor> {
    cfg.params
        .iter()
        .zip(ps.views(cfg))
        .map(|(p, view)| Tensor::f32(&p.shape, view.to_vec()))
        .collect()
}

/// Epoch-level training statistics.
#[derive(Clone, Debug)]
pub struct EpochStats {
    pub epoch: usize,
    pub mean_loss: f64,
    pub secs: f64,
    pub dispatches: u64,
}

pub struct Trainer {
    /// PJRT runtime; `None` on the host-engine backend.
    pub rt: Option<Runtime>,
    /// Host engine executor; `None` on the PJRT backend.
    host_exec: Option<Executor>,
    pub cfg: ModelConfig,
    /// Replace via [`Trainer::set_params`], or follow a direct write
    /// with [`Trainer::invalidate_cache`] — the host paths cache state
    /// derived from these values.
    pub params: ParamSet,
    /// Device dispatch counter (executes issued) — the Fig. 11 signal.
    /// Host-engine steps count in the same units as their artifact
    /// twins: 1 per batched step, B+1 per non-batched step, 1 per
    /// forward.
    pub dispatches: u64,
    /// Cached tiled readout weight (`reference::build_w_rep`) for the
    /// host-engine paths; rebuilt lazily, dropped on every parameter
    /// update.
    w_rep: Option<Vec<f32>>,
    /// One compiled (plan, workspace) per (geometry, mode) for the
    /// host-engine paths (DESIGN.md §11): a fixed-geometry training
    /// loop compiles its train plan on step 1 and replays it — with
    /// zero intermediate allocations — from step 2 on. Geometry
    /// changes compile a new entry; parameter updates keep every plan.
    plans: PlanCache,
    /// Auto-backend decision thresholds baked into new plans.
    thresholds: AutoThresholds,
    /// Persistent gradient accumulator for the planned host backward
    /// (sized lazily on the first host step, reused forever after).
    grad_buf: Vec<f32>,
}

impl Trainer {
    pub fn new(artifacts_dir: &Path, model: &str) -> anyhow::Result<Trainer> {
        let rt = Runtime::new(artifacts_dir)?;
        let cfg = rt.manifest.model(model)?.clone();
        let params = ParamSet::load_init(&cfg, &rt.manifest.dir)?;
        Ok(Trainer {
            rt: Some(rt),
            host_exec: None,
            cfg,
            params,
            dispatches: 0,
            w_rep: None,
            plans: PlanCache::new(),
            thresholds: AutoThresholds::from_env(),
            grad_buf: Vec::new(),
        })
    }

    /// Host-engine trainer (no artifacts): forward, evaluation *and*
    /// training all route through the batched-SpMM engine — the
    /// backward pass is `gcn::backward`, the SGD apply is in-process.
    /// Constructs the trainer's one long-lived worker pool here;
    /// `threads = 0` means one thread per core.
    ///
    /// When `$BSPMM_PLAN_ARTIFACTS` is set the plan cache warm-starts
    /// from that directory (DESIGN.md §13), so steady-state steps
    /// report `plans_built == 0`; geometries without a (valid,
    /// threshold-matching) artifact compile at runtime exactly as
    /// before.
    pub fn new_host(model: &str, threads: usize) -> anyhow::Result<Trainer> {
        let cfg = ModelConfig::synthetic(model)?;
        let params = ParamSet::random_init(&cfg, 0x5EED);
        let thresholds = AutoThresholds::from_env();
        let mut plans = PlanCache::new();
        plan_artifact::warm_start_from_env(&mut plans, &thresholds)?;
        Ok(Trainer {
            rt: None,
            host_exec: Some(Executor::auto(threads)),
            cfg,
            params,
            dispatches: 0,
            w_rep: None,
            plans,
            thresholds,
            grad_buf: Vec::new(),
        })
    }

    /// Warm-start the plan cache from `dir`'s `*.plan.json` artifacts
    /// (the explicit-path form of the `$BSPMM_PLAN_ARTIFACTS` boot).
    /// Artifacts compiled under other [`AutoThresholds`] are skipped —
    /// their frozen `Backend::Auto` resolutions may disagree with this
    /// host's — and those geometries fall back to runtime compilation.
    pub fn warm_start_plans(&mut self, dir: &Path) -> anyhow::Result<WarmStartReport> {
        plan_artifact::warm_start(&mut self.plans, dir, &self.thresholds)
    }

    /// Dump every cached plan to `dir` as AOT artifacts (the producer
    /// side of [`Trainer::warm_start_plans`]); returns how many were
    /// written. Run the geometries you want to ship first — only
    /// compiled (or already-warmed) plans exist to export.
    pub fn export_plans(&self, dir: &Path) -> anyhow::Result<usize> {
        let mut n = 0;
        for plan in self.plans.plans() {
            plan_artifact::save(plan, &self.thresholds, dir)?;
            n += 1;
        }
        Ok(n)
    }

    fn pjrt(&self) -> anyhow::Result<&Runtime> {
        self.rt.as_ref().ok_or_else(|| {
            anyhow::anyhow!("no PJRT runtime: this trainer runs on the host-engine backend")
        })
    }

    /// The host-engine executor (a handle on the trainer's one worker
    /// pool); `None` on the PJRT backend. The spawn/steal-accounting
    /// tests read pool statistics through this.
    pub fn executor(&self) -> Option<&Executor> {
        self.host_exec.as_ref()
    }

    /// Replace the parameter set (e.g. with an externally trained
    /// blob) and drop parameter-derived caches. Step plans are
    /// geometry-derived and survive parameter updates.
    pub fn set_params(&mut self, params: ParamSet) {
        self.params = params;
        self.w_rep = None;
    }

    /// Drop parameter-derived caches after a direct `params` mutation.
    pub fn invalidate_cache(&mut self) {
        self.w_rep = None;
    }

    /// Plan/arena accounting across every (geometry, mode) this trainer
    /// has run (DESIGN.md §11): steady-state fixed-geometry training
    /// shows `plans_built` frozen at 1 and `arena_bytes` constant.
    pub fn plan_stats(&self) -> PlanStats {
        self.plans.stats()
    }

    /// Drop every compiled plan + workspace. The microbench's cold-plan
    /// configuration calls this between steps to measure what plan
    /// caching saves; normal training never needs it.
    pub fn clear_plan_cache(&mut self) {
        self.plans.clear();
    }

    /// Lazily (re)build the cached tiled readout weight.
    fn ensure_w_rep(&mut self) -> anyhow::Result<()> {
        if self.w_rep.is_none() {
            self.w_rep = Some(reference::build_w_rep(&self.cfg, &self.params)?);
        }
        Ok(())
    }

    /// One batched train step; returns the minibatch loss. On the host
    /// backend this is one engine-executed fwd+bwd+SGD (any batch size
    /// — the engine is not shape-locked the way the AOT artifacts are),
    /// replayed from the cached train plan of this geometry: from step
    /// 2 on, no plan is rebuilt and no intermediate is allocated
    /// (DESIGN.md §11).
    pub fn step_batched(&mut self, mb: &ModelBatch, lr: f32) -> anyhow::Result<f32> {
        anyhow::ensure!(mb.batch > 0, "train step on an empty batch");
        if let Some(exec) = self.host_exec.clone() {
            self.ensure_w_rep()?;
            if self.grad_buf.len() != self.cfg.n_params {
                self.grad_buf.resize(self.cfg.n_params, 0.0);
            }
            let cfg = &self.cfg;
            let th = self.thresholds;
            let key = backward::train_plan_key(cfg, mb);
            let (plan, ws) = self
                .plans
                .entry_with(key, || backward::plan_train(cfg, mb, &th))?;
            let loss = backward::grad_planned(
                cfg,
                &self.params,
                mb,
                &exec,
                self.w_rep.as_deref().unwrap(),
                plan,
                ws,
                &mut self.grad_buf,
            )?;
            // params <- params - lr * grad, then drop derived caches.
            axpy(-lr, &self.grad_buf, &mut self.params.data);
            self.w_rep = None;
            self.dispatches += 1;
            return Ok(loss);
        }
        anyhow::ensure!(mb.batch == self.cfg.train_batch, "batch size mismatch");
        let mut inputs = param_tensors(&self.cfg, &self.params);
        inputs.extend(batch_tensors(mb, true));
        inputs.push(Tensor::scalar_f32(lr));
        let out = self.pjrt()?.run(&self.cfg.artifact_train_step, &inputs)?;
        self.dispatches += 1;
        anyhow::ensure!(out.len() == self.cfg.params.len() + 1, "bad output arity");
        for (p, t) in self.cfg.params.iter().zip(&out) {
            self.params.data[p.offset..p.offset + p.size]
                .copy_from_slice(t.as_f32()?);
        }
        self.w_rep = None;
        Ok(out.last().unwrap().as_f32()?[0])
    }

    /// One non-batched train step: B grad dispatches + host-side
    /// accumulation + one apply step. On the host backend each grad
    /// dispatch is a batch-1 engine backward (`gcn::backward`), so the
    /// batched/non-batched contrast is structural, not mathematical —
    /// exactly as on PJRT.
    pub fn step_nonbatched(&mut self, mb: &ModelBatch, lr: f32) -> anyhow::Result<f32> {
        // lr / B below: an empty batch would silently write NaN into
        // every parameter instead of erroring.
        anyhow::ensure!(mb.batch > 0, "train step on an empty batch");
        let b = mb.batch;
        if let Some(exec) = self.host_exec.clone() {
            self.ensure_w_rep()?;
            if self.grad_buf.len() != self.cfg.n_params {
                self.grad_buf.resize(self.cfg.n_params, 0.0);
            }
            let mut grad_sum = vec![0f32; self.cfg.n_params];
            let mut loss_sum = 0f64;
            // Every per-sample gradient replays one shared batch-1
            // train plan — B replays per step, one compile ever.
            for bi in 0..b {
                let one = mb.single(bi);
                let cfg = &self.cfg;
                let th = self.thresholds;
                let key = backward::train_plan_key(cfg, &one);
                let (plan, ws) = self
                    .plans
                    .entry_with(key, || backward::plan_train(cfg, &one, &th))?;
                let loss = backward::grad_planned(
                    cfg,
                    &self.params,
                    &one,
                    &exec,
                    self.w_rep.as_deref().unwrap(),
                    plan,
                    ws,
                    &mut self.grad_buf,
                )?;
                self.dispatches += 1;
                axpy(1.0, &self.grad_buf, &mut grad_sum);
                loss_sum += loss as f64;
            }
            // params <- params - (lr / B) * grad_sum (the apply step).
            axpy(-(lr / b as f32), &grad_sum, &mut self.params.data);
            self.w_rep = None;
            self.dispatches += 1;
            return Ok((loss_sum / b as f64) as f32);
        }
        let mut grad_sum = vec![0f32; self.cfg.n_params];
        let mut loss_sum = 0f64;
        let exe = self.pjrt()?.executable(&self.cfg.artifact_grad_sample)?;
        for bi in 0..b {
            let one = mb.single(bi);
            let mut inputs = param_tensors(&self.cfg, &self.params);
            inputs.extend(batch_tensors(&one, true));
            let out = exe.execute(&inputs)?;
            self.dispatches += 1;
            for (p, t) in self.cfg.params.iter().zip(&out) {
                axpy(1.0, t.as_f32()?, &mut grad_sum[p.offset..p.offset + p.size]);
            }
            loss_sum += out.last().unwrap().as_f32()?[0] as f64;
        }
        // params <- params - (lr / B) * grad_sum, on device.
        let mut inputs = param_tensors(&self.cfg, &self.params);
        for p in &self.cfg.params {
            inputs.push(Tensor::f32(
                &p.shape,
                grad_sum[p.offset..p.offset + p.size].to_vec(),
            ));
        }
        inputs.push(Tensor::scalar_f32(lr / b as f32));
        let out = self.pjrt()?.run(&self.cfg.artifact_apply_sgd, &inputs)?;
        self.dispatches += 1;
        for (p, t) in self.cfg.params.iter().zip(&out) {
            self.params.data[p.offset..p.offset + p.size]
                .copy_from_slice(t.as_f32()?);
        }
        self.w_rep = None;
        Ok((loss_sum / b as f64) as f32)
    }

    /// Large-graph training (DESIGN.md §12): stream `steps`
    /// neighbor-sampled mini-batches from one giant graph through the
    /// batched path. Every sampled batch has the same geometry, so the
    /// whole stream replays one compiled train plan; returns the
    /// per-step losses.
    pub fn train_sampled(
        &mut self,
        sampler: &mut crate::gcn::sampler::NeighborSampler<'_>,
        steps: usize,
        batch: usize,
        lr: f32,
    ) -> anyhow::Result<Vec<f32>> {
        anyhow::ensure!(steps > 0 && batch > 0, "empty sampled training run");
        let mut losses = Vec::with_capacity(steps);
        for _ in 0..steps {
            let mb = sampler.next_batch(batch)?;
            losses.push(self.step_batched(&mb, lr)?);
        }
        Ok(losses)
    }

    /// Train over `idx` (shuffled by the caller) for one epoch;
    /// incomplete trailing minibatches are dropped (paper-style).
    pub fn train_epoch(
        &mut self,
        mode: TrainMode,
        data: &Dataset,
        idx: &[usize],
        lr: f32,
        epoch: usize,
    ) -> anyhow::Result<EpochStats> {
        let b = self.cfg.train_batch;
        let d0 = self.dispatches;
        let t0 = std::time::Instant::now();
        let mut losses = Vec::new();
        for chunk in idx.chunks_exact(b) {
            let mb = data.pack_batch(chunk, self.cfg.max_nodes, self.cfg.ell_width)?;
            let loss = match mode {
                TrainMode::Batched => self.step_batched(&mb, lr)?,
                TrainMode::NonBatched => self.step_nonbatched(&mb, lr)?,
            };
            losses.push(loss as f64);
        }
        anyhow::ensure!(!losses.is_empty(), "epoch with no full minibatch");
        Ok(EpochStats {
            epoch,
            mean_loss: losses.iter().sum::<f64>() / losses.len() as f64,
            secs: t0.elapsed().as_secs_f64(),
            dispatches: self.dispatches - d0,
        })
    }

    /// Forward a packed batch: one engine dispatch on the host backend
    /// (against the cached readout tiling, replaying the cached forward
    /// plan of this geometry), or the matching fwd artifact on PJRT.
    pub fn forward(&mut self, mb: &ModelBatch) -> anyhow::Result<Vec<f32>> {
        if let Some(exec) = self.host_exec.clone() {
            self.ensure_w_rep()?;
            self.dispatches += 1;
            let cfg = &self.cfg;
            let th = self.thresholds;
            let key = reference::forward_plan_key(cfg, mb);
            let (plan, ws) = self
                .plans
                .entry_with(key, || reference::plan_forward(cfg, mb, &th))?;
            return reference::forward_planned(
                cfg,
                &self.params,
                mb,
                &exec,
                self.w_rep.as_deref().unwrap(),
                plan,
                ws,
            );
        }
        let name = if mb.batch == self.cfg.infer_batch {
            &self.cfg.artifact_fwd_infer
        } else if mb.batch == self.cfg.train_batch {
            &self.cfg.artifact_fwd_train
        } else if mb.batch == 1 {
            &self.cfg.artifact_fwd_sample
        } else {
            anyhow::bail!("no fwd artifact for batch {}", mb.batch)
        };
        let mut inputs = param_tensors(&self.cfg, &self.params);
        inputs.extend(batch_tensors(mb, false));
        let out = self.pjrt()?.run(name, &inputs)?;
        self.dispatches += 1;
        Ok(out[0].as_f32()?.to_vec())
    }

    /// [`Trainer::forward`] at an explicit inference precision.
    /// `Precision::F32` is the plain forward; `Bf16`/`Int8` run the
    /// host engine's dequantize-on-the-fly path (quantized adjacency +
    /// bf16-rounded weights, DESIGN.md §16). Training always stays f32
    /// — there is no quantized step, only quantized serving.
    pub fn forward_precision(
        &mut self,
        mb: &ModelBatch,
        precision: Precision,
    ) -> anyhow::Result<Vec<f32>> {
        if precision == Precision::F32 {
            return self.forward(mb);
        }
        let exec = self.host_exec.clone().ok_or_else(|| {
            anyhow::anyhow!(
                "reduced-precision inference runs on the host engine only \
                 (the PJRT artifacts are compiled f32)"
            )
        })?;
        self.dispatches += 1;
        reference::forward_quantized(&self.cfg, &self.params, mb, &exec, precision)
    }

    /// Macro-averaged ROC-AUC over `idx` at an inference precision —
    /// the threshold-free accuracy signal the reduced-precision serving
    /// modes are judged by (DESIGN.md §16): quantization perturbs
    /// logits, AUC measures whether the *ranking* survived.
    pub fn evaluate_auc(
        &mut self,
        data: &Dataset,
        idx: &[usize],
        precision: Precision,
    ) -> anyhow::Result<f64> {
        anyhow::ensure!(!idx.is_empty(), "evaluate on empty index set");
        let b = self.cfg.train_batch;
        let mut logits = Vec::with_capacity(idx.len() * self.cfg.n_out);
        let mut labels = Vec::with_capacity(idx.len() * self.cfg.n_out);
        for chunk in idx.chunks(b) {
            let mb = data.pack_batch(chunk, self.cfg.max_nodes, self.cfg.ell_width)?;
            if chunk.len() == b {
                logits.extend(self.forward_precision(&mb, precision)?);
                labels.extend_from_slice(&mb.labels);
            } else {
                for bi in 0..chunk.len() {
                    let one = mb.single(bi);
                    logits.extend(self.forward_precision(&one, precision)?);
                    labels.extend_from_slice(&one.labels);
                }
            }
        }
        reference::mean_auc(&logits, &labels, idx.len(), self.cfg.n_out).ok_or_else(|| {
            anyhow::anyhow!("every task is single-class on this eval set — AUC is undefined")
        })
    }

    /// Loss + accuracy over `idx`: full train-batch-sized fwd dispatches
    /// plus per-sample dispatches for the remainder (sample-weighted).
    pub fn evaluate(&mut self, data: &Dataset, idx: &[usize]) -> anyhow::Result<(f64, f64)> {
        anyhow::ensure!(!idx.is_empty(), "evaluate on empty index set");
        let b = self.cfg.train_batch;
        let mut loss_sum = 0f64;
        let mut acc_sum = 0f64;
        let mut n = 0usize;
        for chunk in idx.chunks(b) {
            let mb = data.pack_batch(chunk, self.cfg.max_nodes, self.cfg.ell_width)?;
            if chunk.len() == b {
                let logits = self.forward(&mb)?;
                loss_sum +=
                    reference::loss(&self.cfg, &logits, &mb.labels, b) as f64 * b as f64;
                acc_sum += reference::accuracy(&self.cfg, &logits, &mb.labels, b) * b as f64;
            } else {
                for bi in 0..chunk.len() {
                    let one = mb.single(bi);
                    let logits = self.forward(&one)?;
                    loss_sum += reference::loss(&self.cfg, &logits, &one.labels, 1) as f64;
                    acc_sum += reference::accuracy(&self.cfg, &logits, &one.labels, 1);
                }
            }
            n += chunk.len();
        }
        Ok((loss_sum / n as f64, acc_sum / n as f64))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::dataset::DatasetKind;

    #[test]
    fn quantized_eval_auc_tracks_f32_within_dtype_bounds() {
        // The ISSUE-pinned accuracy contract of the reduced-precision
        // serving modes: on a tox21 eval set the macro-AUC moves by
        // < 0.01 under bf16 and < 0.02 under int8 relative to f32.
        let mut tr = Trainer::new_host("tox21", 2).unwrap();
        let data = Dataset::generate(DatasetKind::Tox21, 100, 0xA0C);
        let idx: Vec<usize> = (0..100).collect();
        let auc_f32 = tr.evaluate_auc(&data, &idx, Precision::F32).unwrap();
        assert!((0.0..=1.0).contains(&auc_f32), "AUC out of range: {auc_f32}");
        let auc_bf16 = tr.evaluate_auc(&data, &idx, Precision::Bf16).unwrap();
        let auc_int8 = tr.evaluate_auc(&data, &idx, Precision::Int8).unwrap();
        assert!(
            (auc_bf16 - auc_f32).abs() < 0.01,
            "bf16 AUC {auc_bf16} drifted from f32 {auc_f32}"
        );
        assert!(
            (auc_int8 - auc_f32).abs() < 0.02,
            "int8 AUC {auc_int8} drifted from f32 {auc_f32}"
        );

        // Precision::F32 is exactly the plain forward, bit for bit.
        let mb = data
            .pack_batch(&[0, 1], tr.cfg.max_nodes, tr.cfg.ell_width)
            .unwrap();
        assert_eq!(
            tr.forward_precision(&mb, Precision::F32).unwrap(),
            tr.forward(&mb).unwrap()
        );
        // And the quantized forwards differ from f32 (they really did
        // run a different numeric path) while staying finite.
        let q = tr.forward_precision(&mb, Precision::Int8).unwrap();
        assert!(q.iter().all(|v| v.is_finite()));
        assert_ne!(q, tr.forward(&mb).unwrap());
    }
}
