//! The coordinator (S6 in DESIGN.md): the system-level realization of
//! the paper's batching contribution.
//!
//! * [`request`] — inference request/response types.
//! * [`batcher`] — the dynamic batch assembler (fixed-size vs
//!   size-or-age close rules, age env-calibratable via
//!   `BSPMM_BATCH_AGE_US`); pure data structure, property-tested.
//! * [`dispatch`] — the host-engine forward path: model execution over
//!   the batched-SpMM engine (`sparse::engine`), no artifacts needed,
//!   with the tiled readout weight cached per parameter set. The
//!   multi-model form ([`MultiDispatcher`]) serves every registry
//!   entry from one worker pool with per-tenant plan caches.
//! * [`registry`] — the model registry (DESIGN.md §15): named models
//!   with versioned, atomically hot-swappable parameter sets.
//! * [`server`] — the serving runtime: a device thread owning the
//!   execution backend (PJRT artifacts or host engine), assembling
//!   batches and dispatching either one batched execute (Fig. 7) or
//!   per-sample executes (Fig. 6).
//! * [`trainer`] — the training loop in both dispatch modes (Table II)
//!   on either backend; the host engine trains end-to-end through the
//!   `gcn::backward` engine dispatches (DESIGN.md §8).
//! * [`metrics`] — latency/throughput/occupancy accounting.
//!
//! One artifact-less training step on the host engine:
//!
//! ```
//! use bspmm::coordinator::Trainer;
//! use bspmm::graph::dataset::{Dataset, DatasetKind};
//!
//! let mut tr = Trainer::new_host("tox21", 1)?;
//! let data = Dataset::generate(DatasetKind::Tox21, 4, 9);
//! let mb = data.pack_batch(&[0, 1], tr.cfg.max_nodes, tr.cfg.ell_width)?;
//! let before = tr.params.data.clone();
//! let loss = tr.step_batched(&mb, 0.01)?; // fwd + bwd + SGD, all host
//! assert!(loss.is_finite() && loss > 0.0);
//! assert_ne!(tr.params.data, before); // SGD moved the parameters
//! # Ok::<(), anyhow::Error>(())
//! ```

pub mod batcher;
pub mod dispatch;
pub mod metrics;
pub mod registry;
pub mod request;
pub mod server;
pub mod trainer;

pub use batcher::{BatchAssembler, BatchPolicy, CloseRule, KeyedBatchAssembler};
pub use dispatch::{HostDispatcher, MultiDispatcher};
pub use registry::{ModelRegistry, ParamVersion};
pub use request::{InferRequest, InferResponse};
pub use server::{DispatchMode, ServeBackend, Server, ServerConfig};
pub use trainer::{TrainMode, Trainer};
