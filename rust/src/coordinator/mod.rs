//! The coordinator (S6 in DESIGN.md): the system-level realization of
//! the paper's batching contribution.
//!
//! * [`request`] — inference request/response types.
//! * [`batcher`] — the dynamic batch assembler (size + deadline policy);
//!   pure data structure, property-tested.
//! * [`server`] — the serving runtime: a device thread owning the PJRT
//!   `Runtime`, assembling batches and dispatching either one batched
//!   execute (Fig. 7) or per-sample executes (Fig. 6).
//! * [`trainer`] — the training loop in both dispatch modes (Table II).
//! * [`metrics`] — latency/throughput/occupancy accounting.

pub mod batcher;
pub mod metrics;
pub mod request;
pub mod server;
pub mod trainer;

pub use batcher::{BatchAssembler, BatchPolicy};
pub use request::{InferRequest, InferResponse};
pub use server::{DispatchMode, Server, ServerConfig};
pub use trainer::{TrainMode, Trainer};
