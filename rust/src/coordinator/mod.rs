//! The coordinator (S6 in DESIGN.md): the system-level realization of
//! the paper's batching contribution.
//!
//! * [`request`] — inference request/response types.
//! * [`batcher`] — the dynamic batch assembler (size + deadline policy);
//!   pure data structure, property-tested.
//! * [`dispatch`] — the host-engine forward path: model execution over
//!   the batched-SpMM engine (`sparse::engine`), no artifacts needed.
//! * [`server`] — the serving runtime: a device thread owning the
//!   execution backend (PJRT artifacts or host engine), assembling
//!   batches and dispatching either one batched execute (Fig. 7) or
//!   per-sample executes (Fig. 6).
//! * [`trainer`] — the training loop in both dispatch modes (Table II);
//!   forward/evaluate also run on the host engine.
//! * [`metrics`] — latency/throughput/occupancy accounting.

pub mod batcher;
pub mod dispatch;
pub mod metrics;
pub mod request;
pub mod server;
pub mod trainer;

pub use batcher::{BatchAssembler, BatchPolicy};
pub use dispatch::HostDispatcher;
pub use request::{InferRequest, InferResponse};
pub use server::{DispatchMode, ServeBackend, Server, ServerConfig};
pub use trainer::{TrainMode, Trainer};
