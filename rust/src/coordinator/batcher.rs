//! Dynamic batch assembly: the size-or-deadline policy every batched
//! serving system uses (and the lever the paper pulls: batch 200 at
//! inference "to increase the throughput since the batch size does not
//! affect the accuracy", §V-B).
//!
//! `BatchAssembler` is a pure data structure (no threads, no clocks of
//! its own) so its invariants are property-testable:
//!   * no request is lost or duplicated,
//!   * FIFO order within and across batches,
//!   * batches never exceed `max_batch`,
//!   * under [`CloseRule::SizeOrAge`], a non-empty queue is flushed no
//!     later than `max_wait` after its oldest entry arrived; under
//!     [`CloseRule::FixedSize`] only a full batch (or the shutdown
//!     drain) closes.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

/// When a partially-filled batch is allowed to leave the assembler.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CloseRule {
    /// Close only when `max_batch` requests are queued. Maximum
    /// occupancy, unbounded tail latency under trickle arrivals — the
    /// throughput-first baseline the serving bench contrasts against.
    FixedSize,
    /// Close on size *or* oldest-request age (`max_wait`), whichever
    /// fires first — the deadline-aware adaptive policy. The age knob
    /// is env-calibratable on the serving path via `BSPMM_BATCH_AGE_US`
    /// ([`age_from_env`]).
    SizeOrAge,
}

#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Flush as soon as this many requests are queued (the artifact's
    /// batch capacity).
    pub max_batch: usize,
    /// Flush a non-empty queue once its oldest request has waited this
    /// long (ignored under [`CloseRule::FixedSize`]).
    pub max_wait: Duration,
    /// Which triggers may close a batch.
    pub close: CloseRule,
}

impl BatchPolicy {
    /// The default size-or-age policy (every prior call site keeps its
    /// size-or-deadline semantics).
    pub fn new(max_batch: usize, max_wait: Duration) -> Self {
        assert!(max_batch >= 1);
        Self {
            max_batch,
            max_wait,
            close: CloseRule::SizeOrAge,
        }
    }

    /// Fixed-size policy: only a full batch (or shutdown drain) closes.
    pub fn fixed_size(max_batch: usize) -> Self {
        assert!(max_batch >= 1);
        Self {
            max_batch,
            max_wait: Duration::MAX,
            close: CloseRule::FixedSize,
        }
    }
}

/// Resolve the batch age cap: `BSPMM_BATCH_AGE_US` (integer
/// microseconds) when set and parseable, else `fallback`.
pub fn age_from_env(fallback: Duration) -> Duration {
    parse_age_us(std::env::var("BSPMM_BATCH_AGE_US").ok().as_deref(), fallback)
}

fn parse_age_us(var: Option<&str>, fallback: Duration) -> Duration {
    var.and_then(|v| v.trim().parse::<u64>().ok())
        .map(Duration::from_micros)
        .unwrap_or(fallback)
}

/// Queue entry: the item plus its arrival time.
struct Entry<T> {
    item: T,
    arrived: Instant,
}

pub struct BatchAssembler<T> {
    policy: BatchPolicy,
    queue: VecDeque<Entry<T>>,
    /// Counters for occupancy reporting.
    pub batches_emitted: u64,
    pub items_emitted: u64,
    pub full_batches: u64,
}

impl<T> BatchAssembler<T> {
    pub fn new(policy: BatchPolicy) -> Self {
        Self {
            policy,
            queue: VecDeque::new(),
            batches_emitted: 0,
            items_emitted: 0,
            full_batches: 0,
        }
    }

    pub fn push(&mut self, item: T, now: Instant) {
        self.queue.push_back(Entry { item, arrived: now });
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Age of the oldest queued request (zero when empty).
    pub fn oldest_age(&self, now: Instant) -> Duration {
        self.queue
            .front()
            .map(|e| now.saturating_duration_since(e.arrived))
            .unwrap_or(Duration::ZERO)
    }

    /// Time until the deadline flush would fire (None if queue empty,
    /// or if the close rule is [`CloseRule::FixedSize`] — age never
    /// closes a fixed-size batch). The server uses this as its
    /// `recv_timeout`.
    pub fn time_to_deadline(&self, now: Instant) -> Option<Duration> {
        if self.policy.close == CloseRule::FixedSize {
            return None;
        }
        self.queue.front().map(|e| {
            let elapsed = now.saturating_duration_since(e.arrived);
            self.policy.max_wait.saturating_sub(elapsed)
        })
    }

    /// Emit a batch if the policy says so.
    pub fn poll(&mut self, now: Instant) -> Option<Vec<T>> {
        if self.queue.is_empty() {
            return None;
        }
        let full = self.queue.len() >= self.policy.max_batch;
        let expired = self.policy.close == CloseRule::SizeOrAge
            && self
                .queue
                .front()
                .map(|e| now.saturating_duration_since(e.arrived) >= self.policy.max_wait)
                .unwrap_or(false);
        if !(full || expired) {
            return None;
        }
        let n = self.queue.len().min(self.policy.max_batch);
        let batch: Vec<T> = self.queue.drain(..n).map(|e| e.item).collect();
        self.batches_emitted += 1;
        self.items_emitted += batch.len() as u64;
        if batch.len() == self.policy.max_batch {
            self.full_batches += 1;
        }
        Some(batch)
    }

    /// Flush everything (shutdown path).
    pub fn drain_all(&mut self) -> Vec<T> {
        let batch: Vec<T> = self.queue.drain(..).map(|e| e.item).collect();
        if !batch.is_empty() {
            self.batches_emitted += 1;
            self.items_emitted += batch.len() as u64;
        }
        batch
    }

    /// Mean emitted batch occupancy (fraction of max_batch).
    pub fn mean_occupancy(&self) -> f64 {
        if self.batches_emitted == 0 {
            0.0
        } else {
            self.items_emitted as f64
                / (self.batches_emitted as f64 * self.policy.max_batch as f64)
        }
    }
}

/// Per-key batch assembly for multi-model serving (DESIGN.md §15): one
/// [`BatchAssembler`] lane per key (model name), all under one policy,
/// so batches never mix models — each device batch replays exactly one
/// model's compiled plan. Like `BatchAssembler`, a pure data structure:
/// the same no-loss / FIFO-per-lane / bounded-size invariants hold
/// lane-wise.
pub struct KeyedBatchAssembler<T> {
    policy: BatchPolicy,
    lanes: Vec<(String, BatchAssembler<T>)>,
    /// Round-robin start cursor so a perpetually-ready first lane
    /// cannot starve later lanes.
    next_lane: usize,
}

impl<T> KeyedBatchAssembler<T> {
    pub fn new(policy: BatchPolicy) -> Self {
        Self {
            policy,
            lanes: Vec::new(),
            next_lane: 0,
        }
    }

    fn lane_mut(&mut self, key: &str) -> &mut BatchAssembler<T> {
        if let Some(pos) = self.lanes.iter().position(|(k, _)| k == key) {
            return &mut self.lanes[pos].1;
        }
        self.lanes
            .push((key.to_string(), BatchAssembler::new(self.policy)));
        &mut self.lanes.last_mut().unwrap().1
    }

    pub fn push(&mut self, key: &str, item: T, now: Instant) {
        self.lane_mut(key).push(item, now);
    }

    /// Total queued items across every lane.
    pub fn len(&self) -> usize {
        self.lanes.iter().map(|(_, a)| a.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.lanes.iter().all(|(_, a)| a.is_empty())
    }

    /// Minimum time-to-deadline across lanes — the server's
    /// `recv_timeout` (None when every lane is empty or the close rule
    /// never fires on age).
    pub fn time_to_deadline(&self, now: Instant) -> Option<Duration> {
        self.lanes
            .iter()
            .filter_map(|(_, a)| a.time_to_deadline(now))
            .min()
    }

    /// Emit one ready batch, round-robin across lanes: `(key, batch)`
    /// from the first lane (starting at the rotating cursor) whose
    /// policy fires. Call repeatedly until `None` to drain all ready
    /// batches.
    pub fn poll(&mut self, now: Instant) -> Option<(String, Vec<T>)> {
        let n = self.lanes.len();
        for i in 0..n {
            let pos = (self.next_lane + i) % n;
            if let Some(batch) = self.lanes[pos].1.poll(now) {
                self.next_lane = (pos + 1) % n;
                return Some((self.lanes[pos].0.clone(), batch));
            }
        }
        None
    }

    /// Flush every lane (shutdown path), in lane-creation order.
    pub fn drain_all(&mut self) -> Vec<(String, Vec<T>)> {
        self.lanes
            .iter_mut()
            .filter_map(|(k, a)| {
                let batch = a.drain_all();
                (!batch.is_empty()).then(|| (k.clone(), batch))
            })
            .collect()
    }

    /// Lanes in creation order (occupancy reporting).
    pub fn lanes(&self) -> impl Iterator<Item = (&str, &BatchAssembler<T>)> {
        self.lanes.iter().map(|(k, a)| (k.as_str(), a))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::prop_assert;

    fn t0() -> Instant {
        Instant::now()
    }

    #[test]
    fn flushes_on_size() {
        let mut b = BatchAssembler::new(BatchPolicy::new(3, Duration::from_secs(60)));
        let now = t0();
        b.push(1, now);
        b.push(2, now);
        assert!(b.poll(now).is_none());
        b.push(3, now);
        assert_eq!(b.poll(now), Some(vec![1, 2, 3]));
        assert!(b.is_empty());
    }

    #[test]
    fn flushes_on_deadline() {
        let mut b = BatchAssembler::new(BatchPolicy::new(100, Duration::from_millis(5)));
        let now = t0();
        b.push(7, now);
        assert!(b.poll(now).is_none());
        let later = now + Duration::from_millis(6);
        assert_eq!(b.poll(later), Some(vec![7]));
    }

    #[test]
    fn deadline_tracks_oldest() {
        let mut b = BatchAssembler::new(BatchPolicy::new(100, Duration::from_millis(10)));
        let now = t0();
        b.push(1, now);
        b.push(2, now + Duration::from_millis(9));
        // oldest is 10ms old -> flush both
        let batch = b.poll(now + Duration::from_millis(10)).unwrap();
        assert_eq!(batch, vec![1, 2]);
    }

    #[test]
    fn oversize_queue_emits_capped_batches() {
        let mut b = BatchAssembler::new(BatchPolicy::new(4, Duration::from_millis(0)));
        let now = t0();
        for i in 0..10 {
            b.push(i, now);
        }
        assert_eq!(b.poll(now).unwrap().len(), 4);
        assert_eq!(b.poll(now).unwrap().len(), 4);
        assert_eq!(b.poll(now).unwrap().len(), 2);
        assert!(b.poll(now).is_none());
    }

    #[test]
    fn prop_no_loss_no_dup_fifo() {
        prop::run(200, |rng| {
            let max_batch = rng.range(1, 16);
            let wait_ms = rng.range(0, 20) as u64;
            let mut b = BatchAssembler::new(BatchPolicy::new(
                max_batch,
                Duration::from_millis(wait_ms),
            ));
            let start = t0();
            let n = rng.range(0, 100);
            let mut out = Vec::new();
            let mut clock = start;
            let mut next_id = 0u64;
            while next_id < n as u64 || !b.is_empty() {
                // random interleaving of arrivals, time passage and polls
                match rng.below(3) {
                    0 if next_id < n as u64 => {
                        b.push(next_id, clock);
                        next_id += 1;
                    }
                    1 => clock += Duration::from_millis(rng.range(0, 30) as u64),
                    _ => {
                        if let Some(batch) = b.poll(clock) {
                            prop_assert!(
                                batch.len() <= max_batch,
                                "batch {} > max {max_batch}",
                                batch.len()
                            );
                            out.extend(batch);
                        }
                    }
                }
                // liveness: if stuck with everything pushed, advance time
                if next_id >= n as u64 {
                    clock += Duration::from_millis(wait_ms + 1);
                    if let Some(batch) = b.poll(clock) {
                        out.extend(batch);
                    }
                }
            }
            prop_assert!(out.len() == n, "lost items: {} != {n}", out.len());
            for (i, &v) in out.iter().enumerate() {
                prop_assert!(v == i as u64, "order violated at {i}: {v}");
            }
            Ok(())
        });
    }

    #[test]
    fn prop_deadline_bound() {
        // A poll at (arrival of oldest + max_wait) always emits.
        prop::run(100, |rng| {
            let max_batch = rng.range(2, 32);
            let wait = Duration::from_millis(rng.range(1, 50) as u64);
            let mut b = BatchAssembler::new(BatchPolicy::new(max_batch, wait));
            let now = t0();
            let k = rng.range(1, max_batch - 1); // strictly below size trigger
            for i in 0..k {
                b.push(i, now);
            }
            prop_assert!(b.poll(now + wait).is_some(), "deadline flush missed");
            Ok(())
        });
    }

    #[test]
    fn age_close_fires_before_size_close_under_slow_arrivals() {
        // Two requests trickle into a batch-100 assembler; the age cap
        // closes the pair long before the size trigger could.
        let mut b = BatchAssembler::new(BatchPolicy::new(100, Duration::from_millis(2)));
        let now = t0();
        b.push(1, now);
        b.push(2, now + Duration::from_millis(1));
        assert!(b.poll(now + Duration::from_millis(1)).is_none());
        assert_eq!(b.poll(now + Duration::from_millis(2)), Some(vec![1, 2]));
    }

    #[test]
    fn fixed_size_never_closes_on_age() {
        let mut b = BatchAssembler::new(BatchPolicy::fixed_size(3));
        let now = t0();
        b.push(1, now);
        b.push(2, now);
        // Arbitrarily far in the future: still no partial batch.
        let later = now + Duration::from_secs(3600);
        assert!(b.poll(later).is_none());
        assert!(b.time_to_deadline(later).is_none());
        // The size trigger still fires, and shutdown still drains.
        b.push(3, later);
        assert_eq!(b.poll(later), Some(vec![1, 2, 3]));
        b.push(4, later);
        assert_eq!(b.drain_all(), vec![4]);
    }

    #[test]
    fn age_knob_parsing() {
        let fb = Duration::from_micros(500);
        assert_eq!(parse_age_us(None, fb), fb);
        assert_eq!(parse_age_us(Some("250"), fb), Duration::from_micros(250));
        assert_eq!(parse_age_us(Some(" 250 "), fb), Duration::from_micros(250));
        assert_eq!(parse_age_us(Some("junk"), fb), fb);
        assert_eq!(parse_age_us(Some(""), fb), fb);
    }

    #[test]
    fn occupancy_accounting() {
        let mut b = BatchAssembler::new(BatchPolicy::new(4, Duration::from_millis(0)));
        let now = t0();
        for i in 0..6 {
            b.push(i, now);
        }
        b.poll(now);
        b.poll(now);
        assert_eq!(b.batches_emitted, 2);
        assert_eq!(b.items_emitted, 6);
        assert_eq!(b.full_batches, 1);
        assert!((b.mean_occupancy() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn keyed_lanes_never_mix_keys_and_stay_fifo_per_lane() {
        let mut b = KeyedBatchAssembler::new(BatchPolicy::new(2, Duration::from_secs(60)));
        let now = t0();
        // Interleaved arrivals across two models.
        b.push("a", 1, now);
        b.push("b", 10, now);
        b.push("b", 11, now);
        b.push("a", 2, now);
        b.push("a", 3, now);
        assert_eq!(b.len(), 5);
        // Both lanes have a full batch; round-robin serves each once.
        let (k1, batch1) = b.poll(now).unwrap();
        let (k2, batch2) = b.poll(now).unwrap();
        assert_ne!(k1, k2, "round-robin must rotate lanes");
        for (k, batch) in [(k1, batch1), (k2, batch2)] {
            match k.as_str() {
                "a" => assert_eq!(batch, vec![1, 2]),
                "b" => assert_eq!(batch, vec![10, 11]),
                other => panic!("unknown lane {other}"),
            }
        }
        // "a" still holds one item below the size trigger.
        assert!(b.poll(now).is_none());
        assert_eq!(b.len(), 1);
        assert_eq!(b.drain_all(), vec![("a".to_string(), vec![3])]);
        assert!(b.is_empty());
    }

    #[test]
    fn keyed_deadline_is_the_min_across_lanes() {
        let mut b = KeyedBatchAssembler::new(BatchPolicy::new(100, Duration::from_millis(10)));
        let now = t0();
        assert!(b.time_to_deadline(now).is_none());
        b.push("a", 1, now);
        b.push("b", 2, now + Duration::from_millis(4));
        // Oldest overall is a's entry: 10ms cap, 6ms elapsed -> 4ms.
        let at = now + Duration::from_millis(6);
        assert_eq!(b.time_to_deadline(at), Some(Duration::from_millis(4)));
        // a's lane flushes alone at its deadline; b's stays queued.
        let (k, batch) = b.poll(now + Duration::from_millis(10)).unwrap();
        assert_eq!((k.as_str(), batch), ("a", vec![1]));
        assert_eq!(b.len(), 1);
        assert_eq!(
            b.time_to_deadline(now + Duration::from_millis(10)),
            Some(Duration::from_millis(4))
        );
    }
}
