//! Model registry: the multi-model half of the serving stack
//! (DESIGN.md §15).
//!
//! One server process hosts many models. Each registered model is keyed
//! by name and carries its architecture + geometry ([`ModelConfig`])
//! plus a *versioned* chain of parameter sets ([`ParamVersion`]). The
//! registry is the single authority on "which parameters does a batch
//! for model M run on right now":
//!
//! * **Zero-downtime hot swap.** [`ModelRegistry::swap_params`]
//!   replaces the current version atomically under traffic. Readers
//!   ([`ModelRegistry::current`]) clone an `Arc<ParamVersion>` under a
//!   short read lock and hold it for the whole batch — an in-flight
//!   batch finishes on the version it started with, the next batch
//!   picks up the new one, and no reader ever observes a torn
//!   parameter vector (the swap replaces the whole `Arc`, never writes
//!   through it). Linearization point: the `RwLock` write section in
//!   `swap_params`.
//! * **Version history.** Every version ever installed stays reachable
//!   ([`ModelRegistry::version`]), so a response stamped with the
//!   version it was served under can be replayed bit-identically — the
//!   concurrent hot-swap test in `tests/serving_registry.rs` pins
//!   exactly this.
//! * **Per-model swap counts** feed the registry-wide
//!   `param_swaps` metric ([`ModelRegistry::total_swaps`]).
//!
//! The registry deliberately holds *parameters only*. Compiled plans
//! live in the per-tenant [`TenantPlanCaches`]
//! (`sparse::engine::TenantPlanCaches`) — plans depend on geometry, not
//! on parameter versions, so a hot swap never invalidates a plan (the
//! PR 5 invalidation rule; only the derived `w_rep` readout tile is
//! version-bound and is refreshed by the dispatcher on version change).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};

use crate::gcn::{ModelConfig, ParamSet};

/// One immutable parameter snapshot. Batches hold an
/// `Arc<ParamVersion>` for their whole forward, so the data can never
/// change (or be freed) under them.
#[derive(Debug)]
pub struct ParamVersion {
    /// 1-based, monotonically increasing per model. `0` is reserved as
    /// the "no registry / not applicable" stamp in responses.
    pub version: u64,
    pub params: ParamSet,
}

struct ModelSlot {
    cfg: ModelConfig,
    current: RwLock<Arc<ParamVersion>>,
    /// Every version ever installed, in install order (index = version
    /// - 1). Kept for replay verification; molecule-model ParamSets are
    /// small (tens of KiB) so retention is cheap.
    history: Mutex<Vec<Arc<ParamVersion>>>,
    swaps: AtomicU64,
    next_version: AtomicU64,
}

/// Registry of named models, each with hot-swappable versioned
/// parameters. Registration (`&mut self`) happens at boot; swap/read
/// (`&self`) run concurrently under traffic.
pub struct ModelRegistry {
    slots: BTreeMap<String, ModelSlot>,
}

impl Default for ModelRegistry {
    fn default() -> Self {
        ModelRegistry::new()
    }
}

impl std::fmt::Debug for ModelRegistry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ModelRegistry")
            .field("models", &self.slots.keys().collect::<Vec<_>>())
            .finish()
    }
}

impl ModelRegistry {
    pub fn new() -> ModelRegistry {
        ModelRegistry {
            slots: BTreeMap::new(),
        }
    }

    /// Register a model with its initial parameters as version 1.
    /// Rejects duplicate names and parameter vectors that do not match
    /// the config's `n_params` (a torn or truncated init blob must not
    /// reach serving).
    pub fn register(&mut self, cfg: ModelConfig, params: ParamSet) -> anyhow::Result<u64> {
        anyhow::ensure!(
            !self.slots.contains_key(&cfg.name),
            "model '{}' is already registered",
            cfg.name
        );
        anyhow::ensure!(
            params.data.len() == cfg.n_params,
            "model '{}': parameter vector has {} values, config declares {}",
            cfg.name,
            params.data.len(),
            cfg.n_params
        );
        let v = Arc::new(ParamVersion { version: 1, params });
        self.slots.insert(
            cfg.name.clone(),
            ModelSlot {
                cfg,
                current: RwLock::new(Arc::clone(&v)),
                history: Mutex::new(vec![v]),
                swaps: AtomicU64::new(0),
                next_version: AtomicU64::new(2),
            },
        );
        Ok(1)
    }

    /// Register a named synthetic model ([`ModelConfig::synthetic`])
    /// with deterministically initialized parameters.
    pub fn register_synthetic(&mut self, model: &str, seed: u64) -> anyhow::Result<u64> {
        let cfg = ModelConfig::synthetic(model)?;
        let params = ParamSet::random_init(&cfg, seed);
        self.register(cfg, params)
    }

    fn slot(&self, model: &str) -> anyhow::Result<&ModelSlot> {
        self.slots
            .get(model)
            .ok_or_else(|| anyhow::anyhow!("model '{model}' is not registered"))
    }

    /// Atomically install `params` as the new current version for
    /// `model` and return the new version number. In-flight readers
    /// keep their `Arc` to the old version; the next
    /// [`ModelRegistry::current`] call observes the new one.
    pub fn swap_params(&self, model: &str, params: ParamSet) -> anyhow::Result<u64> {
        let slot = self.slot(model)?;
        anyhow::ensure!(
            params.data.len() == slot.cfg.n_params,
            "model '{model}': parameter vector has {} values, config declares {}",
            params.data.len(),
            slot.cfg.n_params
        );
        let version = slot.next_version.fetch_add(1, Ordering::Relaxed);
        let v = Arc::new(ParamVersion { version, params });
        // History before publication: any reader that observes the new
        // version can already resolve it by number.
        slot.history.lock().unwrap().push(Arc::clone(&v));
        *slot.current.write().unwrap() = v;
        slot.swaps.fetch_add(1, Ordering::Relaxed);
        Ok(version)
    }

    /// The current parameter version for `model`. Cheap (one read lock
    /// + one `Arc` clone); callers hold the result for the whole batch.
    pub fn current(&self, model: &str) -> anyhow::Result<Arc<ParamVersion>> {
        Ok(Arc::clone(&self.slot(model)?.current.read().unwrap()))
    }

    /// A specific historical version of `model`, if it was ever
    /// installed (replay verification).
    pub fn version(&self, model: &str, version: u64) -> Option<Arc<ParamVersion>> {
        let slot = self.slots.get(model)?;
        let hist = slot.history.lock().unwrap();
        hist.iter().find(|v| v.version == version).cloned()
    }

    /// Version numbers installed for `model`, in install order.
    pub fn versions(&self, model: &str) -> Vec<u64> {
        self.slots.get(model).map_or_else(Vec::new, |s| {
            s.history.lock().unwrap().iter().map(|v| v.version).collect()
        })
    }

    pub fn cfg(&self, model: &str) -> anyhow::Result<&ModelConfig> {
        Ok(&self.slot(model)?.cfg)
    }

    pub fn contains(&self, model: &str) -> bool {
        self.slots.contains_key(model)
    }

    /// Registered model names in sorted (BTreeMap) order.
    pub fn models(&self) -> Vec<&str> {
        self.slots.keys().map(|k| k.as_str()).collect()
    }

    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    /// Completed hot swaps for one model.
    pub fn swap_count(&self, model: &str) -> u64 {
        self.slots
            .get(model)
            .map_or(0, |s| s.swaps.load(Ordering::Relaxed))
    }

    /// Registry-wide hot-swap count (the `param_swaps` metric).
    pub fn total_swaps(&self) -> u64 {
        self.slots
            .values()
            .map(|s| s.swaps.load(Ordering::Relaxed))
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn registry_with(model: &str) -> ModelRegistry {
        let mut reg = ModelRegistry::new();
        reg.register_synthetic(model, 0x5EED).unwrap();
        reg
    }

    #[test]
    fn register_swap_and_history_are_versioned() {
        let mut reg = registry_with("tox21");
        assert_eq!(reg.models(), vec!["tox21"]);
        assert_eq!(reg.current("tox21").unwrap().version, 1);
        assert_eq!(reg.swap_count("tox21"), 0);

        let cfg = reg.cfg("tox21").unwrap().clone();
        let v2 = reg
            .swap_params("tox21", ParamSet::random_init(&cfg, 0xBEEF))
            .unwrap();
        assert_eq!(v2, 2);
        assert_eq!(reg.current("tox21").unwrap().version, 2);
        assert_eq!(reg.versions("tox21"), vec![1, 2]);
        assert_eq!(reg.swap_count("tox21"), 1);
        assert_eq!(reg.total_swaps(), 1);
        // Both versions stay reachable and distinct.
        let p1 = reg.version("tox21", 1).unwrap();
        let p2 = reg.version("tox21", 2).unwrap();
        assert_ne!(p1.params.data, p2.params.data);

        // Second model registers independently.
        reg.register_synthetic("reaction100", 0x5EED).unwrap();
        assert_eq!(reg.models(), vec!["reaction100", "tox21"]);
        assert_eq!(reg.total_swaps(), 1);
    }

    #[test]
    fn bad_registrations_and_swaps_are_rejected() {
        let mut reg = registry_with("tox21");
        // Duplicate name.
        assert!(reg.register_synthetic("tox21", 1).is_err());
        // Unknown model.
        assert!(reg.current("nope").is_err());
        assert!(reg
            .swap_params("nope", ParamSet { data: vec![] })
            .is_err());
        // Wrong parameter count.
        assert!(reg
            .swap_params("tox21", ParamSet { data: vec![0.0; 3] })
            .is_err());
        // Registry state is untouched by the failures.
        assert_eq!(reg.current("tox21").unwrap().version, 1);
        assert_eq!(reg.swap_count("tox21"), 0);
    }

    #[test]
    fn readers_never_observe_a_torn_version() {
        // Writer hammers swaps where every parameter value equals the
        // version number; readers assert each snapshot is internally
        // uniform — a torn read would mix values.
        let mut reg = ModelRegistry::new();
        let cfg = ModelConfig::synthetic("tox21").unwrap();
        let n = cfg.n_params;
        reg.register(cfg, ParamSet { data: vec![1.0; n] }).unwrap();
        let reg = Arc::new(reg);

        let writer = {
            let reg = Arc::clone(&reg);
            std::thread::spawn(move || {
                for _ in 0..200 {
                    let v = reg.current("tox21").unwrap().version + 1;
                    reg.swap_params("tox21", ParamSet { data: vec![v as f32; n] })
                        .unwrap();
                }
            })
        };
        let mut last = 0u64;
        for _ in 0..500 {
            let cur = reg.current("tox21").unwrap();
            assert!(
                cur.params.data.iter().all(|&x| x == cur.version as f32),
                "torn read at version {}",
                cur.version
            );
            assert!(cur.version >= last, "versions went backwards");
            last = cur.version;
        }
        writer.join().unwrap();
        assert_eq!(reg.current("tox21").unwrap().version, 201);
        assert_eq!(reg.swap_count("tox21"), 200);
    }
}
