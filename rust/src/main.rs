//! `chemgcn` — leader entrypoint for the batched-spmm-gcn reproduction.
//!
//! Subcommands:
//!   info       — manifest / artifact / model summary
//!   gen-data   — generate + describe the synthetic datasets (Table I)
//!   train      — train a model (batched or non-batched dispatch)
//!   serve      — run the serving coordinator over a synthetic workload
//!                (--models registers several and round-robins across them)
//!   plans      — list/verify/dump/gc AOT step-plan artifacts (no trainer)
//!   timeline   — print the Fig. 11 simulated layer timeline
//!   sim        — print the simulated-P100 five-series sweep for a figure

use std::path::{Path, PathBuf};
use std::time::Duration;

use bspmm::coordinator::server::{DispatchMode, ServeBackend, Server, ServerConfig};
use bspmm::coordinator::trainer::{TrainMode, Trainer};
use bspmm::coordinator::{CloseRule, ModelRegistry};
use bspmm::graph::dataset::{Dataset, DatasetKind};
use bspmm::runtime::{plan_artifact, Runtime};
use bspmm::simulator::cost::CostModel;
use bspmm::simulator::timeline::{render_timeline, simulate_layer};
use bspmm::util::cli::{Args, Cli};
use bspmm::util::json::parse as json_parse;
use bspmm::util::rng::Rng;

const USAGE: &str = "chemgcn <info|gen-data|train|serve|plans|timeline|sim> [options]
  run `chemgcn <cmd> --help` for per-command options";

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first().cloned() else {
        eprintln!("{USAGE}");
        std::process::exit(2);
    };
    let rest = &argv[1..];
    let result = match cmd.as_str() {
        "info" => cmd_info(rest),
        "gen-data" => cmd_gen_data(rest),
        "train" => cmd_train(rest),
        "serve" => cmd_serve(rest),
        "plans" => cmd_plans(rest),
        "timeline" => cmd_timeline(rest),
        "sim" => cmd_sim(rest),
        "--help" | "-h" => {
            println!("{USAGE}");
            Ok(())
        }
        other => {
            eprintln!("unknown command '{other}'\n{USAGE}");
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn parse(cli: &Cli, rest: &[String]) -> anyhow::Result<Args> {
    cli.parse(rest).map_err(|msg| anyhow::anyhow!("{msg}"))
}

fn cmd_info(rest: &[String]) -> anyhow::Result<()> {
    let cli = Cli::new("chemgcn info", "manifest summary")
        .opt("artifacts", "artifacts", "artifacts directory");
    let args = parse(&cli, rest)?;
    let rt = Runtime::new(Path::new(args.str("artifacts")))?;
    println!(
        "platform: {} ({} devices)",
        rt.client.platform_name(),
        rt.client.device_count()
    );
    println!("artifacts: {}", rt.manifest.artifacts.len());
    for (name, cfg) in &rt.manifest.models {
        println!(
            "model {name}: {} params, layers {:?}, channels {}, nnz_cap {}, \
             train batch {}, infer batch {}",
            cfg.n_params, cfg.hidden, cfg.channels, cfg.nnz_cap,
            cfg.train_batch, cfg.infer_batch
        );
    }
    for key in ["fig8a", "fig8b", "fig9a", "fig9b", "fig9c", "fig9d", "fig9e", "fig9f", "fig10"] {
        if let Ok(sw) = rt.manifest.sweep(key) {
            println!(
                "sweep {key}: dim {}, nnz/row {}, batch {}, n_B {:?}{}",
                sw.dim, sw.z, sw.batch, sw.nbs,
                if sw.mixed { " (mixed)" } else { "" }
            );
        }
    }
    Ok(())
}

fn cmd_gen_data(rest: &[String]) -> anyhow::Result<()> {
    let cli = Cli::new("chemgcn gen-data", "describe the synthetic Table I datasets")
        .opt("samples", "2000", "samples to generate per dataset")
        .opt("seed", "0", "generator seed");
    let args = parse(&cli, rest)?;
    let n = args.usize("samples");
    println!("Table I (synthetic stand-ins; see DESIGN.md §7 Substitutions)\n");
    println!(
        "{:<13} {:>9} {:>8} {:>9} {:>10} {:>9}",
        "dataset", "#matrices", "max dim", "mean dim", "mean bonds", "nnz/row"
    );
    for kind in [DatasetKind::Tox21, DatasetKind::Reaction100] {
        let d = Dataset::generate(kind, n, args.u64("seed"));
        let mean_dim: f64 =
            d.samples.iter().map(|s| s.mol.n_atoms as f64).sum::<f64>() / n as f64;
        let mean_bonds: f64 =
            d.samples.iter().map(|s| s.mol.bonds.len() as f64).sum::<f64>() / n as f64;
        let max_dim = d.samples.iter().map(|s| s.mol.n_atoms).max().unwrap_or(0);
        // nnz/row of channel-summed adjacency: (m self loops + 2 bonds)/m
        let nnz_per_row = (mean_dim + 2.0 * mean_bonds) / mean_dim;
        println!(
            "{:<13} {:>9} {:>8} {:>9.1} {:>10.1} {:>9.2}",
            format!("{:?}", kind),
            kind.paper_size(),
            max_dim,
            mean_dim,
            mean_bonds,
            nnz_per_row
        );
    }
    println!("\n(paper: Tox21 7,862 / Reaction100 75,477 matrices, max dim 50)");
    Ok(())
}

fn cmd_train(rest: &[String]) -> anyhow::Result<()> {
    let cli = Cli::new("chemgcn train", "train a model")
        .opt("artifacts", "artifacts", "artifacts directory")
        .opt("model", "tox21", "tox21 | reaction100")
        .opt("samples", "500", "dataset size")
        .opt("epochs", "5", "epochs")
        .opt("lr", "0.02", "learning rate")
        .opt("mode", "batched", "batched | nonbatched")
        .opt("seed", "0", "dataset seed");
    let args = parse(&cli, rest)?;
    let mode = match args.str("mode") {
        "batched" => TrainMode::Batched,
        "nonbatched" => TrainMode::NonBatched,
        other => anyhow::bail!("unknown mode {other}"),
    };
    let mut tr = Trainer::new(Path::new(args.str("artifacts")), args.str("model"))?;
    let kind = match args.str("model") {
        "tox21" => DatasetKind::Tox21,
        _ => DatasetKind::Reaction100,
    };
    let data = Dataset::generate(kind, args.usize("samples"), args.u64("seed"));
    let mut idx: Vec<usize> = (0..data.len()).collect();
    let mut rng = Rng::new(1);
    for epoch in 0..args.usize("epochs") {
        rng.shuffle(&mut idx);
        let st = tr.train_epoch(mode, &data, &idx, args.f64("lr") as f32, epoch)?;
        println!(
            "epoch {epoch}: loss {:.4} ({:.2}s, {} dispatches)",
            st.mean_loss, st.secs, st.dispatches
        );
    }
    Ok(())
}

fn cmd_serve(rest: &[String]) -> anyhow::Result<()> {
    let cli = Cli::new("chemgcn serve", "serve synthetic molecules")
        .opt("artifacts", "artifacts", "artifacts directory")
        .opt("model", "tox21", "model")
        .opt("requests", "400", "request count")
        .opt("batch", "200", "batch capacity")
        .opt("wait-ms", "5", "batch age cap (size-or-age close rule)")
        .opt("policy", "size-or-age", "batch close rule: size-or-age | fixed-size")
        .opt(
            "queue-bound",
            "0",
            "bounded admission queue: max in-flight requests (0 = unbounded)",
        )
        .opt(
            "deadline-ms",
            "0",
            "per-request deadline; stale requests are shed, never executed (0 = off)",
        )
        .opt("mode", "batched", "batched | per-sample")
        .opt("backend", "pjrt", "pjrt | host (in-process batched-SpMM engine)")
        .opt("threads", "0", "host-engine threads (0 = one per core)")
        .opt(
            "models",
            "",
            "comma-separated model list for multi-model serving (host backend only): \
             registers every model, round-robins requests across them, and reports \
             the per-model breakdown (DESIGN.md §15)",
        )
        .opt(
            "plans-dir",
            "",
            "multi-model plan-artifact root with per-model subdirectories to \
             warm-start each tenant's plan cache from",
        );
    let args = parse(&cli, rest)?;
    let mode = match args.str("mode") {
        "batched" => DispatchMode::Batched,
        "per-sample" => DispatchMode::PerSample,
        other => anyhow::bail!("unknown mode {other}"),
    };
    let backend = match args.str("backend") {
        "pjrt" => ServeBackend::Pjrt,
        "host" => ServeBackend::HostEngine {
            threads: args.usize("threads"),
        },
        other => anyhow::bail!("unknown backend {other}"),
    };
    let close = match args.str("policy") {
        "size-or-age" => CloseRule::SizeOrAge,
        "fixed-size" => CloseRule::FixedSize,
        other => anyhow::bail!("unknown policy {other}"),
    };
    let deadline = match args.u64("deadline-ms") {
        0 => None,
        ms => Some(Duration::from_millis(ms)),
    };
    // --models turns the server multi-model (DESIGN.md §15): one
    // registry holding every named model, requests round-robined across
    // them, and the summary broken out per model.
    let models: Vec<String> = match args.str("models") {
        "" => vec![args.str("model").to_string()],
        list => list.split(',').map(|m| m.trim().to_string()).collect(),
    };
    let registry = if args.str("models").is_empty() {
        None
    } else {
        anyhow::ensure!(
            matches!(backend, ServeBackend::HostEngine { .. }),
            "--models needs the host-engine backend (--backend host)"
        );
        let mut reg = ModelRegistry::new();
        for m in &models {
            reg.register_synthetic(m, 0x5EED)?;
        }
        Some(std::sync::Arc::new(reg))
    };
    let plans_dir = match args.str("plans-dir") {
        "" => None,
        d => Some(PathBuf::from(d)),
    };
    let srv = Server::start(ServerConfig {
        artifacts_dir: PathBuf::from(args.str("artifacts")),
        model: models[0].clone(),
        mode,
        backend,
        max_batch: args.usize("batch"),
        max_wait: Duration::from_millis(args.u64("wait-ms")),
        close,
        queue_bound: args.usize("queue-bound"),
        deadline,
        params_path: None,
        registry,
        plans_dir,
    })?;
    let n = args.usize("requests");
    let kinds: Vec<DatasetKind> = models
        .iter()
        .map(|m| match m.as_str() {
            "tox21" => DatasetKind::Tox21,
            _ => DatasetKind::Reaction100,
        })
        .collect();
    let data = Dataset::generate(kinds[0], n, 3);
    let t0 = std::time::Instant::now();
    let rxs: Vec<_> = data
        .samples
        .iter()
        .enumerate()
        .map(|(i, s)| srv.submit_to(&models[i % models.len()], s.mol.clone()))
        .collect();
    for rx in rxs {
        rx.recv_timeout(Duration::from_secs(600))
            .map_err(|_| anyhow::anyhow!("response timeout"))?;
    }
    let secs = t0.elapsed().as_secs_f64();
    let m = srv.shutdown()?;
    println!(
        "{} requests in {secs:.2}s = {:.1} req/s | latency mean {:.2}ms \
         p50 {:.2}ms p99 {:.2}ms p99.9 {:.2}ms | {} batches, occupancy {:.0}% | \
         {} shed, queue hwm {}",
        m.requests,
        m.requests as f64 / secs,
        m.mean_latency_us / 1e3,
        m.p50_latency_us as f64 / 1e3,
        m.p99_latency_us as f64 / 1e3,
        m.p999_latency_us as f64 / 1e3,
        m.batches,
        m.mean_occupancy * 100.0,
        m.shed,
        m.queue_depth_hwm,
    );
    for pm in &m.per_model {
        println!(
            "  model {}: {} done, {} shed, {} batches, p50 {:.2}ms p99 {:.2}ms, \
             occupancy {:.0}%",
            pm.model,
            pm.requests,
            pm.shed,
            pm.batches,
            pm.p50_latency_us as f64 / 1e3,
            pm.p99_latency_us as f64 / 1e3,
            pm.mean_occupancy * 100.0,
        );
    }
    if m.param_swaps > 0 {
        println!("  param hot swaps: {}", m.param_swaps);
    }
    Ok(())
}

/// Inspect a plan-artifact directory (DESIGN.md §13) without booting a
/// trainer: per artifact, the file name, format version, content hash,
/// and the validation verdict (the full `decode` pipeline: JSON → kind
/// → version → content hash → field decode → `StepPlan::validate`).
fn cmd_plans(rest: &[String]) -> anyhow::Result<()> {
    let cli = Cli::new("chemgcn plans", "list/verify/dump AOT step-plan artifacts")
        .opt(
            "dir",
            "",
            "plan-artifact directory (default: $BSPMM_PLAN_ARTIFACTS, else <artifacts>/plans)",
        )
        .opt("dump", "", "print the raw JSON of one artifact (by file name)")
        .opt(
            "gc",
            "",
            "garbage-collect a multi-model plan root: remove plan artifacts under \
             model subdirectories the root's registry manifest no longer names. \
             Dry run by default — pass --apply to delete",
        )
        .flag("apply", "with --gc: actually delete the stale artifacts")
        .flag("verify", "exit with an error if any artifact fails validation");
    let args = parse(&cli, rest)?;
    let gc_root = args.str("gc");
    if !gc_root.is_empty() {
        let report = plan_artifact::gc_plans(Path::new(gc_root), args.flag("apply"))?;
        println!("{}", report.summary());
        for p in &report.stale {
            println!(
                "  {} {}",
                if report.dry_run { "stale:" } else { "removed:" },
                p.display()
            );
        }
        return Ok(());
    }
    let dir = match args.str("dir") {
        "" => plan_artifact::default_plan_dir(),
        d => PathBuf::from(d),
    };
    anyhow::ensure!(
        dir.is_dir(),
        "no plan directory at {} (run `plan_aot --dir {}` to produce artifacts)",
        dir.display(),
        dir.display()
    );
    let mut paths: Vec<PathBuf> = std::fs::read_dir(&dir)?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| {
            p.file_name()
                .and_then(|n| n.to_str())
                .is_some_and(|n| n.ends_with(plan_artifact::FILE_SUFFIX))
        })
        .collect();
    paths.sort();

    if let Some(want) = match args.str("dump") {
        "" => None,
        name => Some(name.to_string()),
    } {
        let path = paths
            .iter()
            .find(|p| p.file_name().and_then(|n| n.to_str()) == Some(want.as_str()))
            .ok_or_else(|| anyhow::anyhow!("no artifact '{want}' in {}", dir.display()))?;
        print!("{}", std::fs::read_to_string(path)?);
        return Ok(());
    }

    println!(
        "{} step-plan artifact(s) in {} (format v{})",
        paths.len(),
        dir.display(),
        plan_artifact::FORMAT_VERSION
    );
    let mut invalid = 0usize;
    for path in &paths {
        let name = path.file_name().and_then(|n| n.to_str()).unwrap_or("?");
        // Raw fields first (best effort), so even a failing artifact
        // shows what it claims to be; the verdict uses the full decode.
        let text = std::fs::read_to_string(path).unwrap_or_default();
        let raw = json_parse(&text).ok();
        let claimed = |key: &str| -> String {
            raw.as_ref()
                .and_then(|j| j.get(key).and_then(|v| v.as_f64()))
                .map(|v| format!("{v}"))
                .or_else(|| {
                    raw.as_ref()
                        .and_then(|j| j.get(key).and_then(|v| v.as_str()))
                        .map(String::from)
                })
                .unwrap_or_else(|| "?".into())
        };
        match plan_artifact::load(path) {
            Ok(art) => println!(
                "  {name}  v{} hash {}  OK: key {:?}, {} dispatches, {} slots, {} params",
                claimed("format_version"),
                art.content_hash,
                art.plan.key.0,
                art.plan.dispatches.len(),
                art.plan.slots.len(),
                art.plan.params.len(),
            ),
            Err(e) => {
                invalid += 1;
                println!(
                    "  {name}  v{} hash {}  INVALID: {e:#}",
                    claimed("format_version"),
                    claimed("content_hash"),
                );
            }
        }
    }
    if args.flag("verify") && invalid > 0 {
        anyhow::bail!("{invalid} invalid artifact(s) in {}", dir.display());
    }
    Ok(())
}

fn cmd_timeline(rest: &[String]) -> anyhow::Result<()> {
    let cli = Cli::new("chemgcn timeline", "Fig. 11 simulated layer timeline")
        .opt("batch", "50", "minibatch size")
        .opt("m", "50", "nodes per graph")
        .opt("fin", "16", "input feature width")
        .opt("fout", "64", "output feature width")
        .opt("z", "2", "nnz per row");
    let args = parse(&cli, rest)?;
    let cm = CostModel::default();
    let (b, m, fi, fo, z) = (
        args.usize("batch"),
        args.usize("m"),
        args.usize("fin"),
        args.usize("fout"),
        args.usize("z"),
    );
    for (label, batched) in [("non-batched", false), ("batched", true)] {
        let sim = simulate_layer(&cm, b, m, fi, fo, z, batched);
        println!(
            "{label} ({} framework ops, {} launches):",
            sim.events.len(),
            sim.launches
        );
        println!("{}", render_timeline(&sim, 64));
    }
    Ok(())
}

fn cmd_sim(rest: &[String]) -> anyhow::Result<()> {
    let cli = Cli::new("chemgcn sim", "simulated-P100 sweep for one figure")
        .opt("artifacts", "artifacts", "artifacts directory")
        .opt("sweep", "fig8a", "sweep key");
    let args = parse(&cli, rest)?;
    let rt = Runtime::new(Path::new(args.str("artifacts")))?;
    let sw = rt.manifest.sweep(args.str("sweep"))?;
    let runner = bspmm::bench::figures::FigureRunner::new(&rt);
    let sim = runner.run_simulated(&sw)?;
    println!("{}", sim.render());
    Ok(())
}
