//! Flat parameter vector + loading the AOT-dumped initial values.

use std::io::Read;
use std::path::Path;

use super::config::ModelConfig;
use crate::util::rng::Rng;

/// All model parameters as one contiguous f32 vector, sliced per the
/// manifest layout. This is exactly the order the artifacts take the
/// parameter literals in.
#[derive(Clone, Debug)]
pub struct ParamSet {
    pub data: Vec<f32>,
}

impl ParamSet {
    pub fn zeros(cfg: &ModelConfig) -> Self {
        Self {
            data: vec![0.0; cfg.n_params],
        }
    }

    /// Load `<artifacts>/<init_file>` (little-endian f32 blob dumped by
    /// aot.py).
    pub fn load_init(cfg: &ModelConfig, artifacts_dir: &Path) -> anyhow::Result<Self> {
        let path = artifacts_dir.join(&cfg.init_file);
        let mut bytes = Vec::new();
        std::fs::File::open(&path)
            .map_err(|e| anyhow::anyhow!("open {}: {e}", path.display()))?
            .read_to_end(&mut bytes)?;
        anyhow::ensure!(
            bytes.len() == cfg.n_params * 4,
            "param file {} has {} bytes, expected {}",
            path.display(),
            bytes.len(),
            cfg.n_params * 4
        );
        let data = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        Ok(Self { data })
    }

    /// Deterministic in-process initialization for manifest-free runs
    /// (the host-engine dispatch path): Glorot-style normal weights,
    /// unit norm scales, zero biases — the same shape of init aot.py
    /// uses, without the artifact dependency.
    pub fn random_init(cfg: &ModelConfig, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let mut ps = Self::zeros(cfg);
        for p in &cfg.params {
            if p.name.ends_with(".gamma") {
                ps.data[p.offset..p.offset + p.size].fill(1.0);
            } else if p.name.ends_with(".w") {
                let d = p.shape.len();
                let (fan_in, fan_out) = (p.shape[d - 2], p.shape[d - 1]);
                let scale = (2.0 / (fan_in + fan_out) as f32).sqrt();
                for v in &mut ps.data[p.offset..p.offset + p.size] {
                    *v = rng.normal() * scale;
                }
            }
        }
        ps
    }

    pub fn slice<'a>(&'a self, cfg: &ModelConfig, name: &str) -> anyhow::Result<&'a [f32]> {
        let p = cfg.param(name)?;
        Ok(&self.data[p.offset..p.offset + p.size])
    }

    /// Mutable view of one named parameter tensor — how the backward
    /// pass writes per-tensor gradients into a [`ParamSet`]-shaped
    /// accumulator.
    pub fn slice_mut<'a>(
        &'a mut self,
        cfg: &ModelConfig,
        name: &str,
    ) -> anyhow::Result<&'a mut [f32]> {
        let p = cfg.param(name)?;
        Ok(&mut self.data[p.offset..p.offset + p.size])
    }

    /// Views in layout order — what gets marshalled into literals.
    pub fn views<'a>(&'a self, cfg: &ModelConfig) -> Vec<&'a [f32]> {
        cfg.params
            .iter()
            .map(|p| &self.data[p.offset..p.offset + p.size])
            .collect()
    }

    pub fn l2_norm(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
    }

    /// bf16 storage of the whole parameter vector (truncation, DESIGN.md
    /// §16) — half the bytes of the f32 blob. The reduced-precision
    /// serving path stores weights in this form and expands them back
    /// with [`ParamSet::from_bf16`] when a model's params are swapped
    /// in.
    pub fn to_bf16(&self) -> Vec<u16> {
        self.data.iter().map(|&v| crate::sparse::batch::f32_to_bf16(v)).collect()
    }

    /// Expand bf16 parameter storage back to a dispatchable f32 set
    /// (exact: bf16 is a prefix of the f32 bit pattern).
    pub fn from_bf16(bits: &[u16]) -> ParamSet {
        ParamSet {
            data: bits.iter().map(|&b| crate::sparse::batch::bf16_to_f32(b)).collect(),
        }
    }

    /// Every parameter rounded through bf16 — the weight cast the
    /// inference-only [`DType::Bf16`](crate::sparse::engine::DType) and
    /// [`DType::Int8`](crate::sparse::engine::DType) precision modes
    /// dispatch with (quantized adjacency keeps f32 activations, so
    /// weights are the only other tensor to cast).
    pub fn round_to_bf16(&self) -> ParamSet {
        ParamSet::from_bf16(&self.to_bf16())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::parse;

    fn tiny_cfg() -> ModelConfig {
        let j = parse(
            r#"{
 "name": "t", "max_nodes": 4, "feat_dim": 2, "channels": 1,
 "hidden": [2], "n_out": 2, "loss": "bce", "nnz_cap": 4, "ell_width": 3,
 "train_batch": 2, "infer_batch": 2, "n_params": 6,
 "params": [
   {"name": "a", "shape": [1, 2, 2], "offset": 0, "size": 4},
   {"name": "b", "shape": [2], "offset": 4, "size": 2}
 ],
 "init_file": "t.bin",
 "artifact_fwd_infer": "x", "artifact_fwd_train": "x",
 "artifact_fwd_sample": "x", "artifact_train_step": "x",
 "artifact_grad_sample": "x", "artifact_apply_sgd": "x"
}"#,
        )
        .unwrap();
        ModelConfig::from_json(&j).unwrap()
    }

    #[test]
    fn load_init_roundtrip() {
        let cfg = tiny_cfg();
        let dir = std::env::temp_dir().join("bspmm_params_test");
        std::fs::create_dir_all(&dir).unwrap();
        let vals: Vec<f32> = vec![1.0, -2.0, 3.5, 0.0, 9.0, -9.0];
        let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        std::fs::write(dir.join("t.bin"), &bytes).unwrap();
        let ps = ParamSet::load_init(&cfg, &dir).unwrap();
        assert_eq!(ps.data, vals);
        assert_eq!(ps.slice(&cfg, "b").unwrap(), &[9.0, -9.0]);
        assert_eq!(ps.views(&cfg).len(), 2);
    }

    #[test]
    fn random_init_is_deterministic_and_shaped() {
        let cfg = ModelConfig::synthetic("tox21").unwrap();
        let a = ParamSet::random_init(&cfg, 9);
        let b = ParamSet::random_init(&cfg, 9);
        assert_eq!(a.data, b.data);
        assert!(a.slice(&cfg, "conv0.gamma").unwrap().iter().all(|&v| v == 1.0));
        assert!(a.slice(&cfg, "conv0.beta").unwrap().iter().all(|&v| v == 0.0));
        assert!(a.slice(&cfg, "conv0.w").unwrap().iter().any(|&v| v != 0.0));
        assert!(a.l2_norm() > 0.0);
        let c = ParamSet::random_init(&cfg, 10);
        assert_ne!(a.data, c.data);
    }

    #[test]
    fn bf16_storage_round_trips_and_halves_bytes() {
        let cfg = ModelConfig::synthetic("tox21").unwrap();
        let ps = ParamSet::random_init(&cfg, 3);
        let bits = ps.to_bf16();
        assert_eq!(bits.len(), ps.data.len());
        let back = ParamSet::from_bf16(&bits);
        // Expansion is exact; a second cast is a fixed point.
        assert_eq!(back.to_bf16(), bits);
        assert_eq!(back.data, ps.round_to_bf16().data);
        for (b, v) in back.data.iter().zip(&ps.data) {
            if *v != 0.0 {
                assert!((b - v).abs() <= v.abs() / 128.0, "{b} vs {v}");
            } else {
                assert_eq!(*b, 0.0);
            }
        }
    }

    #[test]
    fn load_init_rejects_wrong_size() {
        let cfg = tiny_cfg();
        let dir = std::env::temp_dir().join("bspmm_params_test2");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("t.bin"), [0u8; 8]).unwrap();
        assert!(ParamSet::load_init(&cfg, &dir).is_err());
    }
}
