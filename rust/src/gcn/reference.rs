//! Pure-rust ChemGCN forward + loss — mirrors `python/compile/model.py`
//! operation-for-operation. Used by the integration tests as the
//! cross-language oracle for the PJRT artifact executions, and by the
//! examples to report accuracy without a device round-trip. The
//! matching backward pass lives in [`super::backward`] (DESIGN.md §8)
//! and reuses this module's layer helpers so forward and gradient can
//! never drift apart.
//!
//! All multiplication routes through the batched-SpMM engine
//! ([`crate::sparse::engine`]): the per-channel `X @ W` feature
//! transform and the readout head dispatch [`GemmKernel`]s, the
//! adjacency SpMM dispatches an [`EllKernel`] channel view — so one
//! engine dispatch covers the whole batch where the pre-engine code
//! iterated (sample, channel) pairs inline. Iteration order inside the
//! kernels matches the old inlined loops, so logits are bit-identical.
//!
//! The readout head multiplies against a tiled copy of `readout.w`
//! ([`build_w_rep`], `[M*fin, n_out]`, ~10 MB on reaction100). It is a
//! pure function of the parameters, so the coordinator's host paths
//! cache it per [`ParamSet`] and pass it to [`forward_with_readout`];
//! [`forward_with`] rebuilds it every call for one-shot users.
//!
//! **Plan/execute split (DESIGN.md §11).** [`forward_with_readout`] is
//! the *direct* path: it re-derives shapes/params per call and
//! allocates fresh intermediates. The hot paths instead compile a
//! [`StepPlan`] once per geometry ([`plan_forward`]) and replay it
//! ([`forward_planned`]) with every intermediate — layer activations,
//! the `U = XW + b` scratch, the logits — drawn from a caller-held
//! [`Workspace`] arena, so steady-state replays allocate nothing.
//! Both paths run the same layer helpers on the same engine dispatch
//! sequence, so their logits are bit-identical.

use super::config::{LossKind, ModelConfig};
use super::params::ParamSet;
use crate::graph::dataset::ModelBatch;
use crate::sparse::batch::QuantizedEllBatch;
use crate::sparse::engine::{
    choose_backend, AutoThresholds, Backend, DType, DispatchDesc, DispatchProfile, EllKernel,
    Executor, GemmKernel, GeometryKey, PlanCursor, QuantEllKernel, Rhs, RhsKind, SlotId, SlotInit,
    StepPlan, Workspace,
};

/// GraphNorm variance stabilizer — matches `model.py`'s `eps`.
pub(crate) const EPS: f32 = 1e-5;

/// Forward pass on the serial executor: returns logits `[B, n_out]`
/// (row-major).
pub fn forward(cfg: &ModelConfig, ps: &ParamSet, mb: &ModelBatch) -> anyhow::Result<Vec<f32>> {
    forward_with(cfg, ps, mb, &Executor::serial())
}

/// Forward pass with an explicit engine executor (the coordinator's
/// host dispatch paths pass a handle on their long-lived worker pool,
/// so one pool spans every dispatch of a forward or train step).
/// Results are bit-identical for every thread count and steal order
/// (DESIGN.md §9).
pub fn forward_with(
    cfg: &ModelConfig,
    ps: &ParamSet,
    mb: &ModelBatch,
    exec: &Executor,
) -> anyhow::Result<Vec<f32>> {
    let w_rep = build_w_rep(cfg, ps)?;
    forward_with_readout(cfg, ps, mb, exec, &w_rep)
}

/// The tiled readout weight: `readout.w` (`[fin, n_out]`) repeated
/// `max_nodes` times into `[M*fin, n_out]`, so the sum-pool readout is
/// one engine dispatch over `[1, M*fin]` row views. Pure function of
/// the parameters — cache it per [`ParamSet`] and invalidate on every
/// parameter update (the coordinator's host paths do).
pub fn build_w_rep(cfg: &ModelConfig, ps: &ParamSet) -> anyhow::Result<Vec<f32>> {
    let fin = *cfg.hidden.last().unwrap_or(&cfg.feat_dim);
    let w_out = ps.slice(cfg, "readout.w")?; // [fin, n_out]
    let mut w_rep = vec![0f32; cfg.max_nodes * fin * cfg.n_out];
    for row in w_rep.chunks_mut(fin * cfg.n_out) {
        row.copy_from_slice(w_out);
    }
    Ok(w_rep)
}

/// Forward pass against a caller-provided tiled readout weight (from
/// [`build_w_rep`]); bit-identical to [`forward_with`], minus the
/// per-call tiling cost.
pub fn forward_with_readout(
    cfg: &ModelConfig,
    ps: &ParamSet,
    mb: &ModelBatch,
    exec: &Executor,
    w_rep: &[f32],
) -> anyhow::Result<Vec<f32>> {
    check_batch(cfg, mb)?;
    let mut h = mb.x.clone(); // [B, M, fin]
    let mut fin = cfg.feat_dim;
    for (li, &fout) in cfg.hidden.iter().enumerate() {
        let gamma = ps.slice(cfg, &format!("conv{li}.gamma"))?;
        let beta = ps.slice(cfg, &format!("conv{li}.beta"))?;
        let mut y = conv_layer(cfg, ps, li, fin, fout, &h, mb, exec)?;
        // GraphNorm + ReLU (+ re-mask).
        graph_norm_relu(&mut y, &mb.mask, gamma, beta, mb.batch, cfg.max_nodes, fout);
        h = y;
        fin = fout;
    }
    readout(cfg, ps, &h, fin, mb.batch, exec, w_rep)
}

/// Shared geometry validation for forward and backward entry points.
pub(crate) fn check_batch(cfg: &ModelConfig, mb: &ModelBatch) -> anyhow::Result<()> {
    anyhow::ensure!(mb.max_nodes == cfg.max_nodes, "node bucket mismatch");
    anyhow::ensure!(mb.feat_dim == cfg.feat_dim, "feature width mismatch");
    anyhow::ensure!(mb.channels == cfg.channels, "channel count mismatch");
    Ok(())
}

/// One graph-conv layer up to (not including) GraphNorm: returns the
/// pre-normalization accumulator `y[b,m,o] = Σ_ch A[b,ch] @ (X[b] @
/// W[ch] + bias[ch])`. Two engine dispatches per channel, each covering
/// the whole batch. This is the direct (unplanned) wrapper: it resolves
/// parameters by name and allocates fresh intermediates per call.
#[allow(clippy::too_many_arguments)]
pub(crate) fn conv_layer(
    cfg: &ModelConfig,
    ps: &ParamSet,
    li: usize,
    fin: usize,
    fout: usize,
    h: &[f32],
    mb: &ModelBatch,
    exec: &Executor,
) -> anyhow::Result<Vec<f32>> {
    let b = mb.batch;
    let m = cfg.max_nodes;
    let w = ps.slice(cfg, &format!("conv{li}.w"))?; // [CH, fin, fout]
    let bias = ps.slice(cfg, &format!("conv{li}.b"))?; // [CH, fout]
    let mut y = vec![0f32; b * m * fout];
    let mut u = vec![0f32; b * m * fout];
    conv_layer_into(cfg, w, bias, fin, fout, h, mb, exec, None, None, &mut y, &mut u)?;
    Ok(y)
}

/// Shared core of the direct and planned conv layer: accumulate one
/// layer into the caller's `y` (pre-zeroed) using the caller's `u`
/// scratch (fully bias-overwritten per channel, so it needs no
/// zeroing). When `plan` is given, each dispatch consumes its recorded
/// [`DispatchDesc`] — the adjacency dispatch runs on the descriptor's
/// resolved backend and [`DType`] instead of re-deriving them. A
/// quantized adjacency batch (`quant`, DESIGN.md §16) swaps the
/// adjacency dispatch onto the dequantize-on-the-fly
/// [`QuantEllKernel`]; the dense feature transform stays f32 either
/// way (quantization covers adjacency values and weight *storage*,
/// activations remain f32).
#[allow(clippy::too_many_arguments)]
pub(crate) fn conv_layer_into(
    cfg: &ModelConfig,
    w: &[f32],
    bias: &[f32],
    fin: usize,
    fout: usize,
    h: &[f32],
    mb: &ModelBatch,
    exec: &Executor,
    mut plan: Option<&mut PlanCursor<'_>>,
    quant: Option<&QuantizedEllBatch>,
    y: &mut [f32],
    u: &mut [f32],
) -> anyhow::Result<()> {
    let b = mb.batch;
    let m = cfg.max_nodes;
    debug_assert_eq!(y.len(), b * m * fout);
    debug_assert_eq!(u.len(), b * m * fout);
    for ch in 0..cfg.channels {
        let w_ch = &w[ch * fin * fout..(ch + 1) * fin * fout];
        let b_ch = &bias[ch * fout..(ch + 1) * fout];
        // U = X @ W[ch] + bias[ch]   (MatMul + Add, Fig. 6):
        // bias-prefill, then accumulate through the dense backend.
        for row in u.chunks_mut(fout) {
            row.copy_from_slice(b_ch);
        }
        // The planned path reads the dense width off the descriptor —
        // the recorded value, not a re-derivation.
        let n = match plan.as_deref_mut() {
            Some(c) => {
                let d = c.dispatch();
                debug_assert_eq!(d.backend, Backend::Gemm);
                debug_assert_eq!(d.dtype, DType::F32);
                d.n as usize
            }
            None => fout,
        };
        debug_assert_eq!(n, fout);
        let xw = GemmKernel::new(h, b, m, fin);
        exec.dispatch(&xw, Rhs::Shared(w_ch), n, u)?;
        // y += A[ch] @ U             (SpMM + ElementWiseAdd).
        let (backend, dtype) = match plan.as_deref_mut() {
            Some(c) => {
                let d = c.dispatch();
                (d.backend, d.dtype)
            }
            None => (Backend::Ell, quant.map_or(DType::F32, |q| q.dtype)),
        };
        match (backend, dtype) {
            (Backend::Ell, DType::F32) => {
                let adj = EllKernel::channel(mb, ch);
                exec.dispatch(&adj, Rhs::PerSample(u), fout, y)?;
            }
            (Backend::Ell, want) => {
                let q = quant.filter(|q| q.dtype == want).ok_or_else(|| {
                    anyhow::anyhow!(
                        "dispatch wants {want} adjacency but no matching quantized batch \
                         was provided"
                    )
                })?;
                let adj = QuantEllKernel::channel(q, ch, cfg.channels);
                exec.dispatch(&adj, Rhs::PerSample(u), fout, y)?;
            }
            (other, _) => anyhow::bail!("adjacency planned on unpacked backend {other}"),
        }
    }
    Ok(())
}

/// Sum-pool readout + dense head: logits[b] = b_out + Σ_r h[b,r,:] @ W.
/// Viewing h[b] as [1, m*fin] against the tiled weight keeps the
/// original (r, k) accumulation order while routing through the engine.
/// Direct wrapper — allocates the logits buffer per call.
pub(crate) fn readout(
    cfg: &ModelConfig,
    ps: &ParamSet,
    h: &[f32],
    fin: usize,
    b: usize,
    exec: &Executor,
    w_rep: &[f32],
) -> anyhow::Result<Vec<f32>> {
    let b_out = ps.slice(cfg, "readout.b")?;
    let mut logits = vec![0f32; b * cfg.n_out];
    readout_into(cfg, b_out, h, fin, b, exec, w_rep, None, &mut logits)?;
    Ok(logits)
}

/// Shared core of the direct and planned readout: prefill the caller's
/// `logits` buffer with the bias (full overwrite — no zeroing needed)
/// and accumulate the pooled head through one engine dispatch.
#[allow(clippy::too_many_arguments)]
pub(crate) fn readout_into(
    cfg: &ModelConfig,
    b_out: &[f32],
    h: &[f32],
    fin: usize,
    b: usize,
    exec: &Executor,
    w_rep: &[f32],
    mut plan: Option<&mut PlanCursor<'_>>,
    logits: &mut [f32],
) -> anyhow::Result<()> {
    let m = cfg.max_nodes;
    let n_out = cfg.n_out;
    anyhow::ensure!(
        w_rep.len() == m * fin * n_out,
        "w_rep length {} != {m} * {fin} * {n_out} (stale readout cache?)",
        w_rep.len()
    );
    debug_assert_eq!(logits.len(), b * n_out);
    for row in logits.chunks_mut(n_out) {
        row.copy_from_slice(b_out);
    }
    let n = match plan.as_deref_mut() {
        Some(c) => {
            let d = c.dispatch();
            debug_assert_eq!(d.backend, Backend::Gemm);
            d.n as usize
        }
        None => n_out,
    };
    debug_assert_eq!(n, n_out);
    let readout = GemmKernel::new(h, b, 1, m * fin);
    exec.dispatch(&readout, Rhs::Shared(w_rep), n, logits)?;
    Ok(())
}

// ---------------------------------------------------------------------
// Plan/execute split (DESIGN.md §11)
// ---------------------------------------------------------------------

/// Mode tags for [`GeometryKey`]s (forward-only vs full train step).
pub(crate) const MODE_FORWARD: u32 = 1;
pub(crate) const MODE_TRAIN: u32 = 2;

/// The geometry a gcn plan depends on: mode, value precision, batch
/// size, and every model dimension the slot table / dispatch list
/// reads. Batch *contents* (adjacency values, features) are not part
/// of the key — plans replay across minibatches of the same shape.
/// The [`DType`] tag keeps an f32 plan from ever being replayed for a
/// quantized request (and vice versa): the precisions produce
/// different numbers, so they are different plans (DESIGN.md §16).
pub(crate) fn geometry_key(
    cfg: &ModelConfig,
    mb: &ModelBatch,
    mode: u32,
    dtype: DType,
) -> GeometryKey {
    let mut v = vec![
        mode,
        dtype.key_tag(),
        mb.batch as u32,
        mb.max_nodes as u32,
        mb.feat_dim as u32,
        mb.channels as u32,
        mb.ell_width as u32,
        cfg.n_out as u32,
    ];
    v.extend(cfg.hidden.iter().map(|&h| h as u32));
    GeometryKey(v)
}

/// Cache key for an f32 forward plan of this batch shape.
pub fn forward_plan_key(cfg: &ModelConfig, mb: &ModelBatch) -> GeometryKey {
    forward_plan_key_dtype(cfg, mb, DType::F32)
}

/// Cache key for a forward plan at an explicit inference precision.
pub fn forward_plan_key_dtype(cfg: &ModelConfig, mb: &ModelBatch, dtype: DType) -> GeometryKey {
    geometry_key(cfg, mb, MODE_FORWARD, dtype)
}

// Parameter-reference indices into `StepPlan::params`, fixed by
// `plan_forward_into`'s push order: (w, b, gamma, beta) per conv layer,
// then readout.b; train plans append readout.w (backward.rs).
pub(crate) fn p_w(li: usize) -> usize {
    4 * li
}
pub(crate) fn p_b(li: usize) -> usize {
    4 * li + 1
}
pub(crate) fn p_gamma(li: usize) -> usize {
    4 * li + 2
}
pub(crate) fn p_beta(li: usize) -> usize {
    4 * li + 3
}
pub(crate) fn p_readout_b(cfg: &ModelConfig) -> usize {
    4 * cfg.hidden.len()
}
pub(crate) fn p_readout_w(cfg: &ModelConfig) -> usize {
    4 * cfg.hidden.len() + 1
}

/// Workspace slot ids of a forward plan, fixed by construction order:
/// the shared `U = XW + b` scratch, one post-norm activation per conv
/// layer, and the logits. Pure function of the config, so builders and
/// replayers derive identical ids.
pub(crate) struct FwdSlots {
    pub u: SlotId,
    pub act: Vec<SlotId>,
    pub logits: SlotId,
}

pub(crate) fn fwd_slot_ids(cfg: &ModelConfig) -> FwdSlots {
    let l = cfg.hidden.len();
    FwdSlots {
        u: SlotId(0),
        act: (0..l).map(|i| SlotId(1 + i as u32)).collect(),
        logits: SlotId(1 + l as u32),
    }
}

/// Widest feature dimension any intermediate of this model carries.
pub(crate) fn max_feat(cfg: &ModelConfig) -> usize {
    cfg.hidden.iter().copied().max().unwrap_or(cfg.feat_dim)
}

/// Append the forward step's slots, parameter refs and dispatch
/// descriptors to `plan` (the train planner continues from here).
/// Descriptors resolve their backend at build time: the dense feature
/// transform and readout can only run on GEMM, the adjacency SpMM is
/// chosen by the cost model over the packings the [`ModelBatch`]
/// actually holds (ELL today) — so a cached plan never re-runs
/// selection (DESIGN.md §11).
pub(crate) fn plan_forward_into(
    cfg: &ModelConfig,
    mb: &ModelBatch,
    th: &AutoThresholds,
    dtype: DType,
    plan: &mut StepPlan,
) -> anyhow::Result<FwdSlots> {
    check_batch(cfg, mb)?;
    let b = mb.batch;
    let m = cfg.max_nodes;
    let sl = fwd_slot_ids(cfg);
    let u = plan.add_slot(b * m * max_feat(cfg));
    debug_assert_eq!(u, sl.u);
    for (li, &fout) in cfg.hidden.iter().enumerate() {
        let id = plan.add_slot(b * m * fout);
        debug_assert_eq!(id, sl.act[li]);
    }
    let logits = plan.add_slot(b * cfg.n_out);
    debug_assert_eq!(logits, sl.logits);

    for li in 0..cfg.hidden.len() {
        for name in ["w", "b", "gamma", "beta"] {
            let p = cfg.param(&format!("conv{li}.{name}"))?;
            plan.add_param(p.offset, p.size);
        }
    }
    let rb = cfg.param("readout.b")?;
    plan.add_param(rb.offset, rb.size);

    for (li, &fout) in cfg.hidden.iter().enumerate() {
        for ch in 0..cfg.channels {
            // Dense dispatches stay f32 at every precision: only the
            // adjacency values are quantized (DESIGN.md §16).
            plan.add_dispatch(DispatchDesc {
                backend: Backend::Gemm,
                transpose: false,
                rhs: RhsKind::Shared,
                n: fout as u32,
                out: sl.u,
                dtype: DType::F32,
            });
            plan.add_dispatch(DispatchDesc {
                backend: adjacency_backend(mb, ch, th)?,
                transpose: false,
                rhs: RhsKind::PerSample,
                n: fout as u32,
                out: sl.act[li],
                dtype,
            });
        }
    }
    plan.add_dispatch(DispatchDesc {
        backend: Backend::Gemm,
        transpose: false,
        rhs: RhsKind::Shared,
        n: cfg.n_out as u32,
        out: sl.logits,
        dtype: DType::F32,
    });
    Ok(sl)
}

/// Resolve the adjacency SpMM backend for one channel from the O(1)
/// nnz cost model. The [`ModelBatch`] packs its adjacency in ELL only,
/// so the candidate set is `{Ell}` today — the selection still runs so
/// additional packings become a one-line candidate change.
pub(crate) fn adjacency_backend(
    mb: &ModelBatch,
    ch: usize,
    th: &AutoThresholds,
) -> anyhow::Result<Backend> {
    let nnz: usize = (0..mb.batch)
        .map(|b| mb.ell_nnz[b * mb.channels + ch] as usize)
        .sum();
    let profile = DispatchProfile {
        batch: mb.batch,
        rows: mb.max_nodes,
        inner: mb.max_nodes,
        nnz,
        ell_width: Some(mb.ell_width),
    };
    choose_backend(&profile, &[Backend::Ell], th)
}

/// Compile a forward step for this geometry: slot table + resolved
/// dispatch descriptors + cached parameter offsets. Pure function of
/// (config, batch shape, thresholds) — replay it against any batch of
/// the same geometry via [`forward_planned`].
pub fn plan_forward(
    cfg: &ModelConfig,
    mb: &ModelBatch,
    th: &AutoThresholds,
) -> anyhow::Result<StepPlan> {
    plan_forward_dtype(cfg, mb, th, DType::F32)
}

/// [`plan_forward`] at an explicit inference precision: the adjacency
/// dispatch descriptors carry `dtype`, so replays resolve the
/// dequantize-on-the-fly kernel without re-deriving anything, and the
/// plan key separates the precision from its f32 twin (DESIGN.md §16).
pub fn plan_forward_dtype(
    cfg: &ModelConfig,
    mb: &ModelBatch,
    th: &AutoThresholds,
    dtype: DType,
) -> anyhow::Result<StepPlan> {
    let mut plan = StepPlan::new(forward_plan_key_dtype(cfg, mb, dtype));
    plan_forward_into(cfg, mb, th, dtype, &mut plan)?;
    Ok(plan)
}

/// Resize a taken arena buffer to this use's exact length (capacity was
/// reserved by `Workspace::prepare`, so this never reallocates in
/// steady state).
pub(crate) fn fit(buf: &mut Vec<f32>, len: usize) {
    if buf.len() > len {
        buf.truncate(len);
    } else {
        buf.resize(len, 0.0);
    }
}

/// Buffers a planned forward leaves taken out of the workspace; the
/// caller reads them (backward replays them) and must hand every one
/// back via [`restore_planned_fwd`].
pub(crate) struct PlannedFwd {
    /// Post-norm activations, one per conv layer (`acts[l]` feeds layer
    /// `l + 1`; the layer-0 input is `mb.x` and is never copied).
    pub acts: Vec<Vec<f32>>,
    /// Pre-norm accumulators (captured only for train replays).
    pub ypre: Vec<Vec<f32>>,
    pub logits: Vec<f32>,
}

/// Return a planned forward's buffers to their arena slots.
pub(crate) fn restore_planned_fwd(
    cfg: &ModelConfig,
    ws: &mut Workspace,
    ypre_slots: &[SlotId],
    f: PlannedFwd,
) {
    let sl = fwd_slot_ids(cfg);
    for (li, a) in f.acts.into_iter().enumerate() {
        ws.put(sl.act[li], a);
    }
    for (li, y) in f.ypre.into_iter().enumerate() {
        ws.put(ypre_slots[li], y);
    }
    ws.put(sl.logits, f.logits);
}

/// Replay the forward portion of a plan, drawing every intermediate
/// from the workspace. `ypre_slots` non-empty captures pre-norm
/// accumulators for the backward pass (train plans declare those
/// slots). Dispatch sequence and math are identical to
/// [`forward_with_readout`] — bit-identical logits.
#[allow(clippy::too_many_arguments)]
pub(crate) fn forward_planned_core(
    cfg: &ModelConfig,
    ps: &ParamSet,
    mb: &ModelBatch,
    exec: &Executor,
    w_rep: &[f32],
    plan: &StepPlan,
    ws: &mut Workspace,
    cursor: &mut PlanCursor<'_>,
    ypre_slots: &[SlotId],
    quant: Option<&QuantizedEllBatch>,
) -> anyhow::Result<PlannedFwd> {
    check_batch(cfg, mb)?;
    let b = mb.batch;
    let m = cfg.max_nodes;
    let sl = fwd_slot_ids(cfg);
    let mut u = ws.take(sl.u, b * m * max_feat(cfg), SlotInit::Overwrite);
    let mut acts: Vec<Vec<f32>> = Vec::with_capacity(cfg.hidden.len());
    let mut ypre: Vec<Vec<f32>> = Vec::with_capacity(ypre_slots.len());
    let mut fin = cfg.feat_dim;
    for (li, &fout) in cfg.hidden.iter().enumerate() {
        let w = &ps.data[plan.param(p_w(li)).range()];
        let bias = &ps.data[plan.param(p_b(li)).range()];
        let gamma = &ps.data[plan.param(p_gamma(li)).range()];
        let beta = &ps.data[plan.param(p_beta(li)).range()];
        let mut y = ws.take(sl.act[li], b * m * fout, SlotInit::Zeroed);
        fit(&mut u, b * m * fout);
        let h: &[f32] = if li == 0 { &mb.x } else { &acts[li - 1] };
        conv_layer_into(
            cfg,
            w,
            bias,
            fin,
            fout,
            h,
            mb,
            exec,
            Some(&mut *cursor),
            quant,
            &mut y,
            &mut u,
        )?;
        if !ypre_slots.is_empty() {
            let mut yp = ws.take(ypre_slots[li], b * m * fout, SlotInit::Overwrite);
            yp.copy_from_slice(&y);
            ypre.push(yp);
        }
        graph_norm_relu(&mut y, &mb.mask, gamma, beta, b, m, fout);
        acts.push(y);
        fin = fout;
    }
    let mut logits = ws.take(sl.logits, b * cfg.n_out, SlotInit::Overwrite);
    let b_out = &ps.data[plan.param(p_readout_b(cfg)).range()];
    let h_last: &[f32] = acts.last().map_or(&mb.x[..], |v| &v[..]);
    readout_into(
        cfg,
        b_out,
        h_last,
        fin,
        b,
        exec,
        w_rep,
        Some(&mut *cursor),
        &mut logits,
    )?;
    ws.put(sl.u, u);
    Ok(PlannedFwd { acts, ypre, logits })
}

/// Replay a compiled forward plan: bit-identical to
/// [`forward_with_readout`], with zero intermediate allocations in
/// steady state (the returned logits vector is the one per-call copy —
/// results must outlive the arena).
pub fn forward_planned(
    cfg: &ModelConfig,
    ps: &ParamSet,
    mb: &ModelBatch,
    exec: &Executor,
    w_rep: &[f32],
    plan: &StepPlan,
    ws: &mut Workspace,
) -> anyhow::Result<Vec<f32>> {
    anyhow::ensure!(
        plan.key == forward_plan_key(cfg, mb),
        "stale forward plan: geometry changed without a rebuild"
    );
    let mut cursor = PlanCursor::new(plan);
    let f = forward_planned_core(cfg, ps, mb, exec, w_rep, plan, ws, &mut cursor, &[], None)?;
    cursor.finish();
    let out = f.logits.clone();
    restore_planned_fwd(cfg, ws, &[], f);
    Ok(out)
}

/// Replay a quantized-precision forward plan (from
/// [`plan_forward_dtype`]): the adjacency dispatches run on the
/// dequantize-on-the-fly kernel over `quant`, everything else is the
/// planned f32 machinery. The caller supplies bf16-rounded parameters
/// and a matching `w_rep` for the weight-storage half of the precision
/// mode (DESIGN.md §16).
#[allow(clippy::too_many_arguments)]
pub fn forward_planned_quant(
    cfg: &ModelConfig,
    ps: &ParamSet,
    mb: &ModelBatch,
    quant: &QuantizedEllBatch,
    exec: &Executor,
    w_rep: &[f32],
    plan: &StepPlan,
    ws: &mut Workspace,
) -> anyhow::Result<Vec<f32>> {
    anyhow::ensure!(
        plan.key == forward_plan_key_dtype(cfg, mb, quant.dtype),
        "stale {} forward plan: geometry changed without a rebuild",
        quant.dtype
    );
    let mut cursor = PlanCursor::new(plan);
    let f =
        forward_planned_core(cfg, ps, mb, exec, w_rep, plan, ws, &mut cursor, &[], Some(quant))?;
    cursor.finish();
    let out = f.logits.clone();
    restore_planned_fwd(cfg, ws, &[], f);
    Ok(out)
}

/// Quantize a model batch's adjacency planes for an inference-only
/// precision mode — the pack-time half of the quantized path
/// ([`QuantizedEllBatch`], DESIGN.md §16). Planes are `[B, CH]` in the
/// model batch's `[B, CH, M, R]` layout, so channel views line up with
/// [`QuantEllKernel::channel`].
pub fn quantize_batch(mb: &ModelBatch, dtype: DType) -> anyhow::Result<QuantizedEllBatch> {
    QuantizedEllBatch::quantize(
        &mb.ell_cols,
        &mb.ell_vals,
        mb.batch * mb.channels,
        mb.max_nodes,
        mb.ell_width,
        dtype,
    )
}

/// Direct (unplanned) reduced-precision forward: bf16-round the
/// parameters, quantize the adjacency planes to `dtype`, and run the
/// standard layer sequence with the quantized adjacency kernels. The
/// convenience entry the accuracy-delta tests and one-shot users call;
/// serving paths pre-quantize and replay plans instead.
pub fn forward_quantized(
    cfg: &ModelConfig,
    ps: &ParamSet,
    mb: &ModelBatch,
    exec: &Executor,
    dtype: DType,
) -> anyhow::Result<Vec<f32>> {
    anyhow::ensure!(
        dtype != DType::F32,
        "f32 needs no quantized forward — call forward_with"
    );
    check_batch(cfg, mb)?;
    let ps16 = ps.round_to_bf16();
    let w_rep = build_w_rep(cfg, &ps16)?;
    let quant = quantize_batch(mb, dtype)?;
    let b = mb.batch;
    let m = cfg.max_nodes;
    let mut h = mb.x.clone();
    let mut fin = cfg.feat_dim;
    for (li, &fout) in cfg.hidden.iter().enumerate() {
        let w = ps16.slice(cfg, &format!("conv{li}.w"))?;
        let bias = ps16.slice(cfg, &format!("conv{li}.b"))?;
        let gamma = ps16.slice(cfg, &format!("conv{li}.gamma"))?;
        let beta = ps16.slice(cfg, &format!("conv{li}.beta"))?;
        let mut y = vec![0f32; b * m * fout];
        let mut u = vec![0f32; b * m * fout];
        conv_layer_into(
            cfg,
            w,
            bias,
            fin,
            fout,
            &h,
            mb,
            exec,
            None,
            Some(&quant),
            &mut y,
            &mut u,
        )?;
        graph_norm_relu(&mut y, &mb.mask, gamma, beta, b, m, fout);
        h = y;
        fin = fout;
    }
    readout(cfg, &ps16, &h, fin, b, exec, &w_rep)
}

/// In-place per-graph masked normalization + affine + ReLU + re-mask —
/// matches `model.graph_norm` followed by `jax.nn.relu`.
pub(crate) fn graph_norm_relu(
    y: &mut [f32],
    mask: &[f32],
    gamma: &[f32],
    beta: &[f32],
    b: usize,
    m: usize,
    f: usize,
) {
    for bi in 0..b {
        let msk = &mask[bi * m..(bi + 1) * m];
        let cnt = msk.iter().sum::<f32>().max(1.0);
        let rows = &mut y[bi * m * f..(bi + 1) * m * f];
        for j in 0..f {
            let mut mean = 0f32;
            for r in 0..m {
                mean += rows[r * f + j] * msk[r];
            }
            mean /= cnt;
            let mut var = 0f32;
            for r in 0..m {
                let d = rows[r * f + j] - mean;
                var += d * d * msk[r];
            }
            var /= cnt;
            let inv = 1.0 / (var + EPS).sqrt();
            for r in 0..m {
                let hn = (rows[r * f + j] - mean) * inv;
                let v = (gamma[j] * hn + beta[j]) * msk[r];
                rows[r * f + j] = v.max(0.0);
            }
        }
    }
}

/// Mean loss over the batch — matches `model.loss_fn`.
pub fn loss(cfg: &ModelConfig, logits: &[f32], labels: &[f32], batch: usize) -> f32 {
    let n = cfg.n_out;
    assert_eq!(logits.len(), batch * n);
    assert_eq!(labels.len(), batch * n);
    let mut total = 0f64;
    match cfg.loss {
        LossKind::Bce => {
            for i in 0..batch * n {
                let (x, y) = (logits[i], labels[i]);
                // -(y*logsig(x) + (1-y)*logsig(-x)), stable.
                total += (-(y * log_sigmoid(x) + (1.0 - y) * log_sigmoid(-x))) as f64;
            }
        }
        LossKind::Softmax => {
            for bi in 0..batch {
                let row = &logits[bi * n..(bi + 1) * n];
                let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                let lse = max + row.iter().map(|&v| (v - max).exp()).sum::<f32>().ln();
                for j in 0..n {
                    total += (labels[bi * n + j] * (lse - row[j])) as f64;
                }
            }
        }
    }
    (total / batch as f64) as f32
}

fn log_sigmoid(x: f32) -> f32 {
    if x >= 0.0 {
        -(-x).exp().ln_1p()
    } else {
        x - x.exp().ln_1p()
    }
}

/// Prediction accuracy (argmax for softmax; 0.5-threshold per task for
/// BCE, averaged over tasks).
pub fn accuracy(cfg: &ModelConfig, logits: &[f32], labels: &[f32], batch: usize) -> f64 {
    let n = cfg.n_out;
    match cfg.loss {
        LossKind::Softmax => {
            let mut correct = 0usize;
            for bi in 0..batch {
                let row = &logits[bi * n..(bi + 1) * n];
                let pred = argmax(row);
                let truth = argmax(&labels[bi * n..(bi + 1) * n]);
                if pred == truth {
                    correct += 1;
                }
            }
            correct as f64 / batch as f64
        }
        LossKind::Bce => {
            let mut correct = 0usize;
            for i in 0..batch * n {
                let pred = logits[i] > 0.0;
                if pred == (labels[i] > 0.5) {
                    correct += 1;
                }
            }
            correct as f64 / (batch * n) as f64
        }
    }
}

fn argmax(xs: &[f32]) -> usize {
    xs.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap_or(0)
}

/// Rank-based (Mann–Whitney) ROC-AUC of one score column against
/// binary labels (`> 0.5` is positive). Ties share the average rank.
/// `None` when either class is absent — the task carries no ranking
/// signal. Threshold-free, so it is the right metric for the
/// reduced-precision accuracy-delta assertions: quantization shifts
/// logits slightly, and AUC moves only when an ordering flips
/// (DESIGN.md §16).
pub fn auc(scores: &[f32], labels: &[f32]) -> Option<f64> {
    assert_eq!(scores.len(), labels.len());
    let mut idx: Vec<usize> = (0..scores.len()).collect();
    idx.sort_by(|&a, &b| scores[a].partial_cmp(&scores[b]).unwrap());
    let mut rank_sum_pos = 0f64;
    let mut n_pos = 0usize;
    let mut i = 0usize;
    while i < idx.len() {
        // Tie group [i, j): every member takes the average rank.
        let mut j = i + 1;
        while j < idx.len() && scores[idx[j]] == scores[idx[i]] {
            j += 1;
        }
        let avg_rank = (i + 1 + j) as f64 / 2.0; // mean of ranks i+1 ..= j
        for &k in &idx[i..j] {
            if labels[k] > 0.5 {
                rank_sum_pos += avg_rank;
                n_pos += 1;
            }
        }
        i = j;
    }
    let n_neg = scores.len() - n_pos;
    if n_pos == 0 || n_neg == 0 {
        return None;
    }
    Some((rank_sum_pos - (n_pos * (n_pos + 1)) as f64 / 2.0) / (n_pos * n_neg) as f64)
}

/// Macro-averaged AUC over the `n_out` tasks of a `[batch, n_out]`
/// logit block, skipping single-class tasks; `None` if every task is
/// degenerate.
pub fn mean_auc(logits: &[f32], labels: &[f32], batch: usize, n_out: usize) -> Option<f64> {
    assert_eq!(logits.len(), batch * n_out);
    assert_eq!(labels.len(), batch * n_out);
    let mut total = 0f64;
    let mut tasks = 0usize;
    for t in 0..n_out {
        let s: Vec<f32> = (0..batch).map(|b| logits[b * n_out + t]).collect();
        let l: Vec<f32> = (0..batch).map(|b| labels[b * n_out + t]).collect();
        if let Some(a) = auc(&s, &l) {
            total += a;
            tasks += 1;
        }
    }
    (tasks > 0).then(|| total / tasks as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::dataset::{Dataset, DatasetKind};
    use crate::util::json::parse;
    use crate::util::rng::Rng;

    fn tox_like_cfg() -> ModelConfig {
        // Geometry matching graph::dataset Tox21 packing (CH=4, F0=16).
        let j = parse(
            r#"{
 "name": "toxtest", "max_nodes": 50, "feat_dim": 16, "channels": 4,
 "hidden": [8, 8], "n_out": 12, "loss": "bce", "nnz_cap": 128, "ell_width": 12,
 "train_batch": 4, "infer_batch": 4, "n_params": 1030,
 "params": [
  {"name": "conv0.w", "shape": [4, 16, 8], "offset": 0, "size": 512},
  {"name": "conv0.b", "shape": [4, 8], "offset": 512, "size": 32},
  {"name": "conv0.gamma", "shape": [8], "offset": 544, "size": 8},
  {"name": "conv0.beta", "shape": [8], "offset": 552, "size": 8},
  {"name": "conv1.w", "shape": [4, 8, 8], "offset": 560, "size": 256},
  {"name": "conv1.b", "shape": [4, 8], "offset": 816, "size": 32},
  {"name": "conv1.gamma", "shape": [8], "offset": 848, "size": 8},
  {"name": "conv1.beta", "shape": [8], "offset": 856, "size": 8},
  {"name": "readout.w", "shape": [8, 12], "offset": 864, "size": 96},
  {"name": "readout.b", "shape": [12], "offset": 960, "size": 12}
 ],
 "init_file": "none.bin",
 "artifact_fwd_infer": "x", "artifact_fwd_train": "x",
 "artifact_fwd_sample": "x", "artifact_train_step": "x",
 "artifact_grad_sample": "x", "artifact_apply_sgd": "x"
}"#,
        )
        .unwrap();
        let mut c = ModelConfig::from_json(&j).unwrap();
        c.n_params = 972;
        c.validate().unwrap();
        c
    }

    fn random_params(cfg: &ModelConfig, seed: u64) -> ParamSet {
        let mut rng = Rng::new(seed);
        let mut ps = ParamSet::zeros(cfg);
        for p in &cfg.params {
            for i in 0..p.size {
                ps.data[p.offset + i] = if p.name.ends_with(".gamma") {
                    1.0
                } else if p.name.ends_with(".w") {
                    rng.normal() * 0.3
                } else {
                    0.0
                };
            }
        }
        ps
    }

    #[test]
    fn forward_shapes_and_finite() {
        let cfg = tox_like_cfg();
        let ps = random_params(&cfg, 1);
        let d = Dataset::generate(DatasetKind::Tox21, 8, 1);
        let mb = d.pack_batch(&[0, 1, 2, 3], 50, 12).unwrap();
        let logits = forward(&cfg, &ps, &mb).unwrap();
        assert_eq!(logits.len(), 4 * 12);
        assert!(logits.iter().all(|v| v.is_finite()));
        let l = loss(&cfg, &logits, &mb.labels, 4);
        assert!(l.is_finite() && l > 0.0);
    }

    #[test]
    fn batched_equals_per_sample() {
        // The decomposability property the non-batched dispatch relies on.
        let cfg = tox_like_cfg();
        let ps = random_params(&cfg, 2);
        let d = Dataset::generate(DatasetKind::Tox21, 6, 2);
        let mb = d.pack_batch(&[0, 2, 4], 50, 12).unwrap();
        let batched = forward(&cfg, &ps, &mb).unwrap();
        for bi in 0..3 {
            let one = forward(&cfg, &ps, &mb.single(bi)).unwrap();
            for j in 0..12 {
                let (a, b) = (batched[bi * 12 + j], one[j]);
                assert!(
                    (a - b).abs() <= 1e-5 + 1e-5 * b.abs(),
                    "sample {bi} logit {j}: batched {a} vs single {b}"
                );
            }
        }
    }

    #[test]
    fn forward_parallel_matches_serial_bitwise() {
        // Samples are independent, so the executor's thread count must
        // not change a single bit of the output.
        let cfg = tox_like_cfg();
        let ps = random_params(&cfg, 5);
        let d = Dataset::generate(DatasetKind::Tox21, 12, 4);
        let idx: Vec<usize> = (0..12).collect();
        let mb = d.pack_batch(&idx, 50, 12).unwrap();
        let serial = forward(&cfg, &ps, &mb).unwrap();
        for threads in [2, 8] {
            let par = forward_with(&cfg, &ps, &mb, &Executor::new(threads)).unwrap();
            assert_eq!(serial, par, "threads={threads}");
        }
    }

    #[test]
    fn zero_params_give_uniform_logits() {
        let cfg = tox_like_cfg();
        let ps = ParamSet::zeros(&cfg);
        let d = Dataset::generate(DatasetKind::Tox21, 4, 3);
        let mb = d.pack_batch(&[0, 1], 50, 12).unwrap();
        let logits = forward(&cfg, &ps, &mb).unwrap();
        assert!(logits.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn softmax_loss_of_uniform_is_ln_classes() {
        let j = r#"{
 "name": "r", "max_nodes": 4, "feat_dim": 2, "channels": 1, "hidden": [2],
 "n_out": 100, "loss": "softmax", "nnz_cap": 4, "ell_width": 3, "train_batch": 2,
 "infer_batch": 2, "n_params": 0, "params": [], "init_file": "x",
 "artifact_fwd_infer": "x", "artifact_fwd_train": "x",
 "artifact_fwd_sample": "x", "artifact_train_step": "x",
 "artifact_grad_sample": "x", "artifact_apply_sgd": "x"}"#;
        let cfg = ModelConfig::from_json(&parse(j).unwrap()).unwrap();
        let logits = vec![0f32; 2 * 100];
        let mut labels = vec![0f32; 2 * 100];
        labels[3] = 1.0;
        labels[100 + 77] = 1.0;
        let l = loss(&cfg, &logits, &labels, 2);
        assert!((l - (100f32).ln()).abs() < 1e-4, "loss {l}");
        assert!(accuracy(&cfg, &logits, &labels, 2) <= 1.0);
    }

    #[test]
    fn auc_ranks_ties_and_degenerate_cases() {
        // Perfect ranking, inverted ranking, all-tied scores, and
        // single-class columns.
        assert_eq!(auc(&[0.1, 0.9, 0.2, 0.8], &[0.0, 1.0, 0.0, 1.0]), Some(1.0));
        assert_eq!(auc(&[0.9, 0.1, 0.8, 0.2], &[0.0, 1.0, 0.0, 1.0]), Some(0.0));
        assert_eq!(auc(&[0.5, 0.5, 0.5, 0.5], &[0.0, 1.0, 0.0, 1.0]), Some(0.5));
        assert_eq!(auc(&[0.1, 0.2], &[1.0, 1.0]), None);
        // One inversion among 2 pos * 2 neg pairs: AUC = 3/4.
        assert_eq!(auc(&[0.4, 0.3, 0.2, 0.1], &[1.0, 0.0, 1.0, 0.0]), Some(0.75));
        // mean_auc skips the degenerate task and averages the rest.
        let logits = [0.1f32, 0.0, 0.9, 0.0, 0.2, 0.0, 0.8, 0.0];
        let labels = [0f32, 1.0, 1.0, 1.0, 0.0, 1.0, 1.0, 1.0];
        assert_eq!(mean_auc(&logits, &labels, 4, 2), Some(1.0));
    }

    #[test]
    fn quantized_forward_tracks_f32_logits() {
        // The reduced-precision forward (quantized adjacency +
        // bf16-rounded weights) must track the f32 logits closely, and
        // its planned replay must be bit-identical to the direct path.
        let cfg = tox_like_cfg();
        let ps = random_params(&cfg, 7);
        let d = Dataset::generate(DatasetKind::Tox21, 10, 5);
        let idx: Vec<usize> = (0..8).collect();
        let mb = d.pack_batch(&idx, 50, 12).unwrap();
        let exec = Executor::serial();
        let f32_logits = forward(&cfg, &ps, &mb).unwrap();
        for (dtype, tol) in [(DType::Bf16, 0.05f32), (DType::Int8, 0.25f32)] {
            let got = forward_quantized(&cfg, &ps, &mb, &exec, dtype).unwrap();
            assert_eq!(got.len(), f32_logits.len());
            for (g, w) in got.iter().zip(&f32_logits) {
                assert!((g - w).abs() <= tol, "{dtype}: {g} vs {w}");
            }
            // Planned replay: same numbers, bit for bit.
            let th = AutoThresholds::default();
            let plan = plan_forward_dtype(&cfg, &mb, &th, dtype).unwrap();
            assert_ne!(plan.key, forward_plan_key(&cfg, &mb), "{dtype} shares the f32 key");
            let ps16 = ps.round_to_bf16();
            let w_rep = build_w_rep(&cfg, &ps16).unwrap();
            let quant = quantize_batch(&mb, dtype).unwrap();
            let mut ws = Workspace::default();
            ws.prepare(&plan);
            let planned =
                forward_planned_quant(&cfg, &ps16, &mb, &quant, &exec, &w_rep, &plan, &mut ws)
                    .unwrap();
            assert_eq!(planned, got, "{dtype} planned vs direct");
            // An f32 plan must refuse to replay a quantized request.
            let f32_plan = plan_forward(&cfg, &mb, &th).unwrap();
            assert!(forward_planned_quant(
                &cfg, &ps16, &mb, &quant, &exec, &w_rep, &f32_plan, &mut ws
            )
            .is_err());
        }
    }
}
