//! Pure-rust ChemGCN forward + loss — mirrors `python/compile/model.py`
//! operation-for-operation. Used by the integration tests as the
//! cross-language oracle for the PJRT artifact executions, and by the
//! examples to report accuracy without a device round-trip. The
//! matching backward pass lives in [`super::backward`] (DESIGN.md §8)
//! and reuses this module's layer helpers so forward and gradient can
//! never drift apart.
//!
//! All multiplication routes through the batched-SpMM engine
//! ([`crate::sparse::engine`]): the per-channel `X @ W` feature
//! transform and the readout head dispatch [`GemmKernel`]s, the
//! adjacency SpMM dispatches an [`EllKernel`] channel view — so one
//! engine dispatch covers the whole batch where the pre-engine code
//! iterated (sample, channel) pairs inline. Iteration order inside the
//! kernels matches the old inlined loops, so logits are bit-identical.
//!
//! The readout head multiplies against a tiled copy of `readout.w`
//! ([`build_w_rep`], `[M*fin, n_out]`, ~10 MB on reaction100). It is a
//! pure function of the parameters, so the coordinator's host paths
//! cache it per [`ParamSet`] and pass it to [`forward_with_readout`];
//! [`forward_with`] rebuilds it every call for one-shot users.

use super::config::{LossKind, ModelConfig};
use super::params::ParamSet;
use crate::graph::dataset::ModelBatch;
use crate::sparse::engine::{EllKernel, Executor, GemmKernel, Rhs};

/// GraphNorm variance stabilizer — matches `model.py`'s `eps`.
pub(crate) const EPS: f32 = 1e-5;

/// Forward pass on the serial executor: returns logits `[B, n_out]`
/// (row-major).
pub fn forward(cfg: &ModelConfig, ps: &ParamSet, mb: &ModelBatch) -> anyhow::Result<Vec<f32>> {
    forward_with(cfg, ps, mb, &Executor::serial())
}

/// Forward pass with an explicit engine executor (the coordinator's
/// host dispatch paths pass a handle on their long-lived worker pool,
/// so one pool spans every dispatch of a forward or train step).
/// Results are bit-identical for every thread count and steal order
/// (DESIGN.md §9).
pub fn forward_with(
    cfg: &ModelConfig,
    ps: &ParamSet,
    mb: &ModelBatch,
    exec: &Executor,
) -> anyhow::Result<Vec<f32>> {
    let w_rep = build_w_rep(cfg, ps)?;
    forward_with_readout(cfg, ps, mb, exec, &w_rep)
}

/// The tiled readout weight: `readout.w` (`[fin, n_out]`) repeated
/// `max_nodes` times into `[M*fin, n_out]`, so the sum-pool readout is
/// one engine dispatch over `[1, M*fin]` row views. Pure function of
/// the parameters — cache it per [`ParamSet`] and invalidate on every
/// parameter update (the coordinator's host paths do).
pub fn build_w_rep(cfg: &ModelConfig, ps: &ParamSet) -> anyhow::Result<Vec<f32>> {
    let fin = *cfg.hidden.last().unwrap_or(&cfg.feat_dim);
    let w_out = ps.slice(cfg, "readout.w")?; // [fin, n_out]
    let mut w_rep = vec![0f32; cfg.max_nodes * fin * cfg.n_out];
    for row in w_rep.chunks_mut(fin * cfg.n_out) {
        row.copy_from_slice(w_out);
    }
    Ok(w_rep)
}

/// Forward pass against a caller-provided tiled readout weight (from
/// [`build_w_rep`]); bit-identical to [`forward_with`], minus the
/// per-call tiling cost.
pub fn forward_with_readout(
    cfg: &ModelConfig,
    ps: &ParamSet,
    mb: &ModelBatch,
    exec: &Executor,
    w_rep: &[f32],
) -> anyhow::Result<Vec<f32>> {
    check_batch(cfg, mb)?;
    let mut h = mb.x.clone(); // [B, M, fin]
    let mut fin = cfg.feat_dim;
    for (li, &fout) in cfg.hidden.iter().enumerate() {
        let gamma = ps.slice(cfg, &format!("conv{li}.gamma"))?;
        let beta = ps.slice(cfg, &format!("conv{li}.beta"))?;
        let mut y = conv_layer(cfg, ps, li, fin, fout, &h, mb, exec)?;
        // GraphNorm + ReLU (+ re-mask).
        graph_norm_relu(&mut y, &mb.mask, gamma, beta, mb.batch, cfg.max_nodes, fout);
        h = y;
        fin = fout;
    }
    readout(cfg, ps, &h, fin, mb.batch, exec, w_rep)
}

/// Shared geometry validation for forward and backward entry points.
pub(crate) fn check_batch(cfg: &ModelConfig, mb: &ModelBatch) -> anyhow::Result<()> {
    anyhow::ensure!(mb.max_nodes == cfg.max_nodes, "node bucket mismatch");
    anyhow::ensure!(mb.feat_dim == cfg.feat_dim, "feature width mismatch");
    anyhow::ensure!(mb.channels == cfg.channels, "channel count mismatch");
    Ok(())
}

/// One graph-conv layer up to (not including) GraphNorm: returns the
/// pre-normalization accumulator `y[b,m,o] = Σ_ch A[b,ch] @ (X[b] @
/// W[ch] + bias[ch])`. Two engine dispatches per channel, each covering
/// the whole batch.
#[allow(clippy::too_many_arguments)]
pub(crate) fn conv_layer(
    cfg: &ModelConfig,
    ps: &ParamSet,
    li: usize,
    fin: usize,
    fout: usize,
    h: &[f32],
    mb: &ModelBatch,
    exec: &Executor,
) -> anyhow::Result<Vec<f32>> {
    let b = mb.batch;
    let m = cfg.max_nodes;
    let w = ps.slice(cfg, &format!("conv{li}.w"))?; // [CH, fin, fout]
    let bias = ps.slice(cfg, &format!("conv{li}.b"))?; // [CH, fout]
    let mut y = vec![0f32; b * m * fout];
    let mut u = vec![0f32; b * m * fout];
    for ch in 0..cfg.channels {
        let w_ch = &w[ch * fin * fout..(ch + 1) * fin * fout];
        let b_ch = &bias[ch * fout..(ch + 1) * fout];
        // U = X @ W[ch] + bias[ch]   (MatMul + Add, Fig. 6):
        // bias-prefill, then accumulate through the dense backend.
        for row in u.chunks_mut(fout) {
            row.copy_from_slice(b_ch);
        }
        let xw = GemmKernel::new(h, b, m, fin);
        exec.dispatch(&xw, Rhs::Shared(w_ch), fout, &mut u)?;
        // y += A[ch] @ U             (SpMM + ElementWiseAdd).
        let adj = EllKernel::channel(mb, ch);
        exec.dispatch(&adj, Rhs::PerSample(&u), fout, &mut y)?;
    }
    Ok(y)
}

/// Sum-pool readout + dense head: logits[b] = b_out + Σ_r h[b,r,:] @ W.
/// Viewing h[b] as [1, m*fin] against the tiled weight keeps the
/// original (r, k) accumulation order while routing through the engine.
pub(crate) fn readout(
    cfg: &ModelConfig,
    ps: &ParamSet,
    h: &[f32],
    fin: usize,
    b: usize,
    exec: &Executor,
    w_rep: &[f32],
) -> anyhow::Result<Vec<f32>> {
    let m = cfg.max_nodes;
    let n_out = cfg.n_out;
    anyhow::ensure!(
        w_rep.len() == m * fin * n_out,
        "w_rep length {} != {m} * {fin} * {n_out} (stale readout cache?)",
        w_rep.len()
    );
    let b_out = ps.slice(cfg, "readout.b")?;
    let mut logits = vec![0f32; b * n_out];
    for row in logits.chunks_mut(n_out) {
        row.copy_from_slice(b_out);
    }
    let readout = GemmKernel::new(h, b, 1, m * fin);
    exec.dispatch(&readout, Rhs::Shared(w_rep), n_out, &mut logits)?;
    Ok(logits)
}

/// In-place per-graph masked normalization + affine + ReLU + re-mask —
/// matches `model.graph_norm` followed by `jax.nn.relu`.
pub(crate) fn graph_norm_relu(
    y: &mut [f32],
    mask: &[f32],
    gamma: &[f32],
    beta: &[f32],
    b: usize,
    m: usize,
    f: usize,
) {
    for bi in 0..b {
        let msk = &mask[bi * m..(bi + 1) * m];
        let cnt = msk.iter().sum::<f32>().max(1.0);
        let rows = &mut y[bi * m * f..(bi + 1) * m * f];
        for j in 0..f {
            let mut mean = 0f32;
            for r in 0..m {
                mean += rows[r * f + j] * msk[r];
            }
            mean /= cnt;
            let mut var = 0f32;
            for r in 0..m {
                let d = rows[r * f + j] - mean;
                var += d * d * msk[r];
            }
            var /= cnt;
            let inv = 1.0 / (var + EPS).sqrt();
            for r in 0..m {
                let hn = (rows[r * f + j] - mean) * inv;
                let v = (gamma[j] * hn + beta[j]) * msk[r];
                rows[r * f + j] = v.max(0.0);
            }
        }
    }
}

/// Mean loss over the batch — matches `model.loss_fn`.
pub fn loss(cfg: &ModelConfig, logits: &[f32], labels: &[f32], batch: usize) -> f32 {
    let n = cfg.n_out;
    assert_eq!(logits.len(), batch * n);
    assert_eq!(labels.len(), batch * n);
    let mut total = 0f64;
    match cfg.loss {
        LossKind::Bce => {
            for i in 0..batch * n {
                let (x, y) = (logits[i], labels[i]);
                // -(y*logsig(x) + (1-y)*logsig(-x)), stable.
                total += (-(y * log_sigmoid(x) + (1.0 - y) * log_sigmoid(-x))) as f64;
            }
        }
        LossKind::Softmax => {
            for bi in 0..batch {
                let row = &logits[bi * n..(bi + 1) * n];
                let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                let lse = max + row.iter().map(|&v| (v - max).exp()).sum::<f32>().ln();
                for j in 0..n {
                    total += (labels[bi * n + j] * (lse - row[j])) as f64;
                }
            }
        }
    }
    (total / batch as f64) as f32
}

fn log_sigmoid(x: f32) -> f32 {
    if x >= 0.0 {
        -(-x).exp().ln_1p()
    } else {
        x - x.exp().ln_1p()
    }
}

/// Prediction accuracy (argmax for softmax; 0.5-threshold per task for
/// BCE, averaged over tasks).
pub fn accuracy(cfg: &ModelConfig, logits: &[f32], labels: &[f32], batch: usize) -> f64 {
    let n = cfg.n_out;
    match cfg.loss {
        LossKind::Softmax => {
            let mut correct = 0usize;
            for bi in 0..batch {
                let row = &logits[bi * n..(bi + 1) * n];
                let pred = argmax(row);
                let truth = argmax(&labels[bi * n..(bi + 1) * n]);
                if pred == truth {
                    correct += 1;
                }
            }
            correct as f64 / batch as f64
        }
        LossKind::Bce => {
            let mut correct = 0usize;
            for i in 0..batch * n {
                let pred = logits[i] > 0.0;
                if pred == (labels[i] > 0.5) {
                    correct += 1;
                }
            }
            correct as f64 / (batch * n) as f64
        }
    }
}

fn argmax(xs: &[f32]) -> usize {
    xs.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::dataset::{Dataset, DatasetKind};
    use crate::util::json::parse;
    use crate::util::rng::Rng;

    fn tox_like_cfg() -> ModelConfig {
        // Geometry matching graph::dataset Tox21 packing (CH=4, F0=16).
        let j = parse(
            r#"{
 "name": "toxtest", "max_nodes": 50, "feat_dim": 16, "channels": 4,
 "hidden": [8, 8], "n_out": 12, "loss": "bce", "nnz_cap": 128, "ell_width": 12,
 "train_batch": 4, "infer_batch": 4, "n_params": 1030,
 "params": [
  {"name": "conv0.w", "shape": [4, 16, 8], "offset": 0, "size": 512},
  {"name": "conv0.b", "shape": [4, 8], "offset": 512, "size": 32},
  {"name": "conv0.gamma", "shape": [8], "offset": 544, "size": 8},
  {"name": "conv0.beta", "shape": [8], "offset": 552, "size": 8},
  {"name": "conv1.w", "shape": [4, 8, 8], "offset": 560, "size": 256},
  {"name": "conv1.b", "shape": [4, 8], "offset": 816, "size": 32},
  {"name": "conv1.gamma", "shape": [8], "offset": 848, "size": 8},
  {"name": "conv1.beta", "shape": [8], "offset": 856, "size": 8},
  {"name": "readout.w", "shape": [8, 12], "offset": 864, "size": 96},
  {"name": "readout.b", "shape": [12], "offset": 960, "size": 12}
 ],
 "init_file": "none.bin",
 "artifact_fwd_infer": "x", "artifact_fwd_train": "x",
 "artifact_fwd_sample": "x", "artifact_train_step": "x",
 "artifact_grad_sample": "x", "artifact_apply_sgd": "x"
}"#,
        )
        .unwrap();
        let mut c = ModelConfig::from_json(&j).unwrap();
        c.n_params = 972;
        c.validate().unwrap();
        c
    }

    fn random_params(cfg: &ModelConfig, seed: u64) -> ParamSet {
        let mut rng = Rng::new(seed);
        let mut ps = ParamSet::zeros(cfg);
        for p in &cfg.params {
            for i in 0..p.size {
                ps.data[p.offset + i] = if p.name.ends_with(".gamma") {
                    1.0
                } else if p.name.ends_with(".w") {
                    rng.normal() * 0.3
                } else {
                    0.0
                };
            }
        }
        ps
    }

    #[test]
    fn forward_shapes_and_finite() {
        let cfg = tox_like_cfg();
        let ps = random_params(&cfg, 1);
        let d = Dataset::generate(DatasetKind::Tox21, 8, 1);
        let mb = d.pack_batch(&[0, 1, 2, 3], 50, 12).unwrap();
        let logits = forward(&cfg, &ps, &mb).unwrap();
        assert_eq!(logits.len(), 4 * 12);
        assert!(logits.iter().all(|v| v.is_finite()));
        let l = loss(&cfg, &logits, &mb.labels, 4);
        assert!(l.is_finite() && l > 0.0);
    }

    #[test]
    fn batched_equals_per_sample() {
        // The decomposability property the non-batched dispatch relies on.
        let cfg = tox_like_cfg();
        let ps = random_params(&cfg, 2);
        let d = Dataset::generate(DatasetKind::Tox21, 6, 2);
        let mb = d.pack_batch(&[0, 2, 4], 50, 12).unwrap();
        let batched = forward(&cfg, &ps, &mb).unwrap();
        for bi in 0..3 {
            let one = forward(&cfg, &ps, &mb.single(bi)).unwrap();
            for j in 0..12 {
                let (a, b) = (batched[bi * 12 + j], one[j]);
                assert!(
                    (a - b).abs() <= 1e-5 + 1e-5 * b.abs(),
                    "sample {bi} logit {j}: batched {a} vs single {b}"
                );
            }
        }
    }

    #[test]
    fn forward_parallel_matches_serial_bitwise() {
        // Samples are independent, so the executor's thread count must
        // not change a single bit of the output.
        let cfg = tox_like_cfg();
        let ps = random_params(&cfg, 5);
        let d = Dataset::generate(DatasetKind::Tox21, 12, 4);
        let idx: Vec<usize> = (0..12).collect();
        let mb = d.pack_batch(&idx, 50, 12).unwrap();
        let serial = forward(&cfg, &ps, &mb).unwrap();
        for threads in [2, 8] {
            let par = forward_with(&cfg, &ps, &mb, &Executor::new(threads)).unwrap();
            assert_eq!(serial, par, "threads={threads}");
        }
    }

    #[test]
    fn zero_params_give_uniform_logits() {
        let cfg = tox_like_cfg();
        let ps = ParamSet::zeros(&cfg);
        let d = Dataset::generate(DatasetKind::Tox21, 4, 3);
        let mb = d.pack_batch(&[0, 1], 50, 12).unwrap();
        let logits = forward(&cfg, &ps, &mb).unwrap();
        assert!(logits.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn softmax_loss_of_uniform_is_ln_classes() {
        let j = r#"{
 "name": "r", "max_nodes": 4, "feat_dim": 2, "channels": 1, "hidden": [2],
 "n_out": 100, "loss": "softmax", "nnz_cap": 4, "ell_width": 3, "train_batch": 2,
 "infer_batch": 2, "n_params": 0, "params": [], "init_file": "x",
 "artifact_fwd_infer": "x", "artifact_fwd_train": "x",
 "artifact_fwd_sample": "x", "artifact_train_step": "x",
 "artifact_grad_sample": "x", "artifact_apply_sgd": "x"}"#;
        let cfg = ModelConfig::from_json(&parse(j).unwrap()).unwrap();
        let logits = vec![0f32; 2 * 100];
        let mut labels = vec![0f32; 2 * 100];
        labels[3] = 1.0;
        labels[100 + 77] = 1.0;
        let l = loss(&cfg, &logits, &labels, 2);
        assert!((l - (100f32).ln()).abs() < 1e-4, "loss {l}");
        assert!(accuracy(&cfg, &logits, &labels, 2) <= 1.0);
    }
}
