//! ChemGCN model definition on the rust side (S4 in DESIGN.md).
//!
//! [`config`] parses the model geometry + parameter layout from
//! `artifacts/manifest.json` (the ABI produced by `python -m
//! compile.aot`); [`params`] holds the flat parameter vector and loads
//! the AOT-dumped initial values; [`reference`] is a pure-rust forward
//! + loss that mirrors `python/compile/model.py` *exactly* — it is the
//! cross-language oracle the integration tests compare PJRT artifact
//! executions against.

pub mod config;
pub mod params;
pub mod reference;

pub use config::{LossKind, ModelConfig, ParamSpec};
pub use params::ParamSet;
