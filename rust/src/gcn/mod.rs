//! ChemGCN model definition on the rust side (S4 in DESIGN.md).
//!
//! [`config`] parses the model geometry + parameter layout from
//! `artifacts/manifest.json` (the ABI produced by `python -m
//! compile.aot`); [`params`] holds the flat parameter vector and loads
//! the AOT-dumped initial values; [`reference`] is a pure-rust forward
//! + loss that mirrors `python/compile/model.py` *exactly* — it is the
//! cross-language oracle the integration tests compare PJRT artifact
//! executions against; [`backward`] is its gradient twin (DESIGN.md
//! §8): every backward matmul is a batched-SpMM engine dispatch, and
//! the result is checked against central finite differences in
//! `tests/grad_check.rs`.
//!
//! Forward + gradient round-trip, artifact-free:
//!
//! ```
//! use bspmm::gcn::{backward, reference, ModelConfig, ParamSet};
//! use bspmm::graph::dataset::{Dataset, DatasetKind};
//!
//! let cfg = ModelConfig::synthetic("tox21")?;
//! let ps = ParamSet::random_init(&cfg, 7);
//! let data = Dataset::generate(DatasetKind::Tox21, 4, 1);
//! let mb = data.pack_batch(&[0, 1], cfg.max_nodes, cfg.ell_width)?;
//! let logits = reference::forward(&cfg, &ps, &mb)?;
//! let res = backward::grad(&cfg, &ps, &mb)?;
//! assert_eq!(logits.len(), 2 * cfg.n_out);
//! assert_eq!(res.grads.data.len(), cfg.n_params);
//! # Ok::<(), anyhow::Error>(())
//! ```

pub mod backward;
pub mod config;
pub mod params;
pub mod reference;
pub mod sampler;

pub use config::{LossKind, ModelConfig, ParamSpec};
pub use params::ParamSet;
pub use sampler::NeighborSampler;
