//! Model geometry and parameter layout, parsed from the manifest.

use crate::util::json::Json;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LossKind {
    /// Multi-task binary cross-entropy (Tox21: 12 tasks).
    Bce,
    /// Softmax cross-entropy over one-hot labels (Reaction100).
    Softmax,
}

/// One entry of the flat parameter vector.
#[derive(Clone, Debug, PartialEq)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub size: usize,
}

/// Geometry + artifact names for one model (one `models[]` manifest
/// entry). Field meanings follow `python/compile/model.py::GcnConfig`.
#[derive(Clone, Debug)]
pub struct ModelConfig {
    pub name: String,
    pub max_nodes: usize,
    pub feat_dim: usize,
    pub channels: usize,
    pub hidden: Vec<usize>,
    pub n_out: usize,
    pub loss: LossKind,
    pub nnz_cap: usize,
    pub ell_width: usize,
    pub train_batch: usize,
    pub infer_batch: usize,
    pub params: Vec<ParamSpec>,
    pub n_params: usize,
    pub init_file: String,
    pub artifact_fwd_infer: String,
    pub artifact_fwd_train: String,
    pub artifact_fwd_sample: String,
    pub artifact_train_step: String,
    pub artifact_grad_sample: String,
    pub artifact_apply_sgd: String,
}

impl ModelConfig {
    pub fn from_json(j: &Json) -> anyhow::Result<ModelConfig> {
        let loss = match j.req_str("loss")? {
            "bce" => LossKind::Bce,
            "softmax" => LossKind::Softmax,
            other => anyhow::bail!("unknown loss kind '{other}'"),
        };
        let params = j
            .req_arr("params")?
            .iter()
            .map(|p| {
                Ok(ParamSpec {
                    name: p.req_str("name")?.to_string(),
                    shape: p
                        .req_arr("shape")?
                        .iter()
                        .map(|d| d.as_usize().unwrap_or(0))
                        .collect(),
                    offset: p.req_usize("offset")?,
                    size: p.req_usize("size")?,
                })
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        let hidden = j
            .req_arr("hidden")?
            .iter()
            .map(|d| d.as_usize().unwrap_or(0))
            .collect();
        Ok(ModelConfig {
            name: j.req_str("name")?.to_string(),
            max_nodes: j.req_usize("max_nodes")?,
            feat_dim: j.req_usize("feat_dim")?,
            channels: j.req_usize("channels")?,
            hidden,
            n_out: j.req_usize("n_out")?,
            loss,
            nnz_cap: j.req_usize("nnz_cap")?,
            ell_width: j.req_usize("ell_width")?,
            train_batch: j.req_usize("train_batch")?,
            infer_batch: j.req_usize("infer_batch")?,
            params,
            n_params: j.req_usize("n_params")?,
            init_file: j.req_str("init_file")?.to_string(),
            artifact_fwd_infer: j.req_str("artifact_fwd_infer")?.to_string(),
            artifact_fwd_train: j.req_str("artifact_fwd_train")?.to_string(),
            artifact_fwd_sample: j.req_str("artifact_fwd_sample")?.to_string(),
            artifact_train_step: j.req_str("artifact_train_step")?.to_string(),
            artifact_grad_sample: j.req_str("artifact_grad_sample")?.to_string(),
            artifact_apply_sgd: j.req_str("artifact_apply_sgd")?.to_string(),
        })
    }

    /// Manifest-free config for the named model, with the geometry
    /// `python/compile/model.py` bakes into the AOT artifacts. This is
    /// what the coordinator's host-engine dispatch path runs on when no
    /// artifacts directory exists (DESIGN.md §Substitutions): same
    /// model, parameters initialized in-process instead of loaded from
    /// the AOT init blob.
    pub fn synthetic(name: &str) -> anyhow::Result<ModelConfig> {
        // (hidden, n_out, loss, train_batch, max_nodes, channels,
        // ell_width).  tox21 / reaction100 keep the molecule-tier
        // geometry model.py bakes into the AOT artifacts; "largegraph"
        // is the engine-only large-graph tier (DESIGN.md §12): one
        // adjacency channel, subgraphs neighbor-sampled from a power-law
        // graph by `gcn::sampler` — it has no AOT twin.
        let (hidden, n_out, loss, train_batch, max_nodes, channels, ell_width): (
            Vec<usize>,
            usize,
            LossKind,
            usize,
            usize,
            usize,
            usize,
        ) = match name {
            "tox21" => (vec![64, 64], 12, LossKind::Bce, 50, 50, 4, 12),
            "reaction100" => (vec![512, 512, 512], 100, LossKind::Softmax, 100, 50, 4, 12),
            "largegraph" => (vec![32, 32], 8, LossKind::Softmax, 32, 64, 1, 16),
            other => anyhow::bail!("no synthetic model config for '{other}'"),
        };
        let (feat_dim, n_outs) = (16usize, n_out);
        // Parameter layout mirrors model.py::param_specs exactly.
        let mut params = Vec::new();
        let mut off = 0usize;
        let mut push = |params: &mut Vec<ParamSpec>, name: String, shape: Vec<usize>| {
            let size = shape.iter().product::<usize>();
            params.push(ParamSpec {
                name,
                shape,
                offset: off,
                size,
            });
            off += size;
        };
        let mut fin = feat_dim;
        for (i, &fout) in hidden.iter().enumerate() {
            push(&mut params, format!("conv{i}.w"), vec![channels, fin, fout]);
            push(&mut params, format!("conv{i}.b"), vec![channels, fout]);
            push(&mut params, format!("conv{i}.gamma"), vec![fout]);
            push(&mut params, format!("conv{i}.beta"), vec![fout]);
            fin = fout;
        }
        push(&mut params, "readout.w".to_string(), vec![fin, n_outs]);
        push(&mut params, "readout.b".to_string(), vec![n_outs]);
        let n_params = off;
        let cfg = ModelConfig {
            name: name.to_string(),
            max_nodes,
            feat_dim,
            channels,
            hidden,
            n_out: n_outs,
            loss,
            nnz_cap: if channels == 1 { max_nodes * ell_width } else { 128 },
            ell_width,
            train_batch,
            infer_batch: 200,
            params,
            n_params,
            init_file: String::new(),
            artifact_fwd_infer: String::new(),
            artifact_fwd_train: String::new(),
            artifact_fwd_sample: String::new(),
            artifact_train_step: String::new(),
            artifact_grad_sample: String::new(),
            artifact_apply_sgd: String::new(),
        };
        cfg.validate()?;
        Ok(cfg)
    }

    /// Validate the layout is contiguous and ordered (the artifact ABI
    /// depends on it).
    pub fn validate(&self) -> anyhow::Result<()> {
        let mut off = 0;
        for p in &self.params {
            anyhow::ensure!(
                p.offset == off,
                "param {} offset {} != expected {off}",
                p.name,
                p.offset
            );
            anyhow::ensure!(
                p.size == p.shape.iter().product::<usize>(),
                "param {} size/shape mismatch",
                p.name
            );
            off += p.size;
        }
        anyhow::ensure!(off == self.n_params, "n_params {} != sum {off}", self.n_params);
        Ok(())
    }

    pub fn param(&self, name: &str) -> anyhow::Result<&ParamSpec> {
        self.params
            .iter()
            .find(|p| p.name == name)
            .ok_or_else(|| anyhow::anyhow!("no param '{name}'"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::parse;

    fn sample_json() -> Json {
        parse(
            r#"{
 "name": "t", "max_nodes": 8, "feat_dim": 4, "channels": 2,
 "hidden": [8], "n_out": 3, "loss": "softmax", "nnz_cap": 16, "ell_width": 6,
 "train_batch": 4, "infer_batch": 4, "n_params": 107,
 "params": [
   {"name": "conv0.w", "shape": [2, 4, 8], "offset": 0, "size": 64},
   {"name": "conv0.b", "shape": [2, 8], "offset": 64, "size": 16},
   {"name": "conv0.gamma", "shape": [8], "offset": 80, "size": 8},
   {"name": "conv0.beta", "shape": [8], "offset": 88, "size": 8},
   {"name": "readout.w", "shape": [8, 3], "offset": 96, "size": 24},
   {"name": "readout.b", "shape": [3], "offset": 120, "size": 3}
 ],
 "init_file": "t.bin",
 "artifact_fwd_infer": "a", "artifact_fwd_train": "b",
 "artifact_fwd_sample": "c", "artifact_train_step": "d",
 "artifact_grad_sample": "e", "artifact_apply_sgd": "f"
}"#,
        )
        .unwrap()
    }

    #[test]
    fn parses_fields() {
        let c = ModelConfig::from_json(&sample_json()).unwrap();
        assert_eq!(c.hidden, vec![8]);
        assert_eq!(c.loss, LossKind::Softmax);
        assert_eq!(c.param("conv0.b").unwrap().offset, 64);
    }

    #[test]
    fn synthetic_configs_validate() {
        let t = ModelConfig::synthetic("tox21").unwrap();
        assert_eq!(t.hidden, vec![64, 64]);
        assert_eq!(t.loss, LossKind::Bce);
        assert_eq!(t.feat_dim, 16);
        assert_eq!(t.param("conv0.w").unwrap().shape, vec![4, 16, 64]);
        assert_eq!(t.param("readout.w").unwrap().shape, vec![64, 12]);
        let r = ModelConfig::synthetic("reaction100").unwrap();
        assert_eq!(r.hidden.len(), 3);
        assert_eq!(r.loss, LossKind::Softmax);
        assert_eq!((r.max_nodes, r.channels, r.ell_width), (50, 4, 12));
        let g = ModelConfig::synthetic("largegraph").unwrap();
        assert_eq!((g.max_nodes, g.channels, g.ell_width), (64, 1, 16));
        assert_eq!(g.param("conv0.w").unwrap().shape, vec![1, 16, 32]);
        assert_eq!(g.param("readout.w").unwrap().shape, vec![32, 8]);
        assert_eq!(g.loss, LossKind::Softmax);
        assert!(ModelConfig::synthetic("nope").is_err());
    }

    #[test]
    fn validate_catches_gap() {
        let mut c = ModelConfig::from_json(&sample_json()).unwrap();
        // n_params in the fixture is deliberately wrong (107 != 123)
        assert!(c.validate().is_err());
        c.n_params = 123;
        c.validate().unwrap();
        c.params[1].offset = 65;
        assert!(c.validate().is_err());
    }
}
