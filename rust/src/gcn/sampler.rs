//! Neighbor-sampled mini-batching: one giant graph -> a stream of
//! fixed-geometry subgraph batches (DESIGN.md §12).
//!
//! The molecule tier trains on thousands of small independent graphs;
//! the large-graph tier has ONE power-law graph that cannot be fed to
//! the model whole.  GraphSAGE-style neighbor sampling bridges them:
//! each training example is a rooted subgraph grown by breadth-first
//! expansion with a per-node fanout cap, re-indexed locally and packed
//! into the same `ModelBatch` the batched engine and the compiled
//! [`StepPlan`](crate::sparse::engine::StepPlan)s already consume.
//! Because every subgraph has identical geometry (`max_nodes` rows,
//! one `ell_width`-wide adjacency channel), the trainer compiles ONE
//! train plan on the first step and replays it for the rest of the
//! stream — the large graph inherits the plan/execute split for free.
//!
//! Subgraph adjacency is the symmetric-normalized induced edge set:
//! rows keep at most `ell_width - 1` neighbors (edges are dropped
//! symmetrically, so Â stays symmetric), plus a self-loop, with
//! `Â[u][v] = 1 / sqrt(d(u) * d(v))` over *local* degrees.  Node
//! features mirror the molecule featurizer's 16-wide layout: a
//! hash-derived 10-way "element" one-hot, a 5-way log2-global-degree
//! one-hot, and a bias channel.  Labels are a deterministic function
//! of the root's element and degree bucket — both visible in the root
//! row's features, so the stream carries a learnable signal.

use crate::gcn::config::ModelConfig;
use crate::graph::dataset::ModelBatch;
use crate::graph::featurize::FEAT_DIM;
use crate::graph::molecule::N_ELEMENTS;
use crate::sparse::batch::LargeGraphBatch;
use crate::util::rng::{Rng, SplitMix64};

/// Width of the degree one-hot block (mirrors `featurize::DEGREE_CAP`).
const DEGREE_CAP: usize = 5;

/// Deterministic pseudo-element for a global node id — stable across
/// sampler seeds, so a node presents the same features in every
/// subgraph it appears in.
fn node_element(v: usize) -> usize {
    (SplitMix64::new(v as u64 ^ 0x9E37_79B9).next_u64() % N_ELEMENTS as u64) as usize
}

/// log2 bucket of a node's global degree, clamped to the one-hot width:
/// 0 -> isolated, 1 -> deg 1, 2 -> 2..3, 3 -> 4..7, 4 -> 8+.
fn degree_bucket(deg: usize) -> usize {
    ((usize::BITS - deg.leading_zeros()) as usize).min(DEGREE_CAP - 1)
}

/// Streams neighbor-sampled subgraph batches from one [`LargeGraphBatch`].
pub struct NeighborSampler<'g> {
    graph: &'g LargeGraphBatch,
    max_nodes: usize,
    ell_width: usize,
    n_out: usize,
    /// Per-hop expansion fanouts (GraphSAGE-style): level ℓ of the BFS
    /// draws at most `fanouts[ℓ]` fresh neighbors per frontier node,
    /// and expansion stops after `fanouts.len()` hops. Empty = the
    /// legacy schedule (uniform `ell_width - 1`, depth bounded only by
    /// `max_nodes`). The schedule shapes *which* nodes a subgraph
    /// holds, never the packed geometry — every batch still fills
    /// `max_nodes × ell_width`, so the one-plan contract holds.
    fanouts: Vec<usize>,
    rng: Rng,
    /// Global node id -> local index for the sample in flight (-1 =
    /// absent).  Allocated once (O(nodes)); reset via `touched`, so a
    /// sample costs O(subgraph), not O(graph).
    local_of: Vec<i32>,
}

impl<'g> NeighborSampler<'g> {
    pub fn new(
        graph: &'g LargeGraphBatch,
        cfg: &ModelConfig,
        seed: u64,
    ) -> anyhow::Result<NeighborSampler<'g>> {
        Self::build(graph, cfg, Vec::new(), seed)
    }

    /// A sampler with an explicit per-hop fanout schedule: hop ℓ draws
    /// at most `fanouts[ℓ]` fresh neighbors per frontier node, and the
    /// subgraph never reaches past `fanouts.len()` hops from the root.
    /// So a sample holds at most `1 + f0 + f0*f1 + ...` nodes — the
    /// GraphSAGE receptive-field bound — independent of graph degree.
    pub fn with_fanouts(
        graph: &'g LargeGraphBatch,
        cfg: &ModelConfig,
        fanouts: &[usize],
        seed: u64,
    ) -> anyhow::Result<NeighborSampler<'g>> {
        anyhow::ensure!(!fanouts.is_empty(), "fanout schedule must name at least one hop");
        anyhow::ensure!(
            fanouts.iter().all(|&f| f >= 1),
            "every per-hop fanout must be >= 1, got {fanouts:?}"
        );
        Self::build(graph, cfg, fanouts.to_vec(), seed)
    }

    fn build(
        graph: &'g LargeGraphBatch,
        cfg: &ModelConfig,
        fanouts: Vec<usize>,
        seed: u64,
    ) -> anyhow::Result<NeighborSampler<'g>> {
        anyhow::ensure!(
            cfg.channels == 1,
            "neighbor sampling packs one adjacency channel, config has {}",
            cfg.channels
        );
        anyhow::ensure!(
            cfg.feat_dim == FEAT_DIM,
            "sampler features are {FEAT_DIM}-wide, config wants {}",
            cfg.feat_dim
        );
        anyhow::ensure!(cfg.ell_width >= 2, "ell_width must fit self-loop + a neighbor");
        anyhow::ensure!(cfg.max_nodes >= 1, "max_nodes must be positive");
        Ok(NeighborSampler {
            graph,
            max_nodes: cfg.max_nodes,
            ell_width: cfg.ell_width,
            n_out: cfg.n_out,
            fanouts,
            rng: Rng::new(seed),
            local_of: vec![-1; graph.nodes()],
        })
    }

    /// Global degree of `v` excluding the self-loop.
    fn global_degree(&self, v: usize) -> usize {
        let rpt = &self.graph.csr().rpt;
        let row = (rpt[v + 1] - rpt[v]) as usize;
        row.saturating_sub(1)
    }

    /// Sample one batch of subgraphs; geometry is fixed by the config,
    /// so every batch of the same size hits the same compiled plan.
    pub fn next_batch(&mut self, batch: usize) -> anyhow::Result<ModelBatch> {
        anyhow::ensure!(batch > 0, "empty sampled batch");
        let mut mb = ModelBatch::zeros(batch, 1, self.max_nodes, self.ell_width, self.n_out);
        for bi in 0..batch {
            self.fill_sample(&mut mb, bi);
        }
        Ok(mb)
    }

    fn fill_sample(&mut self, mb: &mut ModelBatch, bi: usize) {
        let csr = self.graph.csr();
        let nodes = self.graph.nodes();
        let edge_cap = self.ell_width - 1;

        // --- BFS expansion with per-hop fanout caps -------------------
        let root = self.rng.below(nodes as u64) as usize;
        let mut local: Vec<u32> = vec![root as u32];
        self.local_of[root] = 0;
        let mut lo = 0usize;
        let mut hop = 0usize;
        while lo < local.len() && local.len() < self.max_nodes {
            let fanout = if self.fanouts.is_empty() {
                edge_cap
            } else if hop < self.fanouts.len() {
                self.fanouts[hop]
            } else {
                break; // schedule exhausted: the receptive field ends here
            };
            let hi = local.len();
            for li in lo..hi {
                let v = local[li] as usize;
                let (r0, r1) = (csr.rpt[v] as usize, csr.rpt[v + 1] as usize);
                let row = r1 - r0;
                // Draw up to fanout + 1 distinct slots so a drawn
                // self-loop does not cost a neighbor.
                let take = row.min(fanout + 1);
                let picks = if take == row {
                    (0..row).collect::<Vec<usize>>()
                } else {
                    self.rng.sample_distinct(row, take)
                };
                // A strict per-node cap only under an explicit
                // schedule — the legacy draw can admit one extra node
                // when the self-loop slot went unsampled, and replayed
                // streams must stay bit-stable across versions.
                let fresh_cap = if self.fanouts.is_empty() { usize::MAX } else { fanout };
                let mut fresh = 0usize;
                for off in picks {
                    let c = csr.col_ids[r0 + off] as usize;
                    if c != v && self.local_of[c] < 0 && local.len() < self.max_nodes {
                        if fresh >= fresh_cap {
                            break;
                        }
                        self.local_of[c] = local.len() as i32;
                        local.push(c as u32);
                        fresh += 1;
                    }
                }
                if local.len() >= self.max_nodes {
                    break;
                }
            }
            lo = hi;
            hop += 1;
        }
        let n_local = local.len();

        // --- induced edges, capped symmetrically ----------------------
        // Keep an edge only while BOTH endpoint rows have room, so the
        // adjacency pattern stays symmetric under truncation.
        let mut kept: Vec<Vec<u32>> = vec![Vec::new(); n_local];
        for lu in 0..n_local {
            let v = local[lu] as usize;
            for i in csr.rpt[v] as usize..csr.rpt[v + 1] as usize {
                let c = csr.col_ids[i] as usize;
                if c == v {
                    continue;
                }
                let lv = self.local_of[c];
                if lv > lu as i32 {
                    let lv = lv as usize;
                    if kept[lu].len() < edge_cap && kept[lv].len() < edge_cap {
                        kept[lu].push(lv as u32);
                        kept[lv].push(lu as u32);
                    }
                }
            }
        }

        // --- pack: normalized ELL rows, features, mask, label ---------
        let per_row = self.ell_width;
        let base_adj = bi * self.max_nodes * per_row;
        let inv_sqrt: Vec<f32> = kept
            .iter()
            .map(|ns| 1.0 / ((ns.len() + 1) as f32).sqrt())
            .collect();
        let mut nnz = 0u32;
        for lu in 0..n_local {
            let cols = &mut mb.ell_cols[base_adj + lu * per_row..base_adj + (lu + 1) * per_row];
            let vals = &mut mb.ell_vals[base_adj + lu * per_row..base_adj + (lu + 1) * per_row];
            cols[0] = lu as i32;
            vals[0] = inv_sqrt[lu] * inv_sqrt[lu];
            for (s, &lv) in kept[lu].iter().enumerate() {
                cols[s + 1] = lv as i32;
                vals[s + 1] = inv_sqrt[lu] * inv_sqrt[lv as usize];
            }
            nnz += 1 + kept[lu].len() as u32;
        }
        mb.ell_nnz[bi] = nnz;
        for lu in 0..n_local {
            let v = local[lu] as usize;
            let row =
                &mut mb.x[(bi * self.max_nodes + lu) * FEAT_DIM..(bi * self.max_nodes + lu + 1) * FEAT_DIM];
            row[node_element(v)] = 1.0;
            row[N_ELEMENTS + degree_bucket(self.global_degree(v))] = 1.0;
            row[N_ELEMENTS + DEGREE_CAP] = 1.0;
            mb.mask[bi * self.max_nodes + lu] = 1.0;
        }
        let class =
            (node_element(root) + degree_bucket(self.global_degree(root))) % self.n_out;
        mb.labels[bi * self.n_out + class] = 1.0;

        // Reset the global->local map for the next sample.
        for &v in &local {
            self.local_of[v as usize] = -1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::trainer::Trainer;
    use crate::graph::powerlaw::power_law_graph;

    #[test]
    fn sampled_batches_are_valid_and_deterministic() {
        let g = power_law_graph(2_000, 3, 11).unwrap();
        let cfg = ModelConfig::synthetic("largegraph").unwrap();
        let mut s = NeighborSampler::new(&g, &cfg, 5).unwrap();
        let mb = s.next_batch(6).unwrap();
        assert_eq!(mb.batch, 6);
        assert_eq!(mb.channels, 1);
        let (m, w) = (cfg.max_nodes, cfg.ell_width);
        for bi in 0..6 {
            let n_real = mb.mask[bi * m..(bi + 1) * m]
                .iter()
                .filter(|&&v| v == 1.0)
                .count();
            assert!(n_real >= 1 && n_real <= m);
            // Mask is a prefix (local indices are assigned in order).
            assert!(mb.mask[bi * m..bi * m + n_real].iter().all(|&v| v == 1.0));
            let base = bi * m * w;
            let mut entries = std::collections::HashMap::new();
            let mut nnz = 0usize;
            for lu in 0..m {
                let cols = &mb.ell_cols[base + lu * w..base + (lu + 1) * w];
                let vals = &mb.ell_vals[base + lu * w..base + (lu + 1) * w];
                if lu >= n_real {
                    assert!(vals.iter().all(|&v| v == 0.0), "padded row {lu} not empty");
                    continue;
                }
                // Self-loop first, then neighbors; all cols in range.
                assert_eq!(cols[0] as usize, lu);
                assert!(vals[0] > 0.0);
                for s in 0..w {
                    if vals[s] != 0.0 {
                        assert!((cols[s] as usize) < n_real);
                        entries.insert((lu, cols[s] as usize), vals[s]);
                        nnz += 1;
                    }
                }
            }
            assert_eq!(mb.ell_nnz[bi] as usize, nnz, "cached nnz mismatch");
            // Symmetric pattern and value (the §12 Â construction).
            for (&(u, v), &val) in &entries {
                assert_eq!(entries.get(&(v, u)), Some(&val), "asymmetric at ({u},{v})");
            }
            // One-hot label.
            let lrow = &mb.labels[bi * cfg.n_out..(bi + 1) * cfg.n_out];
            assert_eq!(lrow.iter().filter(|&&v| v == 1.0).count(), 1);
            // Feature rows carry element + degree one-hots + bias.
            for lu in 0..n_real {
                let row = &mb.x[(bi * m + lu) * FEAT_DIM..(bi * m + lu + 1) * FEAT_DIM];
                assert_eq!(row[..N_ELEMENTS].iter().sum::<f32>(), 1.0);
                assert_eq!(
                    row[N_ELEMENTS..N_ELEMENTS + DEGREE_CAP].iter().sum::<f32>(),
                    1.0
                );
                assert_eq!(row[FEAT_DIM - 1], 1.0);
            }
        }
        // Same graph + seed -> the identical stream.
        let mut s2 = NeighborSampler::new(&g, &cfg, 5).unwrap();
        let mb2 = s2.next_batch(6).unwrap();
        assert_eq!(mb.ell_cols, mb2.ell_cols);
        assert_eq!(mb.ell_vals, mb2.ell_vals);
        assert_eq!(mb.x, mb2.x);
        assert_eq!(mb.labels, mb2.labels);
    }

    #[test]
    fn fanout_schedules_bound_the_receptive_field_without_changing_geometry() {
        let g = power_law_graph(2_000, 3, 11).unwrap();
        let cfg = ModelConfig::synthetic("largegraph").unwrap();

        // Bad schedules are rejected up front.
        assert!(NeighborSampler::with_fanouts(&g, &cfg, &[], 5).is_err());
        assert!(NeighborSampler::with_fanouts(&g, &cfg, &[3, 0], 5).is_err());

        // Two-hop schedule [3, 2]: every subgraph holds at most
        // 1 + 3 + 3*2 = 10 real nodes regardless of graph degree.
        let mut s = NeighborSampler::with_fanouts(&g, &cfg, &[3, 2], 5).unwrap();
        let mb = s.next_batch(8).unwrap();
        let m = cfg.max_nodes;
        for bi in 0..8 {
            let n_real = mb.mask[bi * m..(bi + 1) * m]
                .iter()
                .filter(|&&v| v == 1.0)
                .count();
            assert!(n_real >= 1 && n_real <= 10, "sample {bi} has {n_real} nodes");
        }
        // The legacy unbounded schedule overruns that receptive field
        // on a degree-3+ power-law graph — the bound is real.
        let mut legacy = NeighborSampler::new(&g, &cfg, 5).unwrap();
        let lb = legacy.next_batch(8).unwrap();
        let biggest = (0..8)
            .map(|bi| {
                lb.mask[bi * m..(bi + 1) * m].iter().filter(|&&v| v == 1.0).count()
            })
            .max()
            .unwrap();
        assert!(biggest > 10, "legacy sampler never exceeded the 2-hop bound");

        // Packed geometry is schedule-independent: same ModelBatch
        // shape, so the same compiled plan serves both streams.
        assert_eq!((mb.batch, mb.max_nodes, mb.ell_width), (lb.batch, lb.max_nodes, lb.ell_width));

        // Deterministic in seed, like the legacy schedule.
        let mut s2 = NeighborSampler::with_fanouts(&g, &cfg, &[3, 2], 5).unwrap();
        let mb2 = s2.next_batch(8).unwrap();
        assert_eq!(mb.ell_cols, mb2.ell_cols);
        assert_eq!(mb.x, mb2.x);
    }

    #[test]
    fn fanout_sampled_training_still_compiles_one_plan() {
        let g = power_law_graph(20_000, 4, 3).unwrap();
        let mut tr = Trainer::new_host("largegraph", 1).unwrap();
        let cfg = tr.cfg.clone();
        let mut s = NeighborSampler::with_fanouts(&g, &cfg, &[4, 3, 2], 17).unwrap();
        let losses = tr.train_sampled(&mut s, 3, 8, 0.05).unwrap();
        assert_eq!(losses.len(), 3);
        assert!(losses.iter().all(|l| l.is_finite()), "losses {losses:?}");
        // The schedule shapes node selection, not geometry: the whole
        // stream still replays one compiled train plan.
        let ps = tr.plan_stats();
        assert_eq!(ps.plans_built, 1, "fanout-sampled steps should share one plan");
    }

    #[test]
    fn sampled_training_runs_through_compiled_plans_on_a_big_graph() {
        // The ISSUE acceptance path: a 10^5-node power-law graph trains
        // end-to-end through the batched engine and the plan cache.
        let g = power_law_graph(100_000, 4, 3).unwrap();
        let mut tr = Trainer::new_host("largegraph", 1).unwrap();
        let cfg = tr.cfg.clone();
        let mut s = NeighborSampler::new(&g, &cfg, 17).unwrap();
        let losses = tr.train_sampled(&mut s, 3, 8, 0.05).unwrap();
        assert_eq!(losses.len(), 3);
        assert!(losses.iter().all(|l| l.is_finite()), "losses {losses:?}");
        // Fixed subgraph geometry -> one compiled train plan, replayed.
        let ps = tr.plan_stats();
        assert_eq!(ps.plans_built, 1, "sampled steps should share one plan");
        assert_eq!(tr.dispatches, 3);
    }
}
