//! Reference backward pass for the ChemGCN, expressed as batched-SpMM
//! engine dispatches (DESIGN.md §8).
//!
//! [`grad`] mirrors [`reference::forward_with`] layer by layer: a
//! cached forward replay ([`forward_cached`], built from the same
//! `conv_layer`/`readout` helpers the inference path uses), then the
//! chain rule walked backwards with every matrix multiplication routed
//! through the engine:
//!
//! * `dU = A^T @ dY` — [`EllKernel`] channel view on
//!   [`Executor::dispatch_t`] (one batched `A^T·X` dispatch per
//!   channel);
//! * `dW = X^T @ dU` — [`GemmKernel`] over the `[B*M, fin]` stacked
//!   view of the activations, `dispatch_t` (the cross-sample reduction
//!   folds into a batch-1 matmul, which the worker pool row-splits
//!   across workers — bit-stably — rather than leaving it
//!   single-threaded, DESIGN.md §9);
//! * `dX = dU @ W^T` — [`GemmKernel`] with [`Rhs::SharedTransposed`]
//!   (the `X·W^T` form), accumulating across channels through the
//!   engine's `+=` contract;
//! * the readout head gets the same two transpose forms over its
//!   pooled views.
//!
//! GraphNorm/ReLU backward and the bias/γ/β reductions are host-side
//! loops — they contain no matmul. Gradients are checked element-wise
//! against central finite differences in `tests/grad_check.rs`, and
//! batched gradients are pinned to the mean of per-sample gradients
//! (the decomposability contract behind the paper's Table II).

use super::config::{LossKind, ModelConfig};
use super::params::ParamSet;
use super::reference::{self, EPS};
use crate::graph::dataset::ModelBatch;
use crate::sparse::engine::{EllKernel, Executor, GemmKernel, Rhs};
use crate::sparse::ops::axpy;

/// Activations the backward pass replays, captured during one forward.
pub struct ForwardCache {
    /// Layer inputs: `acts[0]` is `mb.x`, `acts[l]` the output of conv
    /// layer `l-1`; `acts[L]` feeds the readout head. Each `[B, M, f]`.
    pub acts: Vec<Vec<f32>>,
    /// Per-layer pre-normalization accumulators `Σ_ch A_ch @ U_ch`,
    /// saved before `graph_norm_relu` runs in place (the norm backward
    /// recomputes its statistics from these).
    pub ypre: Vec<Vec<f32>>,
    /// Readout logits `[B, n_out]`.
    pub logits: Vec<f32>,
    /// Engine dispatches the forward replay issued.
    pub dispatches: u64,
}

/// Forward pass that additionally captures the per-layer activations
/// the backward pass needs. Logits are bit-identical to
/// [`reference::forward_with_readout`] — both run the same helpers.
pub fn forward_cached(
    cfg: &ModelConfig,
    ps: &ParamSet,
    mb: &ModelBatch,
    exec: &Executor,
    w_rep: &[f32],
) -> anyhow::Result<ForwardCache> {
    reference::check_batch(cfg, mb)?;
    let b = mb.batch;
    let m = cfg.max_nodes;
    let mut acts = vec![mb.x.clone()];
    let mut ypre = Vec::with_capacity(cfg.hidden.len());
    let mut fin = cfg.feat_dim;
    for (li, &fout) in cfg.hidden.iter().enumerate() {
        let gamma = ps.slice(cfg, &format!("conv{li}.gamma"))?;
        let beta = ps.slice(cfg, &format!("conv{li}.beta"))?;
        let y = reference::conv_layer(cfg, ps, li, fin, fout, acts.last().unwrap(), mb, exec)?;
        ypre.push(y.clone());
        let mut h = y;
        reference::graph_norm_relu(&mut h, &mb.mask, gamma, beta, b, m, fout);
        acts.push(h);
        fin = fout;
    }
    let logits = reference::readout(cfg, ps, acts.last().unwrap(), fin, b, exec, w_rep)?;
    Ok(ForwardCache {
        acts,
        ypre,
        logits,
        dispatches: (2 * cfg.channels * cfg.hidden.len() + 1) as u64,
    })
}

/// Output of one gradient computation.
pub struct GradResult {
    /// Mean minibatch loss (identical to `reference::loss` on the
    /// replayed logits).
    pub loss: f32,
    /// Gradient of the mean loss with respect to every parameter, in
    /// the same flat layout as [`ParamSet`].
    pub grads: ParamSet,
    /// Engine dispatches issued by the forward replay + backward walk.
    pub dispatches: u64,
}

/// Loss + full parameter gradient on the serial executor.
pub fn grad(cfg: &ModelConfig, ps: &ParamSet, mb: &ModelBatch) -> anyhow::Result<GradResult> {
    grad_with(cfg, ps, mb, &Executor::serial(), None)
}

/// Loss + full parameter gradient with an explicit executor and an
/// optional pre-built tiled readout weight (see
/// [`reference::build_w_rep`]); results are bit-identical for every
/// thread count.
pub fn grad_with(
    cfg: &ModelConfig,
    ps: &ParamSet,
    mb: &ModelBatch,
    exec: &Executor,
    w_rep: Option<&[f32]>,
) -> anyhow::Result<GradResult> {
    // reference::loss divides by the batch size: an empty batch would
    // return loss = NaN with all-zero grads instead of an error.
    anyhow::ensure!(mb.batch > 0, "gradient of an empty batch");
    let built;
    let w_rep: &[f32] = match w_rep {
        Some(w) => w,
        None => {
            built = reference::build_w_rep(cfg, ps)?;
            &built
        }
    };
    let cache = forward_cached(cfg, ps, mb, exec, w_rep)?;
    let mut dispatches = cache.dispatches;
    let b = mb.batch;
    let m = cfg.max_nodes;
    let n_out = cfg.n_out;
    let loss = reference::loss(cfg, &cache.logits, &mb.labels, b);
    let mut g = ParamSet::zeros(cfg);

    // ---- loss -> dlogits (elementwise, no matmul) -----------------------
    let dlogits = loss_grad(cfg, &cache.logits, &mb.labels, b);

    // ---- readout head backward (2 engine dispatches) --------------------
    let fin_last = *cfg.hidden.last().unwrap_or(&cfg.feat_dim);
    let h_last = cache.acts.last().unwrap();
    // d b_out: column sums of dlogits (the bias is added once per sample).
    {
        let gb = g.slice_mut(cfg, "readout.b")?;
        for row in dlogits.chunks(n_out) {
            for (o, v) in row.iter().enumerate() {
                gb[o] += v;
            }
        }
    }
    // d W_out = P^T @ dlogits with P[b,:] = Σ_r h[b,r,:] (sum-pool):
    // one batch-1 transpose GEMM over the pooled [B, fin] view.
    let mut pooled = vec![0f32; b * fin_last];
    for bi in 0..b {
        let dst = &mut pooled[bi * fin_last..(bi + 1) * fin_last];
        for r in 0..m {
            let row = &h_last[(bi * m + r) * fin_last..(bi * m + r + 1) * fin_last];
            for (k, v) in row.iter().enumerate() {
                dst[k] += v;
            }
        }
    }
    {
        let pk = GemmKernel::new(&pooled, 1, b, fin_last);
        let gw = g.slice_mut(cfg, "readout.w")?;
        exec.dispatch_t(&pk, Rhs::Shared(&dlogits), n_out, gw)?;
        dispatches += 1;
    }
    // d h: the readout sums rows, so every row of sample b gets
    // dlogits[b] @ W_out^T — one X·W^T dispatch, then a row broadcast.
    let w_out = ps.slice(cfg, "readout.w")?;
    let mut drow = vec![0f32; b * fin_last];
    let dk = GemmKernel::new(&dlogits, b, 1, n_out);
    exec.dispatch(&dk, Rhs::SharedTransposed(w_out), fin_last, &mut drow)?;
    dispatches += 1;
    let mut dh = vec![0f32; b * m * fin_last];
    for bi in 0..b {
        let src = &drow[bi * fin_last..(bi + 1) * fin_last];
        for r in 0..m {
            dh[(bi * m + r) * fin_last..(bi * m + r + 1) * fin_last].copy_from_slice(src);
        }
    }

    // ---- conv layers, last to first ------------------------------------
    // 3 dispatches per channel; the first layer skips dX and issues 2.
    for li in (0..cfg.hidden.len()).rev() {
        let fout = cfg.hidden[li];
        let fin = if li == 0 {
            cfg.feat_dim
        } else {
            cfg.hidden[li - 1]
        };
        let x = &cache.acts[li];
        let ypre = &cache.ypre[li];
        let gamma = ps.slice(cfg, &format!("conv{li}.gamma"))?;
        let beta = ps.slice(cfg, &format!("conv{li}.beta"))?;

        // GraphNorm + ReLU backward: dL/dH -> dL/dYpre (host-side).
        let mut dypre = vec![0f32; b * m * fout];
        let (dgamma, dbeta) =
            graph_norm_relu_backward(ypre, &mb.mask, gamma, beta, &dh, &mut dypre, b, m, fout);
        axpy(1.0, &dgamma, g.slice_mut(cfg, &format!("conv{li}.gamma"))?);
        axpy(1.0, &dbeta, g.slice_mut(cfg, &format!("conv{li}.beta"))?);

        let w = ps.slice(cfg, &format!("conv{li}.w"))?;
        let mut dx = vec![0f32; b * m * fin];
        let mut gw_all = vec![0f32; cfg.channels * fin * fout];
        let mut gb_all = vec![0f32; cfg.channels * fout];
        for ch in 0..cfg.channels {
            // dU = A_ch^T @ dYpre — batched transpose ELL dispatch.
            let adj = EllKernel::channel(mb, ch);
            let mut du = vec![0f32; b * m * fout];
            exec.dispatch_t(&adj, Rhs::PerSample(&dypre), fout, &mut du)?;
            dispatches += 1;
            // d bias_ch: row sums of dU (the bias broadcasts over rows).
            {
                let gb = &mut gb_all[ch * fout..(ch + 1) * fout];
                for row in du.chunks(fout) {
                    for (o, v) in row.iter().enumerate() {
                        gb[o] += v;
                    }
                }
            }
            // d W_ch = X^T @ dU with all samples stacked: one batch-1
            // transpose GEMM over the [B*M, fin] view of X, folding the
            // cross-sample sum into the matmul itself.
            let xk = GemmKernel::new(x, 1, b * m, fin);
            exec.dispatch_t(
                &xk,
                Rhs::Shared(&du),
                fout,
                &mut gw_all[ch * fin * fout..(ch + 1) * fin * fout],
            )?;
            dispatches += 1;
            // dX += dU @ W_ch^T — X·W^T dispatch, accumulating across
            // channels through the engine's `+=` contract. The first
            // layer's input is the data, which needs no gradient, so
            // the dispatch is skipped there.
            if li > 0 {
                let w_ch = &w[ch * fin * fout..(ch + 1) * fin * fout];
                let duk = GemmKernel::new(&du, b, m, fout);
                exec.dispatch(&duk, Rhs::SharedTransposed(w_ch), fin, &mut dx)?;
                dispatches += 1;
            }
        }
        axpy(1.0, &gw_all, g.slice_mut(cfg, &format!("conv{li}.w"))?);
        axpy(1.0, &gb_all, g.slice_mut(cfg, &format!("conv{li}.b"))?);
        dh = dx;
    }

    Ok(GradResult {
        loss,
        grads: g,
        dispatches,
    })
}

/// d(mean loss)/d(logits), matching `reference::loss` exactly.
pub fn loss_grad(cfg: &ModelConfig, logits: &[f32], labels: &[f32], batch: usize) -> Vec<f32> {
    let n = cfg.n_out;
    assert_eq!(logits.len(), batch * n);
    assert_eq!(labels.len(), batch * n);
    let inv_b = 1.0 / batch as f32;
    let mut d = vec![0f32; batch * n];
    match cfg.loss {
        LossKind::Bce => {
            for i in 0..batch * n {
                d[i] = (sigmoid(logits[i]) - labels[i]) * inv_b;
            }
        }
        LossKind::Softmax => {
            for bi in 0..batch {
                let row = &logits[bi * n..(bi + 1) * n];
                let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                let denom: f32 = row.iter().map(|&v| (v - max).exp()).sum();
                // Labels are one-hot in the datasets, but the loss is
                // linear in them, so keep the general Σ_j y_j factor.
                let lsum: f32 = labels[bi * n..(bi + 1) * n].iter().sum();
                for j in 0..n {
                    let p = (row[j] - max).exp() / denom;
                    d[bi * n + j] = (p * lsum - labels[bi * n + j]) * inv_b;
                }
            }
        }
    }
    d
}

fn sigmoid(x: f32) -> f32 {
    if x >= 0.0 {
        1.0 / (1.0 + (-x).exp())
    } else {
        let e = x.exp();
        e / (1.0 + e)
    }
}

/// Backward of `reference::graph_norm_relu` for one layer: given dL/dH
/// at the layer output, writes dL/dYpre and returns `(dgamma, dbeta)`.
/// Statistics (masked mean/var, normalized values) are recomputed from
/// the cached pre-norm activations in the same operation order as the
/// forward.
///
/// Per (graph, feature) group with mask weights `w_r`, count `N`,
/// `inv = 1/sqrt(var + EPS)` and gate `[v_r > 0]`:
/// `dŷ_r = gate_r · dh_r · γ · w_r`, `S1 = Σ dŷ`, `S2 = Σ dŷ·ĥ`, and
/// `dYpre_r = inv · (dŷ_r − w_r · (S1 + ĥ_r · S2) / N)` — the standard
/// normalization backward, with the mask zeroing both the padded rows'
/// own gradients and their (non-existent) contribution to the
/// statistics.
#[allow(clippy::too_many_arguments)]
fn graph_norm_relu_backward(
    ypre: &[f32],
    mask: &[f32],
    gamma: &[f32],
    beta: &[f32],
    dh: &[f32],
    dypre: &mut [f32],
    b: usize,
    m: usize,
    f: usize,
) -> (Vec<f32>, Vec<f32>) {
    let mut dgamma = vec![0f32; f];
    let mut dbeta = vec![0f32; f];
    let mut hn = vec![0f32; m];
    let mut dhat = vec![0f32; m];
    for bi in 0..b {
        let msk = &mask[bi * m..(bi + 1) * m];
        let cnt = msk.iter().sum::<f32>().max(1.0);
        let rows = &ypre[bi * m * f..(bi + 1) * m * f];
        let drows = &dh[bi * m * f..(bi + 1) * m * f];
        let orows = &mut dypre[bi * m * f..(bi + 1) * m * f];
        for j in 0..f {
            let mut mean = 0f32;
            for r in 0..m {
                mean += rows[r * f + j] * msk[r];
            }
            mean /= cnt;
            let mut var = 0f32;
            for r in 0..m {
                let d = rows[r * f + j] - mean;
                var += d * d * msk[r];
            }
            var /= cnt;
            let inv = 1.0 / (var + EPS).sqrt();
            let mut s1 = 0f32;
            let mut s2 = 0f32;
            for r in 0..m {
                let h = (rows[r * f + j] - mean) * inv;
                hn[r] = h;
                let v = (gamma[j] * h + beta[j]) * msk[r];
                let gate = if v > 0.0 { drows[r * f + j] } else { 0.0 };
                dgamma[j] += gate * h * msk[r];
                dbeta[j] += gate * msk[r];
                let dn = gate * gamma[j] * msk[r];
                dhat[r] = dn;
                s1 += dn;
                s2 += dn * h;
            }
            for r in 0..m {
                orows[r * f + j] = inv * (dhat[r] - msk[r] * (s1 + hn[r] * s2) / cnt);
            }
        }
    }
    (dgamma, dbeta)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::dataset::{Dataset, DatasetKind};

    fn setup(n: usize, seed: u64) -> (ModelConfig, ParamSet, Dataset) {
        let cfg = ModelConfig::synthetic("tox21").unwrap();
        let ps = ParamSet::random_init(&cfg, seed);
        let data = Dataset::generate(DatasetKind::Tox21, n, seed);
        (cfg, ps, data)
    }

    #[test]
    fn cached_forward_matches_plain_forward() {
        let (cfg, ps, data) = setup(4, 3);
        let mb = data.pack_batch(&[0, 1, 2], cfg.max_nodes, cfg.ell_width).unwrap();
        let w_rep = reference::build_w_rep(&cfg, &ps).unwrap();
        let plain = reference::forward(&cfg, &ps, &mb).unwrap();
        let cache =
            forward_cached(&cfg, &ps, &mb, &Executor::serial(), &w_rep).unwrap();
        assert_eq!(plain, cache.logits);
        assert_eq!(cache.acts.len(), cfg.hidden.len() + 1);
        assert_eq!(cache.ypre.len(), cfg.hidden.len());
    }

    #[test]
    fn grad_shapes_and_finiteness() {
        let (cfg, ps, data) = setup(3, 5);
        let mb = data.pack_batch(&[0, 1], cfg.max_nodes, cfg.ell_width).unwrap();
        let res = grad(&cfg, &ps, &mb).unwrap();
        assert_eq!(res.grads.data.len(), cfg.n_params);
        assert!(res.loss.is_finite() && res.loss > 0.0);
        assert!(res.grads.data.iter().all(|v| v.is_finite()));
        assert!(res.grads.data.iter().any(|v| *v != 0.0));
        // 17 forward + 22 backward dispatches for the tox21 geometry
        // (DESIGN.md §8): 2·CH·L + 1 and CH·(3L − 1) + 2 with CH=4,
        // L=2 (no dX dispatch on the first layer — data needs no grad).
        assert_eq!(res.dispatches, 17 + 22);
    }

    #[test]
    fn grad_parallel_is_bitwise_deterministic() {
        let (cfg, ps, data) = setup(6, 7);
        let idx: Vec<usize> = (0..6).collect();
        let mb = data.pack_batch(&idx, cfg.max_nodes, cfg.ell_width).unwrap();
        let serial = grad(&cfg, &ps, &mb).unwrap();
        for threads in [2, 8] {
            let par =
                grad_with(&cfg, &ps, &mb, &Executor::new(threads), None).unwrap();
            assert_eq!(serial.grads.data, par.grads.data, "threads={threads}");
            assert_eq!(serial.loss, par.loss);
        }
    }

    #[test]
    fn loss_grad_matches_finite_difference_of_loss() {
        // Pin the loss->logits gradient on its own (both loss kinds),
        // independent of the model layers.
        for (loss_kind, n, seed) in [("bce", 12usize, 1u64), ("softmax", 5usize, 2u64)] {
            let cfg = crate::util::json::parse(&format!(
                r#"{{
 "name": "t", "max_nodes": 4, "feat_dim": 2, "channels": 1, "hidden": [2],
 "n_out": {n}, "loss": "{loss_kind}", "nnz_cap": 4, "ell_width": 3,
 "train_batch": 2, "infer_batch": 2, "n_params": 0, "params": [],
 "init_file": "x", "artifact_fwd_infer": "x", "artifact_fwd_train": "x",
 "artifact_fwd_sample": "x", "artifact_train_step": "x",
 "artifact_grad_sample": "x", "artifact_apply_sgd": "x"}}"#
            ))
            .and_then(|j| ModelConfig::from_json(&j))
            .unwrap();
            let mut rng = crate::util::rng::Rng::new(seed);
            let batch = 3usize;
            let logits: Vec<f32> = (0..batch * n).map(|_| rng.normal()).collect();
            let labels: Vec<f32> = (0..batch * n)
                .map(|_| if rng.bool(0.3) { 1.0 } else { 0.0 })
                .collect();
            let g = loss_grad(&cfg, &logits, &labels, batch);
            let eps = 1e-2f32;
            for i in 0..batch * n {
                let mut lp = logits.clone();
                lp[i] += eps;
                let mut lm = logits.clone();
                lm[i] -= eps;
                let fd = (reference::loss(&cfg, &lp, &labels, batch)
                    - reference::loss(&cfg, &lm, &labels, batch))
                    / (2.0 * eps);
                assert!(
                    (g[i] - fd).abs() <= 1e-4 + 1e-3 * fd.abs(),
                    "{loss_kind} logit {i}: analytic {} vs fd {fd}",
                    g[i]
                );
            }
        }
    }
}
