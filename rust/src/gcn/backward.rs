//! Reference backward pass for the ChemGCN, expressed as batched-SpMM
//! engine dispatches (DESIGN.md §8).
//!
//! [`grad`] mirrors [`reference::forward_with`] layer by layer: a
//! cached forward replay ([`forward_cached`], built from the same
//! `conv_layer`/`readout` helpers the inference path uses), then the
//! chain rule walked backwards with every matrix multiplication routed
//! through the engine:
//!
//! * `dU = A^T @ dY` — [`EllKernel`] channel view on
//!   [`Executor::dispatch_t`] (one batched `A^T·X` dispatch per
//!   channel);
//! * `dW = X^T @ dU` — [`GemmKernel`] over the `[B*M, fin]` stacked
//!   view of the activations, `dispatch_t` (the cross-sample reduction
//!   folds into a batch-1 matmul, which the worker pool row-splits
//!   across workers — bit-stably — rather than leaving it
//!   single-threaded, DESIGN.md §9);
//! * `dX = dU @ W^T` — [`GemmKernel`] with [`Rhs::SharedTransposed`]
//!   (the `X·W^T` form), accumulating across channels through the
//!   engine's `+=` contract;
//! * the readout head gets the same two transpose forms over its
//!   pooled views.
//!
//! GraphNorm/ReLU backward and the bias/γ/β reductions are host-side
//! loops — they contain no matmul. Gradients are checked element-wise
//! against central finite differences in `tests/grad_check.rs`, and
//! batched gradients are pinned to the mean of per-sample gradients
//! (the decomposability contract behind the paper's Table II).
//!
//! **Plan/execute split (DESIGN.md §11).** [`grad_with`] is the direct
//! path: fresh intermediates, name-resolved parameters, and the
//! executor's per-dispatch `SharedTransposed` materialization. The
//! trainer instead compiles a [`StepPlan`] once per geometry
//! ([`plan_train`]) and replays it ([`grad_planned`]): every
//! intermediate (`du`, `dx`, `dypre`, the pooled/readout buffers, the
//! GraphNorm scratch, the pre-transposed weights) comes from a
//! caller-held [`Workspace`] arena and the gradient accumulates
//! straight into a caller-held flat buffer, so steady-state train
//! steps allocate nothing. Same helpers, same dispatch sequence, same
//! accumulation order — bit-identical gradients.

use super::config::{LossKind, ModelConfig};
use super::params::ParamSet;
use super::reference::{self, EPS};
use crate::graph::dataset::ModelBatch;
use crate::sparse::engine::{
    plan::transpose_into, AutoThresholds, Backend, DType, DispatchDesc, EllKernel, Executor,
    GemmKernel, GeometryKey, ParamRef, PlanCursor, Rhs, RhsKind, SlotId, SlotInit, StepPlan,
    Workspace,
};
use crate::sparse::ops::axpy;

/// Activations the backward pass replays, captured during one forward.
pub struct ForwardCache {
    /// Layer inputs: `acts[0]` is `mb.x`, `acts[l]` the output of conv
    /// layer `l-1`; `acts[L]` feeds the readout head. Each `[B, M, f]`.
    pub acts: Vec<Vec<f32>>,
    /// Per-layer pre-normalization accumulators `Σ_ch A_ch @ U_ch`,
    /// saved before `graph_norm_relu` runs in place (the norm backward
    /// recomputes its statistics from these).
    pub ypre: Vec<Vec<f32>>,
    /// Readout logits `[B, n_out]`.
    pub logits: Vec<f32>,
    /// Engine dispatches the forward replay issued.
    pub dispatches: u64,
}

/// Forward pass that additionally captures the per-layer activations
/// the backward pass needs. Logits are bit-identical to
/// [`reference::forward_with_readout`] — both run the same helpers.
pub fn forward_cached(
    cfg: &ModelConfig,
    ps: &ParamSet,
    mb: &ModelBatch,
    exec: &Executor,
    w_rep: &[f32],
) -> anyhow::Result<ForwardCache> {
    reference::check_batch(cfg, mb)?;
    let b = mb.batch;
    let m = cfg.max_nodes;
    let mut acts = vec![mb.x.clone()];
    let mut ypre = Vec::with_capacity(cfg.hidden.len());
    let mut fin = cfg.feat_dim;
    for (li, &fout) in cfg.hidden.iter().enumerate() {
        let gamma = ps.slice(cfg, &format!("conv{li}.gamma"))?;
        let beta = ps.slice(cfg, &format!("conv{li}.beta"))?;
        let y = reference::conv_layer(cfg, ps, li, fin, fout, acts.last().unwrap(), mb, exec)?;
        ypre.push(y.clone());
        let mut h = y;
        reference::graph_norm_relu(&mut h, &mb.mask, gamma, beta, b, m, fout);
        acts.push(h);
        fin = fout;
    }
    let logits = reference::readout(cfg, ps, acts.last().unwrap(), fin, b, exec, w_rep)?;
    Ok(ForwardCache {
        acts,
        ypre,
        logits,
        dispatches: (2 * cfg.channels * cfg.hidden.len() + 1) as u64,
    })
}

/// Output of one gradient computation.
pub struct GradResult {
    /// Mean minibatch loss (identical to `reference::loss` on the
    /// replayed logits).
    pub loss: f32,
    /// Gradient of the mean loss with respect to every parameter, in
    /// the same flat layout as [`ParamSet`].
    pub grads: ParamSet,
    /// Engine dispatches issued by the forward replay + backward walk.
    pub dispatches: u64,
}

/// Loss + full parameter gradient on the serial executor.
pub fn grad(cfg: &ModelConfig, ps: &ParamSet, mb: &ModelBatch) -> anyhow::Result<GradResult> {
    grad_with(cfg, ps, mb, &Executor::serial(), None)
}

/// Loss + full parameter gradient with an explicit executor and an
/// optional pre-built tiled readout weight (see
/// [`reference::build_w_rep`]); results are bit-identical for every
/// thread count.
pub fn grad_with(
    cfg: &ModelConfig,
    ps: &ParamSet,
    mb: &ModelBatch,
    exec: &Executor,
    w_rep: Option<&[f32]>,
) -> anyhow::Result<GradResult> {
    // reference::loss divides by the batch size: an empty batch would
    // return loss = NaN with all-zero grads instead of an error.
    anyhow::ensure!(mb.batch > 0, "gradient of an empty batch");
    let built;
    let w_rep: &[f32] = match w_rep {
        Some(w) => w,
        None => {
            built = reference::build_w_rep(cfg, ps)?;
            &built
        }
    };
    let cache = forward_cached(cfg, ps, mb, exec, w_rep)?;
    let mut dispatches = cache.dispatches;
    let b = mb.batch;
    let m = cfg.max_nodes;
    let n_out = cfg.n_out;
    let loss = reference::loss(cfg, &cache.logits, &mb.labels, b);
    let mut g = ParamSet::zeros(cfg);

    // ---- loss -> dlogits (elementwise, no matmul) -----------------------
    let dlogits = loss_grad(cfg, &cache.logits, &mb.labels, b);

    // ---- readout head backward (2 engine dispatches) --------------------
    let fin_last = *cfg.hidden.last().unwrap_or(&cfg.feat_dim);
    let h_last = cache.acts.last().unwrap();
    // d b_out: column sums of dlogits (the bias is added once per sample).
    {
        let gb = g.slice_mut(cfg, "readout.b")?;
        for row in dlogits.chunks(n_out) {
            for (o, v) in row.iter().enumerate() {
                gb[o] += v;
            }
        }
    }
    // d W_out = P^T @ dlogits with P[b,:] = Σ_r h[b,r,:] (sum-pool):
    // one batch-1 transpose GEMM over the pooled [B, fin] view.
    let mut pooled = vec![0f32; b * fin_last];
    for bi in 0..b {
        let dst = &mut pooled[bi * fin_last..(bi + 1) * fin_last];
        for r in 0..m {
            let row = &h_last[(bi * m + r) * fin_last..(bi * m + r + 1) * fin_last];
            for (k, v) in row.iter().enumerate() {
                dst[k] += v;
            }
        }
    }
    {
        let pk = GemmKernel::new(&pooled, 1, b, fin_last);
        let gw = g.slice_mut(cfg, "readout.w")?;
        exec.dispatch_t(&pk, Rhs::Shared(&dlogits), n_out, gw)?;
        dispatches += 1;
    }
    // d h: the readout sums rows, so every row of sample b gets
    // dlogits[b] @ W_out^T — one X·W^T dispatch, then a row broadcast.
    let w_out = ps.slice(cfg, "readout.w")?;
    let mut drow = vec![0f32; b * fin_last];
    let dk = GemmKernel::new(&dlogits, b, 1, n_out);
    exec.dispatch(&dk, Rhs::SharedTransposed(w_out), fin_last, &mut drow)?;
    dispatches += 1;
    let mut dh = vec![0f32; b * m * fin_last];
    for bi in 0..b {
        let src = &drow[bi * fin_last..(bi + 1) * fin_last];
        for r in 0..m {
            dh[(bi * m + r) * fin_last..(bi * m + r + 1) * fin_last].copy_from_slice(src);
        }
    }

    // ---- conv layers, last to first ------------------------------------
    // 3 dispatches per channel; the first layer skips dX and issues 2.
    for li in (0..cfg.hidden.len()).rev() {
        let fout = cfg.hidden[li];
        let fin = if li == 0 {
            cfg.feat_dim
        } else {
            cfg.hidden[li - 1]
        };
        let x = &cache.acts[li];
        let ypre = &cache.ypre[li];
        let gamma = ps.slice(cfg, &format!("conv{li}.gamma"))?;
        let beta = ps.slice(cfg, &format!("conv{li}.beta"))?;

        // GraphNorm + ReLU backward: dL/dH -> dL/dYpre (host-side).
        let mut dypre = vec![0f32; b * m * fout];
        let mut dgamma = vec![0f32; fout];
        let mut dbeta = vec![0f32; fout];
        let mut hn = vec![0f32; m];
        let mut dhat = vec![0f32; m];
        graph_norm_relu_backward(
            ypre, &mb.mask, gamma, beta, &dh, &mut dypre, b, m, fout, &mut dgamma, &mut dbeta,
            &mut hn, &mut dhat,
        );
        axpy(1.0, &dgamma, g.slice_mut(cfg, &format!("conv{li}.gamma"))?);
        axpy(1.0, &dbeta, g.slice_mut(cfg, &format!("conv{li}.beta"))?);

        let w = ps.slice(cfg, &format!("conv{li}.w"))?;
        let mut dx = vec![0f32; b * m * fin];
        let mut gw_all = vec![0f32; cfg.channels * fin * fout];
        let mut gb_all = vec![0f32; cfg.channels * fout];
        for ch in 0..cfg.channels {
            // dU = A_ch^T @ dYpre — batched transpose ELL dispatch.
            let adj = EllKernel::channel(mb, ch);
            let mut du = vec![0f32; b * m * fout];
            exec.dispatch_t(&adj, Rhs::PerSample(&dypre), fout, &mut du)?;
            dispatches += 1;
            // d bias_ch: row sums of dU (the bias broadcasts over rows).
            {
                let gb = &mut gb_all[ch * fout..(ch + 1) * fout];
                for row in du.chunks(fout) {
                    for (o, v) in row.iter().enumerate() {
                        gb[o] += v;
                    }
                }
            }
            // d W_ch = X^T @ dU with all samples stacked: one batch-1
            // transpose GEMM over the [B*M, fin] view of X, folding the
            // cross-sample sum into the matmul itself.
            let xk = GemmKernel::new(x, 1, b * m, fin);
            exec.dispatch_t(
                &xk,
                Rhs::Shared(&du),
                fout,
                &mut gw_all[ch * fin * fout..(ch + 1) * fin * fout],
            )?;
            dispatches += 1;
            // dX += dU @ W_ch^T — X·W^T dispatch, accumulating across
            // channels through the engine's `+=` contract. The first
            // layer's input is the data, which needs no gradient, so
            // the dispatch is skipped there.
            if li > 0 {
                let w_ch = &w[ch * fin * fout..(ch + 1) * fin * fout];
                let duk = GemmKernel::new(&du, b, m, fout);
                exec.dispatch(&duk, Rhs::SharedTransposed(w_ch), fin, &mut dx)?;
                dispatches += 1;
            }
        }
        axpy(1.0, &gw_all, g.slice_mut(cfg, &format!("conv{li}.w"))?);
        axpy(1.0, &gb_all, g.slice_mut(cfg, &format!("conv{li}.b"))?);
        dh = dx;
    }

    Ok(GradResult {
        loss,
        grads: g,
        dispatches,
    })
}

/// d(mean loss)/d(logits), matching `reference::loss` exactly.
pub fn loss_grad(cfg: &ModelConfig, logits: &[f32], labels: &[f32], batch: usize) -> Vec<f32> {
    let mut d = vec![0f32; batch * cfg.n_out];
    loss_grad_into(cfg, logits, labels, batch, &mut d);
    d
}

/// [`loss_grad`] into a caller-held buffer (every element is
/// overwritten, so arena callers need no zero-fill).
pub fn loss_grad_into(
    cfg: &ModelConfig,
    logits: &[f32],
    labels: &[f32],
    batch: usize,
    d: &mut [f32],
) {
    let n = cfg.n_out;
    assert_eq!(logits.len(), batch * n);
    assert_eq!(labels.len(), batch * n);
    assert_eq!(d.len(), batch * n);
    let inv_b = 1.0 / batch as f32;
    match cfg.loss {
        LossKind::Bce => {
            for i in 0..batch * n {
                d[i] = (sigmoid(logits[i]) - labels[i]) * inv_b;
            }
        }
        LossKind::Softmax => {
            for bi in 0..batch {
                let row = &logits[bi * n..(bi + 1) * n];
                let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                let denom: f32 = row.iter().map(|&v| (v - max).exp()).sum();
                // Labels are one-hot in the datasets, but the loss is
                // linear in them, so keep the general Σ_j y_j factor.
                let lsum: f32 = labels[bi * n..(bi + 1) * n].iter().sum();
                for j in 0..n {
                    let p = (row[j] - max).exp() / denom;
                    d[bi * n + j] = (p * lsum - labels[bi * n + j]) * inv_b;
                }
            }
        }
    }
}

fn sigmoid(x: f32) -> f32 {
    if x >= 0.0 {
        1.0 / (1.0 + (-x).exp())
    } else {
        let e = x.exp();
        e / (1.0 + e)
    }
}

/// Backward of `reference::graph_norm_relu` for one layer: given dL/dH
/// at the layer output, writes dL/dYpre and *accumulates* into the
/// caller's `dgamma`/`dbeta` (zero-initialized by the direct path;
/// pointed straight at the zeroed gradient accumulator by the planned
/// path — same accumulation order either way, hence identical bits).
/// `hn`/`dhat` are caller-held `[max_nodes]` scratch, fully overwritten
/// per (graph, feature) group before any read — the planned path serves
/// them from the workspace arena instead of allocating per layer.
/// Statistics (masked mean/var, normalized values) are recomputed from
/// the cached pre-norm activations in the same operation order as the
/// forward.
///
/// Per (graph, feature) group with mask weights `w_r`, count `N`,
/// `inv = 1/sqrt(var + EPS)` and gate `[v_r > 0]`:
/// `dŷ_r = gate_r · dh_r · γ · w_r`, `S1 = Σ dŷ`, `S2 = Σ dŷ·ĥ`, and
/// `dYpre_r = inv · (dŷ_r − w_r · (S1 + ĥ_r · S2) / N)` — the standard
/// normalization backward, with the mask zeroing both the padded rows'
/// own gradients and their (non-existent) contribution to the
/// statistics.
#[allow(clippy::too_many_arguments)]
fn graph_norm_relu_backward(
    ypre: &[f32],
    mask: &[f32],
    gamma: &[f32],
    beta: &[f32],
    dh: &[f32],
    dypre: &mut [f32],
    b: usize,
    m: usize,
    f: usize,
    dgamma: &mut [f32],
    dbeta: &mut [f32],
    hn: &mut [f32],
    dhat: &mut [f32],
) {
    debug_assert!(dgamma.len() == f && dbeta.len() == f);
    debug_assert!(hn.len() >= m && dhat.len() >= m);
    for bi in 0..b {
        let msk = &mask[bi * m..(bi + 1) * m];
        let cnt = msk.iter().sum::<f32>().max(1.0);
        let rows = &ypre[bi * m * f..(bi + 1) * m * f];
        let drows = &dh[bi * m * f..(bi + 1) * m * f];
        let orows = &mut dypre[bi * m * f..(bi + 1) * m * f];
        for j in 0..f {
            let mut mean = 0f32;
            for r in 0..m {
                mean += rows[r * f + j] * msk[r];
            }
            mean /= cnt;
            let mut var = 0f32;
            for r in 0..m {
                let d = rows[r * f + j] - mean;
                var += d * d * msk[r];
            }
            var /= cnt;
            let inv = 1.0 / (var + EPS).sqrt();
            let mut s1 = 0f32;
            let mut s2 = 0f32;
            for r in 0..m {
                let h = (rows[r * f + j] - mean) * inv;
                hn[r] = h;
                let v = (gamma[j] * h + beta[j]) * msk[r];
                let gate = if v > 0.0 { drows[r * f + j] } else { 0.0 };
                dgamma[j] += gate * h * msk[r];
                dbeta[j] += gate * msk[r];
                let dn = gate * gamma[j] * msk[r];
                dhat[r] = dn;
                s1 += dn;
                s2 += dn * h;
            }
            for r in 0..m {
                orows[r * f + j] = inv * (dhat[r] - msk[r] * (s1 + hn[r] * s2) / cnt);
            }
        }
    }
}

// ---------------------------------------------------------------------
// Plan/execute split (DESIGN.md §11)
// ---------------------------------------------------------------------

/// Cache key for a train plan of this batch shape.
pub fn train_plan_key(cfg: &ModelConfig, mb: &ModelBatch) -> GeometryKey {
    reference::geometry_key(cfg, mb, reference::MODE_TRAIN, DType::F32)
}

/// Workspace slot ids of a train plan: the forward slots
/// ([`reference::fwd_slot_ids`]) followed by the backward
/// intermediates, fixed by construction order so builders and
/// replayers derive identical ids from the config alone.
struct TrainSlots {
    ypre: Vec<SlotId>,
    dlogits: SlotId,
    pooled: SlotId,
    drow: SlotId,
    dh: SlotId,
    dx: SlotId,
    du: SlotId,
    dypre: SlotId,
    /// Pre-transposed weight scratch — replaces the executor's
    /// per-dispatch `SharedTransposed` materialization allocation.
    wt: SlotId,
    hn: SlotId,
    dhat: SlotId,
}

fn train_slot_ids(cfg: &ModelConfig) -> TrainSlots {
    let l = cfg.hidden.len() as u32;
    // Forward slots occupy 0..=l+1 (u, act[0..l], logits).
    let base = l + 2;
    TrainSlots {
        ypre: (0..l).map(|i| SlotId(base + i)).collect(),
        dlogits: SlotId(base + l),
        pooled: SlotId(base + l + 1),
        drow: SlotId(base + l + 2),
        dh: SlotId(base + l + 3),
        dx: SlotId(base + l + 4),
        du: SlotId(base + l + 5),
        dypre: SlotId(base + l + 6),
        wt: SlotId(base + l + 7),
        hn: SlotId(base + l + 8),
        dhat: SlotId(base + l + 9),
    }
}

/// Compile a full train step (forward replay + backward walk) for this
/// geometry: the forward plan extended with the backward slots,
/// the `readout.w` parameter ref, and the 22 backward dispatch
/// descriptors in issue order. Replay via [`grad_planned`].
pub fn plan_train(
    cfg: &ModelConfig,
    mb: &ModelBatch,
    th: &AutoThresholds,
) -> anyhow::Result<StepPlan> {
    let mut plan = StepPlan::new(train_plan_key(cfg, mb));
    reference::plan_forward_into(cfg, mb, th, DType::F32, &mut plan)?;
    let b = mb.batch;
    let m = cfg.max_nodes;
    let n_out = cfg.n_out;
    let fin_last = *cfg.hidden.last().unwrap_or(&cfg.feat_dim);
    let max_f = reference::max_feat(cfg);
    let sl = train_slot_ids(cfg);

    for (li, &fout) in cfg.hidden.iter().enumerate() {
        let id = plan.add_slot(b * m * fout);
        debug_assert_eq!(id, sl.ypre[li]);
    }
    debug_assert_eq!(plan.add_slot(b * n_out), sl.dlogits);
    debug_assert_eq!(plan.add_slot(b * fin_last), sl.pooled);
    debug_assert_eq!(plan.add_slot(b * fin_last), sl.drow);
    // dh and dx swap buffers every layer, so both declare the widest
    // feature dimension either ever carries.
    debug_assert_eq!(plan.add_slot(b * m * max_f), sl.dh);
    debug_assert_eq!(plan.add_slot(b * m * max_f), sl.dx);
    debug_assert_eq!(plan.add_slot(b * m * max_f), sl.du);
    debug_assert_eq!(plan.add_slot(b * m * max_f), sl.dypre);
    let mut wt_len = n_out * fin_last;
    let mut fin = cfg.feat_dim;
    for &fout in &cfg.hidden {
        wt_len = wt_len.max(fin * fout);
        fin = fout;
    }
    debug_assert_eq!(plan.add_slot(wt_len), sl.wt);
    debug_assert_eq!(plan.add_slot(m), sl.hn);
    debug_assert_eq!(plan.add_slot(m), sl.dhat);

    // Forward params end at readout.b; the backward additionally reads
    // (and writes the gradient of) readout.w.
    let rw = cfg.param("readout.w")?;
    let idx = plan.add_param(rw.offset, rw.size);
    debug_assert_eq!(idx, reference::p_readout_w(cfg));

    // Backward descriptors, in grad_with's dispatch order.
    plan.add_dispatch(DispatchDesc {
        backend: Backend::Gemm,
        transpose: true,
        rhs: RhsKind::Shared,
        n: n_out as u32,
        out: SlotId::NONE, // dW_out accumulates into the grads buffer
        dtype: DType::F32,
    });
    plan.add_dispatch(DispatchDesc {
        backend: Backend::Gemm,
        transpose: false,
        rhs: RhsKind::SharedTransposed,
        n: fin_last as u32,
        out: sl.drow,
        dtype: DType::F32,
    });
    for li in (0..cfg.hidden.len()).rev() {
        let fout = cfg.hidden[li];
        let fin = if li == 0 {
            cfg.feat_dim
        } else {
            cfg.hidden[li - 1]
        };
        for ch in 0..cfg.channels {
            plan.add_dispatch(DispatchDesc {
                backend: reference::adjacency_backend(mb, ch, th)?,
                transpose: true,
                rhs: RhsKind::PerSample,
                n: fout as u32,
                out: sl.du,
                dtype: DType::F32,
            });
            plan.add_dispatch(DispatchDesc {
                backend: Backend::Gemm,
                transpose: true,
                rhs: RhsKind::Shared,
                n: fout as u32,
                out: SlotId::NONE, // dW_ch accumulates into the grads buffer
                dtype: DType::F32,
            });
            if li > 0 {
                plan.add_dispatch(DispatchDesc {
                    backend: Backend::Gemm,
                    transpose: false,
                    rhs: RhsKind::SharedTransposed,
                    n: fin as u32,
                    out: sl.dx,
                    dtype: DType::F32,
                });
            }
        }
    }
    Ok(plan)
}

/// Two disjoint mutable parameter slices of the flat gradient buffer
/// (the γ/β pair the norm backward fills together).
fn two_grad_slices<'a>(
    grads: &'a mut [f32],
    a: ParamRef,
    b: ParamRef,
) -> (&'a mut [f32], &'a mut [f32]) {
    assert!(
        a.offset + a.len <= b.offset || b.offset + b.len <= a.offset,
        "overlapping parameter refs"
    );
    if a.offset < b.offset {
        let (lo, hi) = grads.split_at_mut(b.offset as usize);
        (&mut lo[a.range()], &mut hi[..b.len as usize])
    } else {
        let (lo, hi) = grads.split_at_mut(a.offset as usize);
        let blo = &mut lo[b.range()];
        (&mut hi[..a.len as usize], blo)
    }
}

/// Replay a compiled train plan: loss + full parameter gradient,
/// bit-identical to [`grad_with`] on the same executor, with every
/// intermediate drawn from the workspace and the gradient accumulated
/// into the caller's flat `grads` buffer (`cfg.n_params` long, zeroed
/// here). Steady-state replays allocate no intermediate buffer — only
/// O(1) fixed-size bookkeeping (the key check and the act/ypre handle
/// vectors) remains per step.
#[allow(clippy::too_many_arguments)]
pub fn grad_planned(
    cfg: &ModelConfig,
    ps: &ParamSet,
    mb: &ModelBatch,
    exec: &Executor,
    w_rep: &[f32],
    plan: &StepPlan,
    ws: &mut Workspace,
    grads: &mut [f32],
) -> anyhow::Result<f32> {
    anyhow::ensure!(mb.batch > 0, "gradient of an empty batch");
    anyhow::ensure!(
        plan.key == train_plan_key(cfg, mb),
        "stale train plan: geometry changed without a rebuild"
    );
    anyhow::ensure!(grads.len() == cfg.n_params, "gradient buffer length");
    grads.fill(0.0);
    let sl = train_slot_ids(cfg);
    let mut cursor = PlanCursor::new(plan);
    let f = reference::forward_planned_core(
        cfg,
        ps,
        mb,
        exec,
        w_rep,
        plan,
        ws,
        &mut cursor,
        &sl.ypre,
        None,
    )?;
    let b = mb.batch;
    let m = cfg.max_nodes;
    let n_out = cfg.n_out;
    let loss = reference::loss(cfg, &f.logits, &mb.labels, b);

    // ---- loss -> dlogits (elementwise, no matmul) -----------------------
    let mut dlogits = ws.take(sl.dlogits, b * n_out, SlotInit::Overwrite);
    loss_grad_into(cfg, &f.logits, &mb.labels, b, &mut dlogits);

    // ---- readout head backward (2 engine dispatches) --------------------
    let fin_last = *cfg.hidden.last().unwrap_or(&cfg.feat_dim);
    let h_last: &[f32] = f.acts.last().map_or(&mb.x[..], |v| &v[..]);
    let p_rw = plan.param(reference::p_readout_w(cfg));
    // d b_out: column sums of dlogits (the bias is added once per sample).
    {
        let gb = &mut grads[plan.param(reference::p_readout_b(cfg)).range()];
        for row in dlogits.chunks(n_out) {
            for (o, v) in row.iter().enumerate() {
                gb[o] += v;
            }
        }
    }
    // d W_out = P^T @ dlogits with P[b,:] = Σ_r h[b,r,:] (sum-pool):
    // one batch-1 transpose GEMM over the pooled [B, fin] view.
    let mut pooled = ws.take(sl.pooled, b * fin_last, SlotInit::Zeroed);
    for bi in 0..b {
        let dst = &mut pooled[bi * fin_last..(bi + 1) * fin_last];
        for r in 0..m {
            let row = &h_last[(bi * m + r) * fin_last..(bi * m + r + 1) * fin_last];
            for (k, v) in row.iter().enumerate() {
                dst[k] += v;
            }
        }
    }
    {
        let d = cursor.dispatch();
        debug_assert!(d.backend == Backend::Gemm && d.transpose);
        let pk = GemmKernel::new(&pooled, 1, b, fin_last);
        let gw = &mut grads[p_rw.range()];
        exec.dispatch_t(&pk, Rhs::Shared(&dlogits), d.n as usize, gw)?;
    }
    // d h: the readout sums rows, so every row of sample b gets
    // dlogits[b] @ W_out^T — one X·W^T dispatch (against the
    // pre-transposed weight slot), then a row broadcast.
    let mut wt = ws.take(sl.wt, n_out * fin_last, SlotInit::Overwrite);
    let mut drow = ws.take(sl.drow, b * fin_last, SlotInit::Zeroed);
    {
        let d = cursor.dispatch();
        debug_assert_eq!(d.rhs, RhsKind::SharedTransposed);
        let w_out = &ps.data[p_rw.range()];
        transpose_into(w_out, n_out, fin_last, &mut wt);
        let dk = GemmKernel::new(&dlogits, b, 1, n_out);
        exec.dispatch(&dk, Rhs::Shared(&wt[..n_out * fin_last]), d.n as usize, &mut drow)?;
    }
    let mut dh = ws.take(sl.dh, b * m * fin_last, SlotInit::Overwrite);
    for bi in 0..b {
        let src = &drow[bi * fin_last..(bi + 1) * fin_last];
        for r in 0..m {
            dh[(bi * m + r) * fin_last..(bi * m + r + 1) * fin_last].copy_from_slice(src);
        }
    }

    // ---- conv layers, last to first ------------------------------------
    // 3 dispatches per channel; the first layer skips dX and issues 2.
    let mut dx = ws.take(sl.dx, b * m * reference::max_feat(cfg), SlotInit::Overwrite);
    let mut du = ws.take(sl.du, b * m * reference::max_feat(cfg), SlotInit::Overwrite);
    let mut dypre = ws.take(sl.dypre, b * m * reference::max_feat(cfg), SlotInit::Overwrite);
    let mut hn = ws.take(sl.hn, m, SlotInit::Overwrite);
    let mut dhat = ws.take(sl.dhat, m, SlotInit::Overwrite);
    for li in (0..cfg.hidden.len()).rev() {
        let fout = cfg.hidden[li];
        let fin = if li == 0 {
            cfg.feat_dim
        } else {
            cfg.hidden[li - 1]
        };
        let x: &[f32] = if li == 0 { &mb.x } else { &f.acts[li - 1] };
        let ypre = &f.ypre[li];
        let gamma = &ps.data[plan.param(reference::p_gamma(li)).range()];
        let beta = &ps.data[plan.param(reference::p_beta(li)).range()];

        // GraphNorm + ReLU backward: dL/dH -> dL/dYpre (host-side),
        // with dγ/dβ accumulated straight into the gradient buffer.
        reference::fit(&mut dypre, b * m * fout);
        {
            let (dgamma, dbeta) = two_grad_slices(
                grads,
                plan.param(reference::p_gamma(li)),
                plan.param(reference::p_beta(li)),
            );
            graph_norm_relu_backward(
                ypre, &mb.mask, gamma, beta, &dh, &mut dypre, b, m, fout, dgamma, dbeta, &mut hn,
                &mut dhat,
            );
        }

        let w = &ps.data[plan.param(reference::p_w(li)).range()];
        if li > 0 {
            reference::fit(&mut dx, b * m * fin);
            dx.fill(0.0);
        }
        for ch in 0..cfg.channels {
            // dU = A_ch^T @ dYpre — batched transpose dispatch on the
            // plan's resolved adjacency backend.
            let backend = cursor.dispatch().backend;
            reference::fit(&mut du, b * m * fout);
            du.fill(0.0);
            match backend {
                Backend::Ell => {
                    let adj = EllKernel::channel(mb, ch);
                    exec.dispatch_t(&adj, Rhs::PerSample(&dypre), fout, &mut du)?;
                }
                other => anyhow::bail!("adjacency planned on unpacked backend {other}"),
            }
            // d bias_ch: row sums of dU (the bias broadcasts over rows).
            {
                let pb = plan.param(reference::p_b(li));
                let gb = &mut grads[pb.range()][ch * fout..(ch + 1) * fout];
                for row in du.chunks(fout) {
                    for (o, v) in row.iter().enumerate() {
                        gb[o] += v;
                    }
                }
            }
            // d W_ch = X^T @ dU with all samples stacked: one batch-1
            // transpose GEMM over the [B*M, fin] view of X, straight
            // into the gradient buffer.
            {
                let d = cursor.dispatch();
                debug_assert!(d.backend == Backend::Gemm && d.transpose);
                let xk = GemmKernel::new(x, 1, b * m, fin);
                let pw = plan.param(reference::p_w(li));
                let gw = &mut grads[pw.range()][ch * fin * fout..(ch + 1) * fin * fout];
                exec.dispatch_t(&xk, Rhs::Shared(&du), d.n as usize, gw)?;
            }
            // dX += dU @ W_ch^T — the X·W^T form against the
            // pre-transposed weight slot, accumulating across channels
            // through the engine's `+=` contract. The first layer's
            // input is the data, which needs no gradient.
            if li > 0 {
                let d = cursor.dispatch();
                debug_assert_eq!(d.rhs, RhsKind::SharedTransposed);
                let w_ch = &w[ch * fin * fout..(ch + 1) * fin * fout];
                reference::fit(&mut wt, fout * fin);
                transpose_into(w_ch, fout, fin, &mut wt);
                let duk = GemmKernel::new(&du, b, m, fout);
                exec.dispatch(&duk, Rhs::Shared(&wt[..fout * fin]), d.n as usize, &mut dx)?;
            }
        }
        if li > 0 {
            std::mem::swap(&mut dh, &mut dx);
        }
    }
    cursor.finish();

    ws.put(sl.dlogits, dlogits);
    ws.put(sl.pooled, pooled);
    ws.put(sl.drow, drow);
    ws.put(sl.dh, dh);
    ws.put(sl.dx, dx);
    ws.put(sl.du, du);
    ws.put(sl.dypre, dypre);
    ws.put(sl.wt, wt);
    ws.put(sl.hn, hn);
    ws.put(sl.dhat, dhat);
    reference::restore_planned_fwd(cfg, ws, &sl.ypre, f);
    Ok(loss)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::dataset::{Dataset, DatasetKind};

    fn setup(n: usize, seed: u64) -> (ModelConfig, ParamSet, Dataset) {
        let cfg = ModelConfig::synthetic("tox21").unwrap();
        let ps = ParamSet::random_init(&cfg, seed);
        let data = Dataset::generate(DatasetKind::Tox21, n, seed);
        (cfg, ps, data)
    }

    #[test]
    fn cached_forward_matches_plain_forward() {
        let (cfg, ps, data) = setup(4, 3);
        let mb = data.pack_batch(&[0, 1, 2], cfg.max_nodes, cfg.ell_width).unwrap();
        let w_rep = reference::build_w_rep(&cfg, &ps).unwrap();
        let plain = reference::forward(&cfg, &ps, &mb).unwrap();
        let cache =
            forward_cached(&cfg, &ps, &mb, &Executor::serial(), &w_rep).unwrap();
        assert_eq!(plain, cache.logits);
        assert_eq!(cache.acts.len(), cfg.hidden.len() + 1);
        assert_eq!(cache.ypre.len(), cfg.hidden.len());
    }

    #[test]
    fn grad_shapes_and_finiteness() {
        let (cfg, ps, data) = setup(3, 5);
        let mb = data.pack_batch(&[0, 1], cfg.max_nodes, cfg.ell_width).unwrap();
        let res = grad(&cfg, &ps, &mb).unwrap();
        assert_eq!(res.grads.data.len(), cfg.n_params);
        assert!(res.loss.is_finite() && res.loss > 0.0);
        assert!(res.grads.data.iter().all(|v| v.is_finite()));
        assert!(res.grads.data.iter().any(|v| *v != 0.0));
        // 17 forward + 22 backward dispatches for the tox21 geometry
        // (DESIGN.md §8): 2·CH·L + 1 and CH·(3L − 1) + 2 with CH=4,
        // L=2 (no dX dispatch on the first layer — data needs no grad).
        assert_eq!(res.dispatches, 17 + 22);
    }

    #[test]
    fn grad_parallel_is_bitwise_deterministic() {
        let (cfg, ps, data) = setup(6, 7);
        let idx: Vec<usize> = (0..6).collect();
        let mb = data.pack_batch(&idx, cfg.max_nodes, cfg.ell_width).unwrap();
        let serial = grad(&cfg, &ps, &mb).unwrap();
        for threads in [2, 8] {
            let par =
                grad_with(&cfg, &ps, &mb, &Executor::new(threads), None).unwrap();
            assert_eq!(serial.grads.data, par.grads.data, "threads={threads}");
            assert_eq!(serial.loss, par.loss);
        }
    }

    #[test]
    fn loss_grad_matches_finite_difference_of_loss() {
        // Pin the loss->logits gradient on its own (both loss kinds),
        // independent of the model layers.
        for (loss_kind, n, seed) in [("bce", 12usize, 1u64), ("softmax", 5usize, 2u64)] {
            let cfg = crate::util::json::parse(&format!(
                r#"{{
 "name": "t", "max_nodes": 4, "feat_dim": 2, "channels": 1, "hidden": [2],
 "n_out": {n}, "loss": "{loss_kind}", "nnz_cap": 4, "ell_width": 3,
 "train_batch": 2, "infer_batch": 2, "n_params": 0, "params": [],
 "init_file": "x", "artifact_fwd_infer": "x", "artifact_fwd_train": "x",
 "artifact_fwd_sample": "x", "artifact_train_step": "x",
 "artifact_grad_sample": "x", "artifact_apply_sgd": "x"}}"#
            ))
            .and_then(|j| ModelConfig::from_json(&j))
            .unwrap();
            let mut rng = crate::util::rng::Rng::new(seed);
            let batch = 3usize;
            let logits: Vec<f32> = (0..batch * n).map(|_| rng.normal()).collect();
            let labels: Vec<f32> = (0..batch * n)
                .map(|_| if rng.bool(0.3) { 1.0 } else { 0.0 })
                .collect();
            let g = loss_grad(&cfg, &logits, &labels, batch);
            let eps = 1e-2f32;
            for i in 0..batch * n {
                let mut lp = logits.clone();
                lp[i] += eps;
                let mut lm = logits.clone();
                lm[i] -= eps;
                let fd = (reference::loss(&cfg, &lp, &labels, batch)
                    - reference::loss(&cfg, &lm, &labels, batch))
                    / (2.0 * eps);
                assert!(
                    (g[i] - fd).abs() <= 1e-4 + 1e-3 * fd.abs(),
                    "{loss_kind} logit {i}: analytic {} vs fd {fd}",
                    g[i]
                );
            }
        }
    }
}
