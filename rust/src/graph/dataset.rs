//! Synthetic datasets matched to Table I, with learnable labels,
//! k-fold splits, and packing into the model artifacts' input tensors.

use super::featurize::{featurize, FEAT_DIM};
use super::molecule::{Molecule, MoleculeSpec, N_BOND_TYPES, N_ELEMENTS};
use crate::sparse::coo::Coo;
use crate::util::rng::Rng;

/// Which paper dataset this synthetic set stands in for (Table I).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DatasetKind {
    /// 7,862 molecules, 12 binary toxicity tasks, train batch 50.
    Tox21,
    /// 75,477 molecules, 100 reaction classes, train batch 100.
    Reaction100,
}

impl DatasetKind {
    pub fn paper_size(&self) -> usize {
        match self {
            DatasetKind::Tox21 => 7_862,
            DatasetKind::Reaction100 => 75_477,
        }
    }

    pub fn n_out(&self) -> usize {
        match self {
            DatasetKind::Tox21 => 12,
            DatasetKind::Reaction100 => 100,
        }
    }

    pub fn model_name(&self) -> &'static str {
        match self {
            DatasetKind::Tox21 => "tox21",
            DatasetKind::Reaction100 => "reaction100",
        }
    }
}

#[derive(Clone, Debug)]
pub struct Sample {
    pub mol: Molecule,
    /// Tox21: 12 bits; Reaction100: one-hot over 100 classes.
    pub label: Vec<f32>,
}

#[derive(Clone, Debug)]
pub struct Dataset {
    pub kind: DatasetKind,
    pub samples: Vec<Sample>,
}

impl Dataset {
    /// Generate `n` samples (use `kind.paper_size()` for full fidelity;
    /// tests and quick benches use smaller n).
    pub fn generate(kind: DatasetKind, n: usize, seed: u64) -> Dataset {
        let mut rng = Rng::new(seed);
        let spec = MoleculeSpec::default();
        let samples = (0..n)
            .map(|_| {
                let mol = Molecule::random(&mut rng, &spec);
                let label = match kind {
                    DatasetKind::Tox21 => tox21_label(&mol, &mut rng),
                    DatasetKind::Reaction100 => reaction_label(&mol),
                };
                Sample { mol, label }
            })
            .collect();
        Dataset { kind, samples }
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// K-fold split (paper §V-B: k = 5): returns (train, test) index sets
    /// for the given fold.
    pub fn kfold(&self, k: usize, fold: usize) -> (Vec<usize>, Vec<usize>) {
        assert!(k >= 2 && fold < k);
        let n = self.len();
        let lo = n * fold / k;
        let hi = n * (fold + 1) / k;
        let test: Vec<usize> = (lo..hi).collect();
        let train: Vec<usize> = (0..lo).chain(hi..n).collect();
        (train, test)
    }

    /// Pack samples[idx] into one model-artifact input batch.
    /// `max_nodes`/`ell_width` come from the model geometry (manifest).
    pub fn pack_batch(
        &self,
        idx: &[usize],
        max_nodes: usize,
        ell_width: usize,
    ) -> anyhow::Result<ModelBatch> {
        let b = idx.len();
        let n_out = self.kind.n_out();
        let ch = N_BOND_TYPES;
        let mut mb = ModelBatch::zeros(b, ch, max_nodes, ell_width, n_out);
        for (bi, &si) in idx.iter().enumerate() {
            let sample = &self.samples[si];
            mb.fill_sample(bi, &sample.mol, Some(&sample.label))?;
        }
        Ok(mb)
    }
}

/// Fill one ELL (row-major padded) adjacency channel from a COO matrix.
/// Slot layout per row: entries in insertion order; val 0 = padding.
fn coo_to_ell(
    a: &Coo,
    cols: &mut [i32],
    vals: &mut [f32],
    max_nodes: usize,
    r: usize,
) -> anyhow::Result<()> {
    let mut fill = vec![0usize; max_nodes];
    for i in 0..a.nnz() {
        let row = a.row_ids[i] as usize;
        let slot = fill[row];
        anyhow::ensure!(
            slot < r,
            "row {row} has more than ell_width={r} non-zeros"
        );
        cols[row * r + slot] = a.col_ids[i] as i32;
        vals[row * r + slot] = a.vals[i];
        fill[row] += 1;
    }
    Ok(())
}

/// Pack bare molecules (no labels) for serving-path inference.
/// Slots beyond `mols.len()` are padding: empty adjacency, zero
/// features, zero mask — inert through the whole model.
pub fn pack_molecules(
    mols: &[&Molecule],
    capacity: usize,
    max_nodes: usize,
    ell_width: usize,
    n_out: usize,
) -> anyhow::Result<ModelBatch> {
    anyhow::ensure!(mols.len() <= capacity, "batch overflow");
    let mut mb = ModelBatch::zeros(capacity, N_BOND_TYPES, max_nodes, ell_width, n_out);
    for (bi, mol) in mols.iter().enumerate() {
        mb.fill_sample(bi, mol, None)?;
    }
    Ok(mb)
}

/// One packed minibatch in the model artifacts' ABI:
/// ell_cols [B,CH,M,R] i32, ell_vals [B,CH,M,R] f32, x [B,M,F],
/// mask [B,M], labels [B,n_out] (all row-major flat).
///
/// ELL (padded per-row) adjacency is the model's hot-path format
/// (gather-only SpMM — EXPERIMENTS.md §Perf iteration 3); the figure
/// benches keep the paper's ST/CSR formats.
#[derive(Clone, Debug)]
pub struct ModelBatch {
    pub batch: usize,
    pub channels: usize,
    pub ell_width: usize,
    pub max_nodes: usize,
    pub feat_dim: usize,
    pub n_out: usize,
    pub ell_cols: Vec<i32>,
    pub ell_vals: Vec<f32>,
    /// Real (non-padding) non-zeros per `[B, CH]` adjacency plane,
    /// counted once at pack time so the engine's per-channel ELL views
    /// answer `BatchedSpmm::sample_nnz` in O(1) on every cost-model
    /// scan instead of rescanning `M * R` slots (DESIGN.md §10).
    pub ell_nnz: Vec<u32>,
    pub x: Vec<f32>,
    pub mask: Vec<f32>,
    pub labels: Vec<f32>,
}

impl ModelBatch {
    pub fn zeros(
        batch: usize,
        channels: usize,
        max_nodes: usize,
        ell_width: usize,
        n_out: usize,
    ) -> ModelBatch {
        ModelBatch {
            batch,
            channels,
            ell_width,
            max_nodes,
            feat_dim: FEAT_DIM,
            n_out,
            ell_cols: vec![0i32; batch * channels * max_nodes * ell_width],
            ell_vals: vec![0f32; batch * channels * max_nodes * ell_width],
            ell_nnz: vec![0u32; batch * channels],
            x: vec![0f32; batch * max_nodes * FEAT_DIM],
            mask: vec![0f32; batch * max_nodes],
            labels: vec![0f32; batch * n_out],
        }
    }

    /// Pack one molecule (and optional label) into slot `bi`.
    pub fn fill_sample(
        &mut self,
        bi: usize,
        mol: &Molecule,
        label: Option<&[f32]>,
    ) -> anyhow::Result<()> {
        assert!(bi < self.batch);
        anyhow::ensure!(mol.n_atoms <= self.max_nodes, "molecule too large");
        let (m, r) = (self.max_nodes, self.ell_width);
        for (ci, a) in mol.adjacency().iter().enumerate() {
            let base = (bi * self.channels + ci) * m * r;
            coo_to_ell(
                a,
                &mut self.ell_cols[base..base + m * r],
                &mut self.ell_vals[base..base + m * r],
                m,
                r,
            )?;
            // Explicit zero values pack like padding slots; count what a
            // scan of the plane would see.
            self.ell_nnz[bi * self.channels + ci] =
                a.vals.iter().filter(|v| **v != 0.0).count() as u32;
        }
        let (fx, fm) = featurize(mol, m);
        self.x[bi * m * FEAT_DIM..(bi + 1) * m * FEAT_DIM].copy_from_slice(&fx);
        self.mask[bi * m..(bi + 1) * m].copy_from_slice(&fm);
        if let Some(l) = label {
            self.labels[bi * self.n_out..(bi + 1) * self.n_out].copy_from_slice(l);
        }
        Ok(())
    }

    /// Slice out sample `b` as a batch of 1 (the non-batched dispatch
    /// mode's unit of work).
    pub fn single(&self, b: usize) -> ModelBatch {
        assert!(b < self.batch);
        let sl = |v: &[f32], per: usize| v[b * per..(b + 1) * per].to_vec();
        let per_adj = self.channels * self.max_nodes * self.ell_width;
        ModelBatch {
            batch: 1,
            channels: self.channels,
            ell_width: self.ell_width,
            max_nodes: self.max_nodes,
            feat_dim: self.feat_dim,
            n_out: self.n_out,
            ell_cols: self.ell_cols[b * per_adj..(b + 1) * per_adj].to_vec(),
            ell_vals: sl(&self.ell_vals, per_adj),
            ell_nnz: self.ell_nnz[b * self.channels..(b + 1) * self.channels].to_vec(),
            x: sl(&self.x, self.max_nodes * self.feat_dim),
            mask: sl(&self.mask, self.max_nodes),
            labels: sl(&self.labels, self.n_out),
        }
    }
}

/// Tox21-like labels: 12 binary tasks, each a threshold on a structural
/// statistic, with 5% label noise. Learnable from features.
fn tox21_label(mol: &Molecule, rng: &mut Rng) -> Vec<f32> {
    let n = mol.n_atoms as f32;
    let rings = mol.bonds.len().saturating_sub(mol.n_atoms - 1) as f32;
    let mean_deg =
        mol.bonds.len() as f32 * 2.0 / n.max(1.0);
    let mut out = Vec::with_capacity(12);
    for task in 0..12 {
        let raw = match task % 4 {
            0 => mol.element_count(1 + task / 4) as f32 / n - 0.08,
            1 => rings - 1.5,
            2 => mean_deg - 2.1,
            _ => n - 25.0,
        };
        let mut bit = raw > 0.0;
        if rng.bool(0.05) {
            bit = !bit;
        }
        out.push(if bit { 1.0 } else { 0.0 });
    }
    out
}

/// Reaction100-like labels: class index from the dominant bonded element
/// pair — a deterministic structural function, one-hot over 100 classes.
fn reaction_label(mol: &Molecule) -> Vec<f32> {
    let (a, b) = mol.dominant_bond_pair();
    let class = (a * N_ELEMENTS + b) % 100;
    let mut out = vec![0f32; 100];
    out[class] = 1.0;
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generation_deterministic() {
        let a = Dataset::generate(DatasetKind::Tox21, 20, 7);
        let b = Dataset::generate(DatasetKind::Tox21, 20, 7);
        assert_eq!(a.samples[5].label, b.samples[5].label);
        assert_eq!(a.samples[5].mol.n_atoms, b.samples[5].mol.n_atoms);
    }

    #[test]
    fn kfold_partitions() {
        let d = Dataset::generate(DatasetKind::Tox21, 103, 1);
        let mut seen = vec![0usize; d.len()];
        for fold in 0..5 {
            let (train, test) = d.kfold(5, fold);
            assert_eq!(train.len() + test.len(), d.len());
            for &i in &test {
                seen[i] += 1;
            }
            let tset: std::collections::HashSet<_> = test.iter().collect();
            assert!(train.iter().all(|i| !tset.contains(i)));
        }
        assert!(seen.iter().all(|&c| c == 1), "each sample in exactly one test fold");
    }

    #[test]
    fn labels_have_both_classes() {
        let d = Dataset::generate(DatasetKind::Tox21, 200, 2);
        for task in 0..12 {
            let pos: usize = d
                .samples
                .iter()
                .map(|s| s.label[task] as usize)
                .sum();
            assert!(pos > 0 && pos < 200, "task {task} degenerate: {pos}/200");
        }
    }

    #[test]
    fn reaction_labels_one_hot_and_varied() {
        let d = Dataset::generate(DatasetKind::Reaction100, 300, 3);
        let mut classes = std::collections::HashSet::new();
        for s in &d.samples {
            assert_eq!(s.label.iter().sum::<f32>(), 1.0);
            classes.insert(s.label.iter().position(|&v| v == 1.0).unwrap());
        }
        assert!(classes.len() > 5, "only {} classes", classes.len());
    }

    #[test]
    fn pack_batch_shapes_and_padding() {
        let d = Dataset::generate(DatasetKind::Tox21, 10, 4);
        let mb = d.pack_batch(&[0, 3, 7], 50, 12).unwrap();
        assert_eq!(mb.batch, 3);
        assert_eq!(mb.ell_cols.len(), 3 * 4 * 50 * 12);
        assert_eq!(mb.ell_vals.len(), 3 * 4 * 50 * 12);
        assert_eq!(mb.x.len(), 3 * 50 * FEAT_DIM);
        assert_eq!(mb.labels.len(), 3 * 12);
        // mask matches molecule sizes
        for (bi, &si) in [0usize, 3, 7].iter().enumerate() {
            let n = d.samples[si].mol.n_atoms;
            let m = &mb.mask[bi * 50..(bi + 1) * 50];
            assert_eq!(m.iter().filter(|&&v| v == 1.0).count(), n);
        }
    }

    #[test]
    fn ell_encodes_adjacency_exactly() {
        // Round-trip: unpack the ELL arrays back into a dense adjacency
        // and compare against the molecule's per-channel dense form.
        let d = Dataset::generate(DatasetKind::Tox21, 4, 6);
        let mb = d.pack_batch(&[2], 50, 12).unwrap();
        let adj = d.samples[2].mol.adjacency();
        let (m, r) = (50usize, 12usize);
        for (ci, a) in adj.iter().enumerate() {
            let dense = a.to_dense();
            let base = ci * m * r;
            let mut recon = vec![0f32; m * m];
            for row in 0..m {
                for slot in 0..r {
                    let v = mb.ell_vals[base + row * r + slot];
                    if v != 0.0 {
                        let c = mb.ell_cols[base + row * r + slot] as usize;
                        recon[row * m + c] += v;
                    }
                }
            }
            for row in 0..a.rows {
                for c in 0..a.cols {
                    assert_eq!(recon[row * m + c], dense.at(row, c), "ch {ci} ({row},{c})");
                }
            }
        }
    }

    #[test]
    fn cached_channel_nnz_matches_plane_scan() {
        // The pack-time per-(sample, channel) counts must equal a
        // from-scratch scan of each ELL plane — the O(1) cost-model
        // contract the engine's channel views rely on (DESIGN.md §10).
        let d = Dataset::generate(DatasetKind::Tox21, 10, 11);
        let mb = d.pack_batch(&[0, 2, 5, 9], 50, 12).unwrap();
        let (m, r) = (50usize, 12usize);
        for bi in 0..mb.batch {
            for ci in 0..mb.channels {
                let base = (bi * mb.channels + ci) * m * r;
                let scan = mb.ell_vals[base..base + m * r]
                    .iter()
                    .filter(|v| **v != 0.0)
                    .count();
                assert_eq!(
                    mb.ell_nnz[bi * mb.channels + ci] as usize,
                    scan,
                    "sample {bi} channel {ci}"
                );
            }
        }
        let s = mb.single(2);
        assert_eq!(
            s.ell_nnz,
            mb.ell_nnz[2 * mb.channels..3 * mb.channels].to_vec()
        );
    }

    #[test]
    fn ell_width_overflow_rejected() {
        let d = Dataset::generate(DatasetKind::Tox21, 4, 6);
        // width 1 cannot hold self loop + any bond
        assert!(d.pack_batch(&[0], 50, 1).is_err());
    }

    #[test]
    fn single_slices_match_batch() {
        let d = Dataset::generate(DatasetKind::Reaction100, 6, 5);
        let mb = d.pack_batch(&[1, 2, 4], 50, 12).unwrap();
        let s = mb.single(1);
        assert_eq!(s.batch, 1);
        assert_eq!(s.labels, mb.labels[100..200].to_vec());
        assert_eq!(s.x, mb.x[50 * FEAT_DIM..2 * 50 * FEAT_DIM].to_vec());
        let per_adj = 4 * 50 * 12;
        assert_eq!(s.ell_vals, mb.ell_vals[per_adj..2 * per_adj].to_vec());
    }
}
