//! Node featurization: molecule -> the model's `x [M, F0]` input.
//!
//! F0 = 16: one-hot element (10) + degree one-hot capped at 5 (5) + a
//! constant 1 bias channel. Padded node rows are all-zero (the model's
//! mask keeps them inert).

use super::molecule::{Molecule, N_ELEMENTS};

pub const FEAT_DIM: usize = 16;
const DEGREE_CAP: usize = 5;

/// Features for one molecule, zero-padded to `max_nodes` rows.
/// Returns (x flat [max_nodes * FEAT_DIM], mask [max_nodes]).
pub fn featurize(mol: &Molecule, max_nodes: usize) -> (Vec<f32>, Vec<f32>) {
    assert!(mol.n_atoms <= max_nodes, "molecule larger than bucket");
    let mut x = vec![0f32; max_nodes * FEAT_DIM];
    let mut mask = vec![0f32; max_nodes];
    for v in 0..mol.n_atoms {
        let row = &mut x[v * FEAT_DIM..(v + 1) * FEAT_DIM];
        row[mol.elements[v]] = 1.0;
        let deg = mol.degree(v).min(DEGREE_CAP - 1);
        row[N_ELEMENTS + deg] = 1.0;
        row[N_ELEMENTS + DEGREE_CAP] = 1.0; // bias channel
        mask[v] = 1.0;
    }
    (x, mask)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::molecule::MoleculeSpec;
    use crate::util::rng::Rng;

    #[test]
    fn feature_layout() {
        assert_eq!(FEAT_DIM, N_ELEMENTS + DEGREE_CAP + 1);
    }

    #[test]
    fn one_hot_rows_and_padding() {
        let mut rng = Rng::new(1);
        let mol = Molecule::random(&mut rng, &MoleculeSpec::default());
        let (x, mask) = featurize(&mol, 50);
        for v in 0..mol.n_atoms {
            let row = &x[v * FEAT_DIM..(v + 1) * FEAT_DIM];
            let elem_sum: f32 = row[..N_ELEMENTS].iter().sum();
            let deg_sum: f32 = row[N_ELEMENTS..N_ELEMENTS + DEGREE_CAP].iter().sum();
            assert_eq!(elem_sum, 1.0);
            assert_eq!(deg_sum, 1.0);
            assert_eq!(row[FEAT_DIM - 1], 1.0);
            assert_eq!(mask[v], 1.0);
        }
        for v in mol.n_atoms..50 {
            assert!(x[v * FEAT_DIM..(v + 1) * FEAT_DIM].iter().all(|&f| f == 0.0));
            assert_eq!(mask[v], 0.0);
        }
    }

    #[test]
    #[should_panic]
    fn oversize_molecule_rejected() {
        let mut rng = Rng::new(2);
        let mol = Molecule::random(&mut rng, &MoleculeSpec::default());
        featurize(&mol, 3);
    }
}
