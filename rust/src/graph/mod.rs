//! Molecular-graph substrate (S3 in DESIGN.md).
//!
//! The paper evaluates on Tox21 (downloadable, but this environment is
//! offline) and Reaction100 (derived from the proprietary Reaxys
//! database).  Both are replaced by synthetic molecule generators whose
//! *shape statistics* match Table I — graph count, max dim 50, bond
//! (nnz/row ~ 2) sparsity — because the kernels, batcher, and benches
//! only observe (shape, sparsity, batch) distributions.  Labels are
//! deterministic functions of graph structure plus noise, so the E2E
//! training example has a real learnable signal and a falling loss
//! curve.
//!
//! `powerlaw` adds the opposite workload shape: one 10^4–10^6-node
//! Barabási–Albert graph for the large-graph tier (DESIGN.md §12),
//! consumed whole by the tiled CSR kernel or as neighbor-sampled
//! mini-batches by `gcn::sampler`.

pub mod dataset;
pub mod featurize;
pub mod molecule;
pub mod powerlaw;

pub use dataset::{Dataset, DatasetKind, ModelBatch, Sample};
pub use molecule::{Molecule, MoleculeSpec};
pub use powerlaw::{power_law_graph, PowerLawSpec};
