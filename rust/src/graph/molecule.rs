//! Synthetic molecule-like graphs.
//!
//! A molecule is a connected graph: a random spanning tree (chains with
//! branching, like skeletal organic structures) plus a few ring-closing
//! edges.  Each bond has one of `N_BOND_TYPES` types — these become the
//! GCN's adjacency *channels*.  Self-loops (`a_uu = 1`, paper eq. 1) are
//! added on every channel so a node always convolves its own features.

use crate::sparse::coo::Coo;
use crate::util::rng::Rng;

/// Bond-type channels: single / double / triple / aromatic.
pub const N_BOND_TYPES: usize = 4;
/// Element alphabet size (C, N, O, S, P, F, Cl, Br, I, other).
pub const N_ELEMENTS: usize = 10;

#[derive(Clone, Copy, Debug)]
pub struct MoleculeSpec {
    pub min_atoms: usize,
    pub max_atoms: usize,
    /// Expected ring-closing edges per molecule.
    pub mean_rings: f32,
    /// Per-channel bond cap so the padded nnz budget is never exceeded:
    /// per channel, nnz = 2 * bonds_ch + atoms <= nnz_cap.
    pub max_bonds_per_channel: usize,
    /// Per-atom degree cap so the ELL row width is never exceeded:
    /// ELL row slots = 1 self loop + degree <= ell_width.
    pub max_degree: usize,
}

impl Default for MoleculeSpec {
    fn default() -> Self {
        Self {
            min_atoms: 4,
            max_atoms: 50, // Table I: Max dim = 50
            mean_rings: 1.5,
            max_bonds_per_channel: 39, // (128 - 50) / 2
            max_degree: 8,             // ell_width 12 >= 1 + 8
        }
    }
}

#[derive(Clone, Debug)]
pub struct Bond {
    pub a: usize,
    pub b: usize,
    pub bond_type: usize,
}

#[derive(Clone, Debug)]
pub struct Molecule {
    pub n_atoms: usize,
    /// Element index per atom, < N_ELEMENTS.
    pub elements: Vec<usize>,
    pub bonds: Vec<Bond>,
}

impl Molecule {
    /// Generate one random molecule.
    pub fn random(rng: &mut Rng, spec: &MoleculeSpec) -> Molecule {
        let n = rng.range(spec.min_atoms, spec.max_atoms);
        // Element distribution skewed toward carbon (index 0), like
        // organic molecules.
        let elements = (0..n)
            .map(|_| {
                if rng.bool(0.6) {
                    0
                } else {
                    rng.range(1, N_ELEMENTS - 1)
                }
            })
            .collect();

        let mut per_channel = [0usize; N_BOND_TYPES];
        let mut degrees = vec![0usize; n];
        let mut bonds = Vec::with_capacity(n + 3);
        let max_degree = spec.max_degree;
        let mut push_bond = |rng: &mut Rng,
                             a: usize,
                             b: usize,
                             bonds: &mut Vec<Bond>,
                             degrees: &mut Vec<usize>| {
            if degrees[a] >= max_degree || degrees[b] >= max_degree {
                return; // keep every atom within the ELL row budget
            }
            // Weighted bond types: single 60%, double 20%, triple 10%,
            // aromatic 10% — reassign if the channel budget is full.
            let mut t = match rng.below(10) {
                0..=5 => 0,
                6..=7 => 1,
                8 => 2,
                _ => 3,
            };
            for _ in 0..N_BOND_TYPES {
                if per_channel[t] < spec.max_bonds_per_channel {
                    break;
                }
                t = (t + 1) % N_BOND_TYPES;
            }
            if per_channel[t] >= spec.max_bonds_per_channel {
                return; // drop the bond: every channel is at budget
            }
            per_channel[t] += 1;
            degrees[a] += 1;
            degrees[b] += 1;
            bonds.push(Bond { a, b, bond_type: t });
        };

        // Spanning tree: attach each new atom to a random earlier one,
        // biased toward recent atoms to create chain-like skeletons.
        // Tree edges must never be dropped (connectivity!), so pick a
        // parent below the degree cap, falling back to a linear scan.
        for i in 1..n {
            let lo = i.saturating_sub(4);
            let mut parent = if rng.bool(0.7) {
                rng.range(lo, i - 1)
            } else {
                rng.range(0, i - 1)
            };
            if degrees[parent] >= spec.max_degree {
                parent = (0..i)
                    .find(|&p| degrees[p] < spec.max_degree)
                    .unwrap_or(parent);
            }
            push_bond(rng, parent, i, &mut bonds, &mut degrees);
        }
        // Ring closures.
        if n >= 5 {
            let n_rings = (rng.f32() * 2.0 * spec.mean_rings).round() as usize;
            for _ in 0..n_rings {
                let a = rng.range(0, n - 1);
                let b = rng.range(0, n - 1);
                if a != b && !bonds.iter().any(|e| (e.a, e.b) == (a, b) || (e.b, e.a) == (a, b)) {
                    push_bond(rng, a, b, &mut bonds, &mut degrees);
                }
            }
        }

        Molecule {
            n_atoms: n,
            elements,
            bonds,
        }
    }

    /// Per-channel adjacency matrices: symmetric bonds (value 1 each
    /// direction) plus self-loops on every channel (paper eq. 1 a_uu=1).
    pub fn adjacency(&self) -> Vec<Coo> {
        let mut chans: Vec<Coo> = (0..N_BOND_TYPES)
            .map(|_| Coo::new(self.n_atoms, self.n_atoms))
            .collect();
        for ch in &mut chans {
            for v in 0..self.n_atoms {
                ch.push(v, v, 1.0);
            }
        }
        for bond in &self.bonds {
            let ch = &mut chans[bond.bond_type];
            ch.push(bond.a, bond.b, 1.0);
            ch.push(bond.b, bond.a, 1.0);
        }
        chans
    }

    pub fn degree(&self, v: usize) -> usize {
        self.bonds
            .iter()
            .filter(|b| b.a == v || b.b == v)
            .count()
    }

    /// Count of atoms with the given element index.
    pub fn element_count(&self, e: usize) -> usize {
        self.elements.iter().filter(|&&x| x == e).count()
    }

    /// Most frequent (min_element, max_element) bond pair — the basis of
    /// the Reaction100-like class labels.
    pub fn dominant_bond_pair(&self) -> (usize, usize) {
        let mut counts = std::collections::HashMap::new();
        for b in &self.bonds {
            let (x, y) = (self.elements[b.a], self.elements[b.b]);
            let key = (x.min(y), x.max(y));
            *counts.entry(key).or_insert(0usize) += 1;
        }
        counts
            .into_iter()
            .max_by_key(|&(k, c)| (c, std::cmp::Reverse(k)))
            .map(|(k, _)| k)
            .unwrap_or((0, 0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generated_molecule_is_connected() {
        let mut rng = Rng::new(1);
        for _ in 0..50 {
            let m = Molecule::random(&mut rng, &MoleculeSpec::default());
            // BFS from 0 over bonds.
            let mut seen = vec![false; m.n_atoms];
            let mut queue = vec![0usize];
            seen[0] = true;
            while let Some(v) = queue.pop() {
                for b in &m.bonds {
                    let other = if b.a == v {
                        Some(b.b)
                    } else if b.b == v {
                        Some(b.a)
                    } else {
                        None
                    };
                    if let Some(o) = other {
                        if !seen[o] {
                            seen[o] = true;
                            queue.push(o);
                        }
                    }
                }
            }
            // Bond dropping under channel budget can in principle orphan
            // atoms only when budgets saturate, which the spec prevents.
            assert!(seen.iter().all(|&s| s), "disconnected molecule");
        }
    }

    #[test]
    fn adjacency_within_nnz_budget() {
        let mut rng = Rng::new(2);
        let spec = MoleculeSpec::default();
        for _ in 0..200 {
            let m = Molecule::random(&mut rng, &spec);
            for adj in m.adjacency() {
                assert!(
                    adj.nnz() <= 128,
                    "channel nnz {} exceeds artifact cap",
                    adj.nnz()
                );
            }
        }
    }

    #[test]
    fn adjacency_symmetric_with_self_loops() {
        let mut rng = Rng::new(3);
        let m = Molecule::random(&mut rng, &MoleculeSpec::default());
        for adj in m.adjacency() {
            let d = adj.to_dense();
            for v in 0..m.n_atoms {
                assert_eq!(d.at(v, v), 1.0, "missing self loop");
            }
            for r in 0..m.n_atoms {
                for c in 0..m.n_atoms {
                    assert_eq!(d.at(r, c), d.at(c, r), "asymmetric at ({r},{c})");
                }
            }
        }
    }

    #[test]
    fn atom_count_in_range() {
        let mut rng = Rng::new(4);
        let spec = MoleculeSpec::default();
        for _ in 0..100 {
            let m = Molecule::random(&mut rng, &spec);
            assert!((spec.min_atoms..=spec.max_atoms).contains(&m.n_atoms));
            assert!(m.elements.iter().all(|&e| e < N_ELEMENTS));
        }
    }

    #[test]
    fn dominant_pair_deterministic() {
        let mut rng = Rng::new(5);
        let m = Molecule::random(&mut rng, &MoleculeSpec::default());
        assert_eq!(m.dominant_bond_pair(), m.dominant_bond_pair());
        let (a, b) = m.dominant_bond_pair();
        assert!(a <= b && b < N_ELEMENTS);
    }
}
