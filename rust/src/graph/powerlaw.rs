//! Synthetic power-law graph generator — the large-graph tier workload
//! (DESIGN.md §12).
//!
//! The molecule tier batches thousands of ≤50-node graphs; the
//! large-graph tier is the opposite regime: ONE graph with 10^4–10^6
//! nodes and a heavy-tailed degree distribution, the shape citation
//! graphs and social networks take in the GCN literature (ogbn-arxiv,
//! Reddit).  We grow it Barabási–Albert style: each new node attaches
//! `attach` edges to existing nodes with probability proportional to
//! their current degree, which yields a `P(deg = k) ∝ k^-3` tail —
//! exactly the hub-dominated profile the degree-bucketed planner and
//! the cache-tiled CSR kernel are built to handle.
//!
//! Everything is deterministic in the spec's seed, and the output is a
//! [`LargeGraphBatch`]: the symmetric-normalized self-looped adjacency
//! `Â = D^{-1/2}(A + I)D^{-1/2}` (the standard GCN propagation
//! operator) packed as an exact batch-of-one CSR.  The builder writes
//! the CSR arrays directly with a counting pass — no intermediate COO,
//! so a 10^6-node / ~9M-nnz graph costs two O(nnz) sweeps and no sort.

use crate::sparse::batch::LargeGraphBatch;
use crate::util::rng::Rng;

/// Shape of a synthetic power-law graph.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PowerLawSpec {
    /// Node count; the paper-scale sweep uses 10^4 .. 10^6.
    pub nodes: usize,
    /// Edges added per new node (Barabási–Albert `m`).  Mean degree
    /// converges to `2 * attach`; hubs reach O(sqrt(nodes * attach)).
    pub attach: usize,
    /// PRNG seed — same spec, same graph, bit-for-bit.
    pub seed: u64,
}

impl PowerLawSpec {
    pub fn new(nodes: usize, attach: usize, seed: u64) -> Self {
        Self { nodes, attach, seed }
    }

    /// Grow the graph and pack its normalized adjacency.
    pub fn generate(&self) -> anyhow::Result<LargeGraphBatch> {
        let n = self.nodes;
        let m = self.attach.max(1);
        anyhow::ensure!(n > m, "need nodes > attach ({n} <= {m})");
        anyhow::ensure!(
            n * (2 * m + 1) < i32::MAX as usize,
            "nnz would overflow the CSR i32 index space"
        );
        let mut rng = Rng::new(self.seed);

        // Preferential attachment via the repeated-endpoints trick: a
        // uniform draw from the list of all edge endpoints lands on a
        // node with probability deg(v) / (2 * |E|) — no per-node weight
        // table or prefix sums needed.
        let mut edges: Vec<(u32, u32)> = Vec::with_capacity(n * m);
        let mut endpoints: Vec<u32> = Vec::with_capacity(2 * n * m);
        let mut push_edge = |edges: &mut Vec<(u32, u32)>, endpoints: &mut Vec<u32>, a: u32, b: u32| {
            edges.push((a, b));
            endpoints.push(a);
            endpoints.push(b);
        };
        // Seed core: a ring over the first m + 1 nodes so every node
        // starts with nonzero degree.  At m == 1 the "ring" over two
        // nodes would traverse the same pair twice, so stop one short —
        // the path 0–1 already gives both nodes degree ≥ 1.
        let ring = if m == 1 { 1 } else { m + 1 };
        for v in 0..ring {
            let u = (v + 1) % (m + 1);
            push_edge(&mut edges, &mut endpoints, v as u32, u as u32);
        }
        let mut picked: Vec<u32> = Vec::with_capacity(m);
        for v in (m + 1)..n {
            picked.clear();
            for _ in 0..m {
                // Rejection-sample a target distinct from earlier picks
                // (self-attachment is impossible: `v`'s endpoints are
                // pushed only after all picks).  A bounded retry budget
                // keeps the loop O(1) amortized; the uniform fallback
                // only matters for tiny dense cores.
                let mut t = endpoints[rng.below(endpoints.len() as u64) as usize];
                let mut tries = 0;
                while picked.contains(&t) && tries < 32 {
                    t = endpoints[rng.below(endpoints.len() as u64) as usize];
                    tries += 1;
                }
                while picked.contains(&t) {
                    t = rng.below(v as u64) as u32;
                }
                picked.push(t);
            }
            for i in 0..picked.len() {
                push_edge(&mut edges, &mut endpoints, v as u32, picked[i]);
            }
        }
        drop(endpoints);

        // Degrees of A + I (each node carries a self-loop).
        let mut deg = vec![1u32; n];
        for &(a, b) in &edges {
            deg[a as usize] += 1;
            deg[b as usize] += 1;
        }
        let inv_sqrt: Vec<f32> = deg.iter().map(|&d| 1.0 / (d as f32).sqrt()).collect();

        // Counting pass -> row pointers, then a cursor fill.  Each
        // undirected edge lands in both endpoint rows; the self-loop
        // takes each row's first slot.
        let mut rpt: Vec<i32> = Vec::with_capacity(n + 1);
        rpt.push(0);
        let mut acc = 0i32;
        for &d in &deg {
            acc += d as i32;
            rpt.push(acc);
        }
        let nnz = acc as usize;
        let mut col_ids = vec![0i32; nnz];
        let mut vals = vec![0.0f32; nnz];
        let mut cursor: Vec<i32> = rpt[..n].to_vec();
        for v in 0..n {
            let c = cursor[v] as usize;
            col_ids[c] = v as i32;
            vals[c] = inv_sqrt[v] * inv_sqrt[v];
            cursor[v] += 1;
        }
        for &(a, b) in &edges {
            let (a, b) = (a as usize, b as usize);
            let w = inv_sqrt[a] * inv_sqrt[b];
            let ca = cursor[a] as usize;
            col_ids[ca] = b as i32;
            vals[ca] = w;
            cursor[a] += 1;
            let cb = cursor[b] as usize;
            col_ids[cb] = a as i32;
            vals[cb] = w;
            cursor[b] += 1;
        }
        LargeGraphBatch::from_csr_parts(n, rpt, col_ids, vals)
    }
}

/// One-call convenience for benches and tests.
pub fn power_law_graph(nodes: usize, attach: usize, seed: u64) -> anyhow::Result<LargeGraphBatch> {
    PowerLawSpec::new(nodes, attach, seed).generate()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    #[test]
    fn generation_is_deterministic_in_the_seed() {
        let a = power_law_graph(500, 3, 42).unwrap();
        let b = power_law_graph(500, 3, 42).unwrap();
        assert_eq!(a, b);
        let c = power_law_graph(500, 3, 43).unwrap();
        assert_ne!(a.csr().col_ids, c.csr().col_ids);
    }

    #[test]
    fn adjacency_is_symmetric_normalized_with_self_loops() {
        let g = power_law_graph(200, 2, 7).unwrap();
        let csr = g.csr();
        let n = g.nodes();
        // Reconstruct (row, col) -> val and per-row degree.
        let mut entries = std::collections::HashMap::new();
        let mut deg = vec![0usize; n];
        for r in 0..n {
            let mut seen = HashSet::new();
            for i in csr.rpt[r] as usize..csr.rpt[r + 1] as usize {
                let c = csr.col_ids[i] as usize;
                assert!(seen.insert(c), "duplicate column {c} in row {r}");
                entries.insert((r, c), csr.vals[i]);
                deg[r] += 1;
            }
            assert!(entries.contains_key(&(r, r)), "row {r} missing self-loop");
        }
        for (&(r, c), &v) in &entries {
            // Symmetry of both pattern and value.
            assert_eq!(entries.get(&(c, r)), Some(&v), "asymmetric at ({r},{c})");
            // Â[r][c] = 1 / sqrt(deg(r) * deg(c)) with deg over A + I.
            let want = 1.0 / ((deg[r] * deg[c]) as f32).sqrt();
            assert!((v - want).abs() < 1e-6, "bad weight at ({r},{c})");
        }
        // Mean degree of A (without the self-loop) converges to 2m.
        let mean = (g.nnz() - n) as f64 / n as f64;
        assert!((mean - 4.0).abs() < 0.5, "mean degree {mean}");
    }

    #[test]
    fn degree_distribution_is_heavy_tailed() {
        let g = power_law_graph(20_000, 4, 1).unwrap();
        // Preferential attachment concentrates mass on hubs: max degree
        // far above the mean, and the log2 histogram keeps a long tail.
        assert!(g.skew() > 5.0, "skew {} too flat for a power law", g.skew());
        assert!(
            g.degree_hist.len() >= 7,
            "histogram spans {} buckets",
            g.degree_hist.len()
        );
        // A uniform-degree graph would put ~everything in one bucket.
        let top = *g.degree_hist.iter().max().unwrap();
        assert!(top < g.nodes(), "degenerate degree histogram");
    }
}
