//! # batched-spmm-gcn
//!
//! Reproduction of *"Batched Sparse Matrix Multiplication for Accelerating
//! Graph Convolutional Networks"* (Nagasaka, Nukada, Kojima, Matsuoka —
//! CCGRID 2019) as a three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 1 (Pallas, build-time python)** — the batched SpMM kernels
//!   (SparseTensor/COO and CSR variants) re-thought for the TPU memory
//!   hierarchy: BlockSpec column blocking plays the role the paper's
//!   shared-memory cache blocking plays on the GPU.
//! * **Layer 2 (JAX, build-time python)** — the ChemGCN model: graph
//!   convolution layers in both the paper's *non-batched* (per-sample
//!   kernel launches) and *batched* (single fused launch) formulations,
//!   plus the training step (loss + grad + SGD). AOT-lowered to HLO text.
//! * **Layer 3 (this crate)** — the coordinator: a dataset/graph substrate,
//!   the unified batched-SpMM execution engine (`sparse::engine` — one
//!   `BatchedSpmm` trait, four backends, and an executor over a
//!   persistent work-stealing worker pool that every multiplying layer
//!   dispatches through, DESIGN.md §9), a dynamic batcher
//!   and serving runtime, the training loop, a PJRT runtime that loads
//!   the AOT artifacts, and a P100 GPU cost-model simulator that
//!   regenerates the paper's figures where real-GPU measurements are
//!   gated (see DESIGN.md §Substitutions).
//!
//! Execution backends compose at the coordinator level: the server and
//! trainer dispatch either through the PJRT artifacts or through the
//! host engine (`ServeBackend` / `Trainer::new_host`), so the full
//! serving stack — and the batched-vs-per-sample contrast the paper
//! measures — runs even where no artifacts or XLA toolchain exist.

pub mod util;
pub mod sparse;
pub mod graph;
pub mod gcn;
pub mod runtime;
pub mod coordinator;
pub mod simulator;
pub mod bench;
