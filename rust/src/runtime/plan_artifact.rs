//! AOT `StepPlan` artifacts: serialize compiled plans for fleet
//! cold-start (DESIGN.md §13).
//!
//! PR 5's plan/execute split compiles a [`StepPlan`] per geometry at
//! runtime; a fleet serving millions of users wants those plans ahead
//! of time so a freshly booted host replays from step one. This module
//! is that persistence layer: versioned, content-hashed JSON artifacts
//! over the crate's canonical writer ([`Json::to_string`]), one file
//! per geometry, plus the [`PlanCache`] warm-start loader.
//!
//! Format (`*.plan.json`, canonical key order):
//!
//! ```json
//! {"content_hash":"<fnv1a64 hex>",
//!  "dispatches":[{"backend":"ell","dtype":"f32","n":64,"out":1,"rhs":"per_sample","transpose":false},...],
//!  "format_version":2,
//!  "key":[1,0,4,50,16,4,12,12,64,64],
//!  "kind":"bspmm_step_plan",
//!  "params":[{"len":4096,"offset":0},...],
//!  "slots":[12800,...],
//!  "thresholds":{"ell_waste":3,"gemm_density":0.25}}
//! ```
//!
//! * **Versioning** — [`FORMAT_VERSION`] is bumped on any schema or
//!   canonical-encoding change; a mismatched version is rejected with
//!   an error naming both versions, never reinterpreted. Version 2
//!   added the per-dispatch `dtype` field (the inference precision of
//!   DESIGN.md §16) and the dtype tag in the geometry key — version-1
//!   artifacts predate precision-aware plans and must be regenerated.
//! * **Content hash** — FNV-1a 64 over the canonical encoding *without*
//!   the `content_hash` field, stored as 16 lowercase hex digits.
//!   [`decode`] recomputes and compares before trusting any field, so
//!   bit rot and hand edits are caught up front.
//! * **Thresholds** — the [`AutoThresholds`] in effect at compile time
//!   are part of the artifact: a frozen plan bakes in its
//!   `Backend::Auto` resolutions, so a host running *different*
//!   thresholds must not adopt it ([`warm_start`] skips it and the
//!   geometry falls back to runtime compilation).
//! * **Parity discipline** — a warmed plan must replay bit-identically
//!   to a freshly compiled one. `tests/plan_artifact_golden.rs` pins
//!   this against checked-in golden fixtures across backends, thread
//!   counts, and policies; steady-state serving after a warm start
//!   reports `plans_built == 0`.
//!
//! Fallback semantics: [`warm_start`] never fails the boot on a bad
//! artifact — unreadable, corrupt, version- or threshold-mismatched
//! files are recorded in the [`WarmStartReport`] and skipped, and any
//! geometry that did not warm-start simply compiles at runtime exactly
//! as before. Artifacts can make a boot faster, never wrong.

use std::path::{Path, PathBuf};

use crate::runtime::artifact::default_artifacts_dir;
use crate::sparse::engine::{
    AutoThresholds, Backend, DType, DispatchDesc, GeometryKey, ParamRef, PlanCache, RhsKind,
    SlotId, StepPlan,
};
use crate::util::json::{arr, num, obj, parse, s, Json};

/// Bumped on any schema or canonical-encoding change. Readers reject
/// every other version. 2 = per-dispatch `dtype` (DESIGN.md §16).
pub const FORMAT_VERSION: u32 = 2;

/// The `kind` tag distinguishing plan artifacts from the other JSON
/// files under the artifact root (manifest, bench reports).
pub const KIND: &str = "bspmm_step_plan";

/// File suffix the directory scan selects on.
pub const FILE_SUFFIX: &str = ".plan.json";

/// Env var naming the plan-artifact directory. When set, `Trainer` /
/// `HostDispatcher` warm-start from it at construction; when unset the
/// conventional location is `<artifacts>/plans` ([`default_plan_dir`])
/// but nothing is loaded implicitly — boots stay deterministic unless
/// the operator opts in.
pub const ENV_PLAN_DIR: &str = "BSPMM_PLAN_ARTIFACTS";

/// FNV-1a 64-bit — tiny, dependency-free, and stable across platforms;
/// collision resistance is not a goal (the hash detects corruption,
/// not adversaries).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// One decoded artifact: the plan, the thresholds it was compiled
/// under, and its (verified) content hash.
#[derive(Clone, Debug)]
pub struct PlanArtifact {
    pub plan: StepPlan,
    pub thresholds: AutoThresholds,
    pub content_hash: String,
}

// ---------------------------------------------------------------------
// Encode
// ---------------------------------------------------------------------

fn slot_json(id: SlotId) -> Json {
    if id == SlotId::NONE {
        Json::Null
    } else {
        num(id.0 as f64)
    }
}

/// The artifact object *without* `content_hash` — the exact bytes the
/// hash is defined over are this object's canonical encoding.
fn body(plan: &StepPlan, th: &AutoThresholds) -> Json {
    obj(vec![
        (
            "dispatches",
            arr(plan
                .dispatches
                .iter()
                .map(|d| {
                    obj(vec![
                        ("backend", s(d.backend.name())),
                        ("dtype", s(d.dtype.name())),
                        ("n", num(d.n as f64)),
                        ("out", slot_json(d.out)),
                        ("rhs", s(d.rhs.name())),
                        ("transpose", Json::Bool(d.transpose)),
                    ])
                })
                .collect()),
        ),
        ("format_version", num(FORMAT_VERSION as f64)),
        (
            "key",
            arr(plan.key.0.iter().map(|&v| num(v as f64)).collect()),
        ),
        ("kind", s(KIND)),
        (
            "params",
            arr(plan
                .params
                .iter()
                .map(|p| {
                    obj(vec![
                        ("len", num(p.len as f64)),
                        ("offset", num(p.offset as f64)),
                    ])
                })
                .collect()),
        ),
        (
            "slots",
            arr(plan.slots.iter().map(|&l| num(l as f64)).collect()),
        ),
        (
            "thresholds",
            obj(vec![
                ("ell_waste", num(th.ell_waste)),
                ("gemm_density", num(th.gemm_density)),
            ]),
        ),
    ])
}

/// Canonical artifact text for `plan` (no trailing newline —
/// [`save`] appends one).
pub fn encode(plan: &StepPlan, th: &AutoThresholds) -> String {
    let mut o = body(plan, th);
    let hash = fnv1a64(o.to_string().as_bytes());
    if let Json::Obj(m) = &mut o {
        m.insert("content_hash".into(), Json::Str(format!("{hash:016x}")));
    }
    o.to_string()
}

/// Stable artifact file name for a geometry:
/// `plan_<fnv1a64(key le-bytes)>.plan.json`.
pub fn file_name(key: &GeometryKey) -> String {
    let mut bytes = Vec::with_capacity(key.0.len() * 4);
    for v in &key.0 {
        bytes.extend_from_slice(&v.to_le_bytes());
    }
    format!("plan_{:016x}{FILE_SUFFIX}", fnv1a64(&bytes))
}

/// Write `plan` under `dir` (created if absent) at its
/// [`file_name`]; returns the path. The file is the canonical
/// encoding plus a trailing newline.
pub fn save(plan: &StepPlan, th: &AutoThresholds, dir: &Path) -> anyhow::Result<PathBuf> {
    plan.validate()?;
    std::fs::create_dir_all(dir)
        .map_err(|e| anyhow::anyhow!("cannot create {}: {e}", dir.display()))?;
    let path = dir.join(file_name(&plan.key));
    let mut text = encode(plan, th);
    text.push('\n');
    std::fs::write(&path, text)
        .map_err(|e| anyhow::anyhow!("cannot write {}: {e}", path.display()))?;
    Ok(path)
}

// ---------------------------------------------------------------------
// Decode
// ---------------------------------------------------------------------

fn req_u32(j: &Json, key: &str) -> anyhow::Result<u32> {
    let n = j.req_f64(key)?;
    anyhow::ensure!(
        n.fract() == 0.0 && (0.0..=u32::MAX as f64).contains(&n),
        "field '{key}' is not a u32 (got {n})"
    );
    Ok(n as u32)
}

fn req_bool(j: &Json, key: &str) -> anyhow::Result<bool> {
    j.get(key)
        .and_then(Json::as_bool)
        .ok_or_else(|| anyhow::anyhow!("missing boolean field '{key}'"))
}

fn as_u32(j: &Json, what: &str) -> anyhow::Result<u32> {
    let n = j
        .as_f64()
        .ok_or_else(|| anyhow::anyhow!("{what} is not a number"))?;
    anyhow::ensure!(
        n.fract() == 0.0 && (0.0..=u32::MAX as f64).contains(&n),
        "{what} is not a u32 (got {n})"
    );
    Ok(n as u32)
}

/// Parse and verify one artifact. Checks run outermost-first so the
/// error names the *actual* problem: JSON validity → `kind` →
/// `format_version` → content hash → field decode →
/// [`StepPlan::validate`]. Never panics on malformed input.
pub fn decode(text: &str) -> anyhow::Result<PlanArtifact> {
    let j = parse(text).map_err(|e| anyhow::anyhow!("plan artifact is not valid JSON: {e}"))?;
    anyhow::ensure!(
        j.as_obj().is_some(),
        "plan artifact is not a JSON object"
    );
    let kind = j.req_str("kind")?;
    anyhow::ensure!(
        kind == KIND,
        "not a step-plan artifact: kind is '{kind}', expected '{KIND}'"
    );
    let version = req_u32(&j, "format_version")?;
    anyhow::ensure!(
        version == FORMAT_VERSION,
        "plan artifact format_version {version} but this build reads {FORMAT_VERSION} \
         (v2 added the per-dispatch 'dtype' precision field) — regenerate the artifact \
         (examples/plan_aot.rs dump) with a matching build"
    );
    let stored_hash = j.req_str("content_hash")?.to_string();
    let mut without_hash = j.clone();
    if let Json::Obj(m) = &mut without_hash {
        m.remove("content_hash");
    }
    let actual = format!("{:016x}", fnv1a64(without_hash.to_string().as_bytes()));
    anyhow::ensure!(
        actual == stored_hash,
        "plan artifact content hash mismatch: file says {stored_hash}, canonical content \
         hashes to {actual} — the artifact is corrupt or was hand-edited; regenerate it"
    );

    let th = j
        .get("thresholds")
        .ok_or_else(|| anyhow::anyhow!("missing object field 'thresholds'"))?;
    let thresholds = AutoThresholds {
        gemm_density: th.req_f64("gemm_density")?,
        ell_waste: th.req_f64("ell_waste")?,
    };

    let key = GeometryKey(
        j.req_arr("key")?
            .iter()
            .map(|v| as_u32(v, "geometry key entry"))
            .collect::<anyhow::Result<_>>()?,
    );
    let slots = j
        .req_arr("slots")?
        .iter()
        .map(|v| Ok(as_u32(v, "slot length")? as usize))
        .collect::<anyhow::Result<Vec<_>>>()?;
    let dispatches = j
        .req_arr("dispatches")?
        .iter()
        .enumerate()
        .map(|(i, d)| {
            (|| -> anyhow::Result<DispatchDesc> {
                Ok(DispatchDesc {
                    backend: Backend::parse(d.req_str("backend")?)?,
                    transpose: req_bool(d, "transpose")?,
                    rhs: RhsKind::parse(d.req_str("rhs")?)?,
                    n: req_u32(d, "n")?,
                    out: match d.get("out") {
                        Some(Json::Null) | None => SlotId::NONE,
                        Some(v) => SlotId(as_u32(v, "out slot")?),
                    },
                    dtype: DType::parse(d.req_str("dtype")?)?,
                })
            })()
            .map_err(|e| anyhow::anyhow!("dispatch {i}: {e}"))
        })
        .collect::<anyhow::Result<Vec<_>>>()?;
    let params = j
        .req_arr("params")?
        .iter()
        .enumerate()
        .map(|(i, p)| {
            (|| -> anyhow::Result<ParamRef> {
                Ok(ParamRef {
                    offset: req_u32(p, "offset")?,
                    len: req_u32(p, "len")?,
                })
            })()
            .map_err(|e| anyhow::anyhow!("param ref {i}: {e}"))
        })
        .collect::<anyhow::Result<Vec<_>>>()?;

    let plan = StepPlan {
        key,
        slots,
        dispatches,
        params,
    };
    plan.validate()?;
    Ok(PlanArtifact {
        plan,
        thresholds,
        content_hash: stored_hash,
    })
}

/// Read and [`decode`] one artifact file.
pub fn load(path: &Path) -> anyhow::Result<PlanArtifact> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("cannot read {}: {e}", path.display()))?;
    decode(&text).map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))
}

// ---------------------------------------------------------------------
// Warm start
// ---------------------------------------------------------------------

/// What a [`warm_start`] scan did, per outcome. `errors` holds one
/// message per rejected file (already prefixed with the path); none of
/// them abort the boot — affected geometries compile at runtime.
#[derive(Clone, Debug, Default)]
pub struct WarmStartReport {
    /// Plans installed into the cache.
    pub loaded: usize,
    /// Valid artifacts skipped because their compile-time thresholds
    /// differ from this host's (a frozen `Backend::Auto` resolution
    /// under other thresholds must not be adopted).
    pub skipped_thresholds: usize,
    /// Valid artifacts whose geometry was already cached.
    pub skipped_duplicate: usize,
    /// Rejected files (unreadable / corrupt / wrong version / invalid
    /// plan), with the reason.
    pub errors: Vec<String>,
}

impl WarmStartReport {
    pub fn summary(&self) -> String {
        format!(
            "warm-started {} plan(s) ({} threshold-skipped, {} duplicate, {} rejected)",
            self.loaded,
            self.skipped_thresholds,
            self.skipped_duplicate,
            self.errors.len()
        )
    }
}

fn same_thresholds(a: &AutoThresholds, b: &AutoThresholds) -> bool {
    a.gemm_density.to_bits() == b.gemm_density.to_bits()
        && a.ell_waste.to_bits() == b.ell_waste.to_bits()
}

/// Scan `dir` for `*.plan.json` files (in sorted name order, so boots
/// are deterministic) and install every valid, threshold-matching plan
/// into `cache` via [`PlanCache::insert_warm`]. A missing directory is
/// an empty scan, and bad files are recorded, never fatal — see the
/// module docs' fallback semantics.
pub fn warm_start(
    cache: &mut PlanCache,
    dir: &Path,
    th: &AutoThresholds,
) -> anyhow::Result<WarmStartReport> {
    let mut report = WarmStartReport::default();
    if !dir.is_dir() {
        return Ok(report);
    }
    let mut paths: Vec<PathBuf> = std::fs::read_dir(dir)
        .map_err(|e| anyhow::anyhow!("cannot scan {}: {e}", dir.display()))?
        .filter_map(|entry| entry.ok().map(|e| e.path()))
        .filter(|p| {
            p.file_name()
                .and_then(|n| n.to_str())
                .is_some_and(|n| n.ends_with(FILE_SUFFIX))
        })
        .collect();
    paths.sort();
    for path in paths {
        match load(&path) {
            Err(e) => report.errors.push(format!("{e:#}")),
            Ok(art) => {
                if !same_thresholds(&art.thresholds, th) {
                    report.skipped_thresholds += 1;
                } else if cache.insert_warm(art.plan) {
                    report.loaded += 1;
                } else {
                    report.skipped_duplicate += 1;
                }
            }
        }
    }
    Ok(report)
}

/// Warm-start from [`ENV_PLAN_DIR`] when it is set; `None` when it is
/// not (the common case — boots load nothing implicitly).
pub fn warm_start_from_env(
    cache: &mut PlanCache,
    th: &AutoThresholds,
) -> anyhow::Result<Option<WarmStartReport>> {
    match std::env::var(ENV_PLAN_DIR) {
        Err(_) => Ok(None),
        Ok(dir) => warm_start(cache, Path::new(&dir), th).map(Some),
    }
}

/// Conventional plan directory when [`ENV_PLAN_DIR`] is unset:
/// `<artifacts>/plans` under the shared artifact root
/// ([`default_artifacts_dir`]).
pub fn default_plan_dir() -> PathBuf {
    match std::env::var(ENV_PLAN_DIR) {
        Ok(dir) => PathBuf::from(dir),
        Err(_) => default_artifacts_dir().join("plans"),
    }
}

// ---------------------------------------------------------------------
// Registry manifest + garbage collection
// ---------------------------------------------------------------------

/// File name of the registry manifest a multi-model plan root carries
/// (DESIGN.md §15): the list of live `(model, current version)` pairs
/// the per-model subdirectories belong to.
pub const REGISTRY_MANIFEST: &str = "registry.json";

/// The `kind` tag of the registry manifest.
pub const MANIFEST_KIND: &str = "bspmm_plan_registry";

/// Write the registry manifest for a multi-model plan root: which
/// models (and which current parameter versions) the per-model plan
/// subdirectories under `dir` serve. [`gc_plans`] treats any model
/// subdirectory *not* named here as stale.
pub fn write_registry_manifest(dir: &Path, models: &[(String, u64)]) -> anyhow::Result<PathBuf> {
    std::fs::create_dir_all(dir)
        .map_err(|e| anyhow::anyhow!("cannot create {}: {e}", dir.display()))?;
    let j = obj(vec![
        ("format_version", num(FORMAT_VERSION as f64)),
        ("kind", s(MANIFEST_KIND)),
        (
            "models",
            arr(models
                .iter()
                .map(|(m, v)| {
                    obj(vec![("model", s(m)), ("version", num(*v as f64))])
                })
                .collect()),
        ),
    ]);
    let path = dir.join(REGISTRY_MANIFEST);
    let mut text = j.to_string();
    text.push('\n');
    std::fs::write(&path, text)
        .map_err(|e| anyhow::anyhow!("cannot write {}: {e}", path.display()))?;
    Ok(path)
}

/// Read a registry manifest back as `(model, version)` pairs.
pub fn read_registry_manifest(dir: &Path) -> anyhow::Result<Vec<(String, u64)>> {
    let path = dir.join(REGISTRY_MANIFEST);
    let text = std::fs::read_to_string(&path)
        .map_err(|e| anyhow::anyhow!("cannot read {}: {e}", path.display()))?;
    let j = parse(&text).map_err(|e| anyhow::anyhow!("{}: not valid JSON: {e}", path.display()))?;
    let kind = j.req_str("kind")?;
    anyhow::ensure!(
        kind == MANIFEST_KIND,
        "{}: kind is '{kind}', expected '{MANIFEST_KIND}'",
        path.display()
    );
    let version = req_u32(&j, "format_version")?;
    anyhow::ensure!(
        version == FORMAT_VERSION,
        "{}: manifest format_version {version} but this build reads {FORMAT_VERSION}",
        path.display()
    );
    j.req_arr("models")?
        .iter()
        .map(|m| {
            Ok((
                m.req_str("model")?.to_string(),
                m.req_f64("version")? as u64,
            ))
        })
        .collect()
}

/// What a [`gc_plans`] pass found (and, with `apply`, did). In dry-run
/// mode `removed` stays 0 and `stale` lists what *would* go.
#[derive(Clone, Debug, Default)]
pub struct GcReport {
    /// Models the manifest lists as live.
    pub live_models: Vec<String>,
    /// Stale plan-artifact files: under a model subdirectory the
    /// manifest no longer names.
    pub stale: Vec<PathBuf>,
    /// Files actually deleted (0 in dry-run mode).
    pub removed: usize,
    pub dry_run: bool,
}

impl GcReport {
    pub fn summary(&self) -> String {
        format!(
            "plan gc: {} live model(s), {} stale artifact(s){}",
            self.live_models.len(),
            self.stale.len(),
            if self.dry_run {
                " (dry run — pass --apply to delete)".to_string()
            } else {
                format!(", {} removed", self.removed)
            }
        )
    }
}

/// Garbage-collect a multi-model plan root against its registry
/// manifest: every `*.plan.json` under a model subdirectory the
/// manifest does not name is stale. Dry-run by default — nothing is
/// deleted unless `apply` is set (then emptied stale subdirectories
/// are removed too). Flat legacy artifacts directly under `root` are
/// never touched: they predate the per-model layout and carry no model
/// identity to judge.
pub fn gc_plans(root: &Path, apply: bool) -> anyhow::Result<GcReport> {
    let manifest = read_registry_manifest(root)?;
    let mut report = GcReport {
        live_models: manifest.iter().map(|(m, _)| m.clone()).collect(),
        dry_run: !apply,
        ..GcReport::default()
    };
    let mut subdirs: Vec<PathBuf> = std::fs::read_dir(root)
        .map_err(|e| anyhow::anyhow!("cannot scan {}: {e}", root.display()))?
        .filter_map(|entry| entry.ok().map(|e| e.path()))
        .filter(|p| p.is_dir())
        .collect();
    subdirs.sort();
    for dir in subdirs {
        let name = match dir.file_name().and_then(|n| n.to_str()) {
            Some(n) => n.to_string(),
            None => continue,
        };
        if report.live_models.iter().any(|m| *m == name) {
            continue;
        }
        let mut files: Vec<PathBuf> = std::fs::read_dir(&dir)
            .map_err(|e| anyhow::anyhow!("cannot scan {}: {e}", dir.display()))?
            .filter_map(|entry| entry.ok().map(|e| e.path()))
            .filter(|p| {
                p.file_name()
                    .and_then(|n| n.to_str())
                    .is_some_and(|n| n.ends_with(FILE_SUFFIX))
            })
            .collect();
        if files.is_empty() {
            continue; // not a plan subdirectory — leave it alone
        }
        files.sort();
        if apply {
            for f in &files {
                std::fs::remove_file(f)
                    .map_err(|e| anyhow::anyhow!("cannot remove {}: {e}", f.display()))?;
                report.removed += 1;
            }
            // Remove the directory too if the artifacts were all it held.
            let _ = std::fs::remove_dir(&dir);
        }
        report.stale.extend(files);
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::prop_assert;

    fn sample_plan() -> StepPlan {
        let mut p = StepPlan::new(GeometryKey(vec![1, 4, 50, 16, 4, 12, 12, 64, 64]));
        let a = p.add_slot(12800);
        let b = p.add_slot(48);
        p.add_dispatch(DispatchDesc {
            backend: Backend::Gemm,
            transpose: false,
            rhs: RhsKind::Shared,
            n: 64,
            out: a,
            dtype: DType::F32,
        });
        p.add_dispatch(DispatchDesc {
            backend: Backend::Ell,
            transpose: true,
            rhs: RhsKind::PerSample,
            n: 64,
            out: b,
            dtype: DType::Bf16,
        });
        p.add_dispatch(DispatchDesc {
            backend: Backend::Csr,
            transpose: false,
            rhs: RhsKind::SharedTransposed,
            n: 12,
            out: SlotId::NONE,
            dtype: DType::Int8,
        });
        p.add_dispatch(DispatchDesc {
            backend: Backend::St,
            transpose: true,
            rhs: RhsKind::Shared,
            n: 7,
            out: a,
            dtype: DType::F32,
        });
        p.add_param(0, 4096);
        p.add_param(4096, 256);
        p
    }

    fn rehash(text: &str) -> String {
        // Recompute the content hash of a (possibly tampered) artifact
        // so tests can separate "hash mismatch" from later checks.
        let mut j = parse(text).unwrap();
        if let Json::Obj(m) = &mut j {
            m.remove("content_hash");
        }
        let h = fnv1a64(j.to_string().as_bytes());
        if let Json::Obj(m) = &mut j {
            m.insert("content_hash".into(), Json::Str(format!("{h:016x}")));
        }
        j.to_string()
    }

    #[test]
    fn round_trip_preserves_every_field() {
        let plan = sample_plan();
        let th = AutoThresholds::default();
        let text = encode(&plan, &th);
        let art = decode(&text).unwrap();
        assert_eq!(art.plan, plan);
        assert_eq!(art.thresholds.gemm_density.to_bits(), th.gemm_density.to_bits());
        assert_eq!(art.thresholds.ell_waste.to_bits(), th.ell_waste.to_bits());
        // serialize → deserialize → serialize is byte-identical.
        assert_eq!(encode(&art.plan, &art.thresholds), text);
        // The stored hash is the canonical-content hash.
        assert_eq!(rehash(&text), text);
    }

    #[test]
    fn content_hash_changes_with_content_and_is_stable() {
        let th = AutoThresholds::default();
        let a = encode(&sample_plan(), &th);
        assert_eq!(a, encode(&sample_plan(), &th), "encoding must be deterministic");
        let mut other = sample_plan();
        other.slots[1] = 64;
        let b = encode(&other, &th);
        assert_ne!(
            decode(&a).unwrap().content_hash,
            decode(&b).unwrap().content_hash
        );
    }

    #[test]
    fn property_random_plans_round_trip_byte_identical() {
        prop::run(60, |rng| {
            let mut plan = StepPlan::new(GeometryKey(
                (0..rng.range(1, 8)).map(|_| rng.below(1 << 20) as u32).collect(),
            ));
            for _ in 0..rng.range(1, 6) {
                plan.add_slot(rng.range(1, 1 << 16));
            }
            let n_slots = plan.slots.len() as u32;
            for _ in 0..rng.range(1, 12) {
                plan.add_dispatch(DispatchDesc {
                    backend: Backend::FIXED[rng.range(0, 4)],
                    transpose: rng.bool(0.5),
                    rhs: [RhsKind::Shared, RhsKind::PerSample, RhsKind::SharedTransposed]
                        [rng.range(0, 3)],
                    n: rng.range(1, 512) as u32,
                    out: if rng.bool(0.25) {
                        SlotId::NONE
                    } else {
                        SlotId(rng.below(n_slots as u64) as u32)
                    },
                    dtype: DType::ALL[rng.range(0, 3)],
                });
            }
            for _ in 0..rng.range(0, 5) {
                let off = rng.below(1 << 24) as usize;
                plan.add_param(off, rng.range(1, 1 << 16));
            }
            let th = AutoThresholds {
                gemm_density: rng.f32_range(0.01, 0.9) as f64,
                ell_waste: rng.f32_range(1.0, 8.0) as f64,
            };
            let text = encode(&plan, &th);
            let art = decode(&text).map_err(|e| format!("decode failed: {e}"))?;
            prop_assert!(art.plan == plan, "plan fields not preserved");
            prop_assert!(
                art.thresholds.gemm_density.to_bits() == th.gemm_density.to_bits()
                    && art.thresholds.ell_waste.to_bits() == th.ell_waste.to_bits(),
                "thresholds not preserved"
            );
            let again = encode(&art.plan, &art.thresholds);
            prop_assert!(again == text, "re-encoding is not byte-identical");
            Ok(())
        });
    }

    #[test]
    fn rejects_truncated_and_corrupt_artifacts() {
        let text = encode(&sample_plan(), &AutoThresholds::default());
        let truncated = &text[..text.len() / 2];
        let e = decode(truncated).unwrap_err().to_string();
        assert!(e.contains("not valid JSON"), "unexpected error: {e}");
        let e = decode("not json at all").unwrap_err().to_string();
        assert!(e.contains("not valid JSON"), "unexpected error: {e}");
        let e = decode("[1,2,3]").unwrap_err().to_string();
        assert!(e.contains("not a JSON object"), "unexpected error: {e}");
        // A manifest-like object is not a plan artifact.
        let e = decode(r#"{"kind":"manifest","format_version":1}"#)
            .unwrap_err()
            .to_string();
        assert!(e.contains("kind is 'manifest'"), "unexpected error: {e}");
    }

    #[test]
    fn rejects_wrong_format_version_even_with_valid_hash() {
        // Both a future version and the retired v1 (pre-dtype) layout
        // must be rejected with an error naming both versions and what
        // changed — never silently reinterpreted.
        for wrong in [99.0, 1.0] {
            let text = encode(&sample_plan(), &AutoThresholds::default());
            let mut j = parse(&text).unwrap();
            if let Json::Obj(m) = &mut j {
                m.insert("format_version".into(), num(wrong));
            }
            let tampered = rehash(&j.to_string());
            let e = decode(&tampered).unwrap_err().to_string();
            assert!(
                e.contains(&format!("format_version {wrong}")) && e.contains("reads 2"),
                "unexpected error: {e}"
            );
            assert!(e.contains("dtype"), "v1→v2 hint missing: {e}");
        }
    }

    #[test]
    fn rejects_dispatch_without_dtype_and_unknown_dtype() {
        let th = AutoThresholds::default();
        // Drop one dispatch's dtype field (a v1-shaped dispatch inside
        // a v2 envelope): the decode must name the missing field.
        let text = encode(&sample_plan(), &th);
        let mut j = parse(&text).unwrap();
        if let Json::Obj(m) = &mut j {
            if let Some(Json::Arr(ds)) = m.get_mut("dispatches") {
                if let Json::Obj(d0) = &mut ds[0] {
                    d0.remove("dtype");
                }
            }
        }
        let e = decode(&rehash(&j.to_string())).unwrap_err().to_string();
        assert!(
            e.contains("dispatch 0") && e.contains("dtype"),
            "unexpected error: {e}"
        );
        // Unknown precision names are named in the error.
        let text = encode(&sample_plan(), &th).replacen("\"bf16\"", "\"fp4\"", 1);
        let e = decode(&rehash(&text)).unwrap_err().to_string();
        assert!(e.contains("fp4"), "unexpected error: {e}");
    }

    #[test]
    fn rejects_content_hash_mismatch() {
        let text = encode(&sample_plan(), &AutoThresholds::default());
        // Tamper a slot length without recomputing the hash.
        let tampered = text.replacen("12800", "12801", 1);
        assert_ne!(tampered, text);
        let e = decode(&tampered).unwrap_err().to_string();
        assert!(e.contains("content hash mismatch"), "unexpected error: {e}");
    }

    #[test]
    fn rejects_structurally_invalid_plans() {
        let th = AutoThresholds::default();
        // An Auto backend must never be frozen into an artifact.
        let text = encode(&sample_plan(), &th).replacen("\"gemm\"", "\"auto\"", 1);
        let e = decode(&rehash(&text)).unwrap_err().to_string();
        assert!(e.contains("Backend::Auto"), "unexpected error: {e}");
        // An out-slot past the slot table is rejected, not replayed OOB.
        let mut bad = sample_plan();
        bad.dispatches[0].out = SlotId(99);
        let text = encode(&bad, &th);
        let e = decode(&text).unwrap_err().to_string();
        assert!(e.contains("slot 99"), "unexpected error: {e}");
        // Unknown backend / rhs names are named in the error.
        let text = encode(&sample_plan(), &th).replacen("\"ell\"", "\"cuda\"", 1);
        let e = decode(&rehash(&text)).unwrap_err().to_string();
        assert!(e.contains("unknown backend 'cuda'"), "unexpected error: {e}");
    }

    #[test]
    fn save_load_warm_start_round_trip() {
        let dir = std::env::temp_dir().join("bspmm_plan_artifact_warmstart");
        let _ = std::fs::remove_dir_all(&dir);
        let th = AutoThresholds::default();
        let plan_a = sample_plan();
        let mut plan_b = sample_plan();
        plan_b.key = GeometryKey(vec![2, 4, 50, 16, 4, 12, 12, 64, 64]);
        let path_a = save(&plan_a, &th, &dir).unwrap();
        save(&plan_b, &th, &dir).unwrap();
        assert!(path_a.file_name().unwrap().to_str().unwrap().ends_with(FILE_SUFFIX));
        assert_eq!(load(&path_a).unwrap().plan, plan_a);

        let mut cache = PlanCache::new();
        let report = warm_start(&mut cache, &dir, &th).unwrap();
        assert_eq!(report.loaded, 2, "{}", report.summary());
        assert!(report.errors.is_empty());
        assert!(cache.contains(&plan_a.key) && cache.contains(&plan_b.key));
        let stats = cache.stats();
        assert_eq!(stats.plans_warmed, 2);
        assert_eq!(stats.plans_built, 0, "warm start must not count as building");
        // Second scan: both geometries already cached.
        let report = warm_start(&mut cache, &dir, &th).unwrap();
        assert_eq!((report.loaded, report.skipped_duplicate), (0, 2));

        // Threshold mismatch: skip, don't adopt.
        let other = AutoThresholds {
            gemm_density: 0.5,
            ell_waste: 2.0,
        };
        let mut fresh = PlanCache::new();
        let report = warm_start(&mut fresh, &dir, &other).unwrap();
        assert_eq!((report.loaded, report.skipped_thresholds), (0, 2));
        assert!(fresh.is_empty(), "mismatched artifacts must fall back to runtime compile");

        // A corrupt file is reported but doesn't block the others.
        std::fs::write(dir.join("broken.plan.json"), "{oops").unwrap();
        let mut fresh = PlanCache::new();
        let report = warm_start(&mut fresh, &dir, &th).unwrap();
        assert_eq!(report.loaded, 2);
        assert_eq!(report.errors.len(), 1);
        assert!(report.errors[0].contains("broken.plan.json"));

        // Missing directory is an empty scan, not an error.
        let report = warm_start(
            &mut PlanCache::new(),
            &dir.join("does_not_exist"),
            &th,
        )
        .unwrap();
        assert_eq!(report.loaded, 0);

        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn gc_removes_only_stale_model_subdirectories() {
        let root = std::env::temp_dir().join("bspmm_plan_gc_fixture");
        let _ = std::fs::remove_dir_all(&root);
        let th = AutoThresholds::default();

        // Live model subdir, stale model subdir, a legacy flat artifact,
        // and a non-plan subdir that must all be judged correctly.
        let live = sample_plan();
        save(&live, &th, &root.join("tox21")).unwrap();
        let mut stale = sample_plan();
        stale.key = GeometryKey(vec![9, 4, 50, 16, 4, 12, 12, 64, 64]);
        let stale_path = save(&stale, &th, &root.join("retired_model")).unwrap();
        let flat_path = save(&live, &th, &root).unwrap();
        std::fs::create_dir_all(root.join("notes")).unwrap();
        std::fs::write(root.join("notes").join("readme.txt"), "keep me").unwrap();

        // No manifest: GC refuses rather than guessing liveness.
        assert!(gc_plans(&root, false).is_err());
        write_registry_manifest(&root, &[("tox21".to_string(), 3)]).unwrap();
        assert_eq!(
            read_registry_manifest(&root).unwrap(),
            vec![("tox21".to_string(), 3u64)]
        );

        // Dry run: stale named, nothing deleted.
        let report = gc_plans(&root, false).unwrap();
        assert!(report.dry_run && report.removed == 0, "{}", report.summary());
        assert_eq!(report.live_models, vec!["tox21".to_string()]);
        assert_eq!(report.stale, vec![stale_path.clone()]);
        assert!(stale_path.is_file(), "dry run must not delete");
        assert!(report.summary().contains("--apply"), "{}", report.summary());

        // Apply: stale artifact and its emptied subdir go; the live
        // subdir, the legacy flat artifact and the non-plan dir stay.
        let report = gc_plans(&root, true).unwrap();
        assert_eq!((report.removed, report.stale.len()), (1, 1));
        assert!(!stale_path.exists());
        assert!(!root.join("retired_model").exists());
        assert!(root.join("tox21").join(file_name(&live.key)).is_file());
        assert!(flat_path.is_file());
        assert!(root.join("notes").join("readme.txt").is_file());

        // Idempotent: a second pass finds nothing.
        let report = gc_plans(&root, true).unwrap();
        assert_eq!((report.removed, report.stale.len()), (0, 0));

        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn file_names_are_stable_per_geometry() {
        let a = file_name(&GeometryKey(vec![1, 4, 50]));
        assert_eq!(a, file_name(&GeometryKey(vec![1, 4, 50])));
        assert_ne!(a, file_name(&GeometryKey(vec![2, 4, 50])));
        assert!(a.starts_with("plan_") && a.ends_with(FILE_SUFFIX));
    }
}
