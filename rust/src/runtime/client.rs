//! The `Runtime`: PJRT client + manifest + lazy executable pool.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;
use std::time::Instant;

use crate::runtime::artifact::Manifest;
use crate::runtime::executable::Executable;
use crate::runtime::tensor::Tensor;

/// Owns the PJRT CPU client and a compile-once cache of executables.
/// Not `Send` (the underlying client is `Rc`-based): lives on the
/// coordinator's device thread.
pub struct Runtime {
    pub client: xla::PjRtClient,
    pub manifest: Manifest,
    pool: RefCell<HashMap<String, Rc<Executable>>>,
    /// Cumulative compile time (reported by benches: artifact compile is
    /// a one-time cost, kept out of the steady-state measurements).
    pub compile_secs: std::cell::Cell<f64>,
}

impl Runtime {
    pub fn new(artifacts_dir: &Path) -> anyhow::Result<Runtime> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime {
            client,
            manifest,
            pool: RefCell::new(HashMap::new()),
            compile_secs: std::cell::Cell::new(0.0),
        })
    }

    /// $BSPMM_ARTIFACTS or ./artifacts.
    pub fn new_default() -> anyhow::Result<Runtime> {
        let dir = std::env::var("BSPMM_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
        Self::new(Path::new(&dir))
    }

    /// Get (compiling on first use) the named artifact's executable.
    pub fn executable(&self, name: &str) -> anyhow::Result<Rc<Executable>> {
        if let Some(e) = self.pool.borrow().get(name) {
            return Ok(e.clone());
        }
        let spec = self.manifest.artifact(name)?.clone();
        let path = self.manifest.hlo_path(&spec);
        let t0 = Instant::now();
        let exe = Rc::new(Executable::compile(&self.client, &spec, &path)?);
        self.compile_secs
            .set(self.compile_secs.get() + t0.elapsed().as_secs_f64());
        self.pool.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// One-shot convenience: execute artifact `name` on `inputs`.
    pub fn run(&self, name: &str, inputs: &[Tensor]) -> anyhow::Result<Vec<Tensor>> {
        self.executable(name)?.execute(inputs)
    }

    /// Upload a host tensor to a device-resident buffer.
    pub fn to_device(&self, t: &Tensor) -> anyhow::Result<xla::PjRtBuffer> {
        let lit = t.to_literal()?;
        Ok(self.client.buffer_from_host_literal(None, &lit)?)
    }

    pub fn pool_size(&self) -> usize {
        self.pool.borrow().len()
    }

    /// Per-executable dispatch stats: (name, calls, total_secs).
    pub fn dispatch_stats(&self) -> Vec<(String, u64, f64)> {
        self.pool
            .borrow()
            .iter()
            .map(|(n, e)| (n.clone(), e.calls.get(), e.total_secs.get()))
            .collect()
    }
}
