//! `artifacts/manifest.json` — the ABI between `python -m compile.aot`
//! and the rust runtime.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::gcn::config::ModelConfig;
use crate::runtime::tensor::DType;
use crate::util::json::{parse, Json};

/// Root artifact directory every loader resolves the same way:
/// `$BSPMM_ARTIFACTS`, else `./artifacts`. Shared by [`Manifest::load_default`]
/// and the AOT plan-artifact loader (`runtime::plan_artifact`) so the
/// env lookup lives in exactly one place.
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var("BSPMM_ARTIFACTS")
        .unwrap_or_else(|_| "artifacts".into())
        .into()
}

/// Declared shape/dtype of one artifact input or output.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub dtype: DType,
    pub shape: Vec<usize>,
}

impl TensorSpec {
    fn from_json(j: &Json) -> anyhow::Result<TensorSpec> {
        Ok(TensorSpec {
            name: j.get("name").and_then(Json::as_str).unwrap_or("").to_string(),
            dtype: DType::parse(j.req_str("dtype")?)?,
            shape: j
                .req_arr("shape")?
                .iter()
                .map(|d| d.as_usize().unwrap_or(0))
                .collect(),
        })
    }

    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One AOT-compiled executable's metadata.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    pub meta: Json,
}

impl ArtifactSpec {
    /// Convenience accessors for the spmm-bench metadata fields.
    pub fn meta_usize(&self, key: &str) -> Option<usize> {
        self.meta.get(key).and_then(Json::as_usize)
    }

    pub fn meta_str(&self, key: &str) -> Option<&str> {
        self.meta.get(key).and_then(Json::as_str)
    }
}

/// Parsed manifest: artifact map, model configs, and the benchmark
/// sweep table (shared with aot.py so both sides iterate identical
/// experimental points).
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
    pub models: BTreeMap<String, ModelConfig>,
    pub sweeps: Json,
}

impl Manifest {
    pub fn load(dir: &Path) -> anyhow::Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            anyhow::anyhow!(
                "cannot read {} (run `make artifacts` first): {e}",
                path.display()
            )
        })?;
        Self::parse_str(&text, dir)
    }

    /// Default artifacts directory: [`default_artifacts_dir`]
    /// (`$BSPMM_ARTIFACTS` or `./artifacts`).
    pub fn load_default() -> anyhow::Result<Manifest> {
        Self::load(&default_artifacts_dir())
    }

    pub fn parse_str(text: &str, dir: &Path) -> anyhow::Result<Manifest> {
        let j = parse(text)?;
        let mut artifacts = BTreeMap::new();
        for a in j.req_arr("artifacts")? {
            let spec = ArtifactSpec {
                name: a.req_str("name")?.to_string(),
                file: a.req_str("file")?.to_string(),
                inputs: a
                    .req_arr("inputs")?
                    .iter()
                    .map(TensorSpec::from_json)
                    .collect::<anyhow::Result<_>>()?,
                outputs: a
                    .req_arr("outputs")?
                    .iter()
                    .map(TensorSpec::from_json)
                    .collect::<anyhow::Result<_>>()?,
                meta: a.get("meta").cloned().unwrap_or(Json::Null),
            };
            artifacts.insert(spec.name.clone(), spec);
        }
        let mut models = BTreeMap::new();
        for m in j.req_arr("models")? {
            let cfg = ModelConfig::from_json(m)?;
            cfg.validate()?;
            models.insert(cfg.name.clone(), cfg);
        }
        Ok(Manifest {
            dir: dir.to_path_buf(),
            artifacts,
            models,
            sweeps: j.get("sweeps").cloned().unwrap_or(Json::Null),
        })
    }

    pub fn artifact(&self, name: &str) -> anyhow::Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("artifact '{name}' not in manifest"))
    }

    pub fn model(&self, name: &str) -> anyhow::Result<&ModelConfig> {
        self.models
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("model '{name}' not in manifest"))
    }

    pub fn hlo_path(&self, spec: &ArtifactSpec) -> PathBuf {
        self.dir.join(&spec.file)
    }

    /// Sweep parameters for a figure key ("fig8a", ..., "fig10").
    pub fn sweep(&self, key: &str) -> anyhow::Result<SweepSpec> {
        let s = self.sweeps.get(key).ok_or_else(|| {
            anyhow::anyhow!("sweep '{key}' not in manifest")
        })?;
        Ok(SweepSpec {
            key: key.to_string(),
            dim: s.req_usize("dim")?,
            z: s.req_usize("z")?,
            batch: s.req_usize("batch")?,
            nbs: s
                .req_arr("nbs")?
                .iter()
                .filter_map(Json::as_usize)
                .collect(),
            mixed: s.get("mixed").and_then(Json::as_bool).unwrap_or(false),
        })
    }
}

/// One row of the SWEEPS table.
#[derive(Clone, Debug)]
pub struct SweepSpec {
    pub key: String,
    pub dim: usize,
    pub z: usize,
    pub batch: usize,
    pub nbs: Vec<usize>,
    pub mixed: bool,
}

impl SweepSpec {
    /// Built-in copy of `python/compile/aot.py`'s SWEEPS table, for
    /// artifact-less runs (engine benches, simulated figures). The
    /// manifest remains authoritative when artifacts exist; keep the
    /// two tables in sync.
    pub fn builtin(key: &str) -> anyhow::Result<SweepSpec> {
        let (dim, z, batch, nbs, mixed): (usize, usize, usize, Vec<usize>, bool) = match key {
            "fig8a" => (50, 2, 50, vec![8, 16, 32, 64], false),
            "fig8b" => (50, 2, 100, vec![64, 128, 256, 512], false),
            "fig9a" => (32, 2, 100, vec![32, 128, 512], false),
            "fig9b" => (64, 2, 100, vec![32, 128, 512], false),
            "fig9c" => (128, 2, 100, vec![32, 128, 512], false),
            "fig9d" => (64, 2, 50, vec![32, 128, 512], false),
            "fig9e" => (64, 1, 100, vec![32, 128, 512], false),
            "fig9f" => (64, 5, 100, vec![32, 128, 512], false),
            "fig10" => (256, 5, 100, vec![128, 512, 1024], true),
            other => anyhow::bail!("no builtin sweep '{other}'"),
        };
        Ok(SweepSpec {
            key: key.to_string(),
            dim,
            z,
            batch,
            nbs,
            mixed,
        })
    }

    pub fn nnz_cap(&self) -> usize {
        self.dim * self.z
    }

    /// Artifact names for one (n_b) point of this sweep.
    pub fn st_batched(&self, nb: usize) -> String {
        format!(
            "spmm_st_d{}_z{}_n{nb}_b{}",
            self.dim, self.z, self.batch
        )
    }

    pub fn csr_batched(&self, nb: usize) -> String {
        format!(
            "spmm_csr_d{}_z{}_n{nb}_b{}",
            self.dim, self.z, self.batch
        )
    }

    pub fn gemm_batched(&self, nb: usize) -> String {
        format!("gemm_d{}_n{nb}_b{}", self.dim, self.batch)
    }

    pub fn st_single(&self, nb: usize) -> String {
        format!("spmm_st_d{}_z{}_n{nb}_b1", self.dim, self.z)
    }

    pub fn csr_single(&self, nb: usize) -> String {
        format!("spmm_csr_d{}_z{}_n{nb}_b1", self.dim, self.z)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_real_manifest_if_present() {
        // Integration-style: if `make artifacts` has run, the real
        // manifest must parse and contain both models + all sweeps.
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        assert!(m.models.contains_key("tox21"));
        assert!(m.models.contains_key("reaction100"));
        for key in ["fig8a", "fig8b", "fig9a", "fig9f", "fig10"] {
            let sw = m.sweep(key).unwrap();
            assert!(!sw.nbs.is_empty());
            // every referenced artifact must exist
            for &nb in &sw.nbs {
                m.artifact(&sw.st_batched(nb)).unwrap();
                m.artifact(&sw.csr_batched(nb)).unwrap();
                m.artifact(&sw.gemm_batched(nb)).unwrap();
                m.artifact(&sw.st_single(nb)).unwrap();
                m.artifact(&sw.csr_single(nb)).unwrap();
            }
        }
        let t = m.model("tox21").unwrap();
        assert_eq!(t.max_nodes, 50);
        assert!(dir.join(&t.init_file).exists());
    }

    #[test]
    fn builtin_sweeps_cover_all_figures() {
        for key in [
            "fig8a", "fig8b", "fig9a", "fig9b", "fig9c", "fig9d", "fig9e", "fig9f", "fig10",
        ] {
            let sw = SweepSpec::builtin(key).unwrap();
            assert!(!sw.nbs.is_empty());
            assert!(sw.dim >= 32 && sw.batch >= 50, "{key}");
        }
        assert!(SweepSpec::builtin("fig99").is_err());
    }

    #[test]
    fn sweep_names_match_aot_convention() {
        let sw = SweepSpec {
            key: "fig8a".into(),
            dim: 50,
            z: 2,
            batch: 50,
            nbs: vec![8],
            mixed: false,
        };
        assert_eq!(sw.st_batched(8), "spmm_st_d50_z2_n8_b50");
        assert_eq!(sw.csr_single(8), "spmm_csr_d50_z2_n8_b1");
        assert_eq!(sw.gemm_batched(8), "gemm_d50_n8_b50");
        assert_eq!(sw.nnz_cap(), 100);
    }
}
