//! PJRT runtime (S5 in DESIGN.md): loads the AOT artifacts
//! (`artifacts/*.hlo.txt`) and executes them on the CPU PJRT client.
//!
//! * [`artifact`] — the manifest (artifact ABI) parser.
//! * [`tensor`] — host-side tensors and literal marshalling.
//! * [`executable`] — one compiled artifact + typed execute.
//! * [`client`] — the `Runtime`: client + lazy executable pool.
//!
//! Threading: the `xla` crate's `PjRtClient` is `Rc`-based (not `Send`),
//! so a `Runtime` lives on one thread. The coordinator runs a dedicated
//! *device thread* that owns the `Runtime` and receives work over
//! channels — the same structure a real GPU serving stack uses for its
//! dispatch thread.

pub mod artifact;
pub mod client;
pub mod executable;
pub mod tensor;

pub use artifact::{ArtifactSpec, Manifest, TensorSpec};
pub use client::Runtime;
pub use executable::Executable;
pub use tensor::Tensor;
