//! PJRT runtime (S5 in DESIGN.md): loads the AOT artifacts
//! (`artifacts/*.hlo.txt`) and executes them on the CPU PJRT client.
//!
//! * [`artifact`] — the manifest (artifact ABI) parser.
//! * [`plan_artifact`] — AOT `StepPlan` artifacts: versioned,
//!   content-hashed JSON plans + the `PlanCache` warm-start loader
//!   (DESIGN.md §13).
//! * [`tensor`] — host-side tensors and literal marshalling.
//! * [`executable`] — one compiled artifact + typed execute.
//! * [`client`] — the `Runtime`: client + lazy executable pool.
//!
//! Threading: the `xla` crate's `PjRtClient` is `Rc`-based (not `Send`),
//! so a `Runtime` lives on one thread. The coordinator runs a dedicated
//! *device thread* that owns the `Runtime` and receives work over
//! channels — the same structure a real GPU serving stack uses for its
//! dispatch thread.

pub mod artifact;
pub mod client;
pub mod executable;
pub mod plan_artifact;
pub mod tensor;

pub use artifact::{default_artifacts_dir, ArtifactSpec, Manifest, TensorSpec};
pub use plan_artifact::{PlanArtifact, WarmStartReport};
pub use client::Runtime;
pub use executable::Executable;
pub use tensor::Tensor;
