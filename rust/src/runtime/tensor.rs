//! Host-side tensors and conversion to/from `xla::Literal`.

/// Data type of an artifact input/output (the manifest only uses these
/// two; the L2 models are single-precision like the paper's evaluation).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            other => anyhow::bail!("unsupported dtype '{other}'"),
        }
    }
}

/// A host tensor: shape + typed flat data (row-major).
#[derive(Clone, Debug)]
pub enum Tensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl Tensor {
    pub fn f32(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Tensor::F32 {
            shape: shape.to_vec(),
            data,
        }
    }

    pub fn i32(shape: &[usize], data: Vec<i32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Tensor::I32 {
            shape: shape.to_vec(),
            data,
        }
    }

    pub fn scalar_f32(v: f32) -> Tensor {
        Tensor::f32(&[1], vec![v])
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            Tensor::F32 { shape, .. } | Tensor::I32 { shape, .. } => shape,
        }
    }

    pub fn dtype(&self) -> DType {
        match self {
            Tensor::F32 { .. } => DType::F32,
            Tensor::I32 { .. } => DType::I32,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            Tensor::F32 { data, .. } => data.len(),
            Tensor::I32 { data, .. } => data.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> anyhow::Result<&[f32]> {
        match self {
            Tensor::F32 { data, .. } => Ok(data),
            _ => anyhow::bail!("tensor is not f32"),
        }
    }

    pub fn as_i32(&self) -> anyhow::Result<&[i32]> {
        match self {
            Tensor::I32 { data, .. } => Ok(data),
            _ => anyhow::bail!("tensor is not i32"),
        }
    }

    pub fn into_f32(self) -> anyhow::Result<Vec<f32>> {
        match self {
            Tensor::F32 { data, .. } => Ok(data),
            _ => anyhow::bail!("tensor is not f32"),
        }
    }

    /// Convert to an `xla::Literal` with the right shape.
    pub fn to_literal(&self) -> anyhow::Result<xla::Literal> {
        let dims: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
        let lit = match self {
            Tensor::F32 { data, .. } => xla::Literal::vec1(data),
            Tensor::I32 { data, .. } => xla::Literal::vec1(data),
        };
        Ok(lit.reshape(&dims)?)
    }

    /// Read a literal back into a host tensor.
    pub fn from_literal(lit: &xla::Literal) -> anyhow::Result<Tensor> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => Ok(Tensor::f32(&dims, lit.to_vec::<f32>()?)),
            xla::ElementType::S32 => Ok(Tensor::i32(&dims, lit.to_vec::<i32>()?)),
            other => anyhow::bail!("unsupported output element type {other:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_checks_shape() {
        let t = Tensor::f32(&[2, 3], vec![0.0; 6]);
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.dtype(), DType::F32);
        assert_eq!(t.len(), 6);
    }

    #[test]
    #[should_panic]
    fn mismatched_shape_panics() {
        Tensor::f32(&[2, 3], vec![0.0; 5]);
    }

    #[test]
    fn dtype_parse() {
        assert_eq!(DType::parse("f32").unwrap(), DType::F32);
        assert_eq!(DType::parse("i32").unwrap(), DType::I32);
        assert!(DType::parse("f64").is_err());
    }

    #[test]
    fn accessors_enforce_type() {
        let t = Tensor::i32(&[2], vec![1, 2]);
        assert!(t.as_i32().is_ok());
        assert!(t.as_f32().is_err());
    }
}
