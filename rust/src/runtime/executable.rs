//! One compiled artifact: HLO text -> PJRT executable + typed execute.

use std::time::Instant;

use crate::runtime::artifact::ArtifactSpec;
use crate::runtime::tensor::Tensor;

/// A compiled artifact. Execution validates inputs against the manifest
/// spec so ABI drift fails loudly instead of producing garbage.
pub struct Executable {
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
    /// Cumulative dispatch statistics (per-executable; the coordinator
    /// aggregates these into Table IV-style per-op reports).
    pub calls: std::cell::Cell<u64>,
    pub total_secs: std::cell::Cell<f64>,
}

impl Executable {
    /// Load `<dir>/<file>` HLO text and compile it on `client`.
    pub fn compile(
        client: &xla::PjRtClient,
        spec: &ArtifactSpec,
        path: &std::path::Path,
    ) -> anyhow::Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| anyhow::anyhow!("non-utf8 path {path:?}"))?,
        )
        .map_err(|e| anyhow::anyhow!("parse {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compile {}: {e}", spec.name))?;
        Ok(Executable {
            spec: spec.clone(),
            exe,
            calls: std::cell::Cell::new(0),
            total_secs: std::cell::Cell::new(0.0),
        })
    }

    /// Execute with host tensors; returns the decomposed output tuple.
    ///
    /// This is the *non-resident* path: inputs are transferred host ->
    /// device every call, which is exactly the per-dispatch overhead the
    /// paper's non-batched baseline pays per kernel launch.
    pub fn execute(&self, inputs: &[Tensor]) -> anyhow::Result<Vec<Tensor>> {
        self.check_inputs(inputs)?;
        let t0 = Instant::now();
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<anyhow::Result<_>>()?;
        let result = self.exe.execute::<xla::Literal>(&literals)?;
        let out = Self::collect_outputs(result)?;
        self.calls.set(self.calls.get() + 1);
        self.total_secs
            .set(self.total_secs.get() + t0.elapsed().as_secs_f64());
        Ok(out)
    }

    /// Execute with device-resident buffers (the optimized hot path for
    /// iterated calls like training steps: parameters stay on device).
    /// Returns raw output buffers so the caller can feed them back in.
    pub fn execute_buffers(
        &self,
        inputs: &[&xla::PjRtBuffer],
    ) -> anyhow::Result<Vec<xla::PjRtBuffer>> {
        let t0 = Instant::now();
        let mut result = self.exe.execute_b(inputs)?;
        anyhow::ensure!(!result.is_empty(), "no replica output");
        let outs = result.swap_remove(0);
        self.calls.set(self.calls.get() + 1);
        self.total_secs
            .set(self.total_secs.get() + t0.elapsed().as_secs_f64());
        Ok(outs)
    }

    fn collect_outputs(
        mut result: Vec<Vec<xla::PjRtBuffer>>,
    ) -> anyhow::Result<Vec<Tensor>> {
        anyhow::ensure!(!result.is_empty(), "no replica output");
        let bufs = result.swap_remove(0);
        anyhow::ensure!(!bufs.is_empty(), "empty output buffer list");
        // aot.py lowers with return_tuple=True: one buffer holding the
        // output tuple.
        let lit = bufs[0].to_literal_sync()?;
        let parts = lit.to_tuple()?;
        parts.iter().map(Tensor::from_literal).collect()
    }

    fn check_inputs(&self, inputs: &[Tensor]) -> anyhow::Result<()> {
        anyhow::ensure!(
            inputs.len() == self.spec.inputs.len(),
            "{}: got {} inputs, expected {}",
            self.spec.name,
            inputs.len(),
            self.spec.inputs.len()
        );
        for (i, (t, s)) in inputs.iter().zip(&self.spec.inputs).enumerate() {
            anyhow::ensure!(
                t.shape() == s.shape.as_slice(),
                "{}: input {i} ('{}') shape {:?} != expected {:?}",
                self.spec.name,
                s.name,
                t.shape(),
                s.shape
            );
            anyhow::ensure!(
                t.dtype() == s.dtype,
                "{}: input {i} ('{}') dtype {:?} != expected {:?}",
                self.spec.name,
                s.name,
                t.dtype(),
                s.dtype
            );
        }
        Ok(())
    }

    pub fn mean_dispatch_secs(&self) -> f64 {
        let c = self.calls.get();
        if c == 0 {
            0.0
        } else {
            self.total_secs.get() / c as f64
        }
    }
}
