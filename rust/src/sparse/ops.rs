//! CPU reference multiplications — the rust-side correctness oracle.
//!
//! These follow the paper's pseudocode directly:
//! * [`spmm_st`] — Fig. 2 `SPARSETENSORDENSEMATMUL` (nnz-major loop,
//!   accumulate into C; the atomic add is a plain add on one thread).
//! * [`spmm_csr`] — Fig. 4 row-major CSR SpMM (atomic-free).
//! * [`gemm`] — the dense baseline (cuBLAS stand-in).
//!
//! Every artifact execution in the integration tests is cross-checked
//! against these.

use super::csr::Csr;
use super::dense::Dense;
use super::sparse_tensor::SparseTensor;

/// Fig. 2: C = A @ B with A as SparseTensor.
pub fn spmm_st(a: &SparseTensor, b: &Dense) -> Dense {
    assert_eq!(a.cols, b.rows, "inner dim mismatch");
    let mut c = Dense::zeros(a.rows, b.cols);
    for i in 0..a.nnz() {
        let (rid, cid, val) = a.entry(i);
        let src = b.row(cid);
        let dst = c.row_mut(rid);
        for j in 0..src.len() {
            dst[j] += val * src[j];
        }
    }
    c
}

/// Fig. 4: C = A @ B with A as CSR (row-major, no races by construction).
pub fn spmm_csr(a: &Csr, b: &Dense) -> Dense {
    assert_eq!(a.cols, b.rows, "inner dim mismatch");
    let mut c = Dense::zeros(a.rows, b.cols);
    for r in 0..a.rows {
        let dst = &mut c.data[r * b.cols..(r + 1) * b.cols];
        for i in a.rpt[r] as usize..a.rpt[r + 1] as usize {
            let val = a.vals[i];
            let src = &b.data[a.col_ids[i] as usize * b.cols..][..b.cols];
            for j in 0..b.cols {
                dst[j] += val * src[j];
            }
        }
    }
    c
}

/// Dense GEMM: C = A @ B (the batched-GEMM baseline, one matrix).
pub fn gemm(a: &Dense, b: &Dense) -> Dense {
    assert_eq!(a.cols, b.rows, "inner dim mismatch");
    let mut c = Dense::zeros(a.rows, b.cols);
    for r in 0..a.rows {
        for k in 0..a.cols {
            let av = a.at(r, k);
            if av == 0.0 {
                continue;
            }
            let src = b.row(k);
            let dst = c.row_mut(r);
            for j in 0..b.cols {
                dst[j] += av * src[j];
            }
        }
    }
    c
}

/// `alpha * x + y` in place over flat f32 buffers (gradient accumulation
/// in the non-batched training path).
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::random::{random_coo, RandomSpec};
    use crate::util::rng::Rng;

    #[test]
    fn spmm_st_known_values() {
        // A = [[0,2],[3,0]]; B = [[1,2],[3,4]] => C = [[6,8],[3,6]]
        let st = SparseTensor {
            rows: 2,
            cols: 2,
            ids: vec![0, 1, 1, 0],
            vals: vec![2.0, 3.0],
        };
        let b = Dense::from_rows(vec![vec![1.0, 2.0], vec![3.0, 4.0]]);
        let c = spmm_st(&st, &b);
        assert_eq!(c.data, vec![6.0, 8.0, 3.0, 6.0]);
    }

    #[test]
    fn st_csr_gemm_agree_randomized() {
        let mut rng = Rng::new(99);
        for _ in 0..30 {
            let dim = rng.range(1, 40);
            let spec = RandomSpec {
                dim,
                nnz_per_row: rng.range(1, 5.min(dim)),
                val_lo: -1.0,
                val_hi: 1.0,
            };
            let coo = random_coo(&mut rng, &spec);
            let n_b = rng.range(1, 24);
            let mut b = Dense::zeros(spec.dim, n_b);
            for v in &mut b.data {
                *v = rng.normal();
            }
            let via_st = spmm_st(&coo.to_sparse_tensor(), &b);
            let via_csr = spmm_csr(&coo.to_csr(), &b);
            let via_gemm = gemm(&coo.to_dense(), &b);
            assert!(via_st.allclose(&via_csr, 1e-5, 1e-5));
            assert!(via_st.allclose(&via_gemm, 1e-4, 1e-4));
        }
    }

    #[test]
    fn axpy_accumulates() {
        let x = vec![1.0, 2.0];
        let mut y = vec![10.0, 20.0];
        axpy(0.5, &x, &mut y);
        assert_eq!(y, vec![10.5, 21.0]);
    }
}
