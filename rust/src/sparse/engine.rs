//! The unified batched-SpMM execution engine.
//!
//! The paper's core move is replacing per-sample SpMM kernel launches
//! with one batched launch that processes many small sparse matrices at
//! once. This module is the CPU realization of that idea as an actual
//! execution subsystem rather than a padding format: a [`BatchedSpmm`]
//! trait describing "multiply sample `b` of a packed batch against a
//! dense operand", four backends over the crate's batch layouts, and a
//! sample-parallel [`Executor`] whose `dispatch` processes the whole
//! batch in one call (the CPU analogue of the single fused CUDA launch;
//! `threads = 1` is the serial fallback standing in for the per-sample
//! launch regime).
//!
//! Backends ([`kernels`]):
//! * [`StKernel`] — SparseTensor batches (paper Fig. 2, `PaddedStBatch`);
//! * [`CsrKernel`] — CSR batches (paper Fig. 4, `PaddedCsrBatch`);
//! * [`EllKernel`] — ELL batches (`PaddedEllBatch`, and per-channel
//!   views of the `ModelBatch` adjacency the GCN hot path uses);
//! * [`GemmKernel`] — dense batches (the batched-GEMM / cuBLAS
//!   baseline, also the `X @ W` feature transform in the model).
//!
//! Every caller that multiplies routes through this trait:
//! `gcn::reference::forward`, the coordinator's host dispatch paths,
//! and the bench harness. `sparse::ops` stays the single-matrix oracle
//! the engine is property-tested against (`tests/engine_parity.rs`).

pub mod exec;
pub mod kernels;

pub use exec::Executor;
pub use kernels::{CsrKernel, EllKernel, GemmKernel, StKernel};

/// Right-hand-side operand layout for one engine dispatch.
#[derive(Clone, Copy, Debug)]
pub enum Rhs<'a> {
    /// One dense `[inner_dim, n]` operand shared by every sample
    /// (e.g. a weight matrix).
    Shared(&'a [f32]),
    /// Independent dense operands, flat `[batch, inner_dim, n]`.
    PerSample(&'a [f32]),
}

impl<'a> Rhs<'a> {
    /// The `[inner_dim, n]` slice sample `b` multiplies against.
    #[inline]
    pub fn sample(&self, b: usize, inner: usize, n: usize) -> &'a [f32] {
        match *self {
            Rhs::Shared(s) => &s[..inner * n],
            Rhs::PerSample(s) => &s[b * inner * n..(b + 1) * inner * n],
        }
    }

    /// Total length this layout requires for a given batch geometry.
    pub fn required_len(&self, batch: usize, inner: usize, n: usize) -> usize {
        match self {
            Rhs::Shared(_) => inner * n,
            Rhs::PerSample(_) => batch * inner * n,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            Rhs::Shared(s) | Rhs::PerSample(s) => s.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// One batched sparse (or dense-baseline) matrix multiplication: the
/// uniform interface every execution path dispatches through.
///
/// A kernel owns (a view of) a packed batch of `batch()` operand
/// matrices, each logically `[out_rows, inner_dim]`. The executor calls
/// [`spmm_sample`](BatchedSpmm::spmm_sample) once per sample, possibly
/// from many threads; implementations must therefore be `Sync` and must
/// not mutate shared state.
///
/// Accumulation contract: `out += A[b] @ rhs`. Callers pre-fill `out`
/// with zeros (plain multiply) or a bias (fused bias add) — this is
/// what lets the GCN sum channel contributions through the same
/// interface.
pub trait BatchedSpmm: Sync {
    /// Backend name for bench legends and error messages.
    fn name(&self) -> &'static str;

    /// Number of matrices in the batch.
    fn batch(&self) -> usize;

    /// Rows of each `A[b]` (= rows of each output slice).
    fn out_rows(&self) -> usize;

    /// Columns of each `A[b]` (= rows of the dense operand).
    fn inner_dim(&self) -> usize;

    /// Real (non-padding) non-zeros across the batch — the paper's FLOP
    /// numerator `2 * nnz * n_B`.
    fn real_nnz(&self) -> usize;

    /// `out += A[b] @ rhs` for one sample. `rhs` is `[inner_dim, n]`,
    /// `out` is `[out_rows, n]`, both row-major flat.
    fn spmm_sample(&self, b: usize, rhs: &[f32], n: usize, out: &mut [f32]);
}
