//! The unified batched-SpMM execution engine.
//!
//! The paper's core move is replacing per-sample SpMM kernel launches
//! with one batched launch that processes many small sparse matrices at
//! once. This module is the CPU realization of that idea as an actual
//! execution subsystem rather than a padding format: a [`BatchedSpmm`]
//! trait describing "multiply sample `b` of a packed batch against a
//! dense operand", four backends over the crate's batch layouts, and an
//! [`Executor`] whose `dispatch` processes the whole batch in one call
//! (the CPU analogue of the single fused CUDA launch; `threads = 1` is
//! the serial fallback standing in for the per-sample launch regime).
//! The executor is a thin handle over a persistent [`WorkerPool`]
//! (parked worker threads + a work-stealing task queue over (sample,
//! row-block) tasks, DESIGN.md §9) — share one pool across a trainer's
//! or server's lifetime by cloning the handle instead of constructing
//! executors per call.
//!
//! Backends ([`kernels`]):
//! * [`StKernel`] — SparseTensor batches (paper Fig. 2, `PaddedStBatch`);
//! * [`CsrKernel`] — CSR batches (paper Fig. 4, `PaddedCsrBatch`);
//! * [`EllKernel`] — ELL batches (`PaddedEllBatch`, and per-channel
//!   views of the `ModelBatch` adjacency the GCN hot path uses);
//! * [`GemmKernel`] — dense batches (the batched-GEMM / cuBLAS
//!   baseline, also the `X @ W` feature transform in the model).
//!
//! Every backend dispatches in two transpose forms (DESIGN.md §8): the
//! plain `out += A[b] @ rhs` forward form, and the `out += A[b]^T @ rhs`
//! form ([`Executor::dispatch_t`]) the backward pass uses for `A^T·X`
//! gradients. The `X·W^T` gradient form is covered on the operand side
//! by [`Rhs::SharedTransposed`].
//!
//! Inner loops are vectorized: every dispatch form updates the dense
//! feature dimension in [`LANES`]-wide column blocks the compiler
//! autovectorizes, with the pre-vectorization scalar kernels kept as
//! the [`KernelVariant::Scalar`] parity oracle (DESIGN.md §10).
//! Vectorizing over output columns regroups only independent elements,
//! so both variants are bit-identical — pinned per backend × dispatch
//! form × thread count × policy in `tests/engine_parity.rs`.
//!
//! Every caller that multiplies routes through this trait:
//! `gcn::reference::forward` and `gcn::backward::grad`, the
//! coordinator's host dispatch paths, and the bench harness.
//! `sparse::ops` stays the single-matrix oracle the engine is
//! property-tested against (`tests/engine_parity.rs`).
//!
//! On top of raw dispatch sits the plan/execute split ([`plan`],
//! DESIGN.md §11): a [`StepPlan`] compiles a hot path's dispatch
//! sequence once per geometry (resolved [`Backend`] per dispatch —
//! [`Backend::Auto`] picks ST/CSR/ELL/GEMM from the O(1) nnz cost
//! model — plus shapes, output slots and cached parameter offsets),
//! and a [`Workspace`] arena serves every intermediate buffer, so
//! steady-state replays allocate nothing and skip redundant
//! zero-fills. Planned execution is bit-identical to direct dispatch
//! on every backend × thread count × policy.
//!
//! Forward/transpose round-trip through one backend:
//!
//! ```
//! use bspmm::sparse::batch::PaddedStBatch;
//! use bspmm::sparse::engine::{Executor, Rhs, StKernel};
//! use bspmm::sparse::random::{random_batch, RandomSpec};
//! use bspmm::util::rng::Rng;
//!
//! let mut rng = Rng::new(1);
//! let mats = random_batch(&mut rng, &RandomSpec::new(4, 2), 3);
//! let st = PaddedStBatch::pack(&mats, 4, 8)?;
//! let k = StKernel::new(&st);
//! let x: Vec<f32> = (0..3 * 4 * 2).map(|i| i as f32 * 0.1).collect();
//! let exec = Executor::serial();
//! let y = exec.spmm(&k, Rhs::PerSample(&x), 2)?; // y[b] = A[b] @ x[b]
//! let g = exec.spmm_t(&k, Rhs::PerSample(&y), 2)?; // g[b] = A[b]^T @ y[b]
//! assert_eq!(y.len(), 3 * 4 * 2);
//! assert_eq!(g.len(), 3 * 4 * 2);
//! assert!(g.iter().any(|v| *v != 0.0));
//! # Ok::<(), anyhow::Error>(())
//! ```

pub mod exec;
pub mod kernels;
pub mod plan;
pub mod pool;
pub mod quant;

pub use exec::Executor;
pub use kernels::{CsrKernel, EllKernel, GemmKernel, LANES, StKernel};
pub use plan::{
    choose_backend, plan_budget_from_env, AutoThresholds, Backend, DType, DispatchDesc,
    DispatchProfile, GeometryKey, KernelBundle, ParamRef, PlanCache, PlanCursor, PlanStats,
    RhsKind, SlotId, SlotInit, StepPlan, TenantPlanCaches, Workspace,
};
pub use pool::{PoolStats, SchedPolicy, WorkerPool};
pub use quant::QuantEllKernel;

/// Which inner-loop implementation a dispatch runs (DESIGN.md §10).
///
/// Both variants compute bit-identical output: vectorization happens
/// over *output columns*, which are independent elements, so each
/// output element's accumulation chain over the non-zeros is untouched.
/// The scalar variant survives as the parity oracle the property tests
/// pin the vectorized kernels against, and as the microbench baseline
/// that makes the vectorization win measurable per backend.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum KernelVariant {
    /// The pre-vectorization scalar inner loops (`for j in 0..n`),
    /// kept verbatim as the reference implementation.
    Scalar,
    /// Column-blocked [`LANES`]-wide inner loops (`chunks_exact` +
    /// fixed-size array blocks the compiler autovectorizes, scalar tail
    /// for `n % LANES`). The default.
    #[default]
    Vectorized,
    /// Cache-tiled twin of [`KernelVariant::Vectorized`] for the
    /// large-graph regime (DESIGN.md §12): dispatches run
    /// [`BatchedSpmm::spmm_sample_tiled`] (and the transpose twins
    /// [`BatchedSpmm::spmm_sample_t_tiled`] /
    /// [`BatchedSpmm::spmm_sample_t_rows_tiled`]), which walk the dense
    /// feature matrix in column tiles (width from `BSPMM_TILE_COLS` or
    /// the L2 heuristic) so the gathered `rhs` rows stay hot across the
    /// non-zeros of a tile — GE-SpMM's row-reuse idea on CPU caches.
    /// Backends without a tiled override fall back to the vectorized
    /// loops. Tiling regroups only independent output elements (each
    /// element's accumulation chain over the non-zeros is untouched),
    /// so output is bit-identical to the other variants for any tile
    /// width.
    Tiled,
    /// Explicit-SIMD twin of [`KernelVariant::Vectorized`] (DESIGN.md
    /// §16): dispatches run [`BatchedSpmm::spmm_sample_simd`] and its
    /// transpose / row-blocked twins, whose inner loops call hand-vectorized
    /// `axpy` primitives (AVX2 intrinsics behind the `simd` cargo
    /// feature with runtime CPU detection) instead of trusting
    /// autovectorization. The non-FMA SIMD path performs exactly the
    /// scalar per-element operation sequence (round after multiply,
    /// round after add, same accumulation order), so it stays under the
    /// bit-identity contract; the fused-multiply-add fast path single-
    /// rounds and is therefore opt-in via `BSPMM_ALLOW_FMA=1` with
    /// error-bound tests instead of bit-parity. Without the feature (or
    /// on CPUs without AVX2) the variant falls back to the vectorized
    /// loops — selecting it is always safe.
    Simd,
}

/// Right-hand-side operand layout for one engine dispatch.
#[derive(Clone, Copy, Debug)]
pub enum Rhs<'a> {
    /// One dense `[inner_dim, n]` operand shared by every sample
    /// (e.g. a weight matrix).
    Shared(&'a [f32]),
    /// Independent dense operands, flat `[batch, inner_dim, n]`.
    PerSample(&'a [f32]),
    /// One shared operand stored *transposed*: the slice is `[n,
    /// inner_dim]` row-major and the dispatch multiplies against its
    /// transpose — the `X·W^T` form the backward pass uses
    /// (DESIGN.md §8). The executor materializes the `[inner_dim, n]`
    /// transpose once per dispatch (weights are small), so the
    /// per-sample kernels still read contiguous rows.
    SharedTransposed(&'a [f32]),
}

impl<'a> Rhs<'a> {
    /// The `[inner_dim, n]` slice sample `b` multiplies against.
    ///
    /// # Panics
    /// On [`Rhs::SharedTransposed`]: the executor normalizes that
    /// layout to [`Rhs::Shared`] before any per-sample access.
    #[inline]
    pub fn sample(&self, b: usize, inner: usize, n: usize) -> &'a [f32] {
        match *self {
            Rhs::Shared(s) => &s[..inner * n],
            Rhs::PerSample(s) => &s[b * inner * n..(b + 1) * inner * n],
            Rhs::SharedTransposed(_) => {
                panic!("SharedTransposed must be materialized by the executor before sampling")
            }
        }
    }

    /// Total length this layout requires for a given batch geometry.
    pub fn required_len(&self, batch: usize, inner: usize, n: usize) -> usize {
        match self {
            Rhs::Shared(_) | Rhs::SharedTransposed(_) => inner * n,
            Rhs::PerSample(_) => batch * inner * n,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            Rhs::Shared(s) | Rhs::PerSample(s) | Rhs::SharedTransposed(s) => s.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// One batched sparse (or dense-baseline) matrix multiplication: the
/// uniform interface every execution path dispatches through.
///
/// A kernel owns (a view of) a packed batch of `batch()` operand
/// matrices, each logically `[out_rows, inner_dim]`. The executor calls
/// [`spmm_sample`](BatchedSpmm::spmm_sample) (or its transpose twin
/// [`spmm_sample_t`](BatchedSpmm::spmm_sample_t)) once per sample,
/// possibly from many threads; implementations must therefore be `Sync`
/// and must not mutate shared state.
///
/// Accumulation contract: `out += A[b] @ rhs`. Callers pre-fill `out`
/// with zeros (plain multiply) or a bias (fused bias add) — this is
/// what lets the GCN sum channel contributions through the same
/// interface, and what lets the backward pass accumulate `dX` across
/// channels (DESIGN.md §8).
pub trait BatchedSpmm: Sync {
    /// Backend name for bench legends and error messages.
    fn name(&self) -> &'static str;

    /// Number of matrices in the batch.
    fn batch(&self) -> usize;

    /// Rows of each `A[b]` (= rows of each output slice).
    fn out_rows(&self) -> usize;

    /// Columns of each `A[b]` (= rows of the dense operand).
    fn inner_dim(&self) -> usize;

    /// Real (non-padding) non-zeros across the batch — the paper's FLOP
    /// numerator `2 * nnz * n_B`.
    fn real_nnz(&self) -> usize;

    /// `out += A[b] @ rhs` for one sample. `rhs` is `[inner_dim, n]`,
    /// `out` is `[out_rows, n]`, both row-major flat.
    fn spmm_sample(&self, b: usize, rhs: &[f32], n: usize, out: &mut [f32]);

    /// `out += A[b]^T @ rhs` for one sample — the `A^T·X` transpose
    /// form the backward pass dispatches (DESIGN.md §8). `rhs` is
    /// `[out_rows, n]`, `out` is `[inner_dim, n]`, both row-major flat.
    fn spmm_sample_t(&self, b: usize, rhs: &[f32], n: usize, out: &mut [f32]);

    /// Real non-zeros of sample `b` — the worker pool's cost-model
    /// signal for decomposing a dispatch into near-equal tasks
    /// (DESIGN.md §9). An estimate is fine (the dense backend reports
    /// its full extent without scanning); stealing absorbs the error.
    fn sample_nnz(&self, b: usize) -> usize;

    /// Row-blocked form of [`spmm_sample`](BatchedSpmm::spmm_sample):
    /// accumulate only output rows `row0 .. row0 + out.len() / n`, with
    /// `out` the `[rows, n]` block for exactly that range. Contributions
    /// to each output element must arrive in the same order as in the
    /// full-sample call — that per-element order is what makes pool
    /// output bit-identical to serial regardless of how a sample is
    /// split across workers (DESIGN.md §9).
    fn spmm_sample_rows(&self, b: usize, row0: usize, rhs: &[f32], n: usize, out: &mut [f32]);

    /// Row-blocked form of
    /// [`spmm_sample_t`](BatchedSpmm::spmm_sample_t): accumulate only
    /// transpose-output rows (columns of `A[b]`) `row0 .. row0 +
    /// out.len() / n`, under the same per-element accumulation-order
    /// contract as [`spmm_sample_rows`](BatchedSpmm::spmm_sample_rows).
    /// This is the split that parallelizes the backward's batch-1
    /// `dW = X^T·dU` dispatches within one sample.
    fn spmm_sample_t_rows(&self, b: usize, row0: usize, rhs: &[f32], n: usize, out: &mut [f32]);

    /// Scalar-inner-loop twin of
    /// [`spmm_sample`](BatchedSpmm::spmm_sample): the pre-vectorization
    /// kernel, kept verbatim as the [`KernelVariant::Scalar`] parity
    /// oracle and bench baseline (DESIGN.md §10). Must be bit-identical
    /// to the vectorized form on every input.
    fn spmm_sample_scalar(&self, b: usize, rhs: &[f32], n: usize, out: &mut [f32]);

    /// Scalar twin of [`spmm_sample_t`](BatchedSpmm::spmm_sample_t).
    fn spmm_sample_t_scalar(&self, b: usize, rhs: &[f32], n: usize, out: &mut [f32]);

    /// Scalar twin of
    /// [`spmm_sample_rows`](BatchedSpmm::spmm_sample_rows).
    fn spmm_sample_rows_scalar(
        &self,
        b: usize,
        row0: usize,
        rhs: &[f32],
        n: usize,
        out: &mut [f32],
    );

    /// Scalar twin of
    /// [`spmm_sample_t_rows`](BatchedSpmm::spmm_sample_t_rows).
    fn spmm_sample_t_rows_scalar(
        &self,
        b: usize,
        row0: usize,
        rhs: &[f32],
        n: usize,
        out: &mut [f32],
    );

    /// Cache-tiled twin of [`spmm_sample`](BatchedSpmm::spmm_sample)
    /// ([`KernelVariant::Tiled`], DESIGN.md §12): iterate the sample's
    /// non-zeros once per column tile of the dense operand so the
    /// gathered `rhs` rows stay resident in cache across a tile. Must
    /// be bit-identical to the untiled form — tiling only regroups
    /// independent output elements. The default delegates to the
    /// vectorized kernel; only backends where tiling pays (row-major
    /// CSR over large graphs) override it.
    fn spmm_sample_tiled(&self, b: usize, rhs: &[f32], n: usize, out: &mut [f32]) {
        self.spmm_sample(b, rhs, n, out)
    }

    /// Tiled twin of [`spmm_sample_rows`](BatchedSpmm::spmm_sample_rows)
    /// — the row-blocked form the pool's (sample, row-block) tasks run
    /// under [`KernelVariant::Tiled`]. Same bit-identity contract and
    /// vectorized default as
    /// [`spmm_sample_tiled`](BatchedSpmm::spmm_sample_tiled).
    fn spmm_sample_rows_tiled(&self, b: usize, row0: usize, rhs: &[f32], n: usize, out: &mut [f32]) {
        self.spmm_sample_rows(b, row0, rhs, n, out)
    }

    /// Cache-tiled twin of [`spmm_sample_t`](BatchedSpmm::spmm_sample_t)
    /// — the transpose (scatter) form under [`KernelVariant::Tiled`],
    /// so large-graph backward dispatches get the same column tiling as
    /// forward (DESIGN.md §12). Per column tile, each non-zero `(r, c)`
    /// scatters `rhs[r, tile]` into `out[c, tile]`; restricting both
    /// slices to the tile keeps the touched dense rows L2-resident.
    /// Same bit-identity contract and vectorized default as
    /// [`spmm_sample_tiled`](BatchedSpmm::spmm_sample_tiled).
    fn spmm_sample_t_tiled(&self, b: usize, rhs: &[f32], n: usize, out: &mut [f32]) {
        self.spmm_sample_t(b, rhs, n, out)
    }

    /// Tiled twin of
    /// [`spmm_sample_t_rows`](BatchedSpmm::spmm_sample_t_rows) — the
    /// row-blocked transpose form the pool's (sample, row-block) tasks
    /// run under [`KernelVariant::Tiled`]. Same bit-identity contract
    /// and vectorized default as
    /// [`spmm_sample_t_tiled`](BatchedSpmm::spmm_sample_t_tiled).
    fn spmm_sample_t_rows_tiled(
        &self,
        b: usize,
        row0: usize,
        rhs: &[f32],
        n: usize,
        out: &mut [f32],
    ) {
        self.spmm_sample_t_rows(b, row0, rhs, n, out)
    }

    /// Explicit-SIMD twin of [`spmm_sample`](BatchedSpmm::spmm_sample)
    /// ([`KernelVariant::Simd`], DESIGN.md §16): the inner loop calls
    /// the hand-vectorized `axpy` primitive (AVX2 behind the `simd`
    /// feature, vectorized fallback otherwise). Must be bit-identical
    /// to the scalar oracle whenever FMA is not enabled — the SIMD
    /// lanes perform the same round-after-multiply / round-after-add
    /// sequence per element, in the same accumulation order. The
    /// default delegates to the vectorized kernel.
    fn spmm_sample_simd(&self, b: usize, rhs: &[f32], n: usize, out: &mut [f32]) {
        self.spmm_sample(b, rhs, n, out)
    }

    /// Explicit-SIMD twin of
    /// [`spmm_sample_t`](BatchedSpmm::spmm_sample_t) — the transpose
    /// (scatter) form under [`KernelVariant::Simd`]. Same bit-identity
    /// contract and vectorized default as
    /// [`spmm_sample_simd`](BatchedSpmm::spmm_sample_simd).
    fn spmm_sample_t_simd(&self, b: usize, rhs: &[f32], n: usize, out: &mut [f32]) {
        self.spmm_sample_t(b, rhs, n, out)
    }

    /// Explicit-SIMD twin of
    /// [`spmm_sample_rows`](BatchedSpmm::spmm_sample_rows) — the
    /// row-blocked form the pool's (sample, row-block) tasks run under
    /// [`KernelVariant::Simd`]. Same bit-identity contract and
    /// vectorized default as
    /// [`spmm_sample_simd`](BatchedSpmm::spmm_sample_simd).
    fn spmm_sample_rows_simd(&self, b: usize, row0: usize, rhs: &[f32], n: usize, out: &mut [f32]) {
        self.spmm_sample_rows(b, row0, rhs, n, out)
    }

    /// Explicit-SIMD twin of
    /// [`spmm_sample_t_rows`](BatchedSpmm::spmm_sample_t_rows) — the
    /// row-blocked transpose form under [`KernelVariant::Simd`]. Same
    /// bit-identity contract and vectorized default as
    /// [`spmm_sample_simd`](BatchedSpmm::spmm_sample_simd).
    fn spmm_sample_t_rows_simd(
        &self,
        b: usize,
        row0: usize,
        rhs: &[f32],
        n: usize,
        out: &mut [f32],
    ) {
        self.spmm_sample_t_rows(b, row0, rhs, n, out)
    }

    /// Real non-zeros of sample `b` restricted to output rows
    /// `r0..r1`, in O(1), when the layout can answer that (CSR: a row
    /// pointer difference). `None` means the pool's planner falls back
    /// to equal-row block boundaries; `Some` enables the
    /// degree-bucketed nnz-balanced row split for single-giant-graph
    /// dispatches (DESIGN.md §12).
    fn rows_nnz(&self, _b: usize, _r0: usize, _r1: usize) -> Option<usize> {
        None
    }
}

/// References to kernels are kernels: this is what lets the executor
/// type-erase any `K: BatchedSpmm + ?Sized` into the `&dyn BatchedSpmm`
/// the worker pool runs (an unsized `K` cannot be coerced directly, but
/// `&K` is always `Sized`).
impl<K: BatchedSpmm + ?Sized> BatchedSpmm for &K {
    fn name(&self) -> &'static str {
        (**self).name()
    }

    fn batch(&self) -> usize {
        (**self).batch()
    }

    fn out_rows(&self) -> usize {
        (**self).out_rows()
    }

    fn inner_dim(&self) -> usize {
        (**self).inner_dim()
    }

    fn real_nnz(&self) -> usize {
        (**self).real_nnz()
    }

    fn spmm_sample(&self, b: usize, rhs: &[f32], n: usize, out: &mut [f32]) {
        (**self).spmm_sample(b, rhs, n, out)
    }

    fn spmm_sample_t(&self, b: usize, rhs: &[f32], n: usize, out: &mut [f32]) {
        (**self).spmm_sample_t(b, rhs, n, out)
    }

    fn sample_nnz(&self, b: usize) -> usize {
        (**self).sample_nnz(b)
    }

    fn spmm_sample_rows(&self, b: usize, row0: usize, rhs: &[f32], n: usize, out: &mut [f32]) {
        (**self).spmm_sample_rows(b, row0, rhs, n, out)
    }

    fn spmm_sample_t_rows(&self, b: usize, row0: usize, rhs: &[f32], n: usize, out: &mut [f32]) {
        (**self).spmm_sample_t_rows(b, row0, rhs, n, out)
    }

    fn spmm_sample_scalar(&self, b: usize, rhs: &[f32], n: usize, out: &mut [f32]) {
        (**self).spmm_sample_scalar(b, rhs, n, out)
    }

    fn spmm_sample_t_scalar(&self, b: usize, rhs: &[f32], n: usize, out: &mut [f32]) {
        (**self).spmm_sample_t_scalar(b, rhs, n, out)
    }

    fn spmm_sample_rows_scalar(
        &self,
        b: usize,
        row0: usize,
        rhs: &[f32],
        n: usize,
        out: &mut [f32],
    ) {
        (**self).spmm_sample_rows_scalar(b, row0, rhs, n, out)
    }

    fn spmm_sample_t_rows_scalar(
        &self,
        b: usize,
        row0: usize,
        rhs: &[f32],
        n: usize,
        out: &mut [f32],
    ) {
        (**self).spmm_sample_t_rows_scalar(b, row0, rhs, n, out)
    }

    fn spmm_sample_tiled(&self, b: usize, rhs: &[f32], n: usize, out: &mut [f32]) {
        (**self).spmm_sample_tiled(b, rhs, n, out)
    }

    fn spmm_sample_rows_tiled(
        &self,
        b: usize,
        row0: usize,
        rhs: &[f32],
        n: usize,
        out: &mut [f32],
    ) {
        (**self).spmm_sample_rows_tiled(b, row0, rhs, n, out)
    }

    fn spmm_sample_t_tiled(&self, b: usize, rhs: &[f32], n: usize, out: &mut [f32]) {
        (**self).spmm_sample_t_tiled(b, rhs, n, out)
    }

    fn spmm_sample_t_rows_tiled(
        &self,
        b: usize,
        row0: usize,
        rhs: &[f32],
        n: usize,
        out: &mut [f32],
    ) {
        (**self).spmm_sample_t_rows_tiled(b, row0, rhs, n, out)
    }

    fn spmm_sample_simd(&self, b: usize, rhs: &[f32], n: usize, out: &mut [f32]) {
        (**self).spmm_sample_simd(b, rhs, n, out)
    }

    fn spmm_sample_t_simd(&self, b: usize, rhs: &[f32], n: usize, out: &mut [f32]) {
        (**self).spmm_sample_t_simd(b, rhs, n, out)
    }

    fn spmm_sample_rows_simd(&self, b: usize, row0: usize, rhs: &[f32], n: usize, out: &mut [f32]) {
        (**self).spmm_sample_rows_simd(b, row0, rhs, n, out)
    }

    fn spmm_sample_t_rows_simd(
        &self,
        b: usize,
        row0: usize,
        rhs: &[f32],
        n: usize,
        out: &mut [f32],
    ) {
        (**self).spmm_sample_t_rows_simd(b, row0, rhs, n, out)
    }

    fn rows_nnz(&self, b: usize, r0: usize, r1: usize) -> Option<usize> {
        (**self).rows_nnz(b, r0, r1)
    }
}
