//! Randomly-generated sparse workloads (paper §V-A).
//!
//! "Since our target is graph data, the randomly generated sparse
//! matrices are square. The row size (dim) and nnz/row are parameterized
//! in generating matrix, and the non-zero pattern is different from each
//! other."  We reproduce exactly that: square `dim x dim`, `nnz_per_row`
//! distinct column picks per row, values uniform, every matrix drawn
//! from a fresh PRNG stream.

use super::coo::Coo;
use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug)]
pub struct RandomSpec {
    pub dim: usize,
    pub nnz_per_row: usize,
    pub val_lo: f32,
    pub val_hi: f32,
}

impl RandomSpec {
    pub fn new(dim: usize, nnz_per_row: usize) -> Self {
        Self {
            dim,
            nnz_per_row,
            val_lo: 0.1,
            val_hi: 1.0,
        }
    }

    pub fn nnz(&self) -> usize {
        self.dim * self.nnz_per_row
    }
}

/// One random square matrix: every row gets `nnz_per_row` *distinct*
/// columns (so nnz is exactly `dim * nnz_per_row`, matching the paper's
/// FLOP accounting `2 * nnz_A * n_B`).
pub fn random_coo(rng: &mut Rng, spec: &RandomSpec) -> Coo {
    assert!(spec.nnz_per_row <= spec.dim, "nnz/row > dim");
    let mut coo = Coo::new(spec.dim, spec.dim);
    for r in 0..spec.dim {
        for c in rng.sample_distinct(spec.dim, spec.nnz_per_row) {
            coo.push(r, c, rng.f32_range(spec.val_lo, spec.val_hi));
        }
    }
    coo
}

/// A batch of matrices with identical spec but independent patterns
/// (§V-A preliminary evaluation).
pub fn random_batch(rng: &mut Rng, spec: &RandomSpec, batch: usize) -> Vec<Coo> {
    (0..batch).map(|_| random_coo(rng, spec)).collect()
}

/// Fig. 10's mixed batch: dims uniform in `dims`, nnz/row uniform in
/// `zs`, independent per matrix.
pub fn random_mixed_batch(
    rng: &mut Rng,
    dims: (usize, usize),
    zs: (usize, usize),
    batch: usize,
) -> Vec<Coo> {
    (0..batch)
        .map(|_| {
            let dim = rng.range(dims.0, dims.1);
            let z = rng.range(zs.0, zs.1).min(dim);
            random_coo(rng, &RandomSpec::new(dim, z))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_nnz_and_bounds() {
        let mut rng = Rng::new(1);
        let spec = RandomSpec::new(50, 2);
        let m = random_coo(&mut rng, &spec);
        assert_eq!(m.nnz(), 100);
        assert_eq!(m.rows, 50);
        m.to_sparse_tensor().validate().unwrap();
        m.to_csr().validate().unwrap();
    }

    #[test]
    fn rows_have_distinct_cols() {
        let mut rng = Rng::new(2);
        let m = random_coo(&mut rng, &RandomSpec::new(20, 5));
        let csr = m.to_csr();
        for r in 0..20 {
            let mut cols: Vec<u32> = csr.col_ids[csr.row_range(r)].to_vec();
            let n = cols.len();
            cols.sort_unstable();
            cols.dedup();
            assert_eq!(cols.len(), n, "row {r} has duplicate cols");
        }
    }

    #[test]
    fn patterns_differ_across_batch() {
        let mut rng = Rng::new(3);
        let batch = random_batch(&mut rng, &RandomSpec::new(32, 2), 10);
        assert_eq!(batch.len(), 10);
        let distinct: std::collections::HashSet<Vec<u32>> =
            batch.iter().map(|m| m.col_ids.clone()).collect();
        assert!(distinct.len() > 1, "all patterns identical");
    }

    #[test]
    fn mixed_batch_ranges() {
        let mut rng = Rng::new(4);
        let batch = random_mixed_batch(&mut rng, (32, 256), (1, 5), 100);
        assert!(batch.iter().all(|m| (32..=256).contains(&m.rows)));
        let dims: std::collections::HashSet<usize> = batch.iter().map(|m| m.rows).collect();
        assert!(dims.len() > 10, "dims not actually mixed");
    }

    #[test]
    #[should_panic]
    fn nnz_per_row_cannot_exceed_dim() {
        let mut rng = Rng::new(5);
        random_coo(&mut rng, &RandomSpec::new(3, 4));
    }
}
