//! Sparse-matrix substrate (paper §II-B/C) and the batched execution
//! engine built on top of it.
//!
//! Formats: [`coo::Coo`], [`csr::Csr`], [`sparse_tensor::SparseTensor`]
//! (the TensorFlow-style structure the paper's baseline uses), and
//! [`dense::Dense`] row-major dense matrices. [`batch`] packs many small
//! matrices into the zero-padded batch layouts the AOT artifacts expect
//! (ST, CSR, ELL); [`random`] generates the §V-A randomly-generated
//! workloads; [`ops`] provides CPU reference multiplications (the
//! correctness oracle on the rust side, mirroring
//! `python/compile/kernels/ref.py`).
//!
//! [`engine`] is the execution layer: the [`engine::BatchedSpmm`] trait
//! (one interface, four backends — ST / CSR / ELL / dense-GEMM, each in
//! plain and transpose form) plus an [`engine::Executor`] that
//! processes a whole packed batch in one dispatch over a persistent
//! work-stealing [`engine::WorkerPool`] (DESIGN.md §9). The GCN forward
//! *and backward* passes, the coordinator's host dispatch paths, and
//! the bench harness all multiply through it; `ops` stays the
//! single-matrix oracle it is property-tested against.

pub mod batch;
pub mod coo;
pub mod csr;
pub mod dense;
pub mod engine;
pub mod ops;
pub mod random;
pub mod sparse_tensor;

pub use batch::{LargeGraphBatch, PaddedCsrBatch, PaddedEllBatch, PaddedStBatch};
pub use coo::Coo;
pub use csr::Csr;
pub use dense::Dense;
pub use engine::{BatchedSpmm, Executor, WorkerPool};
pub use sparse_tensor::SparseTensor;
