//! Sparse-matrix substrate (paper §II-B/C).
//!
//! Formats: [`coo::Coo`], [`csr::Csr`], [`sparse_tensor::SparseTensor`]
//! (the TensorFlow-style structure the paper's baseline uses), and
//! [`dense::Dense`] row-major dense matrices. [`batch`] packs many small
//! matrices into the zero-padded batch layouts the AOT artifacts expect;
//! [`random`] generates the §V-A randomly-generated workloads; [`ops`]
//! provides CPU reference multiplications (the correctness oracle on the
//! rust side, mirroring `python/compile/kernels/ref.py`).

pub mod batch;
pub mod coo;
pub mod csr;
pub mod dense;
pub mod ops;
pub mod random;
pub mod sparse_tensor;

pub use batch::{PaddedCsrBatch, PaddedStBatch};
pub use coo::Coo;
pub use csr::Csr;
pub use dense::Dense;
pub use sparse_tensor::SparseTensor;
