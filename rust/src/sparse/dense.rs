//! Row-major dense matrix (the `B`/`C` operands of SpMM).

/// Row-major `rows x cols` f32 matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Dense {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Dense {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn from_rows(rows: Vec<Vec<f32>>) -> Self {
        let r = rows.len();
        let c = rows.first().map_or(0, Vec::len);
        assert!(rows.iter().all(|row| row.len() == c), "ragged rows");
        Self {
            rows: r,
            cols: c,
            data: rows.into_iter().flatten().collect(),
        }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f32 {
        debug_assert!(r < self.rows && c < self.cols);
        &mut self.data[r * self.cols + c]
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Zero-pad (or keep) to a larger shape; used when bucketing
    /// variable-size graphs into fixed artifact shapes.
    pub fn padded(&self, rows: usize, cols: usize) -> Dense {
        assert!(rows >= self.rows && cols >= self.cols);
        let mut out = Dense::zeros(rows, cols);
        for r in 0..self.rows {
            out.row_mut(r)[..self.cols].copy_from_slice(self.row(r));
        }
        out
    }

    pub fn max_abs_diff(&self, other: &Dense) -> f32 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// Relative allclose in the numpy sense.
    pub fn allclose(&self, other: &Dense, rtol: f32, atol: f32) -> bool {
        if (self.rows, self.cols) != (other.rows, other.cols) {
            return false;
        }
        self.data
            .iter()
            .zip(&other.data)
            .all(|(a, b)| (a - b).abs() <= atol + rtol * b.abs())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eye_diagonal() {
        let m = Dense::eye(3);
        assert_eq!(m.at(1, 1), 1.0);
        assert_eq!(m.at(0, 2), 0.0);
    }

    #[test]
    fn padding_preserves_content() {
        let m = Dense::from_rows(vec![vec![1.0, 2.0], vec![3.0, 4.0]]);
        let p = m.padded(3, 4);
        assert_eq!(p.at(1, 1), 4.0);
        assert_eq!(p.at(2, 3), 0.0);
        assert_eq!(p.rows, 3);
        assert_eq!(p.cols, 4);
    }

    #[test]
    fn allclose_tolerances() {
        let a = Dense::from_rows(vec![vec![1.0, 2.0]]);
        let mut b = a.clone();
        b.data[0] += 1e-6;
        assert!(a.allclose(&b, 1e-4, 1e-4));
        b.data[0] += 1.0;
        assert!(!a.allclose(&b, 1e-4, 1e-4));
    }

    #[test]
    #[should_panic]
    fn ragged_rows_rejected() {
        Dense::from_rows(vec![vec![1.0], vec![1.0, 2.0]]);
    }
}
