//! COO (coordinate) format: `(row, col, val)` triples (paper Fig. 1).

use super::csr::Csr;
use super::dense::Dense;
use super::sparse_tensor::SparseTensor;

/// COO sparse matrix. Entries need not be sorted; duplicates accumulate
/// on multiplication (matching the paper's atomic-add semantics).
#[derive(Clone, Debug, PartialEq)]
pub struct Coo {
    pub rows: usize,
    pub cols: usize,
    pub row_ids: Vec<u32>,
    pub col_ids: Vec<u32>,
    pub vals: Vec<f32>,
}

impl Coo {
    pub fn new(rows: usize, cols: usize) -> Self {
        Self {
            rows,
            cols,
            row_ids: Vec::new(),
            col_ids: Vec::new(),
            vals: Vec::new(),
        }
    }

    pub fn push(&mut self, r: usize, c: usize, v: f32) {
        assert!(r < self.rows && c < self.cols, "({r},{c}) out of bounds");
        self.row_ids.push(r as u32);
        self.col_ids.push(c as u32);
        self.vals.push(v);
    }

    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    /// Convert to CSR (counting sort by row; stable within a row).
    pub fn to_csr(&self) -> Csr {
        let mut counts = vec![0u32; self.rows + 1];
        for &r in &self.row_ids {
            counts[r as usize + 1] += 1;
        }
        for i in 0..self.rows {
            counts[i + 1] += counts[i];
        }
        let rpt = counts.clone();
        let mut col_ids = vec![0u32; self.nnz()];
        let mut vals = vec![0f32; self.nnz()];
        let mut cursor = rpt.clone();
        for i in 0..self.nnz() {
            let r = self.row_ids[i] as usize;
            let dst = cursor[r] as usize;
            col_ids[dst] = self.col_ids[i];
            vals[dst] = self.vals[i];
            cursor[r] += 1;
        }
        Csr {
            rows: self.rows,
            cols: self.cols,
            rpt,
            col_ids,
            vals,
        }
    }

    /// Convert to the TF-style SparseTensor (interleaved id pairs).
    pub fn to_sparse_tensor(&self) -> SparseTensor {
        let mut ids = Vec::with_capacity(self.nnz() * 2);
        for i in 0..self.nnz() {
            ids.push(self.row_ids[i]);
            ids.push(self.col_ids[i]);
        }
        SparseTensor {
            rows: self.rows,
            cols: self.cols,
            ids,
            vals: self.vals.clone(),
        }
    }

    pub fn to_dense(&self) -> Dense {
        let mut d = Dense::zeros(self.rows, self.cols);
        for i in 0..self.nnz() {
            *d.at_mut(self.row_ids[i] as usize, self.col_ids[i] as usize) += self.vals[i];
        }
        d
    }

    /// Transpose (swap row/col ids) — the SpMM backward pass operand.
    pub fn transposed(&self) -> Coo {
        Coo {
            rows: self.cols,
            cols: self.rows,
            row_ids: self.col_ids.clone(),
            col_ids: self.row_ids.clone(),
            vals: self.vals.clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Coo {
        // [[0, 1, 0],
        //  [2, 0, 3],
        //  [0, 0, 0]]  (one duplicate on (1,2): 1+2)
        let mut m = Coo::new(3, 3);
        m.push(1, 2, 1.0);
        m.push(0, 1, 1.0);
        m.push(1, 0, 2.0);
        m.push(1, 2, 2.0);
        m
    }

    #[test]
    fn to_dense_accumulates_duplicates() {
        let d = sample().to_dense();
        assert_eq!(d.at(1, 2), 3.0);
        assert_eq!(d.at(0, 1), 1.0);
        assert_eq!(d.at(2, 2), 0.0);
    }

    #[test]
    fn csr_roundtrip_same_dense() {
        let coo = sample();
        let csr = coo.to_csr();
        assert_eq!(csr.rpt, vec![0, 1, 4, 4]);
        assert_eq!(coo.to_dense(), csr.to_dense());
    }

    #[test]
    fn sparse_tensor_roundtrip_same_dense() {
        let coo = sample();
        assert_eq!(coo.to_dense(), coo.to_sparse_tensor().to_dense());
    }

    #[test]
    fn transpose_is_dense_transpose() {
        let coo = sample();
        let t = coo.transposed().to_dense();
        let d = coo.to_dense();
        for r in 0..3 {
            for c in 0..3 {
                assert_eq!(d.at(r, c), t.at(c, r));
            }
        }
    }

    #[test]
    #[should_panic]
    fn out_of_bounds_rejected() {
        Coo::new(2, 2).push(2, 0, 1.0);
    }
}
