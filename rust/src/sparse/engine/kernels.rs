//! The four [`BatchedSpmm`] backends, one per batch layout.
//!
//! Each kernel is a borrowed view over an existing packed batch — no
//! copying at construction, so building a kernel is free and the bench
//! harness can time pure execution. All inner loops follow the same
//! iteration order as the `sparse::ops` single-matrix oracles (and the
//! formerly-inlined loops in `gcn::reference`), so engine results are
//! bit-identical to the code they replaced.
//!
//! Every backend additionally implements the row-blocked variants
//! (`spmm_sample_rows` / `spmm_sample_t_rows`) the worker pool uses to
//! split a single dominant sample across workers (DESIGN.md §9). The
//! row-indexed layouts (CSR/ELL/GEMM forward, GEMM transpose) jump
//! straight to the block; the scatter-shaped forms (ST both ways,
//! CSR/ELL transpose) scan the sample's non-zeros in the serial order
//! and keep only contributions landing inside the block — more scanning
//! than a dedicated index would need, but it preserves the serial
//! per-element accumulation order exactly, which is what makes pool
//! output bit-identical to serial under any steal order.
//!
//! **Vectorization (DESIGN.md §10).** The per-non-zero inner loop over
//! the dense feature dimension — `out[r, j] += a[r, c] * x[c, j]` for
//! `j in 0..n` — is the engine's hottest loop, and the default kernels
//! run it in column-blocked form: [`LANES`]-wide blocks of output
//! columns updated through `chunks_exact` and fixed-size `[f32; LANES]`
//! arrays (which the compiler reliably autovectorizes; no unsafe, no
//! intrinsics), plus a scalar tail for the `n % LANES` trailing
//! columns. Output columns are independent elements, so the blocking
//! regroups *which j's are updated together* without touching any
//! element's accumulation chain over the non-zeros — vectorized output
//! is bit-identical to the scalar reference. The pre-vectorization
//! scalar loops survive verbatim as the `*_scalar` trait methods
//! ([`KernelVariant::Scalar`]): the parity oracle the property tests
//! pin against, and the microbench baseline the scalar-vs-vectorized
//! GFLOPS comparison runs on.
//!
//! **Explicit SIMD (DESIGN.md §16).** The `*_simd` trait methods
//! ([`KernelVariant::Simd`]) run the same loops through
//! [`axpy_row_simd`], which hand-vectorizes the row update with AVX2
//! intrinsics when the `simd` cargo feature is on and the CPU reports
//! AVX2 (runtime detection; everything else falls back to the
//! autovectorized [`axpy_row`]). The non-FMA SIMD lanes perform exactly
//! the scalar round-after-multiply / round-after-add sequence per
//! element in the same accumulation order, so they stay bit-identical
//! to the scalar oracle. The fused-multiply-add path single-rounds
//! (`_mm256_fmadd_ps` / `f32::mul_add`) and therefore breaks
//! bit-identity by up to one product rounding per non-zero; it is
//! opt-in via `BSPMM_ALLOW_FMA=1` ([`fma_allowed`]) and covered by
//! error-bound tests instead of bit-parity.
//!
//! [`KernelVariant::Scalar`]: super::KernelVariant::Scalar
//! [`KernelVariant::Simd`]: super::KernelVariant::Simd

use super::BatchedSpmm;
use crate::graph::dataset::ModelBatch;
use crate::sparse::batch::{PaddedCsrBatch, PaddedEllBatch, PaddedStBatch};

/// Column-block width of the vectorized inner loops: 8 f32 lanes is one
/// 256-bit AVX2 vector (two 128-bit SSE/NEON ops on narrower hosts),
/// wide enough to saturate the FP units on the tox21/reaction100
/// feature widths (64+) while bounding the scalar tail at 7 elements.
/// A compile-time constant because the whole point is fixed-size array
/// blocks the compiler can keep in registers.
pub const LANES: usize = 8;

/// Default column-tile width of the cache-tiled CSR path
/// ([`KernelVariant::Tiled`], DESIGN.md §12). 256 f32 columns = 1 KiB
/// per dense row, so a tile keeps roughly 256 gathered `rhs` rows
/// resident in a 256 KiB L2 — the regime where GE-SpMM-style row reuse
/// pays on 10^5–10^6-node power-law graphs. Tiny-graph dispatches
/// (feature widths ≤ the tile) degenerate to the untiled loop, so the
/// default is safe to leave on everywhere.
///
/// [`KernelVariant::Tiled`]: super::KernelVariant::Tiled
pub const DEFAULT_TILE_COLS: usize = 256;

/// Resolve the process-wide column-tile width: `BSPMM_TILE_COLS` when
/// set to a positive integer (the env override always wins), else the
/// one-shot L2 probe ([`probe_l2_tile_cols`]); either way clamped to at
/// least [`LANES`] so a tile never degenerates below one vector block.
/// Resolved once per process (a launch-time calibration, not a
/// per-dispatch one) — [`Executor`](super::Executor) construction warms
/// this cache so the probe's few milliseconds never land inside a timed
/// dispatch.
pub fn tile_cols_from_env() -> usize {
    static TILE_COLS: std::sync::OnceLock<usize> = std::sync::OnceLock::new();
    *TILE_COLS.get_or_init(|| {
        std::env::var("BSPMM_TILE_COLS")
            .ok()
            .and_then(|v| v.trim().parse::<usize>().ok())
            .filter(|&v| v > 0)
            .unwrap_or_else(probe_l2_tile_cols)
            .max(LANES)
    })
}

/// One-shot L2-size probe behind [`tile_cols_from_env`] (DESIGN.md
/// §16): a timed strided sweep over geometrically growing buffers finds
/// the largest working set that still runs at near-cache speed — the
/// L2 knee — and sizes the column tile so that a tile's worth of
/// gathered `rhs` rows fits it. The model is the one
/// [`DEFAULT_TILE_COLS`] hardcodes: a tile of `tc` f32 columns keeps
/// roughly `tc` dense rows of `4 * tc` bytes hot, so
/// `tc = sqrt(l2_bytes / 4)` (256 KiB L2 → 256 columns, the old
/// default). The result is rounded down to a [`LANES`] multiple and
/// clamped to `[LANES, 1024]`; any timing weirdness (virtualized
/// clocks, tiny machines) degrades to [`DEFAULT_TILE_COLS`], never to
/// an error. Runs entirely on the calling thread, allocates only its
/// probe buffer, and influences performance only — tiled output is
/// bit-identical for every width.
pub fn probe_l2_tile_cols() -> usize {
    // Stride of one 64-byte cache line, in f32s: every access misses
    // once the working set outgrows a cache level, which is what makes
    // the knee visible.
    const STRIDE: usize = 16;
    // 64 KiB .. 8 MiB in doublings: below any L2, above most.
    let sizes_kib = [64usize, 128, 256, 512, 1024, 2048, 4096, 8192];
    let largest = sizes_kib[sizes_kib.len() - 1] * 1024 / 4;
    let buf = vec![1u32; largest];
    let mut per_elem_ns = [0f64; 8];
    for (i, kib) in sizes_kib.iter().enumerate() {
        let len = kib * 1024 / 4;
        // Enough passes to dominate timer granularity, few enough to
        // keep the whole probe in the low milliseconds.
        let passes = (4 * 1024 * 1024 / len).clamp(2, 64);
        let mut acc = 0u32;
        let t0 = std::time::Instant::now();
        for p in 0..passes {
            let mut j = p % STRIDE;
            while j < len {
                acc = acc.wrapping_add(buf[j]);
                j += STRIDE;
            }
        }
        let dt = t0.elapsed().as_nanos() as f64;
        std::hint::black_box(acc);
        per_elem_ns[i] = dt / (passes * len.div_ceil(STRIDE)) as f64;
    }
    // The knee: the largest size still within 1.5x of the fastest
    // per-access time. Sizes beyond the L2 pay main-memory latency and
    // fall well outside that band.
    let fastest = per_elem_ns.iter().cloned().fold(f64::INFINITY, f64::min);
    if !(fastest.is_finite() && fastest > 0.0) {
        return DEFAULT_TILE_COLS;
    }
    let mut l2_bytes = sizes_kib[0] * 1024;
    for (i, kib) in sizes_kib.iter().enumerate() {
        if per_elem_ns[i] <= fastest * 1.5 {
            l2_bytes = kib * 1024;
        }
    }
    let tc = ((l2_bytes as f64 / 4.0).sqrt() as usize) / LANES * LANES;
    tc.clamp(LANES, 1024)
}

/// Whether the opt-in fused-multiply-add serving mode is enabled:
/// `BSPMM_ALLOW_FMA=1` (or `true`), read once per process. FMA
/// single-rounds `d + val * s`, dropping the product rounding the
/// scalar oracle performs — faster and *more* accurate per element,
/// but no longer bit-identical to the scalar/vectorized kernels, which
/// is why it is never on by default (DESIGN.md §16). The error-bound
/// tests cover [`axpy_row_fma`] directly, so flipping this env var is
/// a deployment decision, not a correctness one.
pub fn fma_allowed() -> bool {
    static ALLOW: std::sync::OnceLock<bool> = std::sync::OnceLock::new();
    *ALLOW.get_or_init(|| {
        std::env::var("BSPMM_ALLOW_FMA")
            .map(|v| {
                let v = v.trim();
                v == "1" || v.eq_ignore_ascii_case("true")
            })
            .unwrap_or(false)
    })
}

/// `dst[l] += val * src[l]` over one fixed-width block. The fixed
/// `[f32; LANES]` shape is what lets the compiler emit one vector
/// multiply-add sequence with no bounds checks or trip-count logic.
#[inline(always)]
fn axpy_block(dst: &mut [f32; LANES], val: f32, src: &[f32; LANES]) {
    for l in 0..LANES {
        dst[l] += val * src[l];
    }
}

/// Vectorized `dst[j] += val * src[j]` over a full feature row:
/// [`LANES`]-wide blocks via `chunks_exact`, then a scalar tail for the
/// `n % LANES` trailing columns. Every output element sees exactly the
/// same multiply-then-add it sees in the scalar loop — only the
/// grouping of independent columns changes — so this is bit-identical
/// to the scalar reference for any `n`.
#[inline(always)]
pub fn axpy_row(dst: &mut [f32], val: f32, src: &[f32]) {
    let mut d = dst.chunks_exact_mut(LANES);
    let mut s = src.chunks_exact(LANES);
    for (db, sb) in d.by_ref().zip(s.by_ref()) {
        axpy_block(
            db.try_into().expect("LANES-wide chunk"),
            val,
            sb.try_into().expect("LANES-wide chunk"),
        );
    }
    for (dj, sj) in d.into_remainder().iter_mut().zip(s.remainder()) {
        *dj += val * *sj;
    }
}

/// Explicit-SIMD `dst[j] += val * src[j]` — the primitive behind every
/// `*_simd` kernel method ([`KernelVariant::Simd`], DESIGN.md §16).
/// With the `simd` cargo feature on x86_64 CPUs reporting AVX2, the row
/// runs through 256-bit intrinsics; everywhere else it falls back to
/// the autovectorized [`axpy_row`]. The default (non-FMA) path performs
/// the scalar two-rounding sequence per element — round after multiply,
/// round after add, same accumulation order — so it is bit-identical to
/// the scalar oracle on every input. When [`fma_allowed`] opts in, the
/// row runs through [`axpy_row_fma`] instead (single rounding, error-
/// bound tested, not bit-identical).
///
/// [`KernelVariant::Simd`]: super::KernelVariant::Simd
#[inline]
pub fn axpy_row_simd(dst: &mut [f32], val: f32, src: &[f32]) {
    if fma_allowed() {
        return axpy_row_fma(dst, val, src);
    }
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if std::arch::is_x86_feature_detected!("avx2") {
        // Safety: AVX2 availability just checked at runtime.
        unsafe { avx2::axpy_row(dst, val, src) };
        return;
    }
    axpy_row(dst, val, src);
}

/// Fused-multiply-add twin of [`axpy_row_simd`]: each element computes
/// `fma(val, src[j], dst[j])` with a single rounding (hardware
/// `_mm256_fmadd_ps` under the `simd` feature on FMA-capable x86_64,
/// [`f32::mul_add`] otherwise — both round once, so the two agree
/// bit-for-bit with each other). Relative to the two-rounding scalar
/// oracle the per-element deviation is bounded by one ulp of the
/// product `val * src[j]`; the error-bound tests pin that. Reached from
/// the kernels only through the `BSPMM_ALLOW_FMA` opt-in
/// ([`fma_allowed`]); callable directly so tests exercise it without
/// racing on process-wide env state.
pub fn axpy_row_fma(dst: &mut [f32], val: f32, src: &[f32]) {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if std::arch::is_x86_feature_detected!("avx2")
        && std::arch::is_x86_feature_detected!("fma")
    {
        // Safety: AVX2 + FMA availability just checked at runtime.
        unsafe { avx2::axpy_row_fma(dst, val, src) };
        return;
    }
    for (dj, sj) in dst.iter_mut().zip(src) {
        *dj = val.mul_add(*sj, *dj);
    }
}

/// The AVX2 intrinsic bodies behind [`axpy_row_simd`] /
/// [`axpy_row_fma`]. Compiled only under the `simd` cargo feature on
/// x86_64; every entry point is `unsafe` because the caller must have
/// verified the CPU features at runtime first.
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
mod avx2 {
    use std::arch::x86_64::{
        _mm256_add_ps, _mm256_fmadd_ps, _mm256_loadu_ps, _mm256_mul_ps, _mm256_set1_ps,
        _mm256_storeu_ps,
    };

    /// `dst[j] += val * src[j]` in 8-lane AVX2 blocks with a scalar
    /// tail. Each lane performs the scalar two-rounding sequence
    /// (`_mm256_mul_ps` then `_mm256_add_ps`), so output is
    /// bit-identical to the scalar loop.
    ///
    /// # Safety
    /// The caller must have verified `is_x86_feature_detected!("avx2")`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn axpy_row(dst: &mut [f32], val: f32, src: &[f32]) {
        let n = dst.len().min(src.len());
        let v = _mm256_set1_ps(val);
        let mut j = 0usize;
        while j + 8 <= n {
            let s = _mm256_loadu_ps(src.as_ptr().add(j));
            let d = _mm256_loadu_ps(dst.as_ptr().add(j));
            _mm256_storeu_ps(dst.as_mut_ptr().add(j), _mm256_add_ps(d, _mm256_mul_ps(v, s)));
            j += 8;
        }
        while j < n {
            *dst.get_unchecked_mut(j) += val * *src.get_unchecked(j);
            j += 1;
        }
    }

    /// Single-rounding `dst[j] = fma(val, src[j], dst[j])` in 8-lane
    /// blocks; the tail uses [`f32::mul_add`], which rounds identically
    /// to `_mm256_fmadd_ps`.
    ///
    /// # Safety
    /// The caller must have verified `is_x86_feature_detected!("avx2")`
    /// and `is_x86_feature_detected!("fma")`.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn axpy_row_fma(dst: &mut [f32], val: f32, src: &[f32]) {
        let n = dst.len().min(src.len());
        let v = _mm256_set1_ps(val);
        let mut j = 0usize;
        while j + 8 <= n {
            let s = _mm256_loadu_ps(src.as_ptr().add(j));
            let d = _mm256_loadu_ps(dst.as_ptr().add(j));
            _mm256_storeu_ps(dst.as_mut_ptr().add(j), _mm256_fmadd_ps(v, s, d));
            j += 8;
        }
        while j < n {
            let d = dst.get_unchecked_mut(j);
            *d = val.mul_add(*src.get_unchecked(j), *d);
            j += 1;
        }
    }
}

/// SparseTensor backend (paper Fig. 2): nnz-major loop over the padded
/// `ids`/`vals` arrays. Padding slots carry `val == 0` at `(0, 0)` and
/// are skipped.
pub struct StKernel<'a> {
    st: &'a PaddedStBatch,
}

impl<'a> StKernel<'a> {
    pub fn new(st: &'a PaddedStBatch) -> StKernel<'a> {
        StKernel { st }
    }
}

impl BatchedSpmm for StKernel<'_> {
    fn name(&self) -> &'static str {
        "engine-st"
    }

    fn batch(&self) -> usize {
        self.st.batch
    }

    fn out_rows(&self) -> usize {
        self.st.dim
    }

    fn inner_dim(&self) -> usize {
        self.st.dim
    }

    fn real_nnz(&self) -> usize {
        self.st.real_nnz()
    }

    fn spmm_sample(&self, b: usize, rhs: &[f32], n: usize, out: &mut [f32]) {
        let cap = self.st.nnz_cap;
        for i in 0..cap {
            let val = self.st.vals[b * cap + i];
            if val == 0.0 {
                continue; // padding slot
            }
            let rid = self.st.ids[(b * cap + i) * 2] as usize;
            let cid = self.st.ids[(b * cap + i) * 2 + 1] as usize;
            axpy_row(
                &mut out[rid * n..(rid + 1) * n],
                val,
                &rhs[cid * n..(cid + 1) * n],
            );
        }
    }

    fn spmm_sample_t(&self, b: usize, rhs: &[f32], n: usize, out: &mut [f32]) {
        // Same nnz-major loop with the (row, col) roles swapped:
        // A^T[c, r] = A[r, c].
        let cap = self.st.nnz_cap;
        for i in 0..cap {
            let val = self.st.vals[b * cap + i];
            if val == 0.0 {
                continue; // padding slot
            }
            let rid = self.st.ids[(b * cap + i) * 2] as usize;
            let cid = self.st.ids[(b * cap + i) * 2 + 1] as usize;
            axpy_row(
                &mut out[cid * n..(cid + 1) * n],
                val,
                &rhs[rid * n..(rid + 1) * n],
            );
        }
    }

    fn sample_nnz(&self, b: usize) -> usize {
        // O(1): counted once at pack time (DESIGN.md §10) — this runs
        // on every cost-model scan of every work-stealing dispatch.
        self.st.nnz_per_sample[b] as usize
    }

    fn spmm_sample_rows(&self, b: usize, row0: usize, rhs: &[f32], n: usize, out: &mut [f32]) {
        // nnz-major scan filtered to output rows [row0, row1): each
        // element still receives its contributions in slot order.
        let row1 = row0 + out.len() / n;
        let cap = self.st.nnz_cap;
        for i in 0..cap {
            let val = self.st.vals[b * cap + i];
            if val == 0.0 {
                continue; // padding slot
            }
            let rid = self.st.ids[(b * cap + i) * 2] as usize;
            if rid < row0 || rid >= row1 {
                continue;
            }
            let cid = self.st.ids[(b * cap + i) * 2 + 1] as usize;
            axpy_row(
                &mut out[(rid - row0) * n..(rid - row0 + 1) * n],
                val,
                &rhs[cid * n..(cid + 1) * n],
            );
        }
    }

    fn spmm_sample_t_rows(&self, b: usize, row0: usize, rhs: &[f32], n: usize, out: &mut [f32]) {
        let row1 = row0 + out.len() / n;
        let cap = self.st.nnz_cap;
        for i in 0..cap {
            let val = self.st.vals[b * cap + i];
            if val == 0.0 {
                continue; // padding slot
            }
            let cid = self.st.ids[(b * cap + i) * 2 + 1] as usize;
            if cid < row0 || cid >= row1 {
                continue;
            }
            let rid = self.st.ids[(b * cap + i) * 2] as usize;
            axpy_row(
                &mut out[(cid - row0) * n..(cid - row0 + 1) * n],
                val,
                &rhs[rid * n..(rid + 1) * n],
            );
        }
    }

    fn spmm_sample_scalar(&self, b: usize, rhs: &[f32], n: usize, out: &mut [f32]) {
        let cap = self.st.nnz_cap;
        for i in 0..cap {
            let val = self.st.vals[b * cap + i];
            if val == 0.0 {
                continue; // padding slot
            }
            let rid = self.st.ids[(b * cap + i) * 2] as usize;
            let cid = self.st.ids[(b * cap + i) * 2 + 1] as usize;
            let src = &rhs[cid * n..(cid + 1) * n];
            let dst = &mut out[rid * n..(rid + 1) * n];
            for j in 0..n {
                dst[j] += val * src[j];
            }
        }
    }

    fn spmm_sample_t_scalar(&self, b: usize, rhs: &[f32], n: usize, out: &mut [f32]) {
        let cap = self.st.nnz_cap;
        for i in 0..cap {
            let val = self.st.vals[b * cap + i];
            if val == 0.0 {
                continue; // padding slot
            }
            let rid = self.st.ids[(b * cap + i) * 2] as usize;
            let cid = self.st.ids[(b * cap + i) * 2 + 1] as usize;
            let src = &rhs[rid * n..(rid + 1) * n];
            let dst = &mut out[cid * n..(cid + 1) * n];
            for j in 0..n {
                dst[j] += val * src[j];
            }
        }
    }

    fn spmm_sample_rows_scalar(
        &self,
        b: usize,
        row0: usize,
        rhs: &[f32],
        n: usize,
        out: &mut [f32],
    ) {
        let row1 = row0 + out.len() / n;
        let cap = self.st.nnz_cap;
        for i in 0..cap {
            let val = self.st.vals[b * cap + i];
            if val == 0.0 {
                continue; // padding slot
            }
            let rid = self.st.ids[(b * cap + i) * 2] as usize;
            if rid < row0 || rid >= row1 {
                continue;
            }
            let cid = self.st.ids[(b * cap + i) * 2 + 1] as usize;
            let src = &rhs[cid * n..(cid + 1) * n];
            let dst = &mut out[(rid - row0) * n..(rid - row0 + 1) * n];
            for j in 0..n {
                dst[j] += val * src[j];
            }
        }
    }

    fn spmm_sample_t_rows_scalar(
        &self,
        b: usize,
        row0: usize,
        rhs: &[f32],
        n: usize,
        out: &mut [f32],
    ) {
        let row1 = row0 + out.len() / n;
        let cap = self.st.nnz_cap;
        for i in 0..cap {
            let val = self.st.vals[b * cap + i];
            if val == 0.0 {
                continue; // padding slot
            }
            let cid = self.st.ids[(b * cap + i) * 2 + 1] as usize;
            if cid < row0 || cid >= row1 {
                continue;
            }
            let rid = self.st.ids[(b * cap + i) * 2] as usize;
            let src = &rhs[rid * n..(rid + 1) * n];
            let dst = &mut out[(cid - row0) * n..(cid - row0 + 1) * n];
            for j in 0..n {
                dst[j] += val * src[j];
            }
        }
    }

    fn spmm_sample_simd(&self, b: usize, rhs: &[f32], n: usize, out: &mut [f32]) {
        let cap = self.st.nnz_cap;
        for i in 0..cap {
            let val = self.st.vals[b * cap + i];
            if val == 0.0 {
                continue; // padding slot
            }
            let rid = self.st.ids[(b * cap + i) * 2] as usize;
            let cid = self.st.ids[(b * cap + i) * 2 + 1] as usize;
            axpy_row_simd(
                &mut out[rid * n..(rid + 1) * n],
                val,
                &rhs[cid * n..(cid + 1) * n],
            );
        }
    }

    fn spmm_sample_t_simd(&self, b: usize, rhs: &[f32], n: usize, out: &mut [f32]) {
        let cap = self.st.nnz_cap;
        for i in 0..cap {
            let val = self.st.vals[b * cap + i];
            if val == 0.0 {
                continue; // padding slot
            }
            let rid = self.st.ids[(b * cap + i) * 2] as usize;
            let cid = self.st.ids[(b * cap + i) * 2 + 1] as usize;
            axpy_row_simd(
                &mut out[cid * n..(cid + 1) * n],
                val,
                &rhs[rid * n..(rid + 1) * n],
            );
        }
    }

    fn spmm_sample_rows_simd(&self, b: usize, row0: usize, rhs: &[f32], n: usize, out: &mut [f32]) {
        let row1 = row0 + out.len() / n;
        let cap = self.st.nnz_cap;
        for i in 0..cap {
            let val = self.st.vals[b * cap + i];
            if val == 0.0 {
                continue; // padding slot
            }
            let rid = self.st.ids[(b * cap + i) * 2] as usize;
            if rid < row0 || rid >= row1 {
                continue;
            }
            let cid = self.st.ids[(b * cap + i) * 2 + 1] as usize;
            axpy_row_simd(
                &mut out[(rid - row0) * n..(rid - row0 + 1) * n],
                val,
                &rhs[cid * n..(cid + 1) * n],
            );
        }
    }

    fn spmm_sample_t_rows_simd(
        &self,
        b: usize,
        row0: usize,
        rhs: &[f32],
        n: usize,
        out: &mut [f32],
    ) {
        let row1 = row0 + out.len() / n;
        let cap = self.st.nnz_cap;
        for i in 0..cap {
            let val = self.st.vals[b * cap + i];
            if val == 0.0 {
                continue; // padding slot
            }
            let cid = self.st.ids[(b * cap + i) * 2 + 1] as usize;
            if cid < row0 || cid >= row1 {
                continue;
            }
            let rid = self.st.ids[(b * cap + i) * 2] as usize;
            axpy_row_simd(
                &mut out[(cid - row0) * n..(cid - row0 + 1) * n],
                val,
                &rhs[rid * n..(rid + 1) * n],
            );
        }
    }
}

/// CSR backend (paper Fig. 4): row-major, race-free by construction.
/// Padded rows repeat the final row pointer, so their inner loop is
/// empty.
///
/// The only backend with real cache-tiled overrides
/// ([`BatchedSpmm::spmm_sample_tiled`] and its row-blocked + transpose
/// twins, DESIGN.md §12): its row-major non-zero order makes tiling the
/// dense operand's columns a pure regrouping — in the forward gather
/// *and* the transpose scatter — and its row pointers answer the
/// planner's [`BatchedSpmm::rows_nnz`] range queries in O(1) — the two
/// hooks the large-graph tier rides on.
pub struct CsrKernel<'a> {
    csr: &'a PaddedCsrBatch,
    /// Column-tile width of the tiled path; `0` = resolve from
    /// `BSPMM_TILE_COLS` / the L2 heuristic at dispatch time.
    tile_cols: usize,
    /// Batch-total real nnz, summed once at construction so `real_nnz`
    /// (the cost model's FLOP numerator) stays O(1) per call even on
    /// raw views over million-row graphs (DESIGN.md §10).
    total_nnz: usize,
}

impl<'a> CsrKernel<'a> {
    pub fn new(csr: &'a PaddedCsrBatch) -> CsrKernel<'a> {
        let m1 = csr.dim + 1;
        let total_nnz = (0..csr.batch)
            .map(|b| csr.rpt[b * m1 + csr.dim] as usize)
            .sum();
        CsrKernel {
            csr,
            tile_cols: 0,
            total_nnz,
        }
    }

    /// Pin an explicit column-tile width for the tiled path (any value
    /// ≥ 1; the parity tests sweep degenerate widths like 1 and 7).
    /// Without this, the width comes from [`tile_cols_from_env`].
    pub fn with_tile_cols(mut self, tile_cols: usize) -> CsrKernel<'a> {
        self.tile_cols = tile_cols.max(1);
        self
    }

    #[inline]
    fn resolve_tile_cols(&self) -> usize {
        if self.tile_cols > 0 {
            self.tile_cols
        } else {
            tile_cols_from_env()
        }
    }
}

impl BatchedSpmm for CsrKernel<'_> {
    fn name(&self) -> &'static str {
        "engine-csr"
    }

    fn batch(&self) -> usize {
        self.csr.batch
    }

    fn out_rows(&self) -> usize {
        self.csr.dim
    }

    fn inner_dim(&self) -> usize {
        self.csr.dim
    }

    fn real_nnz(&self) -> usize {
        // O(1): summed once at construction (DESIGN.md §10).
        self.total_nnz
    }

    fn spmm_sample(&self, b: usize, rhs: &[f32], n: usize, out: &mut [f32]) {
        let m1 = self.csr.dim + 1;
        let rpt = &self.csr.rpt[b * m1..(b + 1) * m1];
        let base = b * self.csr.nnz_cap;
        for r in 0..self.csr.dim {
            let dst = &mut out[r * n..(r + 1) * n];
            for i in rpt[r] as usize..rpt[r + 1] as usize {
                let val = self.csr.vals[base + i];
                let cid = self.csr.col_ids[base + i] as usize;
                axpy_row(dst, val, &rhs[cid * n..(cid + 1) * n]);
            }
        }
    }

    fn spmm_sample_t(&self, b: usize, rhs: &[f32], n: usize, out: &mut [f32]) {
        // Row-major traversal turns into a scatter over output rows —
        // still race-free, since each (sample, row-block) task is
        // claimed by exactly one worker.
        let m1 = self.csr.dim + 1;
        let rpt = &self.csr.rpt[b * m1..(b + 1) * m1];
        let base = b * self.csr.nnz_cap;
        for r in 0..self.csr.dim {
            let src = &rhs[r * n..(r + 1) * n];
            for i in rpt[r] as usize..rpt[r + 1] as usize {
                let val = self.csr.vals[base + i];
                let cid = self.csr.col_ids[base + i] as usize;
                axpy_row(&mut out[cid * n..(cid + 1) * n], val, src);
            }
        }
    }

    fn sample_nnz(&self, b: usize) -> usize {
        let m1 = self.csr.dim + 1;
        self.csr.rpt[b * m1 + self.csr.dim] as usize
    }

    fn spmm_sample_rows(&self, b: usize, row0: usize, rhs: &[f32], n: usize, out: &mut [f32]) {
        // Row pointers let the block jump straight to its rows.
        let row1 = row0 + out.len() / n;
        let m1 = self.csr.dim + 1;
        let rpt = &self.csr.rpt[b * m1..(b + 1) * m1];
        let base = b * self.csr.nnz_cap;
        for r in row0..row1 {
            let dst = &mut out[(r - row0) * n..(r - row0 + 1) * n];
            for i in rpt[r] as usize..rpt[r + 1] as usize {
                let val = self.csr.vals[base + i];
                let cid = self.csr.col_ids[base + i] as usize;
                axpy_row(dst, val, &rhs[cid * n..(cid + 1) * n]);
            }
        }
    }

    fn spmm_sample_t_rows(&self, b: usize, row0: usize, rhs: &[f32], n: usize, out: &mut [f32]) {
        // Scatter form: scan every row in serial order, keep only
        // contributions landing in [row0, row1).
        let row1 = row0 + out.len() / n;
        let m1 = self.csr.dim + 1;
        let rpt = &self.csr.rpt[b * m1..(b + 1) * m1];
        let base = b * self.csr.nnz_cap;
        for r in 0..self.csr.dim {
            let src = &rhs[r * n..(r + 1) * n];
            for i in rpt[r] as usize..rpt[r + 1] as usize {
                let cid = self.csr.col_ids[base + i] as usize;
                if cid < row0 || cid >= row1 {
                    continue;
                }
                let val = self.csr.vals[base + i];
                axpy_row(&mut out[(cid - row0) * n..(cid - row0 + 1) * n], val, src);
            }
        }
    }

    fn spmm_sample_tiled(&self, b: usize, rhs: &[f32], n: usize, out: &mut [f32]) {
        // GE-SpMM's row reuse as column tiles (DESIGN.md §12): the
        // outer loop fixes a column range [j0, j1) of the dense
        // operand, and the whole row/nnz traversal runs inside it, so
        // the `rhs` rows gathered for a tile are touched again by every
        // non-zero sharing a column — before they can be evicted. Each
        // output element (r, j) lives in exactly one tile and receives
        // its contributions in row-pointer order, identical to the
        // untiled loop, so the regrouping is bit-exact for any width.
        let tc = self.resolve_tile_cols();
        if tc >= n {
            return self.spmm_sample(b, rhs, n, out);
        }
        let m1 = self.csr.dim + 1;
        let rpt = &self.csr.rpt[b * m1..(b + 1) * m1];
        let base = b * self.csr.nnz_cap;
        let mut j0 = 0usize;
        while j0 < n {
            let j1 = (j0 + tc).min(n);
            for r in 0..self.csr.dim {
                let dst = &mut out[r * n + j0..r * n + j1];
                for i in rpt[r] as usize..rpt[r + 1] as usize {
                    let val = self.csr.vals[base + i];
                    let cid = self.csr.col_ids[base + i] as usize;
                    axpy_row(dst, val, &rhs[cid * n + j0..cid * n + j1]);
                }
            }
            j0 = j1;
        }
    }

    fn spmm_sample_rows_tiled(
        &self,
        b: usize,
        row0: usize,
        rhs: &[f32],
        n: usize,
        out: &mut [f32],
    ) {
        // The row-blocked form the pool's degree-bucketed tasks run:
        // same column tiling, restricted to output rows [row0, row1).
        let tc = self.resolve_tile_cols();
        if tc >= n {
            return self.spmm_sample_rows(b, row0, rhs, n, out);
        }
        let row1 = row0 + out.len() / n;
        let m1 = self.csr.dim + 1;
        let rpt = &self.csr.rpt[b * m1..(b + 1) * m1];
        let base = b * self.csr.nnz_cap;
        let mut j0 = 0usize;
        while j0 < n {
            let j1 = (j0 + tc).min(n);
            for r in row0..row1 {
                let dst = &mut out[(r - row0) * n + j0..(r - row0) * n + j1];
                for i in rpt[r] as usize..rpt[r + 1] as usize {
                    let val = self.csr.vals[base + i];
                    let cid = self.csr.col_ids[base + i] as usize;
                    axpy_row(dst, val, &rhs[cid * n + j0..cid * n + j1]);
                }
            }
            j0 = j1;
        }
    }

    fn spmm_sample_t_tiled(&self, b: usize, rhs: &[f32], n: usize, out: &mut [f32]) {
        // The transpose (scatter) form under the same column tiling:
        // for a fixed tile [j0, j1) each non-zero (r, cid) scatters
        // rhs[r, tile] into out[cid, tile], so the dense rows a hub
        // column keeps landing in stay L2-resident across the tile —
        // large-graph backward gets the same reuse as forward
        // (DESIGN.md §12). Each output element (cid, j) lives in
        // exactly one tile and receives its contributions in the same
        // (row, nnz) order as the untiled scatter, so the regrouping is
        // bit-exact for any width.
        let tc = self.resolve_tile_cols();
        if tc >= n {
            return self.spmm_sample_t(b, rhs, n, out);
        }
        let m1 = self.csr.dim + 1;
        let rpt = &self.csr.rpt[b * m1..(b + 1) * m1];
        let base = b * self.csr.nnz_cap;
        let mut j0 = 0usize;
        while j0 < n {
            let j1 = (j0 + tc).min(n);
            for r in 0..self.csr.dim {
                let src = &rhs[r * n + j0..r * n + j1];
                for i in rpt[r] as usize..rpt[r + 1] as usize {
                    let val = self.csr.vals[base + i];
                    let cid = self.csr.col_ids[base + i] as usize;
                    axpy_row(&mut out[cid * n + j0..cid * n + j1], val, src);
                }
            }
            j0 = j1;
        }
    }

    fn spmm_sample_t_rows_tiled(
        &self,
        b: usize,
        row0: usize,
        rhs: &[f32],
        n: usize,
        out: &mut [f32],
    ) {
        // Row-blocked transpose scatter under column tiling: scan every
        // source row in serial order, keep only contributions landing
        // in transpose-output rows [row0, row1) — the filter the
        // untiled t_rows form uses, now inside each column tile.
        let tc = self.resolve_tile_cols();
        if tc >= n {
            return self.spmm_sample_t_rows(b, row0, rhs, n, out);
        }
        let row1 = row0 + out.len() / n;
        let m1 = self.csr.dim + 1;
        let rpt = &self.csr.rpt[b * m1..(b + 1) * m1];
        let base = b * self.csr.nnz_cap;
        let mut j0 = 0usize;
        while j0 < n {
            let j1 = (j0 + tc).min(n);
            for r in 0..self.csr.dim {
                let src = &rhs[r * n + j0..r * n + j1];
                for i in rpt[r] as usize..rpt[r + 1] as usize {
                    let cid = self.csr.col_ids[base + i] as usize;
                    if cid < row0 || cid >= row1 {
                        continue;
                    }
                    let val = self.csr.vals[base + i];
                    axpy_row(&mut out[(cid - row0) * n + j0..(cid - row0) * n + j1], val, src);
                }
            }
            j0 = j1;
        }
    }

    fn rows_nnz(&self, b: usize, r0: usize, r1: usize) -> Option<usize> {
        // Row pointers make any row range an O(1) difference — the
        // oracle the planner's degree-bucketed nnz-balanced row split
        // binary-searches against (DESIGN.md §12).
        let m1 = self.csr.dim + 1;
        Some((self.csr.rpt[b * m1 + r1] - self.csr.rpt[b * m1 + r0]) as usize)
    }

    fn spmm_sample_scalar(&self, b: usize, rhs: &[f32], n: usize, out: &mut [f32]) {
        let m1 = self.csr.dim + 1;
        let rpt = &self.csr.rpt[b * m1..(b + 1) * m1];
        let base = b * self.csr.nnz_cap;
        for r in 0..self.csr.dim {
            let dst = &mut out[r * n..(r + 1) * n];
            for i in rpt[r] as usize..rpt[r + 1] as usize {
                let val = self.csr.vals[base + i];
                let cid = self.csr.col_ids[base + i] as usize;
                let src = &rhs[cid * n..(cid + 1) * n];
                for j in 0..n {
                    dst[j] += val * src[j];
                }
            }
        }
    }

    fn spmm_sample_t_scalar(&self, b: usize, rhs: &[f32], n: usize, out: &mut [f32]) {
        let m1 = self.csr.dim + 1;
        let rpt = &self.csr.rpt[b * m1..(b + 1) * m1];
        let base = b * self.csr.nnz_cap;
        for r in 0..self.csr.dim {
            let src = &rhs[r * n..(r + 1) * n];
            for i in rpt[r] as usize..rpt[r + 1] as usize {
                let val = self.csr.vals[base + i];
                let cid = self.csr.col_ids[base + i] as usize;
                let dst = &mut out[cid * n..(cid + 1) * n];
                for j in 0..n {
                    dst[j] += val * src[j];
                }
            }
        }
    }

    fn spmm_sample_rows_scalar(
        &self,
        b: usize,
        row0: usize,
        rhs: &[f32],
        n: usize,
        out: &mut [f32],
    ) {
        let row1 = row0 + out.len() / n;
        let m1 = self.csr.dim + 1;
        let rpt = &self.csr.rpt[b * m1..(b + 1) * m1];
        let base = b * self.csr.nnz_cap;
        for r in row0..row1 {
            let dst = &mut out[(r - row0) * n..(r - row0 + 1) * n];
            for i in rpt[r] as usize..rpt[r + 1] as usize {
                let val = self.csr.vals[base + i];
                let cid = self.csr.col_ids[base + i] as usize;
                let src = &rhs[cid * n..(cid + 1) * n];
                for j in 0..n {
                    dst[j] += val * src[j];
                }
            }
        }
    }

    fn spmm_sample_t_rows_scalar(
        &self,
        b: usize,
        row0: usize,
        rhs: &[f32],
        n: usize,
        out: &mut [f32],
    ) {
        let row1 = row0 + out.len() / n;
        let m1 = self.csr.dim + 1;
        let rpt = &self.csr.rpt[b * m1..(b + 1) * m1];
        let base = b * self.csr.nnz_cap;
        for r in 0..self.csr.dim {
            let src = &rhs[r * n..(r + 1) * n];
            for i in rpt[r] as usize..rpt[r + 1] as usize {
                let cid = self.csr.col_ids[base + i] as usize;
                if cid < row0 || cid >= row1 {
                    continue;
                }
                let val = self.csr.vals[base + i];
                let dst = &mut out[(cid - row0) * n..(cid - row0 + 1) * n];
                for j in 0..n {
                    dst[j] += val * src[j];
                }
            }
        }
    }

    fn spmm_sample_simd(&self, b: usize, rhs: &[f32], n: usize, out: &mut [f32]) {
        let m1 = self.csr.dim + 1;
        let rpt = &self.csr.rpt[b * m1..(b + 1) * m1];
        let base = b * self.csr.nnz_cap;
        for r in 0..self.csr.dim {
            let dst = &mut out[r * n..(r + 1) * n];
            for i in rpt[r] as usize..rpt[r + 1] as usize {
                let val = self.csr.vals[base + i];
                let cid = self.csr.col_ids[base + i] as usize;
                axpy_row_simd(dst, val, &rhs[cid * n..(cid + 1) * n]);
            }
        }
    }

    fn spmm_sample_t_simd(&self, b: usize, rhs: &[f32], n: usize, out: &mut [f32]) {
        let m1 = self.csr.dim + 1;
        let rpt = &self.csr.rpt[b * m1..(b + 1) * m1];
        let base = b * self.csr.nnz_cap;
        for r in 0..self.csr.dim {
            let src = &rhs[r * n..(r + 1) * n];
            for i in rpt[r] as usize..rpt[r + 1] as usize {
                let val = self.csr.vals[base + i];
                let cid = self.csr.col_ids[base + i] as usize;
                axpy_row_simd(&mut out[cid * n..(cid + 1) * n], val, src);
            }
        }
    }

    fn spmm_sample_rows_simd(&self, b: usize, row0: usize, rhs: &[f32], n: usize, out: &mut [f32]) {
        let row1 = row0 + out.len() / n;
        let m1 = self.csr.dim + 1;
        let rpt = &self.csr.rpt[b * m1..(b + 1) * m1];
        let base = b * self.csr.nnz_cap;
        for r in row0..row1 {
            let dst = &mut out[(r - row0) * n..(r - row0 + 1) * n];
            for i in rpt[r] as usize..rpt[r + 1] as usize {
                let val = self.csr.vals[base + i];
                let cid = self.csr.col_ids[base + i] as usize;
                axpy_row_simd(dst, val, &rhs[cid * n..(cid + 1) * n]);
            }
        }
    }

    fn spmm_sample_t_rows_simd(
        &self,
        b: usize,
        row0: usize,
        rhs: &[f32],
        n: usize,
        out: &mut [f32],
    ) {
        let row1 = row0 + out.len() / n;
        let m1 = self.csr.dim + 1;
        let rpt = &self.csr.rpt[b * m1..(b + 1) * m1];
        let base = b * self.csr.nnz_cap;
        for r in 0..self.csr.dim {
            let src = &rhs[r * n..(r + 1) * n];
            for i in rpt[r] as usize..rpt[r + 1] as usize {
                let cid = self.csr.col_ids[base + i] as usize;
                if cid < row0 || cid >= row1 {
                    continue;
                }
                let val = self.csr.vals[base + i];
                axpy_row_simd(&mut out[(cid - row0) * n..(cid - row0 + 1) * n], val, src);
            }
        }
    }
}

/// ELL backend: per-row padded slots (`val == 0` = padding), the layout
/// `ModelBatch` packs adjacency channels in. A kernel is a strided view,
/// so one channel of a `[B, CH, M, R]` model batch — or a standalone
/// `PaddedEllBatch` — can be dispatched without copying.
pub struct EllKernel<'a> {
    cols: &'a [i32],
    vals: &'a [f32],
    batch: usize,
    rows: usize,
    width: usize,
    /// Flat offset of sample 0's `[rows, width]` plane.
    offset: usize,
    /// Stride between consecutive samples' planes.
    stride: usize,
    /// Per-sample real-nnz counts cached at pack time, when the view's
    /// backing batch carries them: sample `b`'s count sits at
    /// `nnz[nnz_offset + b * nnz_stride]`. `None` (raw-array views)
    /// falls back to the counts in `owned_nnz`.
    nnz: Option<&'a [u32]>,
    nnz_offset: usize,
    nnz_stride: usize,
    /// Construction-time per-sample counts for raw-array views, which
    /// have no pack-time cache to borrow: [`EllKernel::new`] scans the
    /// value planes exactly once, so `sample_nnz` stays O(1) on every
    /// later cost-model query instead of rescanning `rows * width`
    /// slots per dispatch (DESIGN.md §10). Empty when `nnz` borrows a
    /// pack-time cache.
    owned_nnz: Vec<u32>,
}

impl<'a> EllKernel<'a> {
    /// The raw contiguous view with no nnz source attached — the shared
    /// scaffolding [`EllKernel::new`] / [`EllKernel::from_padded`]
    /// finish off with their respective count caches.
    fn view(
        cols: &'a [i32],
        vals: &'a [f32],
        batch: usize,
        rows: usize,
        width: usize,
    ) -> EllKernel<'a> {
        assert_eq!(cols.len(), batch * rows * width, "ell cols length");
        assert_eq!(vals.len(), batch * rows * width, "ell vals length");
        EllKernel {
            cols,
            vals,
            batch,
            rows,
            width,
            offset: 0,
            stride: rows * width,
            nnz: None,
            nnz_offset: 0,
            nnz_stride: 1,
            owned_nnz: Vec::new(),
        }
    }

    /// Contiguous `[batch, rows, width]` view over raw ELL arrays. Raw
    /// arrays carry no pack-time nnz cache, so construction counts each
    /// sample's real non-zeros once — one O(batch · rows · width) scan
    /// here instead of one per cost-model query on every dispatch.
    pub fn new(
        cols: &'a [i32],
        vals: &'a [f32],
        batch: usize,
        rows: usize,
        width: usize,
    ) -> EllKernel<'a> {
        let mut k = EllKernel::view(cols, vals, batch, rows, width);
        let per = rows * width;
        k.owned_nnz = (0..batch)
            .map(|b| {
                vals[b * per..(b + 1) * per]
                    .iter()
                    .filter(|v| **v != 0.0)
                    .count() as u32
            })
            .collect();
        k
    }

    pub fn from_padded(ell: &'a PaddedEllBatch) -> EllKernel<'a> {
        EllKernel {
            nnz: Some(&ell.nnz_per_sample),
            ..EllKernel::view(&ell.cols, &ell.vals, ell.batch, ell.dim, ell.width)
        }
    }

    /// View of one adjacency channel of a packed model batch
    /// (`ell_cols`/`ell_vals` are `[B, CH, M, R]`; the channel plane of
    /// sample `b` sits at offset `(b * CH + ch) * M * R`).
    pub fn channel(mb: &'a ModelBatch, ch: usize) -> EllKernel<'a> {
        assert!(ch < mb.channels, "channel {ch} out of {}", mb.channels);
        let plane = mb.max_nodes * mb.ell_width;
        EllKernel {
            cols: &mb.ell_cols,
            vals: &mb.ell_vals,
            batch: mb.batch,
            rows: mb.max_nodes,
            width: mb.ell_width,
            offset: ch * plane,
            stride: mb.channels * plane,
            nnz: Some(&mb.ell_nnz),
            nnz_offset: ch,
            nnz_stride: mb.channels,
            owned_nnz: Vec::new(),
        }
    }
}

impl BatchedSpmm for EllKernel<'_> {
    fn name(&self) -> &'static str {
        "engine-ell"
    }

    fn batch(&self) -> usize {
        self.batch
    }

    fn out_rows(&self) -> usize {
        self.rows
    }

    fn inner_dim(&self) -> usize {
        self.rows
    }

    fn real_nnz(&self) -> usize {
        match self.nnz {
            Some(counts) => (0..self.batch)
                .map(|b| counts[self.nnz_offset + b * self.nnz_stride] as usize)
                .sum(),
            // Raw views: counted once at construction (DESIGN.md §10).
            None => self.owned_nnz.iter().map(|&c| c as usize).sum(),
        }
    }

    fn spmm_sample(&self, b: usize, rhs: &[f32], n: usize, out: &mut [f32]) {
        let base = self.offset + b * self.stride;
        let r = self.width;
        for rid in 0..self.rows {
            let dst = &mut out[rid * n..(rid + 1) * n];
            for slot in 0..r {
                let val = self.vals[base + rid * r + slot];
                if val == 0.0 {
                    continue; // padding slot
                }
                let cid = self.cols[base + rid * r + slot] as usize;
                axpy_row(dst, val, &rhs[cid * n..(cid + 1) * n]);
            }
        }
    }

    fn spmm_sample_t(&self, b: usize, rhs: &[f32], n: usize, out: &mut [f32]) {
        // Gather-from-row, scatter-to-column: the form the backward
        // adjacency dispatch `dU = A^T @ dY` uses (DESIGN.md §8).
        let base = self.offset + b * self.stride;
        let r = self.width;
        for rid in 0..self.rows {
            let src = &rhs[rid * n..(rid + 1) * n];
            for slot in 0..r {
                let val = self.vals[base + rid * r + slot];
                if val == 0.0 {
                    continue; // padding slot
                }
                let cid = self.cols[base + rid * r + slot] as usize;
                axpy_row(&mut out[cid * n..(cid + 1) * n], val, src);
            }
        }
    }

    fn sample_nnz(&self, b: usize) -> usize {
        match self.nnz {
            // O(1) either way: counted at pack time, or once at view
            // construction for raw arrays (DESIGN.md §10).
            Some(counts) => counts[self.nnz_offset + b * self.nnz_stride] as usize,
            None => self.owned_nnz[b] as usize,
        }
    }

    fn spmm_sample_rows(&self, b: usize, row0: usize, rhs: &[f32], n: usize, out: &mut [f32]) {
        // ELL rows are directly indexed: run the per-row loop on the
        // block's rows only.
        let row1 = row0 + out.len() / n;
        let base = self.offset + b * self.stride;
        let r = self.width;
        for rid in row0..row1 {
            let dst = &mut out[(rid - row0) * n..(rid - row0 + 1) * n];
            for slot in 0..r {
                let val = self.vals[base + rid * r + slot];
                if val == 0.0 {
                    continue; // padding slot
                }
                let cid = self.cols[base + rid * r + slot] as usize;
                axpy_row(dst, val, &rhs[cid * n..(cid + 1) * n]);
            }
        }
    }

    fn spmm_sample_t_rows(&self, b: usize, row0: usize, rhs: &[f32], n: usize, out: &mut [f32]) {
        // Scatter form: full (rid, slot) scan in serial order, filtered
        // to the block's output rows.
        let row1 = row0 + out.len() / n;
        let base = self.offset + b * self.stride;
        let r = self.width;
        for rid in 0..self.rows {
            let src = &rhs[rid * n..(rid + 1) * n];
            for slot in 0..r {
                let val = self.vals[base + rid * r + slot];
                if val == 0.0 {
                    continue; // padding slot
                }
                let cid = self.cols[base + rid * r + slot] as usize;
                if cid < row0 || cid >= row1 {
                    continue;
                }
                axpy_row(&mut out[(cid - row0) * n..(cid - row0 + 1) * n], val, src);
            }
        }
    }

    fn spmm_sample_scalar(&self, b: usize, rhs: &[f32], n: usize, out: &mut [f32]) {
        let base = self.offset + b * self.stride;
        let r = self.width;
        for rid in 0..self.rows {
            let dst = &mut out[rid * n..(rid + 1) * n];
            for slot in 0..r {
                let val = self.vals[base + rid * r + slot];
                if val == 0.0 {
                    continue; // padding slot
                }
                let cid = self.cols[base + rid * r + slot] as usize;
                let src = &rhs[cid * n..(cid + 1) * n];
                for j in 0..n {
                    dst[j] += val * src[j];
                }
            }
        }
    }

    fn spmm_sample_t_scalar(&self, b: usize, rhs: &[f32], n: usize, out: &mut [f32]) {
        let base = self.offset + b * self.stride;
        let r = self.width;
        for rid in 0..self.rows {
            let src = &rhs[rid * n..(rid + 1) * n];
            for slot in 0..r {
                let val = self.vals[base + rid * r + slot];
                if val == 0.0 {
                    continue; // padding slot
                }
                let cid = self.cols[base + rid * r + slot] as usize;
                let dst = &mut out[cid * n..(cid + 1) * n];
                for j in 0..n {
                    dst[j] += val * src[j];
                }
            }
        }
    }

    fn spmm_sample_rows_scalar(
        &self,
        b: usize,
        row0: usize,
        rhs: &[f32],
        n: usize,
        out: &mut [f32],
    ) {
        let row1 = row0 + out.len() / n;
        let base = self.offset + b * self.stride;
        let r = self.width;
        for rid in row0..row1 {
            let dst = &mut out[(rid - row0) * n..(rid - row0 + 1) * n];
            for slot in 0..r {
                let val = self.vals[base + rid * r + slot];
                if val == 0.0 {
                    continue; // padding slot
                }
                let cid = self.cols[base + rid * r + slot] as usize;
                let src = &rhs[cid * n..(cid + 1) * n];
                for j in 0..n {
                    dst[j] += val * src[j];
                }
            }
        }
    }

    fn spmm_sample_t_rows_scalar(
        &self,
        b: usize,
        row0: usize,
        rhs: &[f32],
        n: usize,
        out: &mut [f32],
    ) {
        let row1 = row0 + out.len() / n;
        let base = self.offset + b * self.stride;
        let r = self.width;
        for rid in 0..self.rows {
            let src = &rhs[rid * n..(rid + 1) * n];
            for slot in 0..r {
                let val = self.vals[base + rid * r + slot];
                if val == 0.0 {
                    continue; // padding slot
                }
                let cid = self.cols[base + rid * r + slot] as usize;
                if cid < row0 || cid >= row1 {
                    continue;
                }
                let dst = &mut out[(cid - row0) * n..(cid - row0 + 1) * n];
                for j in 0..n {
                    dst[j] += val * src[j];
                }
            }
        }
    }

    fn spmm_sample_simd(&self, b: usize, rhs: &[f32], n: usize, out: &mut [f32]) {
        let base = self.offset + b * self.stride;
        let r = self.width;
        for rid in 0..self.rows {
            let dst = &mut out[rid * n..(rid + 1) * n];
            for slot in 0..r {
                let val = self.vals[base + rid * r + slot];
                if val == 0.0 {
                    continue; // padding slot
                }
                let cid = self.cols[base + rid * r + slot] as usize;
                axpy_row_simd(dst, val, &rhs[cid * n..(cid + 1) * n]);
            }
        }
    }

    fn spmm_sample_t_simd(&self, b: usize, rhs: &[f32], n: usize, out: &mut [f32]) {
        let base = self.offset + b * self.stride;
        let r = self.width;
        for rid in 0..self.rows {
            let src = &rhs[rid * n..(rid + 1) * n];
            for slot in 0..r {
                let val = self.vals[base + rid * r + slot];
                if val == 0.0 {
                    continue; // padding slot
                }
                let cid = self.cols[base + rid * r + slot] as usize;
                axpy_row_simd(&mut out[cid * n..(cid + 1) * n], val, src);
            }
        }
    }

    fn spmm_sample_rows_simd(&self, b: usize, row0: usize, rhs: &[f32], n: usize, out: &mut [f32]) {
        let row1 = row0 + out.len() / n;
        let base = self.offset + b * self.stride;
        let r = self.width;
        for rid in row0..row1 {
            let dst = &mut out[(rid - row0) * n..(rid - row0 + 1) * n];
            for slot in 0..r {
                let val = self.vals[base + rid * r + slot];
                if val == 0.0 {
                    continue; // padding slot
                }
                let cid = self.cols[base + rid * r + slot] as usize;
                axpy_row_simd(dst, val, &rhs[cid * n..(cid + 1) * n]);
            }
        }
    }

    fn spmm_sample_t_rows_simd(
        &self,
        b: usize,
        row0: usize,
        rhs: &[f32],
        n: usize,
        out: &mut [f32],
    ) {
        let row1 = row0 + out.len() / n;
        let base = self.offset + b * self.stride;
        let r = self.width;
        for rid in 0..self.rows {
            let src = &rhs[rid * n..(rid + 1) * n];
            for slot in 0..r {
                let val = self.vals[base + rid * r + slot];
                if val == 0.0 {
                    continue; // padding slot
                }
                let cid = self.cols[base + rid * r + slot] as usize;
                if cid < row0 || cid >= row1 {
                    continue;
                }
                axpy_row_simd(&mut out[(cid - row0) * n..(cid - row0 + 1) * n], val, src);
            }
        }
    }
}

/// Dense backend: the batched-GEMM (cuBLAS) baseline over a densified
/// `[batch, rows, inner]` operand — also the `X @ W` feature transform
/// in the GCN forward pass. Explicit zeros are skipped, matching
/// `ops::gemm`.
pub struct GemmKernel<'a> {
    a: &'a [f32],
    batch: usize,
    rows: usize,
    inner: usize,
}

impl<'a> GemmKernel<'a> {
    pub fn new(a: &'a [f32], batch: usize, rows: usize, inner: usize) -> GemmKernel<'a> {
        assert_eq!(a.len(), batch * rows * inner, "dense batch length");
        GemmKernel {
            a,
            batch,
            rows,
            inner,
        }
    }
}

impl BatchedSpmm for GemmKernel<'_> {
    fn name(&self) -> &'static str {
        "engine-gemm"
    }

    fn batch(&self) -> usize {
        self.batch
    }

    fn out_rows(&self) -> usize {
        self.rows
    }

    fn inner_dim(&self) -> usize {
        self.inner
    }

    fn real_nnz(&self) -> usize {
        self.a.iter().filter(|v| **v != 0.0).count()
    }

    fn spmm_sample(&self, b: usize, rhs: &[f32], n: usize, out: &mut [f32]) {
        let base = b * self.rows * self.inner;
        for r in 0..self.rows {
            let dst = &mut out[r * n..(r + 1) * n];
            for k in 0..self.inner {
                let av = self.a[base + r * self.inner + k];
                if av == 0.0 {
                    continue;
                }
                axpy_row(dst, av, &rhs[k * n..(k + 1) * n]);
            }
        }
    }

    fn spmm_sample_t(&self, b: usize, rhs: &[f32], n: usize, out: &mut [f32]) {
        // out[k] += A[r, k] * rhs[r] — the `X^T @ dU` weight-gradient
        // form, traversing A in its native row-major order.
        let base = b * self.rows * self.inner;
        for r in 0..self.rows {
            let src = &rhs[r * n..(r + 1) * n];
            for k in 0..self.inner {
                let av = self.a[base + r * self.inner + k];
                if av == 0.0 {
                    continue;
                }
                axpy_row(&mut out[k * n..(k + 1) * n], av, src);
            }
        }
    }

    fn sample_nnz(&self, _b: usize) -> usize {
        // Dense cost: the full extent, no scan (the pool only needs a
        // relative planning signal).
        self.rows * self.inner
    }

    fn spmm_sample_rows(&self, b: usize, row0: usize, rhs: &[f32], n: usize, out: &mut [f32]) {
        let row1 = row0 + out.len() / n;
        let base = b * self.rows * self.inner;
        for r in row0..row1 {
            let dst = &mut out[(r - row0) * n..(r - row0 + 1) * n];
            for k in 0..self.inner {
                let av = self.a[base + r * self.inner + k];
                if av == 0.0 {
                    continue;
                }
                axpy_row(dst, av, &rhs[k * n..(k + 1) * n]);
            }
        }
    }

    fn spmm_sample_t_rows(&self, b: usize, row0: usize, rhs: &[f32], n: usize, out: &mut [f32]) {
        // Loop interchange (k outer over the block, r inner ascending)
        // keeps every out[k] element's contributions in the same
        // ascending-r order as the full spmm_sample_t, so row-splitting
        // the `X^T @ dU` reduction is bit-exact — and the block never
        // touches the other blocks' columns, so no scan is wasted.
        let row1 = row0 + out.len() / n;
        let base = b * self.rows * self.inner;
        for k in row0..row1 {
            let dst = &mut out[(k - row0) * n..(k - row0 + 1) * n];
            for r in 0..self.rows {
                let av = self.a[base + r * self.inner + k];
                if av == 0.0 {
                    continue;
                }
                axpy_row(dst, av, &rhs[r * n..(r + 1) * n]);
            }
        }
    }

    fn spmm_sample_scalar(&self, b: usize, rhs: &[f32], n: usize, out: &mut [f32]) {
        let base = b * self.rows * self.inner;
        for r in 0..self.rows {
            let dst = &mut out[r * n..(r + 1) * n];
            for k in 0..self.inner {
                let av = self.a[base + r * self.inner + k];
                if av == 0.0 {
                    continue;
                }
                let src = &rhs[k * n..(k + 1) * n];
                for j in 0..n {
                    dst[j] += av * src[j];
                }
            }
        }
    }

    fn spmm_sample_t_scalar(&self, b: usize, rhs: &[f32], n: usize, out: &mut [f32]) {
        let base = b * self.rows * self.inner;
        for r in 0..self.rows {
            let src = &rhs[r * n..(r + 1) * n];
            for k in 0..self.inner {
                let av = self.a[base + r * self.inner + k];
                if av == 0.0 {
                    continue;
                }
                let dst = &mut out[k * n..(k + 1) * n];
                for j in 0..n {
                    dst[j] += av * src[j];
                }
            }
        }
    }

    fn spmm_sample_rows_scalar(
        &self,
        b: usize,
        row0: usize,
        rhs: &[f32],
        n: usize,
        out: &mut [f32],
    ) {
        let row1 = row0 + out.len() / n;
        let base = b * self.rows * self.inner;
        for r in row0..row1 {
            let dst = &mut out[(r - row0) * n..(r - row0 + 1) * n];
            for k in 0..self.inner {
                let av = self.a[base + r * self.inner + k];
                if av == 0.0 {
                    continue;
                }
                let src = &rhs[k * n..(k + 1) * n];
                for j in 0..n {
                    dst[j] += av * src[j];
                }
            }
        }
    }

    fn spmm_sample_t_rows_scalar(
        &self,
        b: usize,
        row0: usize,
        rhs: &[f32],
        n: usize,
        out: &mut [f32],
    ) {
        let row1 = row0 + out.len() / n;
        let base = b * self.rows * self.inner;
        for k in row0..row1 {
            let dst = &mut out[(k - row0) * n..(k - row0 + 1) * n];
            for r in 0..self.rows {
                let av = self.a[base + r * self.inner + k];
                if av == 0.0 {
                    continue;
                }
                let src = &rhs[r * n..(r + 1) * n];
                for j in 0..n {
                    dst[j] += av * src[j];
                }
            }
        }
    }

    fn spmm_sample_simd(&self, b: usize, rhs: &[f32], n: usize, out: &mut [f32]) {
        let base = b * self.rows * self.inner;
        for r in 0..self.rows {
            let dst = &mut out[r * n..(r + 1) * n];
            for k in 0..self.inner {
                let av = self.a[base + r * self.inner + k];
                if av == 0.0 {
                    continue;
                }
                axpy_row_simd(dst, av, &rhs[k * n..(k + 1) * n]);
            }
        }
    }

    fn spmm_sample_t_simd(&self, b: usize, rhs: &[f32], n: usize, out: &mut [f32]) {
        let base = b * self.rows * self.inner;
        for r in 0..self.rows {
            let src = &rhs[r * n..(r + 1) * n];
            for k in 0..self.inner {
                let av = self.a[base + r * self.inner + k];
                if av == 0.0 {
                    continue;
                }
                axpy_row_simd(&mut out[k * n..(k + 1) * n], av, src);
            }
        }
    }

    fn spmm_sample_rows_simd(&self, b: usize, row0: usize, rhs: &[f32], n: usize, out: &mut [f32]) {
        let row1 = row0 + out.len() / n;
        let base = b * self.rows * self.inner;
        for r in row0..row1 {
            let dst = &mut out[(r - row0) * n..(r - row0 + 1) * n];
            for k in 0..self.inner {
                let av = self.a[base + r * self.inner + k];
                if av == 0.0 {
                    continue;
                }
                axpy_row_simd(dst, av, &rhs[k * n..(k + 1) * n]);
            }
        }
    }

    fn spmm_sample_t_rows_simd(
        &self,
        b: usize,
        row0: usize,
        rhs: &[f32],
        n: usize,
        out: &mut [f32],
    ) {
        // Same k-outer loop interchange as the vectorized form: each
        // out[k] row accumulates in ascending-r order, so the SIMD twin
        // stays bit-exact under row splitting too.
        let row1 = row0 + out.len() / n;
        let base = b * self.rows * self.inner;
        for k in row0..row1 {
            let dst = &mut out[(k - row0) * n..(k - row0 + 1) * n];
            for r in 0..self.rows {
                let av = self.a[base + r * self.inner + k];
                if av == 0.0 {
                    continue;
                }
                axpy_row_simd(dst, av, &rhs[r * n..(r + 1) * n]);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::batch::densify_batch;
    use crate::sparse::engine::{Executor, Rhs};
    use crate::sparse::ops;
    use crate::sparse::random::{random_batch, RandomSpec};
    use crate::sparse::Dense;
    use crate::util::rng::Rng;

    #[test]
    fn all_backends_match_single_matrix_oracles() {
        let mut rng = Rng::new(21);
        let (dim, z, batch, nb) = (10usize, 2usize, 6usize, 7usize);
        let mats = random_batch(&mut rng, &RandomSpec::new(dim, z), batch);
        let st = PaddedStBatch::pack(&mats, dim, dim * z).unwrap();
        let csr = PaddedCsrBatch::pack(&mats, dim, dim * z).unwrap();
        let ell = PaddedEllBatch::pack_auto(&mats, dim).unwrap();
        let a_dense = densify_batch(&mats, dim);
        let dense: Vec<f32> = (0..batch * dim * nb).map(|_| rng.normal()).collect();

        let exec = Executor::serial();
        let stk = StKernel::new(&st);
        let csrk = CsrKernel::new(&csr);
        let ellk = EllKernel::from_padded(&ell);
        let gemk = GemmKernel::new(&a_dense, batch, dim, dim);
        let kernels: [&dyn BatchedSpmm; 4] = [&stk, &csrk, &ellk, &gemk];
        for k in kernels {
            let got = exec.spmm(k, Rhs::PerSample(&dense), nb).unwrap();
            for (bi, m) in mats.iter().enumerate() {
                let b = Dense {
                    rows: dim,
                    cols: nb,
                    data: dense[bi * dim * nb..(bi + 1) * dim * nb].to_vec(),
                };
                let want = ops::spmm_st(&m.to_sparse_tensor(), &b);
                for (j, w) in want.data.iter().enumerate() {
                    let g = got[bi * dim * nb + j];
                    assert!(
                        (g - w).abs() <= 1e-5 + 1e-5 * w.abs(),
                        "{} sample {bi} elem {j}: got {g}, want {w}",
                        k.name()
                    );
                }
            }
            assert_eq!(k.real_nnz(), batch * dim * z, "{}", k.name());
        }
    }

    #[test]
    fn all_backends_transpose_matches_transposed_oracle() {
        // out = A^T @ x must equal the plain oracle run on the
        // host-transposed dense form of A, for every backend.
        let mut rng = Rng::new(33);
        let (dim, z, batch, nb) = (9usize, 2usize, 5usize, 4usize);
        let mats = random_batch(&mut rng, &RandomSpec::new(dim, z), batch);
        let st = PaddedStBatch::pack(&mats, dim, dim * z).unwrap();
        let csr = PaddedCsrBatch::pack(&mats, dim, dim * z).unwrap();
        let ell = PaddedEllBatch::pack_auto(&mats, dim).unwrap();
        let a_dense = densify_batch(&mats, dim);
        let dense: Vec<f32> = (0..batch * dim * nb).map(|_| rng.normal()).collect();

        let exec = Executor::serial();
        let stk = StKernel::new(&st);
        let csrk = CsrKernel::new(&csr);
        let ellk = EllKernel::from_padded(&ell);
        let gemk = GemmKernel::new(&a_dense, batch, dim, dim);
        let kernels: [&dyn BatchedSpmm; 4] = [&stk, &csrk, &ellk, &gemk];
        for k in kernels {
            let got = exec.spmm_t(k, Rhs::PerSample(&dense), nb).unwrap();
            for (bi, m) in mats.iter().enumerate() {
                let a = m.to_dense();
                let mut at = Dense::zeros(dim, dim);
                for r in 0..dim {
                    for c in 0..dim {
                        at.data[c * dim + r] = a.at(r, c);
                    }
                }
                let b = Dense {
                    rows: dim,
                    cols: nb,
                    data: dense[bi * dim * nb..(bi + 1) * dim * nb].to_vec(),
                };
                let want = ops::gemm(&at, &b);
                for (j, w) in want.data.iter().enumerate() {
                    let g = got[bi * dim * nb + j];
                    assert!(
                        (g - w).abs() <= 1e-5 + 1e-5 * w.abs(),
                        "{} sample {bi} elem {j}: got {g}, want {w}",
                        k.name()
                    );
                }
            }
        }
    }

    #[test]
    fn ell_channel_view_matches_contiguous_pack() {
        // A ModelBatch channel view and a standalone pack of the same
        // matrices must multiply identically.
        use crate::graph::dataset::{Dataset, DatasetKind};
        let d = Dataset::generate(DatasetKind::Tox21, 4, 9);
        let mb = d.pack_batch(&[0, 1, 2], 50, 12).unwrap();
        let mut rng = Rng::new(5);
        let nb = 3usize;
        let dense: Vec<f32> = (0..3 * 50 * nb).map(|_| rng.normal()).collect();
        let exec = Executor::serial();
        for ch in 0..mb.channels {
            let view = EllKernel::channel(&mb, ch);
            let mats: Vec<_> = (0..3)
                .map(|bi| d.samples[bi].mol.adjacency()[ch].clone())
                .collect();
            let packed = PaddedEllBatch::pack(&mats, 50, 12).unwrap();
            let contiguous = EllKernel::from_padded(&packed);
            let a = exec.spmm(&view, Rhs::PerSample(&dense), nb).unwrap();
            let b = exec.spmm(&contiguous, Rhs::PerSample(&dense), nb).unwrap();
            assert_eq!(a, b, "channel {ch}");
            // The two views must also agree on the cached per-sample
            // cost-model counts.
            for bi in 0..3 {
                assert_eq!(view.sample_nnz(bi), contiguous.sample_nnz(bi), "channel {ch}");
            }
        }
    }

    #[test]
    fn row_blocked_assembly_is_bit_identical_to_full_sample() {
        // Computing a sample in arbitrary row blocks must reproduce the
        // full-sample result bit for bit, in both transpose forms —
        // the invariant the worker pool's row-split tasks rely on.
        let mut rng = Rng::new(71);
        let (dim, z, batch, nb) = (11usize, 3usize, 4usize, 5usize);
        let mats = random_batch(&mut rng, &RandomSpec::new(dim, z), batch);
        let st = PaddedStBatch::pack(&mats, dim, dim * z).unwrap();
        let csr = PaddedCsrBatch::pack(&mats, dim, dim * z).unwrap();
        let ell = PaddedEllBatch::pack_auto(&mats, dim).unwrap();
        let a_dense = densify_batch(&mats, dim);
        let rhs: Vec<f32> = (0..dim * nb).map(|_| rng.normal()).collect();

        let stk = StKernel::new(&st);
        let csrk = CsrKernel::new(&csr);
        let ellk = EllKernel::from_padded(&ell);
        let gemk = GemmKernel::new(&a_dense, batch, dim, dim);
        let kernels: [&dyn BatchedSpmm; 4] = [&stk, &csrk, &ellk, &gemk];
        // Uneven block boundaries, including 1-row blocks.
        let cuts = [0usize, 1, 4, 9, dim];
        for k in kernels {
            let mut nnz_sum = 0;
            for b in 0..batch {
                nnz_sum += k.sample_nnz(b);
                for transpose in [false, true] {
                    let mut full = vec![0.25f32; dim * nb];
                    let mut blocked = vec![0.25f32; dim * nb];
                    if transpose {
                        k.spmm_sample_t(b, &rhs, nb, &mut full);
                    } else {
                        k.spmm_sample(b, &rhs, nb, &mut full);
                    }
                    for w in cuts.windows(2) {
                        let (r0, r1) = (w[0], w[1]);
                        let block = &mut blocked[r0 * nb..r1 * nb];
                        if transpose {
                            k.spmm_sample_t_rows(b, r0, &rhs, nb, block);
                        } else {
                            k.spmm_sample_rows(b, r0, &rhs, nb, block);
                        }
                    }
                    assert_eq!(
                        full,
                        blocked,
                        "{} sample {b} transpose={transpose}",
                        k.name()
                    );
                }
            }
            if k.name() == "engine-gemm" {
                // The dense backend reports its full extent as cost.
                assert_eq!(nnz_sum, batch * dim * dim);
            } else {
                assert_eq!(nnz_sum, k.real_nnz(), "{}", k.name());
            }
        }
    }

    #[test]
    fn shared_rhs_equals_tiled_per_sample() {
        let mut rng = Rng::new(31);
        let (dim, batch, nb) = (8usize, 5usize, 4usize);
        let mats = random_batch(&mut rng, &RandomSpec::new(dim, 2), batch);
        let st = PaddedStBatch::pack(&mats, dim, dim * 2).unwrap();
        let k = StKernel::new(&st);
        let w: Vec<f32> = (0..dim * nb).map(|_| rng.normal()).collect();
        let tiled: Vec<f32> = (0..batch).flat_map(|_| w.iter().copied()).collect();
        let exec = Executor::serial();
        let a = exec.spmm(&k, Rhs::Shared(&w), nb).unwrap();
        let b = exec.spmm(&k, Rhs::PerSample(&tiled), nb).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn axpy_row_is_bit_identical_to_scalar_loop_at_every_width() {
        // The vectorized primitive itself, across full blocks, tails,
        // and sub-LANES widths.
        let mut rng = Rng::new(0xA9);
        for n in [0usize, 1, 3, LANES - 1, LANES, LANES + 1, 2 * LANES, 65] {
            let src: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
            let init: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
            let val = rng.normal();
            let mut vec_out = init.clone();
            axpy_row(&mut vec_out, val, &src);
            let mut ref_out = init;
            for j in 0..n {
                ref_out[j] += val * src[j];
            }
            assert_eq!(vec_out, ref_out, "n={n}");
        }
    }

    #[test]
    fn tiled_csr_is_bit_identical_across_tile_widths() {
        // Column tiling regroups only independent output elements, so
        // every width — including degenerate 1-wide tiles and tiles
        // wider than the feature dimension — must reproduce the untiled
        // result bit for bit, in both the full-sample and row-blocked
        // forms (DESIGN.md §12).
        let mut rng = Rng::new(0x7137);
        let (dim, z, batch, nb) = (17usize, 3usize, 3usize, 13usize);
        let mats = random_batch(&mut rng, &RandomSpec::new(dim, z), batch);
        let csr = PaddedCsrBatch::pack(&mats, dim, dim * z).unwrap();
        let rhs: Vec<f32> = (0..dim * nb).map(|_| rng.normal()).collect();
        let plain = CsrKernel::new(&csr);
        let cuts = [0usize, 2, 5, 11, dim];
        for tc in [1usize, 3, 7, LANES, nb, 64, 4096] {
            let tiled = CsrKernel::new(&csr).with_tile_cols(tc);
            for b in 0..batch {
                let mut want = vec![0.5f32; dim * nb];
                plain.spmm_sample(b, &rhs, nb, &mut want);
                let mut got = vec![0.5f32; dim * nb];
                tiled.spmm_sample_tiled(b, &rhs, nb, &mut got);
                assert_eq!(want, got, "tc={tc} sample {b}");
                let mut blocked = vec![0.5f32; dim * nb];
                for w in cuts.windows(2) {
                    let block = &mut blocked[w[0] * nb..w[1] * nb];
                    tiled.spmm_sample_rows_tiled(b, w[0], &rhs, nb, block);
                }
                assert_eq!(want, blocked, "tc={tc} sample {b} row-blocked");
            }
        }
        // The default (no override) resolves env/heuristic and must
        // stay bit-identical too.
        let mut want = vec![0f32; dim * nb];
        plain.spmm_sample(0, &rhs, nb, &mut want);
        let mut got = vec![0f32; dim * nb];
        plain.spmm_sample_tiled(0, &rhs, nb, &mut got);
        assert_eq!(want, got);
    }

    #[test]
    fn tiled_csr_transpose_is_bit_identical_across_tile_widths() {
        // The transpose (scatter) twins of the tiled path: every tile
        // width must reproduce the untiled transpose result bit for
        // bit, in both the full-sample and row-blocked forms — each
        // output element lives in one tile and its scatter order over
        // the non-zeros is untouched (DESIGN.md §12).
        let mut rng = Rng::new(0x7138);
        let (dim, z, batch, nb) = (17usize, 3usize, 3usize, 13usize);
        let mats = random_batch(&mut rng, &RandomSpec::new(dim, z), batch);
        let csr = PaddedCsrBatch::pack(&mats, dim, dim * z).unwrap();
        let rhs: Vec<f32> = (0..dim * nb).map(|_| rng.normal()).collect();
        let plain = CsrKernel::new(&csr);
        let cuts = [0usize, 2, 5, 11, dim];
        for tc in [1usize, 3, 7, LANES, nb, 64, 4096] {
            let tiled = CsrKernel::new(&csr).with_tile_cols(tc);
            for b in 0..batch {
                let mut want = vec![0.5f32; dim * nb];
                plain.spmm_sample_t(b, &rhs, nb, &mut want);
                let mut got = vec![0.5f32; dim * nb];
                tiled.spmm_sample_t_tiled(b, &rhs, nb, &mut got);
                assert_eq!(want, got, "tc={tc} sample {b} transpose");
                let mut blocked = vec![0.5f32; dim * nb];
                for w in cuts.windows(2) {
                    let block = &mut blocked[w[0] * nb..w[1] * nb];
                    tiled.spmm_sample_t_rows_tiled(b, w[0], &rhs, nb, block);
                }
                assert_eq!(want, blocked, "tc={tc} sample {b} transpose row-blocked");
            }
        }
        // The default (no override) path for the transpose twins.
        let mut want = vec![0f32; dim * nb];
        plain.spmm_sample_t(0, &rhs, nb, &mut want);
        let mut got = vec![0f32; dim * nb];
        plain.spmm_sample_t_tiled(0, &rhs, nb, &mut got);
        assert_eq!(want, got);
    }

    #[test]
    fn csr_rows_nnz_is_exact_on_every_range() {
        let mut rng = Rng::new(0xD3);
        let dim = 19;
        let mats = random_batch(&mut rng, &RandomSpec::new(dim, 2), 4);
        let csr = PaddedCsrBatch::pack(&mats, dim, dim * 2).unwrap();
        let k = CsrKernel::new(&csr);
        for b in 0..4 {
            for r0 in 0..dim {
                for r1 in r0..=dim {
                    // Recount from the COO rows.
                    let want = mats[b]
                        .row_ids
                        .iter()
                        .filter(|&&r| (r as usize) >= r0 && (r as usize) < r1)
                        .count();
                    assert_eq!(k.rows_nnz(b, r0, r1), Some(want), "b={b} [{r0},{r1})");
                }
            }
            assert_eq!(k.rows_nnz(b, 0, dim), Some(k.sample_nnz(b)));
        }
        // The construction-time total must match the per-sample sums.
        assert_eq!(
            k.real_nnz(),
            (0..4).map(|b| k.sample_nnz(b)).sum::<usize>()
        );
    }

    #[test]
    fn cached_sample_nnz_matches_recomputed_scan() {
        // O(1) cached counts on the packed formats must agree with a
        // from-scratch scan of the padded value arrays — the cost-model
        // contract the pool's planner relies on (DESIGN.md §10).
        let mut rng = Rng::new(0xC0);
        let dim = 24;
        let mats = crate::sparse::random::random_mixed_batch(&mut rng, (4, dim), (1, 3), 9);
        let cap = mats.iter().map(crate::sparse::Coo::nnz).max().unwrap();
        let st = PaddedStBatch::pack(&mats, dim, cap).unwrap();
        let ell = PaddedEllBatch::pack_auto(&mats, dim).unwrap();
        let stk = StKernel::new(&st);
        let ellk = EllKernel::from_padded(&ell);
        for b in 0..mats.len() {
            let st_scan = st.vals[b * cap..(b + 1) * cap]
                .iter()
                .filter(|v| **v != 0.0)
                .count();
            assert_eq!(stk.sample_nnz(b), st_scan, "st sample {b}");
            let per = ell.dim * ell.width;
            let ell_scan = ell.vals[b * per..(b + 1) * per]
                .iter()
                .filter(|v| **v != 0.0)
                .count();
            assert_eq!(ellk.sample_nnz(b), ell_scan, "ell sample {b}");
            // The raw-array view (no cache) must agree with the cached one.
            let raw = EllKernel::new(&ell.cols, &ell.vals, ell.batch, ell.dim, ell.width);
            assert_eq!(raw.sample_nnz(b), ellk.sample_nnz(b), "raw ell sample {b}");
        }
        assert_eq!(stk.real_nnz(), mats.iter().map(crate::sparse::Coo::nnz).sum());
    }

    #[test]
    fn axpy_row_simd_is_bit_identical_to_axpy_row_at_every_width() {
        // The SIMD primitive performs the same two roundings per element
        // (round after multiply, round after add) as the vectorized and
        // scalar loops, so it must agree bit for bit — full 8-wide
        // blocks, scalar tails, and sub-LANES widths alike. This holds
        // with and without the `simd` cargo feature (without it the call
        // degrades to `axpy_row`, making the assertion trivially true).
        let mut rng = Rng::new(0xA10);
        for n in [0usize, 1, 3, LANES - 1, LANES, LANES + 1, 2 * LANES, 65] {
            let src: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
            let init: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
            let val = rng.normal();
            let mut simd_out = init.clone();
            axpy_row_simd(&mut simd_out, val, &src);
            let mut ref_out = init;
            for j in 0..n {
                ref_out[j] += val * src[j];
            }
            assert_eq!(simd_out, ref_out, "n={n}");
        }
    }

    #[test]
    fn axpy_row_fma_stays_within_one_product_ulp_of_two_rounding() {
        // FMA rounds once (after the add) where the default path rounds
        // twice, so results may differ — but only by the rounding error
        // of the intermediate product, i.e. at most half an ulp of
        // `val * src[j]` per element (DESIGN.md §16). The hardware FMA
        // and the `f32::mul_add` software fallback round identically,
        // so one bound covers both builds.
        let mut rng = Rng::new(0xF3A);
        for n in [1usize, 7, LANES, LANES + 1, 65] {
            let src: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
            let init: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
            let val = rng.normal();
            let mut fma_out = init.clone();
            axpy_row_fma(&mut fma_out, val, &src);
            for j in 0..n {
                let two_round = init[j] + val * src[j];
                let prod_ulp = (val * src[j]).abs() * f32::EPSILON;
                let tol = prod_ulp.max(f32::MIN_POSITIVE);
                assert!(
                    (fma_out[j] - two_round).abs() <= tol,
                    "n={n} j={j}: fma {} vs two-rounding {two_round} (tol {tol:e})",
                    fma_out[j]
                );
            }
        }
    }

    #[test]
    fn l2_probe_returns_lane_multiple_in_range() {
        // Whatever the machine (bare metal, CI container, VM with noisy
        // timers), the probe must hand back a sane tile width: a LANES
        // multiple within the clamp window. The env-resolved entry point
        // shares the same floor.
        let tc = probe_l2_tile_cols();
        assert!(tc >= LANES && tc <= 1024, "probe gave {tc}");
        assert_eq!(tc % LANES, 0, "probe gave non-lane-multiple {tc}");
        assert!(tile_cols_from_env() >= LANES);
    }

    #[test]
    fn simd_twins_are_bit_identical_to_vectorized_on_every_backend() {
        // Serial, single-kernel check that every backend's four `_simd`
        // dispatch forms reproduce the vectorized forms bit for bit —
        // the engine-level (threaded) twin lives in engine_parity.rs.
        let mut rng = Rng::new(0x51D);
        let (dim, z, batch, nb) = (17usize, 3usize, 4usize, 13usize);
        let mats = random_batch(&mut rng, &RandomSpec::new(dim, z), batch);
        let st = PaddedStBatch::pack(&mats, dim, dim * z).unwrap();
        let csr = PaddedCsrBatch::pack(&mats, dim, dim * z).unwrap();
        let ell = PaddedEllBatch::pack_auto(&mats, dim).unwrap();
        let a_dense = densify_batch(&mats, dim);
        let rhs: Vec<f32> = (0..dim * nb).map(|_| rng.normal()).collect();
        let stk = StKernel::new(&st);
        let csrk = CsrKernel::new(&csr);
        let ellk = EllKernel::from_padded(&ell);
        let gemk = GemmKernel::new(&a_dense, batch, dim, dim);
        let kernels: [&dyn BatchedSpmm; 4] = [&stk, &csrk, &ellk, &gemk];
        let cuts = [0usize, 2, 5, 11, dim];
        for k in kernels {
            for b in 0..batch {
                let mut want = vec![0.25f32; dim * nb];
                k.spmm_sample(b, &rhs, nb, &mut want);
                let mut got = vec![0.25f32; dim * nb];
                k.spmm_sample_simd(b, &rhs, nb, &mut got);
                assert_eq!(want, got, "{} sample {b}", k.name());

                let mut want_t = vec![0.25f32; dim * nb];
                k.spmm_sample_t(b, &rhs, nb, &mut want_t);
                let mut got_t = vec![0.25f32; dim * nb];
                k.spmm_sample_t_simd(b, &rhs, nb, &mut got_t);
                assert_eq!(want_t, got_t, "{} sample {b} transpose", k.name());

                let mut blocked = vec![0.25f32; dim * nb];
                let mut blocked_t = vec![0.25f32; dim * nb];
                for w in cuts.windows(2) {
                    k.spmm_sample_rows_simd(b, w[0], &rhs, nb, &mut blocked[w[0] * nb..w[1] * nb]);
                    k.spmm_sample_t_rows_simd(
                        b,
                        w[0],
                        &rhs,
                        nb,
                        &mut blocked_t[w[0] * nb..w[1] * nb],
                    );
                }
                assert_eq!(want, blocked, "{} sample {b} row-blocked", k.name());
                assert_eq!(
                    want_t, blocked_t,
                    "{} sample {b} transpose row-blocked",
                    k.name()
                );
            }
        }
    }
}
