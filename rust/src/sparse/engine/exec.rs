//! The batched-dispatch executor: one `dispatch` call processes a
//! whole packed batch — the CPU analogue of the paper's single fused
//! kernel launch. `threads = 1` is the serial fallback (the per-sample
//! launch regime the paper compares against); `threads > 1` runs on the
//! executor's persistent [`WorkerPool`] (parked workers + work-stealing
//! over (sample, row-block) tasks, DESIGN.md §9). Output is
//! bit-identical to the serial path for every thread count, policy and
//! steal order: tasks partition the output elements and the row-blocked
//! kernels preserve the serial per-element accumulation order.
//!
//! `Executor` is a cheap `Arc` handle over its pool: clone it to share
//! one pool across every dispatching layer (the trainer, the serving
//! device thread, the benches) instead of constructing executors — and
//! with them, thread pools — per call. The pool's only thread spawns
//! happen at construction ([`Executor::stats`] exposes the accounting
//! the tests pin).
//!
//! Both transpose forms of the backward pass (DESIGN.md §8) ride the
//! same machinery: [`Executor::dispatch_t`] runs the `A^T·X` form via
//! [`BatchedSpmm::spmm_sample_t`], and [`Rhs::SharedTransposed`]
//! covers the `X·W^T` form by materializing the (small) transposed
//! weight once per dispatch. (Planned replays pre-transpose into a
//! workspace slot instead — see [`super::plan`] — so their dispatches
//! pass [`Rhs::Shared`] and allocate nothing; both routes produce the
//! same element order, hence identical bits.)
//!
//! Backend selection composes on top: `Executor::dispatch_bundle`
//! (defined in [`super::plan`]) resolves a [`super::Backend`] request —
//! including [`super::Backend::Auto`], the cost-model-driven choice —
//! against a [`super::KernelBundle`] of available packings and then
//! runs this module's ordinary dispatch on the chosen kernel.

use std::sync::Arc;

use super::pool::{PoolStats, SchedPolicy, WorkerPool};
use super::{BatchedSpmm, KernelVariant, Rhs};

/// Thin, cloneable handle over a persistent [`WorkerPool`]; all engine
/// dispatches go through one of these.
#[derive(Clone)]
pub struct Executor {
    pool: Arc<WorkerPool>,
}

impl Executor {
    /// Serial fallback: everything on the calling thread, no worker
    /// threads spawned, no synchronization on the dispatch path.
    pub fn serial() -> Executor {
        Executor::with_policy(1, SchedPolicy::WorkStealing)
    }

    /// Fixed thread budget (clamped to at least 1) with the default
    /// work-stealing scheduler. Spawns the pool's `threads - 1` workers
    /// now; dispatches never spawn.
    pub fn new(threads: usize) -> Executor {
        Executor::with_policy(threads, SchedPolicy::WorkStealing)
    }

    /// Fixed thread budget with an explicit scheduling policy
    /// ([`SchedPolicy::Static`] is the legacy contiguous sample split
    /// the benches use as the parallel baseline).
    pub fn with_policy(threads: usize, policy: SchedPolicy) -> Executor {
        Executor {
            pool: Arc::new(WorkerPool::new(threads, policy)),
        }
    }

    /// [`Executor::with_policy`] with an explicit kernel variant:
    /// [`KernelVariant::Scalar`] pins the pre-vectorization scalar
    /// inner loops — the parity oracle the property tests compare
    /// against and the baseline the microbench's scalar-vs-vectorized
    /// comparison runs on (DESIGN.md §10). Output is bit-identical
    /// across variants; this is a pure perf/observability knob.
    pub fn with_variant(threads: usize, policy: SchedPolicy, variant: KernelVariant) -> Executor {
        // Resolve the tile width once, up front: the first resolution may
        // run the one-shot L2 probe (DESIGN.md §16), and construction is
        // the right place to pay that millisecond — never a dispatch.
        super::kernels::tile_cols_from_env();
        Executor {
            pool: Arc::new(WorkerPool::with_variant(threads, policy, variant)),
        }
    }

    /// One thread per available core — the "parallel" configuration the
    /// benches compare against [`Executor::serial`].
    pub fn parallel() -> Executor {
        Executor::new(Executor::resolve_threads(0))
    }

    /// The crate-wide "auto" convention: `0` means one thread per core,
    /// anything else a fixed budget.
    pub fn auto(threads: usize) -> Executor {
        Executor::new(Executor::resolve_threads(threads))
    }

    /// Resolve the "auto" convention without constructing a pool: `0`
    /// means one thread per available core, anything else a fixed
    /// budget clamped to at least 1. The benches use this to label
    /// configurations before building their executors.
    pub fn resolve_threads(threads: usize) -> usize {
        if threads == 0 {
            std::thread::available_parallelism()
                .map(std::num::NonZeroUsize::get)
                .unwrap_or(1)
        } else {
            threads.max(1)
        }
    }

    pub fn threads(&self) -> usize {
        self.pool.workers()
    }

    /// Which inner-loop implementation this executor's dispatches run.
    pub fn variant(&self) -> KernelVariant {
        self.pool.variant()
    }

    /// Cumulative scheduling counters of the underlying pool
    /// (dispatches, tasks, steals, threads spawned at construction).
    pub fn stats(&self) -> PoolStats {
        self.pool.stats()
    }

    /// One batched dispatch: `out[b] += A[b] @ rhs[b]` for every sample
    /// in the kernel's batch. `out` is `[batch, out_rows, n]` row-major
    /// flat and must be pre-filled by the caller (zeros or bias).
    pub fn dispatch<K: BatchedSpmm + ?Sized>(
        &self,
        kernel: &K,
        rhs: Rhs<'_>,
        n: usize,
        out: &mut [f32],
    ) -> anyhow::Result<()> {
        self.dispatch_impl(kernel, rhs, n, out, false)
    }

    /// Transpose dispatch: `out[b] += A[b]^T @ rhs[b]` — the `A^T·X`
    /// gradient form (DESIGN.md §8). `out` is `[batch, inner_dim, n]`,
    /// `rhs` samples are `[out_rows, n]`; otherwise identical to
    /// [`Executor::dispatch`], including the pool-parallel split and
    /// the pre-filled-accumulator contract.
    pub fn dispatch_t<K: BatchedSpmm + ?Sized>(
        &self,
        kernel: &K,
        rhs: Rhs<'_>,
        n: usize,
        out: &mut [f32],
    ) -> anyhow::Result<()> {
        self.dispatch_impl(kernel, rhs, n, out, true)
    }

    fn dispatch_impl<K: BatchedSpmm + ?Sized>(
        &self,
        kernel: &K,
        rhs: Rhs<'_>,
        n: usize,
        out: &mut [f32],
        transpose: bool,
    ) -> anyhow::Result<()> {
        let b = kernel.batch();
        // Transposing A swaps the roles of its rows and columns.
        let (out_rows, inner) = if transpose {
            (kernel.inner_dim(), kernel.out_rows())
        } else {
            (kernel.out_rows(), kernel.inner_dim())
        };
        let per_out = out_rows * n;
        anyhow::ensure!(
            out.len() == b * per_out,
            "{}: output length {} != batch {b} * {out_rows} rows * n {n}",
            kernel.name(),
            out.len(),
        );
        anyhow::ensure!(
            rhs.len() == rhs.required_len(b, inner, n),
            "{}: rhs length {} != required {} (batch {b}, inner {inner}, n {n})",
            kernel.name(),
            rhs.len(),
            rhs.required_len(b, inner, n)
        );
        if b == 0 || per_out == 0 {
            return Ok(());
        }

        // X·W^T form: materialize the [inner, n] transpose of the
        // [n, inner] shared operand once per dispatch, so the
        // per-sample kernels keep reading contiguous rows. Planned
        // replays pre-transpose into an arena slot with the same
        // `transpose_into` — one implementation, so the two paths can
        // never drift out of bit-identity.
        let tbuf: Vec<f32>;
        let rhs = match rhs {
            Rhs::SharedTransposed(w) => {
                let mut t = vec![0f32; inner * n];
                super::plan::transpose_into(w, inner, n, &mut t);
                tbuf = t;
                Rhs::Shared(&tbuf)
            }
            other => other,
        };

        // `&K` is Sized even when `K` is not, so it coerces to the
        // `&dyn BatchedSpmm` the (non-generic) pool machinery runs.
        self.pool
            .run_dispatch(&kernel, rhs, n, inner, out_rows, transpose, out);
        Ok(())
    }

    /// Convenience: allocate a zeroed output, dispatch, return it.
    pub fn spmm<K: BatchedSpmm + ?Sized>(
        &self,
        kernel: &K,
        rhs: Rhs<'_>,
        n: usize,
    ) -> anyhow::Result<Vec<f32>> {
        let mut out = vec![0f32; kernel.batch() * kernel.out_rows() * n];
        self.dispatch(kernel, rhs, n, &mut out)?;
        Ok(out)
    }

    /// Convenience twin of [`Executor::spmm`] for the transpose form:
    /// allocate a zeroed `[batch, inner_dim, n]` output, `dispatch_t`,
    /// return it.
    pub fn spmm_t<K: BatchedSpmm + ?Sized>(
        &self,
        kernel: &K,
        rhs: Rhs<'_>,
        n: usize,
    ) -> anyhow::Result<Vec<f32>> {
        let mut out = vec![0f32; kernel.batch() * kernel.inner_dim() * n];
        self.dispatch_t(kernel, rhs, n, &mut out)?;
        Ok(out)
    }
}

impl std::fmt::Debug for Executor {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Executor")
            .field("threads", &self.threads())
            .field("policy", &self.pool.policy())
            .field("variant", &self.variant())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::batch::{random_dense_batch, PaddedStBatch};
    use crate::sparse::engine::kernels::{GemmKernel, StKernel};
    use crate::sparse::random::{random_batch, RandomSpec};
    use crate::util::rng::Rng;

    fn workload(batch: usize, dim: usize, nb: usize) -> (PaddedStBatch, Vec<f32>) {
        let mut rng = Rng::new(11);
        let mats = random_batch(&mut rng, &RandomSpec::new(dim, 2), batch);
        let st = PaddedStBatch::pack(&mats, dim, dim * 2).unwrap();
        let dense = random_dense_batch(&mut rng, batch, dim, nb);
        (st, dense)
    }

    #[test]
    fn parallel_bitwise_equals_serial() {
        let (st, dense) = workload(13, 16, 5);
        let k = StKernel::new(&st);
        let serial = Executor::serial().spmm(&k, Rhs::PerSample(&dense), 5).unwrap();
        for threads in [2, 3, 8, 64] {
            for policy in [SchedPolicy::Static, SchedPolicy::WorkStealing] {
                let par = Executor::with_policy(threads, policy)
                    .spmm(&k, Rhs::PerSample(&dense), 5)
                    .unwrap();
                assert_eq!(serial, par, "threads={threads} policy={policy:?}");
            }
        }
    }

    #[test]
    fn transpose_parallel_bitwise_equals_serial() {
        let (st, dense) = workload(13, 16, 5);
        let k = StKernel::new(&st);
        let serial = Executor::serial()
            .spmm_t(&k, Rhs::PerSample(&dense), 5)
            .unwrap();
        assert!(serial.iter().any(|v| *v != 0.0));
        for threads in [2, 3, 8, 64] {
            let par = Executor::new(threads)
                .spmm_t(&k, Rhs::PerSample(&dense), 5)
                .unwrap();
            assert_eq!(serial, par, "threads={threads}");
        }
    }

    #[test]
    fn scalar_variant_is_bitwise_identical_to_vectorized() {
        let (st, dense) = workload(9, 16, 11); // 11 = tail width 3
        let k = StKernel::new(&st);
        let vec_fwd = Executor::serial().spmm(&k, Rhs::PerSample(&dense), 11).unwrap();
        let vec_bwd = Executor::serial()
            .spmm_t(&k, Rhs::PerSample(&dense), 11)
            .unwrap();
        for threads in [1, 4] {
            let scalar =
                Executor::with_variant(threads, SchedPolicy::WorkStealing, KernelVariant::Scalar);
            assert_eq!(scalar.variant(), KernelVariant::Scalar);
            let sf = scalar.spmm(&k, Rhs::PerSample(&dense), 11).unwrap();
            let sb = scalar.spmm_t(&k, Rhs::PerSample(&dense), 11).unwrap();
            assert_eq!(sf, vec_fwd, "threads={threads}");
            assert_eq!(sb, vec_bwd, "threads={threads}");
        }
        assert_eq!(Executor::serial().variant(), KernelVariant::Vectorized);
    }

    #[test]
    fn tiled_variant_is_bitwise_identical_to_vectorized() {
        // KernelVariant::Tiled through the full executor path — serial
        // fast path, pooled tasks, and both transpose forms (transpose
        // falls back to the vectorized loops) — must match the default
        // variant bit for bit (DESIGN.md §12).
        use crate::sparse::batch::PaddedCsrBatch;
        use crate::sparse::engine::kernels::CsrKernel;
        let mut rng = Rng::new(0x71D);
        let (batch, dim, nb) = (7usize, 16usize, 11usize);
        let mats = random_batch(&mut rng, &RandomSpec::new(dim, 3), batch);
        let csr = PaddedCsrBatch::pack(&mats, dim, dim * 3).unwrap();
        let dense = random_dense_batch(&mut rng, batch, dim, nb);
        let k = CsrKernel::new(&csr).with_tile_cols(4);
        let vec_fwd = Executor::serial().spmm(&k, Rhs::PerSample(&dense), nb).unwrap();
        let vec_bwd = Executor::serial()
            .spmm_t(&k, Rhs::PerSample(&dense), nb)
            .unwrap();
        for threads in [1, 4] {
            let tiled =
                Executor::with_variant(threads, SchedPolicy::WorkStealing, KernelVariant::Tiled);
            assert_eq!(tiled.variant(), KernelVariant::Tiled);
            let tf = tiled.spmm(&k, Rhs::PerSample(&dense), nb).unwrap();
            let tb = tiled.spmm_t(&k, Rhs::PerSample(&dense), nb).unwrap();
            assert_eq!(tf, vec_fwd, "threads={threads}");
            assert_eq!(tb, vec_bwd, "threads={threads}");
        }
    }

    #[test]
    fn simd_variant_is_bitwise_identical_to_vectorized() {
        // KernelVariant::Simd through the full executor path. Without
        // BSPMM_ALLOW_FMA the SIMD loops perform the same two roundings
        // per element as the vectorized loops, so the results must match
        // bit for bit on every thread count and both transpose forms
        // (DESIGN.md §16) — with or without the `simd` cargo feature.
        let (st, dense) = workload(9, 16, 11); // 11 = tail width 3
        let k = StKernel::new(&st);
        let vec_fwd = Executor::serial().spmm(&k, Rhs::PerSample(&dense), 11).unwrap();
        let vec_bwd = Executor::serial()
            .spmm_t(&k, Rhs::PerSample(&dense), 11)
            .unwrap();
        for threads in [1, 4] {
            let simd =
                Executor::with_variant(threads, SchedPolicy::WorkStealing, KernelVariant::Simd);
            assert_eq!(simd.variant(), KernelVariant::Simd);
            let sf = simd.spmm(&k, Rhs::PerSample(&dense), 11).unwrap();
            let sb = simd.spmm_t(&k, Rhs::PerSample(&dense), 11).unwrap();
            assert_eq!(sf, vec_fwd, "threads={threads}");
            assert_eq!(sb, vec_bwd, "threads={threads}");
        }
    }

    #[test]
    fn shared_handle_reuses_one_pool() {
        let (st, dense) = workload(6, 8, 4);
        let k = StKernel::new(&st);
        let exec = Executor::new(3);
        let twin = exec.clone();
        let before = exec.stats();
        assert_eq!(before.spawned_threads, 2);
        twin.spmm(&k, Rhs::PerSample(&dense), 4).unwrap();
        exec.spmm(&k, Rhs::PerSample(&dense), 4).unwrap();
        let after = exec.stats();
        // Both handles dispatched on the same pool, and nothing spawned.
        assert_eq!(after.dispatches - before.dispatches, 2);
        assert_eq!(after.spawned_threads, before.spawned_threads);
    }

    #[test]
    fn shared_transposed_equals_pretransposed_shared() {
        // Rhs::SharedTransposed(W) with W stored [n, inner] must equal
        // Rhs::Shared(W^T) with the transpose done by hand.
        let mut rng = Rng::new(17);
        let (batch, rows, inner, n) = (4usize, 5usize, 3usize, 6usize);
        let a: Vec<f32> = (0..batch * rows * inner).map(|_| rng.normal()).collect();
        let w: Vec<f32> = (0..n * inner).map(|_| rng.normal()).collect(); // [n, inner]
        let mut wt = vec![0f32; inner * n];
        for j in 0..n {
            for k in 0..inner {
                wt[k * n + j] = w[j * inner + k];
            }
        }
        let kernel = GemmKernel::new(&a, batch, rows, inner);
        let exec = Executor::new(2);
        let got = exec.spmm(&kernel, Rhs::SharedTransposed(&w), n).unwrap();
        let want = exec.spmm(&kernel, Rhs::Shared(&wt), n).unwrap();
        assert_eq!(got, want);
    }

    #[test]
    fn dispatch_accumulates_into_prefilled_output() {
        let (st, dense) = workload(3, 8, 4);
        let k = StKernel::new(&st);
        let base = Executor::serial().spmm(&k, Rhs::PerSample(&dense), 4).unwrap();
        let mut out = vec![1.5f32; base.len()];
        Executor::serial()
            .dispatch(&k, Rhs::PerSample(&dense), 4, &mut out)
            .unwrap();
        for (a, b) in out.iter().zip(&base) {
            assert_eq!(*a, 1.5 + *b);
        }
    }

    #[test]
    fn rejects_bad_lengths() {
        let (st, dense) = workload(2, 8, 4);
        let k = StKernel::new(&st);
        let exec = Executor::serial();
        let mut out = vec![0f32; 2 * 8 * 4 - 1];
        assert!(exec.dispatch(&k, Rhs::PerSample(&dense), 4, &mut out).is_err());
        let mut out = vec![0f32; 2 * 8 * 4];
        assert!(exec
            .dispatch(&k, Rhs::PerSample(&dense[..dense.len() - 1]), 4, &mut out)
            .is_err());
        assert!(exec
            .dispatch(&k, Rhs::Shared(&dense), 4, &mut out)
            .is_err());
        assert!(exec
            .dispatch_t(&k, Rhs::PerSample(&dense[..dense.len() - 1]), 4, &mut out)
            .is_err());
    }

    #[test]
    fn thread_budget_clamps() {
        assert_eq!(Executor::new(0).threads(), 1);
        assert!(Executor::parallel().threads() >= 1);
    }

    #[test]
    fn empty_batch_is_a_noop() {
        let st = PaddedStBatch::pack(&[], 4, 4).unwrap();
        let k = StKernel::new(&st);
        let out = Executor::new(4).spmm(&k, Rhs::PerSample(&[]), 3).unwrap();
        assert!(out.is_empty());
        let out = Executor::new(4).spmm_t(&k, Rhs::PerSample(&[]), 3).unwrap();
        assert!(out.is_empty());
    }
}
