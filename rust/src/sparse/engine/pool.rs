//! The persistent work-stealing worker pool behind [`Executor`].
//!
//! Before this module existed the executor spawned fresh scoped OS
//! threads for every dispatch — ~39 times per host train step — and
//! split each batch into *contiguous sample ranges*, which
//! load-imbalances on mixed batches (the Fig. 10 workload: one large
//! matrix next to many small ones). [`WorkerPool`] is the host-side
//! analogue of what GE-SpMM/HC-SpMM do on device: execution resources
//! stay resident (workers park on a condvar between dispatches; the
//! only thread spawns happen at pool construction) and irregular row
//! work is balanced across them at runtime by stealing.
//!
//! One dispatch proceeds in three steps:
//!
//! 1. **Decompose** ([`plan_tasks`]): an nnz-based cost model turns the
//!    batch into near-equal-cost [`Task`]s — contiguous sample chunks,
//!    plus per-sample *row blocks* when a single sample dominates (that
//!    is what lets a batch-1 `dW = X^T·dU` dispatch use every worker).
//!    When the kernel answers row-range nnz queries in O(1)
//!    ([`BatchedSpmm::rows_nnz`], CSR row pointers), the row-block
//!    boundaries are *degree-bucketed* — placed where the non-zero mass
//!    divides evenly ([`balanced_row_cuts`]) rather than the row count,
//!    which is what keeps a single power-law giant graph load-balanced
//!    (DESIGN.md §12). Uniform batches with enough samples keep the
//!    legacy contiguous count split: at most one task per worker, the
//!    static fast path.
//! 2. **Assign**: tasks are handed to workers as contiguous,
//!    count-balanced segments. The assignment is deliberately *not*
//!    cost-balanced — the cost model only sets task granularity, and
//!    stealing absorbs both its mispredictions (padding-heavy samples,
//!    nnz concentrated in a few rows) and OS scheduling noise.
//! 3. **Execute**: each worker drains its own segment, then scans the
//!    other segments and steals leftover tasks ([`PoolStats::steals`]
//!    counts those). When the plan yields at most one task per worker
//!    the scan is skipped entirely (`static_dispatches`).
//!
//! **Determinism.** Output is bit-identical to the serial loop for any
//! worker count, policy and steal order, by construction rather than by
//! synchronization: tasks partition the output elements (a split never
//! crosses a row, and rows of a sample belong to exactly one task), so
//! no output element is ever combined across tasks, and the row-blocked
//! kernel variants preserve the serial per-element accumulation order
//! inside each task (DESIGN.md §9). There is no cross-task reduction to
//! order in the first place.
//!
//! [`Executor`]: super::Executor

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, LockResult, Mutex, MutexGuard, PoisonError};
use std::thread::JoinHandle;

use super::{BatchedSpmm, KernelVariant, Rhs};

/// How a dispatch is decomposed across the pool's workers.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum SchedPolicy {
    /// Always the legacy contiguous sample split: at most one task per
    /// worker, no row blocks, no stealing. The pre-pool executor
    /// behavior, kept as the bench baseline.
    Static,
    /// Adaptive: uniform batches take the static split, skewed batches
    /// (and batches with fewer samples than workers) are decomposed by
    /// the nnz cost model into finer (sample, row-block) tasks that
    /// workers steal from each other.
    #[default]
    WorkStealing,
}

/// Cumulative scheduling counters for one pool (monotonic; read deltas
/// around a region of interest). `spawned_threads` is set at
/// construction and never changes afterwards — the "zero spawns after
/// pool construction" contract the accounting tests pin.
#[derive(Clone, Copy, Debug, Default)]
pub struct PoolStats {
    /// Worker slots, including the dispatching caller.
    pub workers: usize,
    /// OS threads spawned at construction (`workers - 1`).
    pub spawned_threads: u64,
    /// Engine dispatches executed by this pool.
    pub dispatches: u64,
    /// Dispatches that ran on the static path (serial, or at most one
    /// task per worker — no steal scanning).
    pub static_dispatches: u64,
    /// Dispatches that ran with steal scanning enabled.
    pub stealing_dispatches: u64,
    /// Tasks produced by the planner across all dispatches.
    pub tasks: u64,
    /// Tasks executed by a worker other than their assigned owner.
    pub steals: u64,
}

/// One unit of dispatch work: samples `s0..s1` of the batch. A
/// multi-sample task always covers every output row; a single-sample
/// task (`s1 == s0 + 1`) may cover the sub-range `row0..row1` of the
/// output rows, which is how one dominant sample is split across
/// workers.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Task {
    pub s0: u32,
    pub s1: u32,
    pub row0: u32,
    pub row1: u32,
}

impl Task {
    fn full(s0: usize, s1: usize, out_rows: usize) -> Task {
        Task {
            s0: s0 as u32,
            s1: s1 as u32,
            row0: 0,
            row1: out_rows as u32,
        }
    }
}

/// Decompose one dispatch into tasks.
///
/// `costs[s]` is the relative cost of sample `s` (nnz plus a row term),
/// `out_rows` the per-sample output row count of this dispatch
/// (`inner_dim` for transpose dispatches). Uniform batches (max cost at
/// most twice the mean) with at least `workers` samples keep the legacy
/// contiguous count split — at most one task per worker, so the caller
/// runs them without steal scanning and the fast path of the pre-pool
/// executor survives unchanged. Everything else is chunked to
/// near-equal cost at finer granularity (4 tasks per worker on skewed
/// batches), splitting any sample whose cost exceeds the chunk target
/// into row blocks.
pub fn plan_tasks(
    costs: &[u64],
    out_rows: usize,
    workers: usize,
    policy: SchedPolicy,
) -> Vec<Task> {
    plan_tasks_with(costs, out_rows, workers, policy, &|_, _, _| None)
}

/// [`plan_tasks`] with a per-sample row-range nnz oracle
/// (`row_nnz(s, r0, r1)` = real non-zeros of sample `s` in output rows
/// `r0..r1`, O(1) on CSR via [`BatchedSpmm::rows_nnz`]). When the
/// oracle answers, dominant samples are row-split at *nnz-balanced*
/// boundaries instead of equal row counts — the degree-bucketed task
/// shaping that keeps power-law graphs load-balanced (Accel-GCN's
/// degree-aware warp allocation as task sizing, DESIGN.md §12). Blocks
/// stay contiguous row-range partitions, so the split is bit-identical
/// to any other by the §9 argument; only the balance changes.
pub fn plan_tasks_with(
    costs: &[u64],
    out_rows: usize,
    workers: usize,
    policy: SchedPolicy,
    row_nnz: &dyn Fn(usize, usize, usize) -> Option<usize>,
) -> Vec<Task> {
    let mut tasks = Vec::new();
    plan_tasks_into(costs, out_rows, workers, policy, row_nnz, &mut tasks);
    tasks
}

/// Boundaries of `k` contiguous row blocks over `0..out_rows` with
/// near-equal non-zero mass: returns `k + 1` strictly increasing cuts
/// starting at 0 and ending at `out_rows`. `cum_nnz(r)` is the non-zero
/// count of rows `0..r` (monotone; CSR answers it in O(1)). Cut `i` is
/// binary-searched to where the cumulative mass crosses `i/k` of the
/// total, then snapped to whichever neighboring row lands closer to
/// that target — so a power-law hub's heavy head ends up in narrow
/// blocks and the long sparse tail in wide ones, and no block exceeds
/// its fair share by more than one (indivisible) row's mass. Every
/// block keeps at least one row, which bounds the search window and
/// guarantees the partition regardless of how degenerate the profile
/// is (all mass in one row, trailing empty rows, ...).
pub fn balanced_row_cuts(
    k: usize,
    out_rows: usize,
    cum_nnz: &dyn Fn(usize) -> usize,
) -> Vec<usize> {
    let k = k.clamp(1, out_rows.max(1));
    let total = cum_nnz(out_rows) as u64;
    let kk = k as u64;
    let mut cuts = Vec::with_capacity(k + 1);
    cuts.push(0usize);
    let mut prev = 0usize;
    for i in 1..k {
        // Scaled target: cut where k * cum crosses i * total (exact
        // integer arithmetic; cum * k stays far below u64 range).
        let want = i as u64 * total;
        // Smallest r in [prev + 1, out_rows - (k - i)] with
        // k * cum_nnz(r) >= want; the upper clamp reserves one row for
        // each remaining block.
        let mut lo = prev + 1;
        let mut hi = out_rows - (k - i);
        while lo < hi {
            let mid = lo + (hi - lo) / 2;
            if cum_nnz(mid) as u64 * kk >= want {
                hi = mid;
            } else {
                lo = mid + 1;
            }
        }
        // Snap to the nearer side of the crossing (ties to the smaller
        // row, keeping heavy rows out of the earlier block).
        let here = cum_nnz(lo) as u64 * kk;
        if lo > prev + 1 && here > want {
            let before = cum_nnz(lo - 1) as u64 * kk;
            if want - before <= here - want {
                lo -= 1;
            }
        }
        cuts.push(lo);
        prev = lo;
    }
    cuts.push(out_rows);
    cuts
}

/// [`plan_tasks_with`] writing into a caller-held buffer — the pool
/// reuses one task vector across dispatches (under the dispatch lock)
/// so steady-state dispatches allocate no scheduling metadata.
fn plan_tasks_into(
    costs: &[u64],
    out_rows: usize,
    workers: usize,
    policy: SchedPolicy,
    row_nnz: &dyn Fn(usize, usize, usize) -> Option<usize>,
    tasks: &mut Vec<Task>,
) {
    tasks.clear();
    let b = costs.len();
    if b == 0 || out_rows == 0 {
        return;
    }
    let t = workers.max(1);
    let total: u64 = costs.iter().sum();
    let maxc = costs.iter().copied().max().unwrap_or(0);
    let uniform = maxc.saturating_mul(b as u64) <= 2 * total;
    if policy == SchedPolicy::Static || (uniform && b >= t) {
        static_split_into(b, out_rows, t, tasks);
        return;
    }
    let parts = (t * if uniform { 1 } else { 4 }) as u64;
    let target = total.div_ceil(parts).max(1);
    let mut open = 0usize; // start of the currently accumulating chunk
    let mut acc = 0u64;
    for s in 0..b {
        let c = costs[s];
        if c > target && out_rows > 1 {
            if s > open {
                tasks.push(Task::full(open, s, out_rows));
            }
            // Row-split the dominant sample into near-equal blocks.
            // The block count is capped at the worker count: blocks of
            // one sample are cost-uniform under the model (finer
            // granularity adds no balancing power), and the
            // scatter-shaped kernels rescan the sample's non-zeros per
            // block, so every extra block is a full extra scan.
            let k = (c.div_ceil(target) as usize).min(out_rows).min(t);
            // Degree-bucketed boundaries (DESIGN.md §12): when the
            // kernel can answer row-range nnz queries in O(1), place
            // the cuts where the non-zero mass divides evenly instead
            // of where the row count does — on a power-law giant graph
            // the equal-row split hands one worker all the hubs.
            let balanced = row_nnz(s, 0, out_rows).filter(|&tot| tot > 0 && k > 1);
            match balanced {
                Some(_) => {
                    let cum = |r: usize| row_nnz(s, 0, r).unwrap_or(0);
                    let cuts = balanced_row_cuts(k, out_rows, &cum);
                    for w in cuts.windows(2) {
                        tasks.push(Task {
                            s0: s as u32,
                            s1: (s + 1) as u32,
                            row0: w[0] as u32,
                            row1: w[1] as u32,
                        });
                    }
                }
                None => {
                    for i in 0..k {
                        tasks.push(Task {
                            s0: s as u32,
                            s1: (s + 1) as u32,
                            row0: (i * out_rows / k) as u32,
                            row1: ((i + 1) * out_rows / k) as u32,
                        });
                    }
                }
            }
            open = s + 1;
            acc = 0;
        } else {
            if acc > 0 && acc + c > target {
                tasks.push(Task::full(open, s, out_rows));
                open = s;
                acc = 0;
            }
            acc += c;
        }
    }
    if b > open {
        tasks.push(Task::full(open, b, out_rows));
    }
}

/// The legacy contiguous count split: at most one full-row task per
/// worker, samples in order — exactly the partition the pre-pool
/// executor used. Depends only on the batch size, so the static paths
/// call it without computing costs.
fn static_split_into(b: usize, out_rows: usize, workers: usize, tasks: &mut Vec<Task>) {
    tasks.clear();
    let chunk = b.div_ceil(workers.max(1));
    tasks.extend((0..b).step_by(chunk).map(|s0| Task::full(s0, (s0 + chunk).min(b), out_rows)));
}

/// Per-sample planner costs for a dispatch: nnz plus a row term (the
/// padded-row scan every kernel pays) plus one. This is deliberately an
/// approximation — ST/ELL padding slots and row-concentrated nnz are
/// invisible to it — and stealing is what absorbs the error.
/// `sample_nnz` is O(1) on every packed batch format (counts are cached
/// at pack time, DESIGN.md §10), so this whole scan is O(batch) per
/// dispatch, into a reused buffer.
fn sample_costs_into(kernel: &dyn BatchedSpmm, out_rows: usize, costs: &mut Vec<u64>) {
    costs.clear();
    costs.extend((0..kernel.batch()).map(|b| kernel.sample_nnz(b) as u64 + out_rows as u64 + 1));
}

/// Lock, recovering from poisoning: a panicking worker is already
/// reported through `Slot::panicked` (and re-raised by the dispatcher),
/// and no pool invariant spans a poisoned critical section, so later
/// dispatches must not die with an opaque `PoisonError` on top.
fn lock_pool<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// [`lock_pool`]'s twin for condvar waits.
fn unpoison<T>(r: LockResult<MutexGuard<'_, T>>) -> MutexGuard<'_, T> {
    r.unwrap_or_else(PoisonError::into_inner)
}

// ---------------------------------------------------------------------
// Pool internals
// ---------------------------------------------------------------------

/// Owner-indexed slice of the task list. `next` is claimed with
/// `fetch_add` by the owner and by thieves alike; a claim is final, so
/// every task executes exactly once.
struct Segment {
    next: AtomicUsize,
    end: usize,
}

/// Everything a worker needs to execute one dispatch. Lives on the
/// dispatching thread's stack; workers reach it through a raw pointer
/// that is only valid while the dispatcher blocks in
/// [`WorkerPool::run_dispatch`].
struct Job<'a> {
    kernel: &'a dyn BatchedSpmm,
    rhs: Rhs<'a>,
    n: usize,
    /// Rows of the rhs operand (`inner` of the dispatch).
    inner: usize,
    out_rows: usize,
    per_out: usize,
    transpose: bool,
    /// Which inner-loop implementation the tasks run (bit-identical
    /// either way; DESIGN.md §10).
    variant: KernelVariant,
    out: *mut f32,
    tasks: &'a [Task],
    segs: &'a [Segment],
    /// Scan other segments after draining your own.
    steal: bool,
}

/// Lifetime-erased pointer to the active [`Job`], published under the
/// pool mutex. Safety: the dispatcher keeps the pointee alive until
/// every worker has decremented `active` back to zero.
#[derive(Clone, Copy)]
struct JobPtr(*const ());

unsafe impl Send for JobPtr {}

struct Slot {
    epoch: u64,
    job: Option<JobPtr>,
    /// Spawned workers still inside the current epoch's job.
    active: usize,
    /// A worker panicked while executing the current job.
    panicked: bool,
    shutdown: bool,
}

struct Shared {
    slot: Mutex<Slot>,
    work_cv: Condvar,
    done_cv: Condvar,
    steals: AtomicU64,
}

/// Per-pool dispatch scratch — the cost vector, task plan and worker
/// segments of the *current* dispatch, reused across dispatches under
/// the dispatch lock so steady-state dispatches allocate no scheduling
/// metadata (the plan-layer counterpart of the `Workspace` arena,
/// DESIGN.md §11).
#[derive(Default)]
struct Scratch {
    costs: Vec<u64>,
    tasks: Vec<Task>,
    segs: Vec<Segment>,
}

/// A persistent pool of `workers` execution slots: `workers - 1` parked
/// OS threads plus the dispatching caller, who participates as worker
/// 0. Construction is the only place threads are spawned; dispatches
/// wake the workers, run one job, and park them again. Clone the
/// owning [`Executor`](super::Executor) (an `Arc` handle) to share one
/// pool across the engine, trainer and serving hot paths.
pub struct WorkerPool {
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<()>>,
    workers: usize,
    policy: SchedPolicy,
    variant: KernelVariant,
    /// Serializes dispatches (the pool runs one job at a time) and
    /// guards the reusable dispatch scratch.
    dispatch_lock: Mutex<Scratch>,
    dispatches: AtomicU64,
    static_dispatches: AtomicU64,
    stealing_dispatches: AtomicU64,
    tasks: AtomicU64,
}

impl WorkerPool {
    /// A pool with `workers` total slots (clamped to at least 1) and
    /// the given scheduling policy, running the default vectorized
    /// kernels. Spawns `workers - 1` threads — the last spawn this pool
    /// will ever perform.
    pub fn new(workers: usize, policy: SchedPolicy) -> WorkerPool {
        WorkerPool::with_variant(workers, policy, KernelVariant::default())
    }

    /// [`WorkerPool::new`] with an explicit kernel variant:
    /// [`KernelVariant::Scalar`] pins the pre-vectorization inner loops
    /// (the parity oracle and bench baseline, DESIGN.md §10). Both
    /// variants produce bit-identical output.
    pub fn with_variant(
        workers: usize,
        policy: SchedPolicy,
        variant: KernelVariant,
    ) -> WorkerPool {
        let workers = workers.max(1);
        let shared = Arc::new(Shared {
            slot: Mutex::new(Slot {
                epoch: 0,
                job: None,
                active: 0,
                panicked: false,
                shutdown: false,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
            steals: AtomicU64::new(0),
        });
        let handles = (1..workers)
            .map(|me| {
                let sh = shared.clone();
                std::thread::Builder::new()
                    .name(format!("bspmm-worker-{me}"))
                    .spawn(move || worker_loop(&sh, me))
                    .expect("spawn pool worker")
            })
            .collect();
        WorkerPool {
            shared,
            handles,
            workers,
            policy,
            variant,
            dispatch_lock: Mutex::new(Scratch::default()),
            dispatches: AtomicU64::new(0),
            static_dispatches: AtomicU64::new(0),
            stealing_dispatches: AtomicU64::new(0),
            tasks: AtomicU64::new(0),
        }
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    pub fn policy(&self) -> SchedPolicy {
        self.policy
    }

    pub fn variant(&self) -> KernelVariant {
        self.variant
    }

    /// Snapshot of the cumulative scheduling counters.
    pub fn stats(&self) -> PoolStats {
        PoolStats {
            workers: self.workers,
            spawned_threads: self.handles.len() as u64,
            dispatches: self.dispatches.load(Ordering::Relaxed),
            static_dispatches: self.static_dispatches.load(Ordering::Relaxed),
            stealing_dispatches: self.stealing_dispatches.load(Ordering::Relaxed),
            tasks: self.tasks.load(Ordering::Relaxed),
            steals: self.shared.steals.load(Ordering::Relaxed),
        }
    }

    /// Execute one validated, normalized dispatch (`rhs` must not be
    /// [`Rhs::SharedTransposed`]; the executor materializes that form
    /// first). `out` is `[batch, out_rows, n]`, pre-filled by the
    /// caller per the engine's `+=` contract.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn run_dispatch(
        &self,
        kernel: &dyn BatchedSpmm,
        rhs: Rhs<'_>,
        n: usize,
        inner: usize,
        out_rows: usize,
        transpose: bool,
        out: &mut [f32],
    ) {
        let b = kernel.batch();
        let per_out = out_rows * n;
        self.dispatches.fetch_add(1, Ordering::Relaxed);
        if self.workers == 1 {
            // Serial fast path: no planning scan, no synchronization.
            self.static_dispatches.fetch_add(1, Ordering::Relaxed);
            self.tasks.fetch_add(1, Ordering::Relaxed);
            for s in 0..b {
                let sample_out = &mut out[s * per_out..(s + 1) * per_out];
                let rhs_s = rhs.sample(s, inner, n);
                match (self.variant, transpose) {
                    (KernelVariant::Vectorized, false) => {
                        kernel.spmm_sample(s, rhs_s, n, sample_out)
                    }
                    (KernelVariant::Vectorized, true) => {
                        kernel.spmm_sample_t(s, rhs_s, n, sample_out)
                    }
                    (KernelVariant::Scalar, false) => {
                        kernel.spmm_sample_scalar(s, rhs_s, n, sample_out)
                    }
                    (KernelVariant::Scalar, true) => {
                        kernel.spmm_sample_t_scalar(s, rhs_s, n, sample_out)
                    }
                    (KernelVariant::Tiled, false) => {
                        kernel.spmm_sample_tiled(s, rhs_s, n, sample_out)
                    }
                    // The transpose scatter has its own tiled twin
                    // (bit-identical for any tile width).
                    (KernelVariant::Tiled, true) => {
                        kernel.spmm_sample_t_tiled(s, rhs_s, n, sample_out)
                    }
                    (KernelVariant::Simd, false) => {
                        kernel.spmm_sample_simd(s, rhs_s, n, sample_out)
                    }
                    (KernelVariant::Simd, true) => {
                        kernel.spmm_sample_t_simd(s, rhs_s, n, sample_out)
                    }
                }
            }
            return;
        }
        // The dispatch lock serializes jobs *and* hands out the reused
        // scheduling scratch: plans, costs and segments live in
        // pool-owned buffers, so a steady-state dispatch performs no
        // heap allocation here either.
        let mut scratch = lock_pool(&self.dispatch_lock);
        let Scratch { costs, tasks, segs } = &mut *scratch;
        if self.policy == SchedPolicy::Static {
            // The static split only counts samples — skip the
            // O(batch) cost scan it would never read.
            static_split_into(b, out_rows, self.workers, tasks);
        } else {
            sample_costs_into(kernel, out_rows, costs);
            // Row-range nnz oracle for degree-bucketed row splits.
            // `rows_nnz` describes the kernel's forward output rows, so
            // transpose dispatches (out rows = A's columns) plan with
            // the equal-row fallback.
            let oracle = |s: usize, r0: usize, r1: usize| {
                if transpose {
                    None
                } else {
                    kernel.rows_nnz(s, r0, r1)
                }
            };
            plan_tasks_into(costs, out_rows, self.workers, self.policy, &oracle, tasks);
        }
        let ntasks = tasks.len();
        self.tasks.fetch_add(ntasks as u64, Ordering::Relaxed);
        let steal = ntasks > self.workers;
        segs.clear();
        segs.extend((0..self.workers).map(|w| Segment {
            next: AtomicUsize::new(w * ntasks / self.workers),
            end: (w + 1) * ntasks / self.workers,
        }));
        let job = Job {
            kernel,
            rhs,
            n,
            inner,
            out_rows,
            per_out,
            transpose,
            variant: self.variant,
            out: out.as_mut_ptr(),
            tasks: tasks.as_slice(),
            segs: segs.as_slice(),
            steal,
        };
        if ntasks <= 1 {
            // Not worth waking anyone: run inline on the caller.
            self.static_dispatches.fetch_add(1, Ordering::Relaxed);
            for task in job.tasks {
                exec_task(&job, task);
            }
            return;
        }
        if steal {
            self.stealing_dispatches.fetch_add(1, Ordering::Relaxed);
        } else {
            self.static_dispatches.fetch_add(1, Ordering::Relaxed);
        }

        {
            let mut g = lock_pool(&self.shared.slot);
            debug_assert_eq!(g.active, 0, "previous job still active");
            g.epoch += 1;
            g.job = Some(JobPtr(&job as *const Job as *const ()));
            g.active = self.handles.len();
            g.panicked = false;
        }
        self.shared.work_cv.notify_all();
        // The caller is worker 0.
        let caller_panic = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            run_job(&job, 0, &self.shared)
        }))
        .is_err();
        let panicked = {
            let mut g = lock_pool(&self.shared.slot);
            while g.active != 0 {
                g = unpoison(self.shared.done_cv.wait(g));
            }
            // The job (and its borrows of kernel/rhs/out/tasks) must not
            // outlive this frame: unpublish before returning.
            g.job = None;
            g.panicked
        };
        if caller_panic || panicked {
            panic!("engine worker panicked during a pool dispatch");
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut g = lock_pool(&self.shared.slot);
            g.shutdown = true;
        }
        self.shared.work_cv.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl std::fmt::Debug for WorkerPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("WorkerPool")
            .field("workers", &self.workers)
            .field("policy", &self.policy)
            .field("variant", &self.variant)
            .finish()
    }
}

/// Body of each spawned worker thread: park on the condvar, run each
/// published job to completion, report back, park again.
fn worker_loop(shared: &Shared, me: usize) {
    let mut seen = 0u64;
    loop {
        let ptr = {
            let mut g = lock_pool(&shared.slot);
            loop {
                if g.shutdown {
                    return;
                }
                if g.epoch != seen {
                    break;
                }
                g = unpoison(shared.work_cv.wait(g));
            }
            seen = g.epoch;
            g.job.expect("epoch advanced without a job")
        };
        // Safety: the dispatcher keeps the Job alive (and `out`
        // exclusively borrowed) until `active` drops back to zero,
        // which only happens after this call returns.
        let job: &Job = unsafe { &*(ptr.0 as *const Job) };
        let panicked = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            run_job(job, me, shared)
        }))
        .is_err();
        let mut g = lock_pool(&shared.slot);
        g.active -= 1;
        g.panicked |= panicked;
        if g.active == 0 {
            shared.done_cv.notify_all();
        }
    }
}

/// One worker's share of a job: drain the own segment, then (in
/// stealing mode) scan the other segments in cyclic order and steal
/// whatever is left. Claims are `fetch_add`s, so a task runs exactly
/// once no matter who claims it; after a worker has seen every segment
/// drained it can exit — segments never grow, and the dispatcher waits
/// for claimed tasks to finish via the `active` count.
fn run_job(job: &Job, me: usize, shared: &Shared) {
    let nseg = job.segs.len();
    let mut stolen = 0u64;
    let rounds = if job.steal { nseg } else { 1 };
    for off in 0..rounds {
        let v = (me + off) % nseg;
        let seg = &job.segs[v];
        loop {
            let i = seg.next.fetch_add(1, Ordering::Relaxed);
            if i >= seg.end {
                break;
            }
            exec_task(job, &job.tasks[i]);
            if v != me {
                stolen += 1;
            }
        }
    }
    if stolen > 0 {
        shared.steals.fetch_add(stolen, Ordering::Relaxed);
    }
}

/// Execute one task. Safety of the raw output pointer: tasks partition
/// the `[batch, out_rows, n]` output (disjoint (sample, row) ranges by
/// construction in [`plan_tasks`]) and each task is claimed exactly
/// once, so no two threads ever touch the same element.
fn exec_task(job: &Job, task: &Task) {
    use KernelVariant::{Scalar, Simd, Tiled, Vectorized};
    let n = job.n;
    let full = task.row0 == 0 && task.row1 as usize == job.out_rows;
    let row0 = task.row0 as usize;
    let rows = (task.row1 - task.row0) as usize;
    for s in task.s0..task.s1 {
        let s = s as usize;
        let off = s * job.per_out + row0 * n;
        let out = unsafe { std::slice::from_raw_parts_mut(job.out.add(off), rows * n) };
        let rhs = job.rhs.sample(s, job.inner, n);
        match (job.variant, job.transpose, full) {
            (Vectorized, false, true) => job.kernel.spmm_sample(s, rhs, n, out),
            (Vectorized, false, false) => job.kernel.spmm_sample_rows(s, row0, rhs, n, out),
            (Vectorized, true, true) => job.kernel.spmm_sample_t(s, rhs, n, out),
            (Vectorized, true, false) => job.kernel.spmm_sample_t_rows(s, row0, rhs, n, out),
            (Scalar, false, true) => job.kernel.spmm_sample_scalar(s, rhs, n, out),
            (Scalar, false, false) => job.kernel.spmm_sample_rows_scalar(s, row0, rhs, n, out),
            (Scalar, true, true) => job.kernel.spmm_sample_t_scalar(s, rhs, n, out),
            (Scalar, true, false) => job.kernel.spmm_sample_t_rows_scalar(s, row0, rhs, n, out),
            (Tiled, false, true) => job.kernel.spmm_sample_tiled(s, rhs, n, out),
            (Tiled, false, false) => job.kernel.spmm_sample_rows_tiled(s, row0, rhs, n, out),
            (Tiled, true, true) => job.kernel.spmm_sample_t_tiled(s, rhs, n, out),
            (Tiled, true, false) => job.kernel.spmm_sample_t_rows_tiled(s, row0, rhs, n, out),
            (Simd, false, true) => job.kernel.spmm_sample_simd(s, rhs, n, out),
            (Simd, false, false) => job.kernel.spmm_sample_rows_simd(s, row0, rhs, n, out),
            (Simd, true, true) => job.kernel.spmm_sample_t_simd(s, rhs, n, out),
            (Simd, true, false) => job.kernel.spmm_sample_t_rows_simd(s, row0, rhs, n, out),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Every (sample, row) output cell must be covered by exactly one
    /// task, for any cost profile.
    fn assert_partition(tasks: &[Task], b: usize, out_rows: usize) {
        let mut hits = vec![0u32; b * out_rows];
        for t in tasks {
            assert!(t.s1 > t.s0 && t.row1 > t.row0, "empty task {t:?}");
            if t.s1 - t.s0 > 1 {
                assert_eq!((t.row0, t.row1 as usize), (0, out_rows), "{t:?}");
            }
            for s in t.s0..t.s1 {
                for r in t.row0..t.row1 {
                    hits[s as usize * out_rows + r as usize] += 1;
                }
            }
        }
        assert!(hits.iter().all(|&h| h == 1), "coverage {hits:?}");
    }

    #[test]
    fn uniform_batch_keeps_legacy_contiguous_split() {
        let costs = vec![10u64; 64];
        let tasks = plan_tasks(&costs, 24, 8, SchedPolicy::WorkStealing);
        assert_eq!(tasks.len(), 8);
        for (w, t) in tasks.iter().enumerate() {
            assert_eq!((t.s0 as usize, t.s1 as usize), (w * 8, w * 8 + 8));
            assert_eq!((t.row0, t.row1), (0, 24));
        }
        assert_partition(&tasks, 64, 24);
    }

    #[test]
    fn static_policy_never_row_splits() {
        let mut costs = vec![1u64; 8];
        costs[0] = 1000;
        let tasks = plan_tasks(&costs, 16, 4, SchedPolicy::Static);
        assert_eq!(tasks.len(), 4);
        assert!(tasks.iter().all(|t| t.row0 == 0 && t.row1 == 16));
        assert_partition(&tasks, 8, 16);
    }

    #[test]
    fn dominant_sample_is_row_split() {
        let mut costs = vec![2u64; 16];
        costs[3] = 2000;
        let tasks = plan_tasks(&costs, 32, 4, SchedPolicy::WorkStealing);
        assert!(tasks.len() > 4, "skew must oversubscribe: {}", tasks.len());
        let blocks: Vec<&Task> = tasks.iter().filter(|t| t.s0 == 3 && t.s1 == 4).collect();
        assert!(blocks.len() > 1, "sample 3 not split: {tasks:?}");
        assert_partition(&tasks, 16, 32);
    }

    #[test]
    fn batch_one_splits_rows_across_workers() {
        // The dW shape: one sample, many output rows.
        let tasks = plan_tasks(&[500], 16, 8, SchedPolicy::WorkStealing);
        assert_eq!(tasks.len(), 8);
        assert_partition(&tasks, 1, 16);
    }

    #[test]
    fn single_row_samples_are_never_split() {
        let mut costs = vec![1u64; 6];
        costs[2] = 1000;
        let tasks = plan_tasks(&costs, 1, 4, SchedPolicy::WorkStealing);
        assert!(tasks.iter().all(|t| t.row0 == 0 && t.row1 == 1));
        assert_partition(&tasks, 6, 1);
    }

    #[test]
    fn random_plans_always_partition_the_output() {
        let mut rng = crate::util::rng::Rng::new(0x9E57);
        for _ in 0..200 {
            let b = rng.range(1, 20);
            let out_rows = rng.range(1, 40);
            let workers = rng.range(1, 12);
            let costs: Vec<u64> = (0..b)
                .map(|_| {
                    if rng.bool(0.2) {
                        rng.range(1, 5000) as u64
                    } else {
                        rng.range(1, 20) as u64
                    }
                })
                .collect();
            for policy in [SchedPolicy::Static, SchedPolicy::WorkStealing] {
                let tasks = plan_tasks(&costs, out_rows, workers, policy);
                assert_partition(&tasks, b, out_rows);
            }
        }
    }

    #[test]
    fn empty_batch_plans_no_tasks() {
        assert!(plan_tasks(&[], 8, 4, SchedPolicy::WorkStealing).is_empty());
        assert!(plan_tasks(&[5], 0, 4, SchedPolicy::WorkStealing).is_empty());
    }

    /// A power-law per-row nnz profile: row degrees ~ heavy-tailed with
    /// a handful of hubs, the Barabási–Albert shape the large-graph
    /// tier dispatches (DESIGN.md §12).
    fn power_law_rows(rng: &mut crate::util::rng::Rng, rows: usize) -> Vec<usize> {
        (0..rows)
            .map(|_| {
                if rng.bool(0.03) {
                    rng.range(200, 2000) // hub
                } else {
                    rng.range(0, 8) // tail (empty rows allowed)
                }
            })
            .collect()
    }

    #[test]
    fn balanced_cuts_partition_and_balance_power_law_profiles() {
        let mut rng = crate::util::rng::Rng::new(0xBA1A);
        for case in 0..100 {
            let rows = rng.range(1, 400);
            let k = rng.range(1, 16);
            let deg = power_law_rows(&mut rng, rows);
            let mut cum = vec![0usize; rows + 1];
            for r in 0..rows {
                cum[r + 1] = cum[r] + deg[r];
            }
            let total = cum[rows];
            let cuts = balanced_row_cuts(k, rows, &|r| cum[r]);
            // Strictly increasing boundaries from 0 to rows: a
            // contiguous partition with no empty block.
            assert_eq!(*cuts.first().unwrap(), 0, "case {case}");
            assert_eq!(*cuts.last().unwrap(), rows, "case {case}");
            assert!(cuts.windows(2).all(|w| w[0] < w[1]), "case {case}: {cuts:?}");
            assert_eq!(cuts.len() - 1, k.min(rows), "case {case}");
            // Balance: no block exceeds its fair share by more than the
            // largest single row (a row is indivisible).
            let maxrow = deg.iter().copied().max().unwrap_or(0);
            let keff = (cuts.len() - 1) as usize;
            for w in cuts.windows(2) {
                let mass = cum[w[1]] - cum[w[0]];
                assert!(
                    mass <= total.div_ceil(keff) + maxrow,
                    "case {case}: block {w:?} mass {mass} vs total {total} / k {keff}"
                );
            }
        }
    }

    #[test]
    fn degree_bucketed_plans_partition_and_bound_block_mass() {
        // plan_tasks_with + a power-law oracle must (a) still partition
        // the output exactly, and (b) bound every row block's non-zero
        // mass by its fair share plus one indivisible row.
        let mut rng = crate::util::rng::Rng::new(0xACCE1);
        for case in 0..60 {
            let rows = rng.range(32, 300);
            let workers = rng.range(2, 12);
            let deg = power_law_rows(&mut rng, rows);
            let mut cum = vec![0u64; rows + 1];
            for r in 0..rows {
                cum[r + 1] = cum[r] + deg[r] as u64;
            }
            let total = cum[rows] as usize;
            // Batch of one giant sample — the large-graph dispatch shape.
            let costs = vec![total as u64 + rows as u64 + 1];
            let oracle = |s: usize, r0: usize, r1: usize| {
                assert_eq!(s, 0);
                Some((cum[r1] - cum[r0]) as usize)
            };
            let bucketed = plan_tasks_with(
                &costs,
                rows,
                workers,
                SchedPolicy::WorkStealing,
                &oracle,
            );
            assert_partition(&bucketed, 1, rows);
            let maxrow = deg.iter().copied().max().unwrap_or(0);
            let k = bucketed.len();
            for t in &bucketed {
                let mass = (cum[t.row1 as usize] - cum[t.row0 as usize]) as usize;
                assert!(
                    mass <= total.div_ceil(k) + maxrow,
                    "case {case}: block {t:?} mass {mass}, total {total}, k {k}"
                );
            }
        }
    }

    #[test]
    fn degree_bucketed_split_isolates_a_front_hub() {
        // The shape the equal-row fallback handles worst: one hub row
        // holding ~all the mass at the front of a long sparse tail.
        // Equal-row boundaries hand the hub's block a quarter of the
        // remaining rows on top of the hub; nnz-balanced boundaries cut
        // right after the hub.
        let rows = 128usize;
        let workers = 4usize;
        let mut deg = vec![1u64; rows];
        deg[0] = 10_000;
        let mut cum = vec![0u64; rows + 1];
        for r in 0..rows {
            cum[r + 1] = cum[r] + deg[r];
        }
        let total = cum[rows];
        let costs = vec![total + rows as u64 + 1];
        let oracle = |_: usize, r0: usize, r1: usize| Some((cum[r1] - cum[r0]) as usize);
        let bucketed =
            plan_tasks_with(&costs, rows, workers, SchedPolicy::WorkStealing, &oracle);
        assert_partition(&bucketed, 1, rows);
        let fallback = plan_tasks(&costs, rows, workers, SchedPolicy::WorkStealing);
        assert_partition(&fallback, 1, rows);
        let tail_mass = |tasks: &[Task]| {
            // Mass of the hub's block beyond the hub row itself: extra
            // work serialized behind the heaviest row.
            tasks
                .iter()
                .find(|t| t.row0 == 0)
                .map(|t| (cum[t.row1 as usize] - cum[1]) as usize)
                .unwrap()
        };
        // nnz-balanced boundaries put the cut directly after the hub...
        assert_eq!(tail_mass(&bucketed), 0, "{bucketed:?}");
        // ...while equal-row boundaries serialize a full share of the
        // tail behind it.
        assert!(tail_mass(&fallback) >= (rows - 1) / workers - 1, "{fallback:?}");
    }

    #[test]
    fn oracle_plans_still_partition_on_random_mixed_batches() {
        // The full planner with an oracle over multi-sample skewed
        // batches: partition must hold for any profile, worker count
        // and policy (transpose dispatches pass no oracle, so plain
        // plan_tasks covers that side).
        let mut rng = crate::util::rng::Rng::new(0x0DD);
        for _ in 0..120 {
            let b = rng.range(1, 16);
            let out_rows = rng.range(1, 120);
            let workers = rng.range(1, 10);
            let rowdeg: Vec<Vec<usize>> = (0..b)
                .map(|_| power_law_rows(&mut rng, out_rows))
                .collect();
            let cums: Vec<Vec<usize>> = rowdeg
                .iter()
                .map(|deg| {
                    let mut cum = vec![0usize; out_rows + 1];
                    for r in 0..out_rows {
                        cum[r + 1] = cum[r] + deg[r];
                    }
                    cum
                })
                .collect();
            let costs: Vec<u64> = cums
                .iter()
                .map(|cum| cum[out_rows] as u64 + out_rows as u64 + 1)
                .collect();
            let oracle =
                |s: usize, r0: usize, r1: usize| Some(cums[s][r1] - cums[s][r0]);
            for policy in [SchedPolicy::Static, SchedPolicy::WorkStealing] {
                let tasks = plan_tasks_with(&costs, out_rows, workers, policy, &oracle);
                assert_partition(&tasks, b, out_rows);
            }
        }
    }
}
