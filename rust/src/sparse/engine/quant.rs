//! Dequantize-on-the-fly ELL kernels for the reduced-precision
//! inference path (DESIGN.md §16).
//!
//! [`QuantEllKernel`] is the quantized twin of
//! [`EllKernel`](super::kernels::EllKernel): it walks the same
//! `[planes, rows, width]` ELL layout, but reads its values from a
//! [`QuantizedEllBatch`](crate::sparse::batch::QuantizedEllBatch)
//! (bf16 or int8, [`DType`]) and dequantizes each value in the
//! register, just before the same `axpy_row` primitives the f32
//! kernels run. Nothing else changes: the output stays f32, the
//! accumulation order is identical to the f32 ELL kernel's, and the
//! engine's whole dispatch surface (serial, pooled, row-blocked,
//! transpose, every [`KernelVariant`](super::KernelVariant)) works
//! unchanged because the kernel implements the full
//! [`BatchedSpmm`] contract, `_scalar` and `_simd` twins included.
//!
//! The padding contract carries over exactly: quantized padding slots
//! dequantize to exactly `0.0` (bf16 packs padding as bits `0`; int8
//! packs it as the zero point), so the `val == 0.0` skip — and for
//! int8 the cheaper `q == zero_point` pre-dequant skip — fires just
//! like in the f32 kernels, and the pack-time `nnz_per_plane` counts
//! keep the cost model O(1).

use super::kernels::{axpy_row, axpy_row_simd};
use super::{BatchedSpmm, DType};
use crate::sparse::batch::{bf16_to_f32, QuantizedEllBatch};

/// Strided view over a [`QuantizedEllBatch`]: sample `b` of the view
/// reads plane `plane0 + b * plane_stride` — the same channel-view
/// shape as the f32 `EllKernel`, so a `[B, CH]` plane grid packs once
/// and serves one kernel per channel.
pub struct QuantEllKernel<'a> {
    q: &'a QuantizedEllBatch,
    batch: usize,
    plane0: usize,
    plane_stride: usize,
}

impl<'a> QuantEllKernel<'a> {
    /// Contiguous view: one sample per plane.
    pub fn from_batch(q: &'a QuantizedEllBatch) -> QuantEllKernel<'a> {
        QuantEllKernel {
            q,
            batch: q.planes,
            plane0: 0,
            plane_stride: 1,
        }
    }

    /// View of one adjacency channel of a `[B, CH]` plane grid (the
    /// quantized twin of `EllKernel::channel`): sample `b` reads plane
    /// `b * channels + ch`.
    pub fn channel(q: &'a QuantizedEllBatch, ch: usize, channels: usize) -> QuantEllKernel<'a> {
        assert!(channels > 0 && ch < channels, "channel {ch} out of {channels}");
        assert_eq!(
            q.planes % channels,
            0,
            "{} planes do not split into {channels} channels",
            q.planes
        );
        QuantEllKernel {
            q,
            batch: q.planes / channels,
            plane0: ch,
            plane_stride: channels,
        }
    }

    /// The precision this kernel dequantizes from.
    pub fn dtype(&self) -> DType {
        self.q.dtype
    }

    /// Quantized value bytes one full dispatch of this view reads —
    /// the bytes-moved numerator the precision bench reports.
    pub fn dispatch_value_bytes(&self) -> usize {
        self.batch * self.q.rows * self.q.width * self.q.dtype.value_bytes()
    }

    #[inline]
    fn plane(&self, b: usize) -> usize {
        self.plane0 + b * self.plane_stride
    }

    /// Walk the real (non-padding) slots of rows `row0..row1` of sample
    /// `b`, dequantizing each value once, in the same row-major
    /// slot order as the f32 ELL kernel — the single traversal every
    /// dispatch form below is a closure over, so the accumulation
    /// order (and hence bit-identity across variants) is fixed in one
    /// place.
    #[inline]
    fn for_each_nz<F: FnMut(usize, usize, f32)>(
        &self,
        b: usize,
        row0: usize,
        row1: usize,
        mut f: F,
    ) {
        let p = self.plane(b);
        let r = self.q.width;
        let base = p * self.q.rows * r;
        match self.q.dtype {
            DType::F32 => unreachable!("quantized batch never holds f32"),
            DType::Bf16 => {
                for rid in row0..row1 {
                    for slot in 0..r {
                        let val = bf16_to_f32(self.q.vals_bf16[base + rid * r + slot]);
                        if val == 0.0 {
                            continue; // padding slot
                        }
                        let cid = self.q.cols[base + rid * r + slot] as usize;
                        f(rid, cid, val);
                    }
                }
            }
            DType::Int8 => {
                let scale = self.q.scale[p];
                let zp = self.q.zero_point[p] as i32;
                for rid in row0..row1 {
                    for slot in 0..r {
                        let qv = self.q.vals_i8[base + rid * r + slot] as i32;
                        if qv == zp {
                            continue; // padding (or a value on the zero point)
                        }
                        let cid = self.q.cols[base + rid * r + slot] as usize;
                        f(rid, cid, scale * (qv - zp) as f32);
                    }
                }
            }
        }
    }
}

impl BatchedSpmm for QuantEllKernel<'_> {
    fn name(&self) -> &'static str {
        match self.q.dtype {
            DType::F32 => "engine-quant-ell",
            DType::Bf16 => "engine-ell-bf16",
            DType::Int8 => "engine-ell-int8",
        }
    }

    fn batch(&self) -> usize {
        self.batch
    }

    fn out_rows(&self) -> usize {
        self.q.rows
    }

    fn inner_dim(&self) -> usize {
        self.q.rows
    }

    fn real_nnz(&self) -> usize {
        (0..self.batch)
            .map(|b| self.q.nnz_per_plane[self.plane(b)] as usize)
            .sum()
    }

    fn sample_nnz(&self, b: usize) -> usize {
        // O(1): counted once at quantization time (DESIGN.md §10).
        self.q.nnz_per_plane[self.plane(b)] as usize
    }

    fn spmm_sample(&self, b: usize, rhs: &[f32], n: usize, out: &mut [f32]) {
        self.for_each_nz(b, 0, self.q.rows, |rid, cid, val| {
            axpy_row(&mut out[rid * n..(rid + 1) * n], val, &rhs[cid * n..(cid + 1) * n]);
        });
    }

    fn spmm_sample_t(&self, b: usize, rhs: &[f32], n: usize, out: &mut [f32]) {
        self.for_each_nz(b, 0, self.q.rows, |rid, cid, val| {
            axpy_row(&mut out[cid * n..(cid + 1) * n], val, &rhs[rid * n..(rid + 1) * n]);
        });
    }

    fn spmm_sample_rows(&self, b: usize, row0: usize, rhs: &[f32], n: usize, out: &mut [f32]) {
        let row1 = row0 + out.len() / n;
        self.for_each_nz(b, row0, row1, |rid, cid, val| {
            axpy_row(
                &mut out[(rid - row0) * n..(rid - row0 + 1) * n],
                val,
                &rhs[cid * n..(cid + 1) * n],
            );
        });
    }

    fn spmm_sample_t_rows(&self, b: usize, row0: usize, rhs: &[f32], n: usize, out: &mut [f32]) {
        let row1 = row0 + out.len() / n;
        self.for_each_nz(b, 0, self.q.rows, |rid, cid, val| {
            if cid >= row0 && cid < row1 {
                axpy_row(
                    &mut out[(cid - row0) * n..(cid - row0 + 1) * n],
                    val,
                    &rhs[rid * n..(rid + 1) * n],
                );
            }
        });
    }

    fn spmm_sample_scalar(&self, b: usize, rhs: &[f32], n: usize, out: &mut [f32]) {
        self.for_each_nz(b, 0, self.q.rows, |rid, cid, val| {
            let dst = &mut out[rid * n..(rid + 1) * n];
            let src = &rhs[cid * n..(cid + 1) * n];
            for j in 0..n {
                dst[j] += val * src[j];
            }
        });
    }

    fn spmm_sample_t_scalar(&self, b: usize, rhs: &[f32], n: usize, out: &mut [f32]) {
        self.for_each_nz(b, 0, self.q.rows, |rid, cid, val| {
            let dst = &mut out[cid * n..(cid + 1) * n];
            let src = &rhs[rid * n..(rid + 1) * n];
            for j in 0..n {
                dst[j] += val * src[j];
            }
        });
    }

    fn spmm_sample_rows_scalar(
        &self,
        b: usize,
        row0: usize,
        rhs: &[f32],
        n: usize,
        out: &mut [f32],
    ) {
        let row1 = row0 + out.len() / n;
        self.for_each_nz(b, row0, row1, |rid, cid, val| {
            let dst = &mut out[(rid - row0) * n..(rid - row0 + 1) * n];
            let src = &rhs[cid * n..(cid + 1) * n];
            for j in 0..n {
                dst[j] += val * src[j];
            }
        });
    }

    fn spmm_sample_t_rows_scalar(
        &self,
        b: usize,
        row0: usize,
        rhs: &[f32],
        n: usize,
        out: &mut [f32],
    ) {
        let row1 = row0 + out.len() / n;
        self.for_each_nz(b, 0, self.q.rows, |rid, cid, val| {
            if cid >= row0 && cid < row1 {
                let dst = &mut out[(cid - row0) * n..(cid - row0 + 1) * n];
                let src = &rhs[rid * n..(rid + 1) * n];
                for j in 0..n {
                    dst[j] += val * src[j];
                }
            }
        });
    }

    fn spmm_sample_simd(&self, b: usize, rhs: &[f32], n: usize, out: &mut [f32]) {
        self.for_each_nz(b, 0, self.q.rows, |rid, cid, val| {
            axpy_row_simd(&mut out[rid * n..(rid + 1) * n], val, &rhs[cid * n..(cid + 1) * n]);
        });
    }

    fn spmm_sample_t_simd(&self, b: usize, rhs: &[f32], n: usize, out: &mut [f32]) {
        self.for_each_nz(b, 0, self.q.rows, |rid, cid, val| {
            axpy_row_simd(&mut out[cid * n..(cid + 1) * n], val, &rhs[rid * n..(rid + 1) * n]);
        });
    }

    fn spmm_sample_rows_simd(&self, b: usize, row0: usize, rhs: &[f32], n: usize, out: &mut [f32]) {
        let row1 = row0 + out.len() / n;
        self.for_each_nz(b, row0, row1, |rid, cid, val| {
            axpy_row_simd(
                &mut out[(rid - row0) * n..(rid - row0 + 1) * n],
                val,
                &rhs[cid * n..(cid + 1) * n],
            );
        });
    }

    fn spmm_sample_t_rows_simd(
        &self,
        b: usize,
        row0: usize,
        rhs: &[f32],
        n: usize,
        out: &mut [f32],
    ) {
        let row1 = row0 + out.len() / n;
        self.for_each_nz(b, 0, self.q.rows, |rid, cid, val| {
            if cid >= row0 && cid < row1 {
                axpy_row_simd(
                    &mut out[(cid - row0) * n..(cid - row0 + 1) * n],
                    val,
                    &rhs[rid * n..(rid + 1) * n],
                );
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::batch::PaddedEllBatch;
    use crate::sparse::engine::kernels::EllKernel;
    use crate::sparse::engine::{Executor, KernelVariant, Rhs, SchedPolicy};
    use crate::sparse::random::{random_mixed_batch, RandomSpec};
    use crate::util::rng::Rng;

    fn workload(seed: u64, dim: usize, batch: usize, nb: usize) -> (PaddedEllBatch, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let mats = crate::sparse::random::random_batch(&mut rng, &RandomSpec::new(dim, 3), batch);
        let ell = PaddedEllBatch::pack_auto(&mats, dim).unwrap();
        let rhs: Vec<f32> = (0..batch * dim * nb).map(|_| rng.normal()).collect();
        (ell, rhs)
    }

    #[test]
    fn quant_dispatch_tracks_f32_within_dtype_error_bound() {
        // The quantized kernels run the exact f32 ELL traversal over
        // values that are each within the dtype's quantization error of
        // the original, so every output element stays within
        // (per-row nnz) * bound of the f32 dispatch.
        let (ell, rhs) = workload(0x0B16, 14, 5, 9);
        let exec = Executor::serial();
        let f32k = EllKernel::from_padded(&ell);
        let want = exec.spmm(&f32k, Rhs::PerSample(&rhs), 9).unwrap();
        let want_t = exec.spmm_t(&f32k, Rhs::PerSample(&rhs), 9).unwrap();
        for dtype in [DType::Bf16, DType::Int8] {
            let q = QuantizedEllBatch::from_padded(&ell, dtype).unwrap();
            let k = QuantEllKernel::from_batch(&q);
            assert_eq!((k.batch(), k.out_rows()), (5, 14));
            let got = exec.spmm(&k, Rhs::PerSample(&rhs), 9).unwrap();
            let got_t = exec.spmm_t(&k, Rhs::PerSample(&rhs), 9).unwrap();
            let tol = match dtype {
                // width * (value error bound) * max |rhs| with slack.
                DType::Bf16 => 0.05,
                DType::Int8 => 0.5,
                DType::F32 => unreachable!(),
            };
            for (g, w) in got.iter().zip(&want) {
                assert!((g - w).abs() <= tol, "{dtype}: {g} vs {w}");
            }
            for (g, w) in got_t.iter().zip(&want_t) {
                assert!((g - w).abs() <= tol, "{dtype} transpose: {g} vs {w}");
            }
        }
    }

    #[test]
    fn quant_variants_and_thread_counts_are_bit_identical() {
        // Within one dtype, every kernel variant, thread count and
        // row-blocking must agree bit for bit — the same invariant the
        // f32 engine pins, running over dequantized values.
        let (ell, rhs) = workload(0x0B17, 13, 4, 11);
        for dtype in [DType::Bf16, DType::Int8] {
            let q = QuantizedEllBatch::from_padded(&ell, dtype).unwrap();
            let k = QuantEllKernel::from_batch(&q);
            let base = Executor::serial().spmm(&k, Rhs::PerSample(&rhs), 11).unwrap();
            let base_t = Executor::serial().spmm_t(&k, Rhs::PerSample(&rhs), 11).unwrap();
            for variant in [
                KernelVariant::Scalar,
                KernelVariant::Vectorized,
                KernelVariant::Tiled,
                KernelVariant::Simd,
            ] {
                for threads in [1usize, 2, 8] {
                    let exec =
                        Executor::with_variant(threads, SchedPolicy::WorkStealing, variant);
                    let got = exec.spmm(&k, Rhs::PerSample(&rhs), 11).unwrap();
                    let got_t = exec.spmm_t(&k, Rhs::PerSample(&rhs), 11).unwrap();
                    assert_eq!(base, got, "{dtype} {variant:?} threads={threads}");
                    assert_eq!(base_t, got_t, "{dtype} {variant:?} threads={threads} t");
                }
            }
        }
    }

    #[test]
    fn channel_views_split_the_plane_grid() {
        // A [B, CH] plane grid served per channel must match running
        // each channel's planes as a contiguous batch of its own.
        let mut rng = Rng::new(0xC4);
        let (dim, channels, batch, nb) = (8usize, 3usize, 4usize, 5usize);
        let mats = random_mixed_batch(&mut rng, (3, dim), (1, 2), batch * channels);
        let ell = PaddedEllBatch::pack_auto(&mats, dim).unwrap();
        let q = QuantizedEllBatch::from_padded(&ell, DType::Int8).unwrap();
        let rhs: Vec<f32> = (0..batch * dim * nb).map(|_| rng.normal()).collect();
        let exec = Executor::serial();
        for ch in 0..channels {
            let view = QuantEllKernel::channel(&q, ch, channels);
            assert_eq!(view.batch(), batch);
            assert_eq!(
                view.dispatch_value_bytes(),
                batch * q.rows * q.width * DType::Int8.value_bytes()
            );
            let got = exec.spmm(&view, Rhs::PerSample(&rhs), nb).unwrap();
            for b in 0..batch {
                // Plane b*CH+ch as a standalone single-plane batch.
                let plane = b * channels + ch;
                let per = q.rows * q.width;
                let single = QuantizedEllBatch {
                    dtype: q.dtype,
                    planes: 1,
                    rows: q.rows,
                    width: q.width,
                    cols: q.cols[plane * per..(plane + 1) * per].to_vec(),
                    vals_bf16: Vec::new(),
                    vals_i8: q.vals_i8[plane * per..(plane + 1) * per].to_vec(),
                    scale: vec![q.scale[plane]],
                    zero_point: vec![q.zero_point[plane]],
                    nnz_per_plane: vec![q.nnz_per_plane[plane]],
                };
                let sk = QuantEllKernel::from_batch(&single);
                assert_eq!(sk.sample_nnz(0), view.sample_nnz(b));
                let want = exec
                    .spmm(&sk, Rhs::PerSample(&rhs[b * dim * nb..(b + 1) * dim * nb]), nb)
                    .unwrap();
                assert_eq!(&got[b * dim * nb..(b + 1) * dim * nb], &want[..], "ch={ch} b={b}");
            }
        }
    }
}
