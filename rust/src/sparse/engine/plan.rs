//! Compiled step plans: the plan-once / execute-many layer of the
//! engine (DESIGN.md §11).
//!
//! A hot path that issues the same dispatch sequence every iteration —
//! the GCN train step issues 39 — used to pay three avoidable per-step
//! costs: ~15 fresh zero-filled `vec![0f32; ...]` intermediates, a
//! backend/shape re-derivation per dispatch, and redundant zero-fills
//! of buffers whose first use overwrites them anyway. This module
//! splits that into:
//!
//! * [`StepPlan`] — the compiled form of one forward or train step: a
//!   slot table (every intermediate buffer the step needs, with its
//!   maximum length), the ordered list of [`DispatchDesc`] dispatch
//!   descriptors (resolved backend, transpose form, [`RhsKind`],
//!   output slot, dense width), and cached parameter-table offsets so
//!   replays never re-run name lookups. Plans are pure functions of
//!   the model/batch *geometry* — batch contents change freely under a
//!   cached plan.
//! * [`Workspace`] — a slot-addressed arena of reusable f32 buffers
//!   with explicit overwrite-vs-accumulate preparation semantics
//!   ([`SlotInit`]): `Zeroed` zero-fills (the buffer is accumulated
//!   into), `Overwrite` hands the buffer back untouched because the
//!   step fully overwrites it (counted in
//!   [`PlanStats::zero_fills_elided`]). Steady-state replays allocate
//!   no intermediate buffer: every f32 intermediate is served from the
//!   arena (what remains per replay is O(1) fixed-size bookkeeping — a
//!   geometry key and a handful of buffer handles — not data).
//! * [`Backend`] / [`AutoThresholds`] / [`choose_backend`] — per-
//!   dispatch backend selection. `Backend::Auto` resolves to a
//!   concrete backend (ST / CSR / ELL / GEMM) from the O(1) nnz cost
//!   model (density and padding-waste thresholds, calibratable via
//!   env or the microbench); resolution happens once at plan build (or
//!   per [`KernelBundle`] dispatch in the bench) and execution is then
//!   bit-identical to running that fixed backend directly.
//! * [`PlanCache`] + [`PlanStats`] — one (plan, workspace) pair per
//!   [`GeometryKey`], built on first use and replayed thereafter;
//!   geometry changes build a new entry (bounded, LRU eviction),
//!   parameter updates never invalidate a plan.
//! * [`TenantPlanCaches`] — the multi-model serving form (DESIGN.md
//!   §15): one bounded [`PlanCache`] per tenant (model), all stamped
//!   from a single recency clock, with LRU eviction *within* a tenant
//!   at its per-tenant cap and *across* tenants only when the global
//!   `arena_bytes` budget would overflow. A tenant churning through
//!   geometries can never evict another tenant's hot plan while the
//!   budget has headroom.
//!
//! Plans describe *what* runs (backend, transpose form, shapes,
//! [`DType`] precision) — never *how* the executor runs it: the kernel
//! variant (scalar / vectorized / cache-tiled / explicit-SIMD,
//! DESIGN.md §10/§12/§16) is an executor-level setting, deliberately
//! absent from [`DispatchDesc`] and [`GeometryKey`], so the same
//! cached plan replays bit-identically under any variant. The value
//! *precision* ([`DType`]: f32, bf16, int8) is the opposite case — it
//! changes the numbers a dispatch produces, so it lives on the
//! descriptor and in the geometry key, and an f32 plan is never
//! replayed for a quantized request (DESIGN.md §16).
//!
//! Determinism: planning changes where buffers live and which backend
//! runs — never an element's accumulation order — so planned execution
//! is bit-identical to the direct path for every backend × thread
//! count × policy (`tests/engine_parity.rs`).

use super::{BatchedSpmm, Executor, Rhs};

// ---------------------------------------------------------------------
// Backend selection
// ---------------------------------------------------------------------

/// Which [`BatchedSpmm`] backend a dispatch runs on. `Auto` is resolved
/// to one of the four concrete backends at plan-build (or bundle-
/// dispatch) time via [`choose_backend`]; a [`StepPlan`] never stores
/// `Auto`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Backend {
    St,
    Csr,
    Ell,
    Gemm,
    /// Pick per dispatch from the nnz cost model ([`AutoThresholds`]).
    #[default]
    Auto,
}

impl Backend {
    /// All concrete backends, in bench legend order.
    pub const FIXED: [Backend; 4] = [Backend::St, Backend::Csr, Backend::Ell, Backend::Gemm];

    /// Parse a CLI name (`st|csr|ell|gemm|auto`).
    pub fn parse(s: &str) -> anyhow::Result<Backend> {
        Ok(match s {
            "st" => Backend::St,
            "csr" => Backend::Csr,
            "ell" => Backend::Ell,
            "gemm" => Backend::Gemm,
            "auto" => Backend::Auto,
            other => anyhow::bail!("unknown backend '{other}' (st|csr|ell|gemm|auto)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Backend::St => "st",
            Backend::Csr => "csr",
            Backend::Ell => "ell",
            Backend::Gemm => "gemm",
            Backend::Auto => "auto",
        }
    }
}

impl std::fmt::Display for Backend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Value precision of a planned dispatch (DESIGN.md §16). `F32` is the
/// training/default path; `Bf16` and `Int8` are inference-only modes
/// that dequantize a [`QuantizedEllBatch`](crate::sparse::batch::QuantizedEllBatch)
/// on the fly. Precision changes the produced numbers, so — unlike the
/// kernel variant — it is part of [`DispatchDesc`] and of every
/// geometry key, and it round-trips through AOT plan artifacts
/// (`runtime::plan_artifact`, format_version 2).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum DType {
    /// Full-precision f32 values — the only mode training supports.
    #[default]
    F32,
    /// bfloat16 (truncated f32): adjacency values and weights carry 8
    /// mantissa bits, dequantized to f32 in the inner loop. Relative
    /// error per value ≤ 2⁻⁸.
    Bf16,
    /// Affine int8: per-plane scale/zero-point, dequantized to f32 in
    /// the inner loop. Absolute error per value ≤ scale/2.
    Int8,
}

impl DType {
    /// All precisions, in bench legend order.
    pub const ALL: [DType; 3] = [DType::F32, DType::Bf16, DType::Int8];

    /// Stable artifact/CLI name (`f32|bf16|int8`).
    pub fn name(&self) -> &'static str {
        match self {
            DType::F32 => "f32",
            DType::Bf16 => "bf16",
            DType::Int8 => "int8",
        }
    }

    /// Parse an artifact/CLI name back ([`DType::name`] inverse).
    pub fn parse(s: &str) -> anyhow::Result<DType> {
        Ok(match s {
            "f32" => DType::F32,
            "bf16" => DType::Bf16,
            "int8" => DType::Int8,
            other => anyhow::bail!("unknown dtype '{other}' (f32|bf16|int8)"),
        })
    }

    /// Bytes one packed value of this precision occupies — the
    /// bytes-moved accounting the precision bench records alongside
    /// GFLOPS (values only; index streams are unchanged).
    pub fn value_bytes(&self) -> usize {
        match self {
            DType::F32 => 4,
            DType::Bf16 => 2,
            DType::Int8 => 1,
        }
    }

    /// Stable tag for geometry keys: two batches that differ only in
    /// precision must compile distinct plans.
    pub fn key_tag(&self) -> u32 {
        match self {
            DType::F32 => 0,
            DType::Bf16 => 1,
            DType::Int8 => 2,
        }
    }
}

impl std::fmt::Display for DType {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Calibratable decision thresholds for [`Backend::Auto`] (DESIGN.md
/// §11 documents the calibration procedure: sweep the microbench with
/// `--backend auto` against the fixed backends and move the knob until
/// the auto line tracks the best fixed line at every density).
#[derive(Clone, Copy, Debug)]
pub struct AutoThresholds {
    /// Batch density `nnz / (batch * rows * cols)` at or above which
    /// the dense GEMM backend wins: dense inner loops stream
    /// contiguously with no index loads, which beats the sparse formats
    /// once a quarter-ish of the cells are populated.
    pub gemm_density: f64,
    /// ELL padded-slot waste `batch * rows * width / nnz` at or below
    /// which the row-regular ELL layout beats CSR: ELL's fixed-width
    /// rows drop the row-pointer indirection but scan padding, so it
    /// only wins while padding stays a small multiple of the real work.
    pub ell_waste: f64,
}

impl Default for AutoThresholds {
    fn default() -> Self {
        AutoThresholds {
            gemm_density: 0.25,
            ell_waste: 3.0,
        }
    }
}

impl AutoThresholds {
    /// Defaults overridden by `BSPMM_GEMM_DENSITY` / `BSPMM_ELL_WASTE`
    /// (the calibration loop re-runs the microbench under different
    /// values without recompiling).
    pub fn from_env() -> AutoThresholds {
        let read = |key: &str, dflt: f64| {
            std::env::var(key)
                .ok()
                .and_then(|v| v.parse::<f64>().ok())
                .unwrap_or(dflt)
        };
        let d = AutoThresholds::default();
        AutoThresholds {
            gemm_density: read("BSPMM_GEMM_DENSITY", d.gemm_density),
            ell_waste: read("BSPMM_ELL_WASTE", d.ell_waste),
        }
    }
}

/// The aggregate shape/sparsity facts one auto decision reads. All O(1)
/// to assemble on the packed formats (per-sample nnz is counted at pack
/// time, DESIGN.md §10).
#[derive(Clone, Copy, Debug)]
pub struct DispatchProfile {
    pub batch: usize,
    pub rows: usize,
    pub inner: usize,
    /// Real (non-padding) non-zeros across the batch.
    pub nnz: usize,
    /// ELL slot width, when an ELL packing of the operand exists.
    pub ell_width: Option<usize>,
}

impl DispatchProfile {
    /// Profile of an existing kernel (any backend).
    pub fn of(k: &dyn BatchedSpmm, ell_width: Option<usize>) -> DispatchProfile {
        DispatchProfile {
            batch: k.batch(),
            rows: k.out_rows(),
            inner: k.inner_dim(),
            nnz: k.real_nnz(),
            ell_width,
        }
    }

    /// `nnz / (batch * rows * inner)`.
    pub fn density(&self) -> f64 {
        let cells = (self.batch * self.rows * self.inner).max(1) as f64;
        self.nnz as f64 / cells
    }

    /// `batch * rows * width / nnz` — how many padded ELL slots are
    /// scanned per real non-zero.
    pub fn ell_waste(&self) -> f64 {
        match self.ell_width {
            Some(w) => (self.batch * self.rows * w) as f64 / self.nnz.max(1) as f64,
            None => f64::INFINITY,
        }
    }
}

/// Resolve a backend request against the candidates a call site can
/// actually construct. Fixed requests pass through (if available);
/// `Auto` walks the cost model: dense enough → GEMM, row-regular
/// enough → ELL, otherwise CSR, with ST and GEMM as structural
/// fallbacks. Deterministic — same profile, same choice — so a plan
/// that caches the result stays bit-stable across replays.
pub fn choose_backend(
    profile: &DispatchProfile,
    candidates: &[Backend],
    th: &AutoThresholds,
) -> anyhow::Result<Backend> {
    anyhow::ensure!(!candidates.is_empty(), "auto-backend with no candidates");
    let has = |b: Backend| candidates.contains(&b);
    if has(Backend::Gemm) && profile.density() >= th.gemm_density {
        return Ok(Backend::Gemm);
    }
    if has(Backend::Ell) && profile.ell_waste() <= th.ell_waste {
        return Ok(Backend::Ell);
    }
    for b in [Backend::Csr, Backend::Ell, Backend::St, Backend::Gemm] {
        if has(b) {
            return Ok(b);
        }
    }
    anyhow::bail!("no concrete backend among {candidates:?}")
}

/// The packings one logical batch is available in — what the bench (and
/// any caller holding several formats of the same matrices) hands to
/// [`Executor::dispatch_bundle`] so `Backend::Auto` has a real choice.
#[derive(Clone, Copy, Default)]
pub struct KernelBundle<'a> {
    pub st: Option<&'a dyn BatchedSpmm>,
    pub csr: Option<&'a dyn BatchedSpmm>,
    pub ell: Option<&'a dyn BatchedSpmm>,
    pub gemm: Option<&'a dyn BatchedSpmm>,
    /// Slot width of the ELL packing, for the waste heuristic.
    pub ell_width: Option<usize>,
}

impl<'a> KernelBundle<'a> {
    fn get(&self, b: Backend) -> Option<&'a dyn BatchedSpmm> {
        match b {
            Backend::St => self.st,
            Backend::Csr => self.csr,
            Backend::Ell => self.ell,
            Backend::Gemm => self.gemm,
            Backend::Auto => None,
        }
    }

    /// Concrete backends present in this bundle.
    pub fn candidates(&self) -> Vec<Backend> {
        Backend::FIXED
            .into_iter()
            .filter(|&b| self.get(b).is_some())
            .collect()
    }

    /// Aggregate profile (read off any present kernel — they all pack
    /// the same matrices).
    pub fn profile(&self) -> anyhow::Result<DispatchProfile> {
        let k = self
            .st
            .or(self.csr)
            .or(self.ell)
            .or(self.gemm)
            .ok_or_else(|| anyhow::anyhow!("empty kernel bundle"))?;
        Ok(DispatchProfile::of(k, self.ell_width))
    }

    /// Resolve `backend` (possibly `Auto`) to a concrete kernel.
    pub fn resolve(
        &self,
        backend: Backend,
        th: &AutoThresholds,
    ) -> anyhow::Result<(Backend, &'a dyn BatchedSpmm)> {
        let chosen = match backend {
            Backend::Auto => choose_backend(&self.profile()?, &self.candidates(), th)?,
            fixed => fixed,
        };
        let k = self
            .get(chosen)
            .ok_or_else(|| anyhow::anyhow!("backend {chosen} not packed in this bundle"))?;
        Ok((chosen, k))
    }
}

impl Executor {
    /// One dispatch with backend selection: resolve `backend` (fixed or
    /// [`Backend::Auto`]) against the bundle, dispatch on the chosen
    /// kernel, and report which backend ran. Execution is bit-identical
    /// to dispatching that fixed backend directly — selection only
    /// decides *which* kernel's (deterministic) accumulation runs.
    pub fn dispatch_bundle(
        &self,
        bundle: &KernelBundle<'_>,
        backend: Backend,
        th: &AutoThresholds,
        rhs: Rhs<'_>,
        n: usize,
        out: &mut [f32],
    ) -> anyhow::Result<Backend> {
        let (chosen, kernel) = bundle.resolve(backend, th)?;
        self.dispatch(kernel, rhs, n, out)?;
        Ok(chosen)
    }
}

// ---------------------------------------------------------------------
// Workspace arena
// ---------------------------------------------------------------------

/// Index of one arena buffer inside a [`Workspace`], assigned by
/// [`StepPlan::add_slot`] at plan-build time.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SlotId(pub u32);

impl SlotId {
    /// Sentinel for dispatches whose output lives in a caller-held
    /// buffer (the gradient accumulator) rather than an arena slot.
    pub const NONE: SlotId = SlotId(u32::MAX);
}

/// How a slot's contents are prepared when taken for a step.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SlotInit {
    /// Zero-fill: the step accumulates into the buffer (the engine's
    /// `+=` contract), so stale contents must be cleared.
    Zeroed,
    /// Hand the buffer back untouched: the step fully overwrites it
    /// (bias prefill, broadcast, full elementwise store) before any
    /// read. This is where the old code's redundant `vec![0f32; ...]`
    /// zero-fills disappear ([`PlanStats::zero_fills_elided`]).
    Overwrite,
}

/// Slot-addressed arena of reusable f32 buffers. Buffers are `take`n
/// out (owned, so several slots can be live at once with no borrow
/// gymnastics), used, and `put` back; after [`Workspace::prepare`] has
/// reserved a plan's maximum lengths, steady-state take/put cycles
/// never touch the allocator.
#[derive(Debug, Default)]
pub struct Workspace {
    bufs: Vec<Vec<f32>>,
    /// Slot takes served without growing the backing allocation.
    reuses: u64,
    /// Slot takes that had to allocate or grow (first step, or a
    /// geometry the plan under-declared — a bug the stats tests catch).
    grows: u64,
    /// `SlotInit::Overwrite` takes that skipped the zero-fill an
    /// allocate-fresh implementation would have paid.
    zero_fills_elided: u64,
}

impl Workspace {
    pub fn new() -> Workspace {
        Workspace::default()
    }

    /// Reserve every slot's maximum length up front so replay-time
    /// takes never allocate.
    pub fn prepare(&mut self, plan: &StepPlan) {
        if self.bufs.len() < plan.slots.len() {
            self.bufs.resize_with(plan.slots.len(), Vec::new);
        }
        for (buf, &len) in self.bufs.iter_mut().zip(&plan.slots) {
            if buf.capacity() < len {
                buf.reserve_exact(len - buf.len());
            }
        }
    }

    /// Take slot `id` out of the arena as an owned buffer of exactly
    /// `len` elements, prepared per `init`. Pair with
    /// [`Workspace::put`]; a slot that is never put back loses its
    /// allocation (visible as `grows` on the next take).
    pub fn take(&mut self, id: SlotId, len: usize, init: SlotInit) -> Vec<f32> {
        let i = id.0 as usize;
        if i >= self.bufs.len() {
            self.bufs.resize_with(i + 1, Vec::new);
        }
        let mut buf = std::mem::take(&mut self.bufs[i]);
        if buf.capacity() >= len {
            self.reuses += 1;
        } else {
            self.grows += 1;
        }
        match init {
            SlotInit::Zeroed => {
                buf.clear();
                buf.resize(len, 0.0);
            }
            SlotInit::Overwrite => {
                // Contents are about to be overwritten; only the length
                // must match. An elision is only counted when the whole
                // prefix already existed — a shorter buffer still pays a
                // zero-fill for the extension (the full length, on the
                // very first take), which would be dishonest to report
                // as saved.
                if buf.len() >= len {
                    buf.truncate(len);
                    self.zero_fills_elided += 1;
                } else {
                    buf.resize(len, 0.0);
                }
            }
        }
        buf
    }

    /// Return a taken buffer to its slot.
    pub fn put(&mut self, id: SlotId, buf: Vec<f32>) {
        let i = id.0 as usize;
        if i >= self.bufs.len() {
            self.bufs.resize_with(i + 1, Vec::new);
        }
        self.bufs[i] = buf;
    }

    /// Read a slot in place (e.g. results left behind by a replay).
    pub fn peek(&self, id: SlotId) -> &[f32] {
        static EMPTY: [f32; 0] = [];
        self.bufs.get(id.0 as usize).map_or(&EMPTY[..], |b| &b[..])
    }

    /// Total bytes currently backing the arena. Constant across
    /// steady-state replays — the "zero new arena buffers" signal the
    /// stats tests pin.
    pub fn arena_bytes(&self) -> u64 {
        self.bufs
            .iter()
            .map(|b| (b.capacity() * std::mem::size_of::<f32>()) as u64)
            .sum()
    }

    pub fn reuses(&self) -> u64 {
        self.reuses
    }

    pub fn grows(&self) -> u64 {
        self.grows
    }

    pub fn zero_fills_elided(&self) -> u64 {
        self.zero_fills_elided
    }
}

// ---------------------------------------------------------------------
// Step plans
// ---------------------------------------------------------------------

/// The geometry a plan was compiled for: a mode tag plus every
/// dimension the slot table and descriptor list depend on. Two batches
/// with equal keys replay the same plan; any difference (batch size,
/// node bucket, feature widths, …) builds a new one.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct GeometryKey(pub Vec<u32>);

/// Operand layout of a planned dispatch — mirrors [`Rhs`] without the
/// borrow, so descriptors are plain data.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RhsKind {
    Shared,
    PerSample,
    /// Logical `X·W^T` form. Replays pre-transpose the weight into a
    /// workspace slot and dispatch [`Rhs::Shared`], eliding the
    /// executor's per-dispatch transpose allocation.
    SharedTransposed,
}

impl RhsKind {
    /// Stable artifact name (`runtime::plan_artifact` encoding).
    pub fn name(&self) -> &'static str {
        match self {
            RhsKind::Shared => "shared",
            RhsKind::PerSample => "per_sample",
            RhsKind::SharedTransposed => "shared_transposed",
        }
    }

    /// Parse an artifact name back ([`RhsKind::name`] inverse).
    pub fn parse(s: &str) -> anyhow::Result<RhsKind> {
        Ok(match s {
            "shared" => RhsKind::Shared,
            "per_sample" => RhsKind::PerSample,
            "shared_transposed" => RhsKind::SharedTransposed,
            other => anyhow::bail!(
                "unknown rhs kind '{other}' (shared|per_sample|shared_transposed)"
            ),
        })
    }
}

/// One compiled dispatch: everything a replay needs that the direct
/// path re-derives per call.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DispatchDesc {
    /// Concrete backend (never [`Backend::Auto`] — resolution happens
    /// at plan build).
    pub backend: Backend,
    /// `A^T·X` transpose form ([`Executor::dispatch_t`]).
    pub transpose: bool,
    pub rhs: RhsKind,
    /// Dense operand width `n` of this dispatch.
    pub n: u32,
    /// Workspace slot the dispatch accumulates into.
    pub out: SlotId,
    /// Value precision the dispatch runs at ([`DType::F32`] for every
    /// training dispatch; quantized inference plans record `Bf16` /
    /// `Int8`). Carried by AOT artifacts (DESIGN.md §16).
    pub dtype: DType,
}

/// Cached parameter-table entry: flat (offset, len) into the
/// [`ParamSet`](crate::gcn::ParamSet) data vector, resolved once at
/// plan build so replays never run name lookups or `format!`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ParamRef {
    pub offset: u32,
    pub len: u32,
}

impl ParamRef {
    #[inline]
    pub fn range(&self) -> std::ops::Range<usize> {
        self.offset as usize..(self.offset + self.len) as usize
    }
}

/// The compiled form of one forward or train step. Built once per
/// geometry, replayed every iteration after that. `PartialEq` is
/// field-exact — the AOT golden tests compare a deserialized plan
/// against a freshly compiled one with `==`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct StepPlan {
    pub key: GeometryKey,
    /// Required maximum length of each workspace slot.
    pub slots: Vec<usize>,
    /// Dispatch descriptors in issue order.
    pub dispatches: Vec<DispatchDesc>,
    /// Parameter references in a caller-defined fixed order.
    pub params: Vec<ParamRef>,
}

impl StepPlan {
    pub fn new(key: GeometryKey) -> StepPlan {
        StepPlan {
            key,
            slots: Vec::new(),
            dispatches: Vec::new(),
            params: Vec::new(),
        }
    }

    /// Declare a slot of (at most) `len` elements.
    pub fn add_slot(&mut self, len: usize) -> SlotId {
        self.slots.push(len);
        SlotId((self.slots.len() - 1) as u32)
    }

    /// Raise an existing slot's declared length (shared scratch reused
    /// at several sizes declares its maximum).
    pub fn grow_slot(&mut self, id: SlotId, len: usize) {
        let s = &mut self.slots[id.0 as usize];
        *s = (*s).max(len);
    }

    pub fn add_dispatch(&mut self, desc: DispatchDesc) {
        self.dispatches.push(desc);
    }

    pub fn add_param(&mut self, offset: usize, len: usize) -> usize {
        self.params.push(ParamRef {
            offset: offset as u32,
            len: len as u32,
        });
        self.params.len() - 1
    }

    pub fn param(&self, idx: usize) -> ParamRef {
        self.params[idx]
    }

    /// Structural invariants every plan must satisfy — checked on every
    /// deserialized artifact before it may enter a [`PlanCache`]
    /// (`runtime::plan_artifact`), so a corrupt or hand-edited artifact
    /// is rejected with an actionable error instead of replaying out of
    /// bounds. Freshly compiled plans satisfy this by construction.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(!self.key.0.is_empty(), "plan has an empty geometry key");
        anyhow::ensure!(
            !self.dispatches.is_empty(),
            "plan has no dispatch descriptors"
        );
        for (i, d) in self.dispatches.iter().enumerate() {
            anyhow::ensure!(
                d.backend != Backend::Auto,
                "dispatch {i} stores Backend::Auto — plans must freeze \
                 the resolved backend at compile time"
            );
            anyhow::ensure!(d.n >= 1, "dispatch {i} has dense width 0");
            anyhow::ensure!(
                d.out == SlotId::NONE || (d.out.0 as usize) < self.slots.len(),
                "dispatch {i} writes slot {} but the plan declares only {} slots",
                d.out.0,
                self.slots.len()
            );
        }
        for (i, p) in self.params.iter().enumerate() {
            anyhow::ensure!(
                p.offset.checked_add(p.len).is_some(),
                "param ref {i} overflows the parameter table"
            );
        }
        Ok(())
    }
}

/// Sequential reader over a plan's dispatch descriptors; replays
/// consume exactly the recorded sequence (checked in debug builds by
/// [`PlanCursor::finish`]).
pub struct PlanCursor<'a> {
    plan: &'a StepPlan,
    next: usize,
}

impl<'a> PlanCursor<'a> {
    pub fn new(plan: &'a StepPlan) -> PlanCursor<'a> {
        PlanCursor { plan, next: 0 }
    }

    /// The next dispatch descriptor in issue order.
    #[inline]
    pub fn dispatch(&mut self) -> &'a DispatchDesc {
        let d = &self.plan.dispatches[self.next];
        self.next += 1;
        d
    }

    /// Assert the replay issued every planned dispatch.
    pub fn finish(self) {
        debug_assert_eq!(
            self.next,
            self.plan.dispatches.len(),
            "replay consumed {} of {} planned dispatches",
            self.next,
            self.plan.dispatches.len()
        );
    }
}

// ---------------------------------------------------------------------
// Plan cache + stats
// ---------------------------------------------------------------------

/// Cumulative plan/arena accounting for one [`PlanCache`] (the
/// plan-layer analogue of [`PoolStats`](super::PoolStats)). Read deltas
/// around a region of interest; the steady-state contract is
/// `plans_built` frozen and `arena_bytes` constant from step 2 on.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PlanStats {
    /// Plans compiled (one per geometry seen). Warm-started entries do
    /// NOT count here — the AOT cold-start contract is precisely
    /// `plans_built == 0` in steady state after a warm start.
    pub plans_built: u64,
    /// Plans installed from deserialized AOT artifacts
    /// ([`PlanCache::insert_warm`], `runtime::plan_artifact`).
    pub plans_warmed: u64,
    /// Steps served from a cached plan.
    pub replays: u64,
    /// Entries dropped to stay within the per-tenant cap or the global
    /// arena budget ([`TenantPlanCaches`]). A re-entered geometry after
    /// eviction counts in `plans_built` again (readmission recompiles).
    pub plans_evicted: u64,
    /// Bytes currently backing all cached workspaces.
    pub arena_bytes: u64,
    /// Buffer takes served without growing an allocation.
    pub arena_reuses: u64,
    /// Redundant zero-fills skipped via [`SlotInit::Overwrite`].
    pub zero_fills_elided: u64,
}

struct CacheEntry {
    key: GeometryKey,
    plan: StepPlan,
    ws: Workspace,
    /// Recency stamp from the owning cache's clock (or the shared
    /// [`TenantPlanCaches`] clock) — the LRU eviction order.
    last_used: u64,
}

/// One (plan, workspace) pair per geometry, built on first use.
/// Geometry changes build a new entry (bounded, least-recently-used
/// eviction); parameter updates never touch this cache — plans depend
/// only on geometry.
pub struct PlanCache {
    entries: Vec<CacheEntry>,
    cap: usize,
    /// Monotonic recency clock; every hit/insert stamps the entry.
    /// [`TenantPlanCaches`] syncs this across tenants so stamps are
    /// comparable cache-to-cache.
    clock: u64,
    plans_built: u64,
    plans_warmed: u64,
    replays: u64,
    plans_evicted: u64,
}

impl Default for PlanCache {
    fn default() -> Self {
        PlanCache::new()
    }
}

impl PlanCache {
    pub fn new() -> PlanCache {
        PlanCache {
            entries: Vec::new(),
            // Enough for the live modes of one host (train + a couple
            // of eval/serve batch shapes) without unbounded growth.
            cap: 8,
            clock: 0,
            plans_built: 0,
            plans_warmed: 0,
            replays: 0,
            plans_evicted: 0,
        }
    }

    /// Whether a plan for `key` is cached (warm-started or compiled).
    pub fn contains(&self, key: &GeometryKey) -> bool {
        self.entries.iter().any(|e| e.key == *key)
    }

    /// Drop the entry for `key` unless its plan satisfies `keep`. The
    /// per-batch `Backend::Auto` re-resolution path
    /// (`MultiDispatcher::forward`) re-runs the cost model on each
    /// assembled batch's profile and discards a cached plan whose
    /// frozen backend choices no longer match — the next
    /// [`PlanCache::entry_with`] then recompiles for the observed
    /// profile. Returns `true` when an entry was dropped.
    pub fn retain_key(&mut self, key: &GeometryKey, keep: impl FnOnce(&StepPlan) -> bool) -> bool {
        if let Some(pos) = self.entries.iter().position(|e| e.key == *key) {
            if !keep(&self.entries[pos].plan) {
                self.entries.remove(pos);
                return true;
            }
        }
        false
    }

    /// Iterate the cached plans (dump side of the AOT artifact flow —
    /// `runtime::plan_artifact::save` serializes each one).
    pub fn plans(&self) -> impl Iterator<Item = &StepPlan> {
        self.entries.iter().map(|e| &e.plan)
    }

    /// Install a pre-compiled plan (deserialized from an AOT artifact)
    /// with a prepared workspace, so the first live step of this
    /// geometry replays instead of compiling. Counts in
    /// [`PlanStats::plans_warmed`], never in `plans_built` — the
    /// fleet-cold-start contract is `plans_built == 0` at steady state.
    /// A key already cached is left untouched (returns `false`): live
    /// entries are never clobbered by artifacts.
    pub fn insert_warm(&mut self, plan: StepPlan) -> bool {
        if self.contains(&plan.key) {
            return false;
        }
        let mut ws = Workspace::new();
        ws.prepare(&plan);
        self.plans_warmed += 1;
        if self.entries.len() == self.cap {
            self.evict_lru();
        }
        self.clock += 1;
        self.entries.push(CacheEntry {
            key: plan.key.clone(),
            plan,
            ws,
            last_used: self.clock,
        });
        true
    }

    /// The cached plan + workspace for `key`, building (and preparing
    /// the workspace of) a new entry via `build` on a miss. Hits stamp
    /// the entry most-recently-used.
    pub fn entry_with(
        &mut self,
        key: GeometryKey,
        build: impl FnOnce() -> anyhow::Result<StepPlan>,
    ) -> anyhow::Result<(&StepPlan, &mut Workspace)> {
        if let Some(pos) = self.entries.iter().position(|e| e.key == key) {
            self.replays += 1;
            self.clock += 1;
            let e = &mut self.entries[pos];
            e.last_used = self.clock;
            return Ok((&e.plan, &mut e.ws));
        }
        let plan = build()?;
        let mut ws = Workspace::new();
        ws.prepare(&plan);
        self.plans_built += 1;
        if self.entries.len() == self.cap {
            self.evict_lru();
        }
        self.clock += 1;
        self.entries.push(CacheEntry {
            key,
            plan,
            ws,
            last_used: self.clock,
        });
        let e = self.entries.last_mut().unwrap();
        Ok((&e.plan, &mut e.ws))
    }

    /// Drop the least-recently-used entry, if any. Counts in
    /// [`PlanStats::plans_evicted`].
    fn evict_lru(&mut self) {
        if let Some(pos) = self
            .entries
            .iter()
            .enumerate()
            .min_by_key(|(_, e)| e.last_used)
            .map(|(i, _)| i)
        {
            self.entries.remove(pos);
            self.plans_evicted += 1;
        }
    }

    /// Drop every cached plan and workspace (the microbench's cold-plan
    /// configuration does this between steps).
    pub fn clear(&mut self) {
        self.entries.clear();
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Bytes currently backing this cache's workspaces (the quantity
    /// the [`TenantPlanCaches`] global budget bounds).
    pub fn arena_bytes(&self) -> u64 {
        self.entries.iter().map(|e| e.ws.arena_bytes()).sum()
    }

    pub fn stats(&self) -> PlanStats {
        let mut s = PlanStats {
            plans_built: self.plans_built,
            plans_warmed: self.plans_warmed,
            replays: self.replays,
            plans_evicted: self.plans_evicted,
            ..PlanStats::default()
        };
        for e in &self.entries {
            s.arena_bytes += e.ws.arena_bytes();
            s.arena_reuses += e.ws.reuses();
            s.zero_fills_elided += e.ws.zero_fills_elided();
        }
        s
    }
}

// ---------------------------------------------------------------------
// Per-tenant plan caches under a global arena budget
// ---------------------------------------------------------------------

/// Environment override for the [`TenantPlanCaches`] global arena
/// budget, in bytes. `0` disables the budget (per-tenant caps still
/// bound each cache).
pub const ENV_PLAN_BUDGET: &str = "BSPMM_PLAN_BUDGET_BYTES";

/// Default global arena budget: 512 MiB — generous for the molecule
/// models (whose workspaces are a few MiB) while still a hard wall for
/// a fleet of large-graph tenants.
pub const DEFAULT_PLAN_BUDGET: u64 = 512 << 20;

/// The global plan-arena budget in bytes: [`ENV_PLAN_BUDGET`] if set
/// and parseable, else [`DEFAULT_PLAN_BUDGET`].
pub fn plan_budget_from_env() -> u64 {
    std::env::var(ENV_PLAN_BUDGET)
        .ok()
        .and_then(|v| v.trim().parse::<u64>().ok())
        .unwrap_or(DEFAULT_PLAN_BUDGET)
}

/// One bounded [`PlanCache`] per tenant (in multi-model serving: per
/// registered model), all stamped from a single shared recency clock so
/// LRU order is comparable across tenants (DESIGN.md §15).
///
/// Two eviction regimes, deliberately separate:
///
/// * **Per-tenant cap** (each cache's `cap`, 8): a tenant cycling
///   through more geometries than its cap evicts *its own* LRU entry —
///   never a neighbour's. This is the fairness rule: churn is charged
///   to the tenant causing it.
/// * **Global budget** (`budget` bytes over the summed `arena_bytes`):
///   only when admitting a new workspace would overflow the budget does
///   eviction go cross-tenant, dropping the *globally*
///   least-recently-used entry (wherever it lives) until the newcomer
///   fits. Evictions are charged to the owning tenant's
///   [`PlanStats::plans_evicted`].
///
/// Readmission after either eviction recompiles (counts in
/// `plans_built` again) — pinned by the budget tests.
pub struct TenantPlanCaches {
    tenants: Vec<(String, PlanCache)>,
    clock: u64,
    budget: u64,
}

impl TenantPlanCaches {
    /// Empty cache set with an explicit budget (`0` = unbudgeted).
    pub fn new(budget: u64) -> TenantPlanCaches {
        TenantPlanCaches {
            tenants: Vec::new(),
            clock: 0,
            budget,
        }
    }

    /// Empty cache set budgeted from [`plan_budget_from_env`].
    pub fn from_env() -> TenantPlanCaches {
        TenantPlanCaches::new(plan_budget_from_env())
    }

    pub fn budget(&self) -> u64 {
        self.budget
    }

    /// Replace the global budget (takes effect on the next admission;
    /// already-cached entries are not proactively evicted).
    pub fn set_budget(&mut self, budget: u64) {
        self.budget = budget;
    }

    /// Tenant names in registration order.
    pub fn tenants(&self) -> impl Iterator<Item = &str> {
        self.tenants.iter().map(|(t, _)| t.as_str())
    }

    /// Summed `arena_bytes` across every tenant — the quantity the
    /// budget bounds.
    pub fn total_arena_bytes(&self) -> u64 {
        self.tenants.iter().map(|(_, c)| c.arena_bytes()).sum()
    }

    /// Pull the shared clock forward past every tenant clock (tenant
    /// caches mutated directly via [`tenant_cache_mut`] advance their
    /// own clocks; stamps stay comparable as long as the shared clock
    /// never falls behind).
    ///
    /// [`tenant_cache_mut`]: TenantPlanCaches::tenant_cache_mut
    fn sync_clock(&mut self) {
        for (_, c) in &self.tenants {
            self.clock = self.clock.max(c.clock);
        }
    }

    fn ensure_tenant(&mut self, tenant: &str) -> usize {
        if let Some(pos) = self.tenants.iter().position(|(t, _)| t == tenant) {
            return pos;
        }
        self.tenants.push((tenant.to_string(), PlanCache::new()));
        self.tenants.len() - 1
    }

    /// Direct access to one tenant's cache (created empty on first
    /// use) — the warm-start / export seam:
    /// `runtime::plan_artifact::{warm_start, save}` operate on a plain
    /// [`PlanCache`].
    pub fn tenant_cache_mut(&mut self, tenant: &str) -> &mut PlanCache {
        self.sync_clock();
        let idx = self.ensure_tenant(tenant);
        let clock = self.clock;
        let cache = &mut self.tenants[idx].1;
        cache.clock = cache.clock.max(clock);
        cache
    }

    /// The cached plan + workspace for `(tenant, key)`, building via
    /// `build` on a miss. Misses prepare the workspace first, then
    /// enforce the per-tenant cap (own-LRU eviction) and the global
    /// budget (cross-tenant LRU eviction) before admission.
    pub fn entry_with(
        &mut self,
        tenant: &str,
        key: GeometryKey,
        build: impl FnOnce() -> anyhow::Result<StepPlan>,
    ) -> anyhow::Result<(&StepPlan, &mut Workspace)> {
        self.sync_clock();
        let idx = self.ensure_tenant(tenant);
        if let Some(pos) = self.tenants[idx].1.entries.iter().position(|e| e.key == key) {
            self.clock += 1;
            let stamp = self.clock;
            let cache = &mut self.tenants[idx].1;
            cache.replays += 1;
            cache.clock = stamp;
            let e = &mut cache.entries[pos];
            e.last_used = stamp;
            return Ok((&e.plan, &mut e.ws));
        }
        // Miss: compile + prepare before admission so the newcomer's
        // arena cost is known to the budget check.
        let plan = build()?;
        let mut ws = Workspace::new();
        ws.prepare(&plan);
        let new_bytes = ws.arena_bytes();
        // Per-tenant cap first: churn is charged to the churning tenant.
        if self.tenants[idx].1.entries.len() >= self.tenants[idx].1.cap {
            self.tenants[idx].1.evict_lru();
        }
        // Global budget: cross-tenant LRU eviction until the newcomer
        // fits (or nothing is left to evict).
        while self.budget > 0
            && self.total_arena_bytes() + new_bytes > self.budget
            && self.evict_global_lru()
        {}
        self.clock += 1;
        let stamp = self.clock;
        let cache = &mut self.tenants[idx].1;
        cache.plans_built += 1;
        cache.clock = stamp;
        cache.entries.push(CacheEntry {
            key,
            plan,
            ws,
            last_used: stamp,
        });
        let e = cache.entries.last_mut().unwrap();
        Ok((&e.plan, &mut e.ws))
    }

    /// Drop the globally least-recently-used entry across every tenant.
    /// Returns `false` when no tenant holds any entry.
    fn evict_global_lru(&mut self) -> bool {
        let mut victim: Option<(usize, usize, u64)> = None;
        for (ti, (_, cache)) in self.tenants.iter().enumerate() {
            for (ei, e) in cache.entries.iter().enumerate() {
                if victim.map_or(true, |(_, _, stamp)| e.last_used < stamp) {
                    victim = Some((ti, ei, e.last_used));
                }
            }
        }
        match victim {
            Some((ti, ei, _)) => {
                let cache = &mut self.tenants[ti].1;
                cache.entries.remove(ei);
                cache.plans_evicted += 1;
                true
            }
            None => false,
        }
    }

    /// Whether `(tenant, key)` is cached.
    pub fn contains(&self, tenant: &str, key: &GeometryKey) -> bool {
        self.tenants
            .iter()
            .any(|(t, c)| t == tenant && c.contains(key))
    }

    /// Aggregate stats summed across every tenant.
    pub fn stats(&self) -> PlanStats {
        let mut agg = PlanStats::default();
        for (_, c) in &self.tenants {
            let s = c.stats();
            agg.plans_built += s.plans_built;
            agg.plans_warmed += s.plans_warmed;
            agg.replays += s.replays;
            agg.plans_evicted += s.plans_evicted;
            agg.arena_bytes += s.arena_bytes;
            agg.arena_reuses += s.arena_reuses;
            agg.zero_fills_elided += s.zero_fills_elided;
        }
        agg
    }

    /// Per-tenant stats in registration order (the per-model metrics
    /// breakdown and the budget-accounting tests read this).
    pub fn per_tenant_stats(&self) -> Vec<(String, PlanStats)> {
        self.tenants
            .iter()
            .map(|(t, c)| (t.clone(), c.stats()))
            .collect()
    }
}

/// Materialize the transpose of a `[n, inner]` row-major weight into
/// `dst` (`[inner, n]`) — the same element order the executor's
/// [`Rhs::SharedTransposed`] normalization produces, so a planned
/// dispatch against the pre-transposed slot is bit-identical to the
/// direct `SharedTransposed` dispatch while allocating nothing.
pub fn transpose_into(w: &[f32], inner: usize, n: usize, dst: &mut [f32]) {
    debug_assert_eq!(w.len(), inner * n);
    debug_assert!(dst.len() >= inner * n);
    for k in 0..inner {
        for j in 0..n {
            dst[k * n + j] = w[j * inner + k];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn workspace_reuses_and_elides_after_prepare() {
        let mut plan = StepPlan::new(GeometryKey(vec![1]));
        let a = plan.add_slot(16);
        let b = plan.add_slot(8);
        plan.grow_slot(b, 32);
        assert_eq!(plan.slots, vec![16, 32]);

        let mut ws = Workspace::new();
        ws.prepare(&plan);
        let bytes0 = ws.arena_bytes();
        assert!(bytes0 >= ((16 + 32) * 4) as u64);

        for step in 0..3 {
            let mut x = ws.take(a, 16, SlotInit::Zeroed);
            assert!(x.iter().all(|&v| v == 0.0));
            x[3] = 7.0;
            let y = ws.take(b, 20, SlotInit::Overwrite);
            assert_eq!(y.len(), 20);
            ws.put(a, x);
            ws.put(b, y);
            assert_eq!(ws.arena_bytes(), bytes0, "step {step} grew the arena");
        }
        assert_eq!(ws.grows(), 0);
        assert_eq!(ws.reuses(), 6);
        // The first Overwrite take still zero-fills (the buffer starts
        // empty); only the warm takes elide.
        assert_eq!(ws.zero_fills_elided(), 2);
        // Zeroed takes really clear stale contents.
        let x = ws.take(a, 16, SlotInit::Zeroed);
        assert!(x.iter().all(|&v| v == 0.0));
        ws.put(a, x);
    }

    #[test]
    fn workspace_without_prepare_grows_once_then_reuses() {
        let mut ws = Workspace::new();
        let id = SlotId(0);
        let v = ws.take(id, 64, SlotInit::Zeroed);
        ws.put(id, v);
        assert_eq!(ws.grows(), 1);
        let v = ws.take(id, 64, SlotInit::Zeroed);
        ws.put(id, v);
        assert_eq!(ws.grows(), 1);
        assert_eq!(ws.reuses(), 1);
    }

    #[test]
    fn choose_backend_follows_thresholds() {
        let th = AutoThresholds::default();
        let all = [Backend::St, Backend::Csr, Backend::Ell, Backend::Gemm];
        // Dense batch -> GEMM.
        let dense = DispatchProfile {
            batch: 4,
            rows: 8,
            inner: 8,
            nnz: 4 * 8 * 8 / 2,
            ell_width: Some(8),
        };
        assert_eq!(choose_backend(&dense, &all, &th).unwrap(), Backend::Gemm);
        // Sparse + row-regular -> ELL.
        let regular = DispatchProfile {
            batch: 4,
            rows: 64,
            inner: 64,
            nnz: 4 * 64 * 2,
            ell_width: Some(3),
        };
        assert_eq!(choose_backend(&regular, &all, &th).unwrap(), Backend::Ell);
        // Sparse + padding-heavy ELL -> CSR.
        let ragged = DispatchProfile {
            batch: 4,
            rows: 64,
            inner: 64,
            nnz: 40,
            ell_width: Some(16),
        };
        assert_eq!(choose_backend(&ragged, &all, &th).unwrap(), Backend::Csr);
        // Candidate set restricts the choice.
        assert_eq!(
            choose_backend(&ragged, &[Backend::Ell], &th).unwrap(),
            Backend::Ell
        );
        assert_eq!(
            choose_backend(&dense, &[Backend::St], &th).unwrap(),
            Backend::St
        );
        assert!(choose_backend(&dense, &[], &th).is_err());
    }

    fn key(v: u32) -> GeometryKey {
        GeometryKey(vec![v])
    }

    /// Build closure for a one-slot plan of `slot` f32 elements.
    fn build(v: u32, slot: usize) -> impl FnOnce() -> anyhow::Result<StepPlan> {
        move || {
            let mut p = StepPlan::new(GeometryKey(vec![v]));
            p.add_slot(slot);
            Ok(p)
        }
    }

    #[test]
    fn plan_cache_builds_once_per_geometry_and_evicts_lru() {
        let mut cache = PlanCache::new();
        cache.entry_with(key(1), build(1, 8)).unwrap();
        cache.entry_with(key(1), build(1, 8)).unwrap();
        cache.entry_with(key(2), build(2, 8)).unwrap();
        let s = cache.stats();
        assert_eq!(s.plans_built, 2);
        assert_eq!(s.replays, 1);
        assert_eq!(s.plans_evicted, 0);
        assert!(s.arena_bytes >= (2 * 8 * 4) as u64);
        // Node-count-style geometry difference is a different key.
        assert_ne!(key(1), key(2));
        for v in 3..=8 {
            cache.entry_with(key(v), build(v, 8)).unwrap();
        }
        // Full at cap 8. Re-touch key(1): under FIFO it would be the
        // next victim (oldest insertion); under LRU the hit protects it
        // and key(2) — least recently used — goes instead.
        cache.entry_with(key(1), build(1, 8)).unwrap();
        cache.entry_with(key(9), build(9, 8)).unwrap();
        assert_eq!(cache.len(), 8, "cache must stay bounded");
        assert!(cache.contains(&key(1)), "LRU must keep the re-touched entry");
        assert!(!cache.contains(&key(2)), "key(2) was the LRU victim");
        let s = cache.stats();
        assert_eq!(s.plans_built, 9);
        assert_eq!(s.plans_evicted, 1);
        // Readmission after eviction recompiles.
        cache.entry_with(key(2), build(2, 8)).unwrap();
        let s = cache.stats();
        assert_eq!(s.plans_built, 10);
        assert_eq!(s.plans_evicted, 2);
    }

    #[test]
    fn tenant_churn_cannot_evict_a_neighbour_under_budget_headroom() {
        // Generous budget: nothing here approaches it.
        let mut caches = TenantPlanCaches::new(64 << 20);
        caches.entry_with("a", key(100), build(100, 64)).unwrap();
        // Tenant B churns through 3x its per-tenant cap of geometries.
        for v in 0..24 {
            caches.entry_with("b", key(v), build(v, 64)).unwrap();
        }
        // B paid for its own churn; A's hot plan is untouched.
        let stats: std::collections::HashMap<_, _> =
            caches.per_tenant_stats().into_iter().collect();
        assert!(caches.contains("a", &key(100)), "churn evicted a neighbour");
        assert_eq!(stats["a"].plans_evicted, 0);
        assert_eq!(stats["a"].plans_built, 1);
        assert_eq!(stats["b"].plans_built, 24);
        assert_eq!(stats["b"].plans_evicted, 16, "B evicts only its own LRU");
        assert!(caches.total_arena_bytes() <= caches.budget());
        // A replay on A still hits.
        caches.entry_with("a", key(100), build(100, 64)).unwrap();
        assert_eq!(
            caches.per_tenant_stats().into_iter().collect::<std::collections::HashMap<_, _>>()["a"]
                .replays,
            1
        );
    }

    #[test]
    fn over_budget_admission_evicts_the_global_lru_victim_in_order() {
        // Measure one entry's real arena footprint first (allocator
        // rounding makes hardcoded byte counts brittle), then budget
        // exactly three entries.
        let mut caches = TenantPlanCaches::new(0);
        caches.entry_with("a", key(1), build(1, 256)).unwrap();
        let per_entry = caches.total_arena_bytes();
        assert!(per_entry >= (256 * 4) as u64);
        caches.set_budget(3 * per_entry);
        caches.entry_with("a", key(2), build(2, 256)).unwrap();
        caches.entry_with("b", key(3), build(3, 256)).unwrap();
        assert_eq!(caches.total_arena_bytes(), 3 * per_entry);
        assert_eq!(caches.stats().plans_evicted, 0, "at budget is not over it");
        // Fourth entry overflows: the global LRU is a:key(1).
        caches.entry_with("b", key(4), build(4, 256)).unwrap();
        assert!(!caches.contains("a", &key(1)), "a:1 was the global LRU");
        assert!(caches.contains("a", &key(2)));
        let stats: std::collections::HashMap<_, _> =
            caches.per_tenant_stats().into_iter().collect();
        assert_eq!(stats["a"].plans_evicted, 1);
        assert_eq!(stats["b"].plans_evicted, 0);
        assert!(caches.total_arena_bytes() <= caches.budget());
        // Touch a:2, then admit a:5 — the victim order continues with
        // b:3 (cross-tenant LRU), not the freshly touched a:2.
        caches.entry_with("a", key(2), build(2, 256)).unwrap();
        caches.entry_with("a", key(5), build(5, 256)).unwrap();
        assert!(!caches.contains("b", &key(3)), "b:3 was next in LRU order");
        assert!(caches.contains("a", &key(2)));
        assert!(caches.contains("b", &key(4)));
        let stats: std::collections::HashMap<_, _> =
            caches.per_tenant_stats().into_iter().collect();
        assert_eq!(stats["b"].plans_evicted, 1);
        // Readmission of the first victim recompiles and evicts b:4.
        caches.entry_with("a", key(1), build(1, 256)).unwrap();
        let stats: std::collections::HashMap<_, _> =
            caches.per_tenant_stats().into_iter().collect();
        assert_eq!(stats["a"].plans_built, 4, "readmission recompiles");
        assert!(!caches.contains("b", &key(4)));
        assert!(caches.total_arena_bytes() <= caches.budget());
    }

    #[test]
    fn transpose_into_matches_manual_transpose() {
        let (inner, n) = (3usize, 4usize);
        let w: Vec<f32> = (0..n * inner).map(|i| i as f32).collect(); // [n, inner]
        let mut dst = vec![0f32; inner * n];
        transpose_into(&w, inner, n, &mut dst);
        for j in 0..n {
            for k in 0..inner {
                assert_eq!(dst[k * n + j], w[j * inner + k]);
            }
        }
    }

    #[test]
    fn cursor_walks_descriptors_in_order() {
        let mut p = StepPlan::new(GeometryKey(vec![0]));
        let s = p.add_slot(4);
        for n in [3u32, 5] {
            p.add_dispatch(DispatchDesc {
                backend: Backend::Ell,
                transpose: false,
                rhs: RhsKind::PerSample,
                n,
                out: s,
                dtype: DType::F32,
            });
        }
        let mut c = PlanCursor::new(&p);
        assert_eq!(c.dispatch().n, 3);
        assert_eq!(c.dispatch().n, 5);
        c.finish();
    }

    #[test]
    fn dtype_parse_round_trips_and_tags_are_distinct() {
        for d in DType::ALL {
            assert_eq!(DType::parse(d.name()).unwrap(), d);
        }
        assert!(DType::parse("f64").is_err());
        let tags: Vec<u32> = DType::ALL.iter().map(|d| d.key_tag()).collect();
        let mut uniq = tags.clone();
        uniq.sort_unstable();
        uniq.dedup();
        assert_eq!(uniq.len(), tags.len(), "key tags must be distinct");
        assert_eq!(DType::F32.value_bytes(), 4);
        assert_eq!(DType::Bf16.value_bytes(), 2);
        assert_eq!(DType::Int8.value_bytes(), 1);
    }

    #[test]
    fn backend_parse_round_trips() {
        for b in [
            Backend::St,
            Backend::Csr,
            Backend::Ell,
            Backend::Gemm,
            Backend::Auto,
        ] {
            assert_eq!(Backend::parse(b.name()).unwrap(), b);
        }
        assert!(Backend::parse("nope").is_err());
    }
}
