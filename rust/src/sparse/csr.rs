//! CSR (compressed sparse row) format (paper Fig. 1).

use super::coo::Coo;
use super::dense::Dense;

/// CSR sparse matrix: `rpt[r]..rpt[r+1]` indexes row r's non-zeros.
#[derive(Clone, Debug, PartialEq)]
pub struct Csr {
    pub rows: usize,
    pub cols: usize,
    pub rpt: Vec<u32>,
    pub col_ids: Vec<u32>,
    pub vals: Vec<f32>,
}

impl Csr {
    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    /// Validate structural invariants (used by property tests and when
    /// ingesting external data).
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.rpt.len() == self.rows + 1,
            "rpt length {} != rows+1 {}",
            self.rpt.len(),
            self.rows + 1
        );
        anyhow::ensure!(self.rpt[0] == 0, "rpt[0] != 0");
        anyhow::ensure!(
            self.rpt.windows(2).all(|w| w[0] <= w[1]),
            "rpt not monotone"
        );
        anyhow::ensure!(
            *self.rpt.last().unwrap() as usize == self.nnz(),
            "rpt[-1] {} != nnz {}",
            self.rpt.last().unwrap(),
            self.nnz()
        );
        anyhow::ensure!(self.col_ids.len() == self.vals.len(), "ids/vals mismatch");
        anyhow::ensure!(
            self.col_ids.iter().all(|&c| (c as usize) < self.cols),
            "col id out of range"
        );
        Ok(())
    }

    pub fn row_range(&self, r: usize) -> std::ops::Range<usize> {
        self.rpt[r] as usize..self.rpt[r + 1] as usize
    }

    pub fn to_coo(&self) -> Coo {
        let mut coo = Coo::new(self.rows, self.cols);
        for r in 0..self.rows {
            for i in self.row_range(r) {
                coo.push(r, self.col_ids[i] as usize, self.vals[i]);
            }
        }
        coo
    }

    pub fn to_dense(&self) -> Dense {
        self.to_coo().to_dense()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Csr {
        Csr {
            rows: 3,
            cols: 4,
            rpt: vec![0, 2, 2, 3],
            col_ids: vec![1, 3, 0],
            vals: vec![5.0, 6.0, 7.0],
        }
    }

    #[test]
    fn validates_good_matrix() {
        sample().validate().unwrap();
    }

    #[test]
    fn rejects_bad_rpt() {
        let mut m = sample();
        m.rpt[1] = 9;
        assert!(m.validate().is_err());
        let mut m2 = sample();
        m2.rpt = vec![0, 2, 1, 3];
        assert!(m2.validate().is_err());
    }

    #[test]
    fn rejects_col_out_of_range() {
        let mut m = sample();
        m.col_ids[0] = 4;
        assert!(m.validate().is_err());
    }

    #[test]
    fn coo_roundtrip() {
        let csr = sample();
        let back = csr.to_coo().to_csr();
        assert_eq!(csr, back);
    }

    #[test]
    fn empty_rows_ok() {
        let m = sample();
        assert_eq!(m.row_range(1), 2..2);
        assert_eq!(m.to_dense().at(1, 0), 0.0);
    }
}
