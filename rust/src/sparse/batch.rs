//! Zero-padded batch layouts — the ABI between the rust coordinator and
//! the AOT artifacts (DESIGN.md §3).
//!
//! JAX artifacts have static shapes, so a batch of variable-shape graphs
//! is packed into fixed `[B, ...]` buffers:
//!
//! * ST padding slots: `val = 0` at `(0, 0)` — contribute nothing.
//! * CSR padding: `rpt` repeats its final value for rows beyond the true
//!   row count (empty rows), and slots beyond `rpt[M]` are never read.
//!
//! This padding is the measurable analogue of the paper's "redundant
//! threads terminate immediately" load-imbalance handling; the ablation
//! bench quantifies its cost.

use super::coo::Coo;
use super::engine::DType;
use crate::util::rng::Rng;

/// Batched, padded SparseTensor: matches artifact inputs
/// `ids [B, NNZ, 2] i32` and `vals [B, NNZ] f32` (row-major flattening).
#[derive(Clone, Debug, PartialEq)]
pub struct PaddedStBatch {
    pub batch: usize,
    pub dim: usize,
    pub nnz_cap: usize,
    pub ids: Vec<i32>,
    pub vals: Vec<f32>,
    /// Real (non-padding) non-zeros of each sample, counted once at
    /// pack time so the engine's cost model (`BatchedSpmm::sample_nnz`)
    /// is O(1) per sample instead of an O(nnz_cap) scan on every
    /// work-stealing dispatch (DESIGN.md §10).
    pub nnz_per_sample: Vec<u32>,
}

impl PaddedStBatch {
    pub fn pack(mats: &[Coo], dim: usize, nnz_cap: usize) -> anyhow::Result<Self> {
        let batch = mats.len();
        let mut ids = vec![0i32; batch * nnz_cap * 2];
        let mut vals = vec![0f32; batch * nnz_cap];
        let mut nnz_per_sample = vec![0u32; batch];
        for (b, m) in mats.iter().enumerate() {
            anyhow::ensure!(
                m.rows <= dim && m.cols <= dim,
                "matrix {b} is {}x{}, bucket dim {dim}",
                m.rows,
                m.cols
            );
            anyhow::ensure!(
                m.nnz() <= nnz_cap,
                "matrix {b} has nnz {} > cap {nnz_cap}",
                m.nnz()
            );
            for i in 0..m.nnz() {
                ids[(b * nnz_cap + i) * 2] = m.row_ids[i] as i32;
                ids[(b * nnz_cap + i) * 2 + 1] = m.col_ids[i] as i32;
                vals[b * nnz_cap + i] = m.vals[i];
            }
            // Count what a scan of the padded slots would see: explicit
            // zero values pack like padding and the kernels skip them.
            nnz_per_sample[b] = m.vals.iter().filter(|v| **v != 0.0).count() as u32;
        }
        Ok(Self {
            batch,
            dim,
            nnz_cap,
            ids,
            vals,
            nnz_per_sample,
        })
    }

    /// Total *real* non-zeros (excludes padding) — the paper's FLOP
    /// numerator counts only these. O(batch), from the pack-time counts.
    pub fn real_nnz(&self) -> usize {
        self.nnz_per_sample.iter().map(|&c| c as usize).sum()
    }

    /// Padding fraction of nnz slots (ablation metric).
    pub fn pad_fraction(&self) -> f64 {
        1.0 - self.real_nnz() as f64 / (self.batch * self.nnz_cap) as f64
    }

    /// Slice one matrix back out (b < batch) for single-dispatch mode.
    pub fn single(&self, b: usize) -> PaddedStBatch {
        assert!(b < self.batch);
        PaddedStBatch {
            batch: 1,
            dim: self.dim,
            nnz_cap: self.nnz_cap,
            ids: self.ids[b * self.nnz_cap * 2..(b + 1) * self.nnz_cap * 2].to_vec(),
            vals: self.vals[b * self.nnz_cap..(b + 1) * self.nnz_cap].to_vec(),
            nnz_per_sample: vec![self.nnz_per_sample[b]],
        }
    }
}

/// Batched, padded CSR: matches artifact inputs `rpt [B, M+1] i32`,
/// `colids [B, NNZ] i32`, `vals [B, NNZ] f32`.
#[derive(Clone, Debug, PartialEq)]
pub struct PaddedCsrBatch {
    pub batch: usize,
    pub dim: usize,
    pub nnz_cap: usize,
    pub rpt: Vec<i32>,
    pub col_ids: Vec<i32>,
    pub vals: Vec<f32>,
}

impl PaddedCsrBatch {
    pub fn pack(mats: &[Coo], dim: usize, nnz_cap: usize) -> anyhow::Result<Self> {
        let batch = mats.len();
        let m1 = dim + 1;
        let mut rpt = vec![0i32; batch * m1];
        let mut col_ids = vec![0i32; batch * nnz_cap];
        let mut vals = vec![0f32; batch * nnz_cap];
        for (b, m) in mats.iter().enumerate() {
            anyhow::ensure!(
                m.rows <= dim && m.cols <= dim,
                "matrix {b} is {}x{}, bucket dim {dim}",
                m.rows,
                m.cols
            );
            anyhow::ensure!(
                m.nnz() <= nnz_cap,
                "matrix {b} has nnz {} > cap {nnz_cap}",
                m.nnz()
            );
            let csr = m.to_csr();
            for r in 0..=dim {
                // Rows past the true row count repeat the final pointer
                // (empty rows; the kernel's inner loop never runs).
                rpt[b * m1 + r] = csr.rpt[r.min(m.rows)] as i32;
            }
            for i in 0..csr.nnz() {
                col_ids[b * nnz_cap + i] = csr.col_ids[i] as i32;
                vals[b * nnz_cap + i] = csr.vals[i];
            }
        }
        Ok(Self {
            batch,
            dim,
            nnz_cap,
            rpt,
            col_ids,
            vals,
        })
    }

    pub fn single(&self, b: usize) -> PaddedCsrBatch {
        assert!(b < self.batch);
        let m1 = self.dim + 1;
        PaddedCsrBatch {
            batch: 1,
            dim: self.dim,
            nnz_cap: self.nnz_cap,
            rpt: self.rpt[b * m1..(b + 1) * m1].to_vec(),
            col_ids: self.col_ids[b * self.nnz_cap..(b + 1) * self.nnz_cap].to_vec(),
            vals: self.vals[b * self.nnz_cap..(b + 1) * self.nnz_cap].to_vec(),
        }
    }
}

/// Batched, padded ELL: `cols`/`vals` laid out `[B, dim, width]` with
/// per-row slots in insertion order and `val == 0` marking padding —
/// the same per-channel layout `graph::dataset::ModelBatch` packs
/// adjacency into, promoted to a first-class batch format so the
/// engine's ELL backend can run over figure-bench workloads too.
#[derive(Clone, Debug, PartialEq)]
pub struct PaddedEllBatch {
    pub batch: usize,
    pub dim: usize,
    pub width: usize,
    pub cols: Vec<i32>,
    pub vals: Vec<f32>,
    /// Real (non-padding) non-zeros of each sample, counted once at
    /// pack time — the O(1) cost-model source for the engine's ELL
    /// backend (DESIGN.md §10).
    pub nnz_per_sample: Vec<u32>,
}

impl PaddedEllBatch {
    pub fn pack(mats: &[Coo], dim: usize, width: usize) -> anyhow::Result<Self> {
        let batch = mats.len();
        let mut cols = vec![0i32; batch * dim * width];
        let mut vals = vec![0f32; batch * dim * width];
        let mut nnz_per_sample = vec![0u32; batch];
        for (b, m) in mats.iter().enumerate() {
            anyhow::ensure!(
                m.rows <= dim && m.cols <= dim,
                "matrix {b} is {}x{}, bucket dim {dim}",
                m.rows,
                m.cols
            );
            let base = b * dim * width;
            let mut fill = vec![0usize; dim];
            for i in 0..m.nnz() {
                let row = m.row_ids[i] as usize;
                let slot = fill[row];
                anyhow::ensure!(
                    slot < width,
                    "matrix {b} row {row} has more than width={width} non-zeros"
                );
                cols[base + row * width + slot] = m.col_ids[i] as i32;
                vals[base + row * width + slot] = m.vals[i];
                fill[row] += 1;
            }
            // Explicit zero values occupy a slot but scan as padding.
            nnz_per_sample[b] = m.vals.iter().filter(|v| **v != 0.0).count() as u32;
        }
        Ok(Self {
            batch,
            dim,
            width,
            cols,
            vals,
            nnz_per_sample,
        })
    }

    /// Pack with the tightest width that fits every row of the batch.
    pub fn pack_auto(mats: &[Coo], dim: usize) -> anyhow::Result<Self> {
        let width = mats
            .iter()
            .map(|m| {
                let mut fill = vec![0usize; m.rows];
                for &r in &m.row_ids {
                    fill[r as usize] += 1;
                }
                fill.into_iter().max().unwrap_or(0)
            })
            .max()
            .unwrap_or(0)
            .max(1);
        Self::pack(mats, dim, width)
    }

    /// Total *real* non-zeros (excludes padding). O(batch), from the
    /// pack-time counts.
    pub fn real_nnz(&self) -> usize {
        self.nnz_per_sample.iter().map(|&c| c as usize).sum()
    }

    /// Padding fraction of slots (ablation metric).
    pub fn pad_fraction(&self) -> f64 {
        1.0 - self.real_nnz() as f64 / (self.batch * self.dim * self.width) as f64
    }
}

/// f32 → bf16 by truncation: keep the sign, the full 8-bit exponent and
/// the top 7 mantissa bits. Truncation (rather than round-to-nearest)
/// keeps the conversion branch-free and preserves the padding contract
/// exactly — `0.0` truncates to bits `0`, so quantized padding slots
/// dequantize to exactly `0.0` and the ELL kernels' `val == 0.0` skip
/// still fires. Relative error of any non-zero value is below `2^-7`
/// (one ulp of the 8-bit significand), the bound the property tests pin
/// (DESIGN.md §16).
pub fn f32_to_bf16(v: f32) -> u16 {
    (v.to_bits() >> 16) as u16
}

/// bf16 → f32: exact (bf16 is a prefix of the f32 bit pattern).
pub fn bf16_to_f32(b: u16) -> f32 {
    f32::from_bits((b as u32) << 16)
}

/// Quantized ELL adjacency planes for the inference-only reduced
/// precision path ([`DType::Bf16`] / [`DType::Int8`], DESIGN.md §16).
///
/// The layout mirrors the f32 ELL planes (`[planes, rows, width]`, one
/// plane per (sample, channel) adjacency matrix) with the value array
/// quantized once at pack time; column ids stay i32. int8 uses a
/// per-plane affine scheme `v ≈ scale · (q − zero_point)` fitted to the
/// plane's value range (widened to include 0), so padding packs as
/// `q = zero_point` and dequantizes to exactly `0.0` — the same skip
/// contract as f32 padding. bf16 is truncation, so padding is bits `0`.
///
/// Error bounds, asserted by the property tests: bf16 per-value
/// relative error < `2^-7`; int8 per-value absolute error ≤ `scale/2`
/// (its plane's quantization step, half-up).
#[derive(Clone, Debug, PartialEq)]
pub struct QuantizedEllBatch {
    /// [`DType::Bf16`] or [`DType::Int8`] — never [`DType::F32`]
    /// (dispatch the f32 planes directly instead of quantizing).
    pub dtype: DType,
    /// Number of adjacency planes (`batch * channels` when packed from
    /// a model batch; one per sample for a plain ELL batch).
    pub planes: usize,
    pub rows: usize,
    pub width: usize,
    /// `[planes, rows, width]` column ids, copied from the f32 packing.
    pub cols: Vec<i32>,
    /// bf16 value planes (empty unless `dtype == Bf16`).
    pub vals_bf16: Vec<u16>,
    /// int8 value planes (empty unless `dtype == Int8`).
    pub vals_i8: Vec<i8>,
    /// Per-plane dequantization scale (`1.0` for bf16 planes, where it
    /// is unused).
    pub scale: Vec<f32>,
    /// Per-plane zero point (`0` for bf16 planes, where it is unused).
    pub zero_point: Vec<i8>,
    /// Real (dequantizes-non-zero) slots per plane — the O(1)
    /// cost-model source, counted once at quantization time. A real but
    /// tiny value can quantize onto the zero point and scan as padding;
    /// that loss is within the dtype's error bound.
    pub nnz_per_plane: Vec<u32>,
}

impl QuantizedEllBatch {
    /// Quantize raw ELL planes (`cols`/`vals` flattened
    /// `[planes, rows, width]`) at pack time. Rejects [`DType::F32`]
    /// with an actionable error.
    pub fn quantize(
        cols: &[i32],
        vals: &[f32],
        planes: usize,
        rows: usize,
        width: usize,
        dtype: DType,
    ) -> anyhow::Result<QuantizedEllBatch> {
        let per = rows * width;
        anyhow::ensure!(
            cols.len() == planes * per && vals.len() == planes * per,
            "ELL plane arrays have {} cols / {} vals, want {planes} planes * {rows} rows * {width} width",
            cols.len(),
            vals.len(),
        );
        let mut q = QuantizedEllBatch {
            dtype,
            planes,
            rows,
            width,
            cols: cols.to_vec(),
            vals_bf16: Vec::new(),
            vals_i8: Vec::new(),
            scale: vec![1.0; planes],
            zero_point: vec![0i8; planes],
            nnz_per_plane: vec![0u32; planes],
        };
        match dtype {
            DType::F32 => anyhow::bail!(
                "dtype f32 needs no quantized batch — dispatch the f32 ELL planes directly"
            ),
            DType::Bf16 => {
                q.vals_bf16 = vals.iter().map(|v| f32_to_bf16(*v)).collect();
                for p in 0..planes {
                    q.nnz_per_plane[p] = q.vals_bf16[p * per..(p + 1) * per]
                        .iter()
                        .filter(|b| bf16_to_f32(**b) != 0.0)
                        .count() as u32;
                }
            }
            DType::Int8 => {
                q.vals_i8 = vec![0i8; planes * per];
                for p in 0..planes {
                    let plane = &vals[p * per..(p + 1) * per];
                    // Fit the affine range to the plane, widened to
                    // include 0 so the zero point lands in [-128, 127]
                    // and padding is exactly representable.
                    let lo = plane.iter().fold(0f32, |a, &v| a.min(v));
                    let hi = plane.iter().fold(0f32, |a, &v| a.max(v));
                    let scale = if hi > lo { (hi - lo) / 255.0 } else { 1.0 };
                    let zp = (-128i32 - (lo / scale).round() as i32).clamp(-128, 127);
                    q.scale[p] = scale;
                    q.zero_point[p] = zp as i8;
                    let mut nnz = 0u32;
                    for (slot, &v) in plane.iter().enumerate() {
                        let qv = (zp + (v / scale).round() as i32).clamp(-128, 127) as i8;
                        q.vals_i8[p * per + slot] = qv;
                        nnz += u32::from(qv != zp as i8);
                    }
                    q.nnz_per_plane[p] = nnz;
                }
            }
        }
        Ok(q)
    }

    /// Quantize a packed f32 ELL batch (one plane per sample).
    pub fn from_padded(ell: &PaddedEllBatch, dtype: DType) -> anyhow::Result<QuantizedEllBatch> {
        QuantizedEllBatch::quantize(&ell.cols, &ell.vals, ell.batch, ell.dim, ell.width, dtype)
    }

    /// Dequantize one slot of one plane — the scalar reference the
    /// kernels inline and the property tests check against.
    #[inline]
    pub fn dequant(&self, plane: usize, slot: usize) -> f32 {
        let i = plane * self.rows * self.width + slot;
        match self.dtype {
            DType::F32 => unreachable!("quantized batch never holds f32"),
            DType::Bf16 => bf16_to_f32(self.vals_bf16[i]),
            DType::Int8 => {
                self.scale[plane] * (self.vals_i8[i] as i32 - self.zero_point[plane] as i32) as f32
            }
        }
    }

    /// Bytes of quantized value storage — the "bytes moved per
    /// dispatch" numerator the precision bench reports next to GFLOPS.
    pub fn value_bytes(&self) -> usize {
        self.planes * self.rows * self.width * self.dtype.value_bytes()
    }

    /// Total real (dequantizes-non-zero) slots across all planes.
    pub fn real_nnz(&self) -> usize {
        self.nnz_per_plane.iter().map(|&c| c as usize).sum()
    }
}

/// One giant graph packed as a batch of one for the engine's CSR
/// backend — the large-graph tier's dispatch unit (DESIGN.md §12).
///
/// Unlike the molecule buckets there is no padding dimension to
/// amortize: the wrapped [`PaddedCsrBatch`] has `batch = 1`, `dim =
/// nodes` and `nnz_cap` equal to the *exact* non-zero count, so the
/// existing CSR kernel runs it unchanged and every slot is real. The
/// packing also captures the degree profile (max degree, log2-degree
/// histogram) once at construction — the skew statistics the
/// degree-bucketed planner's behavior is judged against, without
/// rescanning a million-row `rpt` per query.
#[derive(Clone, Debug, PartialEq)]
pub struct LargeGraphBatch {
    csr: PaddedCsrBatch,
    /// Row `r`'s out-degree histogram bucket is `floor(log2(deg)) + 1`
    /// (`bucket 0` = isolated rows), so `degree_hist[b]` counts rows
    /// with degree in `[2^(b-1), 2^b)`.
    pub degree_hist: Vec<usize>,
    pub max_degree: usize,
}

impl LargeGraphBatch {
    /// Wrap one graph's CSR arrays (`rpt` of length `nodes + 1`,
    /// `col_ids`/`vals` of length `rpt[nodes]`). Validates the row
    /// pointers and column ids so the kernel's unchecked indexing is
    /// safe by construction.
    pub fn from_csr_parts(
        nodes: usize,
        rpt: Vec<i32>,
        col_ids: Vec<i32>,
        vals: Vec<f32>,
    ) -> anyhow::Result<LargeGraphBatch> {
        anyhow::ensure!(nodes > 0, "graph has no nodes");
        anyhow::ensure!(rpt.len() == nodes + 1, "rpt length {} != nodes + 1", rpt.len());
        anyhow::ensure!(rpt[0] == 0, "rpt must start at 0");
        let mut degree_hist = Vec::new();
        let mut max_degree = 0usize;
        for r in 0..nodes {
            anyhow::ensure!(rpt[r] <= rpt[r + 1], "rpt not monotone at row {r}");
            let deg = (rpt[r + 1] - rpt[r]) as usize;
            max_degree = max_degree.max(deg);
            let bucket = (usize::BITS - deg.leading_zeros()) as usize;
            if degree_hist.len() <= bucket {
                degree_hist.resize(bucket + 1, 0);
            }
            degree_hist[bucket] += 1;
        }
        let nnz = rpt[nodes] as usize;
        anyhow::ensure!(col_ids.len() == nnz, "col_ids length {} != nnz {nnz}", col_ids.len());
        anyhow::ensure!(vals.len() == nnz, "vals length {} != nnz {nnz}", vals.len());
        anyhow::ensure!(
            col_ids.iter().all(|&c| (c as usize) < nodes && c >= 0),
            "column id out of range"
        );
        Ok(LargeGraphBatch {
            csr: PaddedCsrBatch {
                batch: 1,
                dim: nodes,
                nnz_cap: nnz.max(1),
                rpt,
                col_ids,
                vals,
            },
            degree_hist,
            max_degree,
        })
    }

    /// The batch-of-one CSR view the engine's `CsrKernel` dispatches.
    pub fn csr(&self) -> &PaddedCsrBatch {
        &self.csr
    }

    pub fn nodes(&self) -> usize {
        self.csr.dim
    }

    pub fn nnz(&self) -> usize {
        self.csr.rpt[self.csr.dim] as usize
    }

    /// Degree skew `max_degree / mean_degree` — > ~3 is the power-law
    /// regime where the degree-bucketed row split pays (DESIGN.md §12).
    pub fn skew(&self) -> f64 {
        let mean = self.nnz() as f64 / self.nodes() as f64;
        if mean > 0.0 {
            self.max_degree as f64 / mean
        } else {
            0.0
        }
    }
}

/// Densified adjacency batch `[B, dim, dim]` — the GEMM baseline input.
pub fn densify_batch(mats: &[Coo], dim: usize) -> Vec<f32> {
    let mut out = vec![0f32; mats.len() * dim * dim];
    for (b, m) in mats.iter().enumerate() {
        let base = b * dim * dim;
        for i in 0..m.nnz() {
            out[base + m.row_ids[i] as usize * dim + m.col_ids[i] as usize] += m.vals[i];
        }
    }
    out
}

/// Random dense operand batch `[B, dim, n_b]` for the SpMM benches.
pub fn random_dense_batch(rng: &mut Rng, batch: usize, dim: usize, n_b: usize) -> Vec<f32> {
    (0..batch * dim * n_b).map(|_| rng.normal()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::random::{random_batch, random_mixed_batch, RandomSpec};
    use crate::util::rng::Rng;

    #[test]
    fn st_pack_layout() {
        let mut m = Coo::new(2, 2);
        m.push(1, 0, 5.0);
        let b = PaddedStBatch::pack(&[m], 4, 3).unwrap();
        assert_eq!(b.ids[0], 1);
        assert_eq!(b.ids[1], 0);
        assert_eq!(b.vals[0], 5.0);
        assert_eq!(b.vals[1], 0.0); // padding
        assert_eq!(b.real_nnz(), 1);
        assert!((b.pad_fraction() - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn csr_pack_pads_rows_as_empty() {
        let mut m = Coo::new(2, 2);
        m.push(0, 1, 1.0);
        m.push(1, 0, 2.0);
        let b = PaddedCsrBatch::pack(&[m], 4, 4).unwrap();
        // rpt = [0,1,2,2,2]: rows 2..4 empty
        assert_eq!(&b.rpt[..5], &[0, 1, 2, 2, 2]);
    }

    #[test]
    fn pack_rejects_oversize() {
        let mut m = Coo::new(8, 8);
        m.push(0, 0, 1.0);
        assert!(PaddedStBatch::pack(&[m.clone()], 4, 16).is_err()); // dim
        let mut m2 = Coo::new(2, 2);
        for _ in 0..5 {
            m2.push(0, 0, 1.0);
        }
        assert!(PaddedStBatch::pack(&[m2], 4, 4).is_err()); // nnz
    }

    #[test]
    fn single_extracts_matrix() {
        let mut rng = Rng::new(6);
        let mats = random_batch(&mut rng, &RandomSpec::new(8, 2), 5);
        let st = PaddedStBatch::pack(&mats, 8, 16).unwrap();
        let one = st.single(3);
        assert_eq!(one.batch, 1);
        assert_eq!(one.vals, &st.vals[3 * 16..4 * 16]);
        let csr = PaddedCsrBatch::pack(&mats, 8, 16).unwrap();
        let onec = csr.single(2);
        assert_eq!(onec.rpt, &csr.rpt[2 * 9..3 * 9]);
    }

    #[test]
    fn ell_pack_layout_and_auto_width() {
        let mut m = Coo::new(3, 3);
        m.push(0, 2, 1.0);
        m.push(0, 1, 2.0);
        m.push(2, 0, 3.0);
        let e = PaddedEllBatch::pack(&[m.clone()], 4, 2).unwrap();
        // row 0 slots in insertion order, rows 1/3 empty (padding)
        assert_eq!(&e.cols[..2], &[2, 1]);
        assert_eq!(&e.vals[..2], &[1.0, 2.0]);
        assert_eq!(e.vals[2 * 2], 3.0);
        assert_eq!(e.real_nnz(), 3);
        // width 1 cannot hold row 0's two entries
        assert!(PaddedEllBatch::pack(&[m.clone()], 4, 1).is_err());
        let auto = PaddedEllBatch::pack_auto(&[m], 4).unwrap();
        assert_eq!(auto.width, 2);
        assert!(auto.pad_fraction() > 0.0);
    }

    #[test]
    fn densify_matches_coo_dense() {
        let mut rng = Rng::new(7);
        let mats = random_batch(&mut rng, &RandomSpec::new(6, 2), 3);
        let flat = densify_batch(&mats, 6);
        for (b, m) in mats.iter().enumerate() {
            let d = m.to_dense();
            for r in 0..6 {
                for c in 0..6 {
                    assert_eq!(flat[b * 36 + r * 6 + c], d.at(r, c));
                }
            }
        }
    }

    #[test]
    fn cached_nnz_counts_match_recomputed_scan_on_random_batches() {
        // The pack-time per-sample counts must always equal what a
        // from-scratch scan of the padded value arrays reports — the
        // O(1) cost-model contract (DESIGN.md §10) — including when a
        // COO carries an explicit zero value (packed like padding).
        let mut rng = Rng::new(0x77);
        for case in 0..8 {
            let dim = rng.range(4, 24);
            let batch = rng.range(1, 10);
            let mut mats = random_mixed_batch(&mut rng, (2, dim), (1, 3), batch);
            let mut withzero = Coo::new(2, 2);
            withzero.push(0, 1, 0.0); // explicit zero: scans as padding
            withzero.push(1, 0, 2.5);
            mats.push(withzero);
            let cap = mats.iter().map(Coo::nnz).max().unwrap();
            let st = PaddedStBatch::pack(&mats, dim, cap).unwrap();
            let ell = PaddedEllBatch::pack_auto(&mats, dim).unwrap();
            for b in 0..mats.len() {
                let st_scan = st.vals[b * cap..(b + 1) * cap]
                    .iter()
                    .filter(|v| **v != 0.0)
                    .count();
                assert_eq!(
                    st.nnz_per_sample[b] as usize, st_scan,
                    "case {case} st sample {b}"
                );
                let per = ell.dim * ell.width;
                let ell_scan = ell.vals[b * per..(b + 1) * per]
                    .iter()
                    .filter(|v| **v != 0.0)
                    .count();
                assert_eq!(
                    ell.nnz_per_sample[b] as usize, ell_scan,
                    "case {case} ell sample {b}"
                );
                assert_eq!(st.single(b).nnz_per_sample, vec![st.nnz_per_sample[b]]);
            }
            assert_eq!(
                st.real_nnz(),
                st.vals.iter().filter(|v| **v != 0.0).count()
            );
            assert_eq!(
                ell.real_nnz(),
                ell.vals.iter().filter(|v| **v != 0.0).count()
            );
        }
    }

    #[test]
    fn large_graph_batch_wraps_exact_csr_and_profiles_degrees() {
        // 5-node graph: degrees [3, 1, 0, 2, 1].
        let rpt = vec![0, 3, 4, 4, 6, 7];
        let col_ids = vec![0, 1, 3, 0, 2, 4, 3];
        let vals = vec![1.0f32; 7];
        let g = LargeGraphBatch::from_csr_parts(5, rpt, col_ids, vals).unwrap();
        assert_eq!(g.nodes(), 5);
        assert_eq!(g.nnz(), 7);
        assert_eq!(g.max_degree, 3);
        // buckets: 0 -> deg 0 (1 row), 1 -> deg 1 (2 rows), 2 -> deg
        // 2..3 (2 rows).
        assert_eq!(g.degree_hist, vec![1, 2, 2]);
        assert!((g.skew() - 3.0 / (7.0 / 5.0)).abs() < 1e-12);
        let csr = g.csr();
        assert_eq!((csr.batch, csr.dim, csr.nnz_cap), (1, 5, 7));

        // Validation rejects malformed parts.
        assert!(LargeGraphBatch::from_csr_parts(2, vec![0, 1], vec![0], vec![1.0]).is_err());
        assert!(
            LargeGraphBatch::from_csr_parts(2, vec![0, 2, 1], vec![0, 1], vec![1.0; 2]).is_err()
        );
        assert!(
            LargeGraphBatch::from_csr_parts(2, vec![0, 1, 2], vec![0, 5], vec![1.0; 2]).is_err()
        );
    }

    #[test]
    fn bf16_round_trip_is_exact_and_truncation_bounds_relative_error() {
        // bf16 is a prefix of the f32 bit pattern, so bf16 → f32 → bf16
        // must be exact; f32 → bf16 truncation keeps every non-zero
        // value within one 8-bit-significand ulp (relative < 2^-7).
        let mut rng = Rng::new(0xBF16);
        for _ in 0..2000 {
            let v = rng.normal() * 10f32.powi(rng.range(0, 9) as i32 - 4);
            let b = f32_to_bf16(v);
            let back = bf16_to_f32(b);
            assert_eq!(f32_to_bf16(back), b, "v={v}");
            if v != 0.0 {
                assert!(
                    (back - v).abs() <= v.abs() / 128.0,
                    "v={v} back={back}: relative error above 2^-7"
                );
            }
        }
        assert_eq!(f32_to_bf16(0.0), 0);
        assert_eq!(bf16_to_f32(0), 0.0);
    }

    #[test]
    fn quantized_ell_error_bounds_hold_per_plane() {
        // The pack-time quantization contract (DESIGN.md §16): per
        // plane, bf16 values stay within 2^-7 relative error, int8
        // values within scale/2 absolute error, and every padding slot
        // dequantizes to exactly 0.0 so the kernels' skip still fires.
        let mut rng = Rng::new(0x0801);
        for case in 0..8 {
            let dim = rng.range(4, 20);
            let mats = random_mixed_batch(&mut rng, (2, dim), (1, 3), rng.range(2, 7));
            let ell = PaddedEllBatch::pack_auto(&mats, dim).unwrap();
            let per = ell.dim * ell.width;
            for dtype in [DType::Bf16, DType::Int8] {
                let q = QuantizedEllBatch::from_padded(&ell, dtype).unwrap();
                assert_eq!(q.cols, ell.cols, "case {case} {dtype}: cols must be shared");
                for p in 0..q.planes {
                    for slot in 0..per {
                        let v = ell.vals[p * per + slot];
                        let d = q.dequant(p, slot);
                        if v == 0.0 {
                            assert_eq!(d, 0.0, "case {case} {dtype} plane {p} slot {slot}: padding");
                            continue;
                        }
                        match dtype {
                            DType::Bf16 => assert!(
                                (d - v).abs() <= v.abs() / 128.0,
                                "case {case} plane {p} slot {slot}: bf16 {d} vs {v}"
                            ),
                            DType::Int8 => assert!(
                                (d - v).abs() <= q.scale[p] * 0.5 + q.scale[p] * 1e-4,
                                "case {case} plane {p} slot {slot}: int8 {d} vs {v} (scale {})",
                                q.scale[p]
                            ),
                            DType::F32 => unreachable!(),
                        }
                    }
                    // The cached count matches a dequantizing rescan.
                    let scan = (0..per).filter(|&s| q.dequant(p, s) != 0.0).count();
                    assert_eq!(q.nnz_per_plane[p] as usize, scan, "case {case} {dtype} plane {p}");
                }
                assert_eq!(
                    q.value_bytes(),
                    q.planes * per * dtype.value_bytes(),
                    "case {case} {dtype}"
                );
            }
        }
        // f32 is rejected with an actionable message.
        let err = QuantizedEllBatch::quantize(&[0], &[0.0], 1, 1, 1, DType::F32)
            .unwrap_err()
            .to_string();
        assert!(err.contains("f32"), "got: {err}");
    }

    #[test]
    fn int8_all_zero_and_one_sided_planes_quantize_sanely() {
        // Degenerate planes: all-zero (scale falls back to 1.0, every
        // slot is the zero point) and strictly-positive values (the
        // range widens to include 0 so padding stays representable).
        let cols = vec![0i32; 8];
        let zeros = vec![0f32; 8];
        let q = QuantizedEllBatch::quantize(&cols, &zeros, 1, 2, 4, DType::Int8).unwrap();
        assert_eq!(q.scale[0], 1.0);
        assert_eq!(q.real_nnz(), 0);
        assert!((0..8).all(|s| q.dequant(0, s) == 0.0));

        let pos = vec![3.0f32, 1.5, 0.0, 2.25, 4.5, 0.0, 0.75, 3.75];
        let q = QuantizedEllBatch::quantize(&cols, &pos, 1, 2, 4, DType::Int8).unwrap();
        assert_eq!(q.zero_point[0], -128, "range widened to [0, hi]");
        for (s, &v) in pos.iter().enumerate() {
            if v == 0.0 {
                assert_eq!(q.dequant(0, s), 0.0);
            } else {
                assert!((q.dequant(0, s) - v).abs() <= q.scale[0] * 0.5 + 1e-6);
            }
        }
        assert_eq!(q.real_nnz(), 6);
    }

    #[test]
    fn mixed_batch_packs_into_max_bucket() {
        let mut rng = Rng::new(8);
        let mats = random_mixed_batch(&mut rng, (4, 16), (1, 3), 20);
        let st = PaddedStBatch::pack(&mats, 16, 16 * 3).unwrap();
        assert!(st.pad_fraction() > 0.0);
        let csr = PaddedCsrBatch::pack(&mats, 16, 16 * 3).unwrap();
        assert_eq!(csr.rpt.len(), 20 * 17);
    }
}
