//! TensorFlow-style `SparseTensor` (paper Fig. 1, §II-B): non-zeros as
//! an interleaved `[row, col]` id array plus a value array.  The paper
//! assumes non-zeros are *not* sorted by row or column (§IV) — nothing
//! here relies on ordering.

use super::coo::Coo;
use super::dense::Dense;

#[derive(Clone, Debug, PartialEq)]
pub struct SparseTensor {
    pub rows: usize,
    pub cols: usize,
    /// Interleaved: `ids[2*i]` = row of nnz i, `ids[2*i+1]` = col.
    pub ids: Vec<u32>,
    pub vals: Vec<f32>,
}

impl SparseTensor {
    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.ids.len() == 2 * self.vals.len(),
            "ids length {} != 2*nnz {}",
            self.ids.len(),
            2 * self.vals.len()
        );
        for i in 0..self.nnz() {
            let (r, c) = (self.ids[2 * i] as usize, self.ids[2 * i + 1] as usize);
            anyhow::ensure!(
                r < self.rows && c < self.cols,
                "nnz {i} at ({r},{c}) out of {}x{}",
                self.rows,
                self.cols
            );
        }
        Ok(())
    }

    #[inline]
    pub fn entry(&self, i: usize) -> (usize, usize, f32) {
        (
            self.ids[2 * i] as usize,
            self.ids[2 * i + 1] as usize,
            self.vals[i],
        )
    }

    pub fn to_coo(&self) -> Coo {
        let mut coo = Coo::new(self.rows, self.cols);
        for i in 0..self.nnz() {
            let (r, c, v) = self.entry(i);
            coo.push(r, c, v);
        }
        coo
    }

    pub fn to_dense(&self) -> Dense {
        self.to_coo().to_dense()
    }

    /// Transpose = swap each id pair (the SpMM backward operand; this is
    /// why the ST format makes the fused fwd/bwd batching cheap).
    pub fn transposed(&self) -> SparseTensor {
        let mut ids = Vec::with_capacity(self.ids.len());
        for i in 0..self.nnz() {
            ids.push(self.ids[2 * i + 1]);
            ids.push(self.ids[2 * i]);
        }
        SparseTensor {
            rows: self.cols,
            cols: self.rows,
            ids,
            vals: self.vals.clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> SparseTensor {
        SparseTensor {
            rows: 3,
            cols: 3,
            ids: vec![1, 2, 0, 1, 1, 0],
            vals: vec![3.0, 1.0, 2.0],
        }
    }

    #[test]
    fn validate_and_entries() {
        let st = sample();
        st.validate().unwrap();
        assert_eq!(st.entry(0), (1, 2, 3.0));
        assert_eq!(st.nnz(), 3);
    }

    #[test]
    fn rejects_out_of_range() {
        let mut st = sample();
        st.ids[0] = 3;
        assert!(st.validate().is_err());
    }

    #[test]
    fn rejects_odd_ids() {
        let mut st = sample();
        st.ids.pop();
        assert!(st.validate().is_err());
    }

    #[test]
    fn transpose_matches_dense() {
        let st = sample();
        let t = st.transposed().to_dense();
        let d = st.to_dense();
        for r in 0..3 {
            for c in 0..3 {
                assert_eq!(d.at(r, c), t.at(c, r));
            }
        }
    }

    #[test]
    fn coo_roundtrip_dense_equal() {
        let st = sample();
        assert_eq!(st.to_dense(), st.to_coo().to_sparse_tensor().to_dense());
    }
}
