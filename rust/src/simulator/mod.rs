//! P100 GPU cost-model simulator (S7 in DESIGN.md §5).
//!
//! This environment has no GPU (the repro gate), so the paper's
//! *absolute* GFLOPS landscape is regenerated analytically: every
//! algorithm in the evaluation (TF SparseTensorDenseMatMul, cuSPARSE
//! csrmm, the two Batched SpMM variants, cuBLAS gemmBatched) gets a
//! cost model over the same resource vocabulary the paper argues in —
//! kernel-launch overhead, host-side pointer-array assembly, PCIe
//! transfer latency, SM occupancy, memory bandwidth, and atomic
//! contention.
//!
//! Constants are calibrated against the paper's own published numbers
//! (Table IV per-op times, the 9.27x / 6.09x / 1.26x / 1.43x / 3.29x
//! speedups, and the 35.51% -> 89.07% sm_efficiency jump); the
//! calibration tests in [`cost`] pin those ratios. Measured CPU-PJRT
//! numbers (the real half of every bench) are produced by the bench
//! harness instead.

pub mod cost;
pub mod device;
pub mod timeline;

pub use cost::{CostModel, KernelKind, OpCost};
pub use device::DeviceSpec;
pub use timeline::{simulate_layer, LayerSim, OpEvent};
