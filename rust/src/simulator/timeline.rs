//! Fig. 11 / Table IV: the per-op timeline of one graph-convolution
//! layer over one minibatch, in both dispatch modes.
//!
//! Non-batched (Fig. 6): `batchsize * 3` op dispatches (MatMul, Add,
//! SpMM per sample, per channel — we follow the paper's figure, which
//! shows the three ops per sample for one channel).
//! Batched (Fig. 7): exactly 3 dispatches for the whole minibatch.

use super::cost::{CostModel, OpCost};

/// One op execution in the simulated timeline.
#[derive(Clone, Debug)]
pub struct OpEvent {
    pub op: &'static str,
    pub start_us: f64,
    pub end_us: f64,
}

impl OpEvent {
    pub fn dur_us(&self) -> f64 {
        self.end_us - self.start_us
    }
}

/// Simulated layer execution: events plus per-op aggregates.
#[derive(Clone, Debug)]
pub struct LayerSim {
    pub events: Vec<OpEvent>,
    pub matmul_us: f64,
    pub add_us: f64,
    pub spmm_us: f64,
    pub launches: usize,
}

impl LayerSim {
    pub fn total_us(&self) -> f64 {
        self.events.last().map(|e| e.end_us).unwrap_or(0.0)
    }
}

/// Simulate one graph-convolution layer (Tox21 geometry by default:
/// m=50, f_in=16, f_out=64, z~2) over a minibatch.
pub fn simulate_layer(
    cm: &CostModel,
    batch: usize,
    m: usize,
    f_in: usize,
    f_out: usize,
    z: usize,
    batched: bool,
) -> LayerSim {
    let mut events = Vec::new();
    let mut t = 0.0;
    let push = |events: &mut Vec<OpEvent>, op: &'static str, cost: &OpCost, t: &mut f64| {
        let dur = cost.total_us();
        events.push(OpEvent {
            op,
            start_us: *t,
            end_us: *t + dur,
        });
        *t += dur;
    };

    let mut launches = 0;
    if batched {
        // Fig. 7: three device ops for the whole minibatch.
        let mm = cm.matmul(m * batch, f_in, f_out);
        push(&mut events, "MatMul", &mm, &mut t);
        launches += mm.launches;
        let add = cm.elementwise(m * batch, f_out);
        push(&mut events, "Add", &add, &mut t);
        launches += add.launches;
        let spmm = cm.batched_spmm_st(batch, m, z, f_out);
        push(&mut events, "SpMM", &spmm, &mut t);
        launches += spmm.launches;
    } else {
        // Fig. 6: per-sample MatMul / Add / SpMM sequences.
        for _ in 0..batch {
            let mm = cm.matmul(m, f_in, f_out);
            push(&mut events, "MatMul", &mm, &mut t);
            launches += mm.launches;
            let add = cm.elementwise(m, f_out);
            push(&mut events, "Add", &add, &mut t);
            launches += add.launches;
            let spmm = cm.tf_spmm_op(m, z, f_out);
            push(&mut events, "SpMM", &spmm, &mut t);
            launches += spmm.launches;
        }
    }

    let sum = |name: &str| -> f64 {
        events
            .iter()
            .filter(|e| e.op == name)
            .map(OpEvent::dur_us)
            .sum()
    };
    LayerSim {
        matmul_us: sum("MatMul"),
        add_us: sum("Add"),
        spmm_us: sum("SpMM"),
        launches,
        events,
    }
}

/// Render a Fig. 11-style ASCII timeline (one lane per op kind).
pub fn render_timeline(sim: &LayerSim, width: usize) -> String {
    let total = sim.total_us().max(1e-9);
    let mut out = String::new();
    for lane in ["MatMul", "Add", "SpMM"] {
        let mut row = vec![b' '; width];
        for e in sim.events.iter().filter(|e| e.op == lane) {
            let s = ((e.start_us / total) * width as f64) as usize;
            let t = (((e.end_us / total) * width as f64).ceil() as usize).min(width);
            for c in row.iter_mut().take(t).skip(s.min(width.saturating_sub(1))) {
                *c = b'#';
            }
        }
        out.push_str(&format!(
            "{lane:>7} |{}| {:9.1} us\n",
            String::from_utf8(row).unwrap(),
            match lane {
                "MatMul" => sim.matmul_us,
                "Add" => sim.add_us,
                _ => sim.spmm_us,
            }
        ));
    }
    out.push_str(&format!(
        "  total {:.1} us, {} kernel launches\n",
        sim.total_us(),
        sim.launches
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tox21_layer(batched: bool) -> LayerSim {
        simulate_layer(&CostModel::default(), 50, 50, 16, 64, 2, batched)
    }

    #[test]
    fn launch_counts_match_fig11() {
        // "the non-batched approach requires batchsize*3 = 150 times of
        // CUDA kernel launches while the batched approach requires only
        // three" — our TF SpMM op counts its extra init launch, so the
        // non-batched side is batch*(1+1+2) = 200 raw launches over 150
        // framework ops; the framework-op count is the Fig. 11 claim.
        let nb = tox21_layer(false);
        let b = tox21_layer(true);
        assert_eq!(nb.events.len(), 150);
        assert_eq!(b.events.len(), 3);
        assert!(nb.launches > b.launches * 30);
    }

    #[test]
    fn per_op_totals_anchor_table4() {
        // Paper Table IV [us]: MatMul 1571 -> 31, Add 1316 -> 23,
        // SpMM 1981 -> 190. Bands are generous: this is a model.
        let nb = tox21_layer(false);
        assert!((900.0..2500.0).contains(&nb.matmul_us), "mm {}", nb.matmul_us);
        assert!((800.0..2200.0).contains(&nb.add_us), "add {}", nb.add_us);
        assert!((1200.0..2800.0).contains(&nb.spmm_us), "spmm {}", nb.spmm_us);
        let b = tox21_layer(true);
        assert!((15.0..60.0).contains(&b.matmul_us), "mm_b {}", b.matmul_us);
        assert!((15.0..50.0).contains(&b.add_us), "add_b {}", b.add_us);
        assert!((130.0..260.0).contains(&b.spmm_us), "spmm_b {}", b.spmm_us);
    }

    #[test]
    fn batched_layer_much_faster() {
        let nb = tox21_layer(false);
        let b = tox21_layer(true);
        let speedup = nb.total_us() / b.total_us();
        assert!(speedup > 5.0, "layer speedup only {speedup}");
    }

    #[test]
    fn timeline_renders() {
        let b = tox21_layer(true);
        let s = render_timeline(&b, 60);
        assert!(s.contains("MatMul"));
        assert!(s.contains("launches"));
        assert!(s.lines().count() == 4);
    }

    #[test]
    fn events_are_contiguous_and_ordered() {
        let nb = tox21_layer(false);
        for w in nb.events.windows(2) {
            assert!(w[0].end_us <= w[1].start_us + 1e-9);
        }
        assert!(nb.events.iter().all(|e| e.dur_us() > 0.0));
    }
}
