//! Device + software-stack constants for the cost model.

/// Hardware spec plus the framework-overhead constants the paper's
/// analysis hinges on. Defaults model TSUBAME3.0's Tesla P100-SXM2 with
//  TensorFlow 1.8 / CUDA 9 (paper §V).
#[derive(Clone, Debug)]
pub struct DeviceSpec {
    pub name: &'static str,
    pub sms: usize,
    pub fp32_cores_per_sm: usize,
    pub clock_ghz: f64,
    pub mem_bw_gbs: f64,
    pub smem_per_sm_kb: usize,
    /// Max thread blocks resident per SM (occupancy ceiling for the
    /// small blocks these kernels use).
    pub max_blocks_per_sm: usize,
    pub threads_per_block: usize,

    // ---- software-stack constants (calibrated; see cost.rs tests) ----
    /// TF-1.8 per-op dispatch overhead (session graph executor), us.
    pub framework_op_us: f64,
    /// CUDA kernel launch overhead, us.
    pub launch_us: f64,
    /// PCIe H2D transfer latency per distinct transfer, us (the paper:
    /// "our evaluation for batched approaches includes memory transfer
    /// of pointer arrays from host to device").
    pub h2d_latency_us: f64,
    /// Host-side cost to accumulate one matrix's pointers into the
    /// batched argument arrays, us per matrix.
    pub host_ptr_us: f64,
}

impl DeviceSpec {
    pub fn p100() -> Self {
        DeviceSpec {
            name: "Tesla P100-SXM2",
            sms: 56,
            fp32_cores_per_sm: 64,
            clock_ghz: 1.48,
            mem_bw_gbs: 732.0,
            smem_per_sm_kb: 64,
            max_blocks_per_sm: 2, // 32 KB smem per block -> 2 resident
            threads_per_block: 256,
            framework_op_us: 16.0,
            launch_us: 6.0,
            h2d_latency_us: 9.0,
            host_ptr_us: 2.0,
        }
    }

    /// Peak FP32 throughput in GFLOPS (FMA counts as 2).
    pub fn peak_gflops(&self) -> f64 {
        self.sms as f64 * self.fp32_cores_per_sm as f64 * 2.0 * self.clock_ghz
    }

    /// sm_efficiency for a kernel with `blocks` thread blocks — the
    /// nvprof metric the paper reports (% of SMs with >= 1 active
    /// block, time-averaged; for these short kernels one wave
    /// dominates, so it is blocks/sms capped at 1).
    pub fn sm_efficiency(&self, blocks: usize) -> f64 {
        (blocks as f64 / self.sms as f64).min(1.0)
    }

    /// Number of sequential block waves for `blocks` thread blocks.
    pub fn waves(&self, blocks: usize) -> f64 {
        let concurrent = (self.sms * self.max_blocks_per_sm) as f64;
        (blocks as f64 / concurrent).ceil().max(1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn p100_headline_numbers() {
        let d = DeviceSpec::p100();
        // 56 SMs x 64 cores x 2 x 1.48 GHz = 10.6 TFLOPS (P100 spec ~10.6)
        assert!((d.peak_gflops() - 10_608.6).abs() < 1.0);
        assert_eq!(d.sms, 56);
    }

    #[test]
    fn sm_efficiency_caps_at_one() {
        let d = DeviceSpec::p100();
        assert!((d.sm_efficiency(28) - 0.5).abs() < 1e-12);
        assert_eq!(d.sm_efficiency(500), 1.0);
    }

    #[test]
    fn waves_monotone() {
        let d = DeviceSpec::p100();
        assert_eq!(d.waves(1), 1.0);
        assert_eq!(d.waves(112), 1.0);
        assert_eq!(d.waves(113), 2.0);
        assert!(d.waves(1000) >= d.waves(500));
    }
}
