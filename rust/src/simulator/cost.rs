//! Per-algorithm kernel cost models, calibrated to the paper's anchors.
//!
//! Components per operation (all microseconds):
//!   framework — TF-1.8 op-dispatch overhead
//!   launch    — CUDA kernel launch(es)
//!   transfer  — PCIe H2D latency for argument/pointer arrays
//!   host      — host-side batched pointer-array assembly (per matrix)
//!   kernel    — device time: waves x per-block latency, or a
//!               bandwidth/throughput bound, whichever model fits the
//!               algorithm
//!
//! Calibration anchors (see tests): Table IV per-op times (MatMul
//! 1571->31us, Add 1316->23us, SpMM 1981->190us for a 50-sample
//! minibatch), the headline speedups 9.27x (fig8a), 6.09x (fig8b),
//! 1.26x / 1.43x vs cuBLAS, 3.29x (fig10 mixed), and nvprof
//! sm_efficiency 35.51% -> ~89%.
//!
//! The model's *structural* behaviours are emergent, not pinned:
//! CSR gains with dim (more row-parallel blocks), ST loses under
//! column blocking (nnz re-walked per column block) and atomic density,
//! GEMM wins at small n_B (cheaper host/transfer) and loses at large
//! n_B / high sparsity.

use super::device::DeviceSpec;

/// Cycles one subWarp-wide vector op costs in the ST kernel (shared-mem
/// atomic read-modify-write latency chain).
const C_ST_VEC: f64 = 200.0;
/// Same for the CSR kernel (register accumulate, no atomics).
const C_CSR_VEC: f64 = 175.0;
/// ST atomic-contention derate per unit nnz/row.
const ATOMIC_SLOPE: f64 = 0.06;
/// Fixed per-kernel pipeline latency floor, us.
const KERNEL_FLOOR_US: f64 = 2.0;
/// Achieved cuBLAS gemmBatched throughput:
/// `C * (m/50) * n_B^0.72 + FLOOR` GFLOPS (fitted to the 1.26x/1.43x
/// crossover anchors), capped near 40% of peak.
const GEMM_ACHIEVED_C: f64 = 3.77;
const GEMM_ACHIEVED_FLOOR_GFLOPS: f64 = 25.0;
const GEMM_ACHIEVED_CAP_GFLOPS: f64 = 4000.0;
/// Global-memory atomic traffic amplification in the TF baseline.
const TF_ATOMIC_AMP: f64 = 4.0;
/// Uncoalesced-read amplification in the TF baseline.
const TF_UNCOAL_AMP: f64 = 2.0;

/// The paper's subWarp policy (§IV-A) — mirrored by
/// `python/compile/kernels/blocking.py::subwarp` (golden tests on both
/// sides pin the contract).
pub fn subwarp(n_b: usize) -> usize {
    if n_b > 16 {
        32
    } else {
        n_b.next_power_of_two()
    }
}

/// Column blocking plan (§IV-B/C, 32 KB budget) — mirrors
/// `blocking.plan_blocks`. Returns (block_n, n_blocks).
pub fn plan_col_blocks(m: usize, n_b: usize) -> (usize, usize) {
    plan_col_blocks_with_budget(m, n_b, 32 * 1024)
}

/// Budget-parameterized variant (the ablation bench sweeps the budget).
pub fn plan_col_blocks_with_budget(m: usize, n_b: usize, budget: usize) -> (usize, usize) {
    if m * n_b * 4 <= budget {
        return (n_b, 1);
    }
    let mut block_n = (n_b.next_power_of_two()) / 2;
    while block_n >= 8 {
        if m * block_n * 4 <= budget {
            return (block_n, n_b.div_ceil(block_n));
        }
        block_n /= 2;
    }
    (n_b, 1) // case 3: not staged (outside the GCN regime)
}

/// Breakdown of one simulated operation.
#[derive(Clone, Copy, Debug, Default)]
pub struct OpCost {
    pub framework_us: f64,
    pub launch_us: f64,
    pub transfer_us: f64,
    pub host_us: f64,
    pub kernel_us: f64,
    /// Thread blocks of the (main) kernel — the occupancy signal.
    pub blocks: usize,
    pub launches: usize,
}

impl OpCost {
    pub fn total_us(&self) -> f64 {
        self.framework_us + self.launch_us + self.transfer_us + self.host_us + self.kernel_us
    }

    /// Time-averaged nvprof-style sm_efficiency: fraction of the op's
    /// wall time during which SMs are active, times the fraction of SMs
    /// the kernel's blocks cover.
    pub fn sm_efficiency(&self, dev: &DeviceSpec) -> f64 {
        if self.total_us() == 0.0 {
            return 0.0;
        }
        dev.sm_efficiency(self.blocks) * (self.kernel_us / self.total_us())
    }
}

/// Which algorithm a cost belongs to (for reports).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelKind {
    /// TF SparseTensorDenseMatMul, one matrix per launch (Fig. 2).
    TfSpmmNonBatched,
    /// cuSPARSE csrmm/csrmm2, one matrix per launch.
    CusparseNonBatched,
    /// Batched SWA SpMM, SparseTensor (Fig. 3 + Fig. 5-a/b).
    BatchedSpmmSt,
    /// Batched SWA SpMM, CSR (Fig. 4 + Fig. 5-c/d).
    BatchedSpmmCsr,
    /// cuBLAS gemmBatched on the densified matrices.
    BatchedGemm,
}

pub struct CostModel {
    pub dev: DeviceSpec,
}

impl Default for CostModel {
    fn default() -> Self {
        Self {
            dev: DeviceSpec::p100(),
        }
    }
}

impl CostModel {
    pub fn new(dev: DeviceSpec) -> Self {
        Self { dev }
    }

    fn mem_us(&self, bytes: f64, sm_eff: f64) -> f64 {
        // Achieved bandwidth scales with occupancy but has a floor (a
        // single SM still moves data).
        let bw = self.dev.mem_bw_gbs * (0.25 + 0.75 * sm_eff);
        bytes / bw / 1e3 // bytes / (GB/s) -> ns; /1e3 -> us
    }

    // ---- non-batched baselines ------------------------------------------

    /// One TF SparseTensorDenseMatMul op (one matrix). Two launches:
    /// the C zero-init memset plus the SpMM kernel (§IV-B notes the
    /// init-launch overhead the shared-memory variant avoids).
    pub fn tf_spmm_op(&self, dim: usize, z: usize, n_b: usize) -> OpCost {
        let nnz = dim * z;
        let threads = nnz * n_b;
        let blocks = threads.div_ceil(self.dev.threads_per_block).max(1);
        let sm_eff = self.dev.sm_efficiency(blocks);
        let bytes = nnz as f64 * 12.0
            + (nnz * n_b) as f64 * 4.0 * TF_UNCOAL_AMP   // B reads
            + (nnz * n_b) as f64 * 4.0 * TF_ATOMIC_AMP; // atomic C updates
        let kernel = KERNEL_FLOOR_US * self.dev.waves(blocks) + self.mem_us(bytes, sm_eff);
        let init = 0.5 + self.mem_us((dim * n_b * 4) as f64, 1.0);
        OpCost {
            framework_us: self.dev.framework_op_us,
            launch_us: 2.0 * self.dev.launch_us,
            transfer_us: 0.0,
            host_us: 0.0,
            kernel_us: kernel + init,
            blocks,
            launches: 2,
        }
    }

    /// One cuSPARSE csrmm op (one matrix): row-major, no atomics, no
    /// init launch; still one dispatch per matrix.
    pub fn cusparse_op(&self, dim: usize, z: usize, n_b: usize) -> OpCost {
        let nnz = dim * z;
        let threads = dim * 32;
        let blocks = threads.div_ceil(self.dev.threads_per_block).max(1);
        let sm_eff = self.dev.sm_efficiency(blocks);
        let bytes = nnz as f64 * 8.0
            + (nnz * n_b) as f64 * 4.0 * 1.2
            + (dim * n_b) as f64 * 4.0;
        let kernel = KERNEL_FLOOR_US * self.dev.waves(blocks) + self.mem_us(bytes, sm_eff);
        OpCost {
            framework_us: self.dev.framework_op_us,
            launch_us: self.dev.launch_us,
            transfer_us: 0.0,
            host_us: 0.0,
            kernel_us: kernel,
            blocks,
            launches: 1,
        }
    }

    /// A whole non-batched sweep point: `batch` sequential ops.
    pub fn non_batched_total_us(&self, op: &OpCost, batch: usize) -> f64 {
        op.total_us() * batch as f64
    }

    // ---- batched kernels --------------------------------------------------

    /// Batched SWA SpMM for SparseTensor: one thread block per
    /// (matrix, column block); per-block latency chain walks every nnz
    /// once per column block (the "more cache blocking -> more memory
    /// pressure on the same non-zero" effect of Fig. 9).
    pub fn batched_spmm_st(&self, batch: usize, dim: usize, z: usize, n_b: usize) -> OpCost {
        let nnz = dim * z;
        let (block_n, col_blocks) = plan_col_blocks(dim, n_b);
        let blocks = batch * col_blocks;
        let sw = subwarp(block_n.min(32)).max(1);
        let vec_ops = nnz as f64 * (block_n as f64 / sw as f64).ceil();
        let atomic = 1.0 + ATOMIC_SLOPE * z as f64;
        let init_cycles = (dim * block_n) as f64 / 8.0; // smem zero-init
        let block_cycles = init_cycles + vec_ops * C_ST_VEC * atomic;
        let kernel = KERNEL_FLOOR_US
            + self.dev.waves(blocks) * block_cycles / (self.dev.clock_ghz * 1e3);
        OpCost {
            framework_us: self.dev.framework_op_us,
            launch_us: self.dev.launch_us,
            transfer_us: 3.0 * self.dev.h2d_latency_us, // ids/vals/dense ptr arrays
            host_us: self.dev.host_ptr_us * batch as f64,
            kernel_us: kernel,
            blocks,
            launches: 1,
        }
    }

    /// Batched SWA SpMM for CSR: subWarp per row, `subwarp*m` threads
    /// per matrix — parallelism grows with dim (the Fig. 9 trend), and
    /// no atomics, so density only adds useful work.
    pub fn batched_spmm_csr(&self, batch: usize, dim: usize, z: usize, n_b: usize) -> OpCost {
        let sw = subwarp(n_b);
        // Per-row smem need is n_b floats; blocking only if n_b alone
        // exceeds the per-subwarp budget (Fig. 5-d) — with TB=256 and
        // 32 KB that is n_b > 1024, outside the sweep.
        let threads_per_matrix = dim * sw;
        let blocks_per_matrix = threads_per_matrix.div_ceil(self.dev.threads_per_block).max(1);
        let blocks = batch * blocks_per_matrix;
        let rows_per_block = self.dev.threads_per_block / sw.max(1);
        let vec_ops = rows_per_block as f64 * z as f64 * (n_b as f64 / sw as f64).ceil();
        let block_cycles = vec_ops * C_CSR_VEC;
        let kernel = KERNEL_FLOOR_US
            + self.dev.waves(blocks) * block_cycles / (self.dev.clock_ghz * 1e3);
        OpCost {
            framework_us: self.dev.framework_op_us,
            launch_us: self.dev.launch_us,
            transfer_us: 4.0 * self.dev.h2d_latency_us, // rpt/colids/vals/dense
            host_us: self.dev.host_ptr_us * batch as f64,
            kernel_us: kernel,
            blocks,
            launches: 1,
        }
    }

    /// cuBLAS gemmBatched on densified inputs: cheap host/transfer side
    /// (plain pointer arrays), throughput from the fitted small-matrix
    /// achieved-GFLOPS curve.
    pub fn batched_gemm(&self, batch: usize, dim: usize, n_b: usize) -> OpCost {
        let flops = 2.0 * (dim * dim * n_b * batch) as f64;
        let achieved = (GEMM_ACHIEVED_C * (dim as f64 / 50.0) * (n_b as f64).powf(0.72)
            + GEMM_ACHIEVED_FLOOR_GFLOPS)
            .min(GEMM_ACHIEVED_CAP_GFLOPS);
        let tiles = dim.div_ceil(32) * n_b.div_ceil(32);
        let blocks = batch * tiles;
        let kernel = KERNEL_FLOOR_US + flops / achieved / 1e3;
        OpCost {
            framework_us: self.dev.framework_op_us,
            launch_us: self.dev.launch_us,
            transfer_us: 3.0 * self.dev.h2d_latency_us,
            host_us: 0.2 * batch as f64, // bare pointer accumulation
            kernel_us: kernel,
            blocks,
            launches: 1,
        }
    }

    // ---- dense layer ops (Table IV / Fig. 11) -----------------------------

    /// `[m, k] @ [k, n]` MatMul (memory-bound at these sizes).
    pub fn matmul(&self, m: usize, k: usize, n: usize) -> OpCost {
        let blocks = (m.div_ceil(32) * n.div_ceil(32)).max(1);
        let sm_eff = self.dev.sm_efficiency(blocks);
        let bytes = ((m * k + k * n + m * n) * 4) as f64;
        let compute = 2.0 * (m * k * n) as f64 / (self.dev.peak_gflops() * 0.5) / 1e3;
        let kernel = KERNEL_FLOOR_US * self.dev.waves(blocks)
            + self.mem_us(bytes, sm_eff).max(compute);
        OpCost {
            framework_us: self.dev.framework_op_us,
            launch_us: self.dev.launch_us,
            transfer_us: 0.0,
            host_us: 0.0,
            kernel_us: kernel,
            blocks,
            launches: 1,
        }
    }

    /// Elementwise `[m, n] + bias`/accumulate (pure bandwidth).
    pub fn elementwise(&self, m: usize, n: usize) -> OpCost {
        let blocks = (m * n).div_ceil(self.dev.threads_per_block).max(1);
        let sm_eff = self.dev.sm_efficiency(blocks);
        let bytes = (m * n * 4 * 2) as f64;
        OpCost {
            framework_us: self.dev.framework_op_us,
            launch_us: self.dev.launch_us,
            transfer_us: 0.0,
            host_us: 0.0,
            kernel_us: KERNEL_FLOOR_US * self.dev.waves(blocks) + self.mem_us(bytes, sm_eff),
            blocks,
            launches: 1,
        }
    }

    /// Paper GFLOPS metric for a sweep point: `2*nnz*n_B*batch / t`.
    pub fn gflops(&self, batch: usize, dim: usize, z: usize, n_b: usize, total_us: f64) -> f64 {
        2.0 * (dim * z * n_b * batch) as f64 / (total_us * 1e3)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m() -> CostModel {
        CostModel::default()
    }

    // ---- policy mirrors ---------------------------------------------------

    #[test]
    fn subwarp_golden_matches_python() {
        // Same golden vector as python/tests/test_blocking.py.
        for (nb, want) in [
            (1, 1), (2, 2), (3, 4), (4, 4), (5, 8), (8, 8), (9, 16),
            (16, 16), (17, 32), (32, 32), (64, 32), (512, 32),
        ] {
            assert_eq!(subwarp(nb), want, "subwarp({nb})");
        }
    }

    #[test]
    fn col_blocks_golden_matches_python() {
        assert_eq!(plan_col_blocks(50, 64), (64, 1)); // fits (Fig. 5-a)
        let (bn, nblk) = plan_col_blocks(50, 512); // 100 KB -> split
        assert!(nblk > 1 && 50 * bn * 4 <= 32 * 1024);
        assert_eq!(plan_col_blocks(8192, 8).1, 1); // case 1 boundary: 256KB? no ->
    }

    // ---- Table IV anchors -------------------------------------------------

    #[test]
    fn table4_per_op_anchor_bands() {
        let c = m();
        // Non-batched per-op (paper: MatMul 31.4, Add 26.3, SpMM 39.6 us
        // per launch when divided by the 50 launches).
        let mm = c.matmul(50, 16, 64).total_us();
        assert!((15.0..45.0).contains(&mm), "matmul single {mm}");
        let add = c.elementwise(50, 64).total_us();
        assert!((15.0..40.0).contains(&add), "add single {add}");
        let spmm = c.tf_spmm_op(50, 2, 64).total_us();
        assert!((22.0..50.0).contains(&spmm), "tf spmm single {spmm}");
        // Batched (paper: MatMul 31, Add 23, SpMM 190 us).
        let mmb = c.matmul(50 * 50, 16, 64).total_us();
        assert!((18.0..50.0).contains(&mmb), "matmul batched {mmb}");
        let addb = c.elementwise(50 * 50, 64).total_us();
        assert!((15.0..45.0).contains(&addb), "add batched {addb}");
        let spmmb = c.batched_spmm_st(50, 50, 2, 64).total_us();
        assert!((130.0..260.0).contains(&spmmb), "batched spmm {spmmb}");
    }

    // ---- headline speedup anchors ------------------------------------------

    #[test]
    fn fig8a_speedup_anchors() {
        let c = m();
        // dim 50, z 2, batch 50, n_B = 64 (paper: 9.27x vs TF, 1.26x vs cuBLAS)
        let tf = c.non_batched_total_us(&c.tf_spmm_op(50, 2, 64), 50);
        let st = c.batched_spmm_st(50, 50, 2, 64).total_us();
        let gemm = c.batched_gemm(50, 50, 64).total_us();
        let vs_tf = tf / st;
        assert!((6.0..16.0).contains(&vs_tf), "fig8a vs TF: {vs_tf}");
        let vs_gemm = gemm / st;
        assert!((1.05..1.9).contains(&vs_gemm), "fig8a vs cuBLAS: {vs_gemm}");
    }

    #[test]
    fn fig8b_speedup_anchors() {
        let c = m();
        // dim 50, z 2, batch 100, n_B = 512 (paper: 6.09x vs TF, 1.43x vs cuBLAS)
        let tf = c.non_batched_total_us(&c.tf_spmm_op(50, 2, 512), 100);
        let st = c.batched_spmm_st(100, 50, 2, 512).total_us();
        let csr = c.batched_spmm_csr(100, 50, 2, 512).total_us();
        let best = st.min(csr);
        let vs_tf = tf / best;
        assert!((3.5..10.0).contains(&vs_tf), "fig8b vs TF: {vs_tf}");
        let gemm = c.batched_gemm(100, 50, 512).total_us();
        let vs_gemm = gemm / best;
        assert!((1.1..2.0).contains(&vs_gemm), "fig8b vs cuBLAS: {vs_gemm}");
    }

    #[test]
    fn gemm_wins_at_small_nb() {
        // Paper: "In the cases with smaller n_B, the Batched GEMM of
        // cuBLAS shows superior performance to our Batched SpMM."
        let c = m();
        let st = c.batched_spmm_st(50, 50, 2, 8).total_us();
        let gemm = c.batched_gemm(50, 50, 8).total_us();
        assert!(gemm < st, "gemm {gemm} !< st {st} at n_B=8");
    }

    // ---- structural trends (Fig. 9) ----------------------------------------

    #[test]
    fn csr_gains_with_dim() {
        let c = m();
        let g = |dim: usize| {
            let t = c.batched_spmm_csr(100, dim, 2, 512).total_us();
            c.gflops(100, dim, 2, 512, t)
        };
        assert!(g(64) > g(32), "csr gflops not rising 32->64");
        assert!(g(128) > g(64), "csr gflops not rising 64->128");
    }

    #[test]
    fn st_flat_or_falling_with_dim_under_blocking() {
        // "The Batched SpMM for SparseTensor shows only slight
        // performance change ... more cache blocking causes more memory
        // pressure to same non-zero element."
        let c = m();
        let g = |dim: usize| {
            let t = c.batched_spmm_st(100, dim, 2, 512).total_us();
            c.gflops(100, dim, 2, 512, t)
        };
        let (g32, g128) = (g(32), g(128));
        assert!(
            g128 < g32 * 2.0,
            "st should not scale like csr: {g32} -> {g128}"
        );
    }

    #[test]
    fn larger_batch_higher_throughput() {
        let c = m();
        let gf = |b: usize| {
            let t = c.batched_spmm_st(b, 64, 2, 128).total_us();
            c.gflops(b, 64, 2, 128, t)
        };
        assert!(gf(100) > gf(50), "batch 100 not faster than 50");
        // batch 50 cannot fill 56 SMs (paper's occupancy point)
        let op50 = c.batched_spmm_st(50, 64, 2, 128);
        assert!(c.dev.sm_efficiency(op50.blocks) < 1.0);
        let op100 = c.batched_spmm_st(100, 64, 2, 128);
        assert!(c.dev.sm_efficiency(op100.blocks) >= 0.99);
    }

    #[test]
    fn density_flips_st_vs_csr() {
        // Fig. 9-(e)/(f): ST fine at z=1, CSR "keeps best performer on
        // denser input sparse matrices".
        let c = m();
        let st5 = c.batched_spmm_st(100, 64, 5, 512).total_us();
        let csr5 = c.batched_spmm_csr(100, 64, 5, 512).total_us();
        assert!(csr5 < st5, "csr {csr5} !< st {st5} at z=5");
        let st1 = c.batched_spmm_st(100, 64, 1, 128).total_us();
        let gemm1 = c.batched_gemm(100, 64, 128).total_us();
        assert!(st1 < gemm1, "sparse should win at z=1");
    }

    #[test]
    fn sm_efficiency_anchors() {
        // Paper §V-A: TF non-batched 35.51%, batched ST 89.07%, CSR 87.87%
        // at dim 50 / n_B 512 / batch 100.
        let c = m();
        let tf = c.tf_spmm_op(50, 2, 512);
        let e_tf = tf.sm_efficiency(&c.dev);
        assert!((0.05..0.6).contains(&e_tf), "tf sm_eff {e_tf}");
        let st = c.batched_spmm_st(100, 50, 2, 512);
        // blocks = 100 matrices x col blocks >= 56 SMs -> full coverage
        assert!(c.dev.sm_efficiency(st.blocks) > 0.85);
    }

    #[test]
    fn cusparse_beats_tf_but_loses_to_batched() {
        let c = m();
        let tf = c.non_batched_total_us(&c.tf_spmm_op(50, 2, 256), 100);
        let cu = c.non_batched_total_us(&c.cusparse_op(50, 2, 256), 100);
        let st = c.batched_spmm_st(100, 50, 2, 256).total_us();
        assert!(cu < tf, "cusparse {cu} !< tf {tf}");
        assert!(st < cu, "batched {st} !< cusparse {cu}");
    }

    #[test]
    fn gflops_metric_matches_paper_formula() {
        let c = m();
        // 2 * nnz * n_B * batch / t
        let g = c.gflops(10, 50, 2, 64, 100.0);
        assert!((g - 2.0 * 100.0 * 64.0 * 10.0 / 1e5).abs() < 1e-9);
    }
}
