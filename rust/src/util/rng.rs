//! Deterministic PRNG: xoshiro256** seeded via SplitMix64.
//!
//! Every stochastic component in the crate (workload generation,
//! synthetic datasets, property tests) takes an explicit seed so runs
//! are reproducible bit-for-bit — a requirement for the paper-repro
//! benches, whose workloads must be identical across approaches.

/// SplitMix64: used to expand a single `u64` seed into xoshiro state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** — fast, high-quality, 2^256-1 period.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)`. Uses Lemire's multiply-shift rejection-free
    /// bound (bias < 2^-64, negligible for our workloads).
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform usize in `[lo, hi]` inclusive.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as usize
    }

    /// Uniform f32 in `[0, 1)`.
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform f32 in `[lo, hi)`.
    pub fn f32_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Standard normal via Box-Muller (one sample; the pair is dropped —
    /// simplicity over throughput here).
    pub fn normal(&mut self) -> f32 {
        let u1 = self.f32().max(f32::MIN_POSITIVE);
        let u2 = self.f32();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    pub fn bool(&mut self, p: f32) -> bool {
        self.f32() < p
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (k <= n), unordered.
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample_distinct: k={k} > n={n}");
        // Floyd's algorithm: O(k) expected.
        let mut chosen = std::collections::HashSet::with_capacity(k);
        let mut out = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.below((j + 1) as u64) as usize;
            let v = if chosen.contains(&t) { j } else { t };
            chosen.insert(v);
            out.push(v);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn f32_unit_interval() {
        let mut r = Rng::new(4);
        for _ in 0..1000 {
            let x = r.f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_moments_sane() {
        let mut r = Rng::new(5);
        let n = 20_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn sample_distinct_properties() {
        let mut r = Rng::new(6);
        for _ in 0..100 {
            let n = r.range(1, 50);
            let k = r.range(0, n);
            let s = r.sample_distinct(n, k);
            assert_eq!(s.len(), k);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), k, "duplicates in sample");
            assert!(s.iter().all(|&x| x < n));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(8);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
