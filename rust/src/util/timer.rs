//! Wall-clock measurement helpers used by the bench harness.

use std::time::Instant;

/// Time one closure invocation in seconds.
pub fn time_once<F: FnOnce() -> R, R>(f: F) -> (f64, R) {
    let t0 = Instant::now();
    let r = f();
    (t0.elapsed().as_secs_f64(), r)
}

/// Benchmark a closure: `warmup` unmeasured runs, then `iters` measured
/// runs; returns per-iteration seconds.
pub fn bench<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Vec<f64> {
    for _ in 0..warmup {
        f();
    }
    let mut out = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        out.push(t0.elapsed().as_secs_f64());
    }
    out
}

/// Adaptive benchmark: run until `min_time_s` total measured time or
/// `max_iters`, whichever first (with `warmup` unmeasured runs). This is
/// the criterion-equivalent driver for our `harness = false` benches.
pub fn bench_adaptive<F: FnMut()>(
    warmup: usize,
    min_iters: usize,
    max_iters: usize,
    min_time_s: f64,
    mut f: F,
) -> Vec<f64> {
    for _ in 0..warmup {
        f();
    }
    let mut out = Vec::new();
    let mut total = 0.0;
    while out.len() < max_iters && (out.len() < min_iters || total < min_time_s) {
        let t0 = Instant::now();
        f();
        let dt = t0.elapsed().as_secs_f64();
        out.push(dt);
        total += dt;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_once_returns_value() {
        let (dt, v) = time_once(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(dt >= 0.0);
    }

    #[test]
    fn bench_runs_exact_iters() {
        let mut n = 0;
        let samples = bench(2, 5, || n += 1);
        assert_eq!(samples.len(), 5);
        assert_eq!(n, 7); // 2 warmup + 5 measured
    }

    #[test]
    fn adaptive_respects_bounds() {
        let samples = bench_adaptive(0, 3, 10, 0.0, || {});
        assert!(samples.len() >= 3 && samples.len() <= 10);
        let many = bench_adaptive(0, 1, 10_000, 0.01, || {
            std::thread::sleep(std::time::Duration::from_micros(100))
        });
        assert!(many.len() <= 10_000);
        let total: f64 = many.iter().sum();
        assert!(total >= 0.009, "total {total}");
    }
}
