//! Minimal JSON parser/writer (serde is not in the vendored crate set).
//!
//! Covers the full JSON grammar we produce (objects, arrays, strings
//! with escapes, numbers, bools, null); used to read
//! `artifacts/manifest.json` and to emit bench reports.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---- accessors -------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj["a"]["b"]` style access; returns Null for missing paths.
    pub fn at(&self, path: &[&str]) -> &Json {
        static NULL: Json = Json::Null;
        let mut cur = self;
        for k in path {
            cur = cur.get(k).unwrap_or(&NULL);
        }
        cur
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Required-field helpers: error messages name the missing key.
    pub fn req_str(&self, key: &str) -> anyhow::Result<&str> {
        self.get(key)
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("missing string field '{key}'"))
    }

    pub fn req_usize(&self, key: &str) -> anyhow::Result<usize> {
        self.get(key)
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow::anyhow!("missing numeric field '{key}'"))
    }

    pub fn req_f64(&self, key: &str) -> anyhow::Result<f64> {
        self.get(key)
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow::anyhow!("missing numeric field '{key}'"))
    }

    pub fn req_arr(&self, key: &str) -> anyhow::Result<&[Json]> {
        self.get(key)
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("missing array field '{key}'"))
    }

    // ---- writer ----------------------------------------------------------

    /// Canonical encoding: this writer is deterministic — object keys
    /// in sorted (`BTreeMap`) order, no whitespace, integers (`fract()
    /// == 0`, |n| < 1e15) as `i64` digits, other numbers in Rust's
    /// shortest-roundtrip float form. The AOT plan-artifact content
    /// hash (`runtime::plan_artifact`) is defined over exactly this
    /// encoding; changing the writer is a format break that must bump
    /// the artifact `format_version`.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

// ---- builders -------------------------------------------------------------

pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn arr(items: Vec<Json>) -> Json {
    Json::Arr(items)
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

// ---- parser ----------------------------------------------------------------

pub fn parse(input: &str) -> anyhow::Result<Json> {
    let mut p = Parser {
        bytes: input.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        anyhow::bail!("trailing data at byte {}", p.pos);
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> anyhow::Result<u8> {
        let b = self
            .peek()
            .ok_or_else(|| anyhow::anyhow!("unexpected end of JSON"))?;
        self.pos += 1;
        Ok(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> anyhow::Result<()> {
        let got = self.bump()?;
        if got != b {
            anyhow::bail!(
                "expected '{}' at byte {}, got '{}'",
                b as char,
                self.pos - 1,
                got as char
            );
        }
        Ok(())
    }

    fn value(&mut self) -> anyhow::Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(_) => self.number(),
            None => anyhow::bail!("unexpected end of JSON"),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> anyhow::Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            anyhow::bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn object(&mut self) -> anyhow::Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b'}' => return Ok(Json::Obj(m)),
                c => anyhow::bail!("expected ',' or '}}', got '{}'", c as char),
            }
        }
    }

    fn array(&mut self) -> anyhow::Result<Json> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b']' => return Ok(Json::Arr(a)),
                c => anyhow::bail!("expected ',' or ']', got '{}'", c as char),
            }
        }
    }

    fn string(&mut self) -> anyhow::Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump()? {
                b'"' => return Ok(out),
                b'\\' => match self.bump()? {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'n' => out.push('\n'),
                    b't' => out.push('\t'),
                    b'r' => out.push('\r'),
                    b'b' => out.push('\u{8}'),
                    b'f' => out.push('\u{c}'),
                    b'u' => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump()? as char;
                            code = code * 16
                                + c.to_digit(16).ok_or_else(|| {
                                    anyhow::anyhow!("bad \\u escape")
                                })?;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    c => anyhow::bail!("bad escape '\\{}'", c as char),
                },
                // Multi-byte UTF-8: pass through raw bytes.
                b => {
                    let start = self.pos - 1;
                    let len = utf8_len(b);
                    self.pos = start + len;
                    out.push_str(std::str::from_utf8(&self.bytes[start..self.pos])?);
                }
            }
        }
    }

    fn number(&mut self) -> anyhow::Result<Json> {
        let start = self.pos;
        while matches!(
            self.peek(),
            Some(b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        ) {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(text.parse::<f64>().map_err(|e| {
            anyhow::anyhow!("bad number '{text}': {e}")
        })?))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let j = parse(r#"{"a": [1, 2.5, -3e2], "b": "x\ny", "c": true, "d": null}"#)
            .unwrap();
        assert_eq!(j.at(&["a"]).as_arr().unwrap().len(), 3);
        assert_eq!(j.at(&["a"]).as_arr().unwrap()[2].as_f64(), Some(-300.0));
        assert_eq!(j.req_str("b").unwrap(), "x\ny");
        assert_eq!(j.at(&["c"]).as_bool(), Some(true));
        assert_eq!(j.at(&["d"]), &Json::Null);
        // re-parse our own output
        let again = parse(&j.to_string()).unwrap();
        assert_eq!(j, again);
    }

    #[test]
    fn parses_manifest_like_structure() {
        let text = r#"{
 "version": 1,
 "artifacts": [
  {"name": "a", "file": "a.hlo.txt",
   "inputs": [{"name": "x", "dtype": "f32", "shape": [2, 3]}],
   "outputs": [{"dtype": "f32", "shape": [2, 3]}],
   "meta": {"kind": "model", "batched": false}}
 ]
}"#;
        let j = parse(text).unwrap();
        let a = &j.req_arr("artifacts").unwrap()[0];
        assert_eq!(a.req_str("name").unwrap(), "a");
        let shape = a.req_arr("inputs").unwrap()[0].req_arr("shape").unwrap();
        assert_eq!(shape[1].as_usize(), Some(3));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse(r#"{"a" 1}"#).is_err());
        assert!(parse("123 456").is_err());
    }

    #[test]
    fn unicode_escapes() {
        let j = parse(r#""Aé""#).unwrap();
        assert_eq!(j.as_str(), Some("Aé"));
    }

    #[test]
    fn utf8_passthrough() {
        let j = parse("\"héllo — ☃\"").unwrap();
        assert_eq!(j.as_str(), Some("héllo — ☃"));
    }

    #[test]
    fn canonical_encoding_is_stable() {
        // The plan-artifact content hash depends on every one of these
        // properties; a failure here means the artifact format broke.
        // Integral floats render as integers, fractions roundtrip
        // shortest-form.
        assert_eq!(num(3.0).to_string(), "3");
        assert_eq!(num(-0.0).to_string(), "0");
        assert_eq!(num(0.25).to_string(), "0.25");
        assert_eq!(num(1e15).to_string(), "1000000000000000");
        // Keys sort regardless of insertion order, output is compact.
        let a = obj(vec![("b", num(2.0)), ("a", num(1.0))]);
        let b = obj(vec![("a", num(1.0)), ("b", num(2.0))]);
        assert_eq!(a.to_string(), r#"{"a":1,"b":2}"#);
        assert_eq!(a.to_string(), b.to_string());
        // Parse → emit is a fixed point on canonical input.
        let canon = r#"{"ell_waste":3,"gemm_density":0.25,"key":[1,4,50]}"#;
        assert_eq!(parse(canon).unwrap().to_string(), canon);
    }

    #[test]
    fn builders_emit_valid_json() {
        let j = obj(vec![
            ("x", num(1.5)),
            ("y", arr(vec![s("a"), Json::Bool(false)])),
        ]);
        assert_eq!(parse(&j.to_string()).unwrap(), j);
    }
}
