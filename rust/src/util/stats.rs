//! Descriptive statistics for the bench harness and serving metrics.

/// Summary of a set of duration/throughput samples.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

impl Summary {
    pub fn of(samples: &[f64]) -> Self {
        assert!(!samples.is_empty(), "Summary::of on empty slice");
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Self {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            p50: percentile(&sorted, 0.50),
            p95: percentile(&sorted, 0.95),
            p99: percentile(&sorted, 0.99),
        }
    }
}

/// Linear-interpolated percentile of an ascending-sorted slice.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=1.0).contains(&q));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Streaming counter histogram with fixed power-of-two-ish latency
/// buckets (microseconds); cheap enough for the serving hot path.
#[derive(Clone, Debug)]
pub struct LatencyHistogram {
    /// bucket i counts samples in [2^i, 2^(i+1)) microseconds.
    buckets: [u64; 32],
    count: u64,
    sum_us: u64,
    max_us: u64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self {
            buckets: [0; 32],
            count: 0,
            sum_us: 0,
            max_us: 0,
        }
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_us(&mut self, us: u64) {
        let idx = (64 - us.max(1).leading_zeros() as usize - 1).min(31);
        self.buckets[idx] += 1;
        self.count += 1;
        self.sum_us += us;
        self.max_us = self.max_us.max(us);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean_us(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_us as f64 / self.count as f64
        }
    }

    pub fn max_us(&self) -> u64 {
        self.max_us
    }

    /// Upper bound of the bucket containing quantile q (conservative).
    pub fn quantile_us(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = (q * self.count as f64).ceil() as u64;
        let mut seen = 0;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target {
                return 1u64 << (i + 1);
            }
        }
        self.max_us
    }

    pub fn merge(&mut self, other: &Self) {
        for (a, b) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *a += b;
        }
        self.count += other.count;
        self.sum_us += other.sum_us;
        self.max_us = self.max_us.max(other.max_us);
    }
}

/// GFLOPS from the paper's metric: `2 * nnz_A * n_B / exe_time` (§V-A).
pub fn spmm_gflops(nnz: usize, n_b: usize, seconds: f64) -> f64 {
    (2.0 * nnz as f64 * n_b as f64) / seconds / 1e9
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_constant() {
        let s = Summary::of(&[5.0; 10]);
        assert_eq!(s.mean, 5.0);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.p99, 5.0);
    }

    #[test]
    fn summary_known_values() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0]);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
        assert!((s.p50 - 2.5).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let sorted = [0.0, 10.0];
        assert!((percentile(&sorted, 0.5) - 5.0).abs() < 1e-12);
        assert_eq!(percentile(&sorted, 0.0), 0.0);
        assert_eq!(percentile(&sorted, 1.0), 10.0);
    }

    #[test]
    fn histogram_quantiles_monotone() {
        let mut h = LatencyHistogram::new();
        for us in [10u64, 20, 40, 80, 160, 5000, 10000] {
            h.record_us(us);
        }
        assert_eq!(h.count(), 7);
        assert!(h.quantile_us(0.5) <= h.quantile_us(0.95));
        assert!(h.quantile_us(0.95) <= h.quantile_us(1.0).max(h.max_us()));
        assert!(h.mean_us() > 0.0);
    }

    #[test]
    fn histogram_merge_adds_counts() {
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        a.record_us(100);
        b.record_us(200);
        b.record_us(300);
        a.merge(&b);
        assert_eq!(a.count(), 3);
        assert_eq!(a.max_us(), 300);
    }

    #[test]
    fn gflops_matches_paper_formula() {
        // 2 * nnz * n_B / t: 2*100*64 / 1e-6 s = 12.8 GFLOPS
        let g = spmm_gflops(100, 64, 1e-6);
        assert!((g - 12.8).abs() < 1e-9);
    }
}
