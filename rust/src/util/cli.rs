//! Tiny CLI argument parser (clap is not in the vendored crate set).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, and
//! positional arguments, with typed accessors and a generated usage
//! string. Each binary declares its options up front so `--help` is
//! accurate.

use std::collections::BTreeMap;

#[derive(Clone, Debug)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub is_flag: bool,
}

#[derive(Clone, Debug, Default)]
pub struct Args {
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

#[derive(Clone, Debug, Default)]
pub struct Cli {
    pub name: &'static str,
    pub about: &'static str,
    specs: Vec<OptSpec>,
}

impl Cli {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Self {
            name,
            about,
            specs: Vec::new(),
        }
    }

    pub fn opt(mut self, name: &'static str, default: &'static str, help: &'static str) -> Self {
        self.specs.push(OptSpec {
            name,
            help,
            default: Some(default),
            is_flag: false,
        });
        self
    }

    pub fn opt_req(mut self, name: &'static str, help: &'static str) -> Self {
        self.specs.push(OptSpec {
            name,
            help,
            default: None,
            is_flag: false,
        });
        self
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.specs.push(OptSpec {
            name,
            help,
            default: None,
            is_flag: true,
        });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nOptions:\n", self.name, self.about);
        for spec in &self.specs {
            let d = match (spec.is_flag, spec.default) {
                (true, _) => String::new(),
                (false, Some(d)) => format!(" (default: {d})"),
                (false, None) => " (required)".to_string(),
            };
            s.push_str(&format!("  --{:<18} {}{}\n", spec.name, spec.help, d));
        }
        s
    }

    /// Parse; on `--help` or error, returns Err with a printable message.
    pub fn parse(&self, argv: &[String]) -> Result<Args, String> {
        let mut args = Args::default();
        // seed defaults
        for spec in &self.specs {
            if let Some(d) = spec.default {
                args.opts.insert(spec.name.to_string(), d.to_string());
            }
        }
        let mut it = argv.iter().peekable();
        while let Some(a) = it.next() {
            if a == "--help" || a == "-h" {
                return Err(self.usage());
            }
            if let Some(rest) = a.strip_prefix("--") {
                let (key, inline_val) = match rest.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (rest.to_string(), None),
                };
                let spec = self
                    .specs
                    .iter()
                    .find(|s| s.name == key)
                    .ok_or_else(|| format!("unknown option --{key}\n\n{}", self.usage()))?;
                if spec.is_flag {
                    if inline_val.is_some() {
                        return Err(format!("--{key} is a flag and takes no value"));
                    }
                    args.flags.push(key);
                } else {
                    let v = match inline_val {
                        Some(v) => v,
                        None => it
                            .next()
                            .ok_or_else(|| format!("--{key} requires a value"))?
                            .clone(),
                    };
                    args.opts.insert(key, v);
                }
            } else {
                args.positional.push(a.clone());
            }
        }
        for spec in &self.specs {
            if !spec.is_flag && spec.default.is_none() && !args.opts.contains_key(spec.name) {
                return Err(format!("missing required --{}\n\n{}", spec.name, self.usage()));
            }
        }
        Ok(args)
    }
}

impl Args {
    pub fn get(&self, key: &str) -> Option<&str> {
        self.opts.get(key).map(|s| s.as_str())
    }

    pub fn str(&self, key: &str) -> &str {
        self.get(key).unwrap_or_else(|| panic!("option --{key} not declared"))
    }

    pub fn usize(&self, key: &str) -> usize {
        self.str(key)
            .parse()
            .unwrap_or_else(|e| panic!("--{key}: {e}"))
    }

    pub fn u64(&self, key: &str) -> u64 {
        self.str(key)
            .parse()
            .unwrap_or_else(|e| panic!("--{key}: {e}"))
    }

    pub fn f64(&self, key: &str) -> f64 {
        self.str(key)
            .parse()
            .unwrap_or_else(|e| panic!("--{key}: {e}"))
    }

    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }
}

/// Parse `std::env::args` (skipping argv[0]); print-and-exit on --help.
pub fn parse_or_exit(cli: &Cli) -> Args {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    match cli.parse(&argv) {
        Ok(a) => a,
        Err(msg) => {
            eprintln!("{msg}");
            std::process::exit(2);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cli() -> Cli {
        Cli::new("t", "test")
            .opt("count", "5", "how many")
            .opt_req("path", "a path")
            .flag("verbose", "talk more")
    }

    fn parse(args: &[&str]) -> Result<Args, String> {
        cli().parse(&args.iter().map(|s| s.to_string()).collect::<Vec<_>>())
    }

    #[test]
    fn defaults_and_overrides() {
        let a = parse(&["--path", "/x"]).unwrap();
        assert_eq!(a.usize("count"), 5);
        assert_eq!(a.str("path"), "/x");
        assert!(!a.flag("verbose"));

        let a = parse(&["--path=/y", "--count=9", "--verbose"]).unwrap();
        assert_eq!(a.usize("count"), 9);
        assert_eq!(a.str("path"), "/y");
        assert!(a.flag("verbose"));
    }

    #[test]
    fn required_enforced() {
        assert!(parse(&[]).is_err());
    }

    #[test]
    fn unknown_option_rejected() {
        assert!(parse(&["--path", "/x", "--nope", "1"]).is_err());
    }

    #[test]
    fn positional_collected() {
        let a = parse(&["--path", "/x", "pos1", "pos2"]).unwrap();
        assert_eq!(a.positional, vec!["pos1", "pos2"]);
    }

    #[test]
    fn help_is_error_with_usage() {
        let e = parse(&["--help"]).unwrap_err();
        assert!(e.contains("--count"));
        assert!(e.contains("--path"));
    }
}
