//! Small self-contained substrates (S11/S12 in DESIGN.md).
//!
//! The build environment is offline and the vendored crate set has no
//! serde/clap/criterion/proptest/rand, so this module provides the
//! minimal equivalents the rest of the crate needs: a deterministic
//! PRNG, a JSON parser/writer, descriptive statistics, wall-clock
//! timing helpers, a CLI argument parser, and a tiny property-testing
//! harness.

pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod timer;
