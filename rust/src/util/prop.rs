//! Minimal property-testing harness (proptest is not vendored).
//!
//! `run` drives a property over `cases` seeded inputs; on failure it
//! retries with a simple bisection-style shrink over the seed space is
//! not meaningful, so instead it reports the failing seed so the case
//! can be replayed deterministically:
//!
//! ```ignore
//! prop::run(100, |rng| {
//!     let n = rng.range(1, 64);
//!     /* ... build input, check invariant ... */
//!     Ok(())
//! });
//! ```

use crate::util::rng::Rng;

/// Run `property` for `cases` deterministic cases. Panics with the
/// failing case's seed on the first counterexample.
pub fn run<F>(cases: u64, mut property: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    run_seeded(0xB5F3_7ED1, cases, &mut property);
}

/// Like `run` but with an explicit base seed (replay a failure by
/// passing the reported seed with cases = 1).
pub fn run_seeded<F>(base_seed: u64, cases: u64, property: &mut F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case.wrapping_mul(0x9E37_79B9));
        let mut rng = Rng::new(seed);
        if let Err(msg) = property(&mut rng) {
            panic!(
                "property failed at case {case} (replay with base_seed={seed:#x}, cases=1): {msg}"
            );
        }
    }
}

/// Assert helper producing `Result<(), String>` for use inside props.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !($cond) {
            return Err(format!($($fmt)+));
        }
    };
    ($cond:expr) => {
        if !($cond) {
            return Err(format!("assertion failed: {}", stringify!($cond)));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        run(50, |rng| {
            count += 1;
            let x = rng.range(0, 100);
            prop_assert!(x <= 100);
            Ok(())
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        run(50, |rng| {
            let x = rng.range(0, 100);
            prop_assert!(x < 10, "x={x} too big");
            Ok(())
        });
    }

    #[test]
    fn deterministic_replay() {
        let mut first = Vec::new();
        run_seeded(42, 5, &mut |rng| {
            first.push(rng.next_u64());
            Ok(())
        });
        let mut second = Vec::new();
        run_seeded(42, 5, &mut |rng| {
            second.push(rng.next_u64());
            Ok(())
        });
        assert_eq!(first, second);
    }
}
