//! Open-loop load generation for the serving bench (DESIGN.md §14).
//!
//! An *open-loop* generator fixes the arrival process up front and
//! submits on that schedule regardless of how the server is doing —
//! unlike closed-loop clients, it keeps offering load while the server
//! falls behind, which is what exposes queueing collapse and makes
//! shedding observable. The whole trace (arrival offsets *and* request
//! payloads) is a pure function of the seed: two runs with the same
//! seed offer byte-identical traffic, so a fixed-size vs size-or-age
//! comparison at "equal offered load" really is equal.
//!
//! No wall clock enters trace *generation* — entries carry [`Duration`]
//! offsets from an abstract start. Only [`submit_trace`] touches real
//! time, sleeping each entry to its offset against one anchor
//! `Instant` (absolute offsets, so sleep jitter never accumulates).

use std::sync::mpsc;
use std::time::{Duration, Instant};

use crate::coordinator::request::InferResponse;
use crate::coordinator::server::Server;
use crate::graph::molecule::{Molecule, MoleculeSpec};
use crate::util::rng::Rng;

/// The arrival process shaping a trace.
#[derive(Clone, Copy, Debug)]
pub enum Arrivals {
    /// Memoryless arrivals at `rate_rps`: exponential inter-arrival
    /// gaps, the standard open-loop serving model.
    Poisson { rate_rps: f64 },
    /// On/off bursts: groups of `burst` requests arrive Poisson at
    /// `peak_rps`, separated by idle gaps sized so the long-run mean
    /// rate is still `rate_rps`. Stresses the admission queue with
    /// depth spikes a smooth Poisson stream at the same mean never
    /// produces.
    Bursty {
        rate_rps: f64,
        peak_rps: f64,
        burst: usize,
    },
}

impl Arrivals {
    /// Long-run mean offered load of the process.
    pub fn rate_rps(&self) -> f64 {
        match *self {
            Arrivals::Poisson { rate_rps } => rate_rps,
            Arrivals::Bursty { rate_rps, .. } => rate_rps,
        }
    }
}

/// One scheduled request: when it is offered and what it carries.
#[derive(Clone, Debug)]
pub struct TraceEntry {
    /// Arrival offset from the (abstract) trace start.
    pub at: Duration,
    pub mol: Molecule,
}

/// A fully materialized open-loop request schedule.
#[derive(Clone, Debug)]
pub struct Trace {
    pub entries: Vec<TraceEntry>,
    /// Long-run mean rate the trace was generated for.
    pub offered_rps: f64,
    pub seed: u64,
}

impl Trace {
    /// Arrival offset of the last entry (zero for an empty trace).
    pub fn span(&self) -> Duration {
        self.entries.last().map(|e| e.at).unwrap_or(Duration::ZERO)
    }
}

/// Mixed request sizes: roughly half the trace is small molecules
/// (cheap pack, low padding), half the full Table-I size range — so a
/// batch's cost is not a pure function of its occupancy and the bench
/// sees realistic per-request variance.
fn small_spec() -> MoleculeSpec {
    MoleculeSpec {
        min_atoms: 4,
        max_atoms: 12,
        ..MoleculeSpec::default()
    }
}

/// One exponential inter-arrival gap at `rate` req/s. The uniform draw
/// is clamped away from 0 so `ln` stays finite.
fn exp_gap(rng: &mut Rng, rate: f64) -> Duration {
    let u = (rng.f32() as f64).max(1e-9);
    Duration::from_secs_f64(-u.ln() / rate)
}

/// Generate `n` arrivals under the given process, deterministically in
/// `seed`: same `(arrivals, n, seed)` → the identical trace, entry for
/// entry, molecule for molecule.
pub fn generate_trace(arrivals: Arrivals, n: usize, seed: u64) -> Trace {
    let mut rng = Rng::new(seed);
    let small = small_spec();
    let full = MoleculeSpec::default();
    let mut entries = Vec::with_capacity(n);
    let mut at = Duration::ZERO;
    let mut in_burst = 0usize;
    for _ in 0..n {
        match arrivals {
            Arrivals::Poisson { rate_rps } => {
                at += exp_gap(&mut rng, rate_rps);
            }
            Arrivals::Bursty {
                rate_rps,
                peak_rps,
                burst,
            } => {
                debug_assert!(peak_rps >= rate_rps && burst >= 1);
                if in_burst == 0 {
                    // Idle gap: the schedule time a burst "saves" by
                    // arriving at peak_rps instead of rate_rps, handed
                    // back as silence so the long-run mean stays
                    // rate_rps.
                    let off = burst as f64 * (1.0 / rate_rps - 1.0 / peak_rps);
                    at += Duration::from_secs_f64(off.max(0.0));
                    in_burst = burst;
                }
                at += exp_gap(&mut rng, peak_rps);
                in_burst -= 1;
            }
        }
        let spec = if rng.bool(0.5) { &small } else { &full };
        entries.push(TraceEntry {
            at,
            mol: Molecule::random(&mut rng, spec),
        });
    }
    Trace {
        entries,
        offered_rps: arrivals.rate_rps(),
        seed,
    }
}

/// Drive a trace against a live server, open-loop: sleep to each
/// entry's absolute offset and submit, never waiting for responses.
/// Returns the per-request response channels in submission order —
/// collect them *after* [`Server::shutdown`] so the drain has answered
/// every admitted request (under the fixed-size close rule a trailing
/// partial batch is only emitted by that drain).
pub fn submit_trace(server: &Server, trace: &Trace) -> Vec<mpsc::Receiver<InferResponse>> {
    let start = Instant::now();
    let mut rxs = Vec::with_capacity(trace.entries.len());
    for e in &trace.entries {
        if let Some(wait) = e.at.checked_sub(start.elapsed()) {
            std::thread::sleep(wait);
        }
        rxs.push(server.submit(e.mol.clone()));
    }
    rxs
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fingerprint(t: &Trace) -> Vec<(u128, usize, usize)> {
        t.entries
            .iter()
            .map(|e| (e.at.as_nanos(), e.mol.n_atoms, e.mol.bonds.len()))
            .collect()
    }

    #[test]
    fn trace_is_deterministic_in_seed() {
        let a = Arrivals::Poisson { rate_rps: 500.0 };
        let t1 = generate_trace(a, 64, 0x10AD);
        let t2 = generate_trace(a, 64, 0x10AD);
        assert_eq!(fingerprint(&t1), fingerprint(&t2));
        let t3 = generate_trace(a, 64, 7);
        assert_ne!(fingerprint(&t1), fingerprint(&t3));
    }

    #[test]
    fn poisson_mean_rate_is_sane() {
        let n = 4000usize;
        let t = generate_trace(Arrivals::Poisson { rate_rps: 1000.0 }, n, 42);
        assert_eq!(t.entries.len(), n);
        // Arrival offsets are nondecreasing.
        assert!(t.entries.windows(2).all(|w| w[0].at <= w[1].at));
        // Realized mean rate within 10% of offered (n is large).
        let realized = n as f64 / t.span().as_secs_f64();
        assert!(
            (realized - 1000.0).abs() < 100.0,
            "realized {realized} rps vs offered 1000"
        );
        // Mixed sizes actually mixed: both small and large molecules.
        assert!(t.entries.iter().any(|e| e.mol.n_atoms <= 12));
        assert!(t.entries.iter().any(|e| e.mol.n_atoms > 12));
    }

    #[test]
    fn bursty_keeps_mean_rate_but_spikes_peak() {
        let n = 2000usize;
        let t = generate_trace(
            Arrivals::Bursty {
                rate_rps: 500.0,
                peak_rps: 5000.0,
                burst: 20,
            },
            n,
            9,
        );
        let realized = n as f64 / t.span().as_secs_f64();
        assert!(
            (realized - 500.0).abs() < 75.0,
            "realized {realized} rps vs offered mean 500"
        );
        // Within-burst gaps run at the peak rate: the median gap is far
        // below the mean-rate gap (2ms at 500 rps).
        let mut gaps: Vec<u128> = t
            .entries
            .windows(2)
            .map(|w| (w[1].at - w[0].at).as_nanos())
            .collect();
        gaps.sort_unstable();
        let median_us = gaps[gaps.len() / 2] as f64 / 1e3;
        assert!(
            median_us < 1000.0,
            "median gap {median_us}us shows no burst structure"
        );
    }
}
