//! Bench output: aligned console tables + JSON dumps under
//! `target/bench_results/` (EXPERIMENTS.md cites these files).

use std::path::PathBuf;

use crate::util::json::{arr, num, obj, s, Json};

/// One approach's y-values over a shared x-axis.
#[derive(Clone, Debug)]
pub struct Series {
    pub name: String,
    pub values: Vec<f64>,
}

/// A figure-style result: x-axis + several series, with units.
#[derive(Clone, Debug)]
pub struct FigureResult {
    pub key: String,
    pub title: String,
    pub x_label: String,
    pub xs: Vec<f64>,
    pub y_label: String,
    pub series: Vec<Series>,
}

impl FigureResult {
    /// Render an aligned console table (x down, series across).
    pub fn render(&self) -> String {
        let mut out = format!("== {} — {} ==\n", self.key, self.title);
        out.push_str(&format!("{:>10}", self.x_label));
        for sr in &self.series {
            out.push_str(&format!(" {:>16}", sr.name));
        }
        out.push_str(&format!("   [{}]\n", self.y_label));
        for (i, x) in self.xs.iter().enumerate() {
            out.push_str(&format!("{x:>10}"));
            for sr in &self.series {
                match sr.values.get(i) {
                    Some(v) => out.push_str(&format!(" {v:>16.3}")),
                    None => out.push_str(&format!(" {:>16}", "-")),
                }
            }
            out.push('\n');
        }
        out
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("key", s(&self.key)),
            ("title", s(&self.title)),
            ("x_label", s(&self.x_label)),
            ("y_label", s(&self.y_label)),
            ("xs", arr(self.xs.iter().map(|&x| num(x)).collect())),
            (
                "series",
                arr(self
                    .series
                    .iter()
                    .map(|sr| {
                        obj(vec![
                            ("name", s(&sr.name)),
                            ("values", arr(sr.values.iter().map(|&v| num(v)).collect())),
                        ])
                    })
                    .collect()),
            ),
        ])
    }

    /// Write `target/bench_results/<key>.json`; returns the path.
    pub fn save(&self) -> anyhow::Result<PathBuf> {
        save_json(&self.key, &self.to_json())
    }
}

/// Write any bench result blob to `<dir>/<key>.json` (creating the
/// directory), e.g. the repo-root `BENCH_engine.json` the microbench's
/// `--json` flag records the perf trajectory in.
pub fn save_json_in(dir: &std::path::Path, key: &str, j: &Json) -> anyhow::Result<PathBuf> {
    std::fs::create_dir_all(dir)?;
    let path = dir.join(format!("{key}.json"));
    std::fs::write(&path, j.to_string())?;
    Ok(path)
}

/// Write any bench result blob to `target/bench_results/<key>.json`.
pub fn save_json(key: &str, j: &Json) -> anyhow::Result<PathBuf> {
    save_json_in(&PathBuf::from("target/bench_results"), key, j)
}

/// Simple two-column "paper vs ours" comparison row set (tables II-IV).
pub fn render_comparison(
    title: &str,
    header: &[&str],
    rows: &[Vec<String>],
) -> String {
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let mut out = format!("== {title} ==\n");
    for (i, h) in header.iter().enumerate() {
        out.push_str(&format!("{:>w$}  ", h, w = widths[i]));
    }
    out.push('\n');
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            out.push_str(&format!("{:>w$}  ", cell, w = widths[i]));
        }
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn figure_renders_and_roundtrips() {
        let f = FigureResult {
            key: "figtest".into(),
            title: "t".into(),
            x_label: "n_B".into(),
            xs: vec![8.0, 16.0],
            y_label: "GFLOPS".into(),
            series: vec![Series {
                name: "A".into(),
                values: vec![1.0, 2.0],
            }],
        };
        let r = f.render();
        assert!(r.contains("figtest") && r.contains("GFLOPS") && r.contains("2.000"));
        let j = f.to_json();
        assert_eq!(j.at(&["series"]).as_arr().unwrap().len(), 1);
    }

    #[test]
    fn comparison_aligns() {
        let out = render_comparison(
            "Table II",
            &["dataset", "paper", "ours"],
            &[vec!["Tox21".into(), "1.18x".into(), "1.3x".into()]],
        );
        assert!(out.contains("Tox21"));
        assert!(out.lines().count() == 3);
    }
}
