//! Bench harness (S8 in DESIGN.md): everything the figure/table
//! reproductions share.
//!
//! Each `rust/benches/*.rs` binary (all `harness = false`: criterion is
//! not in the vendored crate set, so [`crate::util::timer`] provides the
//! warmup/iterate/summarize driver) builds on:
//!
//! * [`workload`] — deterministic packed inputs for a sweep point,
//! * [`figures`] — the five-series SpMM comparison (measured CPU-PJRT
//!   *and* simulated P100) for Figs. 8/9/10,
//! * [`loadgen`] — deterministic open-loop arrival traces (Poisson /
//!   bursty) for the serving bench (DESIGN.md §14),
//! * [`report`] — human-readable tables + JSON result dumps under
//!   `target/bench_results/` (EXPERIMENTS.md is assembled from these).

pub mod figures;
pub mod loadgen;
pub mod report;
pub mod workload;

/// Iteration counts: quick mode for CI-ish runs (`BENCH_QUICK=1`),
/// fuller sampling otherwise.
#[derive(Clone, Copy, Debug)]
pub struct BenchOpts {
    pub warmup: usize,
    pub min_iters: usize,
    pub max_iters: usize,
    pub min_time_s: f64,
}

impl BenchOpts {
    pub fn from_env() -> Self {
        if std::env::var("BENCH_QUICK").is_ok() {
            BenchOpts {
                warmup: 1,
                min_iters: 2,
                max_iters: 3,
                min_time_s: 0.0,
            }
        } else {
            BenchOpts {
                warmup: 1,
                min_iters: 3,
                max_iters: 8,
                min_time_s: 0.3,
            }
        }
    }
}
