//! Deterministic benchmark workloads: one packed input set per sweep
//! point, identical across all five approaches (the §V-A methodology:
//! same matrices, different algorithms).

use crate::runtime::artifact::SweepSpec;
use crate::runtime::Tensor;
use crate::sparse::batch::{
    densify_batch, random_dense_batch, PaddedCsrBatch, PaddedEllBatch, PaddedStBatch,
};
use crate::sparse::coo::Coo;
use crate::sparse::engine::{CsrKernel, EllKernel, GemmKernel, StKernel};
use crate::sparse::random::{random_batch, random_mixed_batch, RandomSpec};
use crate::util::rng::Rng;

/// All tensor sets one sweep point needs, for every approach.
pub struct SpmmWorkload {
    pub dim: usize,
    pub z: usize,
    pub batch: usize,
    pub nb: usize,
    pub nnz_cap: usize,
    /// Total *real* non-zeros across the batch (the FLOP numerator; for
    /// mixed batches this is less than batch * nnz_cap).
    pub real_nnz: usize,
    pub mats: Vec<Coo>,
    pub st: PaddedStBatch,
    pub csr: PaddedCsrBatch,
    pub ell: PaddedEllBatch,
    pub dense: Vec<f32>,
    pub a_dense: Vec<f32>,
}

impl SpmmWorkload {
    /// Build the workload for one (sweep, n_b) point. Seeds derive from
    /// the sweep key so every approach sees identical matrices and
    /// repeated runs are reproducible.
    pub fn build(sw: &SweepSpec, nb: usize) -> anyhow::Result<SpmmWorkload> {
        let seed = 0x5EED ^ (sw.dim as u64) << 32 ^ (sw.z as u64) << 16 ^ nb as u64;
        let mut rng = Rng::new(seed);
        let mats = if sw.mixed {
            // Fig. 10: dims in [32, 256], nnz/row in [1, 5].
            random_mixed_batch(&mut rng, (32, sw.dim), (1, sw.z), sw.batch)
        } else {
            random_batch(&mut rng, &RandomSpec::new(sw.dim, sw.z), sw.batch)
        };
        let nnz_cap = sw.nnz_cap();
        let real_nnz = mats.iter().map(Coo::nnz).sum();
        let st = PaddedStBatch::pack(&mats, sw.dim, nnz_cap)?;
        let csr = PaddedCsrBatch::pack(&mats, sw.dim, nnz_cap)?;
        let ell = PaddedEllBatch::pack_auto(&mats, sw.dim)?;
        let dense = random_dense_batch(&mut rng, sw.batch, sw.dim, nb);
        let a_dense = densify_batch(&mats, sw.dim);
        Ok(SpmmWorkload {
            dim: sw.dim,
            z: sw.z,
            batch: sw.batch,
            nb,
            nnz_cap,
            real_nnz,
            mats,
            st,
            csr,
            ell,
            dense,
            a_dense,
        })
    }

    /// Engine backend over the ST batch (whole workload, one dispatch).
    pub fn st_kernel(&self) -> StKernel<'_> {
        StKernel::new(&self.st)
    }

    /// Engine backend over the CSR batch.
    pub fn csr_kernel(&self) -> CsrKernel<'_> {
        CsrKernel::new(&self.csr)
    }

    /// Engine backend over the ELL batch.
    pub fn ell_kernel(&self) -> EllKernel<'_> {
        EllKernel::from_padded(&self.ell)
    }

    /// Engine dense-GEMM baseline over the densified batch.
    pub fn gemm_kernel(&self) -> GemmKernel<'_> {
        GemmKernel::new(&self.a_dense, self.batch, self.dim, self.dim)
    }

    /// Inputs for the batched ST artifact.
    pub fn st_batched_inputs(&self) -> Vec<Tensor> {
        vec![
            Tensor::i32(&[self.batch, self.nnz_cap, 2], self.st.ids.clone()),
            Tensor::f32(&[self.batch, self.nnz_cap], self.st.vals.clone()),
            Tensor::f32(&[self.batch, self.dim, self.nb], self.dense.clone()),
        ]
    }

    /// Inputs for the batched CSR artifact.
    pub fn csr_batched_inputs(&self) -> Vec<Tensor> {
        vec![
            Tensor::i32(&[self.batch, self.dim + 1], self.csr.rpt.clone()),
            Tensor::i32(&[self.batch, self.nnz_cap], self.csr.col_ids.clone()),
            Tensor::f32(&[self.batch, self.nnz_cap], self.csr.vals.clone()),
            Tensor::f32(&[self.batch, self.dim, self.nb], self.dense.clone()),
        ]
    }

    /// Inputs for the batched GEMM artifact.
    pub fn gemm_inputs(&self) -> Vec<Tensor> {
        vec![
            Tensor::f32(&[self.batch, self.dim, self.dim], self.a_dense.clone()),
            Tensor::f32(&[self.batch, self.dim, self.nb], self.dense.clone()),
        ]
    }

    /// Per-matrix inputs for the non-batched (single) ST artifact.
    pub fn st_single_inputs(&self, b: usize) -> Vec<Tensor> {
        let one = self.st.single(b);
        vec![
            Tensor::i32(&[1, self.nnz_cap, 2], one.ids),
            Tensor::f32(&[1, self.nnz_cap], one.vals),
            Tensor::f32(
                &[1, self.dim, self.nb],
                self.dense[b * self.dim * self.nb..(b + 1) * self.dim * self.nb].to_vec(),
            ),
        ]
    }

    /// Per-matrix inputs for the non-batched (single) CSR artifact.
    pub fn csr_single_inputs(&self, b: usize) -> Vec<Tensor> {
        let one = self.csr.single(b);
        vec![
            Tensor::i32(&[1, self.dim + 1], one.rpt),
            Tensor::i32(&[1, self.nnz_cap], one.col_ids),
            Tensor::f32(&[1, self.nnz_cap], one.vals),
            Tensor::f32(
                &[1, self.dim, self.nb],
                self.dense[b * self.dim * self.nb..(b + 1) * self.dim * self.nb].to_vec(),
            ),
        ]
    }

    /// Paper GFLOPS metric: `2 * real_nnz * n_B / t`.
    pub fn gflops(&self, seconds: f64) -> f64 {
        2.0 * self.real_nnz as f64 * self.nb as f64 / seconds / 1e9
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifact::SweepSpec;

    fn sweep() -> SweepSpec {
        SweepSpec {
            key: "t".into(),
            dim: 16,
            z: 2,
            batch: 4,
            nbs: vec![8],
            mixed: false,
        }
    }

    #[test]
    fn deterministic_across_builds() {
        let a = SpmmWorkload::build(&sweep(), 8).unwrap();
        let b = SpmmWorkload::build(&sweep(), 8).unwrap();
        assert_eq!(a.st.vals, b.st.vals);
        assert_eq!(a.dense, b.dense);
        assert_eq!(a.real_nnz, 4 * 32);
    }

    #[test]
    fn st_and_csr_encode_same_matrices() {
        let w = SpmmWorkload::build(&sweep(), 8).unwrap();
        for (i, m) in w.mats.iter().enumerate() {
            let d = m.to_dense();
            // spot check densified batch agrees
            for r in 0..w.dim {
                for c in 0..w.dim {
                    assert_eq!(w.a_dense[i * w.dim * w.dim + r * w.dim + c], d.at(r, c));
                }
            }
        }
    }

    #[test]
    fn mixed_workload_has_padding() {
        let sw = SweepSpec {
            key: "mix".into(),
            dim: 64,
            z: 3,
            batch: 10,
            nbs: vec![16],
            mixed: true,
        };
        let w = SpmmWorkload::build(&sw, 16).unwrap();
        assert!(w.real_nnz < w.batch * w.nnz_cap);
        assert!(w.mats.iter().all(|m| m.rows <= 64));
    }

    #[test]
    fn engine_kernels_see_identical_matrices() {
        use crate::sparse::engine::{BatchedSpmm, Executor, Rhs};
        let w = SpmmWorkload::build(&sweep(), 8).unwrap();
        let exec = Executor::serial();
        let stk = w.st_kernel();
        let reference = exec.spmm(&stk, Rhs::PerSample(&w.dense), w.nb).unwrap();
        let csrk = w.csr_kernel();
        let ellk = w.ell_kernel();
        let gemk = w.gemm_kernel();
        let others: [&dyn BatchedSpmm; 3] = [&csrk, &ellk, &gemk];
        for k in others {
            let got = exec.spmm(k, Rhs::PerSample(&w.dense), w.nb).unwrap();
            for (i, (g, r)) in got.iter().zip(&reference).enumerate() {
                assert!(
                    (g - r).abs() <= 1e-5 + 1e-5 * r.abs(),
                    "{} elem {i}: {g} vs {r}",
                    k.name()
                );
            }
            assert_eq!(k.real_nnz(), w.real_nnz, "{}", k.name());
        }
    }

    #[test]
    fn gflops_uses_real_nnz() {
        let w = SpmmWorkload::build(&sweep(), 8).unwrap();
        let g = w.gflops(1e-3);
        assert!((g - 2.0 * 128.0 * 8.0 / 1e-3 / 1e9).abs() < 1e-9);
    }
}
