//! The five-series SpMM comparison behind Figs. 8, 9 and 10.
//!
//! Every sweep point is produced up to three ways:
//! * **engine** — the in-process batched-SpMM engine
//!   (`sparse::engine`): all four backends, serial fallback vs the
//!   sample-parallel executor. Needs no artifacts, so this series runs
//!   everywhere;
//! * **measured** — real executions on the CPU-PJRT runtime, where
//!   per-execute dispatch overhead plays the role CUDA launch overhead
//!   plays in the paper (DESIGN.md §2);
//! * **simulated** — the calibrated P100 cost model (DESIGN.md §5),
//!   which regenerates the paper's absolute GFLOPS landscape.

use crate::bench::report::{FigureResult, Series};
use crate::bench::workload::SpmmWorkload;
use crate::bench::BenchOpts;
use crate::coordinator::trainer::Trainer;
use crate::graph::dataset::{Dataset, DatasetKind};
use crate::runtime::artifact::SweepSpec;
use crate::runtime::Runtime;
use crate::simulator::cost::CostModel;
use crate::sparse::engine::{
    AutoThresholds, Backend, Executor, KernelBundle, KernelVariant, PlanStats, Rhs, SchedPolicy,
};
use crate::util::json::{arr, num, obj, s, Json};
use crate::util::timer;

/// Approach names, in the paper's legend order.
pub const APPROACHES: [&str; 5] = [
    "TF(non-batched)",
    "cuSPARSE(non-batched)",
    "BatchedSpMM(ST)",
    "BatchedSpMM(CSR)",
    "BatchedGEMM",
];

/// Engine series, legend order: the four fixed backends plus the
/// cost-model-selected `Backend::Auto` line (DESIGN.md §11).
pub const ENGINE_SERIES: [Backend; 5] = [
    Backend::St,
    Backend::Csr,
    Backend::Ell,
    Backend::Gemm,
    Backend::Auto,
];

/// Legend name of one engine series.
pub fn engine_legend(b: Backend) -> &'static str {
    match b {
        Backend::St => "Engine-ST",
        Backend::Csr => "Engine-CSR",
        Backend::Ell => "Engine-ELL",
        Backend::Gemm => "Engine-GEMM",
        Backend::Auto => "Engine-AUTO",
    }
}

/// Benchmark the engine series ([`ENGINE_SERIES`]: four fixed backends
/// plus `Backend::Auto`, which resolves per point via the cost model)
/// at every sweep point in five executor configurations: scalar serial
/// baseline (the pre-vectorization inner loops, DESIGN.md §10),
/// vectorized serial fallback, `threads`-wide static split (the legacy
/// contiguous sample partition), `threads`-wide work-stealing pool
/// (`threads = 0` = one per core; static and steal run the vectorized
/// kernels), and the work-stealing pool on the explicit-SIMD kernels
/// (`KernelVariant::Simd`, DESIGN.md §16 — AVX2 intrinsics under
/// `--features simd`, vectorized fallback otherwise). Series come in
/// (scalar, serial, static, steal, simd) quintuples per backend; no
/// runtime or artifacts are needed. scalar → serial isolates the
/// kernel-vectorization win, serial → static/steal the parallel win,
/// steal → simd the explicit-intrinsics win on top of both, and the
/// AUTO group vs the fixed groups ([`auto_vs_fixed_summary`]) shows
/// whether the auto thresholds are calibrated. On uniform sweeps
/// static and steal should coincide (the planner keeps the static fast
/// path); mixed sweeps (fig10) are where stealing pulls ahead.
pub fn run_engine_bench(
    sw: &SweepSpec,
    threads: usize,
    opts: &BenchOpts,
) -> anyhow::Result<FigureResult> {
    run_engine_bench_backends(sw, threads, opts, &ENGINE_SERIES)
}

/// [`run_engine_bench`] restricted to an explicit backend list
/// (`Backend::Auto` resolves per sweep point against all four packings
/// via the [`AutoThresholds`] cost model — env-calibratable, see
/// DESIGN.md §11).
pub fn run_engine_bench_backends(
    sw: &SweepSpec,
    threads: usize,
    opts: &BenchOpts,
    backends: &[Backend],
) -> anyhow::Result<FigureResult> {
    let t = Executor::resolve_threads(threads);
    let th = AutoThresholds::from_env();
    let scalar = Executor::with_variant(1, SchedPolicy::WorkStealing, KernelVariant::Scalar);
    let stat = Executor::with_policy(t, SchedPolicy::Static);
    let steal = Executor::new(t);
    let simd = Executor::with_variant(t, SchedPolicy::WorkStealing, KernelVariant::Simd);
    let labels = [
        "scalar".to_string(),
        "serial".to_string(),
        format!("static-{t}t"),
        format!("steal-{t}t"),
        format!("simd-{t}t"),
    ];
    let execs = [scalar, Executor::serial(), stat, steal, simd];
    let mut series: Vec<Series> = Vec::new();
    for &backend in backends {
        for label in &labels {
            series.push(Series {
                name: format!("{}({label})", engine_legend(backend)),
                values: Vec::new(),
            });
        }
    }
    for &nb in &sw.nbs {
        let w = SpmmWorkload::build(sw, nb)?;
        let stk = w.st_kernel();
        let csrk = w.csr_kernel();
        let ellk = w.ell_kernel();
        let gemk = w.gemm_kernel();
        let bundle = KernelBundle {
            st: Some(&stk),
            csr: Some(&csrk),
            ell: Some(&ellk),
            gemm: Some(&gemk),
            ell_width: Some(w.ell.width),
        };
        for (ki, &backend) in backends.iter().enumerate() {
            let (_, kernel) = bundle.resolve(backend, &th)?;
            let kernel = &kernel;
            for (ei, exec) in execs.iter().enumerate() {
                let mut out = vec![0f32; kernel.batch() * kernel.out_rows() * nb];
                // The zero-fill resets the += accumulation and must stay
                // outside the timed window (at large n_B it is a serial
                // memset that would otherwise dominate the measurement).
                let mut sample_once = || {
                    out.fill(0.0);
                    let t0 = std::time::Instant::now();
                    exec.dispatch(*kernel, Rhs::PerSample(&w.dense), nb, &mut out)
                        .expect("engine dispatch");
                    t0.elapsed().as_secs_f64()
                };
                for _ in 0..opts.warmup {
                    sample_once();
                }
                let mut samples: Vec<f64> = Vec::new();
                let mut total = 0.0;
                while samples.len() < opts.max_iters.max(1)
                    && (samples.len() < opts.min_iters || total < opts.min_time_s)
                {
                    let dt = sample_once();
                    samples.push(dt);
                    total += dt;
                }
                let t = samples.iter().sum::<f64>() / samples.len() as f64;
                series[ki * execs.len() + ei].values.push(w.gflops(t));
            }
        }
    }
    Ok(FigureResult {
        key: format!("{}_engine", sw.key),
        title: format!(
            "Batched-SpMM engine, CPU (dim={}, nnz/row={}, batch={}{})",
            sw.dim,
            sw.z,
            sw.batch,
            if sw.mixed { ", mixed" } else { "" }
        ),
        x_label: "n_B".into(),
        xs: sw.nbs.iter().map(|&n| n as f64).collect(),
        y_label: "GFLOPS (2*nnz*n_B/t)".into(),
        series,
    })
}

/// The large-graph tier sweep (DESIGN.md §12): one power-law graph per
/// x point (node counts in `node_counts`, Barabási–Albert `attach`
/// edges per node, deterministic seeds), dispatched as a batch-of-one
/// CSR through the engine in four configurations — untiled vs
/// cache-tiled kernels (`KernelVariant::Tiled`, tile width from
/// `BSPMM_TILE_COLS`) × static vs work-stealing scheduling. The
/// tiled/untiled contrast isolates the GE-SpMM-style column-tiling win
/// at feature widths where the dense operand overflows L2; the
/// static/steal contrast shows the degree-bucketed planner riding the
/// skewed row mass (hub rows land in narrow row blocks instead of
/// serializing one worker).
pub fn run_large_graph_bench(
    node_counts: &[usize],
    attach: usize,
    nb: usize,
    threads: usize,
    opts: &BenchOpts,
) -> anyhow::Result<FigureResult> {
    use crate::graph::powerlaw::power_law_graph;
    use crate::sparse::batch::random_dense_batch;
    use crate::sparse::engine::CsrKernel;
    use crate::util::rng::Rng;

    anyhow::ensure!(!node_counts.is_empty(), "large sweep needs node counts");
    let t = Executor::resolve_threads(threads);
    let configs = [
        ("untiled", SchedPolicy::Static, KernelVariant::Vectorized),
        ("untiled", SchedPolicy::WorkStealing, KernelVariant::Vectorized),
        ("tiled", SchedPolicy::Static, KernelVariant::Tiled),
        ("tiled", SchedPolicy::WorkStealing, KernelVariant::Tiled),
    ];
    let mut series: Vec<Series> = configs
        .iter()
        .map(|(tile, policy, _)| Series {
            name: format!(
                "Engine-CSR({tile},{}-{t}t)",
                if *policy == SchedPolicy::Static { "static" } else { "steal" }
            ),
            values: Vec::new(),
        })
        .collect();
    for (i, &nodes) in node_counts.iter().enumerate() {
        let g = power_law_graph(nodes, attach, 0xBA5E + i as u64)?;
        let kernel = CsrKernel::new(g.csr());
        let mut rng = Rng::new(0xD0_0D + i as u64);
        let dense = random_dense_batch(&mut rng, 1, nodes, nb);
        let mut out = vec![0f32; nodes * nb];
        let gflops = |secs: f64| 2.0 * g.nnz() as f64 * nb as f64 / (secs * 1e9);
        for (ci, &(_, policy, variant)) in configs.iter().enumerate() {
            let exec = Executor::with_variant(t, policy, variant);
            let mut sample_once = || {
                out.fill(0.0);
                let t0 = std::time::Instant::now();
                exec.dispatch(&kernel, Rhs::PerSample(&dense), nb, &mut out)
                    .expect("large-graph dispatch");
                t0.elapsed().as_secs_f64()
            };
            for _ in 0..opts.warmup {
                sample_once();
            }
            let mut samples: Vec<f64> = Vec::new();
            let mut total = 0.0;
            while samples.len() < opts.max_iters.max(1)
                && (samples.len() < opts.min_iters || total < opts.min_time_s)
            {
                let dt = sample_once();
                samples.push(dt);
                total += dt;
            }
            let mean = samples.iter().sum::<f64>() / samples.len() as f64;
            series[ci].values.push(gflops(mean));
        }
    }
    Ok(FigureResult {
        key: "large_engine".into(),
        title: format!(
            "Large-graph power-law CSR SpMM (attach={attach}, n_B={nb}, \
             tiled vs untiled x static vs stealing)"
        ),
        x_label: "nodes".into(),
        xs: node_counts.iter().map(|&n| n as f64).collect(),
        y_label: "GFLOPS (2*nnz*n_B/t)".into(),
        series,
    })
}

/// Per-backend speedup lines for an engine figure (series arranged in
/// (scalar, serial, static, steal, simd) quintuples, as
/// `run_engine_bench` emits them): the scalar → serial ratio is the
/// pure vectorization win, serial → static/steal the parallel win on
/// top of it, and steal → simd the explicit-intrinsics win over the
/// autovectorized kernels (1.0x when the `simd` feature is off or the
/// CPU lacks AVX2 — the variant falls back to the vectorized loops).
pub fn engine_speedup_summary(f: &FigureResult) -> String {
    let best = |s: &Series| {
        s.values
            .iter()
            .cloned()
            .filter(|v| v.is_finite())
            .fold(f64::MIN, f64::max)
    };
    let mut out = String::new();
    for group in f.series.chunks(5) {
        if group.len() != 5 {
            continue;
        }
        let (sc, s, st, wk, sd) = (
            best(&group[0]),
            best(&group[1]),
            best(&group[2]),
            best(&group[3]),
            best(&group[4]),
        );
        if sc > 0.0 && s > 0.0 && st > 0.0 && wk > 0.0 && sd > 0.0 {
            out.push_str(&format!(
                "  {} {sc:.3} -> {} {s:.3} ({:.2}x vector speedup) -> {} {st:.3} ({:.2}x) \
                 -> {} {wk:.3} GFLOPS ({:.2}x parallel speedup); {} {sd:.3} ({:.2}x simd-vs-steal)\n",
                group[0].name,
                group[1].name,
                s / sc,
                group[2].name,
                st / s,
                group[3].name,
                wk / s,
                group[4].name,
                sd / wk
            ));
        }
    }
    out
}

/// Quantized-precision inference sweep (DESIGN.md §16): the batched
/// adjacency SpMM dispatched from f32, bf16 and int8 ELL value storage
/// on the work-stealing executor. Each precision contributes a GFLOPS
/// series and a bytes-moved-per-dispatch series (quantized value array
/// + i32 column ids + f32 dense operand + f32 output — the value-array
/// term is what shrinks 2x/4x), so the record shows whether the
/// bandwidth saving translates into throughput at each n_B. GFLOPS
/// count the same effective f32 flops for every precision (the
/// dequantize-on-the-fly kernels do the same multiply-adds), so the
/// series are directly a time ratio.
pub fn run_precision_bench(
    sw: &SweepSpec,
    threads: usize,
    opts: &BenchOpts,
) -> anyhow::Result<FigureResult> {
    use crate::sparse::batch::QuantizedEllBatch;
    use crate::sparse::engine::{BatchedSpmm, DType, QuantEllKernel};

    let t = Executor::resolve_threads(threads);
    let exec = Executor::new(t);
    let mut series: Vec<Series> = Vec::new();
    for dt in DType::ALL {
        series.push(Series {
            name: format!("Engine-ELL[{}]({t}t)", dt.name()),
            values: Vec::new(),
        });
        series.push(Series {
            name: format!("Engine-ELL[{}](MB/dispatch)", dt.name()),
            values: Vec::new(),
        });
    }
    for &nb in &sw.nbs {
        let w = SpmmWorkload::build(sw, nb)?;
        let ellk = w.ell_kernel();
        let quant: Vec<QuantizedEllBatch> = [DType::Bf16, DType::Int8]
            .iter()
            .map(|&dt| QuantizedEllBatch::from_padded(&w.ell, dt))
            .collect::<anyhow::Result<_>>()?;
        let qks: Vec<QuantEllKernel<'_>> = quant.iter().map(QuantEllKernel::from_batch).collect();
        for (di, dt) in DType::ALL.iter().enumerate() {
            let kernel: &dyn BatchedSpmm = match di {
                0 => &ellk,
                i => &qks[i - 1],
            };
            let mut out = vec![0f32; kernel.batch() * kernel.out_rows() * nb];
            let mut sample_once = || {
                out.fill(0.0);
                let t0 = std::time::Instant::now();
                exec.dispatch(kernel, Rhs::PerSample(&w.dense), nb, &mut out)
                    .expect("precision dispatch");
                t0.elapsed().as_secs_f64()
            };
            for _ in 0..opts.warmup {
                sample_once();
            }
            let mut samples: Vec<f64> = Vec::new();
            let mut total = 0.0;
            while samples.len() < opts.max_iters.max(1)
                && (samples.len() < opts.min_iters || total < opts.min_time_s)
            {
                let elapsed = sample_once();
                samples.push(elapsed);
                total += elapsed;
            }
            let mean = samples.iter().sum::<f64>() / samples.len() as f64;
            let moved_mb = (w.ell.vals.len() * dt.value_bytes()
                + w.ell.cols.len() * 4
                + w.dense.len() * 4
                + out.len() * 4) as f64
                / 1e6;
            series[di * 2].values.push(w.gflops(mean));
            series[di * 2 + 1].values.push(moved_mb);
        }
    }
    Ok(FigureResult {
        key: format!("{}_precision", sw.key),
        title: format!(
            "Quantized ELL SpMM precision (dim={}, nnz/row={}, batch={}{})",
            sw.dim,
            sw.z,
            sw.batch,
            if sw.mixed { ", mixed" } else { "" }
        ),
        x_label: "n_B".into(),
        xs: sw.nbs.iter().map(|&n| n as f64).collect(),
        y_label: "GFLOPS (bytes series: MB moved per dispatch)".into(),
        series,
    })
}

/// Speedup-vs-f32 lines for a precision figure
/// ([`run_precision_bench`] series come in (GFLOPS, MB/dispatch) pairs
/// per dtype, f32 first): peak quantized GFLOPS against peak f32, with
/// the bytes-moved contrast that explains (or indicts) the ratio.
pub fn precision_speedup_summary(f: &FigureResult) -> String {
    let best = |s: &Series| {
        s.values
            .iter()
            .cloned()
            .filter(|v| v.is_finite())
            .fold(f64::MIN, f64::max)
    };
    let mut out = String::new();
    if f.series.len() < 4 {
        return out;
    }
    let f32_gflops = best(&f.series[0]);
    let f32_mb = best(&f.series[1]);
    if f32_gflops <= 0.0 {
        return out;
    }
    for pair in f.series.chunks(2).skip(1) {
        if pair.len() != 2 {
            continue;
        }
        let (g, mb) = (best(&pair[0]), best(&pair[1]));
        if g > 0.0 {
            out.push_str(&format!(
                "  {} {g:.3} GFLOPS = {:.2}x speedup vs f32 {f32_gflops:.3} \
                 ({mb:.2} vs {f32_mb:.2} MB/dispatch)\n",
                pair[0].name,
                g / f32_gflops,
            ));
        }
    }
    out
}

/// Auto-vs-best-fixed comparison for an engine figure that carries an
/// `Engine-AUTO` series group: peak auto GFLOPS against the peak over
/// every fixed-backend series. A ratio near (or above) 1.0 means the
/// cost-model thresholds are well calibrated for this sweep; far below
/// 1.0 means recalibrate (DESIGN.md §11).
pub fn auto_vs_fixed_summary(f: &FigureResult) -> String {
    let best = |s: &Series| {
        s.values
            .iter()
            .cloned()
            .filter(|v| v.is_finite())
            .fold(f64::MIN, f64::max)
    };
    let (mut auto_best, mut fixed_best) = (f64::MIN, f64::MIN);
    let mut fixed_name = "";
    for s in &f.series {
        let v = best(s);
        if s.name.starts_with("Engine-AUTO") {
            auto_best = auto_best.max(v);
        } else if v > fixed_best {
            fixed_best = v;
            fixed_name = &s.name;
        }
    }
    if auto_best <= 0.0 || fixed_best <= 0.0 {
        return String::new();
    }
    format!(
        "  auto-backend {auto_best:.3} GFLOPS vs best fixed {fixed_name} {fixed_best:.3} \
         ({:.2}x of best fixed)\n",
        auto_best / fixed_best
    )
}

/// Which concrete backend `Backend::Auto` resolves to at each sweep
/// point (pure cost-model resolution — no timing). Note it re-packs
/// the workload per point to read its profile, so it is meant for the
/// one-or-two-point microbench summaries, not for inner loops.
pub fn auto_choices(sw: &SweepSpec) -> anyhow::Result<Vec<(usize, Backend)>> {
    let th = AutoThresholds::from_env();
    let mut out = Vec::new();
    for &nb in &sw.nbs {
        let w = SpmmWorkload::build(sw, nb)?;
        let stk = w.st_kernel();
        let csrk = w.csr_kernel();
        let ellk = w.ell_kernel();
        let gemk = w.gemm_kernel();
        let bundle = KernelBundle {
            st: Some(&stk),
            csr: Some(&csrk),
            ell: Some(&ellk),
            gemm: Some(&gemk),
            ell_width: Some(w.ell.width),
        };
        let (chosen, _) = bundle.resolve(Backend::Auto, &th)?;
        out.push((nb, chosen));
    }
    Ok(out)
}

/// Cold-plan vs cached-plan host `train_step` comparison
/// ([`run_plan_bench`]): what the plan/execute split saves per step.
#[derive(Clone, Debug)]
pub struct PlanBench {
    pub model: String,
    pub batch: usize,
    /// Mean seconds per step with the plan cache cleared before every
    /// step (compile + arena warm-up paid each time).
    pub cold_secs: f64,
    /// Mean seconds per step replaying the cached plan.
    pub cached_secs: f64,
    /// Plan/arena accounting of the cached phase alone (counter fields
    /// are deltas over that phase — `plans_built` should be 0 and every
    /// step a replay; `arena_bytes` is the absolute footprint).
    pub stats: PlanStats,
}

impl PlanBench {
    /// The printable summary line the microbench and CHANGES.md quote.
    pub fn render(&self) -> String {
        format!(
            "plan_reuse[{}, B={}]: cold {:.2} ms/step -> cached {:.2} ms/step \
             ({:.2}x plan-reuse speedup; arena {} KiB, {} zero-fills elided)\n",
            self.model,
            self.batch,
            self.cold_secs * 1e3,
            self.cached_secs * 1e3,
            self.cold_secs / self.cached_secs,
            self.stats.arena_bytes / 1024,
            self.stats.zero_fills_elided,
        )
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("model", s(&self.model)),
            ("batch", num(self.batch as f64)),
            (
                "points",
                arr(vec![
                    obj(vec![
                        ("label", s("cold-plan")),
                        ("secs_per_step", num(self.cold_secs)),
                    ]),
                    obj(vec![
                        ("label", s("cached-plan")),
                        ("secs_per_step", num(self.cached_secs)),
                    ]),
                ]),
            ),
            ("plans_built", num(self.stats.plans_built as f64)),
            ("replays", num(self.stats.replays as f64)),
            ("arena_bytes", num(self.stats.arena_bytes as f64)),
            (
                "zero_fills_elided",
                num(self.stats.zero_fills_elided as f64),
            ),
        ])
    }
}

/// Host `train_step` under the two plan regimes (DESIGN.md §11): the
/// *cold* configuration clears the trainer's plan cache before every
/// step, so each step re-compiles its plan and re-allocates its arena;
/// the *cached* configuration replays one compiled plan. Same trainer,
/// same pool, same minibatch — the delta is exactly what plan/workspace
/// caching saves.
pub fn run_plan_bench(
    model: &str,
    batch: usize,
    threads: usize,
    opts: &BenchOpts,
) -> anyhow::Result<PlanBench> {
    anyhow::ensure!(batch >= 1, "plan bench needs batch >= 1");
    let kind = match model {
        "tox21" => DatasetKind::Tox21,
        "reaction100" => DatasetKind::Reaction100,
        other => anyhow::bail!("no dataset for model '{other}'"),
    };
    let data = Dataset::generate(kind, batch, 77);
    let idx: Vec<usize> = (0..batch).collect();
    let t = Executor::resolve_threads(threads);
    let mut tr = Trainer::new_host(model, t)?;
    let mb = data.pack_batch(&idx, tr.cfg.max_nodes, tr.cfg.ell_width)?;
    let lr = 1e-3f32;
    let mean = |samples: Vec<f64>| samples.iter().sum::<f64>() / samples.len() as f64;
    let cold_samples = timer::bench_adaptive(
        opts.warmup,
        opts.min_iters,
        opts.max_iters.max(1),
        opts.min_time_s,
        || {
            tr.clear_plan_cache();
            tr.step_batched(&mb, lr).expect("cold-plan train step");
        },
    );
    // Snapshot here so the recorded counters cover the cached phase
    // only — the cold loop above built one plan per iteration by
    // design, which must not read as cache thrash in the record.
    let s0 = tr.plan_stats();
    // At least one warm-up step so the cached samples never include the
    // one-time compile.
    let cached_samples = timer::bench_adaptive(
        opts.warmup.max(1),
        opts.min_iters,
        opts.max_iters.max(1),
        opts.min_time_s,
        || {
            tr.step_batched(&mb, lr).expect("cached-plan train step");
        },
    );
    let s1 = tr.plan_stats();
    Ok(PlanBench {
        model: model.to_string(),
        batch,
        cold_secs: mean(cold_samples),
        cached_secs: mean(cached_samples),
        stats: PlanStats {
            plans_built: s1.plans_built - s0.plans_built,
            plans_warmed: s1.plans_warmed - s0.plans_warmed,
            replays: s1.replays - s0.replays,
            plans_evicted: s1.plans_evicted - s0.plans_evicted,
            arena_bytes: s1.arena_bytes,
            arena_reuses: s1.arena_reuses - s0.arena_reuses,
            zero_fills_elided: s1.zero_fills_elided - s0.zero_fills_elided,
        },
    })
}

/// AOT warm-start check ([`run_aot_warmstart_bench`], DESIGN.md §13):
/// dump a trainer's compiled plans as artifacts, boot a fresh trainer
/// from them, and verify the fleet cold-start contract — the warm
/// trainer compiles zero plans and its training stream is bit-identical
/// to a cold boot's.
#[derive(Clone, Debug)]
pub struct AotWarmstartBench {
    pub model: String,
    pub batch: usize,
    /// First-step wall seconds on a cold boot (plan compiled inline).
    pub cold_first_secs: f64,
    /// First-step wall seconds on a warm boot (plan replayed straight
    /// from the deserialized artifact).
    pub warm_first_secs: f64,
    /// Mean steady-state seconds per step on the warm trainer.
    pub steady_secs: f64,
    /// Plans the warm trainer compiled across the whole run. The
    /// contract is 0 — every geometry it ran shipped as an artifact.
    pub plans_built: u64,
    /// Plans installed from artifacts at boot.
    pub plans_warmed: u64,
    /// Warm losses and final parameters bit-identical to the cold run.
    pub bit_identical: bool,
}

impl AotWarmstartBench {
    /// The printable summary line the microbench and CI quote.
    pub fn render(&self) -> String {
        format!(
            "aot_warmstart[{}, B={}]: cold first step {:.2} ms -> warm {:.2} ms \
             (steady {:.2} ms/step; plans_built={}, plans_warmed={}, {})\n",
            self.model,
            self.batch,
            self.cold_first_secs * 1e3,
            self.warm_first_secs * 1e3,
            self.steady_secs * 1e3,
            self.plans_built,
            self.plans_warmed,
            if self.bit_identical {
                "bit-identical"
            } else {
                "OUTPUT MISMATCH"
            },
        )
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("model", s(&self.model)),
            ("batch", num(self.batch as f64)),
            (
                "points",
                arr(vec![
                    obj(vec![
                        ("label", s("cold-first-step")),
                        ("secs_per_step", num(self.cold_first_secs)),
                    ]),
                    obj(vec![
                        ("label", s("warm-first-step")),
                        ("secs_per_step", num(self.warm_first_secs)),
                    ]),
                    obj(vec![
                        ("label", s("warm-steady")),
                        ("secs_per_step", num(self.steady_secs)),
                    ]),
                ]),
            ),
            ("plans_built", num(self.plans_built as f64)),
            ("plans_warmed", num(self.plans_warmed as f64)),
            ("bit_identical", Json::Bool(self.bit_identical)),
        ])
    }
}

/// Round-trip the AOT plan-artifact flow end to end: a producer trainer
/// compiles this geometry's train plan and [`Trainer::export_plans`]
/// dumps it; a cold and a warm consumer (same seed) then train the same
/// minibatch stream, and the warm one must report `plans_built == 0`
/// with bit-identical losses and parameters. Artifacts go under a
/// process-scoped temp directory that is removed afterwards.
pub fn run_aot_warmstart_bench(
    model: &str,
    batch: usize,
    threads: usize,
    opts: &BenchOpts,
) -> anyhow::Result<AotWarmstartBench> {
    anyhow::ensure!(batch >= 1, "aot warm-start bench needs batch >= 1");
    let kind = match model {
        "tox21" => DatasetKind::Tox21,
        "reaction100" => DatasetKind::Reaction100,
        other => anyhow::bail!("no dataset for model '{other}'"),
    };
    let data = Dataset::generate(kind, batch, 77);
    let idx: Vec<usize> = (0..batch).collect();
    let t = Executor::resolve_threads(threads);
    let lr = 1e-3f32;
    let dir = std::env::temp_dir().join(format!(
        "bspmm_aot_warmstart_{}_{model}_b{batch}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);

    // Producer: pay the compile once, ship it. Its first step doubles
    // as the cold-first-step timing.
    let mut producer = Trainer::new_host(model, t)?;
    let mb = data.pack_batch(&idx, producer.cfg.max_nodes, producer.cfg.ell_width)?;
    let (cold_first_secs, step) = timer::time_once(|| producer.step_batched(&mb, lr));
    step?;
    let exported = producer.export_plans(&dir)?;
    anyhow::ensure!(exported >= 1, "producer exported no plans");

    // Parity streams: cold and warm consumers start from identical
    // seed parameters, so their losses and parameters must stay
    // bit-for-bit equal if (and only if) artifact replay is exact.
    let steps = opts.min_iters.max(3);
    let mut cold = Trainer::new_host(model, t)?;
    let mut cold_losses = Vec::with_capacity(steps);
    for _ in 0..steps {
        cold_losses.push(cold.step_batched(&mb, lr)?);
    }

    let mut warm = Trainer::new_host(model, t)?;
    let report = warm.warm_start_plans(&dir)?;
    anyhow::ensure!(
        report.loaded >= 1,
        "warm start loaded nothing: {}",
        report.summary()
    );
    let (warm_first_secs, first) = timer::time_once(|| warm.step_batched(&mb, lr));
    let mut warm_losses = vec![first?];
    for _ in 1..steps {
        warm_losses.push(warm.step_batched(&mb, lr)?);
    }
    // Compare while both trainers have taken exactly `steps` steps —
    // the steady timing below keeps stepping the warm one.
    let bit_identical = cold_losses == warm_losses && cold.params.data == warm.params.data;

    let steady_samples = timer::bench_adaptive(
        0,
        opts.min_iters,
        opts.max_iters.max(1),
        opts.min_time_s,
        || {
            warm.step_batched(&mb, lr).expect("warm steady step");
        },
    );
    let steady_secs = steady_samples.iter().sum::<f64>() / steady_samples.len() as f64;

    let ws = warm.plan_stats();
    let _ = std::fs::remove_dir_all(&dir);
    Ok(AotWarmstartBench {
        model: model.to_string(),
        batch,
        cold_first_secs,
        warm_first_secs,
        steady_secs,
        plans_built: ws.plans_built,
        plans_warmed: ws.plans_warmed,
        bit_identical,
    })
}

/// One (policy × offered load) serving measurement from
/// [`run_serving_bench`]: SLO quantiles, throughput, shed count and
/// occupancy at a fixed open-loop offered rate.
#[derive(Clone, Debug)]
pub struct ServingPoint {
    /// Long-run mean rate of the open-loop trace driven at the server.
    pub offered_rps: f64,
    /// Requests the trace submitted (admitted + shed).
    pub submitted: u64,
    /// Requests that completed (executed and answered with logits).
    pub requests: u64,
    /// Requests refused (admission bounce or deadline drop).
    pub shed: u64,
    pub throughput_rps: f64,
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub p999_ms: f64,
    pub mean_batch_size: f64,
    pub mean_occupancy: f64,
    pub queue_depth_hwm: u64,
    /// `(size, batches_of_that_size)` occupancy histogram.
    pub batch_size_counts: Vec<(usize, u64)>,
}

impl ServingPoint {
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("offered_rps", num(self.offered_rps)),
            ("submitted", num(self.submitted as f64)),
            ("requests", num(self.requests as f64)),
            ("shed", num(self.shed as f64)),
            ("throughput_rps", num(self.throughput_rps)),
            ("p50_ms", num(self.p50_ms)),
            ("p99_ms", num(self.p99_ms)),
            ("p999_ms", num(self.p999_ms)),
            ("mean_batch_size", num(self.mean_batch_size)),
            ("mean_occupancy", num(self.mean_occupancy)),
            ("queue_depth_hwm", num(self.queue_depth_hwm as f64)),
            (
                "batch_size_counts",
                arr(self
                    .batch_size_counts
                    .iter()
                    .map(|&(size, count)| {
                        arr(vec![num(size as f64), num(count as f64)])
                    })
                    .collect()),
            ),
        ])
    }
}

/// One batch-close policy's throughput-vs-latency curve.
#[derive(Clone, Debug)]
pub struct ServingSeries {
    /// `"fixed-size"` or `"size-or-age"` — CI greps these names out of
    /// `BENCH_serving.json`.
    pub name: String,
    pub points: Vec<ServingPoint>,
}

/// The serving bench result ([`run_serving_bench`], DESIGN.md §14):
/// offered load × batch-close policy, one [`ServingPoint`] each, on
/// the host-engine backend under a deterministic open-loop trace.
#[derive(Clone, Debug)]
pub struct ServingBench {
    pub model: String,
    pub max_batch: usize,
    pub threads: usize,
    /// Calibrated full-batch service capacity (requests/s) this
    /// machine can sustain — offered loads are fractions of it, so the
    /// bench shape is machine-independent.
    pub capacity_rps: f64,
    pub age_cap: std::time::Duration,
    pub queue_bound: usize,
    pub series: Vec<ServingSeries>,
}

impl ServingBench {
    /// p99 contrast at the lowest offered load — the acceptance
    /// comparison: the adaptive size-or-age close must beat fixed-size
    /// where batches are slow to fill.
    pub fn headline(&self) -> Option<String> {
        let fixed = self
            .series
            .iter()
            .find(|s| s.name == "fixed-size")?
            .points
            .first()?;
        let adapt = self
            .series
            .iter()
            .find(|s| s.name == "size-or-age")?
            .points
            .first()?;
        Some(format!(
            "  at {:.0} rps offered: size-or-age p99 {:.1} ms vs fixed-size p99 {:.1} ms ({})\n",
            fixed.offered_rps,
            adapt.p99_ms,
            fixed.p99_ms,
            if adapt.p99_ms < fixed.p99_ms {
                format!("{:.1}x lower", fixed.p99_ms / adapt.p99_ms)
            } else {
                "NOT LOWER".into()
            },
        ))
    }

    /// Burst sensitivity at the lowest offered load: the same mean rate
    /// reshaped into bursts against the smooth Poisson stream, both
    /// under the size-or-age close.
    pub fn bursty_headline(&self) -> Option<String> {
        let smooth = self
            .series
            .iter()
            .find(|s| s.name == "size-or-age")?
            .points
            .first()?;
        let bursty = self
            .series
            .iter()
            .find(|s| s.name == "size-or-age-bursty")?
            .points
            .first()?;
        Some(format!(
            "  at {:.0} rps mean: bursty arrivals p99 {:.1} ms vs Poisson p99 {:.1} ms \
             (depth hwm {} vs {})\n",
            smooth.offered_rps,
            bursty.p99_ms,
            smooth.p99_ms,
            bursty.queue_depth_hwm,
            smooth.queue_depth_hwm,
        ))
    }

    /// The printable summary the microbench and CI quote.
    pub fn render(&self) -> String {
        let mut out = format!(
            "serving[{}, B={}, {}t]: capacity ~{:.0} rps, age cap {:.1} ms, queue bound {}\n",
            self.model,
            self.max_batch,
            self.threads,
            self.capacity_rps,
            self.age_cap.as_secs_f64() * 1e3,
            self.queue_bound,
        );
        let npts = self.series.iter().map(|s| s.points.len()).min().unwrap_or(0);
        for i in 0..npts {
            out.push_str(&format!(
                "  load {:.0} rps:\n",
                self.series[0].points[i].offered_rps
            ));
            for se in &self.series {
                let p = &se.points[i];
                out.push_str(&format!(
                    "    {:<11} p50 {:.1} / p99 {:.1} / p99.9 {:.1} ms, {:.0} rps served, \
                     {} done, {} shed, occ {:.2}, depth hwm {}\n",
                    se.name,
                    p.p50_ms,
                    p.p99_ms,
                    p.p999_ms,
                    p.throughput_rps,
                    p.requests,
                    p.shed,
                    p.mean_occupancy,
                    p.queue_depth_hwm,
                ));
            }
        }
        if let Some(line) = self.headline() {
            out.push_str(&line);
        }
        if let Some(line) = self.bursty_headline() {
            out.push_str(&line);
        }
        out
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("model", s(&self.model)),
            ("max_batch", num(self.max_batch as f64)),
            ("threads", num(self.threads as f64)),
            ("capacity_rps", num(self.capacity_rps)),
            ("age_cap_us", num(self.age_cap.as_micros() as f64)),
            ("queue_bound", num(self.queue_bound as f64)),
            (
                "series",
                arr(self
                    .series
                    .iter()
                    .map(|se| {
                        obj(vec![
                            ("name", s(&se.name)),
                            (
                                "requests",
                                num(se.points.iter().map(|p| p.requests).sum::<u64>() as f64),
                            ),
                            ("points", arr(se.points.iter().map(ServingPoint::to_json).collect())),
                        ])
                    })
                    .collect()),
            ),
        ])
    }
}

/// Throughput-vs-latency serving bench (DESIGN.md §14): sweep offered
/// load × batch-close policy on the host-engine server under a
/// deterministic open-loop Poisson trace of mixed-size molecules.
///
/// Offered loads are derived from a calibration forward: one warm
/// full-batch forward gives the service capacity, and each sweep point
/// offers a fixed fraction of it — sub-saturation points where the
/// close policy dominates tail latency, and a saturation point
/// (offered > capacity) where the bounded admission queue must shed.
/// Both policies at a given load replay the *same* trace (same seed),
/// so "equal offered load" is equal byte for byte.
///
/// Every submitted request must be answered exactly once (served or
/// shed) — the bench hard-fails on a lost reply.
pub fn run_serving_bench(model: &str, threads: usize) -> anyhow::Result<ServingBench> {
    use std::path::PathBuf;
    use std::time::Duration;

    use crate::bench::loadgen::{generate_trace, submit_trace, Arrivals};
    use crate::coordinator::dispatch::HostDispatcher;
    use crate::coordinator::server::{DispatchMode, ServeBackend, Server, ServerConfig};
    use crate::coordinator::CloseRule;
    use crate::graph::dataset::pack_molecules;
    use crate::graph::molecule::{Molecule, MoleculeSpec};
    use crate::util::rng::Rng;

    let quick = std::env::var("BENCH_QUICK").is_ok();
    let max_batch = if quick { 8 } else { 16 };
    let threads = Executor::resolve_threads(threads);

    // ---- calibration: what does one full batch cost, warm? ----------
    let mut hd = HostDispatcher::synthetic(model, threads, 0x5EED)?;
    let mut rng = Rng::new(0xCA11);
    let spec = MoleculeSpec::default();
    let mols: Vec<Molecule> = (0..max_batch)
        .map(|_| Molecule::random(&mut rng, &spec))
        .collect();
    let refs: Vec<&Molecule> = mols.iter().collect();
    let mb = pack_molecules(&refs, max_batch, hd.cfg.max_nodes, hd.cfg.ell_width, hd.cfg.n_out)?;
    hd.forward(DispatchMode::Batched, &mb)?; // pay the plan compile
    let (batch_secs, fwd) = timer::time_once(|| hd.forward(DispatchMode::Batched, &mb));
    fwd?;
    drop(hd);
    let batch_secs = batch_secs.max(1e-6);
    let capacity_rps = max_batch as f64 / batch_secs;

    let queue_bound = 2 * max_batch;
    // Age cap ~2 batch times (floor 1 ms): small enough that the
    // fixed-size fill time dwarfs it at the low-load point, large
    // enough that adjacent arrivals still coalesce into one batch.
    let age_cap = Duration::from_secs_f64((2.0 * batch_secs).max(1e-3));

    // (offered rps, trace length): the low point fills a fixed-size
    // batch in 32-64 batch-times (that fill IS the fixed-size latency
    // penalty); the high point offers 2x capacity so the bounded queue
    // must shed. Trace lengths keep each point's wall time modest while
    // leaving the saturation point enough excess to hit the bound.
    let points: Vec<(f64, usize)> = if quick {
        vec![(capacity_rps / 32.0, 24), (2.0 * capacity_rps, 6 * queue_bound)]
    } else {
        vec![
            (capacity_rps / 64.0, 96),
            (capacity_rps / 4.0, 96),
            (2.0 * capacity_rps, (6 * queue_bound).max(192)),
        ]
    };

    let mut series = vec![
        ServingSeries {
            name: "fixed-size".into(),
            points: Vec::new(),
        },
        ServingSeries {
            name: "size-or-age".into(),
            points: Vec::new(),
        },
        // The same mean offered load reshaped into on/off bursts
        // (peak 4x mean, bursts one device batch deep): depth spikes
        // the smooth Poisson stream never produces, served under the
        // adaptive close rule.
        ServingSeries {
            name: "size-or-age-bursty".into(),
            points: Vec::new(),
        },
    ];
    for (pi, &(rate, n)) in points.iter().enumerate() {
        let seed = 0x5E21 + pi as u64;
        let poisson = generate_trace(Arrivals::Poisson { rate_rps: rate }, n, seed);
        let bursty = generate_trace(
            Arrivals::Bursty {
                rate_rps: rate,
                peak_rps: 4.0 * rate,
                burst: max_batch,
            },
            n,
            seed,
        );
        let runs = [
            (CloseRule::FixedSize, &poisson),
            (CloseRule::SizeOrAge, &poisson),
            (CloseRule::SizeOrAge, &bursty),
        ];
        for (si, (close, trace)) in runs.iter().enumerate() {
            let server = Server::start(ServerConfig {
                artifacts_dir: PathBuf::from("unused-for-host-backend"),
                model: model.into(),
                mode: DispatchMode::Batched,
                backend: ServeBackend::HostEngine { threads },
                max_batch,
                max_wait: age_cap,
                close: *close,
                queue_bound,
                deadline: None,
                params_path: None,
                registry: None,
                plans_dir: None,
            })?;
            let rxs = submit_trace(&server, trace);
            let snap = server.shutdown()?;
            let answered = rxs.iter().filter(|rx| rx.recv().is_ok()).count();
            anyhow::ensure!(
                answered == n,
                "serving bench lost replies: {answered}/{n} answered"
            );
            anyhow::ensure!(
                snap.requests + snap.shed == n as u64,
                "accounting mismatch: {} done + {} shed != {n}",
                snap.requests,
                snap.shed
            );
            series[si].points.push(ServingPoint {
                offered_rps: rate,
                submitted: n as u64,
                requests: snap.requests,
                shed: snap.shed,
                throughput_rps: snap.throughput_rps,
                p50_ms: snap.p50_latency_us as f64 / 1e3,
                p99_ms: snap.p99_latency_us as f64 / 1e3,
                p999_ms: snap.p999_latency_us as f64 / 1e3,
                mean_batch_size: snap.mean_batch_size,
                mean_occupancy: snap.mean_occupancy,
                queue_depth_hwm: snap.queue_depth_hwm,
                batch_size_counts: snap.batch_size_counts,
            });
        }
    }
    Ok(ServingBench {
        model: model.to_string(),
        max_batch,
        threads,
        capacity_rps,
        age_cap,
        queue_bound,
        series,
    })
}

/// One model's slice of the mixed-model serving sweep
/// ([`run_mixed_serving_bench`]).
#[derive(Clone, Debug)]
pub struct MixedModelPoint {
    pub model: String,
    pub requests: u64,
    pub shed: u64,
    pub batches: u64,
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub mean_occupancy: f64,
    /// Highest parameter version observed in this model's responses.
    pub max_version: u64,
}

/// The mixed-model serving record (DESIGN.md §15): two registered
/// models round-robined at one server, plans warm-started per tenant,
/// with a parameter hot swap landing mid-trace.
#[derive(Clone, Debug)]
pub struct MixedServingBench {
    pub models: Vec<String>,
    pub max_batch: usize,
    pub threads: usize,
    pub submitted: u64,
    /// Registry-wide hot swaps completed during the trace (>= 1 by
    /// construction — the bench swaps the first model mid-trace).
    pub param_swaps: u64,
    /// Plans compiled while serving — 0: every tenant's geometry was
    /// warm-started from its per-model artifact subdirectory.
    pub plans_built: u64,
    pub plans_warmed: u64,
    pub plan_replays: u64,
    pub per_model: Vec<MixedModelPoint>,
}

impl MixedServingBench {
    /// The printable summary the microbench and CI quote.
    pub fn render(&self) -> String {
        let mut out = format!(
            "mixed-serving[{} models, B={}, {}t]: {} submitted, {} hot swap(s), \
             plans built {} / warmed {} / replayed {}\n",
            self.models.len(),
            self.max_batch,
            self.threads,
            self.submitted,
            self.param_swaps,
            self.plans_built,
            self.plans_warmed,
            self.plan_replays,
        );
        for p in &self.per_model {
            out.push_str(&format!(
                "    model:{:<12} {} done, {} shed, p50 {:.1} / p99 {:.1} ms, \
                 occ {:.2}, param v{}\n",
                p.model, p.requests, p.shed, p.p50_ms, p.p99_ms, p.mean_occupancy, p.max_version,
            ));
        }
        out
    }

    /// Canonical JSON: per-model series named `model:<name>` — the CI
    /// smoke job greps these plus a nonzero `param_swaps` out of
    /// `BENCH_serving.json`.
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("models", arr(self.models.iter().map(|m| s(m)).collect())),
            ("max_batch", num(self.max_batch as f64)),
            ("threads", num(self.threads as f64)),
            ("submitted", num(self.submitted as f64)),
            ("param_swaps", num(self.param_swaps as f64)),
            ("plans_built", num(self.plans_built as f64)),
            ("plans_warmed", num(self.plans_warmed as f64)),
            ("plan_replays", num(self.plan_replays as f64)),
            (
                "series",
                arr(self
                    .per_model
                    .iter()
                    .map(|p| {
                        obj(vec![
                            ("name", s(&format!("model:{}", p.model))),
                            ("requests", num(p.requests as f64)),
                            ("shed", num(p.shed as f64)),
                            ("batches", num(p.batches as f64)),
                            ("p50_ms", num(p.p50_ms)),
                            ("p99_ms", num(p.p99_ms)),
                            ("mean_occupancy", num(p.mean_occupancy)),
                            ("max_version", num(p.max_version as f64)),
                        ])
                    })
                    .collect()),
            ),
        ])
    }
}

/// Mixed-model serving sweep (DESIGN.md §15): register two models,
/// warm a plan per tenant, export the per-model artifact
/// subdirectories (plus the registry manifest the GC reads), then
/// serve a round-robin trace against one server — hot-swapping the
/// first model's parameters mid-trace. Hard-fails unless every request
/// is answered, both models served, the swap landed, and steady state
/// compiled zero plans (the warm start covered every tenant).
pub fn run_mixed_serving_bench(threads: usize) -> anyhow::Result<MixedServingBench> {
    use std::path::PathBuf;
    use std::sync::Arc;
    use std::time::Duration;

    use crate::coordinator::dispatch::MultiDispatcher;
    use crate::coordinator::registry::ModelRegistry;
    use crate::coordinator::server::{DispatchMode, ServeBackend, Server, ServerConfig};
    use crate::coordinator::CloseRule;
    use crate::gcn::params::ParamSet;
    use crate::graph::dataset::pack_molecules;
    use crate::graph::molecule::{Molecule, MoleculeSpec};
    use crate::runtime::plan_artifact;
    use crate::util::rng::Rng;

    let quick = std::env::var("BENCH_QUICK").is_ok();
    let max_batch = if quick { 4 } else { 8 };
    let n = if quick { 32 } else { 96 };
    let threads = Executor::resolve_threads(threads);
    let models = ["tox21", "reaction100"];

    let mut reg = ModelRegistry::new();
    for m in models {
        reg.register_synthetic(m, 0x5EED)?;
    }
    let registry = Arc::new(reg);

    // Warm one full-capacity plan per tenant offline, export the
    // per-model artifact subdirectories and the registry manifest.
    // The server pads every device batch to `max_batch`, so this one
    // geometry per model is all steady state ever replays.
    let plans_root =
        std::env::temp_dir().join(format!("bspmm_mixed_serving_plans_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&plans_root);
    {
        let mut md = MultiDispatcher::new(registry.clone(), threads);
        let mut rng = Rng::new(0xCA11);
        let spec = MoleculeSpec::default();
        for m in models {
            let cfg = registry.cfg(m)?.clone();
            let mols: Vec<Molecule> =
                (0..max_batch).map(|_| Molecule::random(&mut rng, &spec)).collect();
            let refs: Vec<&Molecule> = mols.iter().collect();
            let mb = pack_molecules(&refs, max_batch, cfg.max_nodes, cfg.ell_width, cfg.n_out)?;
            md.forward(m, DispatchMode::Batched, &mb)?;
        }
        md.export_plans(&plans_root)?;
        let manifest: Vec<(String, u64)> = models
            .iter()
            .map(|m| Ok((m.to_string(), registry.current(m)?.version)))
            .collect::<anyhow::Result<_>>()?;
        plan_artifact::write_registry_manifest(&plans_root, &manifest)?;
    }

    let server = Server::start(ServerConfig {
        artifacts_dir: PathBuf::from("unused-for-host-backend"),
        model: models[0].into(),
        mode: DispatchMode::Batched,
        backend: ServeBackend::HostEngine { threads },
        max_batch,
        max_wait: Duration::from_millis(2),
        close: CloseRule::SizeOrAge,
        queue_bound: 0,
        deadline: None,
        params_path: None,
        registry: Some(registry.clone()),
        plans_dir: Some(plans_root.clone()),
    })?;

    // Round-robin the models through one server; swap the first
    // model's parameters at the half-way mark. `swap_params` returns
    // only after the new version is installed, so every later
    // submission must serve on v2.
    let mut rng = Rng::new(0x313E);
    let spec = MoleculeSpec::default();
    let mut rxs = Vec::with_capacity(n);
    let mut labels = Vec::with_capacity(n);
    for i in 0..n {
        if i == n / 2 {
            let cfg = registry.cfg(models[0])?;
            registry.swap_params(models[0], ParamSet::random_init(cfg, 0xBEEF))?;
        }
        let model = models[i % models.len()];
        labels.push(model);
        rxs.push(server.submit_to(model, Molecule::random(&mut rng, &spec)));
    }
    let snap = server.shutdown()?;
    let _ = std::fs::remove_dir_all(&plans_root);

    let mut max_version = vec![0u64; models.len()];
    let mut answered = 0usize;
    for (i, rx) in rxs.iter().enumerate() {
        let resp = rx
            .recv()
            .map_err(|_| anyhow::anyhow!("mixed serving bench lost a reply"))?;
        answered += 1;
        let mi = models.iter().position(|m| *m == labels[i]).unwrap();
        max_version[mi] = max_version[mi].max(resp.version);
    }
    anyhow::ensure!(answered == n, "mixed serving bench lost replies");
    anyhow::ensure!(
        snap.param_swaps >= 1,
        "hot swap not recorded: param_swaps = {}",
        snap.param_swaps
    );
    anyhow::ensure!(
        max_version[0] >= 2,
        "post-swap responses still on v{} — the swap never took effect",
        max_version[0]
    );
    anyhow::ensure!(
        snap.plans_built == 0,
        "steady state compiled {} plan(s) despite the warm start",
        snap.plans_built
    );
    anyhow::ensure!(
        snap.plans_warmed >= models.len() as u64,
        "warm start installed only {} plan(s) for {} tenants",
        snap.plans_warmed,
        models.len()
    );

    let per_model = models
        .iter()
        .enumerate()
        .map(|(mi, m)| {
            let pm = snap
                .model(m)
                .ok_or_else(|| anyhow::anyhow!("no per-model metrics for '{m}'"))?;
            anyhow::ensure!(pm.requests > 0, "model '{m}' served zero requests");
            Ok(MixedModelPoint {
                model: m.to_string(),
                requests: pm.requests,
                shed: pm.shed,
                batches: pm.batches,
                p50_ms: pm.p50_latency_us as f64 / 1e3,
                p99_ms: pm.p99_latency_us as f64 / 1e3,
                mean_occupancy: pm.mean_occupancy,
                max_version: max_version[mi],
            })
        })
        .collect::<anyhow::Result<Vec<_>>>()?;

    Ok(MixedServingBench {
        models: models.iter().map(|m| m.to_string()).collect(),
        max_batch,
        threads,
        submitted: n as u64,
        param_swaps: snap.param_swaps,
        plans_built: snap.plans_built,
        plans_warmed: snap.plans_warmed,
        plan_replays: snap.plan_replays,
        per_model,
    })
}

/// One host `train_step` timing comparison ([`run_train_step_bench`]):
/// mean seconds per step under each executor configuration, in
/// (serial, pool) order.
#[derive(Clone, Debug)]
pub struct TrainStepBench {
    pub model: String,
    pub batch: usize,
    /// `(label, mean seconds per step)` per configuration.
    pub points: Vec<(String, f64)>,
}

impl TrainStepBench {
    /// The printable summary line the microbench and CHANGES.md quote.
    pub fn render(&self) -> String {
        let (_, s) = &self.points[0];
        let mut out = format!(
            "train_step[{}, B={}]: serial {:.2} ms/step",
            self.model,
            self.batch,
            s * 1e3
        );
        for (label, p) in &self.points[1..] {
            out.push_str(&format!(" -> {label} {:.2} ms/step", p * 1e3));
        }
        let (_, last) = &self.points[self.points.len() - 1];
        out.push_str(&format!(": {:.2}x parallel speedup\n", s / last));
        out
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("model", s(&self.model)),
            ("batch", num(self.batch as f64)),
            (
                "points",
                arr(self
                    .points
                    .iter()
                    .map(|(label, secs)| {
                        obj(vec![("label", s(label)), ("secs_per_step", num(*secs))])
                    })
                    .collect()),
            ),
        ])
    }
}

/// Host-engine `train_step` microbench: each step is one full
/// fwd + engine-dispatch backward + SGD on `Trainer::new_host`
/// (DESIGN.md §8), timed on the serial executor vs a `threads`-wide
/// work-stealing pool (`0` = one per core) — every configuration runs
/// all of its steps on one persistent pool. No artifacts needed.
pub fn run_train_step_bench(
    model: &str,
    batch: usize,
    threads: usize,
    opts: &BenchOpts,
) -> anyhow::Result<TrainStepBench> {
    anyhow::ensure!(batch >= 1, "train_step bench needs batch >= 1");
    let kind = match model {
        "tox21" => DatasetKind::Tox21,
        "reaction100" => DatasetKind::Reaction100,
        other => anyhow::bail!("no dataset for model '{other}'"),
    };
    let data = Dataset::generate(kind, batch, 77);
    let idx: Vec<usize> = (0..batch).collect();
    let t = Executor::resolve_threads(threads);
    let configs = [("serial".to_string(), 1usize), (format!("{t}t"), t)];
    let mut points: Vec<(String, f64)> = Vec::new();
    for (label, t) in configs {
        let mut tr = Trainer::new_host(model, t)?;
        let mb = data.pack_batch(&idx, tr.cfg.max_nodes, tr.cfg.ell_width)?;
        // Small lr: the timing loop keeps stepping the same minibatch,
        // and the work per step must not drift with the parameters.
        let lr = 1e-3f32;
        let samples = timer::bench_adaptive(
            opts.warmup,
            opts.min_iters,
            opts.max_iters.max(1),
            opts.min_time_s,
            || {
                tr.step_batched(&mb, lr).expect("host train step");
            },
        );
        points.push((label, samples.iter().sum::<f64>() / samples.len() as f64));
    }
    Ok(TrainStepBench {
        model: model.to_string(),
        batch,
        points,
    })
}

pub struct FigureRunner<'a> {
    pub rt: &'a Runtime,
    pub cm: CostModel,
    pub opts: BenchOpts,
    /// Skip the (slow) measured non-batched series when false.
    pub with_non_batched: bool,
    /// Skip the GEMM series (Fig. 10 excludes cuBLAS: "the kernel only
    /// processes GEMM operations with same matrix sizes").
    pub with_gemm: bool,
}

impl<'a> FigureRunner<'a> {
    pub fn new(rt: &'a Runtime) -> Self {
        Self {
            rt,
            cm: CostModel::default(),
            opts: BenchOpts::from_env(),
            with_non_batched: true,
            with_gemm: true,
        }
    }

    fn mean_secs(&self, mut f: impl FnMut()) -> f64 {
        // Budget guard: if a single execution already blows the
        // per-point budget (heavy scatter points on the old XLA CPU
        // runtime), that one timed run IS the measurement.
        let budget = std::env::var("BENCH_POINT_BUDGET_S")
            .ok()
            .and_then(|v| v.parse::<f64>().ok())
            .unwrap_or(8.0);
        let (first, _) = timer::time_once(&mut f);
        if first > budget {
            return first;
        }
        let samples = timer::bench_adaptive(
            self.opts.warmup.saturating_sub(1),
            self.opts.min_iters,
            self.opts.max_iters,
            self.opts.min_time_s,
            &mut f,
        );
        samples.iter().sum::<f64>() / samples.len() as f64
    }

    /// Measured series for one sweep; returns a FigureResult keyed
    /// `<key>_measured`.
    pub fn run_measured(&self, sw: &SweepSpec) -> anyhow::Result<FigureResult> {
        let mut series: Vec<Series> = APPROACHES
            .iter()
            .map(|n| Series {
                name: n.to_string(),
                values: Vec::new(),
            })
            .collect();
        for &nb in &sw.nbs {
            let w = SpmmWorkload::build(sw, nb)?;

            // Non-batched: one PJRT execute per matrix (launch-overhead
            // bound, exactly the paper's baseline structure).
            if self.with_non_batched {
                let st1 = self.rt.executable(&sw.st_single(nb))?;
                let t = self.mean_secs(|| {
                    for b in 0..w.batch {
                        st1.execute(&w.st_single_inputs(b)).expect("st single");
                    }
                });
                series[0].values.push(w.gflops(t));
                let csr1 = self.rt.executable(&sw.csr_single(nb))?;
                let t = self.mean_secs(|| {
                    for b in 0..w.batch {
                        csr1.execute(&w.csr_single_inputs(b)).expect("csr single");
                    }
                });
                series[1].values.push(w.gflops(t));
            } else {
                series[0].values.push(f64::NAN);
                series[1].values.push(f64::NAN);
            }

            // Batched: single execute for the whole batch.
            let st = self.rt.executable(&sw.st_batched(nb))?;
            let inputs = w.st_batched_inputs();
            let t = self.mean_secs(|| {
                st.execute(&inputs).expect("st batched");
            });
            series[2].values.push(w.gflops(t));

            let csr = self.rt.executable(&sw.csr_batched(nb))?;
            let inputs = w.csr_batched_inputs();
            let t = self.mean_secs(|| {
                csr.execute(&inputs).expect("csr batched");
            });
            series[3].values.push(w.gflops(t));

            if self.with_gemm {
                let gemm = self.rt.executable(&sw.gemm_batched(nb))?;
                let inputs = w.gemm_inputs();
                let t = self.mean_secs(|| {
                    gemm.execute(&inputs).expect("gemm batched");
                });
                series[4].values.push(w.gflops(t));
            } else {
                series[4].values.push(f64::NAN);
            }
        }
        Ok(FigureResult {
            key: format!("{}_measured", sw.key),
            title: format!(
                "SpMM throughput, measured CPU-PJRT (dim={}, nnz/row={}, batch={}{})",
                sw.dim,
                sw.z,
                sw.batch,
                if sw.mixed { ", mixed" } else { "" }
            ),
            x_label: "n_B".into(),
            xs: sw.nbs.iter().map(|&n| n as f64).collect(),
            y_label: "GFLOPS (2*nnz*n_B/t)".into(),
            series,
        })
    }

    /// Simulated-P100 series for the same sweep (`<key>_sim_p100`).
    pub fn run_simulated(&self, sw: &SweepSpec) -> anyhow::Result<FigureResult> {
        run_simulated_sweep(&self.cm, sw, self.with_gemm)
    }
}

/// Simulated-P100 series for a sweep — needs only the cost model, so it
/// runs without artifacts or a runtime.
pub fn run_simulated_sweep(
    cm: &CostModel,
    sw: &SweepSpec,
    with_gemm: bool,
) -> anyhow::Result<FigureResult> {
    let mut series: Vec<Series> = APPROACHES
        .iter()
        .map(|n| Series {
            name: n.to_string(),
            values: Vec::new(),
        })
        .collect();
    for &nb in &sw.nbs {
        let w = SpmmWorkload::build(sw, nb)?;
        let gf = |total_us: f64| 2.0 * w.real_nnz as f64 * nb as f64 / (total_us * 1e3);
        // Non-batched: per-matrix ops at each matrix's true size
        // (for mixed batches the per-matrix dims differ).
        let tf_us: f64 = w
            .mats
            .iter()
            .map(|m| {
                cm.tf_spmm_op(m.rows, (m.nnz() / m.rows.max(1)).max(1), nb)
                    .total_us()
            })
            .sum();
        series[0].values.push(gf(tf_us));
        let cu_us: f64 = w
            .mats
            .iter()
            .map(|m| {
                cm.cusparse_op(m.rows, (m.nnz() / m.rows.max(1)).max(1), nb)
                    .total_us()
            })
            .sum();
        series[1].values.push(gf(cu_us));
        // Batched: the padded bucket geometry (what the kernel sees).
        series[2]
            .values
            .push(gf(cm.batched_spmm_st(w.batch, w.dim, w.z, nb).total_us()));
        series[3]
            .values
            .push(gf(cm.batched_spmm_csr(w.batch, w.dim, w.z, nb).total_us()));
        if with_gemm {
            series[4]
                .values
                .push(gf(cm.batched_gemm(w.batch, w.dim, nb).total_us()));
        } else {
            series[4].values.push(f64::NAN);
        }
    }
    Ok(FigureResult {
        key: format!("{}_sim_p100", sw.key),
        title: format!(
            "SpMM throughput, simulated P100 (dim={}, nnz/row={}, batch={}{})",
            sw.dim,
            sw.z,
            sw.batch,
            if sw.mixed { ", mixed" } else { "" }
        ),
        x_label: "n_B".into(),
        xs: sw.nbs.iter().map(|&n| n as f64).collect(),
        y_label: "GFLOPS (2*nnz*n_B/t)".into(),
        series,
    })
}

/// Shared driver for the fig8/fig9/fig10 bench binaries: run the engine
/// series (always), plus measured CPU-PJRT series when artifacts exist,
/// plus the simulated-P100 series; print and save JSON results. Without
/// artifacts the sweep geometry comes from `SweepSpec::builtin`.
pub fn run_figure_bench(keys: &[&str], with_gemm: bool) -> anyhow::Result<()> {
    let opts = BenchOpts::from_env();
    let rt = match Runtime::new_default() {
        Ok(rt) => Some(rt),
        Err(e) => {
            // Don't conflate "not built" with a broken manifest — print
            // the real reason the measured series is being skipped.
            println!("(PJRT runtime unavailable — engine + simulated series only: {e:#})\n");
            None
        }
    };
    for key in keys {
        let sw = match &rt {
            Some(rt) => rt.manifest.sweep(key)?,
            None => SweepSpec::builtin(key)?,
        };

        // Engine series: every backend, serial vs parallel executor.
        let engine = run_engine_bench(&sw, 0, &opts)?;
        println!("{}", engine.render());
        let path = engine.save()?;
        println!("  -> {}\n", path.display());
        print!("{}", engine_speedup_summary(&engine));
        print!("{}", auto_vs_fixed_summary(&engine));
        println!();

        if let Some(rt) = &rt {
            let mut runner = FigureRunner::new(rt);
            runner.with_gemm = with_gemm;
            let measured = runner.run_measured(&sw)?;
            println!("{}", measured.render());
            let path = measured.save()?;
            println!("  -> {}\n", path.display());
            let sim = runner.run_simulated(&sw)?;
            println!("{}", sim.render());
            let path = sim.save()?;
            println!("  -> {}\n", path.display());
            // Headline ratio: best batched vs best non-batched, measured.
            let best_batched = |f: &FigureResult| -> f64 {
                f.series[2..]
                    .iter()
                    .flat_map(|s| s.values.iter())
                    .cloned()
                    .filter(|v| v.is_finite())
                    .fold(f64::MIN, f64::max)
            };
            let best_nonbatched = |f: &FigureResult| -> f64 {
                f.series[..2]
                    .iter()
                    .flat_map(|s| s.values.iter())
                    .cloned()
                    .filter(|v| v.is_finite())
                    .fold(f64::MIN, f64::max)
            };
            let (bb, bn) = (best_batched(&measured), best_nonbatched(&measured));
            if bb > 0.0 && bn > 0.0 {
                println!(
                    "  {key}: measured peak batched/non-batched speedup = {:.2}x\n",
                    bb / bn
                );
            }
        } else {
            let sim = run_simulated_sweep(&CostModel::default(), &sw, with_gemm)?;
            println!("{}", sim.render());
            let path = sim.save()?;
            println!("  -> {}\n", path.display());
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifact::SweepSpec;

    #[test]
    fn simulated_sweep_runs_without_runtime_artifacts() {
        // run_simulated only needs workloads + the cost model; build a
        // fake runner around a sweep to exercise it would need a
        // Runtime, so we test the underlying pieces directly.
        let sw = SweepSpec {
            key: "x".into(),
            dim: 32,
            z: 2,
            batch: 10,
            nbs: vec![16, 32],
            mixed: false,
        };
        let w = SpmmWorkload::build(&sw, 16).unwrap();
        let cm = CostModel::default();
        let t = cm.batched_spmm_st(w.batch, w.dim, w.z, 16).total_us();
        assert!(t > 0.0);
        let f = run_simulated_sweep(&cm, &sw, true).unwrap();
        assert_eq!(f.series.len(), 5);
        assert!(f.series[2].values.iter().all(|v| *v > 0.0));
    }

    #[test]
    fn train_step_bench_runs_without_artifacts() {
        let opts = BenchOpts {
            warmup: 0,
            min_iters: 1,
            max_iters: 1,
            min_time_s: 0.0,
        };
        let bench = run_train_step_bench("tox21", 4, 2, &opts).unwrap();
        let line = bench.render();
        assert!(line.contains("train_step[tox21, B=4]"), "{line}");
        assert!(line.contains("speedup"), "{line}");
        assert_eq!(bench.points.len(), 2);
        assert!(bench.points.iter().all(|(_, secs)| *secs > 0.0));
        assert!(bench.to_json().to_string().contains("secs_per_step"));
        assert!(run_train_step_bench("nope", 4, 2, &opts).is_err());
    }

    #[test]
    fn engine_bench_runs_without_artifacts() {
        let mut sw = SweepSpec::builtin("fig8a").unwrap();
        // Keep the test fast: one tiny point, one iteration.
        sw.batch = 8;
        sw.nbs = vec![8];
        let opts = BenchOpts {
            warmup: 0,
            min_iters: 1,
            max_iters: 1,
            min_time_s: 0.0,
        };
        let f = run_engine_bench(&sw, 2, &opts).unwrap();
        assert_eq!(f.series.len(), ENGINE_SERIES.len() * 5);
        assert!(f
            .series
            .iter()
            .all(|s| s.values.len() == 1 && s.values[0] > 0.0));
        // Every backend carries its scalar-baseline and explicit-SIMD
        // series.
        assert_eq!(
            f.series.iter().filter(|s| s.name.ends_with("(scalar)")).count(),
            ENGINE_SERIES.len()
        );
        assert_eq!(
            f.series.iter().filter(|s| s.name.ends_with("(simd-2t)")).count(),
            ENGINE_SERIES.len()
        );
        // The auto series resolved and ran.
        assert_eq!(
            f.series
                .iter()
                .filter(|s| s.name.starts_with("Engine-AUTO"))
                .count(),
            5
        );
        let summary = engine_speedup_summary(&f);
        assert!(!summary.is_empty());
        assert!(summary.contains("vector speedup"), "{summary}");
        assert!(summary.contains("static-2t") && summary.contains("steal-2t"));
        assert!(summary.contains("simd-vs-steal"), "{summary}");
        let auto = auto_vs_fixed_summary(&f);
        assert!(auto.contains("best fixed"), "{auto}");
        // Auto resolves to a concrete backend at every point.
        let choices = auto_choices(&sw).unwrap();
        assert_eq!(choices.len(), 1);
        assert_ne!(choices[0].1, Backend::Auto);
        // A restricted backend list restricts the series.
        let only = run_engine_bench_backends(&sw, 1, &opts, &[Backend::Ell]).unwrap();
        assert_eq!(only.series.len(), 5);
        assert!(only.series.iter().all(|s| s.name.starts_with("Engine-ELL")));
    }

    #[test]
    fn precision_bench_runs_and_reports_speedup_vs_f32() {
        let mut sw = SweepSpec::builtin("fig8a").unwrap();
        sw.batch = 8;
        sw.nbs = vec![8];
        let opts = BenchOpts {
            warmup: 0,
            min_iters: 1,
            max_iters: 1,
            min_time_s: 0.0,
        };
        let f = run_precision_bench(&sw, 2, &opts).unwrap();
        // (GFLOPS, MB/dispatch) pairs for f32, bf16, int8 — the CI
        // smoke job greps the recorded JSON for these names.
        assert_eq!(f.series.len(), 6);
        for needle in ["[f32]", "[bf16]", "[int8]"] {
            assert!(
                f.series.iter().any(|s| s.name.contains(needle)),
                "missing series {needle}"
            );
        }
        assert!(f
            .series
            .iter()
            .all(|s| s.values.len() == 1 && s.values[0] > 0.0));
        // Bytes moved per dispatch strictly shrink with the value
        // dtype: f32 (4B) > bf16 (2B) > int8 (1B).
        let mb = |i: usize| f.series[i * 2 + 1].values[0];
        assert!(
            mb(0) > mb(1) && mb(1) > mb(2),
            "bytes/dispatch not ordered: {} {} {}",
            mb(0),
            mb(1),
            mb(2)
        );
        let summary = precision_speedup_summary(&f);
        assert!(summary.contains("speedup vs f32"), "{summary}");
        assert!(summary.contains("MB/dispatch"), "{summary}");
    }

    #[test]
    fn large_graph_bench_runs_and_carries_tiled_series() {
        let opts = BenchOpts {
            warmup: 0,
            min_iters: 1,
            max_iters: 1,
            min_time_s: 0.0,
        };
        let f = run_large_graph_bench(&[500, 1_000], 3, 8, 2, &opts).unwrap();
        assert_eq!(f.key, "large_engine");
        assert_eq!(f.xs, vec![500.0, 1000.0]);
        assert_eq!(f.series.len(), 4);
        assert!(f
            .series
            .iter()
            .all(|s| s.values.len() == 2 && s.values.iter().all(|v| *v > 0.0)));
        // Both kernel variants and both policies appear by name — the
        // CI smoke job greps the recorded JSON for these.
        for needle in ["(untiled,static", "(untiled,steal", "(tiled,static", "(tiled,steal"] {
            assert!(
                f.series.iter().any(|s| s.name.contains(needle)),
                "missing series {needle}"
            );
        }
        assert!(run_large_graph_bench(&[], 3, 8, 1, &opts).is_err());
    }

    #[test]
    fn plan_bench_runs_without_artifacts() {
        let opts = BenchOpts {
            warmup: 0,
            min_iters: 1,
            max_iters: 1,
            min_time_s: 0.0,
        };
        let bench = run_plan_bench("tox21", 4, 1, &opts).unwrap();
        let line = bench.render();
        assert!(line.contains("plan_reuse[tox21, B=4]"), "{line}");
        assert!(line.contains("plan-reuse speedup"), "{line}");
        assert!(bench.cold_secs > 0.0 && bench.cached_secs > 0.0);
        // The cached phase really replayed a cached plan — and built
        // nothing (its counters are deltas over that phase alone).
        assert!(bench.stats.replays > 0, "{:?}", bench.stats);
        assert_eq!(bench.stats.plans_built, 0, "{:?}", bench.stats);
        assert!(bench.to_json().to_string().contains("cached-plan"));
        assert!(run_plan_bench("nope", 4, 1, &opts).is_err());
    }

    #[test]
    fn serving_bench_json_carries_the_ci_contract() {
        // The CI smoke job greps BENCH_serving.json for both policy
        // names and for the absence of zero request counts — pin the
        // canonical-JSON spellings here so a writer change can't
        // silently break the workflow assertions.
        let point = ServingPoint {
            offered_rps: 100.0,
            submitted: 24,
            requests: 24,
            shed: 0,
            throughput_rps: 98.5,
            p50_ms: 2.0,
            p99_ms: 8.2,
            p999_ms: 16.4,
            mean_batch_size: 3.0,
            mean_occupancy: 0.375,
            queue_depth_hwm: 5,
            batch_size_counts: vec![(1, 2), (3, 4)],
        };
        let bench = ServingBench {
            model: "tox21".into(),
            max_batch: 8,
            threads: 2,
            capacity_rps: 800.0,
            age_cap: std::time::Duration::from_millis(2),
            queue_bound: 16,
            series: vec![
                ServingSeries {
                    name: "fixed-size".into(),
                    points: vec![ServingPoint {
                        p99_ms: 64.0,
                        ..point.clone()
                    }],
                },
                ServingSeries {
                    name: "size-or-age".into(),
                    points: vec![point],
                },
            ],
        };
        let json = bench.to_json().to_string();
        assert!(json.contains("\"name\":\"fixed-size\""), "{json}");
        assert!(json.contains("\"name\":\"size-or-age\""), "{json}");
        assert!(json.contains("\"requests\":24"), "{json}");
        assert!(!json.contains("\"requests\":0,"), "{json}");
        assert!(json.contains("\"queue_depth_hwm\":5"), "{json}");
        let line = bench.render();
        assert!(line.contains("serving[tox21, B=8, 2t]"), "{line}");
        let headline = bench.headline().unwrap();
        assert!(headline.contains("7.8x lower"), "{headline}");
    }

    #[test]
    fn aot_warmstart_bench_holds_the_cold_start_contract() {
        let opts = BenchOpts {
            warmup: 0,
            min_iters: 1,
            max_iters: 1,
            min_time_s: 0.0,
        };
        let bench = run_aot_warmstart_bench("tox21", 4, 1, &opts).unwrap();
        assert_eq!(bench.plans_built, 0, "warm trainer compiled a plan");
        assert!(bench.plans_warmed >= 1);
        assert!(bench.bit_identical, "warm replay diverged from cold run");
        assert!(bench.cold_first_secs > 0.0 && bench.steady_secs > 0.0);
        let line = bench.render();
        assert!(line.contains("aot_warmstart[tox21, B=4]"), "{line}");
        assert!(line.contains("bit-identical"), "{line}");
        let json = bench.to_json().to_string();
        assert!(json.contains("warm-first-step") && json.contains("plans_warmed"));
        assert!(run_aot_warmstart_bench("nope", 4, 1, &opts).is_err());
    }
}
