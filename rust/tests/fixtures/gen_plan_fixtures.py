#!/usr/bin/env python3
"""Regenerate the golden plan-artifact fixtures in this directory.

Mirrors the canonical JSON writer (`util::json::Json::to_string`: BTreeMap
key order, no whitespace, integral floats rendered as integers) and the
FNV-1a 64 content hash of `runtime::plan_artifact`. The fixture bytes are
asserted byte-identical to `plan_artifact::encode(...)` of freshly
compiled plans in `tests/plan_artifact_golden.rs` — if that suite fails
after an intentional format change, bump `FORMAT_VERSION` there and in
`plan_artifact.rs` together, then rerun this script.

Plan shapes below are transcriptions of the compilers they pin:
`reference::plan_forward` / `backward::plan_train` for the tox21 B=4
geometry (slots, params and dispatches in construction order), and the
hand-built single-backend engine plans from the golden suite.
"""

import os

FORMAT_VERSION = 2  # v2: per-dispatch "dtype" + dtype tag in geometry keys
KIND = "bspmm_step_plan"
# AutoThresholds::default(), baked into every fixture.
THRESHOLDS = {"ell_waste": 3.0, "gemm_density": 0.25}

OUT_DIR = os.path.dirname(os.path.abspath(__file__))


def fnv1a64(data: bytes) -> int:
    h = 0xCBF29CE484222325
    for b in data:
        h ^= b
        h = (h * 0x00000100000001B3) & 0xFFFFFFFFFFFFFFFF
    return h


def canon(v) -> str:
    """Canonical encoding, byte-for-byte `Json::to_string`."""
    if v is None:
        return "null"
    if isinstance(v, bool):
        return "true" if v else "false"
    if isinstance(v, (int, float)):
        f = float(v)
        if f == int(f) and abs(f) < 1e15:
            return str(int(f))
        r = repr(f)
        assert r == "0.25", f"float {f}: verify repr matches Rust's writer"
        return r
    if isinstance(v, str):
        assert all(c not in '"\\' and ord(c) >= 0x20 for c in v), v
        return '"' + v + '"'
    if isinstance(v, list):
        return "[" + ",".join(canon(x) for x in v) + "]"
    if isinstance(v, dict):
        return "{" + ",".join(canon(k) + ":" + canon(v[k]) for k in sorted(v)) + "}"
    raise TypeError(type(v))


def dispatch(backend, transpose, rhs, n, out, dtype="f32"):
    return {
        "backend": backend,
        "dtype": dtype,
        "n": n,
        "out": out,
        "rhs": rhs,
        "transpose": transpose,
    }


def artifact(key, slots, dispatches, params):
    body = {
        "dispatches": dispatches,
        "format_version": FORMAT_VERSION,
        "key": key,
        "kind": KIND,
        "params": [{"len": ln, "offset": off} for (off, ln) in params],
        "slots": slots,
        "thresholds": THRESHOLDS,
    }
    body["content_hash"] = "%016x" % fnv1a64(canon(body).encode())
    return canon(body) + "\n"


# --- tox21 B=4: hidden=[64,64], feat=16, ch=4, m=50, n_out=12 --------------

B, M, FEAT, CH, ELLW, NOUT = 4, 50, 16, 4, 12, 12
HIDDEN = [64, 64]
KEY_TAIL = [B, M, FEAT, CH, ELLW, NOUT] + HIDDEN
# ModelConfig::synthetic("tox21") parameter table: (offset, len) in
# plan_forward_into's push order, readout.w appended by plan_train.
FWD_PARAMS = [
    (0, 4096), (4096, 256), (4352, 64), (4416, 64),          # conv0 w,b,gamma,beta
    (4480, 16384), (20864, 256), (21120, 64), (21184, 64),   # conv1
    (22016, 12),                                             # readout.b
]
READOUT_W = (21248, 768)

# Forward slots: U scratch, one activation per layer, logits.
FWD_SLOTS = [B * M * 64, B * M * 64, B * M * 64, B * NOUT]
# Forward dispatches: per (layer, channel) the XW GEMM into U then the
# adjacency ELL SpMM into act[layer]; readout GEMM last.
FWD_DISPATCHES = []
for li in range(len(HIDDEN)):
    for _ch in range(CH):
        FWD_DISPATCHES.append(dispatch("gemm", False, "shared", 64, 0))
        FWD_DISPATCHES.append(dispatch("ell", False, "per_sample", 64, 1 + li))
FWD_DISPATCHES.append(dispatch("gemm", False, "shared", NOUT, 3))

# Train plan: forward + backward slots (ypre x2, dlogits, pooled, drow,
# dh, dx, du, dypre, wt, hn, dhat) and the 22 backward dispatches in
# backward::plan_train's issue order. Slot ids: du=11, dx=10, drow=8.
TRAIN_SLOTS = FWD_SLOTS + [
    B * M * 64, B * M * 64,        # ypre per layer
    B * NOUT, B * 64, B * 64,      # dlogits, pooled, drow
    B * M * 64, B * M * 64, B * M * 64, B * M * 64,  # dh, dx, du, dypre
    64 * 64, M, M,                 # wt (widest weight), hn, dhat
]
TRAIN_DISPATCHES = list(FWD_DISPATCHES)
TRAIN_DISPATCHES.append(dispatch("gemm", True, "shared", NOUT, None))  # dW_out
TRAIN_DISPATCHES.append(dispatch("gemm", False, "shared_transposed", 64, 8))
for li in (1, 0):
    for _ch in range(CH):
        TRAIN_DISPATCHES.append(dispatch("ell", True, "per_sample", 64, 11))
        TRAIN_DISPATCHES.append(dispatch("gemm", True, "shared", 64, None))
        if li > 0:
            TRAIN_DISPATCHES.append(dispatch("gemm", False, "shared_transposed", 64, 10))

# geometry_key layout since format v2: [mode, dtype_tag, ...shape]; the
# f32 plans these fixtures pin carry dtype tag 0.
FIXTURES = {
    "tox21_fwd_b4.plan.json": artifact([1, 0] + KEY_TAIL, FWD_SLOTS, FWD_DISPATCHES, FWD_PARAMS),
    "tox21_train_b4.plan.json": artifact(
        [2, 0] + KEY_TAIL, TRAIN_SLOTS, TRAIN_DISPATCHES, FWD_PARAMS + [READOUT_W]
    ),
}

# --- engine-level single-backend plans (batch=2, dim=8, nb=4) --------------
# One forward + one transpose dispatch into slot 0; key tag 100+idx keeps
# these clear of real geometry keys. engine_auto freezes what
# choose_backend resolves for the golden suite's pinned dense (gemm) and
# sparse row-regular (ell) profiles.

EB, EDIM, ENB = 2, 8, 4
for idx, bk in enumerate(["st", "csr", "ell", "gemm"]):
    FIXTURES[f"engine_{bk}.plan.json"] = artifact(
        [100 + idx, EB, EDIM, EDIM, ENB],
        [EB * EDIM * ENB],
        [
            dispatch(bk, False, "per_sample", ENB, 0),
            dispatch(bk, True, "per_sample", ENB, 0),
        ],
        [],
    )
FIXTURES["engine_auto.plan.json"] = artifact(
    [104, EB, EDIM, EDIM, ENB],
    [EB * EDIM * ENB],
    [
        dispatch("gemm", False, "per_sample", ENB, 0),
        dispatch("ell", False, "per_sample", ENB, 0),
    ],
    [],
)

if __name__ == "__main__":
    for name, text in FIXTURES.items():
        path = os.path.join(OUT_DIR, name)
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {name} ({len(text)} bytes, hash {text.split('content_hash')[1][3:19]})")
