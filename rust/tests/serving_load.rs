//! The serving tier under load (DESIGN.md §14): bounded admission,
//! deadline shedding, the size-or-age vs fixed-size close rules, and
//! the determinism-under-load contract — all on the host-engine
//! backend, no AOT artifacts required.

use std::path::PathBuf;
use std::time::Duration;

use bspmm::coordinator::server::{DispatchMode, ServeBackend, Server, ServerConfig};
use bspmm::coordinator::CloseRule;
use bspmm::graph::dataset::{Dataset, DatasetKind};

fn server(
    close: CloseRule,
    max_batch: usize,
    wait_ms: u64,
    queue_bound: usize,
    deadline_ms: Option<u64>,
) -> Server {
    Server::start(ServerConfig {
        artifacts_dir: PathBuf::from("unused-for-host-backend"),
        model: "tox21".into(),
        mode: DispatchMode::Batched,
        backend: ServeBackend::HostEngine { threads: 2 },
        max_batch,
        max_wait: Duration::from_millis(wait_ms),
        close,
        queue_bound,
        deadline: deadline_ms.map(Duration::from_millis),
        params_path: None,
        registry: None,
        plans_dir: None,
    })
    .expect("host server start")
}

/// The saturation acceptance pin: when offered load exceeds capacity,
/// the bounded queue sheds instead of growing without bound — the
/// depth high-water mark never exceeds the bound, every submit is
/// answered exactly once, and shed requests never execute.
#[test]
fn saturating_burst_sheds_at_the_bound_and_never_exceeds_it() {
    const BOUND: usize = 8;
    let srv = server(CloseRule::SizeOrAge, 4, 5, BOUND, None);
    let data = Dataset::generate(DatasetKind::Tox21, 64, 31);
    // Submit the whole burst with zero pacing: far faster than the
    // device can serve, so admission must hit the bound.
    let rxs: Vec<_> = data
        .samples
        .iter()
        .map(|s| srv.submit(s.mol.clone()))
        .collect();
    let mut served = 0u64;
    let mut shed = 0u64;
    for rx in rxs {
        let resp = rx.recv_timeout(Duration::from_secs(120)).expect("reply");
        if resp.shed {
            // A shed request carries no logits and never executed.
            assert!(resp.logits.is_empty(), "shed reply has logits");
            assert_eq!(resp.batch_size, 0);
            shed += 1;
        } else {
            assert_eq!(resp.logits.len(), 12);
            assert!(resp.logits.iter().all(|v| v.is_finite()));
            served += 1;
        }
    }
    let m = srv.shutdown().unwrap();
    assert!(m.shed > 0, "a 64-request burst into a bound of 8 must shed");
    assert!(
        m.queue_depth_hwm <= BOUND as u64,
        "queue depth {} exceeded the bound {BOUND}",
        m.queue_depth_hwm
    );
    assert_eq!(m.shed, shed);
    assert_eq!(m.requests, served);
    assert_eq!(m.requests + m.shed, 64, "a submit went unanswered");
}

/// Age-based close fires before size-based close under slow arrivals:
/// a batch far below capacity is answered after the age cap, without
/// needing a shutdown drain.
#[test]
fn age_close_answers_partial_batch_without_shutdown() {
    let srv = server(CloseRule::SizeOrAge, 50, 10, 0, None);
    let data = Dataset::generate(DatasetKind::Tox21, 3, 33);
    let rxs: Vec<_> = data
        .samples
        .iter()
        .map(|s| srv.submit(s.mol.clone()))
        .collect();
    for rx in rxs {
        let resp = rx.recv_timeout(Duration::from_secs(30)).expect("age close");
        assert!(!resp.shed);
        assert!(resp.batch_size >= 1 && resp.batch_size <= 3);
        assert_eq!(resp.logits.len(), 12);
    }
    let m = srv.shutdown().unwrap();
    assert_eq!(m.requests, 3);
    assert!(m.batches >= 1);
}

/// The fixed-size baseline really is size-only: a partial batch sits
/// unanswered past many age caps' worth of waiting, and only closes
/// when the size trigger fires.
#[test]
fn fixed_size_holds_partial_batch_until_full() {
    let srv = server(CloseRule::FixedSize, 4, 1, 0, None);
    let data = Dataset::generate(DatasetKind::Tox21, 4, 35);
    let first: Vec<_> = data.samples[..2]
        .iter()
        .map(|s| srv.submit(s.mol.clone()))
        .collect();
    // No age trigger: 200ms (200x the configured max_wait, which
    // FixedSize ignores) passes without a reply.
    assert!(
        first[0].recv_timeout(Duration::from_millis(200)).is_err(),
        "fixed-size closed a partial batch on age"
    );
    // Filling the batch closes it.
    let rest: Vec<_> = data.samples[2..]
        .iter()
        .map(|s| srv.submit(s.mol.clone()))
        .collect();
    for rx in first.iter().chain(rest.iter()) {
        let resp = rx.recv_timeout(Duration::from_secs(120)).expect("size close");
        assert!(!resp.shed);
        assert_eq!(resp.batch_size, 4, "batch closed below capacity");
    }
    let m = srv.shutdown().unwrap();
    assert_eq!(m.requests, 4);
    assert_eq!(m.batch_size_counts, vec![(4, 1)]);
}

/// Deadline shedding: requests older than the deadline when their
/// batch is assembled are answered shed=true and never reach the
/// engine (requests == 0, batches == 0), and the queue accounting
/// returns to zero.
#[test]
fn stale_requests_are_deadline_shed_not_executed() {
    // Age cap 30ms >> deadline 5ms: by the time the age close fires,
    // every queued request is past its deadline — all must shed.
    let srv = server(CloseRule::SizeOrAge, 8, 30, 0, Some(5));
    let data = Dataset::generate(DatasetKind::Tox21, 3, 37);
    let rxs: Vec<_> = data
        .samples
        .iter()
        .map(|s| srv.submit(s.mol.clone()))
        .collect();
    for rx in rxs {
        let resp = rx.recv_timeout(Duration::from_secs(30)).expect("shed reply");
        assert!(resp.shed, "stale request was executed");
        assert!(resp.logits.is_empty());
        assert!(resp.latency_us > 5_000, "shed before the deadline elapsed");
    }
    assert_eq!(srv.queue_depth(), 0, "shed requests left queue slots leaked");
    let m = srv.shutdown().unwrap();
    assert_eq!(m.shed, 3);
    assert_eq!(m.requests, 0, "a shed request entered the latency histogram");
    assert_eq!(m.batches, 0, "a shed request reached the engine");
}

/// Determinism under load (DESIGN.md §14): for requests that complete,
/// logits are bit-identical across close policies — batch composition
/// is a latency decision, not a numerics decision. Same capacity both
/// sides; the adaptive server is paced so its batches close small.
#[test]
fn completed_results_are_bit_identical_across_close_policies() {
    let data = Dataset::generate(DatasetKind::Tox21, 12, 39);

    let fixed = server(CloseRule::FixedSize, 4, 1, 0, None);
    let fixed_rxs: Vec<_> = data
        .samples
        .iter()
        .map(|s| fixed.submit(s.mol.clone()))
        .collect();
    let mf = fixed.shutdown().unwrap();
    let fixed_logits: Vec<Vec<f32>> = fixed_rxs
        .into_iter()
        .map(|rx| {
            let r = rx.recv().expect("fixed reply");
            assert!(!r.shed);
            r.logits
        })
        .collect();

    let adaptive = server(CloseRule::SizeOrAge, 4, 1, 0, None);
    // Force a composition difference deterministically: the first
    // request is answered alone (its age close fires while nothing
    // else is queued), so the adaptive side serves a batch of 1 that
    // the fixed-size side never forms.
    let rx0 = adaptive.submit(data.samples[0].mol.clone());
    let r0 = rx0.recv_timeout(Duration::from_secs(30)).expect("age close");
    assert!(!r0.shed);
    assert_eq!(r0.batch_size, 1);
    let adaptive_rxs: Vec<_> = data.samples[1..]
        .iter()
        .map(|s| adaptive.submit(s.mol.clone()))
        .collect();
    let ma = adaptive.shutdown().unwrap();
    let mut adaptive_logits = vec![r0.logits];
    adaptive_logits.extend(adaptive_rxs.into_iter().map(|rx| {
        let r = rx.recv().expect("adaptive reply");
        assert!(!r.shed);
        r.logits
    }));

    assert_eq!(mf.requests, 12);
    assert_eq!(ma.requests, 12);
    // The compositions really differed (the adaptive side needs at
    // least one extra, smaller batch) yet every request's logits are
    // exactly equal.
    assert!(
        ma.batches > mf.batches,
        "adaptive {} batches vs fixed {} — composition never differed",
        ma.batches,
        mf.batches
    );
    assert_eq!(fixed_logits, adaptive_logits);
}
