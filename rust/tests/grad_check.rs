//! Gradient checking for the engine-dispatch backward pass
//! (`gcn::backward`, DESIGN.md §8): every parameter tensor against
//! central finite differences on a tiny mixed batch, plus
//! batched-vs-per-sample gradient decomposability and a loss-goes-down
//! smoke test for the artifact-less host trainer.
//!
//! The differences are computed on an independent f64 mirror of the
//! forward + BCE loss (straight loops, no engine): differencing the
//! f32 forward itself bottoms out at ~3e-4 relative noise, an order of
//! magnitude above the 1e-4 gate this test enforces. The mirror is
//! pinned against the real f32 forward first, so it is checked to be
//! the same function.

use bspmm::coordinator::trainer::Trainer;
use bspmm::gcn::backward;
use bspmm::gcn::reference;
use bspmm::gcn::{ModelConfig, ParamSet};
use bspmm::graph::dataset::{Dataset, DatasetKind, ModelBatch};
use bspmm::sparse::engine::Executor;
use bspmm::util::json::parse;
use bspmm::util::rng::Rng;

/// Small two-conv-layer geometry. Feature width (16) and channel count
/// (4) are fixed by the featurizer/molecule substrate; the hidden and
/// readout widths are shrunk so the finite-difference sweep over every
/// parameter stays fast.
fn tiny_cfg() -> ModelConfig {
    let j = parse(
        r#"{
 "name": "grad-tiny", "max_nodes": 50, "feat_dim": 16, "channels": 4,
 "hidden": [3, 3], "n_out": 12, "loss": "bce", "nnz_cap": 128,
 "ell_width": 12, "train_batch": 3, "infer_batch": 3, "n_params": 312,
 "params": [
  {"name": "conv0.w", "shape": [4, 16, 3], "offset": 0, "size": 192},
  {"name": "conv0.b", "shape": [4, 3], "offset": 192, "size": 12},
  {"name": "conv0.gamma", "shape": [3], "offset": 204, "size": 3},
  {"name": "conv0.beta", "shape": [3], "offset": 207, "size": 3},
  {"name": "conv1.w", "shape": [4, 3, 3], "offset": 210, "size": 36},
  {"name": "conv1.b", "shape": [4, 3], "offset": 246, "size": 12},
  {"name": "conv1.gamma", "shape": [3], "offset": 258, "size": 3},
  {"name": "conv1.beta", "shape": [3], "offset": 261, "size": 3},
  {"name": "readout.w", "shape": [3, 12], "offset": 264, "size": 36},
  {"name": "readout.b", "shape": [12], "offset": 300, "size": 12}
 ],
 "init_file": "none.bin",
 "artifact_fwd_infer": "x", "artifact_fwd_train": "x",
 "artifact_fwd_sample": "x", "artifact_train_step": "x",
 "artifact_grad_sample": "x", "artifact_apply_sgd": "x"
}"#,
    )
    .unwrap();
    let cfg = ModelConfig::from_json(&j).unwrap();
    cfg.validate().unwrap();
    cfg
}

/// A generic parameter point: Glorot weights plus small noise on every
/// tensor, so biases, β and γ are probed away from their special init
/// values (0 and 1).
fn generic_params(cfg: &ModelConfig, seed: u64) -> ParamSet {
    let mut ps = ParamSet::random_init(cfg, seed);
    let mut rng = Rng::new(seed ^ 0xA5A5);
    for v in &mut ps.data {
        *v += 0.05 * rng.normal();
    }
    ps
}

/// Independent f64 mirror of `reference::forward` + BCE
/// `reference::loss`: the same mathematics as the engine-dispatch
/// forward, in plain loops at f64 precision. Used as the
/// finite-difference oracle (and itself cross-checked against the f32
/// forward below).
fn loss_f64(cfg: &ModelConfig, ps: &ParamSet, mb: &ModelBatch) -> f64 {
    let (b, m, ch, r) = (mb.batch, cfg.max_nodes, cfg.channels, mb.ell_width);
    let p = |name: &str| -> Vec<f64> {
        ps.slice(cfg, name)
            .unwrap()
            .iter()
            .map(|&v| v as f64)
            .collect()
    };
    let mut h: Vec<f64> = mb.x.iter().map(|&v| v as f64).collect();
    let mut fin = cfg.feat_dim;
    for (li, &fout) in cfg.hidden.iter().enumerate() {
        let w = p(&format!("conv{li}.w"));
        let bias = p(&format!("conv{li}.b"));
        let gamma = p(&format!("conv{li}.gamma"));
        let beta = p(&format!("conv{li}.beta"));
        let mut y = vec![0f64; b * m * fout];
        for c in 0..ch {
            // u = h @ w[c] + bias[c]
            let mut u = vec![0f64; b * m * fout];
            for bi in 0..b {
                for row in 0..m {
                    for o in 0..fout {
                        let mut acc = bias[c * fout + o];
                        for k in 0..fin {
                            acc += h[(bi * m + row) * fin + k] * w[(c * fin + k) * fout + o];
                        }
                        u[(bi * m + row) * fout + o] = acc;
                    }
                }
            }
            // y += A[c] @ u, straight off the ELL arrays
            for bi in 0..b {
                let base = (bi * ch + c) * m * r;
                for row in 0..m {
                    for slot in 0..r {
                        let val = mb.ell_vals[base + row * r + slot];
                        if val == 0.0 {
                            continue;
                        }
                        let cid = mb.ell_cols[base + row * r + slot] as usize;
                        for o in 0..fout {
                            y[(bi * m + row) * fout + o] +=
                                val as f64 * u[(bi * m + cid) * fout + o];
                        }
                    }
                }
            }
        }
        // GraphNorm + ReLU (+ re-mask), masked per graph.
        for bi in 0..b {
            let msk = &mb.mask[bi * m..(bi + 1) * m];
            let cnt = msk.iter().map(|&v| v as f64).sum::<f64>().max(1.0);
            for j in 0..fout {
                let mut mean = 0f64;
                for row in 0..m {
                    mean += y[(bi * m + row) * fout + j] * msk[row] as f64;
                }
                mean /= cnt;
                let mut var = 0f64;
                for row in 0..m {
                    let d = y[(bi * m + row) * fout + j] - mean;
                    var += d * d * msk[row] as f64;
                }
                var /= cnt;
                let inv = 1.0 / (var + 1e-5).sqrt();
                for row in 0..m {
                    let hn = (y[(bi * m + row) * fout + j] - mean) * inv;
                    let v = (gamma[j] * hn + beta[j]) * msk[row] as f64;
                    y[(bi * m + row) * fout + j] = v.max(0.0);
                }
            }
        }
        h = y;
        fin = fout;
    }
    // Sum-pool readout + stable BCE, mean over the batch.
    let wo = p("readout.w");
    let bo = p("readout.b");
    let n = cfg.n_out;
    let mut total = 0f64;
    for bi in 0..b {
        for o in 0..n {
            let mut x = bo[o];
            for row in 0..m {
                for k in 0..fin {
                    x += h[(bi * m + row) * fin + k] * wo[k * n + o];
                }
            }
            let yl = mb.labels[bi * n + o] as f64;
            // -logsig(x) and -logsig(-x), stable in both branches.
            let ls = if x >= 0.0 {
                (-x).exp().ln_1p()
            } else {
                -x + x.exp().ln_1p()
            };
            let lsn = if x >= 0.0 {
                x + (-x).exp().ln_1p()
            } else {
                x.exp().ln_1p()
            };
            total += yl * ls + (1.0 - yl) * lsn;
        }
    }
    total / b as f64
}

#[test]
fn f64_mirror_matches_f32_forward() {
    // The FD oracle must be the same function as the engine forward.
    let cfg = tiny_cfg();
    let ps = generic_params(&cfg, 11);
    let data = Dataset::generate(DatasetKind::Tox21, 6, 17);
    let mb = data.pack_batch(&[0, 2, 4], cfg.max_nodes, cfg.ell_width).unwrap();
    let logits = reference::forward(&cfg, &ps, &mb).unwrap();
    let l32 = reference::loss(&cfg, &logits, &mb.labels, mb.batch) as f64;
    let l64 = loss_f64(&cfg, &ps, &mb);
    assert!(
        (l32 - l64).abs() <= 1e-4 * l64.abs().max(1.0),
        "f32 loss {l32} vs f64 mirror {l64}"
    );
}

/// Check an analytic gradient against central finite differences at f64
/// on f32-representable points: perturb the f32 parameter, measure the
/// *actual* step `hi - lo` (the nominal ε is rounded to the parameter's
/// f32 grid), difference the f64 mirror. Fallback ε values only shift
/// the (rare) window where a ReLU kink sits inside [lo, hi].
fn assert_grads_match_fd(cfg: &ModelConfig, ps: &ParamSet, mb: &ModelBatch, grads: &[f32]) {
    const EPSILONS: [f32; 3] = [1e-4, 2.5e-5, 5e-4];
    const REL: f64 = 1e-4;
    let fd_at = |i: usize, eps: f32| -> f64 {
        let mut p = ps.clone();
        let old = ps.data[i];
        let hi = old + eps;
        let lo = old - eps;
        p.data[i] = hi;
        let lp = loss_f64(cfg, &p, mb);
        p.data[i] = lo;
        let lm = loss_f64(cfg, &p, mb);
        (lp - lm) / (hi as f64 - lo as f64)
    };
    for spec in &cfg.params {
        let mut checked = 0usize;
        for k in 0..spec.size {
            let i = spec.offset + k;
            let g = grads[i] as f64;
            let ok = EPSILONS.iter().any(|&eps| {
                let fd = fd_at(i, eps);
                (g - fd).abs() <= REL * g.abs().max(fd.abs()).max(1.0)
            });
            assert!(
                ok,
                "{}[{k}]: analytic {g} vs central differences {:?} (eps {:?})",
                spec.name,
                EPSILONS.map(|eps| fd_at(i, eps)),
                EPSILONS,
            );
            checked += 1;
        }
        assert_eq!(checked, spec.size, "{} not fully checked", spec.name);
    }
}

#[test]
fn every_parameter_tensor_matches_central_finite_differences() {
    let cfg = tiny_cfg();
    let ps = generic_params(&cfg, 11);
    // Mixed batch: synthetic molecules have different node/edge counts.
    let data = Dataset::generate(DatasetKind::Tox21, 6, 17);
    let mb = data.pack_batch(&[0, 2, 4], cfg.max_nodes, cfg.ell_width).unwrap();

    let res = backward::grad(&cfg, &ps, &mb).unwrap();
    assert!(res.loss.is_finite());
    assert_grads_match_fd(&cfg, &ps, &mb, &res.grads.data);
}

#[test]
fn row_parallel_batch1_dw_is_bit_stable_and_passes_fd() {
    // A batch-1 gradient makes every `dW = X^T·dU` dispatch (and the
    // readout twin) a batch-1 transpose GEMM: with one sample there is
    // nothing to sample-split, so the worker pool row-splits the
    // reduction across workers (DESIGN.md §9). That split must be
    // invisible bit-for-bit against the single-threaded backward, and
    // the row-parallel gradient must still pass the same 1e-4
    // finite-difference gate as the serial one.
    let cfg = tiny_cfg();
    let ps = generic_params(&cfg, 47);
    let data = Dataset::generate(DatasetKind::Tox21, 4, 53);
    let mb = data.pack_batch(&[2], cfg.max_nodes, cfg.ell_width).unwrap();
    assert_eq!(mb.batch, 1);

    let serial = backward::grad(&cfg, &ps, &mb).unwrap();
    let mut parallel = None;
    for threads in [2, 8] {
        let par = backward::grad_with(&cfg, &ps, &mb, &Executor::new(threads), None).unwrap();
        assert_eq!(
            serial.grads.data, par.grads.data,
            "threads={threads}: row-parallel dW drifted from single-threaded"
        );
        assert_eq!(serial.loss, par.loss);
        parallel = Some(par);
    }
    assert_grads_match_fd(&cfg, &ps, &mb, &parallel.unwrap().grads.data);
}

#[test]
fn batched_grad_equals_mean_of_per_sample_grads() {
    // The decomposability contract behind Table II, now for gradients:
    // grad over a batch == mean of per-sample grads (up to
    // accumulation-order rounding).
    let cfg = tiny_cfg();
    let ps = generic_params(&cfg, 23);
    let data = Dataset::generate(DatasetKind::Tox21, 5, 29);
    let mb = data.pack_batch(&[0, 1, 3], cfg.max_nodes, cfg.ell_width).unwrap();
    let batched = backward::grad(&cfg, &ps, &mb).unwrap();
    let mut mean = vec![0f32; cfg.n_params];
    for bi in 0..3 {
        let one = backward::grad(&cfg, &ps, &mb.single(bi)).unwrap();
        for (m, g) in mean.iter_mut().zip(&one.grads.data) {
            *m += g / 3.0;
        }
    }
    for (i, (a, b)) in batched.grads.data.iter().zip(&mean).enumerate() {
        assert!(
            (a - b).abs() <= 1e-5 + 1e-4 * b.abs(),
            "param {i}: batched {a} vs per-sample mean {b}"
        );
    }
}

#[test]
fn host_trainer_loss_decreases_over_10_steps() {
    // Full-batch SGD on one fixed minibatch must reduce the training
    // loss — the end-to-end signature of a correct gradient + update.
    let mut tr = Trainer::new_host("tox21", 0).unwrap();
    let data = Dataset::generate(DatasetKind::Tox21, 8, 31);
    let idx: Vec<usize> = (0..8).collect();
    let mb = data.pack_batch(&idx, tr.cfg.max_nodes, tr.cfg.ell_width).unwrap();
    let mut losses = Vec::new();
    for _ in 0..10 {
        let l = tr.step_batched(&mb, 0.02).unwrap();
        assert!(l.is_finite(), "loss diverged: {losses:?} then {l}");
        losses.push(l);
    }
    assert!(
        losses.last().unwrap() < losses.first().unwrap(),
        "loss did not decrease over 10 SGD steps: {losses:?}"
    );
}

#[test]
fn grad_thread_count_is_invisible() {
    // Gradients, like logits, must be bit-identical for every executor
    // width (disjoint per-sample output slices; batch-1 reductions are
    // serial either way).
    let cfg = tiny_cfg();
    let ps = generic_params(&cfg, 37);
    let data = Dataset::generate(DatasetKind::Tox21, 4, 41);
    let mb = data.pack_batch(&[0, 1, 2, 3], cfg.max_nodes, cfg.ell_width).unwrap();
    let serial = backward::grad(&cfg, &ps, &mb).unwrap();
    for threads in [2, 8] {
        let par = backward::grad_with(&cfg, &ps, &mb, &Executor::new(threads), None).unwrap();
        assert_eq!(serial.grads.data, par.grads.data, "threads={threads}");
    }
}
