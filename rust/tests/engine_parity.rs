//! Engine parity properties: every `BatchedSpmm` backend × thread count
//! must match the single-matrix oracles in `sparse::ops` on randomized
//! workloads (uniform, mixed, and skewed one-giant-many-tiny batches),
//! worker-pool output must be bit-identical to serial regardless of
//! policy and steal order, the pool's scheduling counters must show the
//! static fast path on uniform batches and actual stealing on skewed
//! ones, and the engine-routed GCN forward must be bit-stable against
//! the pre-engine inlined implementation (kept here verbatim as the
//! refactor oracle).

use bspmm::gcn::backward;
use bspmm::gcn::config::ModelConfig;
use bspmm::gcn::params::ParamSet;
use bspmm::gcn::reference;
use bspmm::graph::dataset::{Dataset, DatasetKind, ModelBatch};
use bspmm::sparse::batch::{
    densify_batch, random_dense_batch, PaddedCsrBatch, PaddedEllBatch, PaddedStBatch,
};
use bspmm::sparse::engine::{
    AutoThresholds, Backend, BatchedSpmm, CsrKernel, EllKernel, Executor, GemmKernel,
    KernelBundle, KernelVariant, LANES, Rhs, SchedPolicy, SlotId, SlotInit, StKernel, Workspace,
};
use bspmm::sparse::ops;
use bspmm::sparse::random::{random_batch, random_coo, random_mixed_batch, RandomSpec};
use bspmm::sparse::{Coo, Dense};
use bspmm::util::rng::Rng;

const THREAD_COUNTS: [usize; 3] = [1, 2, 8];

/// Expected whole-batch output: each matrix through the `ops::spmm_st`
/// oracle, written into its `[dim, nb]` bucket slot (rows past the
/// matrix's true size stay zero, exactly like the padded formats).
fn oracle_batch(mats: &[Coo], dim: usize, dense: &[f32], nb: usize) -> Vec<f32> {
    let mut out = vec![0f32; mats.len() * dim * nb];
    for (bi, m) in mats.iter().enumerate() {
        let b = Dense {
            rows: m.cols,
            cols: nb,
            data: dense[bi * dim * nb..bi * dim * nb + m.cols * nb].to_vec(),
        };
        let want = ops::spmm_st(&m.to_sparse_tensor(), &b);
        for r in 0..m.rows {
            out[bi * dim * nb + r * nb..bi * dim * nb + (r + 1) * nb]
                .copy_from_slice(&want.data[r * nb..(r + 1) * nb]);
        }
    }
    out
}

fn assert_close(got: &[f32], want: &[f32], what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert!(
            (g - w).abs() <= 1e-5 + 1e-5 * w.abs(),
            "{what}: elem {i}: got {g}, want {w}"
        );
    }
}

fn check_all_backends(mats: &[Coo], dim: usize, nb: usize, dense: &[f32], what: &str) {
    let want = oracle_batch(mats, dim, dense, nb);
    let cap = mats.iter().map(Coo::nnz).max().unwrap_or(1);
    let st = PaddedStBatch::pack(mats, dim, cap).unwrap();
    let csr = PaddedCsrBatch::pack(mats, dim, cap).unwrap();
    let ell = PaddedEllBatch::pack_auto(mats, dim).unwrap();
    let a_dense = densify_batch(mats, dim);
    let stk = StKernel::new(&st);
    let csrk = CsrKernel::new(&csr);
    let ellk = EllKernel::from_padded(&ell);
    let gemk = GemmKernel::new(&a_dense, mats.len(), dim, dim);
    let kernels: [&dyn BatchedSpmm; 4] = [&stk, &csrk, &ellk, &gemk];
    for kernel in kernels {
        for threads in THREAD_COUNTS {
            let exec = Executor::new(threads);
            let got = exec.spmm(kernel, Rhs::PerSample(dense), nb).unwrap();
            assert_close(&got, &want, &format!("{what}/{}/t{threads}", kernel.name()));
        }
    }
}

#[test]
fn uniform_workloads_match_oracle_at_all_thread_counts() {
    let mut rng = Rng::new(0xE1);
    for case in 0..12 {
        let dim = rng.range(1, 40);
        let z = rng.range(1, 4.min(dim));
        let batch = rng.range(1, 16);
        let nb = rng.range(1, 24);
        let mats = random_batch(&mut rng, &RandomSpec::new(dim, z), batch);
        let dense = random_dense_batch(&mut rng, batch, dim, nb);
        check_all_backends(&mats, dim, nb, &dense, &format!("uniform case {case}"));
    }
}

#[test]
fn mixed_workloads_match_oracle_at_all_thread_counts() {
    let mut rng = Rng::new(0xE2);
    for case in 0..6 {
        let dim = 32;
        let batch = rng.range(2, 12);
        let nb = rng.range(1, 16);
        let mats = random_mixed_batch(&mut rng, (4, dim), (1, 3), batch);
        let dense = random_dense_batch(&mut rng, batch, dim, nb);
        check_all_backends(&mats, dim, nb, &dense, &format!("mixed case {case}"));
    }
}

/// One giant sample next to many tiny ones: the Fig. 10-style skew that
/// load-imbalances a contiguous sample split. The giant sits first, so
/// the legacy static partition would hand one worker almost all of the
/// work.
fn skewed_batch(rng: &mut Rng) -> (Vec<Coo>, usize) {
    let dim = 96;
    let mut mats = vec![random_coo(rng, &RandomSpec::new(dim, 8))];
    for _ in 0..12 {
        let d = rng.range(3, 8);
        mats.push(random_coo(rng, &RandomSpec::new(d, 1)));
    }
    (mats, dim)
}

#[test]
fn skewed_workloads_match_oracle_at_all_thread_counts() {
    let mut rng = Rng::new(0xE5);
    for case in 0..4 {
        let (mats, dim) = skewed_batch(&mut rng);
        let nb = rng.range(1, 12);
        let dense = random_dense_batch(&mut rng, mats.len(), dim, nb);
        check_all_backends(&mats, dim, nb, &dense, &format!("skewed case {case}"));
    }
}

#[test]
fn skewed_batches_are_bit_identical_to_serial_for_every_backend() {
    // Row-split tasks + stealing must not change a single bit, in
    // either transpose form, for any thread count or policy.
    let mut rng = Rng::new(0xE7);
    let (mats, dim) = skewed_batch(&mut rng);
    let nb = 7;
    let dense = random_dense_batch(&mut rng, mats.len(), dim, nb);
    let cap = mats.iter().map(Coo::nnz).max().unwrap();
    let st = PaddedStBatch::pack(&mats, dim, cap).unwrap();
    let csr = PaddedCsrBatch::pack(&mats, dim, cap).unwrap();
    let ell = PaddedEllBatch::pack_auto(&mats, dim).unwrap();
    let a_dense = densify_batch(&mats, dim);
    let stk = StKernel::new(&st);
    let csrk = CsrKernel::new(&csr);
    let ellk = EllKernel::from_padded(&ell);
    let gemk = GemmKernel::new(&a_dense, mats.len(), dim, dim);
    let kernels: [&dyn BatchedSpmm; 4] = [&stk, &csrk, &ellk, &gemk];
    let serial = Executor::serial();
    for kernel in kernels {
        let fwd = serial.spmm(kernel, Rhs::PerSample(&dense), nb).unwrap();
        let bwd = serial.spmm_t(kernel, Rhs::PerSample(&dense), nb).unwrap();
        for threads in THREAD_COUNTS {
            for policy in [SchedPolicy::Static, SchedPolicy::WorkStealing] {
                let exec = Executor::with_policy(threads, policy);
                let pf = exec.spmm(kernel, Rhs::PerSample(&dense), nb).unwrap();
                assert_eq!(pf, fwd, "{}/t{threads}/{policy:?} fwd", kernel.name());
                let pb = exec.spmm_t(kernel, Rhs::PerSample(&dense), nb).unwrap();
                assert_eq!(pb, bwd, "{}/t{threads}/{policy:?} bwd", kernel.name());
            }
        }
    }
}

#[test]
fn uniform_batches_stay_static_while_skewed_batches_steal() {
    let mut rng = Rng::new(0xE6);

    // Uniform: the planner keeps the legacy contiguous split (at most
    // one task per worker), so stealing is structurally impossible.
    let mats = random_batch(&mut rng, &RandomSpec::new(24, 3), 64);
    let st = PaddedStBatch::pack(&mats, 24, 24 * 3).unwrap();
    let dense = random_dense_batch(&mut rng, 64, 24, 8);
    let k = StKernel::new(&st);
    let exec = Executor::new(8);
    let before = exec.stats();
    assert_eq!(before.spawned_threads, 7);
    exec.spmm(&k, Rhs::PerSample(&dense), 8).unwrap();
    let after = exec.stats();
    assert_eq!(after.dispatches - before.dispatches, 1);
    assert_eq!(after.static_dispatches - before.static_dispatches, 1);
    assert_eq!(after.stealing_dispatches, before.stealing_dispatches);
    assert_eq!(after.steals, before.steals, "uniform batch must not steal");
    assert_eq!(after.spawned_threads, before.spawned_threads);

    // Skewed, with the planner's uniform-rows-per-sample assumption
    // deliberately violated: one sample holds nearly all its non-zeros
    // in its first rows, so the first row block of its split carries
    // almost all the real work and the cost model mispredicts. Idle
    // workers must rebalance by stealing — and stealing must not change
    // the output bits.
    let dim = 512;
    let mut giant = Coo::new(dim, dim);
    for r in 0..32 {
        for c in 0..dim {
            giant.push(r, c, 0.5 + (c % 7) as f32 * 0.1);
        }
    }
    for r in 32..dim {
        giant.push(r, r, 1.0);
    }
    let mut mats = vec![giant];
    for i in 0..15 {
        let mut tiny = Coo::new(4, 4);
        for r in 0..4 {
            tiny.push(r, (r + i) % 4, 1.0);
        }
        mats.push(tiny);
    }
    let cap = mats.iter().map(Coo::nnz).max().unwrap();
    let st = PaddedStBatch::pack(&mats, dim, cap).unwrap();
    let k = StKernel::new(&st);
    let dense = random_dense_batch(&mut rng, mats.len(), dim, 64);
    let want = Executor::serial().spmm(&k, Rhs::PerSample(&dense), 64).unwrap();
    let exec = Executor::new(4);
    let before = exec.stats();
    let mut got = Vec::new();
    for _ in 0..10 {
        got = exec.spmm(&k, Rhs::PerSample(&dense), 64).unwrap();
    }
    assert_eq!(got, want, "stealing changed the output");
    let after = exec.stats();
    assert_eq!(after.dispatches - before.dispatches, 10);
    assert_eq!(
        after.stealing_dispatches - before.stealing_dispatches,
        10,
        "skewed batch did not take the stealing path"
    );
    assert!(
        after.tasks - before.tasks > 10 * 4,
        "skewed plan did not oversubscribe: {} tasks",
        after.tasks - before.tasks
    );
    assert!(
        after.steals > before.steals,
        "skewed dispatches never stole a task"
    );
    assert_eq!(after.spawned_threads, before.spawned_threads);
}

/// Scalar-serial is THE reference: every backend × variant × thread
/// count × policy must reproduce it bit for bit, in both transpose
/// forms. Skewed and batch-1 workloads push dispatches through the
/// row-blocked kernel variants (`spmm_sample[_t]_rows`), so all four
/// dispatch forms are covered (DESIGN.md §10).
fn check_scalar_vs_vectorized(mats: &[Coo], dim: usize, nb: usize, dense: &[f32], what: &str) {
    let cap = mats.iter().map(Coo::nnz).max().unwrap_or(1);
    let st = PaddedStBatch::pack(mats, dim, cap).unwrap();
    let csr = PaddedCsrBatch::pack(mats, dim, cap).unwrap();
    let ell = PaddedEllBatch::pack_auto(mats, dim).unwrap();
    let a_dense = densify_batch(mats, dim);
    let stk = StKernel::new(&st);
    let csrk = CsrKernel::new(&csr);
    let ellk = EllKernel::from_padded(&ell);
    let gemk = GemmKernel::new(&a_dense, mats.len(), dim, dim);
    let kernels: [&dyn BatchedSpmm; 4] = [&stk, &csrk, &ellk, &gemk];
    let oracle = Executor::with_variant(1, SchedPolicy::WorkStealing, KernelVariant::Scalar);
    for kernel in kernels {
        let fwd = oracle.spmm(kernel, Rhs::PerSample(dense), nb).unwrap();
        let bwd = oracle.spmm_t(kernel, Rhs::PerSample(dense), nb).unwrap();
        for variant in [
            KernelVariant::Scalar,
            KernelVariant::Vectorized,
            KernelVariant::Simd,
        ] {
            for threads in THREAD_COUNTS {
                for policy in [SchedPolicy::Static, SchedPolicy::WorkStealing] {
                    let exec = Executor::with_variant(threads, policy, variant);
                    let pf = exec.spmm(kernel, Rhs::PerSample(dense), nb).unwrap();
                    assert_eq!(
                        pf,
                        fwd,
                        "{what}/{}/{variant:?}/t{threads}/{policy:?} fwd",
                        kernel.name()
                    );
                    let pb = exec.spmm_t(kernel, Rhs::PerSample(dense), nb).unwrap();
                    assert_eq!(
                        pb,
                        bwd,
                        "{what}/{}/{variant:?}/t{threads}/{policy:?} bwd",
                        kernel.name()
                    );
                }
            }
        }
    }
}

#[test]
fn vectorized_kernels_bit_identical_to_scalar_reference_everywhere() {
    let mut rng = Rng::new(0xE8);
    // Uniform, with a non-multiple-of-LANES feature width (tail 1).
    let mats = random_batch(&mut rng, &RandomSpec::new(24, 3), 12);
    let dense = random_dense_batch(&mut rng, 12, 24, LANES + 1);
    check_scalar_vs_vectorized(&mats, 24, LANES + 1, &dense, "uniform");
    // Skewed: the pool row-splits the giant sample, exercising the
    // rows/t_rows forms of both variants under stealing.
    let (mats, dim) = skewed_batch(&mut rng);
    let dense = random_dense_batch(&mut rng, mats.len(), dim, 13);
    check_scalar_vs_vectorized(&mats, dim, 13, &dense, "skewed");
    // Batch-1 (the dW = X^T·dU shape): row fan-out across all workers.
    let one = vec![random_coo(&mut rng, &RandomSpec::new(48, 4))];
    let dense = random_dense_batch(&mut rng, 1, 48, 5);
    check_scalar_vs_vectorized(&one, 48, 5, &dense, "batch1");
}

/// Large-graph tentpole property (DESIGN.md §12): the cache-tiled CSR
/// kernel is bit-identical to the untiled vectorized kernel AND the
/// scalar oracle for EVERY tile width — sub-lane (1), odd (7), exactly
/// n_B (14 > n), the L2 default scale (64) and absurdly large (4096) —
/// at every thread count and both scheduling policies, in both
/// transpose forms. Tiling only regroups independent output columns;
/// each element's nnz accumulation chain is untouched, so equality is
/// exact, not approximate.
#[test]
fn tiled_csr_bit_identical_to_untiled_and_scalar_across_widths_threads_policies() {
    let mut rng = Rng::new(0xEB);
    let (skew_mats, skew_dim) = skewed_batch(&mut rng);
    let one = vec![random_coo(&mut rng, &RandomSpec::new(48, 4))];
    let cases: Vec<(Vec<Coo>, usize, &str)> =
        vec![(skew_mats, skew_dim, "skewed"), (one, 48, "batch1")];
    let nb = 13usize; // not a LANES multiple: scalar tail stays live
    for (mats, dim, what) in &cases {
        let dim = *dim;
        let dense = random_dense_batch(&mut rng, mats.len(), dim, nb);
        let cap = mats.iter().map(Coo::nnz).max().unwrap();
        let csr = PaddedCsrBatch::pack(mats, dim, cap).unwrap();
        let base = CsrKernel::new(&csr);
        let scalar = Executor::with_variant(1, SchedPolicy::WorkStealing, KernelVariant::Scalar);
        let want_fwd = scalar.spmm(&base, Rhs::PerSample(&dense), nb).unwrap();
        let want_bwd = scalar.spmm_t(&base, Rhs::PerSample(&dense), nb).unwrap();
        // Anchor the chain: untiled vectorized serial == scalar oracle.
        let serial = Executor::serial();
        assert_eq!(
            serial.spmm(&base, Rhs::PerSample(&dense), nb).unwrap(),
            want_fwd,
            "{what} untiled fwd"
        );
        for tc in [1usize, 7, 14, 64, 4096] {
            let k = CsrKernel::new(&csr).with_tile_cols(tc);
            for threads in THREAD_COUNTS {
                for policy in [SchedPolicy::Static, SchedPolicy::WorkStealing] {
                    let exec = Executor::with_variant(threads, policy, KernelVariant::Tiled);
                    let pf = exec.spmm(&k, Rhs::PerSample(&dense), nb).unwrap();
                    assert_eq!(pf, want_fwd, "{what}/tc{tc}/t{threads}/{policy:?} fwd");
                    // Transpose dispatches take the tiled scatter twin
                    // (spmm_sample_t_tiled) — bit-exact vs scalar at
                    // every tile width, same argument as the forward.
                    let pb = exec.spmm_t(&k, Rhs::PerSample(&dense), nb).unwrap();
                    assert_eq!(pb, want_bwd, "{what}/tc{tc}/t{threads}/{policy:?} bwd");
                }
            }
        }
    }
}

#[test]
fn tail_widths_bit_identical_scalar_vs_vectorized_on_every_form() {
    // The tox21/reaction100 feature widths are not multiples of LANES,
    // so the scalar tail path is always live in training: audit it at
    // n in {1, 7, 8, 9, 65} — sub-block, block-minus-one, exact block,
    // block-plus-one, many-blocks-plus-one — for every backend and all
    // four dispatch forms, directly at the kernel-method level.
    let mut rng = Rng::new(0xE9);
    let dim = 17;
    let mats = random_mixed_batch(&mut rng, (3, dim), (1, 3), 5);
    let cap = mats.iter().map(Coo::nnz).max().unwrap();
    let st = PaddedStBatch::pack(&mats, dim, cap).unwrap();
    let csr = PaddedCsrBatch::pack(&mats, dim, cap).unwrap();
    let ell = PaddedEllBatch::pack_auto(&mats, dim).unwrap();
    let a_dense = densify_batch(&mats, dim);
    let stk = StKernel::new(&st);
    let csrk = CsrKernel::new(&csr);
    let ellk = EllKernel::from_padded(&ell);
    let gemk = GemmKernel::new(&a_dense, mats.len(), dim, dim);
    let kernels: [&dyn BatchedSpmm; 4] = [&stk, &csrk, &ellk, &gemk];
    assert_eq!(LANES, 8, "tail widths below assume LANES == 8");
    for n in [1usize, 7, 8, 9, 65] {
        let rhs: Vec<f32> = (0..dim * n).map(|_| rng.normal()).collect();
        // Uneven row cuts, including 1-row blocks.
        let cuts = [0usize, 1, 9, dim];
        for kernel in kernels {
            for b in 0..mats.len() {
                for transpose in [false, true] {
                    let mut vec_full = vec![0.5f32; dim * n];
                    let mut sc_full = vec_full.clone();
                    if transpose {
                        kernel.spmm_sample_t(b, &rhs, n, &mut vec_full);
                        kernel.spmm_sample_t_scalar(b, &rhs, n, &mut sc_full);
                    } else {
                        kernel.spmm_sample(b, &rhs, n, &mut vec_full);
                        kernel.spmm_sample_scalar(b, &rhs, n, &mut sc_full);
                    }
                    assert_eq!(
                        vec_full,
                        sc_full,
                        "{} n={n} sample {b} transpose={transpose} full",
                        kernel.name()
                    );
                    let mut vec_blocked = vec![0.5f32; dim * n];
                    let mut sc_blocked = vec_blocked.clone();
                    for w in cuts.windows(2) {
                        let (r0, r1) = (w[0], w[1]);
                        let vb = &mut vec_blocked[r0 * n..r1 * n];
                        let sb = &mut sc_blocked[r0 * n..r1 * n];
                        if transpose {
                            kernel.spmm_sample_t_rows(b, r0, &rhs, n, vb);
                            kernel.spmm_sample_t_rows_scalar(b, r0, &rhs, n, sb);
                        } else {
                            kernel.spmm_sample_rows(b, r0, &rhs, n, vb);
                            kernel.spmm_sample_rows_scalar(b, r0, &rhs, n, sb);
                        }
                    }
                    assert_eq!(
                        vec_blocked,
                        sc_blocked,
                        "{} n={n} sample {b} transpose={transpose} rows",
                        kernel.name()
                    );
                    // And the blocked assembly must equal the full form.
                    assert_eq!(
                        vec_blocked,
                        vec_full,
                        "{} n={n} sample {b} transpose={transpose} assembly",
                        kernel.name()
                    );
                }
            }
        }
    }
}

/// SIMD tentpole property (DESIGN.md §16): without `BSPMM_ALLOW_FMA`
/// the explicit-SIMD kernels keep the scalar oracle's
/// round-after-multiply, round-after-add order per element, so
/// [`KernelVariant::Simd`] must be bit-identical to scalar — on every
/// backend, both transpose forms, threads {1, 2, 8}, and tail widths
/// {1, 7, 8, 9, 65} (sub-lane, lane-minus-one, exact lane,
/// lane-plus-one, many-lanes-plus-one). Built with `--features simd`
/// on an AVX2 host this exercises the intrinsics; otherwise the Simd
/// variant is its vectorized fallback and the assertions pin that the
/// fallback, too, matches scalar exactly.
#[test]
fn simd_bit_identical_to_scalar_across_backends_threads_and_tail_widths() {
    let mut rng = Rng::new(0xEC);
    let dim = 33;
    let mats = random_mixed_batch(&mut rng, (3, dim), (1, 3), 6);
    let cap = mats.iter().map(Coo::nnz).max().unwrap();
    let st = PaddedStBatch::pack(&mats, dim, cap).unwrap();
    let csr = PaddedCsrBatch::pack(&mats, dim, cap).unwrap();
    let ell = PaddedEllBatch::pack_auto(&mats, dim).unwrap();
    let a_dense = densify_batch(&mats, dim);
    let stk = StKernel::new(&st);
    let csrk = CsrKernel::new(&csr);
    let ellk = EllKernel::from_padded(&ell);
    let gemk = GemmKernel::new(&a_dense, mats.len(), dim, dim);
    let kernels: [&dyn BatchedSpmm; 4] = [&stk, &csrk, &ellk, &gemk];
    let scalar = Executor::with_variant(1, SchedPolicy::WorkStealing, KernelVariant::Scalar);
    assert_eq!(LANES, 8, "tail widths below assume LANES == 8");
    for n in [1usize, 7, 8, 9, 65] {
        let dense = random_dense_batch(&mut rng, mats.len(), dim, n);
        for kernel in kernels {
            let fwd = scalar.spmm(kernel, Rhs::PerSample(&dense), n).unwrap();
            let bwd = scalar.spmm_t(kernel, Rhs::PerSample(&dense), n).unwrap();
            for threads in THREAD_COUNTS {
                for policy in [SchedPolicy::Static, SchedPolicy::WorkStealing] {
                    let exec = Executor::with_variant(threads, policy, KernelVariant::Simd);
                    let pf = exec.spmm(kernel, Rhs::PerSample(&dense), n).unwrap();
                    assert_eq!(pf, fwd, "{}/n{n}/t{threads}/{policy:?} fwd", kernel.name());
                    let pb = exec.spmm_t(kernel, Rhs::PerSample(&dense), n).unwrap();
                    assert_eq!(pb, bwd, "{}/n{n}/t{threads}/{policy:?} bwd", kernel.name());
                }
            }
            // Row-blocked SIMD forms directly at the kernel-method
            // level, with uneven cuts (the shapes stealing produces).
            for b in 0..mats.len() {
                let rhs = &dense[b * dim * n..(b + 1) * dim * n];
                for transpose in [false, true] {
                    let mut sc = vec![0.25f32; dim * n];
                    let mut sd = sc.clone();
                    for w in [0usize, 1, 9, dim].windows(2) {
                        let (r0, r1) = (w[0], w[1]);
                        if transpose {
                            kernel.spmm_sample_t_rows_scalar(b, r0, rhs, n, &mut sc[r0 * n..r1 * n]);
                            kernel.spmm_sample_t_rows_simd(b, r0, rhs, n, &mut sd[r0 * n..r1 * n]);
                        } else {
                            kernel.spmm_sample_rows_scalar(b, r0, rhs, n, &mut sc[r0 * n..r1 * n]);
                            kernel.spmm_sample_rows_simd(b, r0, rhs, n, &mut sd[r0 * n..r1 * n]);
                        }
                    }
                    assert_eq!(
                        sd,
                        sc,
                        "{} n={n} sample {b} transpose={transpose} rows-simd",
                        kernel.name()
                    );
                }
            }
        }
    }
}

/// Tentpole property (DESIGN.md §11): planned + arena execution —
/// output drawn from a workspace slot, backend resolved through the
/// bundle (fixed or `Auto`) — is bit-identical to the direct path for
/// every backend × thread count × policy, on uniform, skewed and
/// batch-1 workloads, and steady-state replays never grow the arena.
#[test]
fn planned_arena_execution_bit_identical_to_direct_for_every_backend_and_auto() {
    let mut rng = Rng::new(0xEA);
    let th = AutoThresholds::default();
    let uniform = random_batch(&mut rng, &RandomSpec::new(24, 3), 12);
    let (skew_mats, skew_dim) = skewed_batch(&mut rng);
    let one = vec![random_coo(&mut rng, &RandomSpec::new(48, 4))];
    let cases: Vec<(Vec<Coo>, usize, &str)> = vec![
        (uniform, 24, "uniform"),
        (skew_mats, skew_dim, "skewed"),
        (one, 48, "batch1"),
    ];
    for (mats, dim, what) in &cases {
        let dim = *dim;
        let nb = 7usize;
        let dense = random_dense_batch(&mut rng, mats.len(), dim, nb);
        let cap = mats.iter().map(Coo::nnz).max().unwrap();
        let st = PaddedStBatch::pack(mats, dim, cap).unwrap();
        let csr = PaddedCsrBatch::pack(mats, dim, cap).unwrap();
        let ell = PaddedEllBatch::pack_auto(mats, dim).unwrap();
        let a_dense = densify_batch(mats, dim);
        let stk = StKernel::new(&st);
        let csrk = CsrKernel::new(&csr);
        let ellk = EllKernel::from_padded(&ell);
        let gemk = GemmKernel::new(&a_dense, mats.len(), dim, dim);
        let bundle = KernelBundle {
            st: Some(&stk),
            csr: Some(&csrk),
            ell: Some(&ellk),
            gemm: Some(&gemk),
            ell_width: Some(ell.width),
        };
        let out_len = mats.len() * dim * nb;
        for backend in [
            Backend::St,
            Backend::Csr,
            Backend::Ell,
            Backend::Gemm,
            Backend::Auto,
        ] {
            let (chosen, kernel) = bundle.resolve(backend, &th).unwrap();
            assert_ne!(chosen, Backend::Auto, "auto must resolve to a fixed backend");
            for threads in THREAD_COUNTS {
                for policy in [SchedPolicy::Static, SchedPolicy::WorkStealing] {
                    let exec = Executor::with_policy(threads, policy);
                    let direct = exec.spmm(kernel, Rhs::PerSample(&dense), nb).unwrap();
                    let mut ws = Workspace::new();
                    let slot = SlotId(0);
                    for round in 0..2 {
                        let mut out = ws.take(slot, out_len, SlotInit::Zeroed);
                        let ran = exec
                            .dispatch_bundle(
                                &bundle,
                                backend,
                                &th,
                                Rhs::PerSample(&dense),
                                nb,
                                &mut out,
                            )
                            .unwrap();
                        assert_eq!(ran, chosen);
                        assert_eq!(
                            out, direct,
                            "{what}/{backend:?}/t{threads}/{policy:?}/round{round}"
                        );
                        ws.put(slot, out);
                    }
                    assert_eq!(ws.grows(), 1, "second round regrew the arena");
                    assert_eq!(ws.reuses(), 1, "second round did not reuse the slot");
                }
            }
        }
    }
}

/// The same tentpole property one level up: the planned gcn forward and
/// train-step replays are bit-identical to the direct
/// `forward_with_readout` / `grad_with` paths, for every thread count ×
/// policy, and replays never grow the prepared arena.
#[test]
fn planned_gcn_forward_and_train_bit_identical_to_direct() {
    let cfg = ModelConfig::synthetic("tox21").unwrap();
    let ps = ParamSet::random_init(&cfg, 0xAB);
    let d = Dataset::generate(DatasetKind::Tox21, 8, 21);
    let idx: Vec<usize> = (0..6).collect();
    let mb = d.pack_batch(&idx, cfg.max_nodes, cfg.ell_width).unwrap();
    let w_rep = reference::build_w_rep(&cfg, &ps).unwrap();
    let th = AutoThresholds::default();
    let fwd_plan = reference::plan_forward(&cfg, &mb, &th).unwrap();
    let train_plan = backward::plan_train(&cfg, &mb, &th).unwrap();
    // 17 forward + 22 backward dispatch descriptors for the tox21
    // geometry (DESIGN.md §8), resolved once at plan build.
    assert_eq!(fwd_plan.dispatches.len(), 17);
    assert_eq!(train_plan.dispatches.len(), 39);
    assert!(fwd_plan
        .dispatches
        .iter()
        .all(|d| d.backend != Backend::Auto));
    for threads in THREAD_COUNTS {
        for policy in [SchedPolicy::Static, SchedPolicy::WorkStealing] {
            let exec = Executor::with_policy(threads, policy);
            let direct = reference::forward_with_readout(&cfg, &ps, &mb, &exec, &w_rep).unwrap();
            let mut ws = Workspace::new();
            ws.prepare(&fwd_plan);
            for round in 0..2 {
                let planned =
                    reference::forward_planned(&cfg, &ps, &mb, &exec, &w_rep, &fwd_plan, &mut ws)
                        .unwrap();
                assert_eq!(planned, direct, "fwd t{threads}/{policy:?}/round{round}");
            }
            assert_eq!(ws.grows(), 0, "prepared forward arena regrew");

            let res = backward::grad_with(&cfg, &ps, &mb, &exec, Some(&w_rep)).unwrap();
            let mut tws = Workspace::new();
            tws.prepare(&train_plan);
            let mut grads = vec![0f32; cfg.n_params];
            for round in 0..2 {
                let loss = backward::grad_planned(
                    &cfg,
                    &ps,
                    &mb,
                    &exec,
                    &w_rep,
                    &train_plan,
                    &mut tws,
                    &mut grads,
                )
                .unwrap();
                assert_eq!(loss, res.loss, "loss t{threads}/{policy:?}/round{round}");
                assert_eq!(
                    grads, res.grads.data,
                    "grads t{threads}/{policy:?}/round{round}"
                );
            }
            assert_eq!(tws.grows(), 0, "prepared train arena regrew");
        }
    }
}

#[test]
fn parallel_executor_is_bitwise_deterministic() {
    let mut rng = Rng::new(0xE3);
    let mats = random_batch(&mut rng, &RandomSpec::new(24, 3), 64);
    let st = PaddedStBatch::pack(&mats, 24, 24 * 3).unwrap();
    let dense = random_dense_batch(&mut rng, 64, 24, 16);
    let k = StKernel::new(&st);
    let serial = Executor::serial().spmm(&k, Rhs::PerSample(&dense), 16).unwrap();
    for threads in [2, 8, 64] {
        let par = Executor::new(threads)
            .spmm(&k, Rhs::PerSample(&dense), 16)
            .unwrap();
        assert_eq!(serial, par, "threads={threads}");
    }
}

// ---------------------------------------------------------------------
// GCN forward bit-stability: the pre-engine inlined implementation,
// kept verbatim, vs the engine-routed `reference::forward`.
// ---------------------------------------------------------------------

const EPS: f32 = 1e-5;

fn naive_graph_norm_relu(
    y: &mut [f32],
    mask: &[f32],
    gamma: &[f32],
    beta: &[f32],
    b: usize,
    m: usize,
    f: usize,
) {
    for bi in 0..b {
        let msk = &mask[bi * m..(bi + 1) * m];
        let cnt = msk.iter().sum::<f32>().max(1.0);
        let rows = &mut y[bi * m * f..(bi + 1) * m * f];
        for j in 0..f {
            let mut mean = 0f32;
            for r in 0..m {
                mean += rows[r * f + j] * msk[r];
            }
            mean /= cnt;
            let mut var = 0f32;
            for r in 0..m {
                let d = rows[r * f + j] - mean;
                var += d * d * msk[r];
            }
            var /= cnt;
            let inv = 1.0 / (var + EPS).sqrt();
            for r in 0..m {
                let hn = (rows[r * f + j] - mean) * inv;
                let v = (gamma[j] * hn + beta[j]) * msk[r];
                rows[r * f + j] = v.max(0.0);
            }
        }
    }
}

/// The forward pass exactly as it was before the engine refactor:
/// per-(sample, channel) inlined loops.
fn naive_forward(cfg: &ModelConfig, ps: &ParamSet, mb: &ModelBatch) -> anyhow::Result<Vec<f32>> {
    let b = mb.batch;
    let m = cfg.max_nodes;
    let mut h = mb.x.clone();
    let mut fin = cfg.feat_dim;
    for (li, &fout) in cfg.hidden.iter().enumerate() {
        let w = ps.slice(cfg, &format!("conv{li}.w"))?;
        let bias = ps.slice(cfg, &format!("conv{li}.b"))?;
        let gamma = ps.slice(cfg, &format!("conv{li}.gamma"))?;
        let beta = ps.slice(cfg, &format!("conv{li}.beta"))?;
        let mut y = vec![0f32; b * m * fout];
        let mut u = vec![0f32; m * fout];
        for bi in 0..b {
            let x_s = &h[bi * m * fin..(bi + 1) * m * fin];
            for ch in 0..cfg.channels {
                let w_ch = &w[ch * fin * fout..(ch + 1) * fin * fout];
                let b_ch = &bias[ch * fout..(ch + 1) * fout];
                for r in 0..m {
                    let dst = &mut u[r * fout..(r + 1) * fout];
                    dst.copy_from_slice(b_ch);
                    let src = &x_s[r * fin..(r + 1) * fin];
                    for (k, &xv) in src.iter().enumerate() {
                        if xv == 0.0 {
                            continue;
                        }
                        let wrow = &w_ch[k * fout..(k + 1) * fout];
                        for j in 0..fout {
                            dst[j] += xv * wrow[j];
                        }
                    }
                }
                let r = mb.ell_width;
                let base = (bi * cfg.channels + ch) * m * r;
                let y_s = &mut y[bi * m * fout..(bi + 1) * m * fout];
                for rid in 0..m {
                    let dst = &mut y_s[rid * fout..(rid + 1) * fout];
                    for slot in 0..r {
                        let val = mb.ell_vals[base + rid * r + slot];
                        if val == 0.0 {
                            continue;
                        }
                        let cid = mb.ell_cols[base + rid * r + slot] as usize;
                        let src = &u[cid * fout..(cid + 1) * fout];
                        for j in 0..fout {
                            dst[j] += val * src[j];
                        }
                    }
                }
            }
        }
        naive_graph_norm_relu(&mut y, &mb.mask, gamma, beta, b, m, fout);
        h = y;
        fin = fout;
    }
    let w_out = ps.slice(cfg, "readout.w")?;
    let b_out = ps.slice(cfg, "readout.b")?;
    let mut logits = vec![0f32; b * cfg.n_out];
    for bi in 0..b {
        let dst = &mut logits[bi * cfg.n_out..(bi + 1) * cfg.n_out];
        dst.copy_from_slice(b_out);
        for r in 0..m {
            let src = &h[(bi * m + r) * fin..(bi * m + r + 1) * fin];
            for (k, &hv) in src.iter().enumerate() {
                if hv == 0.0 {
                    continue;
                }
                let wrow = &w_out[k * cfg.n_out..(k + 1) * cfg.n_out];
                for j in 0..cfg.n_out {
                    dst[j] += hv * wrow[j];
                }
            }
        }
    }
    Ok(logits)
}

#[test]
fn gcn_forward_bit_stable_vs_pre_engine_implementation() {
    let cfg = ModelConfig::synthetic("tox21").unwrap();
    let ps = ParamSet::random_init(&cfg, 0xBEEF);
    let d = Dataset::generate(DatasetKind::Tox21, 8, 17);
    let idx: Vec<usize> = (0..6).collect();
    let mb = d.pack_batch(&idx, cfg.max_nodes, cfg.ell_width).unwrap();

    let want = naive_forward(&cfg, &ps, &mb).unwrap();
    let got = reference::forward(&cfg, &ps, &mb).unwrap();
    assert_eq!(got, want, "engine-routed forward drifted from the pre-engine math");

    for threads in [2, 8] {
        let par = reference::forward_with(&cfg, &ps, &mb, &Executor::new(threads)).unwrap();
        assert_eq!(par, want, "threads={threads}");
    }
}
