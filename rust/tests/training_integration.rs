//! Integration: training in both dispatch modes over real artifacts.

use std::path::{Path, PathBuf};

use bspmm::coordinator::trainer::{TrainMode, Trainer};
use bspmm::graph::dataset::{Dataset, DatasetKind};
use bspmm::util::rng::Rng;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: run `make artifacts` first");
        None
    }
}

#[test]
fn batched_training_reduces_loss() {
    let Some(dir) = artifacts_dir() else { return };
    let mut tr = Trainer::new(&dir, "tox21").unwrap();
    let data = Dataset::generate(DatasetKind::Tox21, 200, 21);
    let mut idx: Vec<usize> = (0..data.len()).collect();
    let mut rng = Rng::new(1);

    let mut first = None;
    let mut last = 0.0;
    for epoch in 0..6 {
        rng.shuffle(&mut idx);
        let stats = tr
            .train_epoch(TrainMode::Batched, &data, &idx, 0.02, epoch)
            .unwrap();
        first.get_or_insert(stats.mean_loss);
        last = stats.mean_loss;
        assert!(stats.mean_loss.is_finite());
        assert_eq!(stats.dispatches, (200 / tr.cfg.train_batch) as u64);
    }
    let first = first.unwrap();
    assert!(
        last < first * 0.9,
        "loss did not fall: {first} -> {last}"
    );
}

#[test]
fn nonbatched_step_matches_batched_step() {
    // Identical initial params + identical minibatch => identical new
    // params (up to accumulation-order rounding). This is the exact
    // decomposability contract that makes Table II apples-to-apples.
    let Some(dir) = artifacts_dir() else { return };
    let data = Dataset::generate(DatasetKind::Tox21, 64, 22);
    let idx: Vec<usize> = (0..50).collect();
    let mb = {
        let tr = Trainer::new(&dir, "tox21").unwrap();
        data.pack_batch(&idx, tr.cfg.max_nodes, tr.cfg.ell_width).unwrap()
    };

    let mut tr_b = Trainer::new(&dir, "tox21").unwrap();
    let loss_b = tr_b.step_batched(&mb, 0.05).unwrap();

    let mut tr_s = Trainer::new(&dir, "tox21").unwrap();
    let loss_s = tr_s.step_nonbatched(&mb, 0.05).unwrap();

    assert!(
        (loss_b - loss_s).abs() <= 1e-3 + 1e-3 * loss_b.abs(),
        "losses diverge: batched {loss_b} vs non-batched {loss_s}"
    );
    let max_diff = tr_b
        .params
        .data
        .iter()
        .zip(&tr_s.params.data)
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max);
    assert!(max_diff < 5e-4, "params diverge: max |diff| = {max_diff}");
    // Dispatch counts tell the Fig. 11 story: 1 vs B+1.
    assert_eq!(tr_b.dispatches, 1);
    assert_eq!(tr_s.dispatches, 51);
}

#[test]
fn evaluate_reports_sane_metrics() {
    let Some(dir) = artifacts_dir() else { return };
    let mut tr = Trainer::new(&dir, "tox21").unwrap();
    let data = Dataset::generate(DatasetKind::Tox21, 100, 23);
    let idx: Vec<usize> = (0..100).collect();
    let (loss, acc) = tr.evaluate(&data, &idx).unwrap();
    assert!(loss.is_finite() && loss > 0.0);
    assert!((0.0..=1.0).contains(&acc));
}

#[test]
fn kfold_training_improves_heldout_accuracy() {
    let Some(dir) = artifacts_dir() else { return };
    let mut tr = Trainer::new(&dir, "tox21").unwrap();
    let data = Dataset::generate(DatasetKind::Tox21, 250, 24);
    let (train, test) = data.kfold(5, 0);
    let (_, acc_before) = tr.evaluate(&data, &test).unwrap();
    let mut idx = train.clone();
    let mut rng = Rng::new(2);
    for epoch in 0..5 {
        rng.shuffle(&mut idx);
        tr.train_epoch(TrainMode::Batched, &data, &idx, 0.02, epoch)
            .unwrap();
    }
    let (_, acc_after) = tr.evaluate(&data, &test).unwrap();
    assert!(
        acc_after > acc_before - 0.02,
        "held-out accuracy regressed: {acc_before} -> {acc_after}"
    );
}
